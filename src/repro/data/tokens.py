"""Synthetic token pipeline.

Deterministic, restart-safe, host-shardable: batch for step ``s`` is a pure
function of (seed, s), so resuming from a checkpoint reproduces the exact
stream with no iterator state to persist — and an elastic restart on a
different data-parallel size re-slices the same global batch.
"""
from __future__ import annotations

from typing import Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np


def synthetic_batch(seed: int, step: int, batch: int, seq: int, vocab: int,
                    dtype=jnp.int32) -> dict:
    """Global batch for one step: zipf-ish marginals + a copy structure so a
    real model can actually reduce loss (tokens repeat with lag 64)."""
    key = jax.random.fold_in(jax.random.PRNGKey(seed), step)
    k1, k2 = jax.random.split(key)
    u = jax.random.uniform(k1, (batch, seq))
    # zipf via inverse-CDF approximation on ranks
    ranks = jnp.floor(jnp.exp(u * jnp.log(float(vocab)))).astype(dtype)
    toks = jnp.clip(ranks - 1, 0, vocab - 1)
    lag = 64
    if seq > lag:
        copy_mask = jax.random.bernoulli(k2, 0.5, (batch, seq - lag))
        tail = jnp.where(copy_mask, toks[:, :-lag], toks[:, lag:])
        toks = jnp.concatenate([toks[:, :lag], tail], axis=1)
    inputs = toks[:, :-1]
    targets = toks[:, 1:]
    return {"tokens": inputs, "labels": targets}


def token_stream(seed: int, batch: int, seq: int, vocab: int,
                 start_step: int = 0) -> Iterator[dict]:
    step = start_step
    while True:
        yield synthetic_batch(seed, step, batch, seq, vocab)
        step += 1
