"""Unstructured graph Laplacians — the workload class the geometric
multigrid cannot touch (no grid, no stencil layout) and the paper's §1
motivation for sparse linear algebra on "unstructured data: finite element
meshes, graphs, point clouds".  Used by the ``precond="amg"`` tests,
quickstart and benchmarks.
"""
from __future__ import annotations

from typing import Tuple

import numpy as np

from ..core.sparse import SparseTensor


def geometric_graph(n: int, *, radius: float | None = None, seed: int = 0,
                    dim: int = 2) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Random geometric graph on ``n`` points in the unit cube: connect
    pairs within ``radius`` (default tuned for a ~7-neighbour average).
    Returns ``(coords, edge_i, edge_j)`` with each undirected edge listed
    once (i < j)."""
    rng = np.random.default_rng(seed)
    coords = rng.random((n, dim))
    if radius is None:
        # target a ~7-neighbour average: π r² n ≈ 7 (2-D), connected w.h.p.
        radius = (7.0 / (np.pi * n)) ** 0.5 if dim == 2 \
            else (7.0 / n) ** (1.0 / dim)
    # cell binning keeps the pair search O(n) instead of O(n²)
    nb = max(int(1.0 / radius), 1)
    cell = np.minimum((coords / (1.0 / nb)).astype(np.int64), nb - 1)
    key = cell[:, 0] * nb + (cell[:, 1] if dim > 1 else 0)
    order = np.argsort(key, kind="stable")
    ptr = np.searchsorted(key[order], np.arange(nb * nb + 1))
    ei, ej = [], []
    for cx in range(nb):
        for cy in range(nb if dim > 1 else 1):
            mine = order[ptr[cx * nb + cy]:ptr[cx * nb + cy + 1]]
            if not mine.size:
                continue
            cand = [mine]
            for dx in (0, 1):
                for dy in (-1, 0, 1):
                    if (dx, dy) <= (0, 0):
                        continue
                    x2, y2 = cx + dx, cy + dy
                    if 0 <= x2 < nb and 0 <= y2 < nb:
                        cand.append(order[ptr[x2 * nb + y2]:
                                          ptr[x2 * nb + y2 + 1]])
            other = np.concatenate(cand)
            d2 = ((coords[mine][:, None, :] - coords[other][None, :, :]) ** 2
                  ).sum(-1)
            ii, jj = np.nonzero(d2 <= radius * radius)
            gi, gj = mine[ii], other[jj]
            m = gi < gj
            ei.append(gi[m]); ej.append(gj[m])
    return coords, np.concatenate(ei), np.concatenate(ej)


def graph_laplacian(n: int, *, radius: float | None = None, seed: int = 0,
                    shift: float = 1e-2, dtype=np.float64) -> SparseTensor:
    """SPD graph Laplacian L + γ·deg·I of a random geometric graph (COO).

    The γ-shift (relative to the mean degree) grounds the constant
    nullspace, mimicking a Dirichlet boundary / mass term: the result is SPD
    with a condition number that grows with the graph diameter — exactly the
    regime where Jacobi-CG stalls and algebraic coarsening shines.  The
    pattern is unstructured (no stencil layout), so ``precond="mg"`` is
    inapplicable by construction; use ``precond="amg"``.
    """
    _, ei, ej = geometric_graph(n, radius=radius, seed=seed)
    deg = np.bincount(np.concatenate([ei, ej]), minlength=n).astype(dtype)
    gamma = shift * max(float(deg.mean()), 1.0)
    rows = np.concatenate([np.arange(n), ei, ej])
    cols = np.concatenate([np.arange(n), ej, ei])
    vals = np.concatenate([deg + gamma,
                           -np.ones(len(ei), dtype),
                           -np.ones(len(ej), dtype)]).astype(dtype)
    props = {"symmetric": True, "spd_hint": True, "sorted_rows": False,
             "struct_full_diag": True}
    return SparseTensor(vals, rows, cols, (n, n), props=props)
