"""Poisson problem generators — the paper's benchmark workload (§4).

``poisson2d``      : constant-coefficient 5-point Laplacian, COO, Dirichlet.
``poisson2d_vc``   : variable-coefficient −∇·(κ∇u) cell-centered FD assembly
                     (the §4.4 inverse-coefficient operator), differentiable
                     in κ, with both COO and stencil-kernel layouts.
``poisson1d``      : tridiagonal, for cheap unit tests.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.sparse import SparseTensor
from ..kernels.stencil5 import Stencil5Meta


def poisson1d(n: int, dtype=np.float64) -> SparseTensor:
    i = np.arange(n)
    rows = np.concatenate([i, i[1:], i[:-1]])
    cols = np.concatenate([i, i[1:] - 1, i[:-1] + 1])
    vals = np.concatenate([np.full(n, 2.0), np.full(n - 1, -1.0),
                           np.full(n - 1, -1.0)]).astype(dtype)
    return SparseTensor(vals, rows, cols, (n, n))


def poisson2d(ng: int, dtype=np.float64, build_kernel_layout: bool = False
              ) -> SparseTensor:
    """(ng×ng interior points, h=1/(ng+1), scaled by 1/h² omitted — the paper
    benchmarks the unit-scaled stencil)."""
    n = ng * ng
    idx = np.arange(n).reshape(ng, ng)
    rows = [idx.ravel()]
    cols = [idx.ravel()]
    vals = [np.full(n, 4.0, dtype)]
    for (di, dj) in ((-1, 0), (1, 0), (0, -1), (0, 1)):
        src = idx[max(0, -di):ng - max(0, di), max(0, -dj):ng - max(0, dj)]
        dst = idx[max(0, di):ng - max(0, -di), max(0, dj):ng - max(0, -dj)]
        rows.append(src.ravel())
        cols.append(dst.ravel())
        vals.append(np.full(src.size, -1.0, dtype))
    return SparseTensor(np.concatenate(vals), np.concatenate(rows),
                        np.concatenate(cols), (n, n),
                        build_kernel_layout=build_kernel_layout)


# ---------------------------------------------------------------------------
# variable-coefficient assembly (differentiable in κ) — paper §4.4
# ---------------------------------------------------------------------------

def vc_pattern(ng: int) -> Tuple[np.ndarray, np.ndarray, Stencil5Meta]:
    """COO pattern matching the (5, ng, ng) signed coefficient planes of the
    stencil kernel: entry order = planes (C, N, S, W, E) × row-major cells;
    out-of-domain neighbours keep a slot with a structurally-zero value (and
    a clamped in-range column) so COO and stencil layouts share one ``val``."""
    idx = np.arange(ng * ng).reshape(ng, ng)
    rows, cols = [], []
    offs = [(0, 0), (-1, 0), (1, 0), (0, -1), (0, 1)]
    for (di, dj) in offs:
        r = idx
        ii = np.clip(np.arange(ng)[:, None] + di, 0, ng - 1)
        jj = np.clip(np.arange(ng)[None, :] + dj, 0, ng - 1)
        c = idx[ii, jj]
        rows.append(r.ravel())
        cols.append(c.ravel())
    meta = Stencil5Meta(nx=ng, ny=ng)
    return np.concatenate(rows), np.concatenate(cols), meta


def vc_coefficients(kappa: jax.Array) -> jax.Array:
    """κ (ng, ng) cell conductivities → signed planes (5, ng, ng), flattened.

    Face coefficient = harmonic mean of adjacent cells; Dirichlet u=0 via
    boundary faces with coefficient κ_cell (ghost κ = κ_cell).  Fully
    differentiable in κ — this is the assembly inside the §4.4 training loop.
    """
    ng = kappa.shape[0]

    def hmean(a, b):
        return 2.0 * a * b / (a + b + 1e-30)

    kN = jnp.where(jnp.arange(ng)[:, None] > 0,
                   hmean(kappa, jnp.roll(kappa, 1, 0)), kappa)
    kS = jnp.where(jnp.arange(ng)[:, None] < ng - 1,
                   hmean(kappa, jnp.roll(kappa, -1, 0)), kappa)
    kW = jnp.where(jnp.arange(ng)[None, :] > 0,
                   hmean(kappa, jnp.roll(kappa, 1, 1)), kappa)
    kE = jnp.where(jnp.arange(ng)[None, :] < ng - 1,
                   hmean(kappa, jnp.roll(kappa, -1, 1)), kappa)
    C = kN + kS + kW + kE
    # neighbour couplings: zero at the domain boundary (Dirichlet)
    N = jnp.where(jnp.arange(ng)[:, None] > 0, -kN, 0.0)
    S = jnp.where(jnp.arange(ng)[:, None] < ng - 1, -kS, 0.0)
    W = jnp.where(jnp.arange(ng)[None, :] > 0, -kW, 0.0)
    E = jnp.where(jnp.arange(ng)[None, :] < ng - 1, -kE, 0.0)
    return jnp.stack([C, N, S, W, E]).reshape(-1)


def poisson2d_vc(kappa: jax.Array, *, use_stencil_kernel: bool = False
                 ) -> SparseTensor:
    """Assemble A(κ) as a SparseTensor (differentiable values)."""
    ng = kappa.shape[0]
    rows, cols, meta = vc_pattern(ng)
    val = vc_coefficients(kappa)
    props = {"symmetric": True, "spd_hint": True, "sorted_rows": False}
    return SparseTensor(val, rows, cols, (ng * ng, ng * ng), props=props,
                        stencil=meta if use_stencil_kernel else None,
                        validate=False)
