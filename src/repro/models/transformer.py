"""Architecture assembly: one composable decoder (+optional encoder) covering
all 10 assigned architectures via the config layer pattern.

* homogeneous layer *periods* are stacked and scanned (one period traced once
  → compile time independent of depth; remainder layers applied explicitly);
* ``jax.checkpoint`` on the period body implements the remat policy;
* decode threads a per-period cache pytree through the same scan;
* parameter sharding is name-based (``param_axes``) so the launcher can build
  NamedShardings for any mesh without touching model code.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..launch.shardings import logical
from . import attention as attn
from . import moe as moe_mod
from . import ssm as ssm_mod
from .layers import (adtype, embed, init_embed, init_mlp, init_rmsnorm, mlp,
                     pdtype, rmsnorm, unembed)

# ---------------------------------------------------------------------------
# per-layer init
# ---------------------------------------------------------------------------

def _init_layer(key, cfg: ModelConfig, kind: str) -> dict:
    dt = pdtype(cfg)
    ks = jax.random.split(key, 4)
    p: Dict[str, Any] = {"ln1": init_rmsnorm(cfg.d_model, dt)}
    if kind in ("attn", "attn_local", "attn_bidir"):
        p["attn"] = attn.init_attention(ks[0], cfg)
        if cfg.d_ff:
            p["ln2"] = init_rmsnorm(cfg.d_model, dt)
            p["mlp"] = init_mlp(ks[1], cfg)
    elif kind == "moe":
        p["attn"] = attn.init_attention(ks[0], cfg)
        p["ln2"] = init_rmsnorm(cfg.d_model, dt)
        p["moe"] = moe_mod.init_moe(ks[1], cfg)
    elif kind == "rec":
        p["rec"] = ssm_mod.init_rglru(ks[0], cfg)
        if cfg.d_ff:
            p["ln2"] = init_rmsnorm(cfg.d_model, dt)
            p["mlp"] = init_mlp(ks[1], cfg)
    elif kind == "ssd":
        p["ssd"] = ssm_mod.init_ssd(ks[0], cfg)
        if cfg.d_ff:
            p["ln2"] = init_rmsnorm(cfg.d_model, dt)
            p["mlp"] = init_mlp(ks[1], cfg)
    else:
        raise ValueError(f"unknown layer kind {kind!r}")
    if cfg.enc_dec and kind != "attn_bidir":
        p["ln_x"] = init_rmsnorm(cfg.d_model, dt)
        p["cross"] = attn.init_attention(ks[2], cfg, cross=True)
    return p


def _apply_layer(p: dict, x: jax.Array, cfg: ModelConfig, kind: str, *,
                 positions, enc_out=None) -> Tuple[jax.Array, jax.Array]:
    """Returns (x, moe_aux).  The residual stream is constrained to the
    sequence-sharded layout between blocks (launch/shardings.py seq_res)."""
    aux = jnp.zeros((), jnp.float32)
    x = logical(x, "batch", "seq_res", "embed")
    h = rmsnorm(p["ln1"], x, cfg.norm_eps)
    # H5 (EXPERIMENTS §Perf): pin the bf16 norm output to the sequence-
    # sharded layout so GSPMD's full-sequence gather happens AFTER the
    # f32→bf16 convert instead of on the f32 rmsnorm internals.
    h = logical(h, "batch", "seq_norm", "embed")
    if kind in ("attn", "attn_local", "attn_bidir"):
        mode = {"attn": "causal", "attn_local": "local",
                "attn_bidir": "bidir"}[kind]
        x = x + attn.attention(p["attn"], h, cfg, positions=positions,
                               mode=mode)
    elif kind == "moe":
        x = x + attn.attention(p["attn"], h, cfg, positions=positions,
                               mode="causal")
    elif kind == "rec":
        x = x + ssm_mod.rglru_forward(p["rec"], h, cfg)
    elif kind == "ssd":
        x = x + ssm_mod.ssd_forward(p["ssd"], h, cfg)
    if cfg.enc_dec and "cross" in p:
        hx = rmsnorm(p["ln_x"], x, cfg.norm_eps)
        x = x + attn.attention(p["cross"], hx, cfg, positions=positions,
                               mode="cross", enc_out=enc_out)
    if "mlp" in p:
        h2 = rmsnorm(p["ln2"], x, cfg.norm_eps)
        h2 = logical(h2, "batch", "seq_norm", "embed")
        x = x + mlp(p["mlp"], h2, cfg)
    elif "moe" in p:
        h2 = rmsnorm(p["ln2"], x, cfg.norm_eps)
        h2 = logical(h2, "batch", "seq_norm", "embed")
        y, aux = moe_mod.moe_mlp(p["moe"], h2, cfg)
        x = x + y
    x = logical(x, "batch", "seq_res", "embed")
    return x, aux


# ---------------------------------------------------------------------------
# period decomposition
# ---------------------------------------------------------------------------

def _period_split(cfg: ModelConfig) -> Tuple[int, int]:
    period = len(cfg.layer_pattern)
    n_full = cfg.n_layers // period
    n_rem = cfg.n_layers - n_full * period
    return n_full, n_rem


def init_params(cfg: ModelConfig, key) -> dict:
    n_full, n_rem = _period_split(cfg)
    period = cfg.layer_pattern
    k_embed, k_stack, k_rem, k_enc = jax.random.split(key, 4)

    def one_period(k):
        ks = jax.random.split(k, len(period))
        return {f"l{i}": _init_layer(ks[i], cfg, kind)
                for i, kind in enumerate(period)}

    stack = jax.vmap(one_period)(jax.random.split(k_stack, n_full))
    rem = {f"l{i}": _init_layer(k, cfg, period[i])
           for i, k in enumerate(jax.random.split(k_rem, max(n_rem, 1))[:n_rem])}
    params = {
        "embed": init_embed(k_embed, cfg),
        "final_norm": init_rmsnorm(cfg.d_model, pdtype(cfg)),
        "stack": stack,
        "rem": rem,
    }
    if cfg.enc_dec:
        def enc_layer(k):
            return _init_layer(k, cfg, "attn_bidir")
        params["encoder"] = {
            "stack": jax.vmap(enc_layer)(
                jax.random.split(k_enc, cfg.n_enc_layers)),
            "final_norm": init_rmsnorm(cfg.d_model, pdtype(cfg)),
        }
    return params


def param_shapes(cfg: ModelConfig, key=None) -> Any:
    key = key if key is not None else jax.random.PRNGKey(0)
    return jax.eval_shape(lambda k: init_params(cfg, k), key)


# ---------------------------------------------------------------------------
# name-based parameter sharding axes
# ---------------------------------------------------------------------------

_AXES_TABLE = {
    "wq": ("p_embed", "p_heads"), "wk": ("p_embed", "p_kv_heads"),
    "wv": ("p_embed", "p_kv_heads"), "wo": ("p_heads", "p_embed"),
    "bq": ("p_heads",), "bk": ("p_kv_heads",), "bv": ("p_kv_heads",),
    "up": ("p_embed", "p_ff"), "gate": ("p_embed", "p_ff"),
    "down": ("p_ff", "p_embed"),
    "tok": ("p_vocab", "p_embed"), "unembed": ("p_embed", "p_vocab"),
    "router": ("p_embed", None),
    "w_gate": ("p_experts", "p_embed", "p_expert_ff"),
    "w_up": ("p_experts", "p_embed", "p_expert_ff"),
    "w_down": ("p_experts", "p_expert_ff", "p_embed"),
    "in_proj": ("p_embed", "p_ff"), "out_proj": ("p_ff", "p_embed"),
    "w_main": ("p_embed", "p_ff"), "w_gate_br": ("p_embed", "p_ff"),
    "w_r": ("p_ff", None), "w_i": ("p_ff", None), "w_out": ("p_ff", "p_embed"),
    "w": (None, "p_ff"),                       # conv kernels
    "scale": (None,), "lam": ("p_ff",),
    "A_log": (None,), "D": (None,), "dt_bias": (None,),
}


def param_axes(params) -> Any:
    """Pytree of logical-axis tuples parallel to ``params`` (name-based)."""
    return _axes_by_name(params, _AXES_TABLE)


_CACHE_AXES_TABLE = {
    # KV caches shard on the SEQUENCE dim over the model axis ("seq_kv"):
    # kv_heads (often 8) rarely divide a 16-way model axis, and the
    # divisibility fallback would replicate the dominant decode buffer.
    "k": ("batch", "seq_kv", "kv_heads_cache", None),
    "v": ("batch", "seq_kv", "kv_heads_cache", None),
    "pos": ("seq_kv",),
    "cross_k": ("batch", "seq_kv", "kv_heads_cache", None),
    "cross_v": ("batch", "seq_kv", "kv_heads_cache", None),
    "h": "H_SPECIAL",                    # rglru (B,w) vs ssd (B,H,N,P)
    "conv": ("batch", None, "ff"),
}


def cache_axes(state) -> Any:
    """Logical axes for a decode-state pytree (name-based)."""
    def special(name, leaf):
        if name == "h":
            return (("batch", "heads", None, None) if leaf.ndim >= 4
                    else ("batch", "ff"))
        return None
    return _axes_by_name(state, _CACHE_AXES_TABLE, special)


def _axes_by_name(tree, table, special=None) -> Any:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    treedef = jax.tree_util.tree_structure(tree)

    def axes_for(path, leaf):
        name = None
        for part in reversed(path):
            if isinstance(part, jax.tree_util.DictKey):
                name = part.key
                break
        ax = table.get(name, (None,) * leaf.ndim)
        if special is not None and isinstance(ax, str):
            ax = special(name, leaf)
        if ax is None:
            ax = (None,) * leaf.ndim
        if len(ax) == leaf.ndim - 1:
            ax = ("layers",) + tuple(ax)       # stacked period dim
        if len(ax) != leaf.ndim:
            ax = (None,) * leaf.ndim
        return tuple(ax)

    leaves = [axes_for(p, l) for p, l in flat]
    return jax.tree_util.tree_unflatten(treedef, leaves)


# ---------------------------------------------------------------------------
# forward (train / prefill)
# ---------------------------------------------------------------------------

def _maybe_remat(fn, cfg: ModelConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        pol = jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        return jax.checkpoint(fn, policy=pol)
    return jax.checkpoint(fn)


def _encode(params, cfg: ModelConfig, enc_frames: jax.Array) -> jax.Array:
    """Whisper encoder over precomputed frame embeddings (frontend stub)."""
    x = enc_frames.astype(adtype(cfg))
    F = x.shape[1]
    pos = jnp.broadcast_to(jnp.arange(F)[None], x.shape[:2])

    def body(x, lp):
        x, _ = _apply_layer(lp, x, cfg, "attn_bidir", positions=pos)
        return x, None

    x, _ = jax.lax.scan(_maybe_remat(body, cfg), x, params["encoder"]["stack"])
    return rmsnorm(params["encoder"]["final_norm"], x, cfg.norm_eps)


def forward(params, cfg: ModelConfig, tokens: jax.Array, *,
            positions: Optional[jax.Array] = None,
            patches: Optional[jax.Array] = None,
            enc_frames: Optional[jax.Array] = None,
            last_only: bool = False,
            return_hidden: bool = False
            ) -> Tuple[jax.Array, jax.Array]:
    """Full-sequence forward.  Returns (logits (B,S,V) f32, moe_aux).
    ``last_only`` unembeds just the final position (prefill serving);
    ``return_hidden`` skips unembedding (the chunked-CE loss path)."""
    x = embed(params["embed"], tokens, cfg)
    if patches is not None:                    # VLM stub: prefix patch embeds
        x = jnp.concatenate([patches.astype(x.dtype), x], axis=1)
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    enc_out = _encode(params, cfg, enc_frames) if cfg.enc_dec else None
    period = cfg.layer_pattern
    n_full, n_rem = _period_split(cfg)

    def period_body(carry, lp):
        x, aux = carry
        for i, kind in enumerate(period):
            x, a = _apply_layer(lp[f"l{i}"], x, cfg, kind,
                                positions=positions, enc_out=enc_out)
            aux = aux + a
        return (x, aux), None

    aux0 = jnp.zeros((), jnp.float32)
    (x, aux), _ = jax.lax.scan(_maybe_remat(period_body, cfg), (x, aux0),
                               params["stack"])
    for i in range(n_rem):
        x, a = _apply_layer(params["rem"][f"l{i}"], x, cfg, period[i],
                            positions=positions, enc_out=enc_out)
        aux = aux + a
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    if return_hidden:
        return x, aux
    if last_only:
        x = x[:, -1:]
    return unembed(params["embed"], x, cfg), aux


# ---------------------------------------------------------------------------
# decode (serve)
# ---------------------------------------------------------------------------

def _layer_cache(params_layer, cfg: ModelConfig, kind: str, batch: int,
                 seq_len: int, dtype, enc_out=None) -> dict:
    c: Dict[str, Any] = {}
    if kind in ("attn", "attn_local", "moe"):
        mode = "local" if kind == "attn_local" else "causal"
        cap = attn.cache_capacity(cfg, mode, seq_len)
        c["kv"] = attn.init_cache(cfg, batch, cap, mode, dtype)
    elif kind == "rec":
        c["state"] = ssm_mod.init_rglru_state(cfg, batch, dtype)
    elif kind == "ssd":
        c["state"] = ssm_mod.init_ssd_state(cfg, batch, dtype)
    if cfg.enc_dec and kind != "attn_bidir":
        k, v = attn._project_kv(params_layer["cross"], enc_out, cfg, cross=True)
        c["cross_k"], c["cross_v"] = k, v
    return c


def init_decode_state(params, cfg: ModelConfig, batch: int, seq_len: int, *,
                      enc_frames: Optional[jax.Array] = None) -> dict:
    """Decode cache sized for a ``seq_len`` context (ring-capped for local
    layers / O(1) for recurrent ones — the long_500k path)."""
    dtype = adtype(cfg)
    period = cfg.layer_pattern
    n_full, n_rem = _period_split(cfg)
    enc_out = _encode(params, cfg, enc_frames) if cfg.enc_dec else None

    def one_period(lp):
        return {f"l{i}": _layer_cache(lp[f"l{i}"], cfg, kind, batch, seq_len,
                                      dtype, enc_out)
                for i, kind in enumerate(period)}

    state = {
        "stack": jax.vmap(one_period)(params["stack"]) if n_full else {},
        "rem": {f"l{i}": _layer_cache(params["rem"][f"l{i}"], cfg, period[i],
                                      batch, seq_len, dtype, enc_out)
                for i in range(n_rem)},
    }
    return state


def _apply_layer_decode(p, c, x, cfg: ModelConfig, kind: str, *, pos):
    h = rmsnorm(p["ln1"], x, cfg.norm_eps)
    if kind in ("attn", "attn_local", "moe"):
        mode = "local" if kind == "attn_local" else "causal"
        y, kv = attn.decode_attention(p["attn"], h, c["kv"], cfg, pos=pos,
                                      mode=mode)
        x = x + y
        c = dict(c, kv=kv)
    elif kind == "rec":
        y, st = ssm_mod.rglru_step(p["rec"], h, c["state"], cfg)
        x = x + y
        c = dict(c, state=st)
    elif kind == "ssd":
        y, st = ssm_mod.ssd_step(p["ssd"], h, c["state"], cfg)
        x = x + y
        c = dict(c, state=st)
    if cfg.enc_dec and "cross" in p:
        hx = rmsnorm(p["ln_x"], x, cfg.norm_eps)
        y, _ = attn.decode_attention(p["cross"], hx, None, cfg, pos=pos,
                                     mode="cross",
                                     cross_kv=(c["cross_k"], c["cross_v"]))
        x = x + y
    if "mlp" in p:
        x = x + mlp(p["mlp"], rmsnorm(p["ln2"], x, cfg.norm_eps), cfg)
    elif "moe" in p:
        y, _ = moe_mod.moe_mlp(p["moe"], rmsnorm(p["ln2"], x, cfg.norm_eps), cfg)
        x = x + y
    return x, c


def decode_step(params, cfg: ModelConfig, state: dict, token: jax.Array,
                pos: jax.Array) -> Tuple[jax.Array, dict]:
    """One serve step: ``token`` (B, 1) → logits (B, 1, V), updated state."""
    x = embed(params["embed"], token, cfg)
    period = cfg.layer_pattern
    n_full, n_rem = _period_split(cfg)

    def period_body(x, scanned):
        lp, lc = scanned
        new_c = {}
        for i, kind in enumerate(period):
            x, new_c[f"l{i}"] = _apply_layer_decode(
                lp[f"l{i}"], lc[f"l{i}"], x, cfg, kind, pos=pos)
        return x, new_c

    if n_full:
        x, new_stack = jax.lax.scan(period_body, x,
                                    (params["stack"], state["stack"]))
    else:
        new_stack = {}
    new_rem = {}
    for i in range(n_rem):
        x, new_rem[f"l{i}"] = _apply_layer_decode(
            params["rem"][f"l{i}"], state["rem"][f"l{i}"], x, cfg, period[i],
            pos=pos)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = unembed(params["embed"], x, cfg)
    return logits, {"stack": new_stack, "rem": new_rem}
