"""Mixture-of-Experts layer (granite-moe, dbrx).

Sort-based capacity dispatch rather than GShard one-hot einsums: the one-hot
dispatch tensor inflates HLO FLOPs ~E× (it is dense to XLA), wrecking the
MODEL_FLOPS/HLO_FLOPS roofline ratio.  Here tokens are sorted by expert id
*within each sequence group*, scattered into an (E, C, d) buffer (the
sharding boundary where GSPMD inserts the expert-parallel all_to_all), run
through batched expert MLPs at true active-parameter FLOPs, and scattered
back with gate weighting.  Capacity overflow drops tokens (standard; the
residual stream carries them — counted in the aux metrics).

The BELL-kernel connection (DESIGN.md §Arch-applicability): the (E, C, d)
expert buffer is exactly a block-ELL layout — dense per-expert tiles plus an
integer block-to-expert table — so the same TPU tiling idea the paper's SpMV
uses serves expert dispatch.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..launch.shardings import logical
from .layers import dense_init, pdtype


def moe_capacity(cfg: ModelConfig, seq: int) -> int:
    c = int(seq * cfg.top_k / cfg.n_experts * cfg.capacity_factor)
    return max(8, -(-c // 8) * 8)


def init_moe(key, cfg: ModelConfig) -> dict:
    d, E, f = cfg.d_model, cfg.n_experts, cfg.d_ff_expert
    dt = pdtype(cfg)
    ks = jax.random.split(key, 4)
    return {
        "router": dense_init(ks[0], (d, E), dt, scale=0.02),
        "w_gate": dense_init(ks[1], (E, d, f), dt),
        "w_up": dense_init(ks[2], (E, d, f), dt),
        "w_down": dense_init(ks[3], (E, f, d), dt),
    }


def moe_mlp(p: dict, x: jax.Array, cfg: ModelConfig) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, d) → (y, aux_loss).  Routing groups = sequences (rows of the
    batch), so sort/scatter stay device-local under batch sharding and the
    only cross-device movement is the (B, E, C, d) resharding."""
    B, S, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    C = moe_capacity(cfg, S)
    dt = x.dtype

    logits = (x @ p["router"].astype(dt)).astype(jnp.float32)   # (B,S,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)              # (B,S,k)
    gate_vals = (gate_vals / jnp.sum(gate_vals, -1, keepdims=True)).astype(dt)

    # ---- sort-based routing (all GATHERS — scatters replicate under GSPMD) --
    eidx = expert_idx.reshape(B, S * k)
    order = jnp.argsort(eidx, axis=1, stable=True)               # sorted→copy
    se = jnp.take_along_axis(eidx, order, 1)                     # sorted experts
    st = order // k                                              # token of copy
    counts = jax.vmap(lambda e: jnp.bincount(e, length=E))(eidx)  # (B,E)
    seg_start = jnp.cumsum(counts, axis=1) - counts               # (B,E)
    rank = jnp.arange(S * k)[None, :] - jnp.take_along_axis(seg_start, se, 1)

    # load-balance aux (Switch-style) from the routing counts — no one-hots
    frac_routed = counts.astype(jnp.float32) / (S * k)
    mean_prob = jnp.mean(probs, axis=1)
    aux = E * jnp.mean(jnp.sum(frac_routed * mean_prob, -1))

    # ---- dispatch: slot (e,c) ← token st[seg_start[e]+c] (pure gathers) ----
    c_idx = jnp.arange(C)
    pos = seg_start[:, :, None] + c_idx[None, None, :]            # (B,E,C)
    valid = c_idx[None, None, :] < counts[:, :, None]
    pos_c = jnp.clip(pos, 0, S * k - 1).reshape(B, E * C)
    tok = jnp.take_along_axis(st, pos_c, 1)                       # (B,E*C)
    xin = jnp.take_along_axis(x, tok[..., None], axis=1)          # (B,E*C,d)
    buf = jnp.where(valid.reshape(B, E * C)[..., None], xin, 0.0)
    buf = buf.reshape(B, E, C, d)
    # the expert-parallel boundary: batch→data, experts→model (all_to_all)
    buf = logical(buf, "batch", "experts", "expert_cap", "embed")

    # ---- batched expert MLPs (true active FLOPs) ----
    g = jnp.einsum("becd,edf->becf", buf, p["w_gate"].astype(dt))
    u = jnp.einsum("becd,edf->becf", buf, p["w_up"].astype(dt))
    h = jax.nn.silu(g) * u
    out = jnp.einsum("becf,efd->becd", h, p["w_down"].astype(dt))
    out = logical(out, "batch", "experts", "expert_cap", "embed")

    # ---- combine: copy j of token t reads its slot (pure gathers) ----
    inv = jnp.argsort(order, axis=1)                              # copy→sorted
    slot_flat = jnp.where(rank < C, se * C + rank, E * C)         # per sorted
    slot_of_copy = jnp.take_along_axis(slot_flat, inv, 1)         # (B,S*k)
    flat = jnp.concatenate(
        [out.reshape(B, E * C, d), jnp.zeros((B, 1, d), dt)], axis=1)
    per_copy = jnp.take_along_axis(flat, slot_of_copy[..., None], axis=1)
    per_copy = per_copy.reshape(B, S, k, d) * gate_vals[..., None]
    y = jnp.sum(per_copy, axis=2)
    return logical(y, "batch", "seq", "embed"), aux
