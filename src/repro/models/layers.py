"""Shared layers: norms, MLPs, rotary embeddings, token embedding.

Pure-JAX parameter pytrees (nested dicts of arrays); every layer is a pair
``init_*(key, cfg) -> params`` / ``apply(params, x, ...) -> y``.  Activation
sharding hints go through :func:`repro.launch.shardings.logical`.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..launch.shardings import logical


def adtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def pdtype(cfg: ModelConfig):
    return jnp.dtype(cfg.param_dtype)


def dense_init(key, shape, dtype, scale: Optional[float] = None):
    fan_in = shape[0] if len(shape) > 1 else shape[0]
    scale = scale if scale is not None else 1.0 / jnp.sqrt(fan_in)
    return (jax.random.normal(key, shape) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def init_rmsnorm(d: int, dtype) -> dict:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(p: dict, x: jax.Array, eps: float) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# MLP (gated or plain)
# ---------------------------------------------------------------------------

def init_mlp(key, cfg: ModelConfig) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    dt = pdtype(cfg)
    k1, k2, k3 = jax.random.split(key, 3)
    gated = cfg.act in ("silu", "swiglu", "geglu")
    p = {"up": dense_init(k1, (d, f), dt), "down": dense_init(k2, (f, d), dt)}
    if gated:
        p["gate"] = dense_init(k3, (d, f), dt)
    return p


def _act(name: str, x):
    if name in ("silu", "swiglu"):
        return jax.nn.silu(x)
    if name in ("gelu", "geglu"):
        return jax.nn.gelu(x)
    return jax.nn.relu(x)


def mlp(p: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    dt = x.dtype
    up = x @ p["up"].astype(dt)
    up = logical(up, "batch", "seq", "ff")
    if "gate" in p:
        g = _act(cfg.act, x @ p["gate"].astype(dt))
        h = g * up
    else:
        h = _act(cfg.act, up)
    out = h @ p["down"].astype(dt)
    return logical(out, "batch", "seq", "embed")


# ---------------------------------------------------------------------------
# rotary position embeddings (RoPE + M-RoPE)
# ---------------------------------------------------------------------------

def rope_freqs(hd: int, theta: float, dtype=jnp.float32):
    return 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=dtype) / hd))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float,
               mrope: bool = False) -> jax.Array:
    """``x``: (B, S, H, hd); ``positions``: (B, S) or (B, S, 3) for M-RoPE.

    M-RoPE (qwen2-vl) splits the rotary dims into 3 sections driven by
    (temporal, h, w) position ids; the frontend stub supplies all three equal
    to the text position, which degenerates to standard RoPE exactly as for
    text-only inputs in the paper.
    """
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    if mrope:
        if positions.ndim == 2:
            positions = jnp.stack([positions] * 3, axis=-1)
        # sections: 1/2 temporal, 1/4 h, 1/4 w of the rotary dims
        n = hd // 2
        sec = jnp.concatenate([
            jnp.zeros((n - n // 2,), jnp.int32),
            jnp.ones((n // 4,), jnp.int32),
            jnp.full((n - (n - n // 2) - n // 4,), 2, jnp.int32)])
        pos = jnp.take_along_axis(
            positions.astype(jnp.float32),
            jnp.broadcast_to(sec[None, None, :], positions.shape[:2] + (n,)),
            axis=-1)                                     # (B, S, hd/2)
        ang = pos * freqs[None, None, :]
    else:
        ang = positions.astype(jnp.float32)[..., None] * freqs[None, None, :]
    sin = jnp.sin(ang)[..., None, :].astype(x.dtype)     # (B,S,1,hd/2)
    cos = jnp.cos(ang)[..., None, :].astype(x.dtype)
    x1, x2 = jnp.split(x, 2, axis=-1)
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


# ---------------------------------------------------------------------------
# embeddings
# ---------------------------------------------------------------------------

def init_embed(key, cfg: ModelConfig) -> dict:
    dt = pdtype(cfg)
    k1, k2 = jax.random.split(key)
    p = {"tok": dense_init(k1, (cfg.vocab, cfg.d_model), dt, scale=0.02)}
    if not cfg.tie_embeddings:
        p["unembed"] = dense_init(k2, (cfg.d_model, cfg.vocab), dt)
    return p


def embed(p: dict, tokens: jax.Array, cfg: ModelConfig) -> jax.Array:
    x = jnp.take(p["tok"], tokens, axis=0).astype(adtype(cfg))
    return logical(x, "batch", "seq", "embed")


def unembed(p: dict, x: jax.Array, cfg: ModelConfig, *,
            sliced: bool = True) -> jax.Array:
    """Project to vocabulary logits.

    The vocab dim is padded to a multiple of 256 so it shards on any mesh
    axis (granite/whisper/mamba2 vocabs are odd-sized and would otherwise
    fall back to full logits replication — 24 GiB/device at train_4k).
    Padded columns are masked to −∞; ``sliced=False`` keeps the padded
    (shardable) logits for the loss path."""
    w = p["unembed"] if "unembed" in p else p["tok"].T
    V = cfg.vocab
    Vp = -(-V // 256) * 256
    if Vp != V:
        w = jnp.pad(w, ((0, 0), (0, Vp - V)))
    logits = x.astype(jnp.float32) @ w.astype(jnp.float32)
    if Vp != V:
        logits = jnp.where(jnp.arange(Vp) < V, logits, -1e30)
    logits = logical(logits, "batch", "seq", "vocab")
    if sliced and Vp != V:
        logits = logits[..., :V]
    return logits
