"""Attention: GQA with optional qk-norm / QKV bias / RoPE / M-RoPE, full,
local (sliding-window), bidirectional and cross variants, and a ring-buffer
KV cache whose capacity is ``min(seq, window)`` for local layers — the
sub-quadratic path that makes ``long_500k`` decodable for hybrid archs.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..launch.shardings import logical
from .layers import adtype, apply_rope, dense_init, init_rmsnorm, pdtype, rmsnorm

NEG_INF = -1e30


def init_attention(key, cfg: ModelConfig, cross: bool = False) -> dict:
    d, H, K, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    if cross:
        K = cfg.n_heads  # whisper cross-attn is MHA
    dt = pdtype(cfg)
    ks = jax.random.split(key, 6)
    p = {
        "wq": dense_init(ks[0], (d, H * hd), dt),
        "wk": dense_init(ks[1], (d, K * hd), dt),
        "wv": dense_init(ks[2], (d, K * hd), dt),
        "wo": dense_init(ks[3], (H * hd, d), dt),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H * hd,), dt)
        p["bk"] = jnp.zeros((K * hd,), dt)
        p["bv"] = jnp.zeros((K * hd,), dt)
    if cfg.qk_norm:
        p["q_norm"] = init_rmsnorm(hd, dt)
        p["k_norm"] = init_rmsnorm(hd, dt)
    return p


def _project_qkv(p, x, cfg: ModelConfig, cross: bool):
    B, S, _ = x.shape
    H, hd = cfg.n_heads, cfg.hd
    K = H if cross else cfg.n_kv_heads
    dt = x.dtype
    q = x @ p["wq"].astype(dt)
    if "bq" in p:
        q = q + p["bq"].astype(dt)
    q = q.reshape(B, S, H, hd)
    return q, K


def _project_kv(p, src, cfg: ModelConfig, cross: bool):
    B, T, _ = src.shape
    hd = cfg.hd
    K = cfg.n_heads if cross else cfg.n_kv_heads
    dt = src.dtype
    k = src @ p["wk"].astype(dt)
    v = src @ p["wv"].astype(dt)
    if "bk" in p:
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    return k.reshape(B, T, K, hd), v.reshape(B, T, K, hd)


def _expand_kv(kv, H: int):
    """Repeat KV heads to the full query-head count (MaxText-style GQA under
    tensor parallelism: K<mesh_model would cap score sharding at K-way;
    expanded KV lets scores/probs shard H-way — the dominant activation)."""
    B, T, K, hd = kv.shape
    if K == H:
        return kv
    kv = jnp.broadcast_to(kv[:, :, :, None, :], (B, T, K, H // K, hd))
    return kv.reshape(B, T, H, hd)


def _gqa_scores(q, k, cfg: ModelConfig):
    """q: (B,S,H,hd), k: (B,T,K,hd) → scores (B,H,S,T)."""
    B, S, H, hd = q.shape
    ke = _expand_kv(k, H)
    s = jnp.einsum("bshd,bthd->bhst", q, ke) / jnp.sqrt(hd).astype(q.dtype)
    return logical(s, "batch", "heads", None, None)


def _gqa_out(probs, v, wo, B, S, cfg: ModelConfig):
    ve = _expand_kv(v, cfg.n_heads)
    o = jnp.einsum("bhst,bthd->bshd", probs, ve)
    o = o.reshape(B, S, cfg.n_heads * cfg.hd)
    return o @ wo.astype(o.dtype)


def attention(p: dict, x: jax.Array, cfg: ModelConfig, *,
              positions: jax.Array, mode: str = "causal",
              window: Optional[int] = None,
              enc_out: Optional[jax.Array] = None,
              chunk: int = 512) -> jax.Array:
    """Training/prefill attention.  ``mode``: causal | local | bidir | cross.

    Long sequences use query-chunked attention with per-chunk remat (the
    flash-attention memory pattern in pure jnp): the (S,T) score matrix is
    never materialized — per chip the live score block is (B,H,chunk,T).
    """
    B, S, _ = x.shape
    cross = mode == "cross"
    q, K = _project_qkv(p, x, cfg, cross)
    src = enc_out if cross else x
    k, v = _project_kv(p, src, cfg, cross)
    if cfg.qk_norm:
        q = rmsnorm(p["q_norm"], q, cfg.norm_eps)
        k = rmsnorm(p["k_norm"], k, cfg.norm_eps)
    if not cross:
        q = apply_rope(q, positions, cfg.rope_theta, cfg.mrope)
        k = apply_rope(k, positions, cfg.rope_theta, cfg.mrope)
    q = logical(q, "batch", "seq", "heads", "head_dim")
    k = logical(k, "batch", "seq", "kv_heads", "head_dim")
    v = logical(v, "batch", "seq", "kv_heads", "head_dim")

    T = k.shape[1]
    if S > 2 * chunk and S % chunk == 0 and mode in ("causal", "local", "bidir"):
        o = _attention_chunked(q, k, v, cfg, mode, window or cfg.window, chunk)
    else:
        scores = _gqa_scores(q, k, cfg).astype(jnp.float32)
        if mode in ("causal", "local"):
            i = jnp.arange(S)[:, None]
            j = jnp.arange(T)[None, :]
            mask = j <= i
            if mode == "local":
                w = window or cfg.window
                mask &= j > i - w
            scores = jnp.where(mask[None, None], scores, NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
        o = jnp.einsum("bhst,bthd->bshd", probs, _expand_kv(v, cfg.n_heads))
    y = o.reshape(B, S, cfg.n_heads * cfg.hd) @ p["wo"].astype(x.dtype)
    return logical(y, "batch", "seq", "embed")


def _attention_chunked(q, k, v, cfg: ModelConfig, mode: str, window: int,
                       chunk: int) -> jax.Array:
    """Query-chunked attention, rematerialized per chunk.

    Local mode additionally restricts each query chunk's KV view to the
    trailing ``window``-aligned band, so compute is O(S·window) not O(S²) —
    this is what keeps recurrentgemma's attention layers sub-quadratic.
    """
    B, S, H, hd = q.shape
    T = k.shape[1]
    ke = _expand_kv(k, H)
    ve = _expand_kv(v, H)
    nq = S // chunk
    qs = jnp.moveaxis(q.reshape(B, nq, chunk, H, hd), 1, 0)
    scale = 1.0 / jnp.sqrt(hd).astype(q.dtype)

    local_band = None
    if mode == "local":
        # KV band per chunk: [band_start, band_start + band_len)
        band_len = min(T, ((window + chunk - 1) // chunk + 1) * chunk)
        local_band = band_len

    def chunk_fn(idx, qc):
        q0 = idx * chunk
        if local_band is not None:
            start = jnp.clip(q0 + chunk - local_band, 0, T - local_band)
            kb = jax.lax.dynamic_slice_in_dim(ke, start, local_band, axis=1)
            vb = jax.lax.dynamic_slice_in_dim(ve, start, local_band, axis=1)
            jb = start + jnp.arange(local_band)
        else:
            kb, vb = ke, ve
            jb = jnp.arange(T)
        s = jnp.einsum("bshd,bthd->bhst", qc, kb) * scale
        s = s.astype(jnp.float32)
        ib = q0 + jnp.arange(chunk)
        if mode in ("causal", "local"):
            m = jb[None, :] <= ib[:, None]
            if mode == "local":
                m &= jb[None, :] > ib[:, None] - window
            s = jnp.where(m[None, None], s, NEG_INF)
        pr = jax.nn.softmax(s, axis=-1).astype(qc.dtype)
        return jnp.einsum("bhst,bthd->bshd", pr, vb)

    body = jax.checkpoint(chunk_fn)

    def scan_fn(_, inp):
        idx, qc = inp
        return None, body(idx, qc)

    _, os = jax.lax.scan(scan_fn, None, (jnp.arange(nq), qs))
    return jnp.moveaxis(os, 0, 1).reshape(B, S, H, hd)


# ---------------------------------------------------------------------------
# KV cache (decode)
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, capacity: int, mode: str,
               dtype) -> dict:
    """Ring-buffer cache.  ``capacity`` = seq_len for full attention,
    min(seq_len, window) for local — local layers stay O(window) even at
    524k context."""
    K, hd = cfg.n_kv_heads, cfg.hd
    return {
        "k": jnp.zeros((batch, capacity, K, hd), dtype),
        "v": jnp.zeros((batch, capacity, K, hd), dtype),
        "pos": jnp.zeros((capacity,), jnp.int32) - 1,  # absolute pos per slot
    }


def decode_attention(p: dict, x: jax.Array, cache: dict, cfg: ModelConfig, *,
                     pos: jax.Array, mode: str = "causal",
                     window: Optional[int] = None,
                     cross_kv: Optional[Tuple[jax.Array, jax.Array]] = None
                     ) -> Tuple[jax.Array, dict]:
    """One-token decode.  ``x``: (B, 1, d); ``pos``: scalar absolute position.

    Keys are stored post-RoPE at absolute positions; the ring slot is
    ``pos % capacity`` and validity comes from the per-slot absolute-position
    table, which uniformly handles full and sliding-window masks.
    """
    B = x.shape[0]
    if mode == "cross":
        q, _ = _project_qkv(p, x, cfg, cross=True)
        if cfg.qk_norm:
            q = rmsnorm(p["q_norm"], q, cfg.norm_eps)
        k, v = cross_kv
        scores = _gqa_scores(q, k, cfg).astype(jnp.float32)
        probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
        y = _gqa_out(probs, v, p["wo"], B, 1, cfg)
        return y, cache

    q, K = _project_qkv(p, x, cfg, cross=False)
    k_new, v_new = _project_kv(p, x, cfg, cross=False)
    if cfg.qk_norm:
        q = rmsnorm(p["q_norm"], q, cfg.norm_eps)
        k_new = rmsnorm(p["k_norm"], k_new, cfg.norm_eps)
    pos_b = jnp.full((B, 1), pos, jnp.int32)
    q = apply_rope(q, pos_b, cfg.rope_theta, cfg.mrope)
    k_new = apply_rope(k_new, pos_b, cfg.rope_theta, cfg.mrope)

    cap = cache["k"].shape[1]
    slot = pos % cap
    ck = jax.lax.dynamic_update_slice(cache["k"], k_new, (0, slot, 0, 0))
    cv = jax.lax.dynamic_update_slice(cache["v"], v_new, (0, slot, 0, 0))
    cpos = jax.lax.dynamic_update_slice(cache["pos"],
                                        jnp.reshape(pos, (1,)).astype(jnp.int32),
                                        (slot,))
    ck = logical(ck, "batch", "seq_kv", "kv_heads_cache", None)
    cv = logical(cv, "batch", "seq_kv", "kv_heads_cache", None)

    scores = _gqa_scores(q, ck, cfg).astype(jnp.float32)   # (B,H,1,cap)
    valid = (cpos >= 0) & (cpos <= pos)
    if mode == "local":
        w = window or cfg.window
        valid &= cpos > pos - w
    scores = jnp.where(valid[None, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    y = _gqa_out(probs, cv, p["wo"], B, 1, cfg)
    return y, {"k": ck, "v": cv, "pos": cpos}


def cache_capacity(cfg: ModelConfig, mode: str, seq_len: int) -> int:
    if mode == "local":
        return min(seq_len, cfg.window)
    return seq_len
