"""Sequence mixers without attention: Mamba2 SSD and RG-LRU (Griffin).

Both are implemented in their *chunked / scan* forms so training parallelizes
over sequence and decode is O(1)-state — the property that keeps the
``long_500k`` cell sub-quadratic (DESIGN.md §Arch-applicability).  The
inter-chunk state recurrence is the same neighbour-passing pattern as the
paper's halo exchange; under sequence sharding the boundary state crosses
shards with the halo primitive (perf iteration, see EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..launch.shardings import logical
from .layers import dense_init, init_rmsnorm, pdtype, rmsnorm


# ---------------------------------------------------------------------------
# causal depthwise conv1d (width w, shared by both mixers)
# ---------------------------------------------------------------------------

def init_conv1d(key, channels: int, width: int, dtype) -> dict:
    return {"w": dense_init(key, (width, channels), dtype, scale=0.5)}


def conv1d(p: dict, x: jax.Array) -> jax.Array:
    """x: (B, S, C) causal depthwise convolution via static shifts."""
    w = p["w"].astype(x.dtype)
    width = w.shape[0]
    y = x * w[-1]
    for i in range(1, width):
        shifted = jnp.pad(x, ((0, 0), (i, 0), (0, 0)))[:, :-i]
        y = y + shifted * w[-1 - i]
    return y


def conv1d_step(p: dict, x_t: jax.Array, cache: jax.Array
                ) -> Tuple[jax.Array, jax.Array]:
    """x_t: (B, C); cache: (B, width-1, C) past inputs."""
    w = p["w"].astype(x_t.dtype)
    hist = jnp.concatenate([cache, x_t[:, None]], axis=1)   # (B, width, C)
    y = jnp.einsum("bwc,wc->bc", hist, w)
    return y, hist[:, 1:]


# ---------------------------------------------------------------------------
# Mamba2 / SSD (state-space duality, chunked)
# ---------------------------------------------------------------------------

def ssd_dims(cfg: ModelConfig):
    d_in = 2 * cfg.d_model
    nheads = d_in // cfg.ssm_head_dim
    return d_in, nheads, cfg.ssm_head_dim, cfg.ssm_state


def init_ssd(key, cfg: ModelConfig) -> dict:
    d = cfg.d_model
    d_in, H, Pd, N = ssd_dims(cfg)
    dt = pdtype(cfg)
    ks = jax.random.split(key, 6)
    return {
        # in_proj → [z | x | B | C | dt]
        "in_proj": dense_init(ks[0], (d, 2 * d_in + 2 * N + H), dt),
        "conv": init_conv1d(ks[1], d_in + 2 * N, cfg.conv_width, dt),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H)).astype(dt),
        "D": jnp.ones((H,), dt),
        "dt_bias": jnp.zeros((H,), dt),
        "norm": init_rmsnorm(d_in, dt),
        "out_proj": dense_init(ks[2], (d_in, d), dt),
    }


def _ssd_scan(Xd, a, Bm, Cm, chunk: int, h0=None):
    """Core SSD: Xd (B,S,H,P) dt-scaled inputs, a (B,S,H) log-decay (≤0),
    Bm/Cm (B,S,N).  Returns (Y (B,S,H,P), final state (B,H,N,P))."""
    Bsz, S, H, Pd = Xd.shape
    N = Bm.shape[-1]
    L = min(chunk, S)
    S_orig = S
    if S % L:
        pad = L - S % L          # zero-pad: a=0 → decay 1, Xd=0 → no input
        Xd = jnp.pad(Xd, ((0, 0), (0, pad), (0, 0), (0, 0)))
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
        S = S + pad
    nc = S // L
    f32 = jnp.float32

    Xc = Xd.reshape(Bsz, nc, L, H, Pd)
    ac = a.reshape(Bsz, nc, L, H).astype(f32)
    Bc = Bm.reshape(Bsz, nc, L, N)
    Cc = Cm.reshape(Bsz, nc, L, N)

    cum = jnp.cumsum(ac, axis=2)                         # (B,nc,L,H)
    # intra-chunk: att[i,j] = C_i·B_j · exp(cum_i − cum_j), j ≤ i
    cb = jnp.einsum("bcin,bcjn->bcij", Cc.astype(f32), Bc.astype(f32))
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # (B,nc,i,j,H)
    tri = (jnp.arange(L)[:, None] >= jnp.arange(L)[None, :])
    # clamp BEFORE exp: the masked upper triangle has seg > 0 and would
    # overflow, poisoning gradients through the dead branch (inf·0 → NaN)
    seg = jnp.where(tri[None, None, :, :, None], seg, -jnp.inf)
    att = jnp.where(tri[None, None, :, :, None],
                    jnp.exp(seg) * cb[..., None], 0.0)
    Y_intra = jnp.einsum("bcijh,bcjhp->bcihp", att.astype(Xd.dtype), Xc)

    # chunk-final local states: S_c = Σ_j exp(cum_L − cum_j) B_j ⊗ Xd_j
    decay_out = jnp.exp(cum[:, :, -1:, :] - cum)         # (B,nc,L,H)
    Sloc = jnp.einsum("bcjh,bcjn,bcjhp->bchnp",
                      decay_out.astype(Xd.dtype), Bc, Xc)

    # inter-chunk recurrence (the neighbour/halo state pass)
    chunk_decay = jnp.exp(cum[:, :, -1, :])              # (B,nc,H)

    def step(h, inp):
        dec, s = inp
        h_new = h * dec[..., None, None].astype(h.dtype) + s
        return h_new, h

    h_init = (jnp.zeros((Bsz, H, N, Pd), Xd.dtype) if h0 is None else h0)
    h_fin, h_prevs = jax.lax.scan(
        step, h_init,
        (jnp.moveaxis(chunk_decay, 1, 0), jnp.moveaxis(Sloc, 1, 0)))
    h_prevs = jnp.moveaxis(h_prevs, 0, 1)                # (B,nc,H,N,P)

    Y_inter = jnp.einsum("bcin,bchi,bchnp->bcihp",
                         Cc, jnp.exp(cum).astype(Cc.dtype).transpose(0, 1, 3, 2),
                         h_prevs)
    Y = (Y_intra + Y_inter).reshape(Bsz, S, H, Pd)
    return Y[:, :S_orig], h_fin


def _ssd_seq_parallel(Xd, a, Bm, Cm, chunk: int, n_sp: int):
    """Sequence-domain-decomposed SSD: each of ``n_sp`` segments (sharded over
    the model axis via the ``seq_mixer`` rule) runs SSD locally with zero
    initial state; boundary states then propagate segment-to-segment — the
    paper's §3.3 neighbour/halo pattern, with the state tensor (B,H,N,P) as
    the halo payload — and a per-position correction folds the incoming state
    into each segment's output."""
    B, S, H, Pd = Xd.shape
    N = Bm.shape[-1]
    Sl = S // n_sp
    r3 = lambda t: t.reshape(B, n_sp, Sl, *t.shape[2:])
    Xs, as_, Bs, Cs = r3(Xd), r3(a), r3(Bm), r3(Cm)
    Xs = logical(Xs, "batch", "seq_mixer", None, "heads", "head_dim")

    Yl, hf = jax.vmap(
        lambda x_, a_, b_, c_: _ssd_scan(x_, a_, b_, c_, chunk),
        in_axes=1, out_axes=(1, 1))(Xs, as_, Bs, Cs)

    cum_seg = jnp.cumsum(as_.astype(jnp.float32), axis=2)   # (B,n_sp,Sl,H)
    seg_decay = jnp.exp(cum_seg[:, :, -1])                   # (B,n_sp,H)

    def step(h, inp):
        dec, s = inp
        return dec[..., None, None].astype(h.dtype) * h + s, h

    _, h_ins = jax.lax.scan(
        step, jnp.zeros_like(hf[:, 0]),
        (jnp.moveaxis(seg_decay, 1, 0), jnp.moveaxis(hf, 1, 0)))
    h_ins = jnp.moveaxis(h_ins, 0, 1)                        # state entering j

    Y_extra = jnp.einsum("bjtn,bjth,bjhnp->bjthp",
                         Cs, jnp.exp(cum_seg).astype(Cs.dtype), h_ins)
    return (Yl + Y_extra).reshape(B, S, H, Pd)


def ssd_forward(p: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Full-sequence SSD mixer (training / prefill).

    With ``cfg.seq_shards_mixer > 1`` the sequence is domain-decomposed
    across the model axis (the paper's sparse-tensor-parallel pattern) —
    see :func:`_ssd_seq_parallel`."""
    B, S, d = x.shape
    d_in, H, Pd, N = ssd_dims(cfg)
    dt_ = x.dtype
    zxbcdt = x @ p["in_proj"].astype(dt_)
    z, xs, Bm, Cm, dth = jnp.split(
        zxbcdt, [d_in, 2 * d_in, 2 * d_in + N, 2 * d_in + 2 * N], axis=-1)
    conv_in = jnp.concatenate([xs, Bm, Cm], axis=-1)
    conv_out = jax.nn.silu(conv1d(p["conv"], conv_in))
    xs, Bm, Cm = jnp.split(conv_out, [d_in, d_in + N], axis=-1)
    dth = jax.nn.softplus(dth.astype(jnp.float32)
                          + p["dt_bias"].astype(jnp.float32))   # (B,S,H)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))                 # (H,)
    a = dth * A[None, None, :]                                   # log-decay
    Xh = xs.reshape(B, S, H, Pd)
    Xd = Xh * dth[..., None].astype(dt_)
    n_sp = getattr(cfg, "seq_shards_mixer", 1)
    if n_sp > 1 and S % n_sp == 0 and (S // n_sp) >= 2:
        Y = _ssd_seq_parallel(Xd, a, Bm, Cm, min(cfg.ssm_chunk, S // n_sp),
                              n_sp)
    else:
        Xd = logical(Xd, "batch", "seq", "heads", "head_dim")
        Y, _ = _ssd_scan(Xd, a, Bm, Cm, cfg.ssm_chunk)
    Y = Y + Xh * p["D"].astype(dt_)[None, None, :, None]
    Y = Y.reshape(B, S, d_in)
    Y = rmsnorm(p["norm"], Y * jax.nn.silu(z), cfg.norm_eps)
    return logical(Y @ p["out_proj"].astype(dt_), "batch", "seq", "embed")


def init_ssd_state(cfg: ModelConfig, batch: int, dtype) -> dict:
    d_in, H, Pd, N = ssd_dims(cfg)
    return {
        "h": jnp.zeros((batch, H, N, Pd), dtype),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, d_in + 2 * N), dtype),
    }


def ssd_step(p: dict, x: jax.Array, state: dict, cfg: ModelConfig
             ) -> Tuple[jax.Array, dict]:
    """One-token decode.  x: (B, 1, d)."""
    B, _, d = x.shape
    d_in, H, Pd, N = ssd_dims(cfg)
    dt_ = x.dtype
    zxbcdt = (x[:, 0] @ p["in_proj"].astype(dt_))
    z, xs, Bm, Cm, dth = jnp.split(
        zxbcdt, [d_in, 2 * d_in, 2 * d_in + N, 2 * d_in + 2 * N], axis=-1)
    conv_in = jnp.concatenate([xs, Bm, Cm], axis=-1)
    conv_out, conv_cache = conv1d_step(p["conv"], conv_in, state["conv"])
    conv_out = jax.nn.silu(conv_out)
    xs, Bm, Cm = jnp.split(conv_out, [d_in, d_in + N], axis=-1)
    dth = jax.nn.softplus(dth.astype(jnp.float32)
                          + p["dt_bias"].astype(jnp.float32))   # (B,H)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    dec = jnp.exp(dth * A[None, :])                              # (B,H)
    Xh = xs.reshape(B, H, Pd)
    h = state["h"] * dec[..., None, None].astype(dt_)
    h = h + jnp.einsum("bn,bhp,bh->bhnp", Bm, Xh, dth.astype(dt_))
    y = jnp.einsum("bn,bhnp->bhp", Cm, h) + Xh * p["D"].astype(dt_)[None, :, None]
    y = y.reshape(B, d_in)
    y = rmsnorm(p["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    out = (y @ p["out_proj"].astype(dt_))[:, None]
    return out, {"h": h, "conv": conv_cache}


# ---------------------------------------------------------------------------
# RG-LRU recurrent block (Griffin / recurrentgemma)
# ---------------------------------------------------------------------------

def lru_width(cfg: ModelConfig) -> int:
    return cfg.lru_width or cfg.d_model


def init_rglru(key, cfg: ModelConfig) -> dict:
    d = cfg.d_model
    w = lru_width(cfg)
    dt = pdtype(cfg)
    ks = jax.random.split(key, 6)
    # Λ init so a = exp(-8·softplus(Λ)) ∈ (0.9, 0.999) at r = 1
    lam = jnp.log(jnp.expm1(-jnp.log(
        jnp.linspace(0.9, 0.999, w)) / 8.0)).astype(dt)
    return {
        "w_main": dense_init(ks[0], (d, w), dt),
        "w_gate_br": dense_init(ks[1], (d, w), dt),
        "conv": init_conv1d(ks[2], w, cfg.conv_width, dt),
        "w_r": dense_init(ks[3], (w, w), dt),
        "w_i": dense_init(ks[4], (w, w), dt),
        "lam": lam,
        "w_out": dense_init(ks[5], (w, d), dt),
    }


def _rglru_gates(p, u):
    f32 = jnp.float32
    r = jax.nn.sigmoid((u @ p["w_r"].astype(u.dtype)).astype(f32))
    i = jax.nn.sigmoid((u @ p["w_i"].astype(u.dtype)).astype(f32))
    log_a = -8.0 * jax.nn.softplus(p["lam"].astype(f32)) * r
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    return a, beta * i * u.astype(f32)


def rglru_forward(p: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Griffin recurrent block: gate branch ⊙ (conv → RG-LRU), full sequence
    via associative scan."""
    dt_ = x.dtype
    gate = jax.nn.gelu(x @ p["w_gate_br"].astype(dt_))
    u = x @ p["w_main"].astype(dt_)
    u = conv1d(p["conv"], u)
    u = logical(u, "batch", "seq", "ff")
    a, b = _rglru_gates(p, u)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    h = h.astype(dt_)
    y = (gate * h) @ p["w_out"].astype(dt_)
    return logical(y, "batch", "seq", "embed")


def init_rglru_state(cfg: ModelConfig, batch: int, dtype) -> dict:
    w = lru_width(cfg)
    return {
        "h": jnp.zeros((batch, w), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, w), dtype),
    }


def rglru_step(p: dict, x: jax.Array, state: dict, cfg: ModelConfig
               ) -> Tuple[jax.Array, dict]:
    """One-token decode.  x: (B, 1, d)."""
    dt_ = x.dtype
    x0 = x[:, 0]
    gate = jax.nn.gelu(x0 @ p["w_gate_br"].astype(dt_))
    u = x0 @ p["w_main"].astype(dt_)
    u, conv_cache = conv1d_step(p["conv"], u, state["conv"])
    a, b = _rglru_gates(p, u)
    h = a * state["h"] + b
    y = ((gate * h.astype(dt_)) @ p["w_out"].astype(dt_))[:, None]
    return y, {"h": h, "conv": conv_cache}
