"""Fault-tolerant training driver.

Wraps a step function with: periodic (optionally async) checkpointing,
crash/restart recovery (resume from the latest atomic checkpoint — the data
stream is a pure function of step so no iterator state is lost), straggler
detection (per-step timing EWMA; on a real pod the hook would trigger
re-slicing/hot-sparing — here it logs and records), and failure injection
for tests.

Elastic scaling: because checkpoints store global (unsharded) arrays and the
restore path takes target shardings, a restart may use a different mesh /
data-parallel width; the synthetic data stream re-slices the same global
batch (data/tokens.py).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax
import numpy as np

from ..checkpoint.manager import CheckpointManager


@dataclasses.dataclass
class FTConfig:
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_every: int = 50
    keep: int = 3
    async_save: bool = True
    straggler_factor: float = 3.0       # step > factor × EWMA ⇒ flag
    max_restarts: int = 3


class SimulatedFailure(RuntimeError):
    pass


class TrainLoop:
    def __init__(self, cfg: FTConfig, step_fn: Callable, make_batch: Callable,
                 shardings=None):
        """``step_fn(state, batch) -> (state, metrics)``;
        ``make_batch(step) -> batch`` must be pure in ``step``."""
        self.cfg = cfg
        self.step_fn = step_fn
        self.make_batch = make_batch
        self.shardings = shardings
        self.mgr = CheckpointManager(cfg.ckpt_dir, keep=cfg.keep,
                                     async_save=cfg.async_save)
        self.straggler_log: list = []
        self._ewma: Optional[float] = None

    def run(self, state, num_steps: int, start_step: int = 0,
            fail_at: Optional[int] = None, log_every: int = 10,
            logger=print):
        """Returns (state, last_step).  ``fail_at`` injects a failure once."""
        step = start_step
        restarts = 0
        failed_once = False
        while step < num_steps:
            try:
                while step < num_steps:
                    if fail_at is not None and step == fail_at and not failed_once:
                        failed_once = True
                        raise SimulatedFailure(f"injected at step {step}")
                    t0 = time.perf_counter()
                    batch = self.make_batch(step)
                    state, metrics = self.step_fn(state, batch)
                    jax.block_until_ready(jax.tree.leaves(state)[0])
                    dt = time.perf_counter() - t0
                    self._track_straggler(step, dt, logger)
                    step += 1
                    if step % self.cfg.ckpt_every == 0 or step == num_steps:
                        self.mgr.save(step, state, {"metrics": _to_py(metrics)})
                    if log_every and step % log_every == 0:
                        logger(f"step {step}: "
                               + " ".join(f"{k}={_fmt(v)}"
                                          for k, v in metrics.items())
                               + f" ({dt*1e3:.0f} ms)")
                break
            except SimulatedFailure as e:
                restarts += 1
                if restarts > self.cfg.max_restarts:
                    raise
                latest = self.mgr.latest_step()
                logger(f"[ft] failure: {e}; restarting from checkpoint "
                       f"step {latest}")
                if latest is not None:
                    self.mgr.wait()
                    state = self.mgr.restore(latest, state, self.shardings)
                    step = latest
                else:
                    step = start_step
        self.mgr.wait()
        return state, step

    def _track_straggler(self, step: int, dt: float, logger):
        if self._ewma is None:
            self._ewma = dt
        elif dt > self.cfg.straggler_factor * self._ewma and step > 5:
            self.straggler_log.append((step, dt, self._ewma))
            logger(f"[ft] straggler: step {step} took {dt*1e3:.0f} ms "
                   f"(EWMA {self._ewma*1e3:.0f} ms) — on a pod this triggers "
                   f"slice replacement")
        self._ewma = 0.9 * (self._ewma or dt) + 0.1 * dt


def _to_py(tree):
    return jax.tree.map(lambda x: float(np.asarray(x)), tree)


def _fmt(v):
    try:
        return f"{float(np.asarray(v)):.4g}"
    except Exception:
        return str(v)
