"""Roofline-term extraction from compiled dry-run artifacts.

Three terms per (arch × shape × mesh), in seconds (TPU v5e constants):

    compute    = FLOPs_per_chip / 197e12
    memory     = bytes_per_chip / 819e9
    collective = collective_bytes_per_chip / 50e9 (per-link ICI)

XLA facts established by probing (see EXPERIMENTS.md §Roofline methodology):
``compiled.cost_analysis()`` reports **per-device** numbers for the SPMD
partitioned module, and counts while/scan bodies **once** (trip counts are
ignored).  We therefore parse ``compiled.as_text()`` ourselves:

* computations + call graph (fusion ``calls=``, while ``body=/condition=``);
* ``known_trip_count`` from while backend_config (fallback: the constant
  compared in the condition computation);
* per-computation dot FLOPs (2 · |result| · |contracted|, operand shapes from
  the computation symbol table) × the transitive loop multiplier;
* per-computation materialized result bytes (fusion internals excluded)
  × multiplier × 2 (read+write traffic model);
* collective result bytes × multiplier, by kind.

Elementwise FLOPs outside dots use XLA's own (loop-uncorrected) count as a
lower bound; dots dominate every assigned architecture.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

from ..configs.base import ModelConfig, ShapeConfig
from .mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(shape_str: str) -> List[int]:
    m = _SHAPE_RE.search(shape_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",")] if m.group(2) else []


@dataclasses.dataclass
class Comp:
    name: str
    lines: List[str]
    shapes: Dict[str, str]                  # %instr → result shape str
    dots_flops: float = 0.0
    result_bytes: int = 0
    colls: Dict[str, int] = dataclasses.field(default_factory=dict)
    whiles: List[Tuple[str, str, int]] = dataclasses.field(default_factory=list)
    fusion_calls: List[str] = dataclasses.field(default_factory=list)


_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*((?:\([^()]*\))|(?:\w+\[[\d,]*\]"
    r"(?:\{[^}]*\})?))\s*([\w\-]+)")


def parse_hlo(text: str) -> Dict[str, "Comp"]:
    comps: Dict[str, Comp] = {}
    entry: Optional[str] = None
    cur: Optional[Comp] = None
    for raw in text.splitlines():
        header = re.match(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->.*\{",
                          raw)
        if header and not raw.lstrip().startswith("%param"):
            cur = Comp(header.group(2), [], {})
            comps[cur.name] = cur
            if header.group(1):
                entry = cur.name
            continue
        if cur is None:
            continue
        cur.lines.append(raw)
        m = _INSTR_RE.match(raw)
        if not m:
            # parameters: "%p = f32[..] parameter(0)" matches; skip otherwise
            continue
        iname, shape_str, op = m.group(1), m.group(2), m.group(3)
        cur.shapes[iname] = shape_str
        if op == "dot":
            cur.dots_flops += _dot_flops(raw, shape_str, cur.shapes)
        elif op == "convolution":
            cur.dots_flops += _conv_flops(raw, shape_str, cur.shapes)
        elif op == "while":
            body = _attr(raw, "body")
            cond = _attr(raw, "condition")
            trip = _trip_from_config(raw)
            cur.whiles.append((body, cond, trip or 0))
        elif op == "fusion":
            callee = _attr(raw, "calls")
            if callee:
                cur.fusion_calls.append(callee)
        elif op in _COLLECTIVES or op.rstrip("-start") in _COLLECTIVES:
            kind = next(k for k in _COLLECTIVES if op.startswith(k))
            cur.colls[kind] = cur.colls.get(kind, 0) + _shape_bytes(shape_str)
        if op not in ("parameter", "constant", "get-tuple-element", "tuple",
                      "bitcast", "copy"):
            cur.result_bytes += _shape_bytes(shape_str)
    comps["__entry__"] = comps.get(entry, next(iter(comps.values())))
    return comps


def _attr(line: str, key: str) -> Optional[str]:
    m = re.search(key + r"=%?([\w\.\-]+)", line)
    return m.group(1) if m else None


def _trip_from_config(line: str) -> Optional[int]:
    m = re.search(r'known_trip_count[^0-9]*(\d+)', line)
    return int(m.group(1)) if m else None


def _dot_flops(line: str, result_shape: str, shapes: Dict[str, str]) -> float:
    res = _shape_dims(result_shape)
    out = 1.0
    for d in res:
        out *= d
    m = re.search(r"dot\(%?([\w\.\-]+)", line)
    lhs = shapes.get(m.group(1)) if m else None
    cdims = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", line)
    contracted = 1.0
    if lhs and cdims and cdims.group(1):
        ldims = _shape_dims(lhs)
        for d in cdims.group(1).split(","):
            i = int(d)
            if i < len(ldims):
                contracted *= ldims[i]
    return 2.0 * out * contracted


def _conv_flops(line: str, result_shape: str, shapes: Dict[str, str]) -> float:
    res = _shape_dims(result_shape)
    out = 1.0
    for d in res:
        out *= d
    m = re.search(r"convolution\(%?([\w\.\-]+),\s*%?([\w\.\-]+)", line)
    rhs = shapes.get(m.group(2)) if m else None
    k = 1.0
    if rhs:
        rdims = _shape_dims(rhs)
        for d in rdims[:-1]:        # all but output-feature (approximation)
            k *= d
    return 2.0 * out * k


def _multipliers(comps: Dict[str, Comp]) -> Tuple[Dict[str, float], set]:
    """Transitive loop multiplier per computation + the set of computations
    whose instruction results are materialized (fusion internals excluded)."""
    entry = comps["__entry__"].name
    mult: Dict[str, float] = {}
    materialized = set()

    def visit(name: str, m: float, mat: bool):
        if name not in comps:
            return
        mult[name] = mult.get(name, 0.0) + m
        if mat:
            materialized.add(name)
        c = comps[name]
        for body, cond, trip in c.whiles:
            t = max(trip, 1)
            if body:
                visit(body, m * t, mat)
            if cond:
                visit(cond, m * t, False)
        for callee in c.fusion_calls:
            visit(callee, m, False)

    visit(entry, 1.0, True)
    return mult, materialized


@dataclasses.dataclass
class HloStats:
    dot_flops: float
    traffic_bytes: float
    collective_bytes: float
    coll_by_kind: Dict[str, float]
    n_collectives: int


def analyze_hlo(text: str) -> HloStats:
    comps = parse_hlo(text)
    mult, materialized = _multipliers(comps)
    dot_flops = 0.0
    traffic = 0.0
    coll: Dict[str, float] = {}
    n_coll = 0
    for name, c in comps.items():
        if name == "__entry__":
            continue
        m = mult.get(name, 0.0)
        if m == 0.0:
            continue
        dot_flops += m * c.dots_flops
        if name in materialized:
            traffic += m * c.result_bytes * 2.0      # write + read model
        for kind, b in c.colls.items():
            coll[kind] = coll.get(kind, 0.0) + m * b
            n_coll += 1
    return HloStats(dot_flops=dot_flops, traffic_bytes=traffic,
                    collective_bytes=sum(coll.values()), coll_by_kind=coll,
                    n_collectives=n_coll)


# ---------------------------------------------------------------------------
# solver-step traffic model (kernel plans — kernels/solve_step.py)
# ---------------------------------------------------------------------------

# HBM traffic of ONE textbook preconditioned-CG iteration's vector work, in
# vector-lengths (matvec excluded — identical on both sides).  Each pass
# streams its operands from HBM and its outputs back: an axpy is 2 reads +
# 1 write = 3n, a two-vector dot 2n, the self-dot convergence check 1n.
CG_BASELINE_PASSES: Dict[str, int] = {
    "pAp_dot": 2,         # alpha denominator  <p, Ap>
    "x_axpy": 3,          # x += alpha p
    "r_axpy": 3,          # r -= alpha Ap
    "precond_apply": 3,   # z = M r (diagonal scale)
    "rz_dot": 2,          # rho' = <r, z>
    "p_update": 3,        # p = z + beta p
    "conv_rr_dot": 1,     # loop condition recomputes <r, r>
}


def solver_step_traffic(n: int, itemsize: int = 8) -> dict:
    """Byte model: the fused CG step kernel vs the separate-pass baseline.

    The fused kernel (``kernels/solve_step.fused_cg_update``) produces
    (x', r', z') and BOTH reductions (rho', rr') in one pass — 5 reads +
    3 writes = 8n — while the merged (Chronopoulos–Gear) recurrence removes
    the standalone <p, Ap> pass outright (alpha comes from the delta
    reduction riding the direction pass) and the carried rr removes the
    convergence re-dot.  The baseline is the seven separate memory-bound
    passes of ``CG_BASELINE_PASSES`` (17n).  The direction pass exists in
    both variants and is excluded from the ratio; full-iteration totals are
    reported alongside for the honest end-to-end number (14n vs 17n).
    """
    from ..kernels import solve_step as _fk
    baseline = sum(CG_BASELINE_PASSES.values()) * n * itemsize
    fused = _fk.traffic_bytes(_fk.fused_cg_update, n, itemsize)
    direction = _fk.traffic_bytes(_fk.fused_cg_direction, n, itemsize)
    return {
        "baseline_bytes": float(baseline),
        "fused_step_bytes": float(fused),
        "ratio": fused / baseline,
        "iteration_fused_bytes": float(fused + direction),
        "iteration_ratio": (fused + direction) / baseline,
    }


def measured_cg_baseline_bytes(n: int, dtype: str = "float64") -> float:
    """Compile the UNFUSED pass sequence and count its HLO traffic — the
    ground truth the model above is checked against (the fused side cannot
    be measured the same way off-TPU: interpret-mode Pallas lowers to a
    scan emulation whose HLO byte counts are meaningless)."""
    import jax
    import jax.numpy as jnp

    def step(x, r, p, s, dinv, rho):
        pAp = jnp.dot(p, s)
        alpha = rho / pAp
        x = x + alpha * p
        r = r - alpha * s
        z = dinv * r
        rho_new = jnp.dot(r, z)
        beta = rho_new / rho
        p = z + beta * p
        rr = jnp.dot(r, r)
        return x, r, p, rho_new, rr

    vec = jax.ShapeDtypeStruct((n,), dtype)
    sca = jax.ShapeDtypeStruct((), dtype)
    txt = jax.jit(step).lower(vec, vec, vec, vec, vec, sca).compile().as_text()
    return analyze_hlo(txt).traffic_bytes


def assert_fused_step_savings(n: int = 65536, threshold: float = 0.5,
                              itemsize: int = 8) -> dict:
    """CI gate: the fused step's modeled bytes must stay under ``threshold``
    of the separate-pass baseline, and the baseline model must not overstate
    what XLA actually materializes for the unfused sequence by more than the
    read+write double-count allows.  Returns the numbers for reporting."""
    model = solver_step_traffic(n, itemsize)
    if not model["ratio"] < threshold:
        raise AssertionError(
            f"fused CG step bytes {model['fused_step_bytes']:.0f} not < "
            f"{threshold}x baseline {model['baseline_bytes']:.0f} "
            f"(ratio {model['ratio']:.3f})")
    measured = measured_cg_baseline_bytes(n)
    model["measured_baseline_bytes"] = measured
    # the compiled baseline must genuinely move multi-pass traffic: at least
    # the five output vectors' worth even after XLA fusion — otherwise the
    # "savings" would be against a strawman
    floor = 5 * n * itemsize
    if not measured >= floor:
        raise AssertionError(
            f"measured unfused-baseline traffic {measured:.0f} below "
            f"plausibility floor {floor} — HLO parse drifted?")
    return model


# ---------------------------------------------------------------------------
# MODEL_FLOPS (6·N·D analytic)
# ---------------------------------------------------------------------------

def active_params(cfg: ModelConfig) -> int:
    total = cfg.param_count()
    if cfg.n_experts:
        expert_p = 0
        for kind in cfg.pattern_layers:
            if kind == "moe":
                expert_p += cfg.n_experts * 3 * cfg.d_model * cfg.d_ff_expert
        active_expert = expert_p * cfg.top_k // cfg.n_experts
        return total - expert_p + active_expert
    return total


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """Global useful FLOPs: 6·N·D train, 2·N·D prefill, 2·N·B decode."""
    n = active_params(cfg)
    if shape.kind == "train":
        return 6.0 * n * shape.seq_len * shape.global_batch
    if shape.kind == "prefill":
        return 2.0 * n * shape.seq_len * shape.global_batch
    return 2.0 * n * shape.global_batch


# ---------------------------------------------------------------------------
# the three terms (per-chip seconds)
# ---------------------------------------------------------------------------

def roofline_terms(cost: dict, hlo: HloStats, chips: int) -> dict:
    """``cost`` is XLA's per-device cost_analysis dict; ``hlo`` our corrected
    text analysis (also per-device — the module is partitioned)."""
    xla_flops = float(cost.get("flops", 0.0))
    xla_bytes = float(cost.get("bytes accessed", 0.0))
    flops = max(hlo.dot_flops, xla_flops)
    bytes_ = max(hlo.traffic_bytes, xla_bytes)
    return {
        "hlo_flops_per_chip": flops,
        "hlo_bytes_per_chip": bytes_,
        "xla_flops_raw": xla_flops,
        "xla_bytes_raw": xla_bytes,
        "collective_bytes_per_chip": hlo.collective_bytes,
        "t_compute_s": flops / PEAK_FLOPS_BF16,
        "t_memory_s": bytes_ / HBM_BW,
        "t_collective_s": hlo.collective_bytes / ICI_BW,
    }


def dominant_term(terms: dict) -> str:
    t = {"compute": terms["t_compute_s"], "memory": terms["t_memory_s"],
         "collective": terms["t_collective_s"]}
    return max(t, key=t.get)
