"""ShapeDtypeStruct input stands-ins for every (arch × shape) cell.

No device allocation — the same pattern as the dry-run requires: weak-type
correct, shardable.  Modality frontends are stubs per the assignment:
whisper gets precomputed frame embeddings, qwen2-vl precomputed patch
embeddings."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig, ShapeConfig
from ..models import transformer as T

SDS = jax.ShapeDtypeStruct


def batch_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """Inputs for train/prefill: the full-sequence forward."""
    B, S = shape.global_batch, shape.seq_len
    act = jnp.dtype(cfg.dtype)
    specs = {}
    if cfg.vis_patches:
        P = cfg.vis_patches
        specs["tokens"] = SDS((B, S - P), jnp.int32)
        specs["patches"] = SDS((B, P, cfg.d_model), act)
        specs["labels"] = SDS((B, S), jnp.int32)
    elif cfg.enc_dec:
        specs["tokens"] = SDS((B, S), jnp.int32)
        specs["enc_frames"] = SDS((B, cfg.enc_frames, cfg.d_model), act)
        specs["labels"] = SDS((B, S), jnp.int32)
    else:
        specs["tokens"] = SDS((B, S), jnp.int32)
        specs["labels"] = SDS((B, S), jnp.int32)
    return specs


def decode_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """Inputs for one serve step: (state, token, pos).  The cache stand-in
    comes from eval_shape over init_decode_state — ring-capped for local
    layers, O(1) for recurrent ones."""
    B, S = shape.global_batch, shape.seq_len
    act = jnp.dtype(cfg.dtype)
    pshapes = T.param_shapes(cfg)
    ef = (SDS((B, cfg.enc_frames, cfg.d_model), act) if cfg.enc_dec else None)
    state = jax.eval_shape(
        lambda p, e: T.init_decode_state(p, cfg, B, S, enc_frames=e),
        pshapes, ef)
    return {
        "state": state,
        "token": SDS((B, 1), jnp.int32),
        "pos": SDS((), jnp.int32),
    }


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    if shape.kind == "decode":
        return decode_specs(cfg, shape)
    return batch_specs(cfg, shape)
