"""Solve-as-a-service: a request-batching driver over the plan engine.

Production traffic means thousands of concurrent solves on a handful of
sparsity patterns — exactly the amortization the plan engine was built for.
This driver (modeled on :mod:`repro.launch.serve`'s batched-request loop)
turns a stream of independent ``(A, b)`` requests into grouped, vmapped
dispatches:

1. **group** incoming requests by plan key — shared pattern (the tensors'
   plan-cache identity) + resolved :class:`SolverConfig`, so every request
   in a group runs the same traced program;
2. **pad** each group's stacked values/rhs to the next power-of-two batch
   size (bounded jit recompiles: at most log2(max_batch) shapes per group);
3. **dispatch** ONE jitted, vmapped ``plan.solve`` per group — one analyze
   per pattern (``PLAN_STATS["analyze"]``), one vmapped setup per batch
   (``setup_batch``), one XLA program for the whole group.

The CLI runs the smoke workload and prints the serving report::

    PYTHONPATH=src python -m repro.launch.solve_serve --smoke
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core import dispatch as _dispatch
from ..core.dispatch import PLAN_STATS, SolverConfig, make_config
from ..core.solvers import SolveInfo, SolveResult, as_solve_result
from ..core.sparse import SparseTensor


@dataclasses.dataclass
class SolveRequest:
    """One serving request: a values-carrying tensor, a right-hand side, and
    per-request solver options (``backend``/``method``/``precond``/``tol``/
    ``atol``/``maxiter``).  Requests sharing a pattern (``with_values`` views
    of one tensor) and options land in the same dispatch group."""
    A: SparseTensor
    b: jax.Array
    options: dict = dataclasses.field(default_factory=dict)


def _pow2(k: int) -> int:
    return 1 << max(k - 1, 0).bit_length()


class SolveServer:
    """Groups, pads, and dispatches solve requests as vmapped batches.

    Stateless between batches except for caches: the jit cache (one traced
    program per (plan, config); padded pow2 shapes bound recompiles) and the
    plan caches living on the request tensors themselves.  ``stats`` tracks
    dispatch counts and batch-group occupancy (real requests over padded
    slots — the padding overhead the pow2 policy trades for trace reuse).
    """

    def __init__(self, max_batch: int = 64):
        self.max_batch = max_batch
        self._jits: Dict[tuple, callable] = {}
        self.stats = {"dispatches": 0, "requests": 0, "padded_slots": 0}

    @property
    def occupancy(self) -> float:
        """Real requests / padded batch slots across all dispatches so far."""
        slots = self.stats["padded_slots"]
        return self.stats["requests"] / slots if slots else 1.0

    def _plan_for(self, req: SolveRequest):
        cfg = make_config(req.A, **req.options)
        plan = _dispatch.get_plan(req.A, cfg)
        return plan, cfg

    def _dispatch_fn(self, plan, cfg: SolverConfig):
        key = (id(plan), cfg)
        fn = self._jits.get(key)
        if fn is None:
            def batched(vals, bs, plan=plan, cfg=cfg):
                return plan.solve(plan.matrix(vals), bs, cfg=cfg)
            fn = jax.jit(batched)
            self._jits[key] = fn
        return fn

    def submit_batch(self, requests: List[SolveRequest]) -> List[SolveResult]:
        """Solve a wave of requests; results come back in request order.

        Groups by (pattern identity, resolved config), pads each group's
        stacked values/rhs to a power of two by repeating the first lane,
        and runs one vmapped ``plan.solve`` per group.  Per-request
        diagnostics are sliced back out of the stacked :class:`SolveInfo`.
        """
        groups: Dict[tuple, dict] = {}
        for idx, req in enumerate(requests):
            plan, cfg = self._plan_for(req)
            key = (id(getattr(req.A, "_plans", None)), cfg)
            g = groups.setdefault(key, {"plan": plan, "cfg": cfg,
                                        "members": []})
            g["members"].append((idx, req))

        results: List[Optional[SolveResult]] = [None] * len(requests)
        for g in groups.values():
            plan, cfg, members = g["plan"], g["cfg"], g["members"]
            for start in range(0, len(members), self.max_batch):
                chunk = members[start:start + self.max_batch]
                k = len(chunk)
                pad = _pow2(k)
                vals = jnp.stack(
                    [r.A.val for _, r in chunk]
                    + [chunk[0][1].A.val] * (pad - k))
                bs = jnp.stack(
                    [r.b for _, r in chunk] + [chunk[0][1].b] * (pad - k))
                xs, infos = self._dispatch_fn(plan, cfg)(vals, bs)
                self.stats["dispatches"] += 1
                self.stats["requests"] += k
                self.stats["padded_slots"] += pad
                for lane, (idx, _) in enumerate(chunk):
                    info = SolveInfo(infos.iters[lane], infos.resnorm[lane],
                                     infos.converged[lane])
                    results[idx] = as_solve_result(xs[lane], info)
        return results


# ---------------------------------------------------------------------------
# smoke workload + serving report (what benchmarks/serve.py gates on)
# ---------------------------------------------------------------------------

def _workload(n_requests: int, grid: int, n_patterns: int, seed: int,
              options: dict) -> List[SolveRequest]:
    """Shared-pattern request stream: ``n_patterns`` Poisson grids, each
    request a scaled-values view (same pattern, different values) with a
    random rhs — the traffic shape the plan engine amortizes."""
    from ..data.poisson import poisson2d
    rng = np.random.default_rng(seed)
    bases = [poisson2d(grid + i) for i in range(n_patterns)]
    reqs = []
    for i in range(n_requests):
        A0 = bases[i % n_patterns]
        scale = float(rng.uniform(0.7, 1.4))   # similar conditioning: vmap
        Ai = A0.with_values(A0.val * scale)    # lanes stay near-lockstep
        bi = jnp.asarray(rng.normal(size=A0.shape[0]), A0.val.dtype)
        reqs.append(SolveRequest(Ai, bi, dict(options)))
    return reqs


def serve(n_requests: int = 64, grid: int = 20, n_patterns: int = 1,
          max_batch: int = 32, seed: int = 0, check: bool = True,
          **solve_options) -> dict:
    """Run the serving smoke workload; return the metrics report.

    Times two drivers over the SAME request stream and jitted programs:
    the batched server (grouped + padded + vmapped dispatch) and the
    one-at-a-time loop (one jitted single solve per request).  Reports
    p50/p99 request latency, solves/sec for both, their ratio, batch-group
    occupancy, and the analyze count — the acceptance gate is
    ``speedup ≥ 2`` with ``analyze == n_patterns`` across the whole run.

    ``check=True`` additionally verifies every batched solution against the
    sequential one (parity, not just speed).
    """
    solve_options.setdefault("backend", "jnp")
    solve_options.setdefault("method", "cg")
    solve_options.setdefault("precond", "jacobi")
    solve_options.setdefault("tol", 1e-8)

    _dispatch.reset_plan_stats()
    requests = _workload(n_requests, grid, n_patterns, seed, solve_options)
    server = SolveServer(max_batch=max_batch)

    # sequential driver: one jitted single-rhs solve per request, plan and
    # trace reused — this is the fair baseline (no re-analyze, no re-compile)
    seq_fns = {}
    for req in requests:
        plan, cfg = server._plan_for(req)
        key = (id(req.A._plans), cfg)
        if key not in seq_fns:
            def single(v, bb, plan=plan, cfg=cfg):
                return plan.solve(plan.matrix(v), bb, cfg=cfg)
            seq_fns[key] = (jax.jit(single), plan, cfg)

    # warmup: compile every traced program outside the timed windows
    _ = server.submit_batch(requests)
    seq_results = []
    for req in requests:
        plan, cfg = server._plan_for(req)
        fn = seq_fns[(id(req.A._plans), cfg)][0]
        seq_results.append(fn(req.A.val, req.b))
    jax.block_until_ready([r[0] for r in seq_results])

    # timed: batched server, stream consumed in max_batch waves
    lat_batched = []
    t0 = time.perf_counter()
    out_batched = []
    for start in range(0, len(requests), max_batch):
        wave = requests[start:start + max_batch]
        res = server.submit_batch(wave)
        jax.block_until_ready([r.x for r in res])
        done = time.perf_counter() - t0
        lat_batched.extend([done] * len(wave))
        out_batched.extend(res)
    t_batched = time.perf_counter() - t0

    # timed: sequential loop
    lat_seq = []
    t0 = time.perf_counter()
    out_seq = []
    for req in requests:
        fn = seq_fns[(id(req.A._plans),
                      server._plan_for(req)[1])][0]
        x, info = fn(req.A.val, req.b)
        jax.block_until_ready(x)
        lat_seq.append(time.perf_counter() - t0)
        out_seq.append((x, info))
    t_seq = time.perf_counter() - t0

    if check:
        for res, (x_ref, _) in zip(out_batched, out_seq):
            np.testing.assert_allclose(np.asarray(res.x), np.asarray(x_ref),
                                       rtol=1e-6, atol=1e-8)

    n = len(requests)
    report = {
        "n_requests": n,
        "n_patterns": n_patterns,
        "grid": grid,
        "max_batch": max_batch,
        "batched": {
            "total_s": t_batched,
            "solves_per_sec": n / t_batched,
            "p50_ms": float(np.percentile(lat_batched, 50) * 1e3),
            "p99_ms": float(np.percentile(lat_batched, 99) * 1e3),
        },
        "sequential": {
            "total_s": t_seq,
            "solves_per_sec": n / t_seq,
            "p50_ms": float(np.percentile(lat_seq, 50) * 1e3),
            "p99_ms": float(np.percentile(lat_seq, 99) * 1e3),
        },
        "speedup": t_seq / t_batched,
        "occupancy": server.occupancy,
        "plan_stats": dict(PLAN_STATS),
        "converged": bool(all(r.reason == "converged" for r in out_batched)),
    }
    return report


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=256)
    ap.add_argument("--grid", type=int, default=32)
    ap.add_argument("--patterns", type=int, default=2)
    ap.add_argument("--max-batch", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    kw = dict(n_requests=args.requests, grid=args.grid,
              n_patterns=args.patterns, max_batch=args.max_batch,
              seed=args.seed)
    if args.smoke:
        kw.update(n_requests=64, grid=20, n_patterns=1)
    rep = serve(**kw)
    b, s = rep["batched"], rep["sequential"]
    print(f"requests={rep['n_requests']} patterns={rep['n_patterns']} "
          f"grid={rep['grid']} max_batch={rep['max_batch']}")
    print(f"batched    : {b['solves_per_sec']:8.1f} solves/s  "
          f"p50={b['p50_ms']:.2f} ms  p99={b['p99_ms']:.2f} ms")
    print(f"sequential : {s['solves_per_sec']:8.1f} solves/s  "
          f"p50={s['p50_ms']:.2f} ms  p99={s['p99_ms']:.2f} ms")
    print(f"speedup={rep['speedup']:.2f}x  occupancy={rep['occupancy']:.2f}  "
          f"analyze={rep['plan_stats']['analyze']} "
          f"(converged={rep['converged']})")
    return rep


if __name__ == "__main__":
    main()
