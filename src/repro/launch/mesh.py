"""Production meshes.

Functions, not module-level constants — importing this module never touches
jax device state (device count locks on first use)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips/pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    if hasattr(jax.sharding, "AxisType"):      # absent on older jax releases
        return jax.make_mesh(shape, axes,
                             axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_host_mesh(n: int = 1, axes=("data",)):
    """Small host mesh for tests/examples on forced CPU devices."""
    devs = jax.devices()[:n]
    import numpy as np
    shape = (n,) if len(axes) == 1 else None
    return jax.sharding.Mesh(np.array(devs).reshape(shape), axes)


# TPU v5e hardware constants (roofline targets; this container is CPU-only)
PEAK_FLOPS_BF16 = 197e12       # per chip
HBM_BW = 819e9                 # bytes/s per chip
ICI_BW = 50e9                  # bytes/s per link
