"""Logical-axis sharding rules (MaxText-style), divisibility-aware.

Model code annotates activations/params with *logical* axis names via
``logical(x, "batch", "seq", "embed")``; a rule set maps logical names to
mesh axes.  Rules are installed with a context manager so the same model code
runs unsharded (smoke tests), single-pod (16×16) and multi-pod (2×16×16).

A logical axis silently falls back to replication when the dimension does not
divide the mesh-axis product — e.g. 12 attention heads on a 16-way model axis
(qwen2-1.5b) — so every assigned architecture compiles under the same rules.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional, Sequence, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_STATE = threading.local()

Axes = Union[None, str, Sequence[str]]


class Rules:
    def __init__(self, mesh: Mesh, table: dict):
        self.mesh = mesh
        self.table = dict(table)

    def axes_for(self, name: Optional[str], dim: int) -> Axes:
        if name is None:
            return None
        ax = self.table.get(name)
        if ax is None:
            return None
        axes = (ax,) if isinstance(ax, str) else tuple(ax)
        size = 1
        for a in axes:
            size *= self.mesh.shape[a]
        if dim % size != 0:
            # divisibility fallback: drop trailing axes until it fits
            while axes:
                axes = axes[:-1]
                size = 1
                for a in axes:
                    size *= self.mesh.shape[a]
                if size and dim % size == 0:
                    break
            if not axes:
                return None
        return axes if len(axes) > 1 else (axes[0] if axes else None)

    def spec(self, names: Sequence[Optional[str]], shape) -> P:
        return P(*(self.axes_for(n, d) for n, d in zip(names, shape)))


def current_rules() -> Optional[Rules]:
    return getattr(_STATE, "rules", None)


@contextlib.contextmanager
def use_rules(rules: Optional[Rules]):
    prev = getattr(_STATE, "rules", None)
    _STATE.rules = rules
    try:
        yield
    finally:
        _STATE.rules = prev


def logical(x: jax.Array, *names: Optional[str]) -> jax.Array:
    """Apply a sharding constraint by logical axis names (no-op without
    rules).  A name mapped to "__skip__" disables the whole constraint —
    used for opt-in hints that must not force replication in the baseline."""
    rules = current_rules()
    if rules is None or x.ndim != len(names):
        return x
    if any(rules.table.get(n) == "__skip__" for n in names if n):
        return x
    spec = rules.spec(names, x.shape)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(rules.mesh, spec))


# ---------------------------------------------------------------------------
# rule tables
# ---------------------------------------------------------------------------

def baseline_rules(mesh: Mesh) -> Rules:
    """Paper-faithful baseline: DP over (pod, data), TP over model,
    FSDP-style parameter sharding over data."""
    dp = ("pod", "data") if "pod" in mesh.shape else ("data",)
    return Rules(mesh, {
        "batch": dp,
        "seq": None,
        # residual stream between blocks: sequence-sharded over the model
        # axis (Megatron sequence parallelism) — shrinks the per-layer remat
        # saves 16× and turns TP all-reduces into RS/AG pairs.  Falls back to
        # replication when seq < mesh (decode).
        "seq_res": "model",
        "seq_norm": "__skip__",    # H5 opt-in: pin norm outputs seq-sharded
        "seq_kv": "model",         # decode KV caches: shard cache length
        "kv_heads_cache": None,
        "embed": None,
        "heads": "model",
        "kv_heads": "model",
        "head_dim": None,
        "ff": "model",
        "vocab": "model",
        "experts": "model",
        "expert_cap": None,
        # parameter axes (FSDP over data, TP over model)
        "p_embed": "data",
        "p_ff": "model",
        "p_heads": "model",
        "p_kv_heads": "model",
        "p_vocab": "model",
        "p_experts": "model",
        "p_expert_ff": None,       # EP already consumes the model axis
        "layers": None,
        # long-context sequence parallelism (halo-exchange local attention)
        "seq_shard": dp,
        "state": "model",
    })


def make_specs(rules: Rules, names_tree, shape_tree):
    """Build a pytree of NamedShardings from logical-name tuples + shapes."""
    return jax.tree.map(
        lambda names, shp: NamedSharding(rules.mesh, rules.spec(names, shp)),
        names_tree, shape_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(i, (str, type(None))) for i in x))
