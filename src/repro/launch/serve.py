"""Serving: jitted one-token decode step + a batched-request driver.

``make_serve_step`` is what the decode_* dry-run cells lower; the CLI runs a
small-model batched greedy-decoding demo on CPU:

    PYTHONPATH=src python -m repro.launch.serve --arch mamba2-780m --smoke
"""
from __future__ import annotations

import argparse
import time
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding

from ..configs import get_config, smoke_variant
from ..configs.base import ModelConfig
from ..models import transformer as T
from . import shardings as sh
from .train import make_shardings


def make_serve_step(cfg: ModelConfig, rules: Optional[sh.Rules] = None):
    def serve_step(params, state, token, pos):
        with sh.use_rules(rules):
            logits, new_state = T.decode_step(params, cfg, state, token, pos)
        next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return next_tok[:, None], new_state

    return serve_step


def jit_serve_step(cfg: ModelConfig, rules: sh.Rules, params_shapes,
                   decode_specs: dict):
    paxes = T.param_axes(params_shapes)
    caxes = T.cache_axes(decode_specs["state"])
    p_sh = make_shardings(rules, paxes,
                          jax.tree.map(lambda x: x.shape, params_shapes))
    c_sh = make_shardings(rules, caxes, jax.tree.map(
        lambda x: x.shape, decode_specs["state"]))
    tok_sh = NamedSharding(rules.mesh, rules.spec(("batch", None),
                                                  decode_specs["token"].shape))
    pos_sh = NamedSharding(rules.mesh, rules.spec((), ()))
    step = make_serve_step(cfg, rules)
    return jax.jit(step,
                   in_shardings=(p_sh, c_sh, tok_sh, pos_sh),
                   out_shardings=(tok_sh, c_sh)), (p_sh, c_sh)


# ---------------------------------------------------------------------------
# CLI demo: batched greedy decoding
# ---------------------------------------------------------------------------

def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-780m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_variant(cfg)
    params = T.init_params(cfg, jax.random.PRNGKey(args.seed))
    B = args.batch
    total = args.prompt_len + args.gen_len
    ef = (jnp.zeros((B, cfg.enc_frames, cfg.d_model), jnp.dtype(cfg.dtype))
          if cfg.enc_dec else None)
    state = T.init_decode_state(params, cfg, B, total, enc_frames=ef)
    step = jax.jit(make_serve_step(cfg))

    prompts = jax.random.randint(jax.random.PRNGKey(1), (B, args.prompt_len),
                                 0, cfg.vocab)
    tok = prompts[:, :1]
    out = [tok]
    t0 = time.perf_counter()
    for t in range(total - 1):
        nxt, state = step(params, state, tok, jnp.array(t, jnp.int32))
        tok = prompts[:, t + 1:t + 2] if t + 1 < args.prompt_len else nxt
        out.append(tok)
    jax.block_until_ready(tok)
    dt = time.perf_counter() - t0
    seq = jnp.concatenate(out, axis=1)
    print(f"arch={cfg.name} batch={B} steps={total-1} "
          f"{dt*1e3/(total-1):.1f} ms/token")
    print("sample:", seq[0, :24].tolist())
    return seq


if __name__ == "__main__":
    main()
