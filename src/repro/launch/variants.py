"""Named configuration variants for the §Perf hypothesis→change→measure loop.

Each variant = (rules builder, config transform).  The dry-run records cells
under the variant name so before/after roofline terms live side by side in
the ledger.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Tuple

from ..configs.base import ModelConfig
from . import shardings as sh


def _identity(cfg: ModelConfig) -> ModelConfig:
    return cfg


def _tp_allreduce_rules(mesh) -> sh.Rules:
    """Paper-naive TP: seq-replicated residual stream (all-reduce after every
    row-parallel matmul, full-size remat saves) — the pre-seq_res baseline."""
    r = sh.baseline_rules(mesh)
    r.table["seq_res"] = None
    return r


def _bf16_params(cfg: ModelConfig) -> ModelConfig:
    """H1: parameters in bf16 (f32 optimizer moments unchanged) — halves the
    FSDP all-gather / grad reduce-scatter payloads and the parameter HBM
    traffic."""
    return dataclasses.replace(cfg, param_dtype="bfloat16")


def _moe_tight_capacity(cfg: ModelConfig) -> ModelConfig:
    """H2 (MoE): capacity factor 1.25 → 1.0 — cuts the (B,E,C,d) all-to-all
    payload and expert FLOPs by 20% at the cost of more dropped tokens."""
    return dataclasses.replace(_bf16_params(cfg), capacity_factor=1.0)


def _ssm_seqpar(cfg: ModelConfig) -> ModelConfig:
    """H3 (SSM): sequence-domain decomposition of the SSD mixer across the
    model axis with neighbour state passing — the paper's §3.3 halo pattern;
    per-chip mixer work drops ~16×."""
    return dataclasses.replace(_bf16_params(cfg), seq_shards_mixer=16)


def _seqpar_rules(mesh) -> sh.Rules:
    r = sh.baseline_rules(mesh)
    r.table["seq_mixer"] = "model"
    r.table["seq"] = "__skip__"     # let seq sharding propagate from seq_res
    r.table["heads"] = None         # the model axis now belongs to sequence
    r.table["kv_heads"] = None
    r.table["ff"] = None
    return r


def _h5_rules(mesh) -> sh.Rules:
    """H5: pin bf16 norm outputs to the sequence-sharded layout so GSPMD
    gathers the 2-byte tensor, not the f32 rmsnorm internals (the dominant
    all-gather in large dense trains is an f32 (B,S,d) gather)."""
    r = sh.baseline_rules(mesh)
    r.table["seq_norm"] = "model"
    return r


def _dots_remat(cfg: ModelConfig) -> ModelConfig:
    """H4: remat policy full → dots-saveable (keeps matmul outputs, skips
    recompute) — trades HBM bytes for compute-term FLOPs."""
    return dataclasses.replace(_bf16_params(cfg), remat="dots")


def _pack2(cfg: ModelConfig) -> ModelConfig:
    """H7 (memory): scan TWO layers per period — the per-step remat save is
    the period input, so the saved-carry stack halves (L/2 × (B,S/16,d))
    while full-remat recompute FLOPs stay identical."""
    return dataclasses.replace(cfg, layer_pattern=cfg.layer_pattern * 2)


VARIANTS: dict = {
    "baseline": (sh.baseline_rules, _identity),
    "tp_allreduce": (_tp_allreduce_rules, _identity),
    "bf16_params": (sh.baseline_rules, _bf16_params),
    "moe_cap1.0": (sh.baseline_rules, _moe_tight_capacity),
    "ssm_seqpar": (_seqpar_rules, _ssm_seqpar),
    "dots_remat": (sh.baseline_rules, _dots_remat),
    "h5_norm_shard": (_h5_rules, _identity),
    "h5+cap1.0": (_h5_rules, _moe_tight_capacity),
    "pack2": (sh.baseline_rules, _pack2),
}
