import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run: prove the distribution config is coherent without
hardware.

For every (architecture × input shape) cell, on the single-pod 16×16 mesh and
the 2×16×16 multi-pod mesh:

    lowered  = jax.jit(step, in_shardings=…, out_shardings=…).lower(**specs)
    compiled = lowered.compile()
    print(compiled.memory_analysis())     # fits?
    print(compiled.cost_analysis())       # FLOPs/bytes → §Roofline

Results append to a JSONL ledger (results/dryrun.jsonl) consumed by
EXPERIMENTS.md §Dry-run and §Roofline.  long_500k is skipped (and recorded
as such) for pure full-attention archs per DESIGN.md §Arch-applicability.

    PYTHONPATH=src python -m repro.launch.dryrun --arch llama3.2-1b \
        --shape train_4k --mesh single
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
"""
import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp


def run_cell(arch: str, shape_name: str, multi_pod: bool, *,
             variant: str = "baseline") -> dict:
    from ..configs import SHAPES, get_config, is_subquadratic
    from ..models import transformer as T
    from ..optim.adamw import AdamWConfig, init_opt_state
    from . import roofline as R
    from . import shardings as sh
    from .mesh import make_production_mesh
    from .specs import batch_specs, decode_specs
    from .train import jit_train_step
    from .serve import jit_serve_step
    from .variants import VARIANTS

    rules_builder, cfg_transform = VARIANTS[variant]
    cfg = cfg_transform(get_config(arch))
    shape = SHAPES[shape_name]
    mesh_name = "2x16x16" if multi_pod else "16x16"
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "rules": variant, "kind": shape.kind}

    if shape_name == "long_500k" and not is_subquadratic(cfg):
        rec.update(status="skipped",
                   reason="pure full-attention arch — quadratic at 524k "
                          "(DESIGN.md §Arch-applicability)")
        return rec

    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    rules = rules_builder(mesh)
    pshapes = T.param_shapes(cfg)
    n_params = sum(int(jnp.prod(jnp.array(x.shape)))
                   for x in jax.tree.leaves(pshapes))

    if shape.kind == "train":
        specs = batch_specs(cfg, shape)
        opt_cfg = AdamWConfig()
        step, state_sh = jit_train_step(cfg, opt_cfg, rules, pshapes, specs)
        state_shapes = {"params": pshapes,
                        "opt": jax.eval_shape(init_opt_state, pshapes)}
        lowered = step.lower(state_shapes, specs)
    elif shape.kind == "prefill":
        specs = batch_specs(cfg, shape)
        specs.pop("labels")
        from .train import BATCH_AXES, make_shardings

        def prefill(params, batch):
            with sh.use_rules(rules):
                logits, _ = T.forward(params, cfg, batch["tokens"],
                                      patches=batch.get("patches"),
                                      enc_frames=batch.get("enc_frames"),
                                      last_only=True)
            return logits

        p_sh = make_shardings(rules, T.param_axes(pshapes),
                              jax.tree.map(lambda x: x.shape, pshapes))
        b_sh = make_shardings(rules, {k: BATCH_AXES[k] for k in specs},
                              {k: v.shape for k, v in specs.items()})
        lowered = jax.jit(prefill, in_shardings=(p_sh, b_sh)).lower(
            pshapes, specs)
    else:  # decode
        specs = decode_specs(cfg, shape)
        step, _ = jit_serve_step(cfg, rules, pshapes, specs)
        lowered = step.lower(pshapes, specs["state"], specs["token"],
                             specs["pos"])

    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost_list = compiled.cost_analysis()
    cost = cost_list[0] if isinstance(cost_list, (list, tuple)) else cost_list
    text = compiled.as_text()
    hlo = R.analyze_hlo(text)
    terms = R.roofline_terms(cost, hlo, chips)
    mf = R.model_flops(cfg, shape)

    mem_rec = {}
    for attr in ("temp_size_in_bytes", "argument_size_in_bytes",
                 "output_size_in_bytes", "alias_size_in_bytes",
                 "generated_code_size_in_bytes"):
        v = getattr(mem, attr, None)
        if v is not None:
            mem_rec[attr] = int(v)

    global_flops = terms["hlo_flops_per_chip"] * chips
    rec.update(
        status="ok",
        chips=chips,
        n_params=n_params,
        lower_s=round(t_lower, 1),
        compile_s=round(t_compile, 1),
        memory=mem_rec,
        bytes_per_device=int(sum(mem_rec.get(k, 0) for k in
                                 ("temp_size_in_bytes",
                                  "argument_size_in_bytes"))),
        collectives=hlo.coll_by_kind,
        n_collectives=hlo.n_collectives,
        model_flops=mf,
        useful_ratio=(mf / global_flops) if global_flops else None,
        **terms,
    )
    rec["dominant"] = R.dominant_term(terms)
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--ledger", default="results/dryrun.jsonl")
    ap.add_argument("--force", action="store_true",
                    help="recompute cells already in the ledger")
    args = ap.parse_args(argv)

    from ..configs import ARCH_IDS, SHAPES

    archs = ARCH_IDS if (args.all or args.arch is None) else [args.arch]
    shapes = list(SHAPES) if (args.all or args.shape is None) else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    os.makedirs(os.path.dirname(args.ledger) or ".", exist_ok=True)
    done = set()
    if os.path.exists(args.ledger) and not args.force:
        with open(args.ledger) as f:
            for line in f:
                try:
                    r = json.loads(line)
                    done.add((r["arch"], r["shape"], r["mesh"], r.get("rules", "baseline")))
                except json.JSONDecodeError:
                    pass

    n_ok = n_skip = n_fail = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                mesh_name = "2x16x16" if mp else "16x16"
                key = (arch, shape, mesh_name, args.variant)
                if key in done:
                    continue
                print(f"=== {arch} × {shape} × {mesh_name} ===", flush=True)
                try:
                    rec = run_cell(arch, shape, mp, variant=args.variant)
                except Exception as e:
                    rec = {"arch": arch, "shape": shape, "mesh": mesh_name,
                           "rules": args.variant, "status": "error",
                           "error": f"{type(e).__name__}: {e}",
                           "trace": traceback.format_exc()[-2000:]}
                with open(args.ledger, "a") as f:
                    f.write(json.dumps(rec) + "\n")
                st = rec["status"]
                n_ok += st == "ok"
                n_skip += st == "skipped"
                n_fail += st == "error"
                if st == "ok":
                    print(f"  compile {rec['compile_s']}s | "
                          f"{rec['bytes_per_device']/2**30:.2f} GiB/dev | "
                          f"t_comp {rec['t_compute_s']*1e3:.2f} ms "
                          f"t_mem {rec['t_memory_s']*1e3:.2f} ms "
                          f"t_coll {rec['t_collective_s']*1e3:.2f} ms "
                          f"→ {rec['dominant']} | useful "
                          f"{(rec['useful_ratio'] or 0)*100:.0f}%", flush=True)
                else:
                    print(f"  {st}: {rec.get('reason', rec.get('error'))}",
                          flush=True)
    print(f"done: {n_ok} ok, {n_skip} skipped, {n_fail} failed")
    return 0 if n_fail == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
