"""Training step + fault-tolerant CLI driver.

``make_train_step`` builds the jitted (params, opt, batch) → (params, opt,
metrics) function with logical-rule sharding; the CLI trains a reduced config
on CPU with checkpoint/restart through the FT driver:

    PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b \
        --steps 200 --smoke --batch 8 --seq 256
"""
from __future__ import annotations

import argparse
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs import get_config, smoke_variant
from ..configs.base import ModelConfig
from ..models import transformer as T
from ..optim.adamw import AdamWConfig, adamw_update, init_opt_state
from . import shardings as sh

BATCH_AXES = {
    "tokens": ("batch", None), "labels": ("batch", None),
    "patches": ("batch", None, None), "enc_frames": ("batch", None, None),
}


def _ce_terms(embed_params, cfg, x, labels):
    """(−Σ log p, Σ mask) for one slice — logits live only inside, kept in
    the padded (vocab-shardable) layout."""
    from ..models.layers import unembed
    logits = unembed(embed_params, x, cfg, sliced=False)
    mask = (labels >= 0).astype(jnp.float32)
    safe = jnp.maximum(labels, 0)
    lse = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(lse, safe[..., None], axis=-1)[..., 0]
    return -(ll * mask).sum(), mask.sum()


def chunked_ce(embed_params, cfg: ModelConfig, x, labels, num_chunks: int):
    """Cross-entropy scanned over batch chunks with per-chunk remat: the
    (B,S,V) f32 logits tensor is never materialized — peak extra memory is
    one (B/num_chunks, S, V) block."""
    B = x.shape[0]
    if num_chunks <= 1 or B % num_chunks:
        return _ce_terms(embed_params, cfg, x, labels)
    c = B // num_chunks
    xs = x.reshape(num_chunks, c, *x.shape[1:])
    ls = labels.reshape(num_chunks, c, *labels.shape[1:])

    body = jax.checkpoint(
        lambda xc, lc: _ce_terms(embed_params, cfg, xc, lc))

    def scan_fn(acc, inp):
        xc, lc = inp
        nll, cnt = body(xc, lc)
        return (acc[0] + nll, acc[1] + cnt), None

    (nll, cnt), _ = jax.lax.scan(
        scan_fn, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        (xs, ls))
    return nll, cnt


def loss_fn(params, cfg: ModelConfig, batch: dict, num_ce_chunks: int = 1):
    hidden, aux = T.forward(params, cfg, batch["tokens"],
                            patches=batch.get("patches"),
                            enc_frames=batch.get("enc_frames"),
                            return_hidden=True)
    labels = batch["labels"]
    nll, cnt = chunked_ce(params["embed"], cfg, hidden, labels, num_ce_chunks)
    loss = nll / jnp.maximum(cnt, 1.0)
    total = loss + 0.01 * aux
    return total, {"loss": loss, "moe_aux": aux, "tokens": cnt}


def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig,
                    rules: Optional[sh.Rules] = None,
                    num_ce_chunks: int = 1):
    def step(state, batch):
        with sh.use_rules(rules):
            grad_fn = jax.value_and_grad(
                lambda p: loss_fn(p, cfg, batch, num_ce_chunks), has_aux=True)
            (total, metrics), grads = grad_fn(state["params"])
            params, opt, opt_metrics = adamw_update(
                opt_cfg, state["params"], grads, state["opt"])
        metrics = dict(metrics, **opt_metrics, total_loss=total)
        return {"params": params, "opt": opt}, metrics

    return step


def state_axes(params_shapes) -> dict:
    paxes = T.param_axes(params_shapes)
    return {
        "params": paxes,
        "opt": {"m": paxes, "v": paxes,
                "step": (None,) if False else ()},
    }


def make_shardings(rules: sh.Rules, axes_tree, shapes_tree):
    def one(ax, shp):
        if not isinstance(shp, (tuple, list)):
            shp = shp.shape
        return NamedSharding(rules.mesh, rules.spec(ax, shp))
    return jax.tree.map(
        one, axes_tree, shapes_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(i, (str, type(None))) for i in x))


def jit_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig, rules: sh.Rules,
                   params_shapes, batch_specs: dict):
    """Fully-sharded jitted train step for dry-run / pods."""
    saxes = state_axes(params_shapes)
    state_shapes = {"params": params_shapes,
                    "opt": jax.eval_shape(init_opt_state, params_shapes)}
    saxes["opt"]["step"] = ()
    state_sh = make_shardings(rules, saxes, jax.tree.map(
        lambda x: x.shape, state_shapes))
    batch_axes = {k: BATCH_AXES[k] for k in batch_specs}
    batch_sh = make_shardings(rules, batch_axes,
                              {k: v.shape for k, v in batch_specs.items()})
    # CE chunking: one batch row per data shard at a time
    B = batch_specs["labels"].shape[0]
    dp = 1
    for ax in ("pod", "data"):
        dp *= rules.mesh.shape.get(ax, 1)
    nc = (B // dp) if B % dp == 0 and B // dp > 1 else 1
    step = make_train_step(cfg, opt_cfg, rules, num_ce_chunks=nc)
    return jax.jit(step, in_shardings=(state_sh, batch_sh),
                   out_shardings=(state_sh, None)), state_sh


# ---------------------------------------------------------------------------
# CLI: end-to-end CPU training with fault tolerance
# ---------------------------------------------------------------------------

def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-trainable)")
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--fail-at", type=int, default=None,
                    help="inject a failure (FT demo)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_variant(cfg)
    opt_cfg = AdamWConfig(lr=args.lr, warmup_steps=20, total_steps=args.steps)

    from ..data.tokens import synthetic_batch
    from ..ft.driver import FTConfig, TrainLoop

    key = jax.random.PRNGKey(args.seed)
    params = T.init_params(cfg, key)
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"arch={cfg.name} params={n_params/1e6:.1f}M "
          f"steps={args.steps} batch={args.batch} seq={args.seq}")
    state = {"params": params, "opt": init_opt_state(params)}
    step = jax.jit(make_train_step(cfg, opt_cfg))

    def make_batch(s):
        b = synthetic_batch(args.seed, s, args.batch, args.seq + 1, cfg.vocab)
        if cfg.vis_patches:
            P_ = cfg.vis_patches
            b = {"tokens": b["tokens"],
                 "patches": jnp.zeros((args.batch, P_, cfg.d_model),
                                      jnp.dtype(cfg.dtype)),
                 "labels": jnp.concatenate(
                     [-jnp.ones((args.batch, P_), jnp.int32), b["labels"]], 1)}
        elif cfg.enc_dec:
            b = dict(b, enc_frames=jnp.zeros(
                (args.batch, cfg.enc_frames, cfg.d_model), jnp.dtype(cfg.dtype)))
        return b

    loop = TrainLoop(FTConfig(ckpt_dir=args.ckpt_dir,
                              ckpt_every=args.ckpt_every),
                     step, make_batch)
    start = 0
    if args.resume:
        latest = loop.mgr.latest_step()
        if latest is not None:
            state = loop.mgr.restore(latest, state)
            start = latest
            print(f"resumed from step {latest}")
    state, last = loop.run(state, args.steps, start_step=start,
                           fail_at=args.fail_at)
    print(f"finished at step {last}")
    return state


if __name__ == "__main__":
    main()
