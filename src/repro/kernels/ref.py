"""Pure-jnp oracles for the Pallas kernels.

Each kernel in this package has its reference implementation here; tests
sweep shapes/dtypes and ``assert_allclose`` kernel-vs-ref (interpret mode on
CPU, compiled on TPU).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def stencil5_ref(val5: jax.Array, x: jax.Array) -> jax.Array:
    """Variable-coefficient 5-point stencil apply.

    ``val5``: (5, nx, ny) signed coefficient planes ordered (C, N, S, W, E);
    ``x``: (nx, ny).  Out-of-domain coefficients are zero by construction, so
    clamped shifts never contribute.

        y[i,j] = C·x[i,j] + N·x[i-1,j] + S·x[i+1,j] + W·x[i,j-1] + E·x[i,j+1]
    """
    xn = jnp.pad(x, ((1, 0), (0, 0)))[:-1, :]   # x[i-1, j]
    xs = jnp.pad(x, ((0, 1), (0, 0)))[1:, :]    # x[i+1, j]
    xw = jnp.pad(x, ((0, 0), (1, 0)))[:, :-1]   # x[i, j-1]
    xe = jnp.pad(x, ((0, 0), (0, 1)))[:, 1:]    # x[i, j+1]
    return (val5[0] * x + val5[1] * xn + val5[2] * xs
            + val5[3] * xw + val5[4] * xe)


def fused_cg_update_ref(x, r, p, s, dinv, alpha):
    xn = x + alpha * p
    rn = r - alpha * s
    zn = dinv * rn
    return xn, rn, zn, jnp.sum(rn * zn), jnp.sum(rn * rn)


def fused_cg_direction_ref(z, w, p, s, beta):
    return z + beta * p, w + beta * s, jnp.sum(w * z)


def fused_cg_halfstep_ref(x, r, p, s, alpha):
    xn = x + alpha * p
    rn = r - alpha * s
    return xn, rn, jnp.sum(rn * rn)


def fused_cheb_step_ref(x, dk, rk, c1, c2):
    dn = c1 * dk + c2 * rk
    return x + dn, dn


def fused_dots2_ref(u, v):
    return jnp.sum(u * v), jnp.sum(u * u)


def fused_bicg_p_ref(r, p, v, dinv, beta, omega, restart):
    pn = jnp.where(restart != 0, r, r + beta * (p - omega * v))
    return pn, dinv * pn


def fused_bicg_s_ref(r, v, dinv, alpha):
    sn = r - alpha * v
    return sn, dinv * sn


def fused_bicg_tail_ref(x, s, t, phat, shat, rhat, alpha, omega):
    xn = x + alpha * phat + omega * shat
    rn = s - omega * t
    return xn, rn, jnp.sum(rhat * rn), jnp.sum(rn * rn)


def bell_matvec_ref(bell_vals: jax.Array, block_cols: jax.Array,
                    x_pad: jax.Array, n: int) -> jax.Array:
    """Block-ELL SpMV oracle.

    ``bell_vals``: (n_rb, k, bm, bn) dense blocks; ``block_cols``: (n_rb, k)
    column-block ids; ``x_pad``: (m_pad,).  Returns y (n,).
    """
    n_rb, k, bm, bn = bell_vals.shape
    xb = x_pad.reshape(-1, bn)                       # (n_cb, bn)
    gathered = xb[block_cols]                        # (n_rb, k, bn)
    y = jnp.einsum("rkab,rkb->ra", bell_vals, gathered)
    return y.reshape(n_rb * bm)[:n]


# ---------------------------------------------------------------------------
# Supernodal panel kernels (kernels/supernode.py)
#
# The single-lane *_body functions below are the single source of truth for
# the panel math: the oracles here vmap them over the bucket's lane axis, and
# the Pallas kernels call the very same bodies on their per-lane VMEM blocks,
# so kernel-vs-ref parity is structural rather than re-derived.
#
# Lane layout (one supernode of bucket shape (wb, rb), true size (w, r)):
#   P (wb+rb, wb): rows 0..wb-1 the dense diagonal block D (strict lower = L,
#       diagonal = pivots, strict upper = U), rows wb.. the sub-diagonal
#       L panel over the supernode's row structure R_s;
#   Q (wb, rb):    the U panel — rows of U over R_s.
# Entries gathered from pad slots hold scratch garbage, so every body first
# masks rows/columns beyond (w, r) to zero and plants a unit diagonal on pad
# pivots, making pad lanes exact no-ops (unlike the scalar path, where pads
# are element-wise no-ops by construction).
# ---------------------------------------------------------------------------


def sn_pair_det(a, b, c, e):
    """Clamped determinant of a static Bunch–Kaufman 2x2 pivot E=[[a,b],[c,e]].

    The floor is *locally* scaled (eps·max|E|² + tiny), computed identically
    at factor and solve time from the same raw stored entries, so both sides
    see the same (possibly clamped) determinant without persisting it."""
    det = a * e - b * c
    eps = jnp.finfo(det.dtype).eps
    scale = jnp.maximum(jnp.maximum(jnp.abs(a), jnp.abs(e)),
                        jnp.maximum(jnp.abs(b), jnp.abs(c)))
    floor = eps * scale * scale + jnp.finfo(det.dtype).tiny
    bad = jnp.abs(det) < floor
    detc = jnp.where(bad, jnp.where(det < 0, -floor, floor), det)
    return detc, bad


def sn_panel_mask(P, Q, w, r):
    """Zero pad rows/cols of a gathered (P, Q) lane; unit pad diagonal."""
    m, wb = P.shape
    rb = Q.shape[1]
    ri = jnp.arange(m)[:, None]
    cj = jnp.arange(wb)[None, :]
    tw = cj < w
    row_ok = jnp.where(ri < wb, ri < w, (ri - wb) < r)
    P = jnp.where(row_ok & tw, P, 0.0)
    P = P + jnp.where((ri == cj) & (cj >= w), 1.0, 0.0)
    Q = jnp.where((jnp.arange(wb)[:, None] < w)
                  & (jnp.arange(rb)[None, :] < r), Q, 0.0)
    return P, Q


def sn_block_mask(D, w):
    """Zero pad rows/cols of a gathered diagonal block; unit pad diagonal."""
    wb = D.shape[0]
    ri = jnp.arange(wb)[:, None]
    cj = jnp.arange(wb)[None, :]
    D = jnp.where((ri < w) & (cj < w), D, 0.0)
    return D + jnp.where((ri == cj) & (ri >= w), 1.0, 0.0)


def sn_panel_factor_body(P, Q, w, r, tau, bkm, *, pairs: bool, guard: bool):
    """Dense right-looking factorization of one supernode panel.

    Matches the scalar packed-scan semantics entry for entry: L columns are
    divided by their pivot, U rows (including the diagonal block's strict
    upper and the Q panel) stay raw, and clamped pivots persist into storage.
    With ``pairs``, columns flagged in ``bkm`` start a static 2x2 pivot: the
    pair is eliminated jointly through E⁻¹ and its four defining entries
    (a, e on the diagonal, b above, c below) are stored raw — they are private
    to the diagonal block, consumed only by block solves and slogdet.
    Returns (P, Q, nbad) where nbad counts clamped 1x1 pivots / 2x2 dets.
    """
    m, wb = P.shape
    P, Q = sn_panel_mask(P, Q, w, r)
    one = jnp.ones((), P.dtype)
    zero = jnp.zeros((), P.dtype)
    false = jnp.zeros((), bool)
    rows = jnp.arange(m)
    cols = jnp.arange(wb)

    def step(t, carry):
        P, Q, nbad = carry
        start = bkm[t] if pairs else false
        second = (bkm[jnp.maximum(t - 1, 0)] & (t > 0)) if pairs else false
        # -- 1x1 elimination (bypassed with a unit divisor on pair members,
        #    so the discarded branch stays finite under AD) --
        d = P[t, t]
        if guard:
            bad1 = jnp.abs(d) < tau
            dc = jnp.where(bad1, jnp.where(d < 0, -tau, tau), d)
        else:
            bad1 = false
            dc = d
        deff = jnp.where(start | second, one, dc) if pairs else dc
        colL = jnp.where(rows > t, P[:, t] / deff, 0.0)
        urow = jnp.where(cols > t, P[t, :], 0.0)
        P1 = P - colL[:, None] * urow[None, :]
        P1 = P1.at[:, t].set(jnp.where(rows > t, colL, P[:, t]))
        P1 = P1.at[t, t].set(dc)
        Q1 = Q - colL[:wb, None] * Q[t, :][None, :]
        if not pairs:
            return P1, Q1, nbad + bad1.astype(P.dtype)
        # -- 2x2 elimination for the pair (t, t+1); t1 is clamped so the
        #    branch stays in-bounds when discarded at t = wb-1 --
        t1 = jnp.minimum(t + 1, wb - 1)
        a, b = P[t, t], P[t, t1]
        c, e = P[t1, t], P[t1, t1]
        detc, bad2 = sn_pair_det(a, b, c, e)
        below2 = rows > t1
        u = jnp.where(below2, P[:, t], 0.0)
        v = jnp.where(below2, P[:, t1], 0.0)
        lu = (u * e - v * c) / detc
        lv = (v * a - u * b) / detc
        urow1 = jnp.where(cols > t1, P[t, :], 0.0)
        urow2 = jnp.where(cols > t1, P[t1, :], 0.0)
        P2 = P - lu[:, None] * urow1[None, :] - lv[:, None] * urow2[None, :]
        P2 = P2.at[:, t].set(jnp.where(below2, lu, P[:, t]))
        P2 = P2.at[:, t1].set(jnp.where(below2, lv, P[:, t1]))
        Q2 = (Q - lu[:wb, None] * Q[t, :][None, :]
              - lv[:wb, None] * Q[t1, :][None, :])
        Pn = jnp.where(start, P2, jnp.where(second, P, P1))
        Qn = jnp.where(start, Q2, jnp.where(second, Q, Q1))
        nbad = nbad + jnp.where(
            start, bad2.astype(P.dtype),
            jnp.where(second, zero, bad1.astype(P.dtype)))
        return Pn, Qn, nbad

    return jax.lax.fori_loop(0, wb, step, (P, Q, zero))


def sn_trsv_body(D, y, w, bkm, *, mode: str, pairs: bool):
    """Dense triangular solve on one supernode diagonal block.

    Modes (all operate on the packed block: strict lower = unit-L, diagonal =
    pivots, strict upper = U):

    - ``"l"``:  unit-lower forward solve (L y = b);
    - ``"lt"``: unit-upper backward solve (Lᵀ x = y);
    - ``"u"``:  upper backward solve with pivot divides (U x = y);
    - ``"ut"``: lower forward solve with pivot divides (Uᵀ y = b).

    With ``pairs``, the stored subdiagonal c at a pair start is NOT an L entry
    (the pair's L block is the identity): the unit-triangular modes mask it,
    and the pivot modes solve the 2x2 system E / Eᵀ jointly.
    """
    wb = D.shape[0]
    D = sn_block_mask(D, w)
    idx = jnp.arange(wb)
    x = jnp.where(idx < w, y, 0.0)
    one = jnp.ones((), D.dtype)
    false = jnp.zeros((), bool)
    if pairs and mode in ("l", "lt"):
        # pair-start subdiagonal holds raw c — identity in the unit factor
        sub = (idx[:, None] == idx[None, :] + 1) & bkm[None, :]
        D = jnp.where(sub, 0.0, D)
    if mode == "l":
        return jax.lax.fori_loop(
            0, wb,
            lambda t, x: x - jnp.where(idx > t, D[:, t], 0.0) * x[t], x)
    if mode == "lt":
        def lt_step(i, x):
            t = wb - 1 - i
            return x - jnp.where(idx < t, D[t, :], 0.0) * x[t]
        return jax.lax.fori_loop(0, wb, lt_step, x)

    def step(i, x):
        t = (wb - 1 - i) if mode == "u" else i
        start = bkm[t] if pairs else false
        second = (bkm[jnp.maximum(t - 1, 0)] & (t > 0)) if pairs else false
        dd = jnp.where(start | second, one, D[t, t]) if pairs else D[t, t]
        xt1 = x[t] / dd
        if mode == "u":
            prop = jnp.where(idx < t, D[:, t], 0.0)       # U column above t
        else:
            prop = jnp.where(idx > t, D[t, :], 0.0)       # Uᵀ: U row past t
        x1 = (x - prop * xt1).at[t].set(xt1)
        if not pairs:
            return x1
        t1 = jnp.minimum(t + 1, wb - 1)
        a, b = D[t, t], D[t, t1]
        c, e = D[t1, t], D[t1, t1]
        detc, _ = sn_pair_det(a, b, c, e)
        rt, rt1 = x[t], x[t1]
        if mode == "u":           # E [xt, xtt] = [rt, rt1]
            xt = (e * rt - b * rt1) / detc
            xtt = (a * rt1 - c * rt) / detc
            p1 = jnp.where(idx < t, D[:, t], 0.0)
            p2 = jnp.where(idx < t, D[:, t1], 0.0)
        else:                     # Eᵀ [xt, xtt] = [rt, rt1]
            xt = (e * rt - c * rt1) / detc
            xtt = (a * rt1 - b * rt) / detc
            p1 = jnp.where(idx > t1, D[t, :], 0.0)
            p2 = jnp.where(idx > t1, D[t1, :], 0.0)
        x2 = (x - p1 * xt - p2 * xtt).at[t].set(xt).at[t1].set(xtt)
        return jnp.where(start, x2, jnp.where(second, x, x1))

    return jax.lax.fori_loop(0, wb, step, x)


def sn_panel_factor_ref(P, Q, wvec, rvec, tau, bkm, *, pairs=False,
                        guard=True):
    """Batched oracle: vmap of :func:`sn_panel_factor_body` over lanes.

    Returns (P, Q, nbad_total)."""
    fn = jax.vmap(
        lambda p, q, w, r, m: sn_panel_factor_body(
            p, q, w, r, tau, m, pairs=pairs, guard=guard))
    P, Q, nbad = fn(P, Q, wvec, rvec, bkm)
    return P, Q, jnp.sum(nbad)


def sn_schur_ref(P, Q):
    """Batched Schur-complement GEMM: S[l] = Lpanel[l] @ Upanel[l].

    ``P`` (k, wb+rb, wb) masked/divided panels, ``Q`` (k, wb, rb) raw U rows;
    returns (k, rb, rb) updates to scatter-subtract into the trailing slots.
    """
    wb = Q.shape[1]
    return jnp.einsum("kiw,kwr->kir", P[:, wb:, :], Q)


def sn_trsv_ref(D, y, wvec, bkm, *, mode, pairs=False):
    """Batched oracle: vmap of :func:`sn_trsv_body` over lanes."""
    return jax.vmap(
        lambda d, yy, w, m: sn_trsv_body(d, yy, w, m, mode=mode,
                                         pairs=pairs))(D, y, wvec, bkm)
