"""Pure-jnp oracles for the Pallas kernels.

Each kernel in this package has its reference implementation here; tests
sweep shapes/dtypes and ``assert_allclose`` kernel-vs-ref (interpret mode on
CPU, compiled on TPU).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def stencil5_ref(val5: jax.Array, x: jax.Array) -> jax.Array:
    """Variable-coefficient 5-point stencil apply.

    ``val5``: (5, nx, ny) signed coefficient planes ordered (C, N, S, W, E);
    ``x``: (nx, ny).  Out-of-domain coefficients are zero by construction, so
    clamped shifts never contribute.

        y[i,j] = C·x[i,j] + N·x[i-1,j] + S·x[i+1,j] + W·x[i,j-1] + E·x[i,j+1]
    """
    xn = jnp.pad(x, ((1, 0), (0, 0)))[:-1, :]   # x[i-1, j]
    xs = jnp.pad(x, ((0, 1), (0, 0)))[1:, :]    # x[i+1, j]
    xw = jnp.pad(x, ((0, 0), (1, 0)))[:, :-1]   # x[i, j-1]
    xe = jnp.pad(x, ((0, 0), (0, 1)))[:, 1:]    # x[i, j+1]
    return (val5[0] * x + val5[1] * xn + val5[2] * xs
            + val5[3] * xw + val5[4] * xe)


def fused_cg_update_ref(x, r, p, s, dinv, alpha):
    xn = x + alpha * p
    rn = r - alpha * s
    zn = dinv * rn
    return xn, rn, zn, jnp.sum(rn * zn), jnp.sum(rn * rn)


def fused_cg_direction_ref(z, w, p, s, beta):
    return z + beta * p, w + beta * s, jnp.sum(w * z)


def fused_cg_halfstep_ref(x, r, p, s, alpha):
    xn = x + alpha * p
    rn = r - alpha * s
    return xn, rn, jnp.sum(rn * rn)


def fused_cheb_step_ref(x, dk, rk, c1, c2):
    dn = c1 * dk + c2 * rk
    return x + dn, dn


def fused_dots2_ref(u, v):
    return jnp.sum(u * v), jnp.sum(u * u)


def fused_bicg_p_ref(r, p, v, dinv, beta, omega, restart):
    pn = jnp.where(restart != 0, r, r + beta * (p - omega * v))
    return pn, dinv * pn


def fused_bicg_s_ref(r, v, dinv, alpha):
    sn = r - alpha * v
    return sn, dinv * sn


def fused_bicg_tail_ref(x, s, t, phat, shat, rhat, alpha, omega):
    xn = x + alpha * phat + omega * shat
    rn = s - omega * t
    return xn, rn, jnp.sum(rhat * rn), jnp.sum(rn * rn)


def bell_matvec_ref(bell_vals: jax.Array, block_cols: jax.Array,
                    x_pad: jax.Array, n: int) -> jax.Array:
    """Block-ELL SpMV oracle.

    ``bell_vals``: (n_rb, k, bm, bn) dense blocks; ``block_cols``: (n_rb, k)
    column-block ids; ``x_pad``: (m_pad,).  Returns y (n,).
    """
    n_rb, k, bm, bn = bell_vals.shape
    xb = x_pad.reshape(-1, bn)                       # (n_cb, bn)
    gathered = xb[block_cols]                        # (n_rb, k, bn)
    y = jnp.einsum("rkab,rkb->ra", bell_vals, gathered)
    return y.reshape(n_rb * bm)[:n]
