"""Pallas TPU kernel: flash attention (online-softmax, causal/bidir).

The LM substrate's perf-critical hotspot.  The CPU dry-run uses the pure-jnp
query-chunked attention (models/attention.py) so HLO stays portable; on TPU
this kernel replaces it — O(block) VMEM, no (S,T) score materialization,
MXU-aligned (bq×d)·(d×bk) tiles.

Layout per grid step (bh, i, j):
    q   (1, bq, d)   — query block i of batch·head bh
    k,v (1, bk, d)   — KV block j
    scratch: m (bq,), l (bq,), acc (bq, d)  — running max / denom / output
The KV dimension is the innermost grid axis; scratch carries the online
softmax state across j and the output is normalized at the last block.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale: float, causal: bool, bq: int, bk: int, nk: int):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    i = pl.program_id(1)
    run = True
    if causal:
        # skip blocks strictly above the diagonal
        run = (j * bk) <= (i * bq + bq - 1)

    @pl.when(run if causal else (j >= 0))
    def _step():
        q = q_ref[0].astype(jnp.float32)
        k = k_ref[0].astype(jnp.float32)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale
        if causal:
            qi = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            kj = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(kj <= qi, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=1)
        acc_ref[...] = (acc_ref[...] * alpha[:, None]
                        + jax.lax.dot(p, v))
        m_ref[...] = m_new

    @pl.when(j == nk - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / denom[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("causal", "bq", "bk", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, bq: int = 128, bk: int = 128,
                    interpret: bool = True) -> jax.Array:
    """q,k,v: (BH, S, d) with KV already expanded to query heads.
    Returns (BH, S, d).  S must divide bq/bk (pad externally)."""
    BH, S, d = q.shape
    T = k.shape[1]
    bq = min(bq, S)
    bk = min(bk, T)
    nq = S // bq
    nk = T // bk
    assert nq * bq == S and nk * bk == T, "pad S/T to block multiples"
    scale = 1.0 / (d ** 0.5)

    return pl.pallas_call(
        functools.partial(_kernel, scale=scale, causal=causal, bq=bq, bk=bk,
                          nk=nk),
        grid=(BH, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)


def flash_attention_ref(q, k, v, *, causal: bool = True) -> jax.Array:
    """Pure-jnp oracle."""
    d = q.shape[-1]
    s = jnp.einsum("bsd,btd->bst", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / (d ** 0.5)
    if causal:
        S, T = s.shape[-2:]
        mask = jnp.arange(T)[None, :] <= jnp.arange(S)[:, None]
        s = jnp.where(mask[None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bst,btd->bsd", p, v.astype(jnp.float32)).astype(q.dtype)
