"""Pallas TPU kernel: variable-coefficient 5-point stencil SpMV.

TPU adaptation of the paper's structured-grid SpMV (its entire 2D-Poisson
benchmark suite is this operator).  Instead of a GPU scatter/gather CSR
kernel we tile the grid into **row bands** resident in VMEM and realize the
stencil with VPU shifts; the row halo is obtained by *also* mapping the
neighbouring row-band blocks of the same input array (overlapping reads are
legal in Pallas) — boundary bands clamp their halo index and the clamped
values are annihilated by the zero boundary coefficients, so the kernel body
is branch-free.

Block layout (per grid step i):
    val5  (5, bm, ny_pad)  — coefficient planes for band i
    x_up  (bm, ny_pad)     — band i-1 (clamped at 0)
    x_c   (bm, ny_pad)     — band i
    x_dn  (bm, ny_pad)     — band i+1 (clamped at n_bands-1)
    y     (bm, ny_pad)

VMEM footprint: 9 · bm · ny_pad · 4 B  (bm=8, ny≤16384 → ≤4.7 MB).
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


@dataclasses.dataclass(frozen=True)
class Stencil5Meta:
    nx: int
    ny: int
    bm: int = 8
    lane: int = 128  # column padding multiple

    @property
    def nx_pad(self) -> int:
        return -(-self.nx // self.bm) * self.bm

    @property
    def ny_pad(self) -> int:
        return -(-self.ny // self.lane) * self.lane

    @property
    def n_bands(self) -> int:
        return self.nx_pad // self.bm


def _kernel(val_ref, xu_ref, xc_ref, xd_ref, y_ref):
    xc = xc_ref[...]
    xu = xu_ref[...]
    xd = xd_ref[...]
    # row shifts across band boundaries (halo rows come from neighbour bands)
    x_north = jnp.concatenate([xu[-1:], xc[:-1]], axis=0)   # x[i-1, j]
    x_south = jnp.concatenate([xc[1:], xd[:1]], axis=0)     # x[i+1, j]
    # column shifts stay within the band (full width resident)
    zcol = jnp.zeros_like(xc[:, :1])
    x_west = jnp.concatenate([zcol, xc[:, :-1]], axis=1)    # x[i, j-1]
    x_east = jnp.concatenate([xc[:, 1:], zcol], axis=1)     # x[i, j+1]
    v = val_ref[...]
    y_ref[...] = (v[0] * xc + v[1] * x_north + v[2] * x_south
                  + v[3] * x_west + v[4] * x_east)


@functools.partial(jax.jit, static_argnums=(0, 3))
def stencil5_pallas(meta: Stencil5Meta, val5: jax.Array, x: jax.Array,
                    interpret: bool = True) -> jax.Array:
    """Apply the stencil.  ``val5``: (5, nx, ny) planes; ``x``: (nx, ny)."""
    nxp, nyp, bm = meta.nx_pad, meta.ny_pad, meta.bm
    nb = meta.n_bands
    vp = jnp.pad(val5, ((0, 0), (0, nxp - meta.nx), (0, nyp - meta.ny)))
    xp = jnp.pad(x, ((0, nxp - meta.nx), (0, nyp - meta.ny)))

    grid = (nb,)
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((5, bm, nyp), lambda i: (0, i, 0)),
            pl.BlockSpec((bm, nyp), lambda i: (jnp.maximum(i - 1, 0), 0)),
            pl.BlockSpec((bm, nyp), lambda i: (i, 0)),
            pl.BlockSpec((bm, nyp), lambda i: (jnp.minimum(i + 1, nb - 1), 0)),
        ],
        out_specs=pl.BlockSpec((bm, nyp), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nxp, nyp), x.dtype),
        interpret=interpret,
    )(vp, xp, xp, xp)
    return out[:meta.nx, :meta.ny]
