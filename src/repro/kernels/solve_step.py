"""Pallas TPU kernels: fused iterative-solver step passes.

One CG/BiCGStab iteration in the seed is ~five separate memory-bound passes
over n-length vectors (axpy updates, preconditioner apply, reduction dots,
plus the convergence-check dot re-read in ``cond``).  Each kernel here fuses
one group of those passes into a single sweep: vectors stream through VMEM in
(8, 128) tiles over a 1-D grid, scalar coefficients ride in SMEM, and the
reduction dots accumulate into an SMEM output across the sequential grid
(initialized at step 0 — TPU grids execute in order, so in-place accumulation
into a revisited output block is well defined).

Every kernel declares its traffic model via a ``passes = (reads, writes)``
attribute (units of n-length vectors); ``launch/roofline.py`` consumes these
for the fused-step byte assertion in the bench suite.

The fused CG path uses the merged (Chronopoulos/Gear) recurrence: with
M-orthogonal residuals, <p', A p'> = <w, z> - (beta/alpha)·<r', z'>, so the
standalone p·Ap reduction pass disappears — both dots fall out of passes that
stream the vectors anyway (see ``core/solvers.py::cg_fused``).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BM, BN = 8, 128          # f32/f64 min tile; vectors are viewed as (nb, 8, 128)
BLK = BM * BN


def default_interpret() -> bool:
    """Interpret (emulate) only off compiled backends — the satellite fix for
    the old ``interpret=True`` default that silently emulated on TPU."""
    return jax.default_backend() not in ("tpu", "gpu")


def _make_kernel(body, n_in: int, n_sc: int, n_out: int, n_dots: int):
    def kernel(*refs):
        vin = refs[:n_in]
        pos = n_in
        sc = ()
        if n_sc:
            sref = refs[pos]
            pos += 1
            sc = tuple(sref[0, j] for j in range(n_sc))
        vout = refs[pos:pos + n_out]
        dref = refs[pos + n_out] if n_dots else None
        if n_dots:
            @pl.when(pl.program_id(0) == 0)
            def _init():
                for j in range(n_dots):
                    dref[0, j] = jnp.zeros((), dref.dtype)
        outs, dots = body(tuple(r[...] for r in vin), sc)
        for r, v in zip(vout, outs):
            r[...] = v
        for j in range(n_dots):
            dref[0, j] += dots[j]
    return kernel


def _run(body, vecs, scalars, n_out: int, n_dots: int, interpret):
    """Launch one fused vector pass.

    ``vecs``: n-length arrays, tiled to (nb, 8, 128) blocks (zero-padded —
    every body below maps pad zeros to zeros, so dots are exact); ``scalars``:
    loop coefficients, stacked into one SMEM row.  Returns the n_out output
    vectors (truncated to n) followed by the n_dots reduction scalars.
    """
    if interpret is None:
        interpret = default_interpret()
    n = vecs[0].shape[0]
    dtype = vecs[0].dtype
    nb = max(1, -(-n // BLK))
    pad = nb * BLK - n
    vb = [jnp.pad(v, (0, pad)).reshape(nb, BM, BN) for v in vecs]
    n_in, n_sc = len(vecs), len(scalars)
    vspec = pl.BlockSpec((1, BM, BN), lambda i: (i, 0, 0))
    in_specs = [vspec] * n_in
    args = list(vb)
    if n_sc:
        in_specs.append(pl.BlockSpec((1, n_sc), lambda i: (0, 0),
                                     memory_space=pltpu.SMEM))
        args.append(jnp.stack([jnp.asarray(s, dtype) for s in scalars])
                    .reshape(1, n_sc))
    out_specs = [vspec] * n_out
    out_shape = [jax.ShapeDtypeStruct((nb, BM, BN), dtype)] * n_out
    if n_dots:
        out_specs.append(pl.BlockSpec((1, n_dots), lambda i: (0, 0),
                                      memory_space=pltpu.SMEM))
        out_shape.append(jax.ShapeDtypeStruct((1, n_dots), dtype))
    res = pl.pallas_call(
        _make_kernel(body, n_in, n_sc, n_out, n_dots),
        grid=(nb,),
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(*args)
    outs = tuple(r.reshape(nb * BLK)[:n] for r in res[:n_out])
    dots = tuple(res[n_out][0, j] for j in range(n_dots)) if n_dots else ()
    return outs + dots


# ---------------------------------------------------------------------------
# CG (merged recurrence, diagonal preconditioner)
# ---------------------------------------------------------------------------

def fused_cg_update(x, r, p, s, dinv, alpha, *, interpret=None):
    """x' = x + α·p;  r' = r − α·s;  z' = dinv·r';  ρ' = <r',z'>;  rr' = <r',r'>.

    Replaces the x-axpy, r-axpy, preconditioner apply, r·z dot, and the
    convergence-check r·r dot (s = A p)."""
    def body(v, sc):
        x_, r_, p_, s_, d_ = v
        (a,) = sc
        xn = x_ + a * p_
        rn = r_ - a * s_
        zn = d_ * rn
        return (xn, rn, zn), (jnp.sum(rn * zn), jnp.sum(rn * rn))
    return _run(body, (x, r, p, s, dinv), (alpha,), 3, 2, interpret)


fused_cg_update.passes = (5, 3)


def fused_cg_direction(z, w, p, s, beta, *, interpret=None):
    """p' = z + β·p;  s' = w + β·s;  δ = <w,z>  (w = A z).

    δ feeds the merged-CG α recurrence one iteration later, so there is no
    reduction barrier inside the pass and no standalone p·Ap dot at all."""
    def body(v, sc):
        z_, w_, p_, s_ = v
        (b,) = sc
        return (z_ + b * p_, w_ + b * s_), (jnp.sum(w_ * z_),)
    return _run(body, (z, w, p, s), (beta,), 2, 1, interpret)


fused_cg_direction.passes = (4, 2)


def fused_cg_halfstep(x, r, p, s, alpha, *, interpret=None):
    """x' = x + α·p;  r' = r − α·s;  rr' = <r',r'> — the partial fusion used
    when the preconditioner apply is not a diagonal scale (AMG, MG, ILU)."""
    def body(v, sc):
        x_, r_, p_, s_ = v
        (a,) = sc
        xn = x_ + a * p_
        rn = r_ - a * s_
        return (xn, rn), (jnp.sum(rn * rn),)
    return _run(body, (x, r, p, s), (alpha,), 2, 1, interpret)


fused_cg_halfstep.passes = (4, 2)


def fused_cheb_step(x, dk, rk, c1, c2, *, interpret=None):
    """d' = c1·d + c2·r;  x' = x + d' — one inner step of the Chebyshev
    polynomial apply (two axpy passes fused; the residual update rides the
    matvec that follows)."""
    def body(v, sc):
        x_, d_, r_ = v
        a, b = sc
        dn = a * d_ + b * r_
        return (x_ + dn, dn), ()
    return _run(body, (x, dk, rk), (c1, c2), 2, 0, interpret)


fused_cheb_step.passes = (3, 2)


def fused_dots2(u, v, *, interpret=None):
    """(Σ u·v, Σ u·u) in one read of each operand (BiCGStab ω numerator and
    denominator, computed together)."""
    def body(vv, sc):
        u_, v_ = vv
        return (), (jnp.sum(u_ * v_), jnp.sum(u_ * u_))
    return _run(body, (u, v), (), 0, 2, interpret)


fused_dots2.passes = (2, 0)


# ---------------------------------------------------------------------------
# BiCGStab
# ---------------------------------------------------------------------------

def fused_bicg_p(r, p, v, dinv, beta, omega, restart, *, interpret=None):
    """p' = r + β·(p − ω·v)  (p' = r when the restart flag is set);
    p̂ = dinv·p'."""
    def body(vv, sc):
        r_, p_, v_, d_ = vv
        b, w, rs = sc
        pn = jnp.where(rs != 0, r_, r_ + b * (p_ - w * v_))
        return (pn, d_ * pn), ()
    return _run(body, (r, p, v, dinv), (beta, omega, restart), 2, 0, interpret)


fused_bicg_p.passes = (4, 2)


def fused_bicg_s(r, v, dinv, alpha, *, interpret=None):
    """s = r − α·v;  ŝ = dinv·s."""
    def body(vv, sc):
        r_, v_, d_ = vv
        (a,) = sc
        sn = r_ - a * v_
        return (sn, d_ * sn), ()
    return _run(body, (r, v, dinv), (alpha,), 2, 0, interpret)


fused_bicg_s.passes = (3, 2)


def fused_bicg_tail(x, s, t, phat, shat, rhat, alpha, omega, *, interpret=None):
    """x' = x + α·p̂ + ω·ŝ;  r' = s − ω·t;  ρ' = <r̂,r'>;  rr' = <r',r'>.

    ρ' is next iteration's head dot computed for free while r' is resident;
    rr' makes the convergence check read-free."""
    def body(vv, sc):
        x_, s_, t_, ph_, sh_, rh_ = vv
        a, w = sc
        xn = x_ + a * ph_ + w * sh_
        rn = s_ - w * t_
        return (xn, rn), (jnp.sum(rh_ * rn), jnp.sum(rn * rn))
    return _run(body, (x, s, t, phat, shat, rhat), (alpha, omega), 2, 2,
                interpret)


fused_bicg_tail.passes = (6, 2)


def traffic_bytes(kernel, n: int, itemsize: int = 8) -> int:
    """Modeled HBM traffic of one fused pass: (reads + writes) · n · itemsize,
    from the kernel's declared ``passes`` attribute (dots are O(1))."""
    reads, writes = kernel.passes
    return (reads + writes) * n * itemsize
