"""Pallas TPU kernel: block-ELLPACK SpMV with scalar-prefetch column gather.

TPU adaptation of general (unstructured) sparse SpMV.  GPU CSR kernels key on
warp-per-row scalar gathers; the TPU equivalent is **dense value tiles +
scalar-prefetched block indices**: the (n_rb, k) column-block table is
prefetched into SMEM before the grid runs, so each x block arrives via the
BlockSpec ``index_map`` (a DMA the compiler can pipeline), and the inner
product is a dense (bm, bn)·(bn,) contraction on VMEM-resident tiles.

Grid: (n_rb, k) — the output band is revisited across the k slot dimension
and accumulated in place (out index_map constant in k, initialized at slot 0).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..core.sparse import BellMeta


def _kernel(cols_ref, vals_ref, x_ref, y_ref):
    s = pl.program_id(1)

    @pl.when(s == 0)
    def _init():
        y_ref[...] = jnp.zeros_like(y_ref)

    blk = vals_ref[0, 0]            # (bm, bn)
    xv = x_ref[0]                   # (bn,)
    y_ref[0, :] += jnp.dot(blk, xv, preferred_element_type=y_ref.dtype)


@functools.partial(jax.jit, static_argnums=(0, 4))
def bell_spmv_pallas(meta: BellMeta, block_cols: jax.Array,
                     bell_vals: jax.Array, x: jax.Array,
                     interpret: bool = None) -> jax.Array:
    """y = A @ x with A in block-ELL form.

    ``block_cols``: (n_rb, k) int32 column-block table (scalar-prefetched);
    ``bell_vals``: (n_rb, k, bm, bn); ``x``: (m,) — padded internally.
    Returns the padded y (n_pad,); ops.py truncates to n.

    ``interpret=None`` auto-detects: compile on TPU/GPU, emulate elsewhere.
    (Static argnum, so None resolves once at trace time.)
    """
    if interpret is None:
        from .solve_step import default_interpret
        interpret = default_interpret()
    bm, bn, k, n_rb = meta.bm, meta.bn, meta.k, meta.n_rb
    xp = jnp.pad(x, (0, meta.m_pad - x.shape[0]))
    x2 = xp.reshape(meta.n_cb, bn)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n_rb, k),
        in_specs=[
            pl.BlockSpec((1, 1, bm, bn), lambda r, s, cols: (r, s, 0, 0)),
            pl.BlockSpec((1, bn), lambda r, s, cols: (cols[r, s], 0)),
        ],
        out_specs=pl.BlockSpec((1, bm), lambda r, s, cols: (r, 0)),
    )
    out = pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_rb, bm), x.dtype),
        interpret=interpret,
    )(block_cols.astype(jnp.int32), bell_vals, x2)
    return out.reshape(meta.n_pad)
