"""Public jit'd wrappers over the Pallas kernels.

On CPU (this container) the kernels execute in interpret mode — the kernel
body runs in Python for correctness validation; on TPU they compile to
Mosaic.  Both wrappers are differentiable: value assembly (COO → kernel
layout) is a pure gather/scatter, and the kernel itself is linear in (val, x),
so JAX's builtin transpose rules suffice — the O(1)-graph adjoint in
core/adjoint.py wraps the *solver*, not the matvec.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ..core.sparse import BellMeta
from . import ref as _ref
from .spmv_bell import bell_spmv_pallas
from .stencil5 import Stencil5Meta, stencil5_pallas


def _interpret() -> bool:
    """Default Pallas interpret flag: emulate only off compiled backends."""
    from .solve_step import default_interpret
    return default_interpret()


# ---------------------------------------------------------------------------
# block-ELL
# ---------------------------------------------------------------------------

def bell_assemble(meta: BellMeta, perm: jax.Array, val: jax.Array) -> jax.Array:
    """Scatter COO values into the dense (n_rb, k, bm, bn) block tensor.

    ``perm[e] == -1`` marks entries dropped by a max_k cap; they scatter a
    zero into slot 0 (harmless).  Differentiable (transpose = gather)."""
    size = meta.n_rb * meta.k * meta.bm * meta.bn
    safe = jnp.where(perm >= 0, perm, 0)
    contrib = jnp.where(perm >= 0, val, jnp.zeros_like(val))
    flat = jnp.zeros((size,), val.dtype).at[safe].add(contrib)
    return flat.reshape(meta.n_rb, meta.k, meta.bm, meta.bn)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 5, 6))
def bell_matvec(meta: BellMeta, block_cols: jax.Array, perm: jax.Array,
                val: jax.Array, x: jax.Array, n: int,
                interpret: bool = None) -> jax.Array:
    """``interpret=None`` resolves to the platform default; the plan engine
    threads its analyze-time flag through here (kernel plans)."""
    bv = bell_assemble(meta, perm, val)
    y = bell_spmv_pallas(meta, block_cols, bv, x, interpret)
    return y[:n]


def _bell_mv_fwd(meta, block_cols, perm, val, x, n, interpret):
    return (bell_matvec(meta, block_cols, perm, val, x, n, interpret),
            (block_cols, perm, val, x))


def _bell_mv_bwd(meta, n, interpret, res, g):
    """The op is bilinear: ∂/∂x = Aᵀg (scatter over column blocks);
    ∂/∂val_e = g[row_e]·x[col_e], realized through the bell layout."""
    block_cols, perm, val, x = res
    bv = bell_assemble(meta, perm, val)
    gp = jnp.pad(g, (0, meta.n_pad - n)).reshape(meta.n_rb, meta.bm)
    xp = jnp.pad(x, (0, meta.m_pad - x.shape[0])).reshape(meta.n_cb, meta.bn)
    # grad wrt x: scatter-add blkᵀ·g_band into each block column
    contrib = jnp.einsum("rkab,ra->rkb", bv, gp)            # (n_rb, k, bn)
    gx = jnp.zeros((meta.n_cb, meta.bn), x.dtype).at[block_cols].add(contrib)
    gx = gx.reshape(meta.m_pad)[: x.shape[0]]
    # grad wrt val: outer(g_band, x_block) gathered back through perm
    gathered = xp[block_cols]                               # (n_rb, k, bn)
    gbell = jnp.einsum("ra,rkb->rkab", gp, gathered).reshape(-1)
    safe = jnp.where(perm >= 0, perm, 0)
    gval = jnp.where(perm >= 0, gbell[safe], jnp.zeros_like(val))
    return None, None, gval, gx


bell_matvec.defvjp(_bell_mv_fwd, _bell_mv_bwd)


def bell_matvec_ref(meta: BellMeta, block_cols: jax.Array, perm: jax.Array,
                    val: jax.Array, x: jax.Array, n: int) -> jax.Array:
    bv = bell_assemble(meta, perm, val)
    xp = jnp.pad(x, (0, meta.m_pad - x.shape[0]))
    return _ref.bell_matvec_ref(bv, block_cols, xp, n)


# ---------------------------------------------------------------------------
# 5-point stencil
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def stencil5_matvec(meta: Stencil5Meta, val: jax.Array, x: jax.Array) -> jax.Array:
    """``val``: (5·nx·ny,) flattened signed planes; ``x``: (nx·ny,)."""
    v5 = val.reshape(5, meta.nx, meta.ny)
    x2 = x.reshape(meta.nx, meta.ny)
    y = stencil5_pallas(meta, v5, x2, _interpret())
    return y.reshape(meta.nx * meta.ny)


def _stencil_transpose_planes(v5: jax.Array) -> jax.Array:
    """Planes of Aᵀ: (Aᵀy)[c] = Σ_d val_d[c−off_d]·y[c−off_d] — each neighbour
    plane swaps with its mirror and shifts by its own offset."""
    C, N, S, W, E = v5
    Nt = jnp.pad(S, ((1, 0), (0, 0)))[:-1, :]   # S shifted down   → plays N
    St = jnp.pad(N, ((0, 1), (0, 0)))[1:, :]    # N shifted up     → plays S
    Wt = jnp.pad(E, ((0, 0), (1, 0)))[:, :-1]   # E shifted right  → plays W
    Et = jnp.pad(W, ((0, 0), (0, 1)))[:, 1:]    # W shifted left   → plays E
    return jnp.stack([C, Nt, St, Wt, Et])


def _stencil_fwd(meta, val, x):
    return stencil5_matvec(meta, val, x), (val, x)


def _stencil_bwd(meta, res, g):
    val, x = res
    v5 = val.reshape(5, meta.nx, meta.ny)
    x2 = x.reshape(meta.nx, meta.ny)
    g2 = g.reshape(meta.nx, meta.ny)
    # ∂/∂x = Aᵀ g — reuse the kernel with transposed planes
    vt = _stencil_transpose_planes(v5)
    gx = stencil5_pallas(meta, vt, g2, _interpret()).reshape(-1)
    # ∂/∂val_d[i,j] = g[i,j] · x[i+off_d, j+off_d]
    xn = jnp.pad(x2, ((1, 0), (0, 0)))[:-1, :]
    xs = jnp.pad(x2, ((0, 1), (0, 0)))[1:, :]
    xw = jnp.pad(x2, ((0, 0), (1, 0)))[:, :-1]
    xe = jnp.pad(x2, ((0, 0), (0, 1)))[:, 1:]
    gval = jnp.stack([g2 * x2, g2 * xn, g2 * xs, g2 * xw, g2 * xe]).reshape(-1)
    return gval, gx


stencil5_matvec.defvjp(_stencil_fwd, _stencil_bwd)


def stencil5_matvec_ref(meta: Stencil5Meta, val: jax.Array, x: jax.Array) -> jax.Array:
    v5 = val.reshape(5, meta.nx, meta.ny)
    x2 = x.reshape(meta.nx, meta.ny)
    return _ref.stencil5_ref(v5, x2).reshape(meta.nx * meta.ny)
