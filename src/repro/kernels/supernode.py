"""Pallas TPU kernels: supernodal panel factorize / triangular solve.

The supernodal direct path (``core/direct.py``) groups columns into
fundamental supernodes and buckets them by padded panel shape; each bucket is
a batch of identically-shaped dense panels gathered from the packed factor
vector.  The kernels here run one supernode per grid lane:

- :func:`panel_factor` — right-looking dense factorization of the
  (wb+rb, wb) panel (diagonal-block elimination + L-panel divide + trailing
  update), including the static Bunch–Kaufman 2x2 pivot pairs;
- :func:`schur_update` — the extend-add GEMM ``S = Lpanel @ Upanel`` whose
  result is scatter-subtracted into ancestor slots (the MXU-bound step that
  replaces O(w·r²) scalar packed-scan multiply-adds);
- :func:`block_trsv` — dense triangular solves on the diagonal block for the
  four sweep modes (L, Lᵀ, U, Uᵀ).

Each kernel's math lives in a single-lane ``sn_*_body`` function in
``kernels/ref.py`` — the pure-jnp oracles vmap those bodies, and the Pallas
kernels call the very same bodies on their per-lane VMEM blocks, so
kernel-vs-ref parity is structural.  Per-lane true sizes (w, r) ride in SMEM;
pad rows/columns are masked inside the body (gathered pads hold scratch
garbage).  Every kernel declares its traffic model via a
``passes = (reads, writes)`` attribute in units of full operand arrays.

On CPU the direct driver calls the jnp oracles directly (interpret-mode
Pallas emulation would serialize the python loop); the kernels are still
exercised under ``interpret=True`` by the parity tests.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from . import ref as _ref
from .solve_step import default_interpret

__all__ = ["panel_factor", "schur_update", "block_trsv", "default_interpret"]


def _scalar_spec():
    return pl.BlockSpec((1, 1), lambda i: (i, 0), memory_space=pltpu.SMEM)


def panel_factor(P, Q, wvec, rvec, tau, bkm, *, pairs=False, guard=True,
                 interpret=None):
    """Factorize a bucket of supernode panels in place.

    ``P`` (k, wb+rb, wb) gathered [D-block; L-panel] columns, ``Q``
    (k, wb, rb) gathered U-panel rows, ``wvec``/``rvec`` (k,) true
    width/sub-row counts, ``tau`` the 1x1 pivot clamp, ``bkm`` (k, wb) bool
    pair-start flags.  Returns (P, Q, nbad) with L divided, U raw, clamped
    pivots persisted — bit-identical storage semantics to the scalar path.
    """
    if interpret is None:
        interpret = default_interpret()
    k, m, wb = P.shape
    rb = Q.shape[2]
    dtype = P.dtype

    def kern(wv, rv, tv, bk, p, q, po, qo, nb):
        w = wv[0, 0]
        r = rv[0, 0]
        t = tv[0, 0]
        mask = bk[0] != 0
        Pn, Qn, bad = _ref.sn_panel_factor_body(
            p[0], q[0], w, r, t, mask, pairs=pairs, guard=guard)
        po[0] = Pn
        qo[0] = Qn
        nb[0, 0] = bad

    Po, Qo, nbad = pl.pallas_call(
        kern,
        grid=(k,),
        in_specs=[
            _scalar_spec(), _scalar_spec(),
            pl.BlockSpec((1, 1), lambda i: (0, 0), memory_space=pltpu.SMEM),
            pl.BlockSpec((1, wb), lambda i: (i, 0)),
            pl.BlockSpec((1, m, wb), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, wb, rb), lambda i: (i, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, m, wb), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, wb, rb), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0), memory_space=pltpu.SMEM),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((k, m, wb), dtype),
            jax.ShapeDtypeStruct((k, wb, rb), dtype),
            jax.ShapeDtypeStruct((k, 1), dtype),
        ],
        interpret=interpret,
    )(wvec.reshape(k, 1).astype(jnp.int32),
      rvec.reshape(k, 1).astype(jnp.int32),
      jnp.asarray(tau, dtype).reshape(1, 1),
      bkm.astype(dtype),
      P, Q)
    return Po, Qo, jnp.sum(nbad)


panel_factor.passes = (2, 2)


def schur_update(P, Q, *, interpret=None):
    """Extend-add GEMM: S[l] = Lpanel[l] @ Upanel[l] per lane on the MXU.

    ``P`` (k, wb+rb, wb) factored panels (rows wb.. hold divided L),
    ``Q`` (k, wb, rb) raw U rows.  Returns S (k, rb, rb); the driver
    scatter-subtracts it into the ancestors' packed slots (extend-add).
    """
    if interpret is None:
        interpret = default_interpret()
    k, m, wb = P.shape
    rb = Q.shape[2]
    dtype = P.dtype

    def kern(p, q, s):
        s[0] = jax.lax.dot_general(
            p[0][wb:, :], q[0], (((1,), (0,)), ((), ())),
            preferred_element_type=dtype)

    return pl.pallas_call(
        kern,
        grid=(k,),
        in_specs=[
            pl.BlockSpec((1, m, wb), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, wb, rb), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, rb, rb), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((k, rb, rb), dtype),
        interpret=interpret,
    )(P, Q)


schur_update.passes = (2, 1)


def block_trsv(D, y, wvec, bkm, *, mode, pairs=False, interpret=None):
    """Dense triangular solve on a bucket of diagonal blocks.

    ``D`` (k, wb, wb) packed blocks (strict lower = unit-L, diagonal =
    pivots, strict upper = U), ``y`` (k, wb) right-hand sides, ``mode`` one
    of ``"l"``/``"lt"``/``"u"``/``"ut"`` (see ``ref.sn_trsv_body``).
    Returns x (k, wb).
    """
    if interpret is None:
        interpret = default_interpret()
    k, wb = y.shape
    dtype = D.dtype

    def kern(wv, bk, d, yy, xo):
        w = wv[0, 0]
        mask = bk[0] != 0
        xo[0] = _ref.sn_trsv_body(d[0], yy[0], w, mask, mode=mode,
                                  pairs=pairs)

    return pl.pallas_call(
        kern,
        grid=(k,),
        in_specs=[
            _scalar_spec(),
            pl.BlockSpec((1, wb), lambda i: (i, 0)),
            pl.BlockSpec((1, wb, wb), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, wb), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, wb), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((k, wb), dtype),
        interpret=interpret,
    )(wvec.reshape(k, 1).astype(jnp.int32), bkm.astype(dtype), D, y)


block_trsv.passes = (2, 1)
