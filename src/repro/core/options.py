"""Typed, scoped engine options — the replacement for the dispatch globals.

Until PR 7 the engine's knobs were mutable module globals on
:mod:`repro.core.dispatch` (``FUSED_STEP``, ``DENSE_BUDGET``,
``DIRECT_BUDGET``, ``BELL_MIN_FILL``, ``PLAN_CACHE_CAP``).  Globals are hard
to scope (a benchmark flipping ``FUSED_STEP`` leaks into the next suite) and
invisible to the public API.  This module holds ONE immutable
:class:`Options` record behind three entry points, re-exported by
:mod:`repro.sla`:

* :func:`set_options` — process-wide update (``sla.set_options(fused_step="on")``);
* :func:`options` — context manager for a scoped override
  (``with sla.options(direct_budget=10**5): ...``), restored on exit even
  when the body raises;
* ``REPRO_SLA_*`` environment variables — read once at import, e.g.
  ``REPRO_SLA_FUSED_STEP=off`` or ``REPRO_SLA_PLAN_CACHE_BYTES=1e8``.

Every internal read goes through :func:`current` at *use* time (budgets at
dispatch time, ``fused_step`` at solve-trace time, cache bounds at
insertion), so overrides apply to plans that already exist.  The old module
globals survive as deprecated read/write aliases on ``repro.core.dispatch``
that emit a :class:`DeprecationWarning` once per name and forward here.
"""
from __future__ import annotations

import contextlib
import dataclasses
import os
import threading
import warnings
from typing import Optional

__all__ = ["Options", "current", "set_options", "options"]


@dataclasses.dataclass(frozen=True)
class Options:
    """Engine configuration (immutable; update via :func:`set_options`).

    fused_step
        Fused CG/BiCGStab Pallas step kernels: ``"auto"`` enables them where
        the kernels compile (TPU/GPU) and keeps plain XLA loops in interpret
        mode (CPU); ``"on"``/``"off"`` force either path.  Read at
        solve-trace time, never frozen into a plan.
    supernodal
        Supernodal (dense-panel) direct factorization: ``"auto"`` emits the
        panel program when the analyze-stage partition says it pays off
        (mean supernode width and schedule size heuristics) or when static
        Bunch–Kaufman pivot pairs were requested; ``"on"``/``"off"`` force
        either path (``"off"`` keeps the scalar packed-scan program — the
        A/B baseline).  Read at analyze time by
        :func:`repro.core.direct.symbolic_factor`.
    dense_budget
        Auto-dispatch crossover: systems with ``n <= dense_budget`` take the
        dense MXU direct path.
    direct_budget
        Auto-dispatch crossover to the sparse-direct backend (cached symbolic
        factorization); ``props["illcond_hint"]`` widens it 4x.  Raised to
        10⁵ by the supernodal panel kernels (the numeric refactorization is
        no longer the bottleneck; the one-time symbolic analysis amortizes
        across the plan's lifetime).
    bell_min_fill
        Minimum block-ELL fill (nnz over padded slot capacity) for the
        analyze-time kernel plan to adopt the BELL layout on its own.
    plan_cache_cap
        Per-pattern plan cache entry bound (LRU).
    plan_cache_bytes
        Optional byte budget for the same cache, sized from each plan's
        artifact arrays (BELL slot tables, direct/ILU/AMG factor programs);
        ``None`` means entry-count-only bounding.
    jac_coloring_budget
        Cap on the number of Jacobian colors (jvp probe vectors) a
        :class:`repro.core.nonlinear.SparseNewton` pattern may need before
        the coloring-based assembly refuses — a nearly-dense column of the
        declared pattern would otherwise silently turn each Newton step into
        O(n) residual sweeps.  Past the cap, pass an explicit
        ``assemble_jacobian`` callback (or raise the budget).  Read at
        coloring time by :func:`repro.core.nonlinear.SparseNewton`.
    """
    fused_step: str = "auto"
    supernodal: str = "auto"
    dense_budget: int = 4096
    direct_budget: int = 100_000
    bell_min_fill: float = 1.0 / 64.0
    plan_cache_cap: int = 32
    plan_cache_bytes: Optional[int] = None
    jac_coloring_budget: int = 256

    def _validate(self) -> "Options":
        if self.fused_step not in ("auto", "on", "off"):
            raise ValueError(
                f"fused_step must be 'auto'|'on'|'off', got {self.fused_step!r}")
        if self.supernodal not in ("auto", "on", "off"):
            raise ValueError(
                f"supernodal must be 'auto'|'on'|'off', got {self.supernodal!r}")
        for name in ("dense_budget", "direct_budget", "jac_coloring_budget"):
            v = getattr(self, name)
            if not isinstance(v, int) or v < 0:
                raise ValueError(f"{name} must be a non-negative int, got {v!r}")
        if not (0.0 <= float(self.bell_min_fill) <= 1.0):
            raise ValueError(
                f"bell_min_fill must be in [0, 1], got {self.bell_min_fill!r}")
        if not isinstance(self.plan_cache_cap, int) or self.plan_cache_cap < 1:
            raise ValueError(
                f"plan_cache_cap must be a positive int, got "
                f"{self.plan_cache_cap!r}")
        if self.plan_cache_bytes is not None and (
                not isinstance(self.plan_cache_bytes, int)
                or self.plan_cache_bytes < 0):
            raise ValueError(
                f"plan_cache_bytes must be None or a non-negative int, got "
                f"{self.plan_cache_bytes!r}")
        return self


_FIELDS = tuple(f.name for f in dataclasses.fields(Options))
ENV_PREFIX = "REPRO_SLA_"


def _parse_env(environ) -> dict:
    """``REPRO_SLA_*`` overrides as an Options kwargs dict (pure; testable).

    Integers accept float-ish spellings (``1e8``); ``plan_cache_bytes``
    additionally accepts ``none``/empty for "unbounded".  Unknown
    ``REPRO_SLA_*`` names raise — a typo'd knob must not silently no-op.
    """
    out = {}
    for key, raw in environ.items():
        if not key.startswith(ENV_PREFIX):
            continue
        name = key[len(ENV_PREFIX):].lower()
        if name not in _FIELDS:
            raise ValueError(
                f"unknown option env var {key} (valid: "
                + ", ".join(ENV_PREFIX + f.upper() for f in _FIELDS) + ")")
        if name in ("fused_step", "supernodal"):
            out[name] = raw.strip().lower()
        elif name == "bell_min_fill":
            out[name] = float(raw)
        elif name == "plan_cache_bytes" and raw.strip().lower() in ("", "none"):
            out[name] = None
        else:
            out[name] = int(float(raw))
    return out


class _State(threading.local):
    """Per-thread override stack; the base (index 0) is process-wide."""

    def __init__(self):
        self.stack = [_BASE]


_BASE = Options(**_parse_env(os.environ))._validate()
_state = _State()


def current() -> Options:
    """The active :class:`Options` (innermost ``options()`` scope wins)."""
    stack = _state.stack
    # a set_options() on another thread replaces the shared base; pick it up
    # unless this thread is inside a scoped override
    if len(stack) == 1:
        stack[0] = _BASE
    return stack[-1]


def set_options(**kw) -> Options:
    """Update the process-wide options; returns the new record.

    Inside a ``with options(...)`` scope the update applies to that scope
    (and is discarded when it exits), matching the lexical intent.
    """
    global _BASE
    new = dataclasses.replace(current(), **kw)._validate()
    _state.stack[-1] = new
    if len(_state.stack) == 1:
        _BASE = new
    return new


@contextlib.contextmanager
def options(**kw):
    """Scoped override: ``with options(fused_step="on"): ...`` — restored on
    exit (exception-safe).  Yields the overridden :class:`Options`."""
    new = dataclasses.replace(current(), **kw)._validate()
    _state.stack.append(new)
    try:
        yield new
    finally:
        _state.stack.pop()


# ---------------------------------------------------------------------------
# deprecated-alias plumbing (the old dispatch globals)
# ---------------------------------------------------------------------------

_warned: set = set()


def warn_deprecated_alias(old: str, new: str) -> None:
    """Emit the deprecation warning for a legacy global — once per name."""
    if old in _warned:
        return
    _warned.add(old)
    warnings.warn(
        f"repro.core.dispatch.{old} is deprecated; use "
        f"repro.sla.set_options({new}=...) or the repro.sla.options(...) "
        f"context manager (env: {ENV_PREFIX}{new.upper()})",
        DeprecationWarning, stacklevel=3)
