"""repro.core — differentiable sparse linear algebra (the paper's contribution).

Public API mirrors torch-sla:

    from repro.core import SparseTensor, SparseTensorList, nonlinear_solve
    x = A.solve(b)                      # auto-dispatched, adjoint gradients
    w, V = A.eigsh(k=6)                 # Hellmann–Feynman gradients
    u = nonlinear_solve(residual, x0, theta)
"""
from .sparse import SparseTensor, SparseTensorList, coo_matvec, build_bell
from .adjoint import nonlinear_solve, sparse_solve, sparse_eigsh
from .nonlinear import SparseNewton
from .dispatch import (SolverConfig, SolverPlan, get_plan, make_config,
                       select_backend, register_backend, PLAN_STATS,
                       reset_plan_stats)
from . import solvers, precond

__all__ = [
    "SparseTensor", "SparseTensorList", "coo_matvec", "build_bell",
    "DSparseTensor", "DSparseTensorList",
    "nonlinear_solve", "sparse_solve", "sparse_eigsh", "SparseNewton",
    "SolverConfig", "SolverPlan", "get_plan", "make_config",
    "select_backend", "register_backend", "PLAN_STATS", "reset_plan_stats",
    "solvers", "precond",
]

_LAZY = {"DSparseTensor": "distributed", "DSparseTensorList": "distributed"}


def __getattr__(name):
    """Lazy re-export of the distributed layer (PEP 562): plain
    single-device imports never pay the shard_map/mesh import cost."""
    if name in _LAZY:
        from importlib import import_module
        return getattr(import_module(f".{_LAZY[name]}", __name__), name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
