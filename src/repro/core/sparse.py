"""Typed sparse tensors (paper §3.1).

``SparseTensor``      — one matrix, or a batch sharing one sparsity pattern (COO).
``SparseTensorList``  — a batch with *distinct* patterns (ragged dispatch).

Distributed variants (``DSparseTensor``) live in :mod:`repro.core.distributed`.

The COO triplet ``(val, row, col)`` is the canonical storage; auxiliary
TPU-friendly forms (block-ELL for the Pallas SpMV kernel, structured-stencil
metadata) are attached at construction time when the pattern allows it.
``val`` may carry leading batch dimensions — the pattern is shared across the
batch and a single symbolic setup (BELL layout / dispatch decision) is reused,
mirroring torch-sla's shared-pattern batching.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "SparseTensor",
    "SparseTensorList",
    "coo_matvec",
    "coo_to_dense",
    "detect_properties",
    "has_full_diagonal",
    "build_bell",
    "aggregate_pattern",
    "spgemm_program",
    "tentative_coarse_pattern",
    "color_pattern",
]


def has_full_diagonal(row, col, n: int) -> bool:
    """True when every diagonal position is structurally present — the pivot
    prerequisite of the no-pivoting direct factorization (core/direct.py).
    ``row``/``col`` must be concrete."""
    r = np.asarray(row)
    c = np.asarray(col)
    return bool(np.unique(r[r == c]).size == n)


# ---------------------------------------------------------------------------
# low-level COO kernels (autodiff-safe, XLA-fused)
# ---------------------------------------------------------------------------

def coo_matvec(val: jax.Array, row: jax.Array, col: jax.Array, x: jax.Array,
               n_rows: int) -> jax.Array:
    """y = A @ x for COO A.  Supports leading batch dims on ``val``/``x``.

    Uses ``segment_sum`` (sorted-by-row patterns get the fast path; unsorted
    still correct).  This is the ``jnp`` backend's SpMV and the oracle for the
    Pallas kernels.
    """
    if val.ndim == 1 and x.ndim == 1:
        return jax.ops.segment_sum(val * x[col], row, num_segments=n_rows)
    # broadcast batch dims: val (..., nnz), x (..., n)
    batch_shape = jnp.broadcast_shapes(val.shape[:-1], x.shape[:-1])
    val = jnp.broadcast_to(val, batch_shape + val.shape[-1:])
    x = jnp.broadcast_to(x, batch_shape + x.shape[-1:])
    flat_v = val.reshape((-1, val.shape[-1]))
    flat_x = x.reshape((-1, x.shape[-1]))
    y = jax.vmap(lambda v, xx: jax.ops.segment_sum(v * xx[col], row,
                                                   num_segments=n_rows))(flat_v, flat_x)
    return y.reshape(batch_shape + (n_rows,))


def coo_rmatvec(val, row, col, y, n_cols):
    """x = Aᵀ @ y — transpose is a row/col swap (paper Eq. 6 uses this)."""
    return coo_matvec(val, col, row, y, n_cols)


def coo_to_dense(val, row, col, shape):
    n, m = shape
    base = jnp.zeros(val.shape[:-1] + (n, m), dtype=val.dtype)
    return base.at[..., row, col].add(val)


def coo_diagonal(val, row, col, n):
    mask = (row == col)
    return jax.ops.segment_sum(jnp.where(mask, val, 0.0), row, num_segments=n)


# ---------------------------------------------------------------------------
# pattern-level coarsening / product helpers (eager / numpy — the symbolic
# half of the algebraic-multigrid plan, see core/multigrid.py)
# ---------------------------------------------------------------------------

def aggregate_pattern(row, col, n: int):
    """Greedy aggregation of the (symmetrized) pattern graph.

    The values-free half of smoothed-aggregation coarsening: pass 1 seeds an
    aggregate at every node whose whole neighbourhood is still free (node ∪
    neighbours become one aggregate — the standard Vaněk sweep); pass 2
    attaches leftover nodes to the neighbouring aggregate they touch most;
    pass 3 turns isolated stragglers into singletons.  Returns ``(agg, n_agg)``
    with ``agg[i]`` the aggregate id of fine node ``i``.
    """
    r = np.asarray(row, dtype=np.int64)
    c = np.asarray(col, dtype=np.int64)
    mask = r != c
    rr = np.concatenate([r[mask], c[mask]])
    cc = np.concatenate([c[mask], r[mask]])
    order = np.lexsort((cc, rr))
    rr, cc = rr[order], cc[order]
    keep = np.ones(len(rr), bool)
    keep[1:] = (rr[1:] != rr[:-1]) | (cc[1:] != cc[:-1])
    rr, cc = rr[keep], cc[keep]
    ptr = np.searchsorted(rr, np.arange(n + 1))

    agg = np.full(n, -1, dtype=np.int64)
    n_agg = 0
    for i in range(n):                     # pass 1: free-neighbourhood seeds
        if agg[i] >= 0:
            continue
        nb = cc[ptr[i]:ptr[i + 1]]
        if nb.size and (agg[nb] >= 0).any():
            continue
        agg[i] = n_agg
        agg[nb] = n_agg
        n_agg += 1
    for i in range(n):                     # pass 2: attach to busiest neighbour
        if agg[i] >= 0:
            continue
        nb_agg = agg[cc[ptr[i]:ptr[i + 1]]]
        nb_agg = nb_agg[nb_agg >= 0]
        if nb_agg.size:
            agg[i] = np.bincount(nb_agg).argmax()
    for i in range(n):                     # pass 3: isolated singletons
        if agg[i] < 0:
            agg[i] = n_agg
            n_agg += 1
    return agg, int(n_agg)


def spgemm_program(arow, acol, brow, bcol, shape_c):
    """Static index program for the sparse product C = A·B (pattern-level).

    Enumerates every structurally-nonzero pair (entry ``e`` of A, entry ``f``
    of B with ``brow[f] == acol[e]``), assigns each its slot in the unique
    pattern of C, and returns ``(ga, gb, gdst, crow, ccol)``: the numeric
    product is ONE gather + segment-sum, ``c_val = segment_sum(
    a_val[ga] * b_val[gb], gdst, num_segments=len(crow))`` — the same
    static-index discipline as ``core/direct.py``'s step programs, reused by
    the Galerkin triple product of the AMG plan.
    """
    arow = np.asarray(arow, np.int64); acol = np.asarray(acol, np.int64)
    brow = np.asarray(brow, np.int64); bcol = np.asarray(bcol, np.int64)
    ob = np.argsort(brow, kind="stable")
    # CSR-ish grouping of B by row (row range = A's column space)
    n_mid = int(max(acol.max(initial=-1), brow.max(initial=-1))) + 1
    bptr = np.searchsorted(brow[ob], np.arange(n_mid + 1))
    cnt = (bptr[acol + 1] - bptr[acol])            # pairs per A entry
    total = int(cnt.sum())
    ga = np.repeat(np.arange(len(arow), dtype=np.int64), cnt)
    grp = np.repeat(np.cumsum(cnt) - cnt, cnt)
    loc = np.arange(total, dtype=np.int64) - grp
    gb = ob[np.repeat(bptr[acol], cnt) + loc]
    keys = arow[ga] * np.int64(shape_c[1]) + bcol[gb]
    ukeys, gdst = np.unique(keys, return_inverse=True)
    crow = (ukeys // shape_c[1]).astype(np.int64)
    ccol = (ukeys % shape_c[1]).astype(np.int64)
    return ga, gb, gdst.astype(np.int64), crow, ccol


def tentative_coarse_pattern(row, col, n: int, *, coarsest: int = 48,
                             max_levels: int = 12):
    """Repeated pattern aggregation down to ``coarsest`` nodes (values-free).

    Composes the per-level aggregate maps into ONE fine→coarse map and the
    coarse Galerkin pattern Tᵀ·A·T of the *tentative* (piecewise-constant)
    prolongator: because every T entry is 1, the numeric coarse matrix is a
    single segment-sum of the fine values through ``e2c``.  Returns
    ``(agg, n_c, e2c, crow, ccol)``.  This is the coarse level of the
    two-level Schwarz preconditioner (core/precond.py).
    """
    agg = np.arange(n, dtype=np.int64)
    n_c = n
    r = np.asarray(row, np.int64)
    c = np.asarray(col, np.int64)
    for _ in range(max_levels):
        if n_c <= coarsest:
            break
        a, na = aggregate_pattern(r, c, n_c)
        if na >= n_c:                       # aggregation stalled
            break
        agg = a[agg]
        keys = np.unique(a[r] * np.int64(na) + a[c])
        r = (keys // na).astype(np.int64)
        c = (keys % na).astype(np.int64)
        n_c = na
    keys = agg[np.asarray(row, np.int64)] * np.int64(n_c) + \
        agg[np.asarray(col, np.int64)]
    ukeys, e2c = np.unique(keys, return_inverse=True)
    crow = (ukeys // n_c).astype(np.int64)
    ccol = (ukeys % n_c).astype(np.int64)
    return agg, int(n_c), e2c.astype(np.int64), crow, ccol


def color_pattern(row, col, n_cols: int):
    """Greedy column coloring of a Jacobian pattern (Curtis–Powell–Reid).

    Two columns get different colors whenever they share a structurally
    nonzero row — a distance-1 coloring of the column-intersection graph —
    so ONE ``jax.jvp`` probe per color recovers every pattern entry exactly:
    ``J[r, c] == (J @ p_{color[c]})[r]`` because no other column of c's
    color touches row r.  Eager numpy, run once per pattern by
    :class:`repro.core.nonlinear.SparseNewton` — the symbolic half of sparse
    Jacobian assembly, the same analyze-once discipline as the direct
    backend's AMD/etree pass.  Columns are visited largest-degree first
    (the classic LF ordering keeps the color count near the max row count).
    Returns ``(color, n_colors)`` with ``color[j] in [0, n_colors)``.
    """
    r = np.asarray(row, np.int64)
    c = np.asarray(col, np.int64)
    if r.size == 0:
        return np.zeros(n_cols, np.int64), 1 if n_cols else 0
    n_rows = int(r.max()) + 1
    orow = np.argsort(r, kind="stable")
    cols_sorted = c[orow]
    rptr = np.searchsorted(r[orow], np.arange(n_rows + 1))
    row_cols = np.split(cols_sorted, rptr[1:-1])
    ocol = np.argsort(c, kind="stable")
    rows_sorted = r[ocol]
    cptr = np.searchsorted(c[ocol], np.arange(n_cols + 1))

    color = np.full(n_cols, -1, np.int64)
    n_colors = 1
    deg = cptr[1:] - cptr[:-1]
    for j in np.argsort(-deg, kind="stable"):
        rows_j = rows_sorted[cptr[j]:cptr[j + 1]]
        if rows_j.size == 0:
            color[j] = 0          # structurally empty column: any color
            continue
        nb = np.concatenate([row_cols[i] for i in rows_j])
        used = np.zeros(n_colors + 1, bool)
        seen = color[nb]
        used[seen[seen >= 0]] = True
        free = int(np.flatnonzero(~used)[0])
        color[j] = free
        n_colors = max(n_colors, free + 1)
    return color, int(n_colors)


# ---------------------------------------------------------------------------
# pattern analysis (eager / numpy — runs once at construction)
# ---------------------------------------------------------------------------

def detect_properties(val, row, col, shape, check_values: bool = True) -> dict:
    """Detect structural symmetry / SPD-likelihood.

    Mirrors torch-sla's automatic upgrade of LU → Cholesky/LDLT.  Value-level
    checks only run when ``val`` is a concrete (non-traced) array.
    """
    props = {"symmetric": False, "spd_hint": False, "sorted_rows": False}
    if shape[0] != shape[1]:
        return props
    try:
        r = np.asarray(row)
        c = np.asarray(col)
    except Exception:  # traced
        return props
    props["sorted_rows"] = bool(np.all(np.diff(r) >= 0))
    # pivot availability for the no-pivoting direct backend (core/direct.py)
    props["struct_full_diag"] = has_full_diagonal(r, c, shape[0])
    key_f = (r.astype(np.int64) * shape[1] + c)
    key_t = (c.astype(np.int64) * shape[1] + r)
    of, ot = np.argsort(key_f), np.argsort(key_t)
    if not np.array_equal(key_f[of], key_t[ot]):
        return props  # pattern not symmetric
    sym = True
    if check_values:
        try:
            v = np.asarray(val)
        except Exception:
            v = None
        if v is not None and not isinstance(val, jax.core.Tracer):
            vf = v[..., of]
            vt = v[..., ot]
            sym = bool(np.allclose(vf, vt, rtol=1e-12, atol=1e-12))
            if sym:
                # cheap SPD hint: all diagonal entries present and positive
                dmask = r == c
                diag = np.zeros(v.shape[:-1] + (shape[0],), v.dtype)
                flat = diag.reshape(-1, shape[0])
                vflat = v.reshape(-1, v.shape[-1])
                for b in range(flat.shape[0]):
                    np.add.at(flat[b], r[dmask], vflat[b][dmask])
                props["spd_hint"] = bool(np.all(flat > 0))
    props["symmetric"] = sym
    return props


# ---------------------------------------------------------------------------
# block-ELL construction for the Pallas SpMV kernel
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class BellMeta:
    """Static layout of a block-ELL matrix (see kernels/spmv_bell.py)."""
    bm: int            # rows per row-band
    bn: int            # cols per column block (128-aligned)
    n_rb: int          # number of row bands
    n_cb: int          # number of column blocks
    k: int             # blocks per row band (padded)
    n_pad: int         # padded row count
    m_pad: int         # padded col count
    fill: float        # nnz / (n_rb*k*bm*bn) — padding efficiency


def build_bell(row, col, shape, bm: int = 8, bn: int = 128,
               max_k: Optional[int] = None):
    """Build block-ELLPACK layout: per row-band, the list of non-empty column
    blocks (padded to k) plus a scatter map from COO nnz → dense block slots.

    Returns ``(meta, block_cols[int32 (n_rb,k)], perm[int32 (nnz,)])`` where
    ``perm[e]`` is the flat index into the (n_rb,k,bm,bn) value tensor for COO
    entry e.  Values are materialized per-call with a scatter so gradients flow
    through the same COO ``val`` regardless of kernel.
    """
    r = np.asarray(row).astype(np.int64)
    c = np.asarray(col).astype(np.int64)
    n, m = shape
    n_rb = -(-n // bm)
    n_cb = -(-m // bn)
    rb = r // bm
    cb = c // bn
    # unique (row-band, col-block) pairs
    key = rb * n_cb + cb
    uniq, inv = np.unique(key, return_inverse=True)
    u_rb = uniq // n_cb
    u_cb = uniq % n_cb
    counts = np.bincount(u_rb, minlength=n_rb)
    k = int(counts.max()) if counts.size else 1
    if max_k is not None:
        k = min(k, max_k)
    # slot index of each unique block within its row band
    order = np.argsort(u_rb, kind="stable")
    slot = np.zeros_like(u_rb)
    slot_sorted = np.concatenate([np.arange(cnt) for cnt in counts]) if counts.size else np.zeros(0, np.int64)
    slot[order] = slot_sorted
    block_cols = np.zeros((n_rb, k), np.int32)
    block_cols[u_rb, np.minimum(slot, k - 1)] = u_cb.astype(np.int32)
    # scatter map: COO entry e → flat slot in (n_rb, k, bm, bn)
    e_slot = slot[inv]
    keep = e_slot < k
    e_rb = rb
    e_lr = r % bm
    e_lc = c % bn
    perm = ((e_rb * k + e_slot) * bm + e_lr) * bn + e_lc
    perm = np.where(keep, perm, -1).astype(np.int64)
    fill = float(len(r)) / float(max(n_rb * k * bm * bn, 1))
    meta = BellMeta(bm=bm, bn=bn, n_rb=int(n_rb), n_cb=int(n_cb), k=int(k),
                    n_pad=int(n_rb * bm), m_pad=int(n_cb * bn), fill=fill)
    return meta, jnp.asarray(block_cols), jnp.asarray(perm)


# ---------------------------------------------------------------------------
# SparseTensor
# ---------------------------------------------------------------------------

def _plan_cache():
    """Fresh bounded-LRU plan cache (:class:`repro.core.dispatch.PlanCache`).
    Imported lazily: dispatch imports this module at module level, so the
    cycle must break here."""
    from .dispatch import PlanCache
    return PlanCache()


@jax.tree_util.register_pytree_node_class
class SparseTensor:
    """A sparse matrix (or shared-pattern batch) with autograd-aware solvers.

    Construction is eager w.r.t. the *pattern* (row/col as concrete arrays);
    values may later be replaced by traced arrays (``with_values``) so the
    same object works inside jit/grad — mirroring torch-sla, where the pattern
    defines one symbolic setup reused across a batch or a training loop.
    """

    def __init__(self, val, row, col, shape: Sequence[int], *,
                 props: Optional[dict] = None,
                 bell: Optional[tuple] = None,
                 stencil: Optional[Any] = None,
                 build_kernel_layout: bool = False,
                 validate: bool = True):
        val = jnp.asarray(val) if not isinstance(val, jax.core.Tracer) else val
        self.val = val
        self.row = jnp.asarray(row, dtype=jnp.int32)
        self.col = jnp.asarray(col, dtype=jnp.int32)
        self.shape = tuple(int(s) for s in shape)
        if validate and not isinstance(val, jax.core.Tracer):
            assert val.shape[-1] == self.row.shape[0] == self.col.shape[0], (
                f"nnz mismatch: val {val.shape}, row {self.row.shape}")
        self.props = props if props is not None else detect_properties(
            val, self.row, self.col, self.shape)
        self.stencil = stencil
        self._plans = _plan_cache()  # plan_key → SolverPlan (bounded LRU)
        if bell is not None:
            self.bell = bell
        elif build_kernel_layout:
            self.bell = build_bell(self.row, self.col, self.shape)
        else:
            self.bell = None

    # -- pytree protocol ----------------------------------------------------
    def tree_flatten(self):
        bell_children = self.bell[1:] if self.bell is not None else ()
        children = (self.val, self.row, self.col) + tuple(bell_children)
        aux = (self.shape, _freeze(self.props),
               self.bell[0] if self.bell is not None else None, self.stencil)
        return children, aux

    @classmethod
    def tree_unflatten(cls, aux, children):
        shape, props, bell_meta, stencil = aux
        val, row, col = children[:3]
        obj = cls.__new__(cls)
        obj.val, obj.row, obj.col = val, row, col
        obj.shape = shape
        obj.props = dict(props)
        obj.stencil = stencil
        obj.bell = (bell_meta,) + tuple(children[3:]) if bell_meta is not None else None
        obj._plans = _plan_cache()
        return obj

    # -- basic ops ----------------------------------------------------------
    @property
    def nnz(self) -> int:
        return self.row.shape[0]

    @property
    def n(self) -> int:
        return self.shape[0]

    @property
    def batch_shape(self):
        return self.val.shape[:-1]

    @property
    def dtype(self):
        return self.val.dtype

    @property
    def T(self) -> "SparseTensor":
        return SparseTensor(self.val, self.col, self.row,
                            (self.shape[1], self.shape[0]),
                            props=self.props, validate=False)

    def with_values(self, val) -> "SparseTensor":
        """Same pattern, new (possibly traced) values.  The plan cache is
        SHARED with the parent — the jit/grad hot path re-solves without
        re-analyzing (paper §3.2.3: one symbolic setup per pattern)."""
        obj = SparseTensor.__new__(SparseTensor)
        obj.val, obj.row, obj.col = val, self.row, self.col
        obj.shape, obj.props = self.shape, dict(self.props)
        obj.bell, obj.stencil = self.bell, self.stencil
        obj._plans = self._plans
        return obj

    def matvec(self, x, *, backend: Optional[str] = None):
        from . import dispatch
        return dispatch.matvec(self, x, backend=backend)

    def __matmul__(self, x):
        return self.matvec(x)

    def rmatvec(self, y):
        return coo_rmatvec(self.val, self.row, self.col, y, self.shape[1])

    def todense(self):
        return coo_to_dense(self.val, self.row, self.col, self.shape)

    def diagonal(self):
        return coo_diagonal(self.val, self.row, self.col, self.shape[0])

    # -- solvers (autograd-aware; see core/adjoint.py) ----------------------
    def plan(self, **solve_kwargs):
        """Analyze (or fetch the cached) :class:`~repro.core.dispatch.SolverPlan`
        for this pattern + solver options — the analyze stage of
        analyze → setup → solve."""
        from . import dispatch
        return dispatch.get_plan(self, dispatch.make_config(self, **solve_kwargs))

    def solve(self, b, *, backend: Optional[str] = None,
              method: Optional[str] = None, tol: float = 1e-6,
              atol: float = 0.0, maxiter: Optional[int] = None,
              precond: str = "jacobi", x0=None):
        """Differentiable solve of ``A x = b`` through the plan engine.

        ``backend`` ∈ {auto, dense, direct, jnp, pallas, stencil}: ``direct``
        is the sparse LDLᵀ/LU path with a cached symbolic factorization
        (methods ``ldlt``/``lu``); auto prefers it for mid-size systems and
        whenever ``props["illcond_hint"]`` is set.  ``precond`` ∈ {none,
        jacobi, block_jacobi, chebyshev, mg, amg, ilu} applies to the
        iterative backends; ``ilu`` is ILU(0)/IC(0) built on the same
        symbolic machinery, ``mg`` the geometric V-cycle (stencil layouts),
        ``amg`` smoothed-aggregation algebraic multigrid for any pattern
        (coarsening and Galerkin programs cached on the plan).  Multiple
        right-hand sides (leading batch dims on ``b``) share one setup — a
        single factorization serves the whole batch.
        """
        from . import adjoint, dispatch
        cfg = dispatch.make_config(self, backend=backend, method=method,
                                   tol=tol, atol=atol, maxiter=maxiter,
                                   precond=precond)
        return adjoint.sparse_solve(cfg, self, b, x0)

    def eigsh(self, k: int = 6, *, method: str = "lobpcg", tol: float = 1e-6,
              maxiter: int = 200, compute_vector_grads: bool = True,
              largest: bool = False, precond: Optional[str] = None,
              seed: int = 0):
        from . import adjoint
        return adjoint.sparse_eigsh(self, k, method=method, tol=tol,
                                    maxiter=maxiter,
                                    compute_vector_grads=compute_vector_grads,
                                    largest=largest, precond=precond,
                                    seed=seed)

    def slogdet(self):
        """(sign, log|det|): sparse via the plan engine's cached LDLᵀ/LU
        factors (Σ log |d_i| with sign tracking) for concrete patterns
        within the ``direct_budget`` option; dense fallback beyond
        (paper §3.3)."""
        from . import adjoint
        return adjoint.sparse_slogdet(self)

    def __repr__(self):
        return (f"SparseTensor(shape={self.shape}, nnz={self.nnz}, "
                f"batch={self.batch_shape}, dtype={self.dtype}, "
                f"sym={self.props.get('symmetric')}, bell={self.bell is not None})")


def _freeze(d: dict):
    return tuple(sorted(d.items()))


# ---------------------------------------------------------------------------
# SparseTensorList — distinct sparsity patterns
# ---------------------------------------------------------------------------

@jax.tree_util.register_pytree_node_class
class SparseTensorList:
    """A batch of matrices with *distinct* patterns (GNN minibatches, irregular
    meshes).  Each element dispatches independently with an isolated adjoint —
    semantics match torch-sla's SparseTensorList."""

    def __init__(self, tensors: Sequence[SparseTensor]):
        self.tensors = list(tensors)

    def tree_flatten(self):
        return tuple(self.tensors), len(self.tensors)

    @classmethod
    def tree_unflatten(cls, aux, children):
        obj = cls.__new__(cls)
        obj.tensors = list(children)
        return obj

    def __len__(self):
        return len(self.tensors)

    def __getitem__(self, i):
        return self.tensors[i]

    def solve(self, bs, **kw):
        assert len(bs) == len(self.tensors)
        return [A.solve(b, **kw) for A, b in zip(self.tensors, bs)]

    def matvec(self, xs):
        return [A.matvec(x) for A, x in zip(self.tensors, xs)]

    def eigsh(self, k: int = 6, **kw):
        return [A.eigsh(k, **kw) for A in self.tensors]
