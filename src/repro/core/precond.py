"""Preconditioners, split into eager ``build(pattern)`` + traced ``refresh(values)``.

The paper's pytorch-native backend supports only Jacobi (its stated
limitation, §5).  We reproduce Jacobi faithfully and add *beyond-paper*
preconditioners: block-Jacobi (dense MXU-sized diagonal blocks), Chebyshev
polynomial, a geometric multigrid V-cycle (``precond="mg"``, stencil
operators only), smoothed-aggregation algebraic multigrid
(``precond="amg"``, any COO pattern — coarsening and the Galerkin triple
product live as static index programs on the plan, see
:mod:`repro.core.multigrid`), and an incomplete factorization
(``precond="ilu"``, ILU(0)/IC(0)) that shares the direct backend's symbolic
machinery (:mod:`repro.core.direct`): the zero-fill elimination structures
and the packed level schedule are computed once per pattern in ``build``,
and the numeric refactorization + two level-scheduled triangular sweeps are
traced-safe ``lax.scan`` kernels.

Plan protocol (used by :class:`repro.core.dispatch.SolverPlan`):

* :class:`PreconditionerPlan` — constructed once per sparsity pattern by the
  backend's ``analyze`` stage.  Everything that only depends on the *pattern*
  (diagonal-block membership, scatter indices, level sizes) is computed here,
  eagerly, with numpy when the pattern is concrete.
* ``PreconditionerPlan.refresh(A, matvec)`` — called by the ``setup(values)``
  stage with the current (possibly traced) values.  Only traced-safe jnp ops
  run here, so the same plan works under ``jit``/``grad``/``vmap`` and is
  shared by the forward and adjoint solves.

The legacy functional constructors (``jacobi``, ``block_jacobi``,
``chebyshev``) remain for direct use and are themselves traced-safe now.
"""
from __future__ import annotations

from functools import partial
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "identity", "jacobi", "block_jacobi", "chebyshev",
    "PreconditionerPlan", "DistPreconditionerPlan", "make_preconditioner",
]

PRECONDITIONERS = ("none", "identity", "jacobi", "block_jacobi", "chebyshev",
                   "mg", "amg", "ilu")
DIST_PRECONDITIONERS = ("none", "identity", "jacobi", "schwarz", "schwarz2")


def identity():
    return lambda r: r


def jacobi(diag: jax.Array, eps: float = 1e-30):
    """M⁻¹ = D⁻¹ — the paper's default for the pytorch-native backend."""
    inv = jnp.where(jnp.abs(diag) > eps, 1.0 / diag, 1.0)
    return lambda r: inv * r


def _bj_indices(row, col, block: int):
    """(scatter target, in-diagonal-block mask) for COO entries — the
    pattern-only half of block-Jacobi.  Works on numpy or jnp index arrays."""
    rb = row // block
    same = rb == (col // block)
    flat = (rb * block + row % block) * block + col % block
    return jnp.where(same, flat, 0), same


def _bj_assemble(val, safe, same, nb: int, block: int):
    """Scatter diagonal-block entries into (nb, B, B) — traced-safe (the
    off-block entries scatter an explicit zero into slot 0)."""
    contrib = jnp.where(same, val, jnp.zeros_like(val))
    blocks = jnp.zeros((nb * block * block,), val.dtype).at[safe].add(contrib)
    blocks = blocks.reshape(nb, block, block)
    # regularize structurally-empty diagonal slots (padded tail rows)
    ar = jnp.arange(block)
    d = blocks[:, ar, ar]
    return blocks.at[:, ar, ar].set(jnp.where(jnp.abs(d) < 1e-12, 1.0, d))


def _bj_apply(inv, n: int, nb: int, block: int):
    def apply(rvec):
        rp = jnp.pad(rvec, (0, nb * block - n)).reshape(nb, block)
        out = jnp.einsum("bij,bj->bi", inv, rp).reshape(nb * block)
        return out[:n]
    return apply


def block_jacobi(val, row, col, n: int, block: int = 128):
    """Dense-block diagonal inverse.  Blocks are MXU-aligned (default 128):
    application is one batched matmul.  Beyond-paper: no TPU-hostile
    triangular solves, still much stronger than point Jacobi on PDE matrices.
    Traced-safe — works on tracer ``val`` inside jit/grad."""
    nb = -(-n // block)
    safe, same = _bj_indices(row, col, block)
    inv = jnp.linalg.inv(_bj_assemble(val, safe, same, nb, block))
    return _bj_apply(inv, n, nb, block)


def chebyshev(matvec: Callable, lam_min: float, lam_max: float, degree: int = 8,
              fused: bool = False, interpret: Optional[bool] = None):
    """Chebyshev-polynomial approximation of A⁻¹ on [lam_min, lam_max].

    Pure matvec recurrence — ideal for TPU and for the distributed backend
    (no extra reductions).  Beyond-paper addition.  With ``fused=True`` the
    inner d/x axpy pair runs as one Pallas pass per degree
    (:func:`repro.kernels.solve_step.fused_cheb_step`); the recurrence is
    unchanged."""
    theta = 0.5 * (lam_max + lam_min)
    delta = 0.5 * (lam_max - lam_min)
    sigma = theta / delta

    def apply(r):
        # 3-term Chebyshev smoother recurrence approximating x ≈ A⁻¹ r
        x = r / theta
        rk = r - matvec(x)
        rho_k = 1.0 / sigma
        dk = x
        if fused:
            from ..kernels import solve_step as _fk
        for _ in range(degree - 1):
            rho_k1 = 1.0 / (2.0 * sigma - rho_k)
            if fused:
                x, dk = _fk.fused_cheb_step(x, dk, rk, rho_k1 * rho_k,
                                            2.0 * rho_k1 / delta,
                                            interpret=interpret)
            else:
                dk = rho_k1 * rho_k * dk + (2.0 * rho_k1 / delta) * rk
                x = x + dk
            rk = rk - matvec(dk)
            rho_k = rho_k1
        return x

    return apply


def estimate_spectrum(matvec: Callable, n: int, dtype=jnp.float32,
                      steps: int = 16, seed: int = 0):
    """Lanczos-based extremal eigenvalue estimate for Chebyshev bounds.

    Traced-safe (pure jnp) — runs once per ``setup(values)``, not per solve.
    """
    from .solvers import lanczos
    v0 = jax.random.normal(jax.random.PRNGKey(seed), (n,), dtype)
    a, b_, _ = lanczos(matvec, v0, steps)
    T = jnp.diag(a) + jnp.diag(b_[:-1], 1) + jnp.diag(b_[:-1], -1)
    w = jnp.linalg.eigvalsh(T)
    return w[0], w[-1]


# ---------------------------------------------------------------------------
# plan protocol: build(pattern) eager / refresh(values) traced
# ---------------------------------------------------------------------------

class PreconditionerPlan:
    """Pattern-level preconditioner state, reusable across values refreshes.

    ``__init__`` is the eager ``build(pattern)`` stage: it validates the
    choice against the pattern and precomputes every values-independent
    artifact.  ``refresh`` is the traced ``setup(values)`` stage returning the
    apply closure consumed by the Krylov loops.
    """

    def __init__(self, name: Optional[str], row, col, shape, *,
                 stencil=None, block: int = 128, degree: int = 8):
        self.name = "none" if name in (None, "none", "identity") else name
        if self.name not in PRECONDITIONERS:
            raise ValueError(f"unknown preconditioner {name!r}")
        self.row, self.col = row, col
        self.shape = tuple(shape)
        self.stencil = stencil
        self.block = block
        self.degree = degree
        if self.name == "mg":
            if stencil is None:
                raise ValueError(
                    "precond='mg' needs a stencil-layout SparseTensor "
                    "(structured-grid operator)")
            if stencil.nx != stencil.ny:
                raise ValueError("precond='mg' requires a square grid")
        if self.name == "block_jacobi":
            # eager pattern part: diagonal-block membership + scatter targets
            self.nb = -(-self.shape[0] // block)
            try:
                r = np.asarray(row).astype(np.int64)
                c = np.asarray(col).astype(np.int64)
            except Exception:  # traced pattern — fall back to jnp in refresh
                self._bj_idx = None
            else:
                self._bj_idx = _bj_indices(r, c, block)
        if self.name == "ilu":
            # eager pattern part: the direct backend's symbolic stage in
            # zero-fill (ILU(0)) mode — structures + packed level schedule
            from . import direct as _direct
            try:
                r = np.asarray(row).astype(np.int64)
                c = np.asarray(col).astype(np.int64)
            except Exception:
                raise ValueError(
                    "precond='ilu' needs a concrete sparsity pattern "
                    "(symbolic analysis is eager)")
            self._ilu = _direct.symbolic_factor(r, c, self.shape[0],
                                                incomplete=True)
        if self.name == "amg":
            # eager pattern part: smoothed-aggregation coarsening + the
            # Galerkin index programs + the coarsest level's LDLᵀ/LU program
            # (core/multigrid.amg_symbolic) — once per pattern, cached here
            from . import multigrid as _mg
            try:
                r = np.asarray(row).astype(np.int64)
                c = np.asarray(col).astype(np.int64)
            except Exception:
                raise ValueError(
                    "precond='amg' needs a concrete sparsity pattern "
                    "(aggregation and the Galerkin programs are eager)")
            self._amg = _mg.amg_symbolic(r, c, self.shape[0])

    def fused_diag(self, A) -> Optional[jax.Array]:
        """Diagonal-inverse vector for the fused step kernels
        (:mod:`repro.kernels.solve_step`), or None when the apply is not a
        pure diagonal scale — the fused solvers then keep the ``refresh``
        closure outside the fused pass (partial fusion)."""
        if self.name == "none":
            return jnp.ones(self.shape[0], A.dtype)
        if self.name == "jacobi":
            d = A.diagonal()
            return jnp.where(jnp.abs(d) > 1e-30, 1.0 / d, 1.0)
        return None

    def refresh_state(self, A, matvec: Callable) -> tuple:
        """values-dependent stage, ARRAYS ONLY — traced-safe AND vmappable.

        Returns a pytree of arrays (no closures), so a whole stacked batch of
        shared-pattern matrices can run ``jax.vmap(refresh_state)`` through
        one trace — the engine half of the serving tentpole.  The apply
        closure is assembled from this state at solve time by
        :meth:`make_apply` (cheap, no array work)."""
        if self.name == "none":
            return ()
        if self.name == "jacobi":
            d = A.diagonal()
            return (jnp.where(jnp.abs(d) > 1e-30, 1.0 / d, 1.0),)
        if self.name == "block_jacobi":
            block = self.block
            if self._bj_idx is None:      # traced pattern: derive per refresh
                safe, same = _bj_indices(A.row, A.col, block)
            else:
                safe, same = self._bj_idx
            inv = jnp.linalg.inv(_bj_assemble(A.val, safe, same, self.nb, block))
            return (inv,)
        if self.name == "chebyshev":
            lmin, lmax = estimate_spectrum(matvec, self.shape[0], A.dtype)
            lmin = jnp.maximum(lmin, lmax * 1e-4)
            return (lmin, lmax)
        if self.name == "mg":
            from .multigrid import MultigridPreconditioner
            nx, ny = self.stencil.nx, self.stencil.ny
            v5 = A.val.reshape(5, nx, ny)
            return MultigridPreconditioner.from_planes(v5).state()
        if self.name == "ilu":
            from . import direct as _direct
            return (_direct.numeric_factor(self._ilu, A.val),)
        if self.name == "amg":
            from . import multigrid as _mg
            return _mg.amg_numeric(self._amg, A.val)  # traced-safe Galerkin
        raise ValueError(f"unknown preconditioner {self.name!r}")

    def make_apply(self, state, matvec: Callable, fused: bool = False,
                   interpret: Optional[bool] = None) -> Callable:
        """Apply closure over a :meth:`refresh_state` pytree (solve stage).

        Pure closure assembly — no array computation happens here, so it can
        run inside a per-instance ``vmap`` lane of a batched solve.  ``fused``
        routes multi-pass applies (Chebyshev) through the fused step kernels
        where they have one; it is a solve-time decision, never baked into
        the state."""
        if self.name == "none":
            return identity()
        if self.name == "jacobi":
            (inv,) = state
            return lambda r: inv * r
        if self.name == "block_jacobi":
            (inv,) = state
            return _bj_apply(inv, self.shape[0], self.nb, self.block)
        if self.name == "chebyshev":
            lmin, lmax = state
            return chebyshev(matvec, lmin, lmax, degree=self.degree,
                             fused=fused, interpret=interpret)
        if self.name == "mg":
            from .multigrid import MultigridPreconditioner
            return MultigridPreconditioner.from_state(state)
        if self.name == "ilu":
            from . import direct as _direct
            art = self._ilu
            (C,) = state
            return lambda r: _direct.factored_solve(art, C, r)
        if self.name == "amg":
            from . import multigrid as _mg
            return _mg.AMGPreconditioner(self._amg, state)
        raise ValueError(f"unknown preconditioner {self.name!r}")

    def refresh(self, A, matvec: Callable, fused: bool = False) -> Callable:
        """values-dependent stage — traced-safe; one call per solver setup.
        Composition of :meth:`refresh_state` + :meth:`make_apply`, kept for
        callers that want the one-shot closure."""
        return self.make_apply(self.refresh_state(A, matvec), matvec,
                               fused=fused)


class DistPreconditionerPlan:
    """Distributed preconditioner, split like :class:`PreconditionerPlan`:
    eager ``build(pattern)`` in ``__init__`` + traced ``refresh(values)``.

    Operates on the stacked ``(P, ·)`` storage of a ``DSparseTensor``.  The
    build stage only sees the pattern (stacked local row/col indices +
    ``DistMeta``) and precomputes every values-free artifact eagerly:

    * ``jacobi`` — the per-shard diagonal-entry mask (padding excluded via
      ``meta.shard_nnz``), so ``refresh`` is a single masked ``segment_sum``.
    * ``schwarz`` — shard-local overlapping Schwarz: each shard's extended
      matrix ``A[ext, ext]`` (owned rows ∪ halo-overlap rows, Dirichlet
      truncation at the extended boundary — a principal submatrix, so SPD
      inputs stay SPD) is analyzed ONCE through the direct machinery's
      union-pattern ILU(0)/IC(0) program (:func:`repro.core.direct.
      schwarz_symbolic`); ``refresh`` is a vmapped numeric refactorization,
      and the per-iteration apply is gather-halos → local triangular sweeps →
      transposed-halo combine (Σ Rᵀ A_ext⁻¹ R — the additive-Schwarz sum).
    * ``schwarz2`` — the two-level variant: the one-level sum above PLUS an
      additive coarse correction ``T A_c⁻¹ Tᵀ r``.  The coarse level is the
      AMG machinery's tentative (piecewise-constant) aggregation of the
      GLOBAL pattern (:func:`repro.core.sparse.tentative_coarse_pattern`),
      its Galerkin matrix assembled by ONE segment-sum from the stacked
      values and factored through :func:`repro.core.direct.symbolic_factor`
      — a distributed direct coarse solve on cached factors.  The
      per-iteration apply is all_gather residual → aggregate → coarse
      triangular sweeps → scatter correction, all through frozen index maps
      (nothing queries the axis environment at trace time).

    ``refresh(lval)`` returns a tuple of state arrays — stacked ``(P, ·)``
    leaves sharded over the mesh axis, plus replicated leaves (the coarse
    factor) flagged by :meth:`state_sharded` — that the solve stage ships
    through ``shard_map``; ``local_closure`` turns the per-shard slice of
    that state into the apply closure used inside the Krylov loop.  Halo
    application is injected by the caller (``halo_fwd``/``halo_bwd``) so
    this module stays mesh-agnostic.
    """

    def __init__(self, name: Optional[str], lrow, lcol, meta, *,
                 bounds=None, coarsest: int = 160):
        self.name = "none" if name in (None, "none", "identity") else name
        if self.name not in DIST_PRECONDITIONERS:
            raise ValueError(
                f"unknown distributed preconditioner {name!r} "
                f"(supported: {DIST_PRECONDITIONERS})")
        self.meta = meta
        lr = np.asarray(lrow)
        lc = np.asarray(lcol)
        p, nnz_loc = lr.shape
        valid = np.ones((p, nnz_loc), bool)
        if meta.shard_nnz is not None:
            valid = np.arange(nnz_loc)[None, :] < \
                np.asarray(meta.shard_nnz)[:, None]
        if self.name == "jacobi":
            self._diag_mask = jnp.asarray(
                (lr + meta.h_lo == lc) & valid)
            self._lrow = jnp.asarray(lr, jnp.int32)
        if self.name in ("schwarz", "schwarz2"):
            from . import direct as _direct
            from .distributed import global_entries
            if bounds is None:
                raise ValueError("schwarz build needs partition bounds")
            h_lo, h_hi, n_loc = meta.h_lo, meta.h_hi, meta.n_loc
            n_ext = h_lo + n_loc + h_hi
            # global entry list (shard-major) + each entry's flat value slot
            row_g, col_g, fa = global_entries(lr, lc, meta, bounds)
            # each shard's extended window [bounds[q]-h_lo, bounds[q+1]+h_hi)
            # in local extended coordinates — overlap rows included, entries
            # leaving the window dropped (Dirichlet truncation)
            entries = []
            for q in range(p):
                lo = bounds[q] - h_lo
                hi = bounds[q] + n_loc + h_hi     # uniform n_ext window
                m = ((row_g >= lo) & (row_g < hi) &
                     (col_g >= lo) & (col_g < hi))
                entries.append((row_g[m] - lo, col_g[m] - lo, fa[m]))
            self._schwarz = _direct.schwarz_symbolic(
                entries, n_ext, n_src=p * nnz_loc)
        if self.name == "schwarz2":
            from . import direct as _direct
            from .sparse import tentative_coarse_pattern
            agg, n_c, e2c, crow, ccol = tentative_coarse_pattern(
                row_g, col_g, meta.n, coarsest=coarsest)
            self._coarse_art = _direct.symbolic_factor(crow, ccol, n_c)
            self._n_c = n_c
            self._c_nnz = len(crow)
            # value-assembly program: c_val = Σ flat[fa] into coarse slots
            self._c_fa = jnp.asarray(fa, jnp.int32)
            self._c_e2c = jnp.asarray(e2c, jnp.int32)
            # owned-row → coarse-node map, padded tail rows → dump slot n_c
            own = np.full((p, n_loc), n_c, np.int64)
            for q in range(p):
                cnt = int(bounds[q + 1] - bounds[q])
                own[q, :cnt] = agg[bounds[q]:bounds[q + 1]]
            self._own2coarse = jnp.asarray(own, jnp.int32)

    def state_sharded(self) -> tuple:
        """Per-leaf sharding of :meth:`refresh`'s output: True → stacked
        ``(P, ·)`` sharded over the mesh axis, False → replicated (the
        two-level coarse factor, identical on every shard)."""
        if self.name == "none":
            return ()
        if self.name == "schwarz2":
            return (True, False)
        return (True,)

    def refresh(self, lval) -> tuple:
        """values-dependent stage — traced-safe; returns stacked state."""
        if self.name == "none":
            return ()
        if self.name == "jacobi":
            n_loc = self.meta.n_loc

            def one(v, m_, r):
                d = jax.ops.segment_sum(jnp.where(m_, v, 0.0), r,
                                        num_segments=n_loc)
                return jnp.where(jnp.abs(d) > 1e-30, 1.0 / d, 1.0)

            return (jax.vmap(one)(lval, self._diag_mask, self._lrow),)
        if self.name in ("schwarz", "schwarz2"):
            from . import direct as _direct
            C = _direct.schwarz_numeric(self._schwarz, lval.reshape(-1))
            if self.name == "schwarz":
                return (C,)
            # coarse Galerkin values Tᵀ A T: every tentative-prolongator
            # entry is 1, so the triple product is ONE segment-sum of the
            # flat values through the frozen entry→coarse-slot map
            c_val = jax.ops.segment_sum(lval.reshape(-1)[self._c_fa],
                                        self._c_e2c,
                                        num_segments=self._c_nnz)
            Cc = _direct.numeric_factor(self._coarse_art, c_val)
            return (C, Cc)
        raise ValueError(f"unknown distributed preconditioner {self.name!r}")

    def local_closure(self, state_q, halo_fwd: Callable,
                      halo_bwd: Callable,
                      matvec: Optional[Callable] = None) -> Callable:
        """Per-shard apply closure (inside ``shard_map``; state pre-sliced).
        ``matvec`` (the shard-local halo'd SpMV) is only required by the
        two-level mode's deflation products."""
        if self.name == "none":
            return identity()
        if self.name == "jacobi":
            (inv,) = state_q
            return lambda r: inv * r
        if self.name in ("schwarz", "schwarz2"):
            from . import direct as _direct
            from jax import lax
            C = state_q[0]
            art = self._schwarz.art

            def apply(r):
                r_ext = halo_fwd(r)
                z_ext = _direct.factored_solve(art, C, r_ext)
                return halo_bwd(z_ext)     # Σ Rᵀ A_ext⁻¹ R: overlap summed

            if self.name == "schwarz":
                return apply

            if matvec is None:
                raise ValueError("schwarz2 needs the shard-local matvec")
            Cc = state_q[1]
            c_art = self._coarse_art
            own = self._own2coarse
            n_c = self._n_c
            axis = self.meta.axis

            def coarse(r):
                # Q r = T A_c⁻¹ Tᵀ r: gather the global residual (frozen
                # axis name; all_gather orders shards by axis index),
                # aggregate, solve on the cached coarse factors, scatter
                r_all = lax.all_gather(r, axis)          # (P, n_loc)
                rc = jax.ops.segment_sum(
                    r_all.reshape(-1), own.reshape(-1),
                    num_segments=n_c + 1)[:n_c]
                zc = _direct.factored_solve(c_art, Cc, rc)
                zc_pad = jnp.concatenate([zc, jnp.zeros((1,), zc.dtype)])
                return zc_pad[own[lax.axis_index(axis)]]

            def apply2(r):
                # symmetric deflated two-level (BNN/ADEF-2 form):
                #   M = Q + (I − Q A) M_AS (I − A Q)
                # — the coarse space is solved exactly and REMOVED from the
                # Schwarz sweep's workload instead of added on top (a purely
                # additive T A_c⁻¹ Tᵀ term double-counts the low modes the
                # exact subdomain solves already resolve)
                zc = coarse(r)
                w = apply(r - matvec(zc))
                return zc + w - coarse(matvec(w))

            return apply2
        raise ValueError(f"unknown distributed preconditioner {self.name!r}")


def make_preconditioner(name: str, A, matvec: Callable):
    """One-shot factory: build(pattern) + refresh(values) in one call.

    Name ∈ {none, jacobi, block_jacobi, chebyshev, mg, ilu}.  Prefer going through
    a :class:`~repro.core.dispatch.SolverPlan` so the build stage is cached.
    """
    plan = PreconditionerPlan(name, A.row, A.col, A.shape, stencil=A.stencil)
    return plan.refresh(A, matvec)
