"""Preconditioners.

The paper's pytorch-native backend supports only Jacobi (its stated
limitation, §5).  We reproduce Jacobi faithfully and add two *beyond-paper*
matvec-only preconditioners that suit TPU (no scalar triangular solves):
block-Jacobi (dense MXU-sized diagonal blocks) and Chebyshev polynomial.
"""
from __future__ import annotations

from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["identity", "jacobi", "block_jacobi", "chebyshev", "make_preconditioner"]


def identity():
    return lambda r: r


def jacobi(diag: jax.Array, eps: float = 1e-30):
    """M⁻¹ = D⁻¹ — the paper's default for the pytorch-native backend."""
    inv = jnp.where(jnp.abs(diag) > eps, 1.0 / diag, 1.0)
    return lambda r: inv * r


def block_jacobi(val, row, col, n: int, block: int = 128):
    """Dense-block diagonal inverse.  Blocks are MXU-aligned (default 128):
    extraction is eager (concrete pattern), application is one batched matmul.
    Beyond-paper: no TPU-hostile triangular solves, still much stronger than
    point Jacobi on PDE matrices."""
    nb = -(-n // block)
    r = np.asarray(row); c = np.asarray(col); v = np.asarray(val)
    blocks = np.zeros((nb, block, block), v.dtype)
    same = (r // block) == (c // block)
    rb = r[same] // block
    blocks[rb, r[same] % block, c[same] % block] = v[same]
    # regularize empty tail rows of the padded final block
    for b_ in range(nb):
        d = np.abs(np.diag(blocks[b_]))
        fix = d < 1e-12
        blocks[b_][np.where(fix)[0], np.where(fix)[0]] = 1.0
    inv = jnp.asarray(np.linalg.inv(blocks))

    def apply(rvec):
        pad = nb * block - n
        rp = jnp.pad(rvec, (0, pad)).reshape(nb, block)
        out = jnp.einsum("bij,bj->bi", inv, rp).reshape(nb * block)
        return out[:n]

    return apply


def chebyshev(matvec: Callable, lam_min: float, lam_max: float, degree: int = 8):
    """Chebyshev-polynomial approximation of A⁻¹ on [lam_min, lam_max].

    Pure matvec recurrence — ideal for TPU and for the distributed backend
    (no extra reductions).  Beyond-paper addition."""
    theta = 0.5 * (lam_max + lam_min)
    delta = 0.5 * (lam_max - lam_min)
    sigma = theta / delta

    def apply(r):
        # 3-term Chebyshev smoother recurrence approximating x ≈ A⁻¹ r
        x = r / theta
        rk = r - matvec(x)
        rho_k = 1.0 / sigma
        dk = x
        for _ in range(degree - 1):
            rho_k1 = 1.0 / (2.0 * sigma - rho_k)
            dk = rho_k1 * rho_k * dk + (2.0 * rho_k1 / delta) * rk
            x = x + dk
            rk = rk - matvec(dk)
            rho_k = rho_k1
        return x

    return apply


def estimate_spectrum(matvec: Callable, n: int, dtype=jnp.float32,
                      steps: int = 16, seed: int = 0):
    """Lanczos-based extremal eigenvalue estimate for Chebyshev bounds."""
    from .solvers import lanczos
    v0 = jax.random.normal(jax.random.PRNGKey(seed), (n,), dtype)
    a, b_, _ = lanczos(matvec, v0, steps)
    T = jnp.diag(a) + jnp.diag(b_[:-1], 1) + jnp.diag(b_[:-1], -1)
    w = jnp.linalg.eigvalsh(T)
    return w[0], w[-1]


def make_preconditioner(name: str, A, matvec: Callable):
    """Factory used by dispatch: name ∈ {none, jacobi, block_jacobi, chebyshev}."""
    if name in (None, "none", "identity"):
        return identity()
    if name == "jacobi":
        return jacobi(A.diagonal())
    if name == "block_jacobi":
        return block_jacobi(A.val, A.row, A.col, A.shape[0])
    if name == "chebyshev":
        lmin, lmax = estimate_spectrum(matvec, A.shape[0], A.dtype)
        lmin = jnp.maximum(lmin, lmax * 1e-4)
        return chebyshev(matvec, lmin, lmax, degree=8)
    raise ValueError(f"unknown preconditioner {name!r}")
