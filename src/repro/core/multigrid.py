"""Geometric multigrid V-cycle preconditioner for structured-grid operators.

The paper's stated limitation (§5): the pytorch-native backend supports only
Jacobi preconditioning, "insufficient at large DOF — hence the 1e-2
residuals in our multi-GPU runs"; AMG (AmgX/hypre) is named as future work.
This module closes that gap for the paper's own benchmark family
(variable-coefficient 2D Poisson): a matrix-free geometric V-cycle —
weighted-Jacobi smoothing, full-weighting restriction of both residual and
coefficient field, bilinear prolongation, dense coarse solve — usable as the
``M`` of any Krylov solver in this library (and TPU-friendly: shifts,
pooling and small matmuls only; no triangular solves).

It is also a first-class ``precond="mg"`` option of the solver-plan factory
(:mod:`repro.core.precond`): the hierarchy *structure* (level sizes) is
static per grid shape, while the per-level operators are rebuilt traced-safe
from the current stencil values by :meth:`MultigridPreconditioner.from_planes`
inside the plan's ``setup(values)`` stage.
"""
from __future__ import annotations

import functools
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp

from ..data.poisson import vc_coefficients
from ..kernels.ref import stencil5_ref


def _smooth(v5, x, b, omega: float = 0.8, iters: int = 2):
    """Weighted-Jacobi smoothing on the 5-point stencil planes."""
    diag = v5[0]
    inv = jnp.where(jnp.abs(diag) > 1e-30, omega / diag, 0.0)
    for _ in range(iters):
        r = b - stencil5_ref(v5, x)
        x = x + inv * r
    return x


def _restrict(r):
    """Full-weighting 2×2 restriction (cell-centered)."""
    ng = r.shape[0]
    return r.reshape(ng // 2, 2, ng // 2, 2).mean(axis=(1, 3))


def _prolong(e):
    """Piecewise-constant/bilinear-ish prolongation (transpose of restrict)."""
    return jnp.repeat(jnp.repeat(e, 2, axis=0), 2, axis=1)


def _build_levels(kappa: jax.Array, coarsest: int,
                  fine_planes: Optional[jax.Array] = None
                  ) -> Tuple[List[jax.Array], List[int]]:
    """Level hierarchy by 2×2-averaging κ (rediscretization coarsening).

    ``fine_planes``, when given, is used verbatim as the finest operator (so
    the smoother sees the *actual* assembled matrix, not a rediscretization);
    coarser levels always come from ``vc_coefficients`` of the restricted κ.
    All ops are traced-safe; only level *sizes* (static, from shapes) steer
    the Python loop.
    """
    levels: List[jax.Array] = []
    sizes: List[int] = []
    ng = kappa.shape[0]
    k = kappa

    def level_op(k, ng):
        if fine_planes is not None and not levels:
            return fine_planes
        return vc_coefficients(k).reshape(5, ng, ng)

    while ng >= coarsest and ng % 2 == 0:
        levels.append(level_op(k, ng))
        sizes.append(ng)
        k = _restrict(k)
        ng //= 2
    levels.append(level_op(k, ng))
    sizes.append(ng)
    return levels, sizes


class MultigridPreconditioner:
    """One V-cycle per application, built from a κ field (paper §4.4 operator).

    Levels are built eagerly by 2×2-averaging κ (rediscretization
    coarsening); the coarsest level solves densely.  All per-level operators
    are the same signed (5, n, n) planes the stencil kernel consumes.
    """

    def __init__(self, kappa: Optional[jax.Array] = None, *,
                 coarsest: int = 16, pre_smooth: int = 2,
                 post_smooth: int = 2, omega: float = 0.8,
                 _levels: Optional[List[jax.Array]] = None,
                 _sizes: Optional[List[int]] = None):
        self.pre, self.post, self.omega = pre_smooth, post_smooth, omega
        if _levels is None:
            _levels, _sizes = _build_levels(kappa, coarsest)
        self.levels, self.sizes = _levels, _sizes
        # dense coarse operator (assembled once per setup; traced-safe)
        ng = self.sizes[-1]
        nc = ng * ng
        eye = jnp.eye(nc).reshape(nc, ng, ng)
        Ac = jax.vmap(lambda col: stencil5_ref(self.levels[-1], col))(eye)
        self.A_coarse = Ac.reshape(nc, nc).T
        # h-scaling between levels: rediscretized coarse operator acts on a
        # 2×-coarser grid — the restricted residual needs a 4× factor to
        # keep the two-grid correction consistent (h² scaling of the stencil)
        self.scale = 4.0

    @classmethod
    def from_planes(cls, v5: jax.Array, *, coarsest: int = 16,
                    **kw) -> "MultigridPreconditioner":
        """Build from assembled (5, ng, ng) stencil planes (traced-safe).

        Recovers a κ proxy from the centre plane (C = ΣkN,kS,kW,kE ≈ 4κ for
        the variable-coefficient Poisson family), keeps the given planes as
        the finest operator, and rediscretizes the restricted proxy below.
        This is the ``precond="mg"`` entry point of the plan factory.
        """
        if v5.ndim != 3 or v5.shape[0] != 5 or v5.shape[1] != v5.shape[2]:
            raise ValueError(f"from_planes expects (5, ng, ng), got {v5.shape}")
        kappa_proxy = v5[0] / 4.0
        levels, sizes = _build_levels(kappa_proxy, coarsest, fine_planes=v5)
        return cls(_levels=levels, _sizes=sizes, **kw)

    def _vcycle(self, level: int, b):
        v5 = self.levels[level]
        x = _smooth(v5, jnp.zeros_like(b), b, self.omega, self.pre)
        if level == len(self.levels) - 1:
            nc = b.size
            return jnp.linalg.solve(self.A_coarse, b.reshape(nc)).reshape(b.shape)
        r = b - stencil5_ref(v5, x)
        rc = _restrict(r) * self.scale
        ec = self._vcycle(level + 1, rc)
        x = x + _prolong(ec)
        x = _smooth(v5, x, b, self.omega, self.post)
        return x

    def __call__(self, r: jax.Array) -> jax.Array:
        ng = self.sizes[0]
        return self._vcycle(0, r.reshape(ng, ng)).reshape(-1)


def make_mg_preconditioner(kappa: jax.Array, **kw):
    """Factory matching the core.precond interface."""
    mg = MultigridPreconditioner(kappa, **kw)
    return lambda r: mg(r)
