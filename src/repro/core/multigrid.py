"""Multigrid preconditioners — one level-hierarchy abstraction, two builders.

The paper's stated limitation (§5): the pytorch-native backend supports only
Jacobi preconditioning, "insufficient at large DOF — hence the 1e-2
residuals in our multi-GPU runs"; AMG (AmgX/hypre) is named as future work.
This module closes that gap twice over:

* **Geometric** (``precond="mg"``, stencil operators): matrix-free V-cycle —
  weighted-Jacobi smoothing, full-weighting restriction of both residual and
  coefficient field, bilinear prolongation, dense coarse solve.  TPU-friendly:
  shifts, pooling and small matmuls only.

* **Algebraic** (``precond="amg"``, any COO pattern): smoothed-aggregation
  AMG as a first-class citizen of the plan engine.  The *analyze* half
  (:func:`amg_symbolic` — eager, numpy, values-free, cached on the
  ``SolverPlan``) runs greedy aggregation over the sparsity pattern
  (:func:`repro.core.sparse.aggregate_pattern`), freezes the smoothed-
  prolongator fill pattern, and packs the Galerkin triple product R·A·P into
  static gather/segment-sum index programs
  (:func:`repro.core.sparse.spgemm_program` — the same discipline as
  ``core/direct.py``'s step programs); the coarsest level gets a cached
  LDLᵀ/LU program from :func:`repro.core.direct.symbolic_factor`.  The
  *setup* half (:func:`amg_numeric` — traced-safe) evaluates filtered-matrix
  weights, prolongator smoothing and the triple product through those
  programs, so it jits/vmaps and is memoized per values array by the plan's
  setup stage (``PLAN_STATS["coarsen"]``/``["galerkin"]`` count the two
  halves).

Both builders produce a tuple of :class:`Level` closures consumed by the
shared :func:`v_cycle` driver, so the solve stage is one code path.
"""
from __future__ import annotations

import functools
from typing import Callable, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..data.poisson import vc_coefficients
from ..kernels.ref import stencil5_ref
from .sparse import aggregate_pattern, coo_matvec, spgemm_program


# ---------------------------------------------------------------------------
# the shared hierarchy abstraction: Level closures + one V-cycle driver
# ---------------------------------------------------------------------------

class Level(NamedTuple):
    """One level of a multigrid hierarchy, as closures over the (possibly
    traced) numeric state.  The coarsest level only needs ``coarse_solve``;
    every other level supplies the smoother/transfer quadruple.
    ``post_smooth`` defaults to ``smooth`` when None."""
    matvec: Callable            # x -> A_l @ x
    smooth: Callable            # (x, b) -> relaxed x (pre-smoother)
    restrict: Optional[Callable] = None    # r_l -> r_{l+1}
    prolong: Optional[Callable] = None     # e_{l+1} -> e_l
    coarse_solve: Optional[Callable] = None  # b -> A_l^{-1} b (last level)
    post_smooth: Optional[Callable] = None


def v_cycle(levels: Tuple[Level, ...], b, level: int = 0):
    """One V(pre, post)-cycle over ``levels`` — the recursion is Python
    (static level count), every op inside is traced-safe."""
    lv = levels[level]
    if lv.coarse_solve is not None:
        return lv.coarse_solve(b)
    x = lv.smooth(jnp.zeros_like(b), b)
    r = b - lv.matvec(x)
    ec = v_cycle(levels, lv.restrict(r), level + 1)
    x = x + lv.prolong(ec)
    return (lv.post_smooth or lv.smooth)(x, b)


# ---------------------------------------------------------------------------
# geometric builder (structured 5-point stencil planes)
# ---------------------------------------------------------------------------

def _smooth(v5, x, b, omega: float = 0.8, iters: int = 2):
    """Weighted-Jacobi smoothing on the 5-point stencil planes."""
    diag = v5[0]
    inv = jnp.where(jnp.abs(diag) > 1e-30, omega / diag, 0.0)
    for _ in range(iters):
        r = b - stencil5_ref(v5, x)
        x = x + inv * r
    return x


def _restrict(r):
    """Full-weighting 2×2 restriction (cell-centered)."""
    ng = r.shape[0]
    return r.reshape(ng // 2, 2, ng // 2, 2).mean(axis=(1, 3))


def _prolong(e):
    """Piecewise-constant/bilinear-ish prolongation (transpose of restrict)."""
    return jnp.repeat(jnp.repeat(e, 2, axis=0), 2, axis=1)


def _build_levels(kappa: jax.Array, coarsest: int,
                  fine_planes: Optional[jax.Array] = None
                  ) -> Tuple[List[jax.Array], List[int]]:
    """Level hierarchy by 2×2-averaging κ (rediscretization coarsening).

    ``fine_planes``, when given, is used verbatim as the finest operator (so
    the smoother sees the *actual* assembled matrix, not a rediscretization);
    coarser levels always come from ``vc_coefficients`` of the restricted κ.
    All ops are traced-safe; only level *sizes* (static, from shapes) steer
    the Python loop.
    """
    levels: List[jax.Array] = []
    sizes: List[int] = []
    ng = kappa.shape[0]
    k = kappa

    def level_op(k, ng):
        if fine_planes is not None and not levels:
            return fine_planes
        return vc_coefficients(k).reshape(5, ng, ng)

    while ng >= coarsest and ng % 2 == 0:
        levels.append(level_op(k, ng))
        sizes.append(ng)
        k = _restrict(k)
        ng //= 2
    levels.append(level_op(k, ng))
    sizes.append(ng)
    return levels, sizes


class MultigridPreconditioner:
    """One V-cycle per application, built from a κ field (paper §4.4 operator).

    Levels are built eagerly by 2×2-averaging κ (rediscretization
    coarsening); the coarsest level solves densely.  All per-level operators
    are the same signed (5, n, n) planes the stencil kernel consumes; the
    cycle itself runs through the shared :func:`v_cycle` driver.
    """

    def __init__(self, kappa: Optional[jax.Array] = None, *,
                 coarsest: int = 16, pre_smooth: int = 2,
                 post_smooth: int = 2, omega: float = 0.8,
                 _levels: Optional[List[jax.Array]] = None,
                 _sizes: Optional[List[int]] = None):
        self.pre, self.post, self.omega = pre_smooth, post_smooth, omega
        if _levels is None:
            _levels, _sizes = _build_levels(kappa, coarsest)
        self.levels, self.sizes = _levels, _sizes
        # dense coarse operator (assembled once per setup; traced-safe)
        ng = self.sizes[-1]
        nc = ng * ng
        eye = jnp.eye(nc).reshape(nc, ng, ng)
        Ac = jax.vmap(lambda col: stencil5_ref(self.levels[-1], col))(eye)
        self.A_coarse = Ac.reshape(nc, nc).T
        # h-scaling between levels: rediscretized coarse operator acts on a
        # 2×-coarser grid — the restricted residual needs a 4× factor to
        # keep the two-grid correction consistent (h² scaling of the stencil)
        self.scale = 4.0
        self._hier = self._build_hierarchy()

    @classmethod
    def from_planes(cls, v5: jax.Array, *, coarsest: int = 16,
                    **kw) -> "MultigridPreconditioner":
        """Build from assembled (5, ng, ng) stencil planes (traced-safe).

        Recovers a κ proxy from the centre plane (C = ΣkN,kS,kW,kE ≈ 4κ for
        the variable-coefficient Poisson family), keeps the given planes as
        the finest operator, and rediscretizes the restricted proxy below.
        This is the ``precond="mg"`` entry point of the plan factory.
        """
        if v5.ndim != 3 or v5.shape[0] != 5 or v5.shape[1] != v5.shape[2]:
            raise ValueError(f"from_planes expects (5, ng, ng), got {v5.shape}")
        kappa_proxy = v5[0] / 4.0
        levels, sizes = _build_levels(kappa_proxy, coarsest, fine_planes=v5)
        return cls(_levels=levels, _sizes=sizes, **kw)

    def _build_hierarchy(self) -> Tuple[Level, ...]:
        out = []
        last = len(self.levels) - 1
        for l, v5 in enumerate(self.levels):
            if l == last:
                ng = self.sizes[l]
                nc = ng * ng
                out.append(Level(
                    matvec=functools.partial(stencil5_ref, v5),
                    smooth=lambda x, b: x,
                    coarse_solve=lambda b, A=self.A_coarse, ng=ng, nc=nc:
                        jnp.linalg.solve(A, b.reshape(nc)).reshape(b.shape)))
            else:
                out.append(Level(
                    matvec=functools.partial(stencil5_ref, v5),
                    smooth=lambda x, b, v5=v5, it=self.pre:
                        _smooth(v5, x, b, self.omega, it),
                    restrict=lambda r: _restrict(r) * self.scale,
                    prolong=_prolong,
                    post_smooth=lambda x, b, v5=v5, it=self.post:
                        _smooth(v5, x, b, self.omega, it)))
        return tuple(out)

    def state(self) -> tuple:
        """Array-only pytree of the hierarchy: per-level stencil planes plus
        the dense coarse operator.  Everything static (grid sizes, smoother
        counts) is shape metadata or defaults, so a stacked batch of these
        states vmaps cleanly; :meth:`from_state` rehydrates per lane."""
        return (tuple(self.levels), self.A_coarse)

    @classmethod
    def from_state(cls, state: tuple, *, pre_smooth: int = 2,
                   post_smooth: int = 2,
                   omega: float = 0.8) -> "MultigridPreconditioner":
        """Rebuild the apply from a :meth:`state` pytree — closure assembly
        only, no array work (the coarse operator rides along in the state),
        so it is safe inside a ``vmap`` lane of a batched solve."""
        levels, A_coarse = state
        mg = cls.__new__(cls)
        mg.pre, mg.post, mg.omega = pre_smooth, post_smooth, omega
        mg.levels = list(levels)
        mg.sizes = [int(v5.shape[1]) for v5 in levels]
        mg.A_coarse = A_coarse
        mg.scale = 4.0
        mg._hier = mg._build_hierarchy()
        return mg

    def __call__(self, r: jax.Array) -> jax.Array:
        ng = self.sizes[0]
        return v_cycle(self._hier, r.reshape(ng, ng)).reshape(-1)


def make_mg_preconditioner(kappa: jax.Array, **kw):
    """Factory matching the core.precond interface."""
    mg = MultigridPreconditioner(kappa, **kw)
    return lambda r: mg(r)


# ---------------------------------------------------------------------------
# algebraic builder — smoothed-aggregation AMG in the plan engine
# ---------------------------------------------------------------------------

class AMGLevelSymbolic(NamedTuple):
    """Pattern-only artifacts of one AMG level (products of ``analyze``).

    ``a2p`` scatters every A entry into its smoothed-prolongator slot
    (entry (i,j) → P slot (i, agg[j]), always structurally present); the
    ``g1_*``/``g2_*`` arrays are the two :func:`spgemm_program` halves of the
    Galerkin triple product Pᵀ·(A·P), so the numeric setup is two gathers +
    two segment-sums per level — no dynamic sparse-sparse matmul ever runs.
    """
    n: int                       # fine size of this level
    n_c: int                     # coarse size (number of aggregates)
    arow: jax.Array              # this level's pattern (level 0 = input A)
    acol: jax.Array
    diag_mask: jax.Array         # (nnz,) bool — diagonal entries of A_l
    agg: jax.Array               # (n,) aggregate id per fine node
    p_row: jax.Array             # smoothed-prolongator pattern
    p_col: jax.Array
    a2p: jax.Array               # (nnz,) A entry → P slot
    tent: jax.Array              # (nnzP,) 1.0 on tentative slots (i, agg[i])
    g1_a: jax.Array              # A·P product program
    g1_p: jax.Array
    g1_dst: jax.Array
    nnz_ap: int
    g2_p: jax.Array              # Pᵀ·(A·P) product program
    g2_ap: jax.Array
    g2_dst: jax.Array
    nnz_c: int


class AMGArtifacts(NamedTuple):
    """Product of :func:`amg_symbolic` — the pattern-time half of the AMG
    plan, shared by every ``with_values`` refresh and the adjoint."""
    levels: Tuple[AMGLevelSymbolic, ...]
    coarse: "object"             # DirectArtifacts of the coarsest level
    n_coarse: int
    theta: float
    omega: float
    smooth_omega: float
    pre: int
    post: int
    stats: dict


def amg_symbolic(row, col, n: int, *, theta: float = 0.08,
                 omega: float = 2.0 / 3.0, smooth_omega: float = 2.0 / 3.0,
                 coarsest: int = 64, max_levels: int = 12,
                 pre_smooth: int = 1, post_smooth: int = 1) -> AMGArtifacts:
    """Analyze one sparsity pattern for smoothed-aggregation AMG (eager).

    Values-free by contract (plans outlive any single trace): aggregation,
    the smoothed-prolongator fill pattern and both Galerkin product programs
    depend only on the graph.  ``theta`` (strength threshold) and ``omega``
    (prolongator-smoothing damping) are *numeric* knobs consumed later by
    :func:`amg_numeric`.  The coarsest level's pattern goes through
    :func:`repro.core.direct.symbolic_factor`, so the V-cycle bottoms out in
    the cached-LDLᵀ machinery instead of a dense solve.
    """
    from . import direct as _direct
    from .dispatch import PLAN_STATS
    with jax.ensure_compile_time_eval():
        r = np.asarray(row, np.int64)
        c = np.asarray(col, np.int64)
        levels: List[AMGLevelSymbolic] = []
        n_l = n
        for _ in range(max_levels):
            if n_l <= coarsest:
                break
            agg, n_c = aggregate_pattern(r, c, n_l)
            if n_c >= n_l:                   # aggregation stalled — stop
                break
            # smoothed-prolongator pattern: P = (I − ω D⁻¹ Ā) T has slots
            # {(i, agg[j]) : (i,j) ∈ A} ∪ {(i, agg[i])}
            pkeys = np.unique(np.concatenate(
                [r * np.int64(n_c) + agg[c],
                 np.arange(n_l, dtype=np.int64) * np.int64(n_c) + agg]))
            p_row = (pkeys // n_c).astype(np.int64)
            p_col = (pkeys % n_c).astype(np.int64)
            a2p = np.searchsorted(pkeys, r * np.int64(n_c) + agg[c])
            tent = (p_col == agg[p_row]).astype(np.float64)
            # Galerkin R·A·P as two static spgemm programs: AP = A·P, then
            # A_c = Pᵀ·AP (R = Pᵀ — symmetric-pattern Galerkin)
            g1_a, g1_p, g1_dst, ap_row, ap_col = spgemm_program(
                r, c, p_row, p_col, (n_l, n_c))
            g2_p, g2_ap, g2_dst, c_row, c_col = spgemm_program(
                p_col, p_row, ap_row, ap_col, (n_c, n_c))
            levels.append(AMGLevelSymbolic(
                n=n_l, n_c=n_c,
                arow=jnp.asarray(r, jnp.int32), acol=jnp.asarray(c, jnp.int32),
                diag_mask=jnp.asarray(r == c),
                agg=jnp.asarray(agg, jnp.int32),
                p_row=jnp.asarray(p_row, jnp.int32),
                p_col=jnp.asarray(p_col, jnp.int32),
                a2p=jnp.asarray(a2p, jnp.int32),
                tent=jnp.asarray(tent),
                g1_a=jnp.asarray(g1_a, jnp.int32),
                g1_p=jnp.asarray(g1_p, jnp.int32),
                g1_dst=jnp.asarray(g1_dst, jnp.int32), nnz_ap=len(ap_row),
                g2_p=jnp.asarray(g2_p, jnp.int32),
                g2_ap=jnp.asarray(g2_ap, jnp.int32),
                g2_dst=jnp.asarray(g2_dst, jnp.int32), nnz_c=len(c_row)))
            r, c, n_l = c_row, c_col, n_c
        coarse = _direct.symbolic_factor(r, c, n_l)
        PLAN_STATS["coarsen"] += 1
        stats = {"n_levels": len(levels) + 1, "n_coarse": n_l,
                 "sizes": [lv.n for lv in levels] + [n_l]}
        return AMGArtifacts(levels=tuple(levels), coarse=coarse, n_coarse=n_l,
                            theta=theta, omega=omega,
                            smooth_omega=smooth_omega,
                            pre=pre_smooth, post=post_smooth, stats=stats)


def _amg_level_numeric(lev: AMGLevelSymbolic, aval, theta: float,
                       omega: float):
    """One level of the numeric setup (traced-safe): filtered-matrix weights,
    prolongator smoothing, Galerkin triple product through the index
    programs.  Returns ``(dinv, p_val, c_val)``."""
    d = jax.ops.segment_sum(jnp.where(lev.diag_mask, aval, 0.0),
                            lev.arow, num_segments=lev.n)
    # strength filtering: keep |a_ij| ≥ θ √|a_ii a_jj|, lump dropped mass
    # into the diagonal (Vaněk's filtered matrix Ā) — numeric, not symbolic,
    # so the SAME pattern program serves every values refresh
    offd = lev.arow != lev.acol
    strong = jnp.abs(aval) >= theta * jnp.sqrt(
        jnp.abs(d[lev.arow] * d[lev.acol]) + 1e-300)
    keep = (~offd) | strong
    a_f = jnp.where(keep, aval, 0.0)
    lump = jax.ops.segment_sum(jnp.where(keep, 0.0, aval), lev.arow,
                               num_segments=lev.n)
    d_f = d - lump
    dinv_f = jnp.where(jnp.abs(d_f) > 1e-30, 1.0 / d_f, 0.0)
    # P = (I − ω D̄⁻¹ Ā) T: scatter Ā through a2p, subtract the lumped mass
    # at the tentative slot (it is Ā's diagonal adjustment), add T
    p_sum = jax.ops.segment_sum(a_f, lev.a2p, num_segments=len(lev.p_row))
    p_sum = p_sum - lev.tent * lump[lev.p_row]
    p_val = lev.tent.astype(aval.dtype) - omega * dinv_f[lev.p_row] * p_sum
    # Galerkin A_c = Pᵀ (A P) — two gathers + two segment-sums, UNfiltered A
    ap = jax.ops.segment_sum(aval[lev.g1_a] * p_val[lev.g1_p], lev.g1_dst,
                             num_segments=lev.nnz_ap)
    c_val = jax.ops.segment_sum(p_val[lev.g2_p] * ap[lev.g2_ap], lev.g2_dst,
                                num_segments=lev.nnz_c)
    dinv = jnp.where(jnp.abs(d) > 1e-30, 1.0 / d, 0.0)
    return dinv, p_val, c_val


def amg_numeric(art: AMGArtifacts, val: jax.Array):
    """The jit/vmap-safe numeric half of the AMG plan (the ``setup`` stage):
    per-level smoothing weights + prolongator values + Galerkin coarse
    values, and the coarsest level's numeric LDLᵀ/LU refactorization.
    Memoized per values array by ``SolverPlan.setup``."""
    from . import direct as _direct
    from .dispatch import PLAN_STATS
    PLAN_STATS["galerkin"] += 1
    state = []
    aval = val
    for lev in art.levels:
        dinv, p_val, c_val = _amg_level_numeric(lev, aval, art.theta,
                                                art.omega)
        state.append((aval, dinv, p_val))
        aval = c_val
    C = _direct.numeric_factor(art.coarse, aval)
    return tuple(state), C


def amg_hierarchy(art: AMGArtifacts, state) -> Tuple[Level, ...]:
    """Assemble the shared-driver :class:`Level` tuple from symbolic
    artifacts + numeric state — flat-vector transfers via the prolongator
    COO pattern (restrict = Pᵀ r, prolong = P e)."""
    from . import direct as _direct
    per_level, C = state
    levels = []
    for lev, (aval, dinv, p_val) in zip(art.levels, per_level):
        mv = functools.partial(coo_matvec, aval, lev.arow, lev.acol,
                               n_rows=lev.n)

        def make_smooth(mv, dinv, it, om=art.smooth_omega):
            def smooth(x, b):
                for _ in range(it):
                    x = x + om * dinv * (b - mv(x))
                return x
            return smooth

        levels.append(Level(
            matvec=mv,
            smooth=make_smooth(mv, dinv, art.pre),
            restrict=lambda r, lev=lev, p_val=p_val:
                jax.ops.segment_sum(p_val * r[lev.p_row], lev.p_col,
                                    num_segments=lev.n_c),
            prolong=lambda e, lev=lev, p_val=p_val:
                jax.ops.segment_sum(p_val * e[lev.p_col], lev.p_row,
                                    num_segments=lev.n),
            post_smooth=make_smooth(mv, dinv, art.post)))
    levels.append(Level(
        matvec=lambda x: x,
        smooth=lambda x, b: x,
        coarse_solve=lambda b: _direct.factored_solve(art.coarse, C, b)))
    return tuple(levels)


class AMGPreconditioner:
    """Apply closure for ``precond="amg"``: one V-cycle per application over
    the plan's frozen hierarchy.  Built by ``PreconditionerPlan.refresh``
    from (symbolic artifacts, numeric state)."""

    def __init__(self, art: AMGArtifacts, state):
        self.art = art
        self.levels = amg_hierarchy(art, state)

    def __call__(self, r: jax.Array) -> jax.Array:
        return v_cycle(self.levels, r)
