"""Backend abstraction + auto-dispatch (paper §3.1, Appendix A Table 6).

Five interchangeable backends behind one API — the TPU/JAX analogue of
torch-sla's {scipy, eigen, cudss, cupy, pytorch}:

| backend   | device  | methods                      | regime                         |
|-----------|---------|------------------------------|--------------------------------|
| dense     | MXU     | lu, cholesky                 | direct; n ≤ dense budget       |
| jnp       | any     | cg, bicgstab, gmres          | general COO, segment-sum SpMV  |
| pallas    | TPU     | cg, bicgstab, gmres          | block-ELL Pallas SpMV          |
| stencil   | TPU     | cg, bicgstab                 | matrix-free structured grids   |
| dist      | mesh    | cg, bicgstab, pipelined_cg   | DSparseTensor (core/distributed)|

Dispatch policy (mirrors paper §3.1 rules, TPU constants):
  (i)   honor explicit ``backend=``/``method=`` overrides;
  (ii)  direct below the dense budget (paper: cuDSS below the fill-in budget);
  (iii) iterative above, preferring the Pallas/stencil SpMV when the tensor
        carries that layout; CG when SPD-ish, BiCGStab otherwise.

Extensibility: ``register_backend`` adds a backend exactly like torch-sla's
``select_backend`` registration — implement ``solve(cfg, A, b, x0)`` and an
applicability predicate.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from . import precond as _precond
from . import solvers as _solvers
from .sparse import SparseTensor, coo_matvec

DENSE_BUDGET = 4096          # TPU dense-direct crossover (measured, see EXPERIMENTS.md)
DEFAULT_MAXITER = 2000

_REGISTRY: dict = {}


@dataclasses.dataclass(frozen=True)
class SolverConfig:
    """Hashable solver configuration (goes through custom_vjp nondiff args)."""
    backend: str = "auto"
    method: str = "auto"
    tol: float = 1e-6
    atol: float = 0.0
    maxiter: int = DEFAULT_MAXITER
    precond: str = "jacobi"
    restart: int = 32            # gmres

    def resolved(self, A: SparseTensor) -> "SolverConfig":
        b, m = select_backend(A, self.backend, self.method)
        return dataclasses.replace(self, backend=b, method=m)

    def transposed_for(self, A: SparseTensor) -> "SolverConfig":
        """Config for the adjoint solve Aᵀλ = g — same backend/method; the
        paper reuses the forward backend (and factorization) for the adjoint."""
        return self


def register_backend(name: str, solve_fn: Callable, applicable: Callable):
    _REGISTRY[name] = (solve_fn, applicable)


def select_backend(A: SparseTensor, backend: str, method: str):
    """Device- and size-aware auto-dispatch (paper §3.1)."""
    n = A.shape[0]
    sym = A.props.get("symmetric", False)
    spd = A.props.get("spd_hint", False)
    platform = jax.default_backend()

    if backend == "auto":
        if A.stencil is not None:
            backend = "stencil"
        elif n <= DENSE_BUDGET and not A.batch_shape:
            backend = "dense"
        elif A.bell is not None and platform == "tpu":
            backend = "pallas"
        else:
            backend = "jnp"
    if method == "auto":
        if backend == "dense":
            method = "cholesky" if spd else "lu"
        else:
            method = "cg" if (spd or sym) else "bicgstab"
    return backend, method


def make_config(A: SparseTensor, *, backend=None, method=None, tol=1e-6,
                atol=0.0, maxiter=None, precond="jacobi", restart=32) -> SolverConfig:
    cfg = SolverConfig(backend=backend or "auto", method=method or "auto",
                       tol=tol, atol=atol,
                       maxiter=maxiter or DEFAULT_MAXITER,
                       precond=precond, restart=restart)
    return cfg.resolved(A)


# ---------------------------------------------------------------------------
# matvec selection
# ---------------------------------------------------------------------------

def make_matvec(A: SparseTensor, backend: Optional[str] = None) -> Callable:
    backend = backend or ("stencil" if A.stencil is not None else
                          ("pallas" if A.bell is not None and
                           jax.default_backend() == "tpu" else "jnp"))
    if backend == "stencil" and A.stencil is not None:
        from ..kernels import ops as kops
        return partial(kops.stencil5_matvec, A.stencil, A.val)
    if backend == "pallas" and A.bell is not None:
        from ..kernels import ops as kops
        meta, block_cols, perm = A.bell
        return lambda x: kops.bell_matvec(meta, block_cols, perm, A.val, x,
                                          A.shape[0])
    return lambda x: coo_matvec(A.val, A.row, A.col, x, A.shape[0])


def matvec(A: SparseTensor, x, backend: Optional[str] = None):
    if A.batch_shape or (hasattr(x, "ndim") and x.ndim > 1):
        return coo_matvec(A.val, A.row, A.col, x, A.shape[0])
    return make_matvec(A, backend)(x)


# ---------------------------------------------------------------------------
# the raw (non-differentiable) solve — called by the adjoint framework for
# both the forward and the adjoint systems.
# ---------------------------------------------------------------------------

def solve_impl(cfg: SolverConfig, A: SparseTensor, b: jax.Array,
               x0: Optional[jax.Array] = None):
    """One un-differentiated solve.  Batched values/rhs are vmapped here so
    the adjoint layer never needs to care (shared-pattern batching)."""
    if cfg.backend in _REGISTRY:
        return _REGISTRY[cfg.backend][0](cfg, A, b, x0)

    batch = jnp.broadcast_shapes(A.batch_shape, b.shape[:-1])
    if batch:
        val = jnp.broadcast_to(A.val, batch + A.val.shape[-1:])
        bb = jnp.broadcast_to(b, batch + b.shape[-1:])
        fv = val.reshape((-1, val.shape[-1]))
        fb = bb.reshape((-1, bb.shape[-1]))
        if x0 is not None:
            fx0 = jnp.broadcast_to(x0, batch + x0.shape[-1:]).reshape(fb.shape)
        def one(v, rhs, xx0=None):
            Ai = A.with_values(v)
            x, info = _solve_single(cfg, Ai, rhs, xx0)
            return x, info
        if x0 is None:
            xs, infos = jax.vmap(lambda v, rhs: one(v, rhs))(fv, fb)
        else:
            xs, infos = jax.vmap(one)(fv, fb, fx0)
        return xs.reshape(batch + (b.shape[-1],)), infos
    return _solve_single(cfg, A, b, x0)


def _solve_single(cfg: SolverConfig, A: SparseTensor, b, x0):
    if cfg.backend == "dense":
        return _solvers.dense_solve(A.todense(), b, cfg.method)
    mv = make_matvec(A, cfg.backend)
    M = _precond.make_preconditioner(cfg.precond, A, mv)
    if cfg.method == "cg":
        return _solvers.cg(mv, b, x0, M=M, tol=cfg.tol, atol=cfg.atol,
                           maxiter=cfg.maxiter)
    if cfg.method == "bicgstab":
        return _solvers.bicgstab(mv, b, x0, M=M, tol=cfg.tol, atol=cfg.atol,
                                 maxiter=cfg.maxiter)
    if cfg.method == "gmres":
        return _solvers.gmres(mv, b, x0, M=M, tol=cfg.tol, atol=cfg.atol,
                              restart=cfg.restart,
                              maxiter=max(cfg.maxiter // cfg.restart, 1))
    raise ValueError(f"unknown method {cfg.method!r} for backend {cfg.backend!r}")
