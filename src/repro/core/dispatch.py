"""Backend registry + plan-cached auto-dispatch (paper §3.1, §3.2.3, App. A).

Five built-in backends behind one API — the TPU/JAX analogue of torch-sla's
{scipy, eigen, cudss, cupy, pytorch}:

| backend   | device  | methods                      | regime                         |
|-----------|---------|------------------------------|--------------------------------|
| dense     | MXU     | lu, cholesky                 | direct; n ≤ dense budget       |
| direct    | any     | ldlt, lu                     | sparse direct (cuDSS analogue):|
|           |         |                              | cached symbolic factorization  |
| jnp       | any     | cg, bicgstab, gmres          | general COO, segment-sum SpMV  |
| pallas    | TPU     | cg, bicgstab, gmres          | block-ELL Pallas SpMV          |
| stencil   | TPU     | cg, bicgstab                 | matrix-free structured grids   |
| dist      | mesh    | cg, bicgstab, pipelined_cg   | DSparseTensor (core/distributed)|

The ``direct`` backend (:mod:`repro.core.direct`) is the paper's headline
path: ``analyze`` computes the fill-reducing ordering (quotient-graph AMD by
default) + the etree-derived static fill pattern ONCE per pattern, ``setup``
is a jit/vmap-safe numeric refactorization memoized per values array
(``PLAN_STATS["factorize"]``/``["setup_reuse"]``), and the adjoint reuses
the forward factors — LDLᵀ is self-adjoint, LU swaps the triangular sweeps
via a shared-artifact transpose plan.

Plan lifecycle (paper §3.2.3 "one symbolic setup per pattern")
--------------------------------------------------------------
Every solve goes through a three-stage split::

    plan  = get_plan(A, cfg)        # ❶ analyze(pattern)  — eager, cached
    state = plan.setup(A)           # ❷ setup(values)     — traced-safe
    x, info = plan.solve(A, b, x0)  # ❸ solve(b)          — runs ❷ then Krylov/LU

❶ ``analyze`` runs ONCE per (sparsity pattern, backend/method/precond): it
picks the backend class, freezes the kernel layout (block-ELL / stencil
metadata), and builds the pattern-level half of the preconditioner
(:class:`repro.core.precond.PreconditionerPlan` — for ``precond="amg"``
that includes the smoothed-aggregation coarsening and the packed Galerkin
index programs of :mod:`repro.core.multigrid`, counted by
``PLAN_STATS["coarsen"]``/``["galerkin"]``).  Plans are cached on the
``SparseTensor`` keyed by ``SolverConfig.plan_key()`` — solve-loop knobs
(tol/atol/maxiter/restart) are NOT part of the key, so a tolerance sweep or
continuation loop reuses one plan — and the cache dict is *shared* by
``with_values``, so the jit/grad hot path and every solve in a
shared-pattern batch reuse one analysis.

❷ ``setup`` consumes the current (possibly traced) values: preconditioner
refresh (block inverses, Chebyshev spectrum bounds, MG hierarchy), dense
materialization.  It never touches numpy, so it is safe under jit/grad/vmap.

❸ ``solve`` executes the configured method.  The adjoint layer
(:mod:`repro.core.adjoint`) fetches ``plan.transpose()`` for the backward
system Aᵀλ = g: for symmetric patterns that is the SAME plan object (BELL
layout and preconditioner build reused); for non-symmetric patterns a
transposed sibling plan is analyzed once and cached on the forward plan.

``PLAN_STATS`` counts analyze/setup/cache events so tests (and profiles) can
assert reuse; ``register_backend`` adds custom backends either as a
``Backend`` subclass or as a legacy ``solve(cfg, A, b, x0)`` function.
"""
from __future__ import annotations

import collections
import dataclasses
import sys
import types
import weakref
from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import direct as _direct
from . import options as _options
from . import precond as _precond
from . import solvers as _solvers
from .sparse import SparseTensor, build_bell, coo_matvec, has_full_diagonal

# The dispatch knobs (dense/direct budgets, BELL fill floor, fused-step mode,
# plan-cache bounds) live in repro.core.options now — one immutable record
# behind sla.set_options() / sla.options(...) / REPRO_SLA_* env vars.  The
# historical module globals (DENSE_BUDGET, DIRECT_BUDGET, BELL_MIN_FILL,
# FUSED_STEP, PLAN_CACHE_CAP, PLAN_CACHE_BYTES) remain as deprecated
# read/write aliases — see the module __getattr__ / class swap at the bottom.
DEFAULT_MAXITER = 2000

# observable analyze/setup/cache counters (reset with ``reset_plan_stats``)
PLAN_STATS: Dict[str, int] = {
    "analyze": 0,          # SolverPlan constructions (pattern analyses)
    "setup": 0,            # values-dependent setups actually executed
    "setup_reuse": 0,      # setups served from the per-values memo
    "factorize": 0,        # numeric factorizations run by the direct backend
    "cache_hit": 0,        # plan served from a SparseTensor's plan cache
    "cache_miss": 0,       # plan analyzed fresh
    "transpose_shared": 0,  # adjoint reused the forward plan (or its factors)
    "t_partition": 0,      # distributed Aᵀ partitions built (once per plan)
    "coarsen": 0,          # AMG pattern coarsenings (symbolic, once/pattern)
    "galerkin": 0,         # AMG numeric Galerkin products (once/values array)
    "kernel_plan": 0,      # BELL conversions run by the analyze-time kernel plan
    "evictions": 0,        # plans dropped by the bounded LRU plan cache
    "jac_color": 0,        # Jacobian pattern colorings (once per SparseNewton)
    "jac_assemble": 0,     # numeric Jacobian assemblies (jvp probe sweeps)
}


def reset_plan_stats() -> None:
    """Zero every ``PLAN_STATS`` counter (tests and benchmarks call this
    before a measured region)."""
    for k in PLAN_STATS:
        PLAN_STATS[k] = 0


class PlanCache(collections.OrderedDict):
    """Pattern-keyed plan cache: LRU entry cap + optional byte budget.

    Plans are cheap-ish to hold, but a long-running server sweeping configs
    on one tensor would otherwise grow the dict without bound — and plans
    are NOT all the same size: BELL slot tables and direct/ILU/AMG factor
    programs scale with the pattern, so the cache additionally tracks each
    plan's :meth:`SolverPlan.nbytes` estimate and evicts LRU-first until the
    resident total fits ``plan_cache_bytes`` (``None`` = entry-count-only).
    Both bounds are live reads of :mod:`repro.core.options` unless pinned by
    the constructor; evictions count in ``PLAN_STATS["evictions"]``.  Shared
    by ``with_values`` views exactly like the plain dict it replaces."""

    def __init__(self, cap: Optional[int] = None,
                 max_bytes: Optional[int] = None):
        super().__init__()
        self._cap = cap
        self._max_bytes = max_bytes
        self._sizes: Dict[Any, int] = {}
        self.total_bytes = 0

    @property
    def cap(self) -> int:
        return self._cap if self._cap is not None \
            else _options.current().plan_cache_cap

    @property
    def max_bytes(self) -> Optional[int]:
        return self._max_bytes if self._max_bytes is not None \
            else _options.current().plan_cache_bytes

    @staticmethod
    def _nbytes_of(value) -> int:
        try:
            return int(value.nbytes())
        except Exception:
            return 0

    def get(self, key, default=None):
        if key in self:
            self.move_to_end(key)
            return super().get(key)
        return default

    def _evict_oldest(self) -> None:
        old, _ = self.popitem(last=False)
        self.total_bytes -= self._sizes.pop(old, 0)
        PLAN_STATS["evictions"] += 1

    def __setitem__(self, key, value):
        if key in self:            # replace = delete + fresh LRU insert
            super().__delitem__(key)
            self.total_bytes -= self._sizes.pop(key, 0)
        nb = self._nbytes_of(value)
        budget = self.max_bytes
        # the `while self` guard keeps at least the incoming entry resident:
        # a single plan larger than the whole budget still gets cached (and
        # evicts everything else) rather than thrashing on every get_plan
        while self and (len(self) >= self.cap or
                        (budget is not None and
                         self.total_bytes + nb > budget)):
            self._evict_oldest()
        super().__setitem__(key, value)
        self._sizes[key] = nb
        self.total_bytes += nb

    def __delitem__(self, key):
        super().__delitem__(key)
        self.total_bytes -= self._sizes.pop(key, 0)

    def clear(self):
        super().clear()
        self._sizes.clear()
        self.total_bytes = 0


@dataclasses.dataclass
class KernelPlan:
    """Analyze-time matvec kernel choice — a frozen plan artifact.

    ``choice``: "bell" | "stencil" | "coo"; ``reason`` records why (fill
    ratio, traced pattern, interpret-mode platform) for observability.
    ``interpret`` is the platform-resolved Pallas flag threaded into every
    kernel launch; ``bell``/``t_bell`` are the (meta, block_cols, perm)
    layouts of A and Aᵀ built in the same analyze pass so the adjoint's
    backward matvec shares the conversion (``t_bell is bell`` for symmetric
    patterns)."""
    choice: str
    reason: str
    interpret: bool
    bell: Optional[tuple] = None
    t_bell: Optional[tuple] = None


def _build_kernel_plan(pattern, prefer: str) -> KernelPlan:
    """Freeze the matvec kernel for one analyzed pattern.

    ``prefer`` is the backend's kernel preference: "stencil" (stencil
    backend), "bell" (pallas backend — explicit opt-in, adopted even in
    interpret mode), "auto" (jnp backend — BELL only where it is profitable
    AND compiles), "coo" (never convert).  Runs inside ``analyze``'s
    ``ensure_compile_time_eval`` so the slot tables are concrete."""
    from ..kernels.solve_step import default_interpret
    interp = default_interpret()
    if prefer == "stencil":
        if pattern.stencil is not None:
            return KernelPlan("stencil", "stencil layout present", interp)
        prefer = "auto"
    if prefer == "coo":
        return KernelPlan("coo", "backend prefers segment-sum", interp)
    if prefer == "auto" and interp:
        # interpret-mode Pallas is an emulation — segment_sum wins on CPU
        return KernelPlan("coo", "interpret-mode platform", interp)
    concrete = not isinstance(pattern.row, jax.core.Tracer)
    bell = pattern.bell                     # construction-time layout, if any
    if bell is None:
        if not concrete:
            return KernelPlan("coo", "traced pattern (no eager conversion)",
                              interp)
        bell = build_bell(pattern.row, pattern.col, pattern.shape)
        PLAN_STATS["kernel_plan"] += 1
    meta = bell[0]
    # minimum BELL fill (nnz over padded slot capacity) for the kernel plan
    # to adopt the block-ELL layout on its own; below it the padding work
    # outweighs the dense-tile win and the plan records a segment-sum
    # fallback.  The default (1/64) keeps 2-D Poisson (fill ≈ 0.02 at bm=8,
    # bn=128) on the kernel path.
    min_fill = _options.current().bell_min_fill
    if prefer != "bell" and meta.fill < min_fill:
        return KernelPlan(
            "coo", f"bell fill {meta.fill:.4f} < {min_fill:.4f}", interp)
    n, m = pattern.shape
    if n == m and pattern.props.get("symmetric", False):
        t_bell = bell                       # Aᵀ shares A's layout outright
    elif concrete:
        t_bell = build_bell(pattern.col, pattern.row, (m, n))
        PLAN_STATS["kernel_plan"] += 1
    else:
        t_bell = None          # traced indices: adjoint takes the generic path
    return KernelPlan("bell", f"fill={meta.fill:.4f}", interp, bell, t_bell)


def _fuse_enabled(kp: Optional[KernelPlan]) -> bool:
    """Fused CG/BiCGStab step kernels (kernels/solve_step.py): "auto"
    enables them when the Pallas kernels compile (TPU/GPU) and keeps the
    plain XLA loops in interpret mode (CPU), where an emulated kernel per
    iteration would be a slowdown; "on"/"off" force either path.  Read at
    solve-trace time, not frozen into the plan."""
    mode = _options.current().fused_step
    if mode == "on":
        return True
    if mode == "off" or kp is None:
        return False
    return not kp.interpret


def _plan_matvec(plan: "SolverPlan", kp: KernelPlan, val) -> Callable:
    """Single-instance matvec closure through the kernel plan's choice."""
    n = plan.shape[0]
    if kp.choice == "stencil" and plan.stencil is not None:
        from ..kernels import ops as kops
        return lambda x: kops.stencil5_matvec(plan.stencil, val, x)
    if kp.choice == "bell" and kp.bell is not None:
        from ..kernels import ops as kops
        meta, block_cols, perm = kp.bell
        interp = kp.interpret
        return lambda x: kops.bell_matvec(meta, block_cols, perm, val, x, n,
                                          interp)
    row, col = plan.row, plan.col
    return lambda x: coo_matvec(val, row, col, x, n)


@dataclasses.dataclass(frozen=True)
class SolverConfig:
    """Hashable solver configuration (goes through custom_vjp nondiff args)."""
    backend: str = "auto"
    method: str = "auto"
    tol: float = 1e-6
    atol: float = 0.0
    maxiter: int = DEFAULT_MAXITER
    precond: str = "jacobi"
    restart: int = 32            # gmres

    def resolved(self, A: SparseTensor) -> "SolverConfig":
        b, m = select_backend(A, self.backend, self.method)
        return dataclasses.replace(self, backend=b, method=m)

    def plan_key(self) -> Tuple[str, str, str]:
        """Plan-cache key: only the fields the analyze stage depends on.
        tol/atol/maxiter/restart steer the solve loop, not the symbolic
        setup — a tolerance sweep reuses one plan."""
        return (self.backend, self.method, self.precond)


# ---------------------------------------------------------------------------
# kernel (matvec) selection — shared by backends and the public ``matvec``
# ---------------------------------------------------------------------------

def _select_kernel(A: SparseTensor, backend: Optional[str] = None) -> str:
    if backend in (None, "auto"):
        if A.stencil is not None:
            return "stencil"
        if A.bell is not None and jax.default_backend() == "tpu":
            return "bell"
        return "coo"
    if backend == "stencil" and A.stencil is not None:
        return "stencil"
    if backend == "pallas" and A.bell is not None:
        return "bell"
    return "coo"


def _kernel_fn(A: SparseTensor, kernel: str) -> Callable:
    """Single-instance SpMV as a function of (val, x) — vmap-able."""
    if kernel == "stencil" and A.stencil is not None:
        from ..kernels import ops as kops
        return partial(kops.stencil5_matvec, A.stencil)
    if kernel == "bell" and A.bell is not None:
        from ..kernels import ops as kops
        meta, block_cols, perm = A.bell
        n = A.shape[0]
        return lambda v, x: kops.bell_matvec(meta, block_cols, perm, v, x, n)
    row, col, n = A.row, A.col, A.shape[0]
    return lambda v, x: coo_matvec(v, row, col, x, n)


def make_matvec(A: SparseTensor, backend: Optional[str] = None) -> Callable:
    """Closure ``x ↦ A @ x`` through the selected kernel (unbatched)."""
    fn = _kernel_fn(A, _select_kernel(A, backend))
    return lambda x: fn(A.val, x)


def matvec(A: SparseTensor, x, backend: Optional[str] = None):
    """A @ x — batched values and/or rhs route through the SAME selected
    kernel via vmap (shared-pattern batching keeps the kernel layout)."""
    kernel = _select_kernel(A, backend)
    batched = bool(A.batch_shape) or (hasattr(x, "ndim") and x.ndim > 1)
    if not batched:
        return _kernel_fn(A, kernel)(A.val, x)
    if kernel == "coo":
        return coo_matvec(A.val, A.row, A.col, x, A.shape[0])
    fn = _kernel_fn(A, kernel)
    batch = jnp.broadcast_shapes(A.batch_shape, x.shape[:-1])
    val = jnp.broadcast_to(A.val, batch + A.val.shape[-1:])
    xx = jnp.broadcast_to(x, batch + x.shape[-1:])
    y = jax.vmap(fn)(val.reshape((-1, val.shape[-1])),
                     xx.reshape((-1, xx.shape[-1])))
    return y.reshape(batch + (A.shape[0],))


# ---------------------------------------------------------------------------
# backend classes — each exposes the analyze/setup/solve stages
# ---------------------------------------------------------------------------

class Backend:
    """A solver backend.  Subclasses implement the three plan stages.

    ``analyze(cfg, pattern)`` — eager, values-free; returns the artifact dict
    stored on the plan.  ``setup(plan, A)`` — traced-safe, values-dependent.
    ``solve(plan, state, A, b, x0)`` — one un-differentiated solve.
    """
    name: str = "abstract"
    methods: Tuple[str, ...] = ()
    handles_batch = False       # True: backend does its own batch vmapping
    cache_setup = False         # True: memoize setup() per values array

    def applicable(self, A: SparseTensor) -> bool:
        return True

    def transpose_plan(self, plan: "SolverPlan") -> Optional["SolverPlan"]:
        """Optionally build the adjoint plan from this plan's own artifacts
        (zero re-analysis).  ``None`` falls back to analyzing a transposed
        sibling pattern — the generic non-symmetric path."""
        return None

    def default_method(self, A: SparseTensor) -> str:
        sym = A.props.get("symmetric", False)
        spd = A.props.get("spd_hint", False)
        return "cg" if (spd or sym) else "bicgstab"

    def analyze(self, cfg: SolverConfig, pattern) -> dict:
        return {}

    def setup(self, plan: "SolverPlan", A: SparseTensor):
        return None

    def solve(self, plan: "SolverPlan", state, A: SparseTensor, b, x0,
              cfg: SolverConfig):
        raise NotImplementedError


class DenseBackend(Backend):
    name = "dense"
    methods = ("lu", "cholesky")
    # setup is just the (vmappable) densification — memoizing it makes a
    # stacked-values batch densify once per stack instead of once per solve
    cache_setup = True

    def applicable(self, A):
        return A.shape[0] == A.shape[1]

    def default_method(self, A):
        return "cholesky" if A.props.get("spd_hint", False) else "lu"

    def setup(self, plan, A):
        return A.todense()

    def solve(self, plan, dense, A, b, x0, cfg):
        return _solvers.dense_solve(dense, b, cfg.method)


class DirectBackend(Backend):
    """Sparse direct LDLᵀ/LU with a cached symbolic factorization — the
    cuDSS-analogue path (paper §3.1/§3.2.3).  ``analyze`` runs the eager
    symbolic stage of :mod:`repro.core.direct` once per pattern; ``setup``
    is the jit/vmap-safe numeric refactorization (memoized per values array
    via ``cache_setup``); ``solve`` is two level-scheduled triangular sweeps.
    The adjoint reuses the forward factors: symmetric patterns share the plan
    outright, non-symmetric ones get a shared-artifact transpose plan whose
    solve runs the mirrored (Uᵀ, Lᵀ) sweeps — zero refactorizations either way.
    """
    name = "direct"
    methods = ("ldlt", "lu")
    cache_setup = True

    def applicable(self, A):
        n, m = A.shape
        if n != m:
            return False
        if isinstance(A.row, jax.core.Tracer) or \
                isinstance(A.col, jax.core.Tracer):
            return False        # symbolic analysis needs a concrete pattern
        if "struct_full_diag" not in A.props:
            A.props["struct_full_diag"] = has_full_diagonal(A.row, A.col, n)
        return A.props["struct_full_diag"]   # no pivoting: pivots must exist

    def default_method(self, A):
        return "ldlt" if A.props.get("symmetric", False) else "lu"

    def analyze(self, cfg, pattern):
        if cfg.method == "ldlt" and not pattern.props.get("symmetric", False):
            raise ValueError(
                "method='ldlt' needs symmetric values; use method='lu'")
        art = _direct.symbolic_factor(
            np.asarray(pattern.row), np.asarray(pattern.col),
            pattern.shape[0],
            # indefinite-hinted systems get static Bunch–Kaufman 2x2 pivot
            # blocks (chosen at analyze time) instead of relying on the
            # zero-pivot perturbation stopgap at factor time
            pivot_blocks=("auto" if pattern.props.get("indefinite_hint")
                          else None))
        return {"direct": art, "transposed": False}

    def setup(self, plan, A):
        PLAN_STATS["factorize"] += 1
        return _direct.numeric_factor(plan.artifacts["direct"], A.val)

    def solve(self, plan, C, A, b, x0, cfg):
        x = _direct.factored_solve(plan.artifacts["direct"], C, b,
                                   transposed=plan.artifacts["transposed"])
        r = b - coo_matvec(A.val, A.row, A.col, x, A.shape[0])
        rn = jnp.linalg.norm(r)
        target = jnp.maximum(cfg.tol * jnp.linalg.norm(b), cfg.atol)
        return x, _solvers.SolveInfo(iters=jnp.asarray(1), resnorm=rn,
                                     converged=rn <= target)

    def transpose_plan(self, plan):
        """Adjoint plan sharing THIS plan's symbolic artifacts and numeric
        factors (the setup memo is shared): solving Aᵀλ = g runs the Uᵀ/Lᵀ
        sweeps on the forward factorization."""
        tp = SolverPlan.__new__(SolverPlan)
        tp.cfg = plan.cfg
        tp.backend = plan.backend
        tp.row, tp.col = plan.col, plan.row
        tp.shape = (plan.shape[1], plan.shape[0])
        tp.props = dict(plan.props)
        tp.bell, tp.stencil = None, None
        tp._cache = {tp.cfg.plan_key(): tp}
        tp._tplan = plan
        tp._setup_memo = plan._setup_memo       # forward factors reused
        tp.artifacts = dict(plan.artifacts,
                            transposed=not plan.artifacts["transposed"])
        return tp


class IterativeBackend(Backend):
    """Shared machinery for Krylov backends: kernel matvec + preconditioner.

    ``cache_setup``: the preconditioner refresh (block inverses, Lanczos
    spectrum bounds, ILU refactorization, MG hierarchy) is memoized per
    values array exactly like the direct backend's numeric factorization —
    a tolerance sweep or the symmetric adjoint backward re-traces nothing
    (``PLAN_STATS['setup_reuse']``); new values still refresh.
    """
    kernel = "auto"             # kernel-plan preference (see _build_kernel_plan)
    methods = ("cg", "bicgstab", "gmres", "block_cg")
    cache_setup = True

    def analyze(self, cfg, pattern):
        return {
            "kernel": _build_kernel_plan(pattern, self.kernel),
            "precond": _precond.PreconditionerPlan(
                cfg.precond, pattern.row, pattern.col, pattern.shape,
                stencil=pattern.stencil)}

    def _matvec_from_val(self, plan, val) -> Callable:
        kp = plan.artifacts.get("kernel")
        if kp is not None:
            return _plan_matvec(plan, kp, val)
        # plan built without a kernel artifact: plan carries the same
        # row/col/bell/stencil attributes _kernel_fn reads off a tensor
        fn = _kernel_fn(plan, self.kernel)
        return lambda x: fn(val, x)

    def setup(self, plan, A):
        """Values-dependent setup as an ARRAYS-ONLY pytree.

        Returns ``(val, pstate, dinv)`` — the (possibly transpose-remapped)
        values, the preconditioner's refresh_state pytree (block inverses,
        spectrum bounds, MG/AMG hierarchy arrays, ILU factors), and the
        diagonal-inverse vector for the fused step kernels (None when the
        apply is not a diagonal scale).  No closures: a stacked batch of
        shared-pattern instances runs ONE ``jax.vmap`` of this method
        (:meth:`SolverPlan.setup_batch`) and the solve stage rebuilds the
        matvec/apply closures per lane.  The fuse decision itself stays a
        solve-time read of ``options.fused_step``."""
        mv = self._matvec_from_val(plan, A.val)
        pre = plan.artifacts["precond"]
        pstate = pre.refresh_state(A, mv)
        dinv = pre.fused_diag(A)
        return A.val, pstate, dinv

    def solve(self, plan, state, A, b, x0, cfg):
        val, pstate, dinv = state
        # rebuild from the STATE's values, not A.val: transpose plans remap
        # the forward values in setup (_StencilTransposeBackend) and batched
        # solves feed per-lane state slices
        mv = self._matvec_from_val(plan, val)
        kp = plan.artifacts.get("kernel")
        fuse = _fuse_enabled(kp)
        interp = kp.interpret if kp is not None else None
        M = plan.artifacts["precond"].make_apply(pstate, mv, fused=fuse,
                                                 interpret=interp)
        if cfg.method == "block_cg":
            single = b.ndim == 1
            B = b[None] if single else b
            X0 = None if x0 is None else (x0[None] if single else x0)
            X, info = _solvers.block_cg(mv, B, X0, M=M, tol=cfg.tol,
                                        atol=cfg.atol, maxiter=cfg.maxiter)
            if single:
                return X[0], _solvers.SolveInfo(info.iters, info.resnorm[0],
                                                info.converged[0])
            return X, info
        if cfg.method == "cg":
            if fuse:
                return _solvers.cg_fused(mv, b, x0, dinv=dinv, M=M,
                                         tol=cfg.tol, atol=cfg.atol,
                                         maxiter=cfg.maxiter,
                                         interpret=interp)
            return _solvers.cg(mv, b, x0, M=M, tol=cfg.tol, atol=cfg.atol,
                               maxiter=cfg.maxiter)
        if cfg.method == "bicgstab":
            if fuse:
                return _solvers.bicgstab_fused(mv, b, x0, dinv=dinv, M=M,
                                               tol=cfg.tol, atol=cfg.atol,
                                               maxiter=cfg.maxiter,
                                               interpret=interp)
            return _solvers.bicgstab(mv, b, x0, M=M, tol=cfg.tol,
                                     atol=cfg.atol, maxiter=cfg.maxiter)
        if cfg.method == "gmres":
            return _solvers.gmres(mv, b, x0, M=M, tol=cfg.tol, atol=cfg.atol,
                                  restart=cfg.restart,
                                  maxiter=max(cfg.maxiter // cfg.restart, 1))
        raise ValueError(
            f"unknown method {cfg.method!r} for backend {cfg.backend!r}")

    def transpose_plan(self, plan):
        """Adjoint plan sharing THIS plan's kernel layouts: the kernel plan
        built Aᵀ's block-ELL slot table in the same analyze pass (``t_bell``),
        so the backward matvec hits the same Pallas kernel with zero
        re-analysis.  Only for plans that adopted BELL — COO-choice plans
        have no layout to share and fall back to the generic transposed
        sibling; ``mg`` needs the stencil view the sibling would drop."""
        kp = plan.artifacts.get("kernel")
        if kp is None or kp.choice != "bell" or kp.t_bell is None:
            return None
        n, m = plan.shape
        if n != m or plan.cfg.precond == "mg":
            return None
        tp = SolverPlan.__new__(SolverPlan)
        tp.cfg = plan.cfg
        tp.backend = plan.backend
        tp.row, tp.col = plan.col, plan.row
        tp.shape = (m, n)
        tp.props = dict(plan.props)
        tp.bell, tp.stencil = kp.t_bell, None
        tp._cache = {tp.cfg.plan_key(): tp}
        tp._tplan = plan
        tp._setup_memo = {}      # Aᵀ preconditioner state differs
        with jax.ensure_compile_time_eval():
            tp.artifacts = {
                "kernel": dataclasses.replace(kp, bell=kp.t_bell,
                                              t_bell=kp.bell),
                "precond": _precond.PreconditionerPlan(
                    plan.cfg.precond, tp.row, tp.col, tp.shape,
                    stencil=None)}
        return tp


class JnpBackend(IterativeBackend):
    """General COO backend.  Its kernel plan is "auto": segment-sum on
    interpret-mode platforms (CPU) and for low-fill patterns, block-ELL
    Pallas where the conversion pays off on compiled hardware."""
    name = "jnp"
    kernel = "auto"


class PallasBackend(IterativeBackend):
    """Explicit block-ELL opt-in: the kernel plan adopts BELL regardless of
    fill or platform (interpret mode included — parity tests run here)."""
    name = "pallas"
    kernel = "bell"

    def applicable(self, A):
        # a construction-time layout OR a concrete pattern the kernel plan
        # can convert at analyze time
        return A.bell is not None or not isinstance(A.row, jax.core.Tracer)


class StencilBackend(IterativeBackend):
    name = "stencil"
    kernel = "stencil"
    methods = ("cg", "bicgstab")

    def applicable(self, A):
        return A.stencil is not None

    def transpose_plan(self, plan):
        """Adjoint plan that KEEPS the fast stencil kernel (no COO fallback):
        Aᵀ of a 5-point stencil operator is the same operator with its
        coupling planes exchanged and shifted (N'↔S, W'↔E, values taken from
        the neighbour's opposing slot).  The shift is a pure gather frozen at
        analyze time (``tmap``, with a zero slot for the domain boundary);
        the transpose plan's setup maps the FORWARD values through it and
        then runs the ordinary stencil setup — the same kernel, the same
        preconditioner machinery (``precond='mg'`` included), zero
        re-analysis."""
        meta = plan.stencil
        if meta is None or meta.nx != meta.ny:
            return None
        ng = meta.nx
        if plan.shape != (ng * ng, ng * ng):
            return None
        idx = np.arange(5 * ng * ng).reshape(5, ng, ng)
        zslot = 5 * ng * ng
        tmap = np.empty_like(idx)
        tmap[0] = idx[0]                       # C' = C
        # plane order (C, N, S, W, E), N = coupling to (x-1, y):
        # Aᵀ[i, i_north] = A[i_north, i] = S-plane at the north neighbour
        tmap[1, 1:, :] = idx[2, :-1, :]
        tmap[1, 0, :] = zslot
        tmap[2, :-1, :] = idx[1, 1:, :]        # S' from N shifted up
        tmap[2, -1, :] = zslot
        tmap[3, :, 1:] = idx[4, :, :-1]        # W' from E shifted right
        tmap[3, :, 0] = zslot
        tmap[4, :, :-1] = idx[3, :, 1:]        # E' from W shifted left
        tmap[4, :, -1] = zslot

        tp = SolverPlan.__new__(SolverPlan)
        tp.cfg = plan.cfg
        tp.backend = _STENCIL_T
        # the transposed operator in PLANE layout shares the forward's
        # pattern arrays (vc_pattern of the same grid): values are remapped,
        # indices are not — COO and stencil views stay consistent
        tp.row, tp.col = plan.row, plan.col
        tp.shape = plan.shape
        tp.props = dict(plan.props)
        tp.bell, tp.stencil = None, plan.stencil
        tp._cache = {tp.cfg.plan_key(): tp}
        tp._tplan = plan
        tp._setup_memo = {}        # Aᵀ values differ from the forward values
        with jax.ensure_compile_time_eval():
            tp.artifacts = {
                "tmap": jnp.asarray(tmap.reshape(-1), jnp.int32),
                "kernel": _build_kernel_plan(tp, "stencil"),
                "precond": _precond.PreconditionerPlan(
                    plan.cfg.precond, plan.row, plan.col, plan.shape,
                    stencil=plan.stencil)}
        return tp


class _StencilTransposeBackend(StencilBackend):
    """Internal backend of the stencil transpose plan: identical solve path,
    but setup first remaps the forward values into transposed planes."""
    name = "stencil"            # reported name matches the forward backend

    def setup(self, plan, A):
        padded = jnp.concatenate([A.val, jnp.zeros((1,), A.val.dtype)])
        return super().setup(plan, plan.matrix(padded[plan.artifacts["tmap"]]))


_STENCIL_T = _StencilTransposeBackend()


class DistBackend(Backend):
    """Distributed mesh backend (paper §3.3) — ``DSparseTensor`` as a
    first-class citizen of the plan engine.

    ``analyze`` runs ONCE per (global pattern, mesh, partition) and freezes
    everything eager: partition bounds, the halo program (axis size +
    ppermute perms), the Aᵀ partition for non-symmetric adjoints
    (``PLAN_STATS['t_partition']``), and a
    :class:`~repro.core.precond.DistPreconditionerPlan` (``jacobi`` or
    shard-local overlapping-Schwarz ``schwarz`` sharing the direct
    machinery's ILU(0)/IC(0) programs).  ``setup`` is the traced-safe
    preconditioner refresh on the stacked values, memoized per values array
    (``cache_setup``); ``solve`` is the shard_map'd Krylov loop.  The heavy
    lifting lives in :mod:`repro.core.distributed` (imported lazily: that
    module imports this registry at module level, so the cycle must break
    here — and plain single-device use never loads the mesh machinery)."""
    name = "dist"
    methods = ("cg", "bicgstab", "pipelined_cg")
    handles_batch = True        # (P, n_loc) stacking is sharding, not batch
    cache_setup = True

    def applicable(self, A):
        return getattr(A, "mesh", None) is not None

    def default_method(self, A):
        return "cg" if A.props.get("symmetric", False) else "bicgstab"

    def analyze(self, cfg, pattern):
        from . import distributed as _dist
        return _dist.dist_analyze(cfg, pattern)

    def setup(self, plan, A):
        from . import distributed as _dist
        return _dist.dist_setup(plan, A)

    def solve(self, plan, state, A, b, x0, cfg):
        from . import distributed as _dist
        return _dist.dist_solve(plan, state, A, b, x0, cfg)

    def transpose_plan(self, plan):
        from . import distributed as _dist
        return _dist.dist_transpose_plan(plan)


class _FnBackend(Backend):
    """Adapter for legacy ``register_backend(name, solve_fn, applicable)``."""
    handles_batch = True

    def __init__(self, name, solve_fn, applicable):
        self.name = name
        self._solve_fn = solve_fn
        self._applicable = applicable

    def applicable(self, A):
        return self._applicable(A)

    def solve(self, plan, state, A, b, x0, cfg):
        return self._solve_fn(cfg, A, b, x0)


BACKENDS: Dict[str, Backend] = {
    b.name: b for b in (DenseBackend(), DirectBackend(), JnpBackend(),
                        PallasBackend(), StencilBackend(), DistBackend())}


def register_backend(name: str, solve_fn: Optional[Callable] = None,
                     applicable: Optional[Callable] = None, *,
                     backend: Optional[Backend] = None):
    """Register a backend: either a :class:`Backend` instance (``backend=``)
    or the legacy ``(solve_fn, applicable)`` function pair."""
    if backend is not None:
        backend.name = name
        BACKENDS[name] = backend
    else:
        BACKENDS[name] = _FnBackend(name, solve_fn,
                                    applicable or (lambda A: True))


def select_backend(A: SparseTensor, backend: str, method: str):
    """Device- and size-aware auto-dispatch (paper §3.1 rules, TPU constants):
    (i) honor explicit overrides; (ii) dense-direct below the dense budget;
    (iii) sparse-direct (cached symbolic factorization) for mid-size systems
    and whenever the caller hints ill-conditioning (Krylov stalls there);
    (iv) iterative above, preferring the Pallas/stencil SpMV when the tensor
    carries that layout; CG when SPD-ish, BiCGStab otherwise."""
    n = A.shape[0]
    platform = jax.default_backend()
    opts = _options.current()
    if backend == "auto":
        if A.stencil is not None:
            backend = "stencil"
        elif n <= opts.dense_budget and not A.batch_shape and \
                BACKENDS["dense"].applicable(A):
            backend = "dense"
        elif A.props.get("illcond_hint", False) \
                and n <= 4 * opts.direct_budget \
                and BACKENDS["direct"].applicable(A):
            # the hint is an explicit opt-in, so it buys a wider direct
            # window — the caller accepts the one-time (minutes-scale at the
            # ceiling) symbolic analysis over a stalling Krylov solve
            backend = "direct"
        elif A.bell is not None and platform == "tpu":
            backend = "pallas"
        elif n <= opts.direct_budget and BACKENDS["direct"].applicable(A):
            backend = "direct"
        else:
            backend = "jnp"
    if method == "auto":
        method = BACKENDS[backend].default_method(A) \
            if backend in BACKENDS else "cg"
    return backend, method


def make_config(A: SparseTensor, *, backend=None, method=None, tol=1e-6,
                atol=0.0, maxiter=None, precond="jacobi", restart=32) -> SolverConfig:
    cfg = SolverConfig(backend=backend or "auto", method=method or "auto",
                       tol=tol, atol=atol,
                       maxiter=maxiter or DEFAULT_MAXITER,
                       precond=precond, restart=restart)
    return cfg.resolved(A)


# ---------------------------------------------------------------------------
# SolverPlan — the analyze(pattern) product
# ---------------------------------------------------------------------------

class SolverPlan:
    """Reusable symbolic setup for one (sparsity pattern, SolverConfig).

    Holds only pattern-level state — row/col indices, shape, detected
    properties, kernel layouts, and the backend's analyze artifacts — never
    values, so one plan serves every ``with_values`` refresh, every element
    of a shared-pattern batch, and the adjoint solve of ``jax.grad``.

    Mesh-aware: for distributed tensors the plan additionally freezes the
    ``Mesh`` and ``DistMeta`` (``mesh``/``dmeta``) so the ``dist`` backend's
    stages never re-derive partition state; single-device plans carry None.
    """

    mesh = None          # jax.sharding.Mesh for dist-backed plans
    dmeta = None         # repro.core.distributed.DistMeta for dist plans

    def __init__(self, cfg: SolverConfig, A: SparseTensor,
                 cache: Optional[dict] = None):
        if cfg.backend not in BACKENDS:
            raise ValueError(f"unknown backend {cfg.backend!r}")
        self.cfg = cfg              # first-seen config; solve-loop knobs
        self.backend = BACKENDS[cfg.backend]   # (tol/maxiter) may be overridden per call
        if self.backend.methods and cfg.method not in self.backend.methods:
            raise ValueError(
                f"method {cfg.method!r} not supported by backend "
                f"{cfg.backend!r} (supported: {self.backend.methods})")
        self.row, self.col = A.row, A.col
        self.shape = tuple(A.shape)
        self.props = dict(A.props)
        self.bell = A.bell
        self.stencil = A.stencil
        self.mesh = getattr(A, "mesh", None)
        self.dmeta = getattr(A, "meta", None)
        self._cache = cache if cache is not None else {cfg.plan_key(): self}
        self._tplan: Optional["SolverPlan"] = None
        self._setup_memo: dict = {}
        PLAN_STATS["analyze"] += 1
        # analyze is eager BY CONTRACT: plans outlive any single trace, so
        # artifact arrays built here must be concrete even when the first
        # solve happens inside jit/grad — a traced constant stored on the
        # plan would leak into (and break) every later trace
        with jax.ensure_compile_time_eval():
            self.artifacts = self.backend.analyze(cfg, self)

    # -- stage ❷: values-dependent setup (traced-safe) ----------------------
    def _memo_lookup(self, slot: str, key_array):
        """Per-values-array memo hit: identity of the array is the key."""
        hit = self._setup_memo.get(slot)
        if hit is not None and hit[0]() is key_array:
            PLAN_STATS["setup_reuse"] += 1
            return hit[1]
        return None

    def _memo_store(self, slot: str, key_array, state) -> None:
        # memo-poisoning guard: when a CONCRETE values array is set up
        # inside a staging trace (a jitted solve closing over the matrix),
        # the state embeds tracers — possibly hidden inside matvec or
        # preconditioner closures, invisible to any leaf inspection — and
        # storing it would leak them into the next eager solve.  The probe
        # asks the ambient trace directly: does an op on a fresh constant
        # come back traced?  (Eager jax.grad says no — its fwd runs ops on
        # concrete primals concretely, so that state stays cacheable.)
        staging = isinstance(jnp.zeros(()) + 0.0, jax.core.Tracer)
        if staging and not isinstance(key_array, jax.core.Tracer):
            return
        memo = self._setup_memo
        box = {}

        def _drop(_, m=memo, b=box, s=slot):
            # evict ONLY our own entry: a dead values array must not pop
            # a successor that already replaced it (the old entry's ref
            # can die between the successor's fwd store and bwd lookup)
            if m.get(s) is b.get("entry"):
                m.pop(s, None)

        box["entry"] = (weakref.ref(key_array, _drop), state)
        memo[slot] = box["entry"]

    def setup(self, A: SparseTensor):
        """Run (or reuse) the backend's values-dependent setup.

        Backends with ``cache_setup`` (the direct backend's numeric
        factorization, the iterative preconditioner refresh, the distributed
        backend) memoize the state per values *array*: a tolerance sweep, a
        continuation loop, and the adjoint backward all reuse ONE setup —
        identity of ``A.val`` is the key, which holds across custom_vjp
        forward/backward in both eager and jit traces.  The memo is
        single-slot per kind (latest values win), shared with the transpose
        plan where that is sound (direct: Aᵀ solves never refactorize), and
        holds the values array weakly: a dead array can never produce a hit,
        so a stale entry is harmless.  The weak eviction only actually fires
        when the state does not itself capture the values array; setup
        states are array pytrees that keep the LATEST values array (or trace
        tracer) alive per plan until the next setup replaces it — a bounded,
        single-slot residency."""
        if self.backend.cache_setup:
            hit = self._memo_lookup("state", A.val)
            if hit is not None:
                return hit
        PLAN_STATS["setup"] += 1
        state = self.backend.setup(self, A)
        if self.backend.cache_setup:
            self._memo_store("state", A.val, state)
        return state

    def setup_batch(self, A: SparseTensor):
        """Batched setup over stacked values — ONE vmapped trace, memoized.

        ``A.val`` carries leading batch dims ``(..., nnz)`` sharing this
        plan's pattern.  The per-values memo is batch-aware: it keys on the
        STACKED array's identity (slot ``"batch_state"``), so a tolerance
        sweep or the adjoint backward over the same batch reuses one setup,
        and ``PLAN_STATS["setup"]`` counts one setup for the whole batch.
        The backend's per-instance setup runs under ``jax.vmap`` directly —
        numeric factorizations, block inverses, Galerkin products, and MG
        hierarchies all batch through their array-only state pytrees."""
        val = A.val
        if self.backend.cache_setup:
            hit = self._memo_lookup("batch_state", val)
            if hit is not None:
                return hit
        PLAN_STATS["setup"] += 1
        flat = val.reshape((-1, val.shape[-1]))
        state = jax.vmap(
            lambda v: self.backend.setup(self, self.matrix(v)))(flat)
        if self.backend.cache_setup:
            self._memo_store("batch_state", val, state)
        return state

    # -- stage ❸: solve ------------------------------------------------------
    def solve_single(self, A: SparseTensor, b, x0=None, state=None,
                     cfg: Optional[SolverConfig] = None):
        cfg = cfg if cfg is not None else self.cfg
        state = self.setup(A) if state is None else state
        return self.backend.solve(self, state, A, b, x0, cfg)

    def solve(self, A: SparseTensor, b, x0=None,
              cfg: Optional[SolverConfig] = None):
        """One un-differentiated solve; shared-pattern batches are vmapped
        here so the adjoint layer never needs to care.  ``cfg`` overrides the
        solve-loop knobs (tol/atol/maxiter/restart) without re-analyzing."""
        cfg = cfg if cfg is not None else self.cfg
        if self.backend.handles_batch:
            return self.backend.solve(self, self.setup(A), A, b, x0, cfg)
        batch = jnp.broadcast_shapes(A.batch_shape, b.shape[:-1])
        if batch and not A.batch_shape:
            # multi-rhs on ONE matrix: a single setup (one factorization /
            # preconditioner build) serves every right-hand side.
            state = self.setup(A)
            fb = b.reshape((-1, b.shape[-1]))
            if cfg.method == "block_cg":
                # the whole (k, n) block goes down in ONE coupled solve —
                # k matvecs per iteration as one batched sweep, Krylov
                # directions shared across right-hand sides
                fx0 = None if x0 is None else jnp.broadcast_to(
                    x0, batch + x0.shape[-1:]).reshape(fb.shape)
                xs, infos = self.backend.solve(self, state, A, fb, fx0, cfg)
                return xs.reshape(batch + (b.shape[-1],)), infos

            def one(rhs, xx0=None):
                return self.backend.solve(self, state, A, rhs, xx0, cfg)

            if x0 is None:
                xs, infos = jax.vmap(lambda rhs: one(rhs))(fb)
            else:
                fx0 = jnp.broadcast_to(x0, batch + x0.shape[-1:]).reshape(fb.shape)
                xs, infos = jax.vmap(one)(fb, fx0)
            return xs.reshape(batch + (b.shape[-1],)), infos
        if batch:
            val = jnp.broadcast_to(A.val, batch + A.val.shape[-1:])
            bb = jnp.broadcast_to(b, batch + b.shape[-1:])
            fv = val.reshape((-1, val.shape[-1]))
            fb = bb.reshape((-1, bb.shape[-1]))
            fx0 = None if x0 is None else jnp.broadcast_to(
                x0, batch + x0.shape[-1:]).reshape(fb.shape)
            if self.backend.cache_setup:
                # batched values: ONE vmapped setup over the stack (memoized
                # on the stacked array — see setup_batch), then a vmapped
                # solve over per-lane state slices.  Setup never re-runs
                # inside the solve vmap, so a batch costs one traced
                # factorization/preconditioner build, not B of them.
                Ab = A if A.val.ndim > 1 and A.val.shape[:-1] == batch \
                    else self.matrix(fv)
                states = self.setup_batch(Ab)

                def one(st, v, rhs, xx0=None):
                    return self.backend.solve(self, st, self.matrix(v), rhs,
                                              xx0, cfg)

                if fx0 is None:
                    xs, infos = jax.vmap(
                        lambda st, v, rhs: one(st, v, rhs))(states, fv, fb)
                else:
                    xs, infos = jax.vmap(one)(states, fv, fb, fx0)
            else:
                def one_nostate(v, rhs, xx0=None):
                    return self.solve_single(self.matrix(v), rhs, xx0,
                                             cfg=cfg)

                if fx0 is None:
                    xs, infos = jax.vmap(
                        lambda v, rhs: one_nostate(v, rhs))(fv, fb)
                else:
                    xs, infos = jax.vmap(one_nostate)(fv, fb, fx0)
            return xs.reshape(batch + (b.shape[-1],)), infos
        return self.solve_single(A, b, x0, cfg=cfg)

    # -- pattern helpers -----------------------------------------------------
    def nbytes(self) -> int:
        """Estimated resident bytes of this plan's analyze artifacts — BELL
        slot tables, direct/ILU symbolic programs, AMG index programs, plus
        the pattern arrays they reference.  This is the size the
        :class:`PlanCache` byte budget (``options.plan_cache_bytes``) counts
        against; an estimate (arrays shared between plans are counted in
        each), not an allocator measurement."""
        seen = set()
        total = 0

        def visit(obj):
            nonlocal total
            if obj is None or isinstance(obj, (int, float, bool, str, bytes,
                                               complex)):
                return
            if id(obj) in seen:
                return
            seen.add(id(obj))
            nb = getattr(obj, "nbytes", None)
            if isinstance(nb, (int, np.integer)):
                total += int(nb)
                return
            if isinstance(obj, dict):
                for v in obj.values():
                    visit(v)
            elif isinstance(obj, (tuple, list)):
                for v in obj:
                    visit(v)
            elif dataclasses.is_dataclass(obj):
                for f in dataclasses.fields(obj):
                    visit(getattr(obj, f.name))
            elif hasattr(obj, "__dict__"):
                for v in vars(obj).values():
                    visit(v)

        try:
            visit(self.artifacts)
            visit(self.bell)
            visit((self.row, self.col))
        except Exception:
            pass
        return total

    def matrix(self, val) -> SparseTensor:
        """SparseTensor view of this plan's pattern carrying ``val`` —
        shares the plan cache, so nested solves hit this plan."""
        obj = SparseTensor.__new__(SparseTensor)
        obj.val = val
        obj.row, obj.col = self.row, self.col
        obj.shape = self.shape
        obj.props = dict(self.props)
        obj.bell, obj.stencil = self.bell, self.stencil
        obj._plans = self._cache
        return obj

    def transpose(self) -> "SolverPlan":
        """Plan for the adjoint system Aᵀλ = g (paper §3.2.3).

        Symmetric pattern → the SAME plan (layouts + preconditioner build
        shared).  A backend may instead derive the adjoint plan from its own
        artifacts (``Backend.transpose_plan`` — the direct backend shares its
        symbolic factorization AND numeric factors, swapping the triangular
        sweeps).  Otherwise a transposed sibling is analyzed once and cached
        here; its block-ELL layout is rebuilt eagerly when the pattern is
        concrete, and the stencil kernel (whose values encode A, not Aᵀ) is
        dropped in favour of the COO path — matching the forward numerics.
        """
        if self._tplan is not None:
            return self._tplan
        n, m = self.shape
        if n == m and self.props.get("symmetric", False):
            PLAN_STATS["transpose_shared"] += 1
            self._tplan = self
            return self
        tp = self.backend.transpose_plan(self)
        if tp is not None:
            PLAN_STATS["transpose_shared"] += 1
            self._tplan = tp
            return tp

        tbell = None
        if self.bell is not None and not isinstance(self.row, jax.core.Tracer):
            tbell = build_bell(self.col, self.row, (m, n))
        tcfg = self.cfg
        if tcfg.backend == "stencil" or (tcfg.backend == "pallas" and
                                         tbell is None):
            tcfg = dataclasses.replace(tcfg, backend="jnp")
            if tcfg.precond == "mg":   # V-cycle needs the dropped stencil view
                tcfg = dataclasses.replace(tcfg, precond="jacobi")
        At = SparseTensor.__new__(SparseTensor)
        At.val = None
        At.row, At.col = self.col, self.row
        At.shape = (m, n)
        At.props = dict(self.props)
        At.bell, At.stencil = tbell, None
        At._plans = {}
        tplan = SolverPlan(tcfg, At, cache=At._plans)
        At._plans[tcfg.plan_key()] = tplan
        tplan._tplan = self       # (Aᵀ)ᵀ = A
        self._tplan = tplan
        return tplan

    def adapt(self, cfg: SolverConfig) -> SolverConfig:
        """Project a caller's config onto this plan's analyze-stage choices
        (backend/method/precond), keeping the caller's solve-loop knobs —
        used by the adjoint so tol/maxiter follow the forward request even
        when the transpose plan rewrote the backend."""
        return dataclasses.replace(cfg, backend=self.cfg.backend,
                                   method=self.cfg.method,
                                   precond=self.cfg.precond)


def get_plan(A: SparseTensor, cfg: Optional[SolverConfig] = None,
             **kw) -> SolverPlan:
    """Fetch (or analyze-and-cache) the plan for ``A``'s pattern + ``cfg``.

    The cache lives on the SparseTensor and is SHARED by ``with_values``
    views, so repeated solves on one pattern — including inside jit/grad —
    analyze exactly once."""
    if cfg is None:
        cfg = make_config(A, **kw)
    elif cfg.backend in (None, "auto") or cfg.method in (None, "auto"):
        cfg = cfg.resolved(A)
    cache = getattr(A, "_plans", None)
    if cache is None:
        cache = PlanCache()
        try:
            A._plans = cache
        except AttributeError:
            pass
    extra = getattr(A, "plan_key_extra", None)
    key = cfg.plan_key() + (tuple(extra()) if extra is not None else ())
    plan = cache.get(key)
    if plan is not None:
        PLAN_STATS["cache_hit"] += 1
        return plan
    PLAN_STATS["cache_miss"] += 1
    plan = SolverPlan(cfg, A, cache=cache)
    cache[key] = plan
    return plan


# ---------------------------------------------------------------------------
# legacy free-function API (kept for callers/benchmarks; plan-backed now)
# ---------------------------------------------------------------------------

def solve_impl(cfg: SolverConfig, A: SparseTensor, b: jax.Array,
               x0: Optional[jax.Array] = None):
    """One un-differentiated solve through the cached plan."""
    return get_plan(A, cfg).solve(A, b, x0, cfg=cfg)


# ---------------------------------------------------------------------------
# deprecated knob aliases — the pre-options module globals
# ---------------------------------------------------------------------------

_DEPRECATED_GLOBALS = {
    "FUSED_STEP": "fused_step",
    "DENSE_BUDGET": "dense_budget",
    "DIRECT_BUDGET": "direct_budget",
    "BELL_MIN_FILL": "bell_min_fill",
    "PLAN_CACHE_CAP": "plan_cache_cap",
    "PLAN_CACHE_BYTES": "plan_cache_bytes",
}


def __getattr__(name: str):
    """PEP 562 read alias: ``dispatch.FUSED_STEP`` etc. forward to the active
    :class:`repro.core.options.Options`, warning once per name."""
    field = _DEPRECATED_GLOBALS.get(name)
    if field is not None:
        _options.warn_deprecated_alias(name, field)
        return getattr(_options.current(), field)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


class _DeprecatedGlobalsModule(types.ModuleType):
    """Write alias: PEP 562 covers reads only, so assignment to the legacy
    globals (``dispatch.FUSED_STEP = "on"``) is intercepted by swapping the
    module's class — the write warns once and forwards to ``set_options``,
    keeping old scripts working without reintroducing mutable globals."""

    def __setattr__(self, name, value):
        field = _DEPRECATED_GLOBALS.get(name)
        if field is not None:
            _options.warn_deprecated_alias(name, field)
            _options.set_options(**{field: value})
            return
        super().__setattr__(name, value)


sys.modules[__name__].__class__ = _DeprecatedGlobalsModule
