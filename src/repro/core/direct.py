"""Sparse direct factorization backend — the cuDSS analogue (paper §3.1/§3.2.3).

The paper's headline backend is a *direct* sparse solver whose symbolic
factorization is computed once per sparsity pattern and reused across numeric
refactorizations and adjoint solves.  This module is that path for the plan
engine, entirely in JAX:

``symbolic_factor(row, col, n)``  — eager, numpy, values-free (the plan's
``analyze`` stage).  Three sub-stages, none of which ever forms the filled
graph explicitly:

1. **Ordering** — approximate minimum degree on a *quotient graph*
   (Amestoy/Davis/Duff style: element absorption, hash-based supervariable
   detection, aggressive absorption, mass elimination) is the default
   (``ordering="amd"``); the exact-minimum-degree elimination is retained as
   ``ordering="md"`` for A/B comparisons, plus ``"rcm"`` and ``"natural"``.
2. **Etree symbolic pass** — the elimination tree of the ordered pattern is
   built with Liu's algorithm, and the static fill pattern of L (and its
   mirror U) plus per-column fill counts fall out of one row-subtree
   traversal (marker-pruned: each path is walked once per fresh L entry, so
   the pass is O(nnz(L)) — no clique formation, no set algebra).
3. **Program emission** — a longest-path *level schedule* of the elimination
   DAG and a **packed step program**: every level's work is cut into
   fixed-width steps (finalize entries, rank-1 update tuples, sweep entries,
   pivot divides) so the numeric kernels are single ``lax.scan`` loops over
   uniform index tensors.  Emission is vectorized prefix-sum/cummax
   placement (no Python per-tuple loops).  One small compiled body serves
   every level, every ``with_values`` refresh, every batch element, and the
   adjoint.

``numeric_factor(art, val)``      — traced-safe (the ``setup`` stage).  Runs
the numeric LU/LDLᵀ over the precomputed fill pattern: per scan step, one
fused pivot-divide + scatter-update pair.  Jits, vmaps over batched values,
and re-traces nothing symbolic.

``factored_solve(art, C, b)``     — two level-scheduled triangular sweeps
(the ``solve`` stage).  ``transposed=True`` swaps the sweeps (Uᵀ then Lᵀ),
which is how the adjoint solves Aᵀλ = g on the FORWARD factors — LDLᵀ is
self-adjoint, LU just runs the mirrored sweeps — zero refactorizations.

Storage layout of the factor vector ``C`` (length ``nnzF + 2``)::

    C[0:n]              pivots  U[k,k]              (permuted order)
    C[n:n+nnzL]         L entries, column-major     (unit diagonal implicit)
    C[n+nnzL:nnzF]      U entries, mirror-aligned   (U[j,k] at mirror of L[k,j])
    C[nnzF]             scratch 0  (padding sink for scatter/gather)
    C[nnzF+1]           scratch 1  (padding divisor — keeps pads NaN-free)

For symmetric values (method ``ldlt``) the same kernel computes U = D·Lᵀ in
the mirror half, i.e. an LDLᵀ factorization with D folded into U; the solve
and adjoint exploit self-adjointness through the plan layer.  No numerical
pivoting is performed — intended for SPD / diagonally-dominant systems
(pivoting for indefinite systems is a ROADMAP follow-up).

``incomplete=True`` restricts the update program to the original pattern
(zero fill): that is ILU(0)/IC(0), which :mod:`repro.core.precond` exposes as
``precond="ilu"`` sharing this exact machinery.

Consumers of :func:`symbolic_factor`, all paying the analyze cost once per
pattern: ``backend="direct"`` solves, ``precond="ilu"``, the AMG coarsest
level (:mod:`repro.core.multigrid`), the ``schwarz``/``schwarz2`` subdomain
and coarse factors (:mod:`repro.core.distributed`), and ``slogdet``.  The
auto-dispatch policy prefers the direct backend up to
the ``direct_budget`` option (:mod:`repro.core.options`; raised to 24576 by the AMD + etree
pipeline; ~7–8 s one-time analyze at that ceiling, amortized across the
plan's lifetime) and 4× that under ``props["illcond_hint"]``.
"""
from __future__ import annotations

from typing import List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

__all__ = [
    "DirectArtifacts", "symbolic_factor", "numeric_factor", "factored_solve",
    "SchwarzArtifacts", "schwarz_symbolic", "schwarz_numeric",
]


class PackedFactor(NamedTuple):
    """Step program for the numeric factorization: all arrays are (S, width)
    int32.  Per step: ``C[fin_lpos] /= C[fin_piv]`` (column finalize), then
    ``C[up_dst] -= C[up_s1] * C[up_s2]`` (right-looking updates).  Pads point
    at the scratch slots, so they are exact no-ops."""
    fin_lpos: jax.Array
    fin_piv: jax.Array
    up_s1: jax.Array
    up_s2: jax.Array
    up_dst: jax.Array


class PackedSweep(NamedTuple):
    """Step program for one triangular-sweep direction ((S, width) int32).

    ``row`` program (levels leaf→root): forward-L (use ``lpos``) and
    transposed-Uᵀ (use ``upos`` + divides).  ``col`` program (root→leaf):
    backward-U (``upos`` + divides) and transposed-Lᵀ (``lpos``).  Per step:
    ``y[tgt] -= C[pos] * y[src]`` then optionally ``y[dn] /= C[dpiv]``.
    The solution vector carries one scratch element at index n for pads.
    """
    tgt: jax.Array
    src: jax.Array
    lpos: jax.Array
    upos: jax.Array
    dn: jax.Array
    dpiv: jax.Array


class DirectArtifacts(NamedTuple):
    """Product of the symbolic analysis — pattern-only, shared by every
    ``with_values`` refresh, every batch element, and the adjoint."""
    n: int
    nnzF: int
    perm: jax.Array          # perm[k] = original index eliminated at step k
    ipos: jax.Array          # ipos[v] = elimination position of index v
    a2f: jax.Array           # COO entry e -> position in C (scatter-add)
    factor: PackedFactor
    row_sweep: PackedSweep
    col_sweep: PackedSweep
    stats: dict              # nnz_L, fill_ratio, n_levels, flops, n_steps


# ---------------------------------------------------------------------------
# symbolic analysis (eager / numpy — the analyze stage, once per pattern)
# ---------------------------------------------------------------------------

def _sym_lower_csr(row: np.ndarray, col: np.ndarray, n: int,
                   ipos: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """CSR of the *strict lower triangle* of the permuted, symmetrized
    pattern: returns ``(rptr, rcol)`` — for permuted row ``i``, the sorted
    permuted indices ``j < i`` with ``A(perm[i], perm[j]) != 0`` (either
    triangle).  Duplicates collapse; the diagonal is dropped."""
    mask = row != col
    pi = ipos[row[mask]]
    pj = ipos[col[mask]]
    hi = np.maximum(pi, pj)
    lo = np.minimum(pi, pj)
    keys = np.unique(hi * np.int64(n) + lo)
    ri = keys // n
    rj = keys % n
    rptr = np.searchsorted(ri, np.arange(n + 1, dtype=np.int64))
    return rptr, rj


def _rcm_order(row: np.ndarray, col: np.ndarray, n: int) -> np.ndarray:
    try:
        import scipy.sparse as sp
        from scipy.sparse.csgraph import reverse_cuthill_mckee
    except Exception:                       # scipy absent — degrade gracefully
        return np.arange(n, dtype=np.int64)
    G = sp.csr_matrix((np.ones(len(row)), (row, col)), shape=(n, n))
    return np.asarray(reverse_cuthill_mckee(G, symmetric_mode=False),
                      dtype=np.int64)


def _sym_adj_sets(row: np.ndarray, col: np.ndarray, n: int) -> List[set]:
    """Per-vertex neighbour sets of the symmetrized pattern graph (no self
    loops, duplicates collapsed) — the shared starting point of both
    degree-based orderings."""
    mask = row != col
    rr = np.concatenate([row[mask], col[mask]])
    cc = np.concatenate([col[mask], row[mask]])
    key = np.unique(rr * np.int64(n) + cc)
    ai = (key // n).astype(np.int64)
    aj = (key % n).astype(np.int64)
    ptr = np.searchsorted(ai, np.arange(n + 1, dtype=np.int64))
    return [set(aj[ptr[v]:ptr[v + 1]].tolist()) for v in range(n)]


def _exact_md_order(row: np.ndarray, col: np.ndarray, n: int) -> np.ndarray:
    """Exact minimum degree: full graph elimination with clique formation,
    selecting the minimum *remaining* degree each step.  O(fill) set algebra
    per pivot — the quality yardstick ``ordering="amd"`` is measured against
    (tests assert AMD fill-in stays within 25%), not the production path."""
    adj = _sym_adj_sets(row, col, n)
    INF = np.int64(1) << np.int64(60)
    deg = np.array([len(a) for a in adj], dtype=np.int64)
    perm = np.empty(n, dtype=np.int64)
    for k in range(n):
        v = int(np.argmin(deg))
        perm[k] = v
        deg[v] = INF
        nb = adj[v]
        for u in nb:
            adj[u].discard(v)
        for u in nb:
            au = adj[u]
            au |= nb
            au.discard(u)
            deg[u] = len(au)
        adj[v] = set()
    return perm


def _amd_order(row: np.ndarray, col: np.ndarray, n: int, *,
               aggressive: bool = True) -> np.ndarray:
    """Approximate minimum degree on a quotient graph (Amestoy/Davis/Duff).

    Instead of forming the clique of each eliminated vertex (the O(fill)
    step that makes exact MD quadratic-ish in practice), the eliminated
    pivot becomes an *element* whose boundary list represents the clique
    implicitly.  Per pivot:

    - the pivot structure ``Lp`` is the union of its variable neighbours and
      the boundaries of its elements, which are *absorbed* into the new
      element (each element is scanned O(1) times over its life);
    - every ``v ∈ Lp`` gets an **approximate** external degree
      ``d(v) ≈ |A_v| + |Lp \\ v| + Σ_e |Le \\ Lp|`` (the classic AMD upper
      bound — element overlaps are counted once per element, not exactly),
      clamped by ``n_left - |v|`` and ``d_old + |Lp \\ v|``;
    - elements with ``|Le \\ Lp| = 0`` are **aggressively absorbed**;
    - variables whose entire structure is inside ``Lp`` are
      **mass-eliminated** with the pivot (no new fill, no new pivot search);
    - variables in ``Lp`` with identical quotient adjacency (same pruned
      variable set, same element set) are detected via a hash bucket over
      ``Σ ids`` and merged into **supervariables**, eliminated together.

    Returns the elimination permutation (supervariables expanded in merge
    order).  Degrees are weighted by supervariable size throughout, so the
    approximation tracks the true external degree of the compressed graph.
    """
    if n == 0:
        return np.empty(0, dtype=np.int64)
    INF = np.int64(1) << np.int64(60)
    adj = _sym_adj_sets(row, col, n)
    elem: List[list] = [[] for _ in range(n)]   # element lists per variable
    Le: dict = {}                               # alive elements: id -> [vars]
    wt = [1] * n                                # supervariable weights
    members: List[list] = [[v] for v in range(n)]
    status = [0] * n                            # 0 alive, 1 ordered, 2 merged
    deg = np.array([len(a) for a in adj], dtype=np.int64)
    order: List[int] = []
    nleft = n
    while nleft > 0:
        p = int(np.argmin(deg))
        # ---- pivot structure Lp = (A_p ∪ ⋃ Le[e]) \ {p, dead} -------------
        Lp_set: set = set()
        for e in elem[p]:
            le = Le.pop(e, None)                # absorb e into the new element
            if le is not None:
                Lp_set.update(le)
        Lp_set.update(adj[p])
        Lp = [v for v in Lp_set if status[v] == 0 and v != p]
        Lp_set = set(Lp)
        order.append(p)
        status[p] = 1
        deg[p] = INF
        nleft -= wt[p]
        adj[p] = set()
        elem[p] = []
        if not Lp:
            continue
        WLp = 0
        for v in Lp:
            WLp += wt[v]
        # ---- scan 1: prune neighbour lists, weigh |Le \ Lp| per element ---
        wext: dict = {}
        for v in Lp:
            av = adj[v]
            if av:
                adj[v] = {u for u in av
                          if status[u] == 0 and u not in Lp_set}
            ev = []
            for e in elem[v]:
                le = Le.get(e)
                if le is None:                  # absorbed earlier — drop
                    continue
                w = wext.get(e)
                if w is None:                   # first touch: compact + weigh
                    le2 = [u for u in le if status[u] == 0]
                    if len(le2) != len(le):
                        Le[e] = le = le2
                    w = 0
                    for u in le:
                        w += wt[u]
                wext[e] = w - wt[v]
                ev.append(e)
            elem[v] = ev
        Le[p] = Lp
        # ---- scan 2: approximate degrees, absorption, mass elim, hashing --
        buckets: dict = {}
        mass: List[int] = []
        for v in Lp:
            ext = 0
            ev2 = []
            for e in elem[v]:
                w = wext[e]
                if w <= 0 and aggressive:
                    Le.pop(e, None)             # Le[e] ⊆ Lp: absorbed by p
                    continue
                ev2.append(e)
                ext += w
            da = 0
            for u in adj[v]:
                da += wt[u]
            if ext == 0 and da == 0:
                elem[v] = []                    # struct(v) ⊆ Lp: mass elim
                mass.append(v)
                continue
            ev2.append(p)
            elem[v] = ev2
            d = da + (WLp - wt[v]) + ext
            bound = nleft - wt[v]
            if d > bound:
                d = bound
            ob = int(deg[v]) + WLp - wt[v]
            if d > ob:
                d = ob
            deg[v] = d
            h = 0
            for e in ev2:
                h += e
            for u in adj[v]:
                h += u
            buckets.setdefault(h % 1048573, []).append(v)
        for v in mass:
            order.append(v)
            status[v] = 1
            deg[v] = INF
            nleft -= wt[v]
            adj[v] = set()
        if mass:
            mset = set(mass)
            Le[p] = [v for v in Lp if v not in mset]
        # ---- supervariable merging (exact check within hash buckets) ------
        for bucket in buckets.values():
            if len(bucket) < 2:
                continue
            for k, v in enumerate(bucket):
                if status[v] != 0:
                    continue
                ve = None
                for u in bucket[k + 1:]:
                    if status[u] != 0 or len(elem[u]) != len(elem[v]):
                        continue
                    if ve is None:
                        ve = set(elem[v])
                    if adj[u] == adj[v] and ve == set(elem[u]):
                        wt[v] += wt[u]          # merge u into v
                        members[v].extend(members[u])
                        members[u] = []
                        status[u] = 2
                        deg[u] = INF
                        deg[v] -= wt[u]
                        adj[u] = set()
                        elem[u] = []
    perm = np.empty(n, dtype=np.int64)
    k = 0
    for r in order:
        for v in members[r]:
            perm[k] = v
            k += 1
    assert k == n, "AMD lost variables (quotient-graph bookkeeping bug)"
    return perm


def _etree_fill(n: int, rptr: np.ndarray,
                rcol: np.ndarray) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Elimination tree + static fill pattern + level schedule in O(nnz(L)).

    One pass of Liu's etree construction fused with the row-subtree
    traversal: for permuted row ``i``, walking from every pattern entry
    ``k < i`` up the partial etree emits exactly the nonzeros of row ``i``
    of L (the walk is pruned at the first vertex already marked for ``i``,
    so each L entry is produced exactly once — the filled graph is never
    materialized).  Longest-path levels of the elimination DAG
    (``level(i) > level(j)`` for every L(i,j)) ride the same pass.

    Returns ``(Ri, Rj, level)`` — L entries as (row, col) index arrays in
    permuted coordinates plus the per-node level.
    """
    parent = [-1] * n
    mark = [-1] * n
    level = [0] * n
    ei: List[int] = []
    ej: List[int] = []
    rp = rptr.tolist()
    rc = rcol.tolist()
    for i in range(n):
        mark[i] = i
        lv = -1
        for t in range(rp[i], rp[i + 1]):
            j = rc[t]
            while mark[j] != i:
                mark[j] = i
                ei.append(i)
                ej.append(j)
                lj = level[j]
                if lj > lv:
                    lv = lj
                pj = parent[j]
                if pj == -1:
                    parent[j] = i
                    break
                j = pj
        level[i] = lv + 1
    return (np.asarray(ei, dtype=np.int64), np.asarray(ej, dtype=np.int64),
            np.asarray(level, dtype=np.int64))


def _pattern_levels(n: int, rptr: np.ndarray, rcol: np.ndarray) -> np.ndarray:
    """Longest-path levels when the L structure IS the (permuted strict
    lower) pattern — the zero-fill ILU(0)/IC(0) case needs no etree."""
    level = [0] * n
    rp = rptr.tolist()
    rc = rcol.tolist()
    for i in range(n):
        lv = -1
        for t in range(rp[i], rp[i + 1]):
            lj = level[rc[t]]
            if lj > lv:
                lv = lj
        level[i] = lv + 1
    return np.asarray(level, dtype=np.int64)


def _width(total: int, n_levels: int, lo: int = 32, hi: int = 1 << 16) -> int:
    """Step width ≈ mean level load, clamped and rounded DOWN to a power of
    two — few distinct shapes across patterns keeps XLA's compile cache
    warm, and the floor (vs the previous ceil) cuts the padded step area by
    ~30% on 2-D Poisson at n = 10⁴, which speeds the numeric factorization
    and the sweeps by the same fraction (the scan does strictly less padded
    work; measured 17–20% faster end-to-end)."""
    w = max(lo, min(hi, -(-total // max(n_levels, 1))))
    return 1 << max(int(np.floor(np.log2(w))), 5)


def _emit_factor(n: int, nnzL: int, Li: np.ndarray, Lptr: np.ndarray,
                 counts: np.ndarray, level: np.ndarray, n_levels: int,
                 lkeys: np.ndarray, incomplete: bool
                 ) -> Tuple[PackedFactor, int, int]:
    """Packed factorization program, emitted with vectorized placement.

    Columns are walked level by level (elimination DAG order).  Within one
    step the scan body runs finalize-then-update, so a column's updates may
    share its finalize step; a new level's finalizes must start strictly
    after any step holding earlier levels' updates (those updates write into
    the new level's entries and pivots).  Placement replicates the greedy
    fixed-width packer with prefix sums: finalize entries of a level are
    consecutive from ``max(cursor, ceil(up_cursor/w_up))``; each column's
    update tuples start no earlier than the step of its last finalize, which
    a running-max scan over ``f_i·w_up − Σ u_j`` resolves level-wide without
    a Python per-tuple loop.  ``lkeys`` is the sorted column-major key array
    ``col·n + row`` of L used to resolve update destinations (an update pair
    (i, j) maps to the diagonal, an L slot, or its mirrored U slot).

    Returns ``(program, n_steps, kept_updates)``.
    """
    szero = n + 2 * nnzL                       # scratch slots in C
    sone = szero + 1
    flops = int(np.sum(counts.astype(np.int64) ** 2))
    wf = _width(nnzL, n_levels)
    wu = _width(flops, n_levels)

    # ---- values (one vectorized pass over all levels) ---------------------
    # Columns in schedule order (level, then index); every T-sized array is
    # built globally — only the *placement* below walks levels, and it only
    # touches per-column scalars.
    colorder = np.argsort(level, kind="stable").astype(np.int64)
    lvl_cnt = np.bincount(level, minlength=n_levels)
    lvl_ptr = np.concatenate([[0], np.cumsum(lvl_cnt)])
    Li32 = Li.astype(np.int32)

    m = counts[colorder]
    mex = np.concatenate([[0], np.cumsum(m)])          # fin offsets/column
    F = int(mex[-1])                                   # == nnzL
    cid = np.repeat(np.arange(n, dtype=np.int64), m)   # fin item -> column pos
    lbase = Lptr[colorder]
    lidx = lbase[cid] + (np.arange(F, dtype=np.int64) - mex[cid])
    finl = (n + lidx).astype(np.int32)                 # fin lpos values
    finp = colorder[cid].astype(np.int32)              # fin pivot values
    rows = Li32[lidx]                                  # permuted row per item
    # update tuples: every (a, b) pair of each column's fin items.  Only the
    # strict a < b half is generated (item (k, a) spawns m_k − 1 − a minor
    # entries b = a+1..m_k−1); the mirrored (b, a) half and the diagonal
    # (a, a) tuples are derived arithmetically — a pair and its mirror share
    # one L slot index ``t`` (rows are sorted within a column, so a < b ⇔
    # Li[a] < Li[b]: the (a, b) tuple hits the mirror-U slot n+nnzL+t, the
    # (b, a) tuple the L slot n+t, the diagonal the pivot slot).
    kt = np.int32 if n <= 46340 else np.int64          # n² within int32?
    lk32 = lkeys.astype(kt) if kt is np.int32 else lkeys
    a_loc = np.arange(F, dtype=np.int64) - mex[cid]    # a within its column
    len1 = np.repeat(m, m) - 1 - a_loc                 # strict pairs per item
    T1 = int(len1.sum())
    gex1 = np.concatenate([[0], np.cumsum(len1)])[:-1]
    jidx = np.repeat(lidx + 1 - gex1, len1) \
        + np.arange(T1, dtype=np.int64)                # Lptr[col] + b
    jj = Li32[jidx]
    ii = np.repeat(rows, len1)                         # Li[base + a], ii < jj
    pa = np.repeat(finl, len1)                         # base + a
    pb = (jidx + n).astype(np.int32)                   # base + b
    lk = ii.astype(kt) * kt(n) + jj
    t = np.searchsorted(lk32, lk)
    if incomplete:                                     # ILU(0): drop fill
        tc = np.minimum(t, max(nnzL - 1, 0))
        keep = (lkeys[tc] == lk) if nnzL else np.zeros_like(lk, bool)
        t = tc[keep].astype(np.int32)
        jj, ii, pa, pb = jj[keep], ii[keep], pa[keep], pb[keep]
        P = np.bincount(np.repeat(cid, len1)[keep], minlength=n)
    else:
        # closure guard: every strict pair of an etree-derived structure
        # must hit its exact L slot — a miss here must fail fast, not
        # scatter updates into a wrong (or scratch) slot
        tc = np.minimum(t, max(nnzL - 1, 0))
        assert not t.size or bool((lkeys[tc] == lk).all()), \
            "fill closure violated"
        t = tc.astype(np.int32)
        P = (m * (m - 1)) // 2                         # strict pairs/column
    u = m + 2 * P                                      # diag + both halves
    kept_updates = int(m.sum() + 2 * t.size)
    uex = np.concatenate([[0], np.cumsum(u)])

    # ---- placement (per level, per-column scalars only) -------------------
    # barrier: a level's finalizes start strictly after any step holding
    # earlier levels' updates; a column's updates start no earlier than the
    # step of its last finalize (the scan body runs finalize-then-update,
    # so sharing that step is sound).  Greedy fixed-width packing resolves
    # to  d_i = max(d_{i-1}, f_i·wu − E_i)  over columns (running max),
    # column i's tuples then occupying slots [d_i + E_i, d_i + E_i + u_i).
    col_fs = np.zeros(n, dtype=np.int64)               # fin start slot/column
    col_us = np.zeros(n, dtype=np.int64)               # up start slot/column
    c_fin = 0
    c_up = 0
    for l in range(n_levels):
        s0, s1_ = lvl_ptr[l], lvl_ptr[l + 1]
        if s0 == s1_:
            continue
        Fl = int(mex[s1_] - mex[s0])
        if not Fl:
            continue
        start_f = max(c_fin, -(-c_up // wu) * wf)
        col_fs[s0:s1_] = start_f + (mex[s0:s1_] - mex[s0])
        c_fin = start_f + Fl
        ml = m[s0:s1_]
        f = np.where(ml > 0, (col_fs[s0:s1_] + ml - 1) // wf, 0)
        ul = u[s0:s1_]
        Kl = int(uex[s1_] - uex[s0])
        if not Kl:
            continue
        E = uex[s0:s1_] - uex[s0]
        g = np.where(ul > 0, f * np.int64(wu) - E, 0)
        d = np.maximum.accumulate(np.concatenate([[c_up], g]))[1:]
        col_us[s0:s1_] = d + E
        c_up = int(d[-1] + E[-1] + ul[-1])

    # column k's slot block [col_us[k], col_us[k] + u_k) is laid out as
    # [diag tuples | (a, b) half | mirrored (b, a) half], each group
    # column-contiguous, so positions are repeats of per-column bases
    fin_pos = np.repeat(col_fs, m) + a_loc
    pos0 = np.repeat(col_us, m) + a_loc
    Pex = np.concatenate([[0], np.cumsum(P)])[:-1]
    pos1 = np.repeat(col_us + m - Pex, P) + np.arange(t.size, dtype=np.int64)
    pos2 = pos1 + np.repeat(P, P)
    fS = max(-(-c_fin // wf), -(-c_up // wu))
    nn = np.int32(nnzL)

    def grid(width, pad, writes):
        out = np.empty(fS * width, dtype=np.int32)
        out.fill(pad)
        for p, v in writes:
            out[p] = v
        return out.reshape(fS, width)

    factor = PackedFactor(
        fin_lpos=jnp.asarray(grid(wf, szero, [(fin_pos, finl)])),
        fin_piv=jnp.asarray(grid(wf, sone, [(fin_pos, finp)])),
        up_s1=jnp.asarray(grid(wu, szero, [(pos0, finl), (pos1, pa),
                                           (pos2, pb)])),
        up_s2=jnp.asarray(grid(wu, szero, [(pos0, finl + nn), (pos1, pb + nn),
                                           (pos2, pa + nn)])),
        up_dst=jnp.asarray(grid(wu, szero, [(pos0, rows),
                                            (pos1, np.int32(n) + nn + t),
                                            (pos2, np.int32(n) + t)])))
    return factor, fS, kept_updates


def _emit_sweep(n: int, nnzL: int, tgt: np.ndarray, src: np.ndarray,
                level: np.ndarray, n_levels: int,
                descending: bool) -> PackedSweep:
    """Packed program for one triangular-sweep direction (vectorized).

    Entries are grouped by the level of their *target* node (ascending for
    the row program, descending for the col program); within a level, a
    node's divide shares (or follows) the step of its last incoming add,
    and adds of different levels never share a step (the next level's floor
    is one past the last divide).  Same prefix-sum/cummax placement as the
    factorization program, two streams: adds (width ~ mean entries/level)
    and divides (width ~ mean nodes/level).
    """
    szero = n + 2 * nnzL
    sone = szero + 1
    we = _width(nnzL, n_levels)
    wd = _width(n, n_levels)

    gpos = level[tgt]
    npos = level
    if descending:
        gpos = (n_levels - 1) - gpos
        npos = (n_levels - 1) - npos
    eorder = np.lexsort((np.arange(nnzL), tgt, gpos))
    ecnt = np.bincount(gpos, minlength=n_levels)
    eptr = np.concatenate([[0], np.cumsum(ecnt)])
    norder = np.lexsort((np.arange(n), npos))
    ncnt = np.bincount(npos, minlength=n_levels)
    nptr = np.concatenate([[0], np.cumsum(ncnt)])

    c_e = 0
    c_d = 0
    floor = 0
    e_pos: List[np.ndarray] = []
    e_ent: List[np.ndarray] = []
    d_pos: List[np.ndarray] = []
    d_val: List[np.ndarray] = []
    for l in range(n_levels):
        vs = norder[nptr[l]:nptr[l + 1]]
        ets = eorder[eptr[l]:eptr[l + 1]]
        if not vs.size:
            assert not ets.size, "sweep entry without its target node?"
            floor += 1
            continue
        Q = ets.size
        if Q:
            start_e = max(c_e, floor * we)
            e_pos.append(start_e + np.arange(Q, dtype=np.int64))
            e_ent.append(ets)
            # per-node entry counts (entries sorted by target within level)
            tv = tgt[ets]
            q = (np.searchsorted(tv, vs, side="right")
                 - np.searchsorted(tv, vs, side="left"))
            assert int(q.sum()) == Q, "sweep entry without its target node?"
            cq = np.cumsum(q)
            f = np.where(q > 0, (start_e + cq - 1) // we, floor)
            c_e = start_e + Q
        else:
            f = np.full(vs.size, floor, dtype=np.int64)
        # one divide per node, floored at its last incoming add
        g = f * np.int64(wd) - np.arange(vs.size, dtype=np.int64)
        d = np.maximum.accumulate(np.concatenate([[c_d], g]))[1:]
        pos = d + np.arange(vs.size, dtype=np.int64)
        d_pos.append(pos)
        d_val.append(vs)
        c_d = int(pos[-1]) + 1
        floor = (c_d - 1) // wd + 1            # next level strictly after

    S = max(-(-c_e // we), -(-c_d // wd))

    def grid(pos_list, val_list, width, pad):
        out = np.empty(S * width, dtype=np.int32)
        out.fill(pad)
        if pos_list:
            out[np.concatenate(pos_list)] = np.concatenate(val_list)
        return out.reshape(S, width)

    ents = (np.concatenate(e_ent) if e_ent else np.empty(0, np.int64))
    epos = e_pos
    return PackedSweep(
        tgt=jnp.asarray(grid(epos, [tgt[ents]], we, n), jnp.int32),
        src=jnp.asarray(grid(epos, [src[ents]], we, n), jnp.int32),
        lpos=jnp.asarray(grid(epos, [n + ents], we, szero), jnp.int32),
        upos=jnp.asarray(grid(epos, [n + nnzL + ents], we, szero), jnp.int32),
        dn=jnp.asarray(grid(d_pos, d_val, wd, n), jnp.int32),
        dpiv=jnp.asarray(grid(d_pos, d_val, wd, sone), jnp.int32))


def symbolic_factor(row, col, n: int, *, ordering: str = "amd",
                    incomplete: bool = False) -> DirectArtifacts:
    """Analyze one sparsity pattern for direct (or incomplete) factorization.

    This is the plan engine's ``analyze`` stage: values-free, eager numpy,
    run ONCE per sparsity pattern and shared by every ``with_values``
    refresh, every shared-pattern batch element, the adjoint's transposed
    solves, ``precond="ilu"``, the AMG coarsest level, and ``slogdet``.

    Parameters
    ----------
    row, col : integer index arrays (COO, concrete — never tracers).
    n : matrix dimension.
    ordering : fill-reducing ordering of the symmetrized pattern graph.

        - ``"amd"`` (default) — approximate minimum degree on a quotient
          graph (:func:`_amd_order`): element absorption, hash-based
          supervariable detection, aggressive absorption and mass
          elimination.  Near-MD fill quality at a fraction of the analyze
          cost; the whole pipeline is ~15–20× faster than ``"md"`` at
          n = 10⁴.
        - ``"md"`` — exact minimum degree (clique-forming elimination),
          retained for A/B fill-quality comparisons.
        - ``"rcm"`` — reverse Cuthill–McKee (scipy when available,
          identity fallback otherwise).
        - ``"natural"`` — identity permutation.
    incomplete : ``True`` produces the ILU(0)/IC(0) program — same storage
        and kernels, zero fill (update tuples restricted to the original
        symmetrized pattern), no elimination tree needed.  Degree-based
        orderings are pointless at zero fill, so ``"amd"``/``"md"`` resolve
        to ``"natural"`` (ILU(0) keeps the assembly order).

    Raises ``ValueError`` when the pattern lacks a structurally full
    diagonal (no pivoting is performed, so every pivot must exist
    structurally; see ``numeric_factor``'s ``pivot_guard`` for the
    *numerically* zero case).

    The analysis is eager even when invoked inside a jit trace (plans are
    cached on long-lived SparseTensors, so the index tensors must be
    concrete arrays, never trace-bound constants).  Nothing values-dependent
    may be captured here — that is ``setup``'s job.
    """
    with jax.ensure_compile_time_eval():
        return _symbolic_factor(row, col, n, ordering, incomplete)


def _symbolic_factor(row, col, n: int, ordering: str,
                     incomplete: bool) -> DirectArtifacts:
    row = np.asarray(row, dtype=np.int64)
    col = np.asarray(col, dtype=np.int64)
    from .sparse import has_full_diagonal
    if not has_full_diagonal(row, col, n):
        raise ValueError(
            "direct factorization needs a structurally full diagonal "
            "(no pivoting); use an iterative backend for this pattern")

    if incomplete and ordering in ("amd", "md"):
        ordering = "natural"        # ILU(0) keeps the assembly order
    if ordering == "amd":
        perm = _amd_order(row, col, n)
    elif ordering == "md":
        perm = _exact_md_order(row, col, n)
    elif ordering == "rcm":
        perm = _rcm_order(row, col, n)
    elif ordering == "natural":
        perm = np.arange(n, dtype=np.int64)
    else:
        raise ValueError(f"unknown ordering {ordering!r}")
    ipos = np.empty(n, dtype=np.int64)
    ipos[perm] = np.arange(n)

    # strict-lower symmetrized pattern in permuted coordinates, CSR by row
    rptr, rcol = _sym_lower_csr(row, col, n, ipos)
    if incomplete:                  # zero fill: the L structure IS the pattern
        Ri, Rj = np.repeat(np.arange(n, dtype=np.int64),
                           np.diff(rptr)), rcol
        level = _pattern_levels(n, rptr, rcol)
    else:                           # etree pass: fill without the filled graph
        Ri, Rj, level = _etree_fill(n, rptr, rcol)
    n_levels = int(level.max()) + 1 if n else 1

    # L pattern, column-major: column k holds sorted permuted row indices.
    corder = np.lexsort((Ri, Rj))
    Li = Ri[corder]
    counts = np.bincount(Rj, minlength=n).astype(np.int64)
    Lptr = np.concatenate([[0], np.cumsum(counts)])
    nnzL = int(Lptr[-1])
    nnzF = n + 2 * nnzL
    lkeys = Rj[corder] * np.int64(n) + Li      # sorted: position lookup in L

    # scatter map for A's entries into F = diag ∪ L ∪ mirror(U)
    pi, pj = ipos[row], ipos[col]
    ak = np.minimum(pi, pj) * np.int64(n) + np.maximum(pi, pj)
    at = np.searchsorted(lkeys, ak)
    at = np.minimum(at, max(nnzL - 1, 0))
    aok = (lkeys[at] == ak) if nnzL else np.zeros_like(ak, bool)
    diag = pi == pj
    assert bool((aok | diag).all()), \
        "A entry outside its own symmetrized pattern?"
    a2f = np.where(diag, pi, np.where(pi > pj, n + at, n + nnzL + at))

    factor, fS, kept_updates = _emit_factor(
        n, nnzL, Li, Lptr, counts, level, n_levels, lkeys, incomplete)

    # row program (levels leaf→root): forward-L and transposed-Uᵀ sweeps;
    # col program (root→leaf): backward-U and transposed-Lᵀ sweeps.
    Ljc = Rj[corder]
    row_sweep = _emit_sweep(n, nnzL, Li, Ljc, level, n_levels,
                            descending=False)
    col_sweep = _emit_sweep(n, nnzL, Ljc, Li, level, n_levels,
                            descending=True)

    stats = {"nnz_L": nnzL, "n_levels": n_levels, "flops": kept_updates,
             "fill_ratio": float(nnzF) / float(max(len(row), 1)),
             "n_steps": fS, "ordering": ordering, "incomplete": incomplete}
    return DirectArtifacts(
        n=n, nnzF=nnzF,
        perm=jnp.asarray(perm, jnp.int32), ipos=jnp.asarray(ipos, jnp.int32),
        a2f=jnp.asarray(a2f, jnp.int32),
        factor=factor, row_sweep=row_sweep, col_sweep=col_sweep, stats=stats)


# ---------------------------------------------------------------------------
# shard-local factorization (the distributed plan engine's Schwarz stage)
# ---------------------------------------------------------------------------

class SchwarzArtifacts(NamedTuple):
    """Product of :func:`schwarz_symbolic` — ONE union-pattern symbolic
    factorization shared by every shard, plus the per-shard numeric assembly
    programs.  Everything is pattern-only; the numeric half is a plain
    ``vmap(numeric_factor)`` over per-shard values at setup time."""
    art: DirectArtifacts     # ILU(0)/IC(0) program on the union pattern
    nnz_u: int               # union-pattern nonzeros
    src: jax.Array           # (P, m) gather into flat values (+zero slot last)
    dst: jax.Array           # (P, m) scatter into union slots (pads → nnz_u)
    diag_fix: jax.Array      # (P, nnz_u) +1.0 on structurally-absent diagonals


def schwarz_symbolic(entries, n_ext: int, n_src: int) -> SchwarzArtifacts:
    """Analyze shard-local extended matrices for overlapping Schwarz.

    ``entries[q]`` lists shard ``q``'s extended-domain matrix as
    ``(rows, cols, srcs)`` — COO coordinates in ``[0, n_ext)`` plus the flat
    index of each entry's value in the global value storage (length
    ``n_src``; a trailing zero slot is appended at gather time).  The
    extended matrices of all shards are unioned into ONE sparsity pattern so
    a single zero-fill (ILU(0)/IC(0)) step program — built by
    :func:`symbolic_factor` — serves every shard under ``vmap``/``shard_map``:
    per-shard numeric values are scattered into union slots, structurally
    absent diagonals (phantom halos of edge shards, padded tail rows) are
    completed with 1.0 identity pivots, and entries another shard has but
    this one lacks stay numerically zero.
    """
    p = len(entries)
    keys = [r.astype(np.int64) * n_ext + c.astype(np.int64)
            for r, c, _ in entries]
    dkeys = np.arange(n_ext, dtype=np.int64) * (n_ext + 1)
    ukeys = np.unique(np.concatenate(keys + [dkeys]))
    nnz_u = int(ukeys.size)
    urow = (ukeys // n_ext).astype(np.int64)
    ucol = (ukeys % n_ext).astype(np.int64)

    m = max(max((k.size for k in keys), default=1), 1)
    src = np.full((p, m), n_src, dtype=np.int64)        # pads → zero slot
    dst = np.full((p, m), nnz_u, dtype=np.int64)        # pads → dump slot
    diag_fix = np.ones((p, nnz_u), dtype=np.float64)
    dslot = np.searchsorted(ukeys, dkeys)
    for q, (k, (_, _, s)) in enumerate(zip(keys, entries)):
        slot = np.searchsorted(ukeys, k)
        src[q, :k.size] = np.asarray(s, np.int64)
        dst[q, :k.size] = slot
        diag_fix[q] = 0.0
        have = np.zeros(nnz_u, bool)
        have[slot] = True
        diag_fix[q, dslot[~have[dslot]]] = 1.0          # identity completion

    art = symbolic_factor(urow, ucol, n_ext, incomplete=True)
    return SchwarzArtifacts(art=art, nnz_u=nnz_u,
                            src=jnp.asarray(src, jnp.int32),
                            dst=jnp.asarray(dst, jnp.int32),
                            diag_fix=jnp.asarray(diag_fix))


def schwarz_numeric(sch: SchwarzArtifacts, flat_val: jax.Array) -> jax.Array:
    """Traced-safe numeric half: assemble every shard's extended matrix from
    the flat global values and refactorize — ``(P, nnzF + 2)`` stacked
    factors, one per shard (the setup stage of ``precond='schwarz'``)."""
    padded = jnp.concatenate([flat_val, jnp.zeros((1,), flat_val.dtype)])

    def one_shard(src_q, dst_q, fix_q):
        v = jnp.zeros(sch.nnz_u + 1, flat_val.dtype).at[dst_q].add(
            padded[src_q])[:-1]
        return numeric_factor(sch.art, v + fix_q.astype(flat_val.dtype))

    return jax.vmap(one_shard)(sch.src, sch.dst, sch.diag_fix)


# ---------------------------------------------------------------------------
# numeric factorization (traced-safe — the setup stage)
# ---------------------------------------------------------------------------

def numeric_factor(art: DirectArtifacts, val: jax.Array, *,
                   pivot_guard: bool = True,
                   pivot_eps: Optional[float] = None) -> jax.Array:
    """Numeric LU/LDLᵀ over the precomputed fill pattern.

    One ``lax.scan`` over the packed step program: pure gather/scatter with
    uniform shapes, so it compiles once, jits, and vmaps over a leading batch
    dimension of ``val`` (shared-pattern batches).  Duplicate COO entries
    accumulate, matching ``coo_matvec`` semantics.

    ``pivot_guard`` (default on): a structurally-present but numerically-
    (near-)zero pivot would silently turn the whole factorization into NaNs
    — no numerical pivoting is performed.  The guard applies a *static
    diagonal perturbation* at divide time instead: any pivot with
    ``|d| < τ`` (τ = ``pivot_eps`` or ``√eps·max|A|``) is replaced by
    ``±τ``, persisted in the factor storage so the triangular sweeps stay
    consistent, and — when the values are concrete (not inside a trace) —
    reported with a ``UserWarning``.  The perturbed factorization solves a
    nearby matrix; proper Bunch–Kaufman pivoting for indefinite systems
    remains a ROADMAP follow-up (this is the documented stopgap).
    """
    scale = jnp.max(jnp.abs(val))
    tau = jnp.asarray(
        pivot_eps if pivot_eps is not None
        else jnp.sqrt(jnp.finfo(val.dtype).eps) * jnp.maximum(scale, 1e-300),
        val.dtype)
    C = jnp.zeros(art.nnzF + 2, dtype=val.dtype)
    C = C.at[art.a2f].add(val).at[art.nnzF + 1].set(1.0)

    if not pivot_guard:
        def step(C, xs):
            fl, fpv, s1, s2, dst = xs
            C = C.at[fl].set(C[fl] / C[fpv])
            C = C.at[dst].add(-C[s1] * C[s2])
            return C, None

        C, _ = lax.scan(step, C, tuple(art.factor))
        return C

    # perturbation BOOKKEEPING (for the warning) only runs when the values
    # are concrete — its sole consumer is the eager warning, which can never
    # fire under jit/vmap, so traced factorizations keep the lean scan body
    # (the safe-pivot clamp itself is always on)
    track = not isinstance(val, jax.core.Tracer)

    def clamp(C, fpv):
        piv = C[fpv]
        bad = jnp.abs(piv) < tau            # pads divide by scratch 1.0 — ok
        safe = jnp.where(bad, jnp.where(piv >= 0, tau, -tau), piv)
        return C.at[fpv].set(safe), bad     # persist: sweeps see the same d

    if not track:
        def step(C, xs):
            fl, fpv, s1, s2, dst = xs
            C, _ = clamp(C, fpv)
            C = C.at[fl].set(C[fl] / C[fpv])
            C = C.at[dst].add(-C[s1] * C[s2])
            return C, None

        C, _ = lax.scan(step, C, tuple(art.factor))
        return C

    pert0 = jnp.zeros(art.nnzF + 2, dtype=bool)

    def step(carry, xs):
        C, pert = carry
        fl, fpv, s1, s2, dst = xs
        C, bad = clamp(C, fpv)
        pert = pert.at[fpv].max(bad)
        C = C.at[fl].set(C[fl] / C[fpv])
        C = C.at[dst].add(-C[s1] * C[s2])
        return (C, pert), None

    (C, pert), _ = lax.scan(step, (C, pert0), tuple(art.factor))
    if not isinstance(pert, jax.core.Tracer):
        n_bad = int(jnp.sum(pert[:art.n]))
        if n_bad:
            import warnings
            warnings.warn(
                f"numeric factorization hit {n_bad} numerically-zero "
                f"pivot(s); applied a scaled diagonal perturbation "
                f"(|d|<{float(tau):.2e} -> ±{float(tau):.2e}). The factors "
                f"solve a nearby matrix — consider an iterative backend or "
                f"a symmetric shift for indefinite systems.")
    return C


# ---------------------------------------------------------------------------
# triangular sweeps (traced-safe — the solve stage)
# ---------------------------------------------------------------------------

def _sweep(art: DirectArtifacts, C: jax.Array, c: jax.Array,
           program: PackedSweep, use_upos: bool, divide: bool) -> jax.Array:
    y = jnp.concatenate([c, jnp.zeros((1,), c.dtype)])   # scratch slot at n
    pos = program.upos if use_upos else program.lpos

    def step(y, xs):
        tgt, src, p, dn, dpiv = xs
        y = y.at[tgt].add(-C[p] * y[src])
        if divide:
            y = y.at[dn].set(y[dn] / C[dpiv])
        return y, None

    y, _ = lax.scan(step, y, (program.tgt, program.src, pos,
                              program.dn, program.dpiv))
    return y[:-1]


def factored_solve(art: DirectArtifacts, C: jax.Array, b: jax.Array,
                   *, transposed: bool = False) -> jax.Array:
    """x with A x = b (or Aᵀ x = b) from the factors ``C``.

    Forward: permute, unit-L then U sweeps, unpermute.  Transposed: the SAME
    factors with Uᵀ then Lᵀ sweeps — this is the adjoint's zero-refactorize
    path (LDLᵀ is self-adjoint; LU mirrors the sweeps).
    """
    c = b[art.perm]
    if transposed:
        w = _sweep(art, C, c, art.row_sweep, use_upos=True, divide=True)
        x = _sweep(art, C, w, art.col_sweep, use_upos=False, divide=False)
    else:
        y = _sweep(art, C, c, art.row_sweep, use_upos=False, divide=False)
        x = _sweep(art, C, y, art.col_sweep, use_upos=True, divide=True)
    return x[art.ipos]
