"""Sparse direct factorization backend — the cuDSS analogue (paper §3.1/§3.2.3).

The paper's headline backend is a *direct* sparse solver whose symbolic
factorization is computed once per sparsity pattern and reused across numeric
refactorizations and adjoint solves.  This module is that path for the plan
engine, entirely in JAX:

``symbolic_factor(row, col, n)``  — eager, numpy, values-free (the plan's
``analyze`` stage).  Three sub-stages, none of which ever forms the filled
graph explicitly:

1. **Ordering** — approximate minimum degree on a *quotient graph*
   (Amestoy/Davis/Duff style: element absorption, hash-based supervariable
   detection, aggressive absorption, mass elimination) is the default
   (``ordering="amd"``); the exact-minimum-degree elimination is retained as
   ``ordering="md"`` for A/B comparisons, plus ``"rcm"`` and ``"natural"``.
2. **Etree symbolic pass** — the elimination tree of the ordered pattern is
   built with Liu's algorithm, and the static fill pattern of L (and its
   mirror U) plus per-column fill counts fall out of one row-subtree
   traversal (marker-pruned: each path is walked once per fresh L entry, so
   the pass is O(nnz(L)) — no clique formation, no set algebra).
3. **Program emission** — a longest-path *level schedule* of the elimination
   DAG and a **packed step program**: every level's work is cut into
   fixed-width steps (finalize entries, rank-1 update tuples, sweep entries,
   pivot divides) so the numeric kernels are single ``lax.scan`` loops over
   uniform index tensors.  Emission is vectorized prefix-sum/cummax
   placement (no Python per-tuple loops).  One small compiled body serves
   every level, every ``with_values`` refresh, every batch element, and the
   adjoint.

``numeric_factor(art, val)``      — traced-safe (the ``setup`` stage).  Runs
the numeric LU/LDLᵀ over the precomputed fill pattern.  Two programs share
the storage: the scalar packed scan (per step one fused pivot-divide +
scatter-update pair) and — when the analyze stage emitted a supernodal
program (the ``supernodal`` option, auto) — batched dense *panel* kernels:
columns with identical fill structure are grouped into supernodes, each
assembly-tree level factors all its panels in one kernel launch, and the
Schur complement is a lane-batched GEMM extend-add
(:mod:`repro.kernels.supernode`; pure-jnp oracles on CPU).  Both write the
same factor vector bit-compatibly.  Jits, vmaps over batched values, and
re-traces nothing symbolic.

``factored_solve(art, C, b)``     — two level-scheduled triangular sweeps
(the ``solve`` stage).  ``transposed=True`` swaps the sweeps (Uᵀ then Lᵀ),
which is how the adjoint solves Aᵀλ = g on the FORWARD factors — LDLᵀ is
self-adjoint, LU just runs the mirrored sweeps — zero refactorizations.

Storage layout of the factor vector ``C`` (length ``nnzF + 2``)::

    C[0:n]              pivots  U[k,k]              (permuted order)
    C[n:n+nnzL]         L entries, column-major     (unit diagonal implicit)
    C[n+nnzL:nnzF]      U entries, mirror-aligned   (U[j,k] at mirror of L[k,j])
    C[nnzF]             scratch 0  (padding sink for scatter/gather)
    C[nnzF+1]           scratch 1  (padding divisor — keeps pads NaN-free)

For symmetric values (method ``ldlt``) the same kernel computes U = D·Lᵀ in
the mirror half, i.e. an LDLᵀ factorization with D folded into U; the solve
and adjoint exploit self-adjointness through the plan layer.  No *numerical*
pivoting is performed, but ``pivot_blocks="auto"`` places **static
Bunch–Kaufman 2×2 pivot blocks** at analyze time (etree-guided column
amalgamation inside supernodes), so structurally-indefinite systems —
saddle-point KKT blocks with numerically-zero diagonals — factor exactly
instead of through the zero-pivot perturbation guard;
:func:`factor_slogdet` accounts the 2×2 block determinants.

``incomplete=True`` restricts the update program to the original pattern
(zero fill): that is ILU(0)/IC(0), which :mod:`repro.core.precond` exposes as
``precond="ilu"`` sharing this exact machinery.

Consumers of :func:`symbolic_factor`, all paying the analyze cost once per
pattern: ``backend="direct"`` solves, ``precond="ilu"``, the AMG coarsest
level (:mod:`repro.core.multigrid`), the ``schwarz``/``schwarz2`` subdomain
and coarse factors (:mod:`repro.core.distributed`), and ``slogdet``.  The
auto-dispatch policy prefers the direct backend up to
the ``direct_budget`` option (:mod:`repro.core.options`; raised to 24576 by
the AMD + etree pipeline, then to 10⁵ by the supernodal panel kernels — the
sequential scalar scan is no longer the numeric-stage bottleneck; the
one-time analyze amortizes across the plan's lifetime) and 4× that under
``props["illcond_hint"]``.
"""
from __future__ import annotations

import functools
from typing import List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

__all__ = [
    "DirectArtifacts", "symbolic_factor", "numeric_factor", "factored_solve",
    "factor_slogdet",
    "SchwarzArtifacts", "schwarz_symbolic", "schwarz_numeric",
]

SN_MAX_W = 32            # supernode width cap (panel column count per bucket)


class PackedFactor(NamedTuple):
    """Step program for the numeric factorization: all arrays are (S, width)
    int32.  Per step: ``C[fin_lpos] /= C[fin_piv]`` (column finalize), then
    ``C[up_dst] -= C[up_s1] * C[up_s2]`` (right-looking updates).  Pads point
    at the scratch slots, so they are exact no-ops."""
    fin_lpos: jax.Array
    fin_piv: jax.Array
    up_s1: jax.Array
    up_s2: jax.Array
    up_dst: jax.Array


class PackedSweep(NamedTuple):
    """Step program for one triangular-sweep direction ((S, width) int32).

    ``row`` program (levels leaf→root): forward-L (use ``lpos``) and
    transposed-Uᵀ (use ``upos`` + divides).  ``col`` program (root→leaf):
    backward-U (``upos`` + divides) and transposed-Lᵀ (``lpos``).  Per step:
    ``y[tgt] -= C[pos] * y[src]`` then optionally ``y[dn] /= C[dpiv]``.
    The solution vector carries one scratch element at index n for pads.
    """
    tgt: jax.Array
    src: jax.Array
    lpos: jax.Array
    upos: jax.Array
    dn: jax.Array
    dpiv: jax.Array


class SnodeBucket(NamedTuple):
    """One (assembly-level, padded-shape) bucket of supernodes.

    ``k`` supernode lanes share the padded panel shape (wb, rb); lanes past
    the true count are all-pad (``wvec = 0``, slots at the scratch sink).
    All index arrays address the packed factor vector ``C``:

    - ``pidx`` (k, wb+rb, wb): gather/scatter slots for the P panel —
      rows 0..wb-1 the dense diagonal block (pivots/L/U-mirror), rows wb..
      the sub-diagonal L panel over the supernode's row structure R_s;
    - ``qidx`` (k, wb, rb): the U panel (rows of U over R_s);
    - ``uidx`` (k, rb, rb): the extend-add targets — every (R_s × R_s) slot
      (present by fill closure) the Schur GEMM scatter-subtracts into;
    - ``rows`` (k, wb+rb): permuted row ids (block cols then R_s; pads → n,
      the solution vector's scratch element);
    - ``bkm`` (k, wb): static Bunch–Kaufman pair-start flags.
    """
    wb: int
    rb: int
    pairs: bool
    pidx: jax.Array
    qidx: jax.Array
    uidx: jax.Array
    rows: jax.Array
    wvec: jax.Array          # (k,) true widths
    rvec: jax.Array          # (k,) true sub-row counts
    bkm: jax.Array


class SnodeProgram(NamedTuple):
    """Supernodal panel program — the dense-panel alternative to the scalar
    packed-scan program, emitted by the same symbolic analysis.

    ``schedule`` is a tuple of assembly-tree levels, each a tuple of
    :class:`SnodeBucket`; levels run ascending for the factorization and the
    forward/Uᵀ sweeps, descending for the backward/Lᵀ sweeps.  The pair
    arrays feed :func:`factor_slogdet` (a 2x2 pivot contributes
    ``log|a·e − b·c|``, not ``log|a| + log|e|``): ``pair_cols`` (p, 2) the
    permuted pivot columns (t, t+1), ``pair_off`` (p, 2) the C slots of the
    raw b = U(t,t+1) and c = L(t+1,t) entries, ``unpaired`` (n,) the columns
    still owned by 1x1 pivots."""
    schedule: tuple
    pair_cols: jax.Array
    pair_off: jax.Array
    unpaired: jax.Array
    stats: dict              # n_snodes, mean_width, panel_fraction, n_groups


class DirectArtifacts(NamedTuple):
    """Product of the symbolic analysis — pattern-only, shared by every
    ``with_values`` refresh, every batch element, and the adjoint."""
    n: int
    nnzF: int
    perm: jax.Array          # perm[k] = original index eliminated at step k
    ipos: jax.Array          # ipos[v] = elimination position of index v
    a2f: jax.Array           # COO entry e -> position in C (scatter-add)
    factor: PackedFactor
    row_sweep: PackedSweep
    col_sweep: PackedSweep
    stats: dict              # nnz_L, fill_ratio, n_levels, flops, n_steps
    snode: Optional[SnodeProgram] = None    # dense-panel program (else scalar)


# ---------------------------------------------------------------------------
# symbolic analysis (eager / numpy — the analyze stage, once per pattern)
# ---------------------------------------------------------------------------

def _sym_lower_csr(row: np.ndarray, col: np.ndarray, n: int,
                   ipos: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """CSR of the *strict lower triangle* of the permuted, symmetrized
    pattern: returns ``(rptr, rcol)`` — for permuted row ``i``, the sorted
    permuted indices ``j < i`` with ``A(perm[i], perm[j]) != 0`` (either
    triangle).  Duplicates collapse; the diagonal is dropped."""
    mask = row != col
    pi = ipos[row[mask]]
    pj = ipos[col[mask]]
    hi = np.maximum(pi, pj)
    lo = np.minimum(pi, pj)
    keys = np.unique(hi * np.int64(n) + lo)
    ri = keys // n
    rj = keys % n
    rptr = np.searchsorted(ri, np.arange(n + 1, dtype=np.int64))
    return rptr, rj


def _rcm_order(row: np.ndarray, col: np.ndarray, n: int) -> np.ndarray:
    try:
        import scipy.sparse as sp
        from scipy.sparse.csgraph import reverse_cuthill_mckee
    except Exception:                       # scipy absent — degrade gracefully
        return np.arange(n, dtype=np.int64)
    G = sp.csr_matrix((np.ones(len(row)), (row, col)), shape=(n, n))
    return np.asarray(reverse_cuthill_mckee(G, symmetric_mode=False),
                      dtype=np.int64)


def _sym_adj_sets(row: np.ndarray, col: np.ndarray, n: int) -> List[set]:
    """Per-vertex neighbour sets of the symmetrized pattern graph (no self
    loops, duplicates collapsed) — the shared starting point of both
    degree-based orderings."""
    mask = row != col
    rr = np.concatenate([row[mask], col[mask]])
    cc = np.concatenate([col[mask], row[mask]])
    key = np.unique(rr * np.int64(n) + cc)
    ai = (key // n).astype(np.int64)
    aj = (key % n).astype(np.int64)
    ptr = np.searchsorted(ai, np.arange(n + 1, dtype=np.int64))
    return [set(aj[ptr[v]:ptr[v + 1]].tolist()) for v in range(n)]


def _exact_md_order(row: np.ndarray, col: np.ndarray, n: int) -> np.ndarray:
    """Exact minimum degree: full graph elimination with clique formation,
    selecting the minimum *remaining* degree each step.  O(fill) set algebra
    per pivot — the quality yardstick ``ordering="amd"`` is measured against
    (tests assert AMD fill-in stays within 25%), not the production path."""
    adj = _sym_adj_sets(row, col, n)
    INF = np.int64(1) << np.int64(60)
    deg = np.array([len(a) for a in adj], dtype=np.int64)
    perm = np.empty(n, dtype=np.int64)
    for k in range(n):
        v = int(np.argmin(deg))
        perm[k] = v
        deg[v] = INF
        nb = adj[v]
        for u in nb:
            adj[u].discard(v)
        for u in nb:
            au = adj[u]
            au |= nb
            au.discard(u)
            deg[u] = len(au)
        adj[v] = set()
    return perm


def _amd_order(row: np.ndarray, col: np.ndarray, n: int, *,
               aggressive: bool = True) -> np.ndarray:
    """Approximate minimum degree on a quotient graph (Amestoy/Davis/Duff).

    Instead of forming the clique of each eliminated vertex (the O(fill)
    step that makes exact MD quadratic-ish in practice), the eliminated
    pivot becomes an *element* whose boundary list represents the clique
    implicitly.  Per pivot:

    - the pivot structure ``Lp`` is the union of its variable neighbours and
      the boundaries of its elements, which are *absorbed* into the new
      element (each element is scanned O(1) times over its life);
    - every ``v ∈ Lp`` gets an **approximate** external degree
      ``d(v) ≈ |A_v| + |Lp \\ v| + Σ_e |Le \\ Lp|`` (the classic AMD upper
      bound — element overlaps are counted once per element, not exactly),
      clamped by ``n_left - |v|`` and ``d_old + |Lp \\ v|``;
    - elements with ``|Le \\ Lp| = 0`` are **aggressively absorbed**;
    - variables whose entire structure is inside ``Lp`` are
      **mass-eliminated** with the pivot (no new fill, no new pivot search);
    - variables in ``Lp`` with identical quotient adjacency (same pruned
      variable set, same element set) are detected via a hash bucket over
      ``Σ ids`` and merged into **supervariables**, eliminated together.

    Returns the elimination permutation (supervariables expanded in merge
    order).  Degrees are weighted by supervariable size throughout, so the
    approximation tracks the true external degree of the compressed graph.
    """
    if n == 0:
        return np.empty(0, dtype=np.int64)
    INF = np.int64(1) << np.int64(60)
    adj = _sym_adj_sets(row, col, n)
    elem: List[list] = [[] for _ in range(n)]   # element lists per variable
    Le: dict = {}                               # alive elements: id -> [vars]
    wt = [1] * n                                # supervariable weights
    members: List[list] = [[v] for v in range(n)]
    status = [0] * n                            # 0 alive, 1 ordered, 2 merged
    deg = np.array([len(a) for a in adj], dtype=np.int64)
    order: List[int] = []
    nleft = n
    while nleft > 0:
        p = int(np.argmin(deg))
        # ---- pivot structure Lp = (A_p ∪ ⋃ Le[e]) \ {p, dead} -------------
        Lp_set: set = set()
        for e in elem[p]:
            le = Le.pop(e, None)                # absorb e into the new element
            if le is not None:
                Lp_set.update(le)
        Lp_set.update(adj[p])
        Lp = [v for v in Lp_set if status[v] == 0 and v != p]
        Lp_set = set(Lp)
        order.append(p)
        status[p] = 1
        deg[p] = INF
        nleft -= wt[p]
        adj[p] = set()
        elem[p] = []
        if not Lp:
            continue
        WLp = 0
        for v in Lp:
            WLp += wt[v]
        # ---- scan 1: prune neighbour lists, weigh |Le \ Lp| per element ---
        wext: dict = {}
        for v in Lp:
            av = adj[v]
            if av:
                adj[v] = {u for u in av
                          if status[u] == 0 and u not in Lp_set}
            ev = []
            for e in elem[v]:
                le = Le.get(e)
                if le is None:                  # absorbed earlier — drop
                    continue
                w = wext.get(e)
                if w is None:                   # first touch: compact + weigh
                    le2 = [u for u in le if status[u] == 0]
                    if len(le2) != len(le):
                        Le[e] = le = le2
                    w = 0
                    for u in le:
                        w += wt[u]
                wext[e] = w - wt[v]
                ev.append(e)
            elem[v] = ev
        Le[p] = Lp
        # ---- scan 2: approximate degrees, absorption, mass elim, hashing --
        buckets: dict = {}
        mass: List[int] = []
        for v in Lp:
            ext = 0
            ev2 = []
            for e in elem[v]:
                w = wext[e]
                if w <= 0 and aggressive:
                    Le.pop(e, None)             # Le[e] ⊆ Lp: absorbed by p
                    continue
                ev2.append(e)
                ext += w
            da = 0
            for u in adj[v]:
                da += wt[u]
            if ext == 0 and da == 0:
                elem[v] = []                    # struct(v) ⊆ Lp: mass elim
                mass.append(v)
                continue
            ev2.append(p)
            elem[v] = ev2
            d = da + (WLp - wt[v]) + ext
            bound = nleft - wt[v]
            if d > bound:
                d = bound
            ob = int(deg[v]) + WLp - wt[v]
            if d > ob:
                d = ob
            deg[v] = d
            h = 0
            for e in ev2:
                h += e
            for u in adj[v]:
                h += u
            buckets.setdefault(h % 1048573, []).append(v)
        for v in mass:
            order.append(v)
            status[v] = 1
            deg[v] = INF
            nleft -= wt[v]
            adj[v] = set()
        if mass:
            mset = set(mass)
            Le[p] = [v for v in Lp if v not in mset]
        # ---- supervariable merging (exact check within hash buckets) ------
        for bucket in buckets.values():
            if len(bucket) < 2:
                continue
            for k, v in enumerate(bucket):
                if status[v] != 0:
                    continue
                ve = None
                for u in bucket[k + 1:]:
                    if status[u] != 0 or len(elem[u]) != len(elem[v]):
                        continue
                    if ve is None:
                        ve = set(elem[v])
                    if adj[u] == adj[v] and ve == set(elem[u]):
                        wt[v] += wt[u]          # merge u into v
                        members[v].extend(members[u])
                        members[u] = []
                        status[u] = 2
                        deg[u] = INF
                        deg[v] -= wt[u]
                        adj[u] = set()
                        elem[u] = []
    perm = np.empty(n, dtype=np.int64)
    k = 0
    for r in order:
        for v in members[r]:
            perm[k] = v
            k += 1
    assert k == n, "AMD lost variables (quotient-graph bookkeeping bug)"
    return perm


def _etree_fill(n: int, rptr: np.ndarray, rcol: np.ndarray
                ) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Elimination tree + static fill pattern + level schedule in O(nnz(L)).

    One pass of Liu's etree construction fused with the row-subtree
    traversal: for permuted row ``i``, walking from every pattern entry
    ``k < i`` up the partial etree emits exactly the nonzeros of row ``i``
    of L (the walk is pruned at the first vertex already marked for ``i``,
    so each L entry is produced exactly once — the filled graph is never
    materialized).  Longest-path levels of the elimination DAG
    (``level(i) > level(j)`` for every L(i,j)) ride the same pass.

    Returns ``(Ri, Rj, level, parent)`` — L entries as (row, col) index
    arrays in permuted coordinates, the per-node level, and the etree parent
    (-1 at roots; the supernode partition reads ``parent[j] == j+1`` chains).
    """
    parent = [-1] * n
    mark = [-1] * n
    level = [0] * n
    ei: List[int] = []
    ej: List[int] = []
    rp = rptr.tolist()
    rc = rcol.tolist()
    for i in range(n):
        mark[i] = i
        lv = -1
        for t in range(rp[i], rp[i + 1]):
            j = rc[t]
            while mark[j] != i:
                mark[j] = i
                ei.append(i)
                ej.append(j)
                lj = level[j]
                if lj > lv:
                    lv = lj
                pj = parent[j]
                if pj == -1:
                    parent[j] = i
                    break
                j = pj
        level[i] = lv + 1
    return (np.asarray(ei, dtype=np.int64), np.asarray(ej, dtype=np.int64),
            np.asarray(level, dtype=np.int64),
            np.asarray(parent, dtype=np.int64))


def _pattern_levels(n: int, rptr: np.ndarray, rcol: np.ndarray) -> np.ndarray:
    """Longest-path levels when the L structure IS the (permuted strict
    lower) pattern — the zero-fill ILU(0)/IC(0) case needs no etree."""
    level = [0] * n
    rp = rptr.tolist()
    rc = rcol.tolist()
    for i in range(n):
        lv = -1
        for t in range(rp[i], rp[i + 1]):
            lj = level[rc[t]]
            if lj > lv:
                lv = lj
        level[i] = lv + 1
    return np.asarray(level, dtype=np.int64)


def _width(total: int, n_levels: int, lo: int = 32, hi: int = 1 << 16) -> int:
    """Step width ≈ mean level load, clamped and rounded DOWN to a power of
    two — few distinct shapes across patterns keeps XLA's compile cache
    warm, and the floor (vs the previous ceil) cuts the padded step area by
    ~30% on 2-D Poisson at n = 10⁴, which speeds the numeric factorization
    and the sweeps by the same fraction (the scan does strictly less padded
    work; measured 17–20% faster end-to-end)."""
    w = max(lo, min(hi, -(-total // max(n_levels, 1))))
    return 1 << max(int(np.floor(np.log2(w))), 5)


def _emit_factor(n: int, nnzL: int, Li: np.ndarray, Lptr: np.ndarray,
                 counts: np.ndarray, level: np.ndarray, n_levels: int,
                 lkeys: np.ndarray, incomplete: bool
                 ) -> Tuple[PackedFactor, int, int]:
    """Packed factorization program, emitted with vectorized placement.

    Columns are walked level by level (elimination DAG order).  Within one
    step the scan body runs finalize-then-update, so a column's updates may
    share its finalize step; a new level's finalizes must start strictly
    after any step holding earlier levels' updates (those updates write into
    the new level's entries and pivots).  Placement replicates the greedy
    fixed-width packer with prefix sums: finalize entries of a level are
    consecutive from ``max(cursor, ceil(up_cursor/w_up))``; each column's
    update tuples start no earlier than the step of its last finalize, which
    a running-max scan over ``f_i·w_up − Σ u_j`` resolves level-wide without
    a Python per-tuple loop.  ``lkeys`` is the sorted column-major key array
    ``col·n + row`` of L used to resolve update destinations (an update pair
    (i, j) maps to the diagonal, an L slot, or its mirrored U slot).

    Returns ``(program, n_steps, kept_updates)``.
    """
    szero = n + 2 * nnzL                       # scratch slots in C
    sone = szero + 1
    flops = int(np.sum(counts.astype(np.int64) ** 2))
    wf = _width(nnzL, n_levels)
    wu = _width(flops, n_levels)

    # ---- values (one vectorized pass over all levels) ---------------------
    # Columns in schedule order (level, then index); every T-sized array is
    # built globally — only the *placement* below walks levels, and it only
    # touches per-column scalars.
    colorder = np.argsort(level, kind="stable").astype(np.int64)
    lvl_cnt = np.bincount(level, minlength=n_levels)
    lvl_ptr = np.concatenate([[0], np.cumsum(lvl_cnt)])
    Li32 = Li.astype(np.int32)

    m = counts[colorder]
    mex = np.concatenate([[0], np.cumsum(m)])          # fin offsets/column
    F = int(mex[-1])                                   # == nnzL
    cid = np.repeat(np.arange(n, dtype=np.int64), m)   # fin item -> column pos
    lbase = Lptr[colorder]
    lidx = lbase[cid] + (np.arange(F, dtype=np.int64) - mex[cid])
    finl = (n + lidx).astype(np.int32)                 # fin lpos values
    finp = colorder[cid].astype(np.int32)              # fin pivot values
    rows = Li32[lidx]                                  # permuted row per item
    # update tuples: every (a, b) pair of each column's fin items.  Only the
    # strict a < b half is generated (item (k, a) spawns m_k − 1 − a minor
    # entries b = a+1..m_k−1); the mirrored (b, a) half and the diagonal
    # (a, a) tuples are derived arithmetically — a pair and its mirror share
    # one L slot index ``t`` (rows are sorted within a column, so a < b ⇔
    # Li[a] < Li[b]: the (a, b) tuple hits the mirror-U slot n+nnzL+t, the
    # (b, a) tuple the L slot n+t, the diagonal the pivot slot).
    kt = np.int32 if n <= 46340 else np.int64          # n² within int32?
    lk32 = lkeys.astype(kt) if kt is np.int32 else lkeys
    a_loc = np.arange(F, dtype=np.int64) - mex[cid]    # a within its column
    len1 = np.repeat(m, m) - 1 - a_loc                 # strict pairs per item
    T1 = int(len1.sum())
    gex1 = np.concatenate([[0], np.cumsum(len1)])[:-1]
    jidx = np.repeat(lidx + 1 - gex1, len1) \
        + np.arange(T1, dtype=np.int64)                # Lptr[col] + b
    jj = Li32[jidx]
    ii = np.repeat(rows, len1)                         # Li[base + a], ii < jj
    pa = np.repeat(finl, len1)                         # base + a
    pb = (jidx + n).astype(np.int32)                   # base + b
    lk = ii.astype(kt) * kt(n) + jj
    t = np.searchsorted(lk32, lk)
    if incomplete:                                     # ILU(0): drop fill
        tc = np.minimum(t, max(nnzL - 1, 0))
        keep = (lkeys[tc] == lk) if nnzL else np.zeros_like(lk, bool)
        t = tc[keep].astype(np.int32)
        jj, ii, pa, pb = jj[keep], ii[keep], pa[keep], pb[keep]
        P = np.bincount(np.repeat(cid, len1)[keep], minlength=n)
    else:
        # closure guard: every strict pair of an etree-derived structure
        # must hit its exact L slot — a miss here must fail fast, not
        # scatter updates into a wrong (or scratch) slot
        tc = np.minimum(t, max(nnzL - 1, 0))
        assert not t.size or bool((lkeys[tc] == lk).all()), \
            "fill closure violated"
        t = tc.astype(np.int32)
        P = (m * (m - 1)) // 2                         # strict pairs/column
    u = m + 2 * P                                      # diag + both halves
    kept_updates = int(m.sum() + 2 * t.size)
    uex = np.concatenate([[0], np.cumsum(u)])

    # ---- placement (per level, per-column scalars only) -------------------
    # barrier: a level's finalizes start strictly after any step holding
    # earlier levels' updates; a column's updates start no earlier than the
    # step of its last finalize (the scan body runs finalize-then-update,
    # so sharing that step is sound).  Greedy fixed-width packing resolves
    # to  d_i = max(d_{i-1}, f_i·wu − E_i)  over columns (running max),
    # column i's tuples then occupying slots [d_i + E_i, d_i + E_i + u_i).
    col_fs = np.zeros(n, dtype=np.int64)               # fin start slot/column
    col_us = np.zeros(n, dtype=np.int64)               # up start slot/column
    c_fin = 0
    c_up = 0
    for l in range(n_levels):
        s0, s1_ = lvl_ptr[l], lvl_ptr[l + 1]
        if s0 == s1_:
            continue
        Fl = int(mex[s1_] - mex[s0])
        if not Fl:
            continue
        start_f = max(c_fin, -(-c_up // wu) * wf)
        col_fs[s0:s1_] = start_f + (mex[s0:s1_] - mex[s0])
        c_fin = start_f + Fl
        ml = m[s0:s1_]
        f = np.where(ml > 0, (col_fs[s0:s1_] + ml - 1) // wf, 0)
        ul = u[s0:s1_]
        Kl = int(uex[s1_] - uex[s0])
        if not Kl:
            continue
        E = uex[s0:s1_] - uex[s0]
        g = np.where(ul > 0, f * np.int64(wu) - E, 0)
        d = np.maximum.accumulate(np.concatenate([[c_up], g]))[1:]
        col_us[s0:s1_] = d + E
        c_up = int(d[-1] + E[-1] + ul[-1])

    # column k's slot block [col_us[k], col_us[k] + u_k) is laid out as
    # [diag tuples | (a, b) half | mirrored (b, a) half], each group
    # column-contiguous, so positions are repeats of per-column bases
    fin_pos = np.repeat(col_fs, m) + a_loc
    pos0 = np.repeat(col_us, m) + a_loc
    Pex = np.concatenate([[0], np.cumsum(P)])[:-1]
    pos1 = np.repeat(col_us + m - Pex, P) + np.arange(t.size, dtype=np.int64)
    pos2 = pos1 + np.repeat(P, P)
    fS = max(-(-c_fin // wf), -(-c_up // wu))
    nn = np.int32(nnzL)

    def grid(width, pad, writes):
        out = np.empty(fS * width, dtype=np.int32)
        out.fill(pad)
        for p, v in writes:
            out[p] = v
        return out.reshape(fS, width)

    factor = PackedFactor(
        fin_lpos=jnp.asarray(grid(wf, szero, [(fin_pos, finl)])),
        fin_piv=jnp.asarray(grid(wf, sone, [(fin_pos, finp)])),
        up_s1=jnp.asarray(grid(wu, szero, [(pos0, finl), (pos1, pa),
                                           (pos2, pb)])),
        up_s2=jnp.asarray(grid(wu, szero, [(pos0, finl + nn), (pos1, pb + nn),
                                           (pos2, pa + nn)])),
        up_dst=jnp.asarray(grid(wu, szero, [(pos0, rows),
                                            (pos1, np.int32(n) + nn + t),
                                            (pos2, np.int32(n) + t)])))
    return factor, fS, kept_updates


def _emit_sweep(n: int, nnzL: int, tgt: np.ndarray, src: np.ndarray,
                level: np.ndarray, n_levels: int,
                descending: bool) -> PackedSweep:
    """Packed program for one triangular-sweep direction (vectorized).

    Entries are grouped by the level of their *target* node (ascending for
    the row program, descending for the col program); within a level, a
    node's divide shares (or follows) the step of its last incoming add,
    and adds of different levels never share a step (the next level's floor
    is one past the last divide).  Same prefix-sum/cummax placement as the
    factorization program, two streams: adds (width ~ mean entries/level)
    and divides (width ~ mean nodes/level).
    """
    szero = n + 2 * nnzL
    sone = szero + 1
    we = _width(nnzL, n_levels)
    wd = _width(n, n_levels)

    gpos = level[tgt]
    npos = level
    if descending:
        gpos = (n_levels - 1) - gpos
        npos = (n_levels - 1) - npos
    eorder = np.lexsort((np.arange(nnzL), tgt, gpos))
    ecnt = np.bincount(gpos, minlength=n_levels)
    eptr = np.concatenate([[0], np.cumsum(ecnt)])
    norder = np.lexsort((np.arange(n), npos))
    ncnt = np.bincount(npos, minlength=n_levels)
    nptr = np.concatenate([[0], np.cumsum(ncnt)])

    c_e = 0
    c_d = 0
    floor = 0
    e_pos: List[np.ndarray] = []
    e_ent: List[np.ndarray] = []
    d_pos: List[np.ndarray] = []
    d_val: List[np.ndarray] = []
    for l in range(n_levels):
        vs = norder[nptr[l]:nptr[l + 1]]
        ets = eorder[eptr[l]:eptr[l + 1]]
        if not vs.size:
            assert not ets.size, "sweep entry without its target node?"
            floor += 1
            continue
        Q = ets.size
        if Q:
            start_e = max(c_e, floor * we)
            e_pos.append(start_e + np.arange(Q, dtype=np.int64))
            e_ent.append(ets)
            # per-node entry counts (entries sorted by target within level)
            tv = tgt[ets]
            q = (np.searchsorted(tv, vs, side="right")
                 - np.searchsorted(tv, vs, side="left"))
            assert int(q.sum()) == Q, "sweep entry without its target node?"
            cq = np.cumsum(q)
            f = np.where(q > 0, (start_e + cq - 1) // we, floor)
            c_e = start_e + Q
        else:
            f = np.full(vs.size, floor, dtype=np.int64)
        # one divide per node, floored at its last incoming add
        g = f * np.int64(wd) - np.arange(vs.size, dtype=np.int64)
        d = np.maximum.accumulate(np.concatenate([[c_d], g]))[1:]
        pos = d + np.arange(vs.size, dtype=np.int64)
        d_pos.append(pos)
        d_val.append(vs)
        c_d = int(pos[-1]) + 1
        floor = (c_d - 1) // wd + 1            # next level strictly after

    S = max(-(-c_e // we), -(-c_d // wd))

    def grid(pos_list, val_list, width, pad):
        out = np.empty(S * width, dtype=np.int32)
        out.fill(pad)
        if pos_list:
            out[np.concatenate(pos_list)] = np.concatenate(val_list)
        return out.reshape(S, width)

    ents = (np.concatenate(e_ent) if e_ent else np.empty(0, np.int64))
    epos = e_pos
    return PackedSweep(
        tgt=jnp.asarray(grid(epos, [tgt[ents]], we, n), jnp.int32),
        src=jnp.asarray(grid(epos, [src[ents]], we, n), jnp.int32),
        lpos=jnp.asarray(grid(epos, [n + ents], we, szero), jnp.int32),
        upos=jnp.asarray(grid(epos, [n + nnzL + ents], we, szero), jnp.int32),
        dn=jnp.asarray(grid(d_pos, d_val, wd, n), jnp.int32),
        dpiv=jnp.asarray(grid(d_pos, d_val, wd, sone), jnp.int32))


# ---------------------------------------------------------------------------
# supernodal analysis (fundamental chains -> dense-panel program)
# ---------------------------------------------------------------------------

def _supernode_partition(parent: np.ndarray, counts: np.ndarray,
                         max_w: int) -> np.ndarray:
    """Fundamental supernodes of the filled pattern, width-capped.

    Column ``j+1`` extends column ``j``'s supernode iff ``parent[j] == j+1``
    and ``counts[j+1] == counts[j] - 1`` — by the etree subset property this
    forces ``struct(j) = {j+1} ∪ struct(j+1)``, i.e. a dense trapezoidal
    panel.  AMD's hash-merged supervariables are expanded adjacently, so they
    land in one chain for free.  Returns supernode boundaries ``sptr``
    (ns+1,) with runs capped at ``max_w`` columns.
    """
    n = counts.size
    if n == 0:
        return np.zeros(1, dtype=np.int64)
    chain = np.zeros(n, dtype=bool)
    if n > 1:
        j = np.arange(n - 1, dtype=np.int64)
        chain[1:] = (parent[:-1] == j + 1) & (counts[1:] == counts[:-1] - 1)
    starts = [0]
    w = 1
    for jj in range(1, n):
        if chain[jj] and w < max_w:
            w += 1
        else:
            starts.append(jj)
            w = 1
    starts.append(n)
    return np.asarray(starts, dtype=np.int64)


def _amalgamate_pairs(n: int, Ri: np.ndarray, Rj: np.ndarray,
                      parent: np.ndarray, Li: np.ndarray, Lptr: np.ndarray,
                      sptr: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """Relaxed amalgamation: pad singleton etree-chain columns so they merge
    into pairable supernodes (the static Bunch–Kaufman prerequisite).

    A width-1 supernode {j} with ``parent[j] == j+1`` (e.g. the sibling-leaf
    chains AMD emits around indefinite saddle blocks) is padded to
    ``struct(j) := {j+1} ∪ struct(j+1)`` — a pure superset by the etree
    property, so fill closure and the level schedule stay valid — which makes
    the fundamental-chain condition hold and fuses {j} with the following
    supernode on re-partition.  Merges never chain: a merge target is
    consumed and cannot initiate its own merge (left-to-right scan), keeping
    the extra fill at one struct-union per pair instead of densifying
    tridiagonal-like patterns.  Returns (possibly padded) (Ri, Rj).
    """
    w = np.diff(sptr)
    pad_i: List[np.ndarray] = []
    pad_j: List[np.ndarray] = []
    consumed = False
    for s in range(w.size - 1):
        if consumed:                    # this snode is a merge target
            consumed = False
            continue
        j = int(sptr[s])
        if w[s] != 1 or parent[j] != j + 1:
            continue
        cur = Li[Lptr[j]:Lptr[j + 1]]
        nxt = Li[Lptr[j + 1]:Lptr[j + 2]]
        target = np.union1d(nxt, np.asarray([j + 1], dtype=np.int64))
        assert np.setdiff1d(cur, target).size == 0, \
            "etree subset property violated in amalgamation"
        extra = np.setdiff1d(target, cur)
        if extra.size:
            pad_i.append(extra.astype(np.int64))
            pad_j.append(np.full(extra.size, j, dtype=np.int64))
        consumed = True
    if not pad_i:
        return Ri, Rj
    return (np.concatenate([Ri] + pad_i), np.concatenate([Rj] + pad_j))


def _sn_slots(ri, cj, n: int, nnzL: int, lkeys: np.ndarray, valid):
    """Vectorized C-slot lookup for supernode index grids.

    Entry (ri, cj): the pivot slot on the diagonal, the column-major L slot
    below it, the mirror-U slot above it; invalid (pad) entries land on the
    scratch sink.  Asserts fill closure for every valid off-diagonal entry.
    """
    ri = ri.astype(np.int64)
    cj = cj.astype(np.int64)
    key = np.where(ri > cj, cj * n + ri, ri * n + cj)
    t = np.searchsorted(lkeys, key)
    tc = np.minimum(t, max(nnzL - 1, 0))
    ok = (lkeys[tc] == key) if nnzL else np.zeros(key.shape, dtype=bool)
    assert bool((ok | ~valid | (ri == cj)).all()), \
        "supernode slot closure violated"
    slot = np.where(ri == cj, ri,
                    np.where(ri > cj, n + tc, n + nnzL + tc))
    return np.where(valid, slot, n + 2 * nnzL).astype(np.int32)


def _pow2(x: np.ndarray, lo: int) -> np.ndarray:
    v = np.maximum(np.asarray(x, dtype=np.int64), lo)
    out = np.ones_like(v)
    while True:
        mask = out < v
        if not mask.any():
            return out
        out = np.where(mask, out * 2, out)


def _emit_snode(n: int, nnzL: int, Li: np.ndarray, Lptr: np.ndarray,
                Ljc: np.ndarray, counts: np.ndarray, lkeys: np.ndarray,
                sptr: np.ndarray, want_pairs: bool,
                mode: str) -> Optional[SnodeProgram]:
    """Emit the supernodal panel program (or None when ``mode="auto"``
    declines — narrow chains / deep schedules where the scalar scan wins).

    Supernodes are scheduled by assembly-tree level (longest path over
    cross-supernode L edges — every edge source has the smaller supernode id,
    so one ascending pass computes levels), then bucketed by padded panel
    shape (pow2 width/sub-row counts, pow2 lane counts) so the number of
    distinct compiled panel kernels is logarithmic in problem size.
    """
    ns = sptr.size - 1
    if ns == 0:
        return None
    c0 = sptr[:-1]
    c1 = sptr[1:]
    w = c1 - c0
    r = counts[c1 - 1]
    assert bool((counts[c0] == w - 1 + r).all()), \
        "fundamental supernode chain violated"
    mean_w = float(n) / float(ns)
    col2s = np.repeat(np.arange(ns, dtype=np.int64), w)

    # assembly-tree levels over cross-supernode dependencies
    es = col2s[Ljc]
    ed = col2s[Li]
    msk = es != ed
    es, ed = es[msk], ed[msk]
    eo = np.argsort(ed, kind="stable")
    es, ed = es[eo], ed[eo]
    eptr = np.searchsorted(ed, np.arange(ns + 1, dtype=np.int64))
    slev = np.zeros(ns, dtype=np.int64)
    for s in range(ns):
        lo, hi = eptr[s], eptr[s + 1]
        if hi > lo:
            slev[s] = int(slev[es[lo:hi]].max()) + 1

    wb_of = _pow2(w, 2)
    rb_of = _pow2(r, 4)
    groups: dict = {}
    for s in range(ns):
        groups.setdefault(
            (int(slev[s]), int(wb_of[s]), int(rb_of[s])), []).append(s)
    n_groups = len(groups)
    nnz_sn = w * r + (w * (w - 1)) // 2
    panel_fraction = (float(nnz_sn[w >= 2].sum()) / float(max(nnzL, 1)))
    stats = {"n_snodes": int(ns), "mean_snode_width": mean_w,
             "panel_fraction": panel_fraction, "n_groups": n_groups,
             "n_slevels": int(slev.max()) + 1 if ns else 0}

    if mode == "auto" and not want_pairs:
        # the panel path pays off when each bucketed kernel launch batches
        # many supernode lanes (level-parallel elimination) — narrow snodes
        # are fine (2-D Poisson averages ~1.3 and still wins 3-4x on the
        # lane batching alone), but a sequential chain — e.g. a tridiagonal,
        # where every snode is its own level with one lane — would serialize
        # n tiny kernel launches and lose to the scalar scan
        lanes_per_group = float(ns) / float(max(n_groups, 1))
        if n < 512 or lanes_per_group < 4.0 or n_groups > 4096:
            return None

    nlev = int(slev.max()) + 1 if ns else 1
    by_level: List[List[SnodeBucket]] = [[] for _ in range(nlev)]
    pair_p1: List[np.ndarray] = []
    for (lv, wb, rb), members in sorted(groups.items()):
        idx = np.asarray(members, dtype=np.int64)
        k = idx.size
        kp = 1 << max(int(k - 1).bit_length(), 0)   # pow2 lanes, pads no-op
        c0g = c0[idx]
        wg = w[idx]
        rg = r[idx]
        aw = np.arange(wb, dtype=np.int64)
        ar = np.arange(rb, dtype=np.int64)
        tw = aw[None, :] < wg[:, None]
        ta = ar[None, :] < rg[:, None]
        rows_blk = np.where(tw, c0g[:, None] + aw[None, :], n)
        pstart = Lptr[c1[idx] - 1]
        gidx = np.minimum(pstart[:, None] + ar[None, :], max(nnzL - 1, 0))
        rows_sub = np.where(ta, Li[gidx], n)
        rows = np.concatenate([rows_blk, rows_sub], axis=1)   # (k, wb+rb)
        cjs = c0g[:, None] + aw[None, :]                      # (k, wb)
        vP = (rows < n)[:, :, None] & tw[:, None, :]
        pidx = _sn_slots(rows[:, :, None], cjs[:, None, :], n, nnzL,
                         lkeys, vP)
        vQ = tw[:, :, None] & ta[:, None, :]
        qidx = _sn_slots(cjs[:, :, None], rows_sub[:, None, :], n, nnzL,
                         lkeys, vQ)
        vU = ta[:, :, None] & ta[:, None, :]
        uidx = _sn_slots(rows_sub[:, :, None], rows_sub[:, None, :], n, nnzL,
                         lkeys, vU)
        if want_pairs:
            bkm = tw & (aw[None, :] % 2 == 0) & (aw[None, :] + 1 < wg[:, None])
            for l in range(k):
                offs = np.arange(0, int(wg[l]) - 1, 2, dtype=np.int64)
                if offs.size:
                    pair_p1.append(c0g[l] + offs)
        else:
            bkm = np.zeros((k, wb), dtype=bool)
        if kp > k:                                            # pad lanes
            pad = kp - k

            def lanepad(arr, fill):
                ext = np.full((pad,) + arr.shape[1:], fill, arr.dtype)
                return np.concatenate([arr, ext], axis=0)

            szero = np.int32(n + 2 * nnzL)
            pidx = lanepad(pidx, szero)
            qidx = lanepad(qidx, szero)
            uidx = lanepad(uidx, szero)
            rows = lanepad(rows, n)
            wg = lanepad(wg, 0)
            rg = lanepad(rg, 0)
            bkm = lanepad(bkm, False)
        by_level[lv].append(SnodeBucket(
            wb=int(wb), rb=int(rb), pairs=bool(want_pairs and bkm.any()),
            pidx=jnp.asarray(pidx), qidx=jnp.asarray(qidx),
            uidx=jnp.asarray(uidx),
            rows=jnp.asarray(rows.astype(np.int32)),
            wvec=jnp.asarray(wg.astype(np.int32)),
            rvec=jnp.asarray(rg.astype(np.int32)),
            bkm=jnp.asarray(bkm)))

    if pair_p1:
        p1 = np.sort(np.concatenate(pair_p1))
        key = p1 * np.int64(n) + (p1 + 1)          # L(t+1, t), col-major key
        t = np.searchsorted(lkeys, key)
        tc = np.minimum(t, max(nnzL - 1, 0))
        assert bool((lkeys[tc] == key).all()), \
            "pair pivot off the fundamental chain"
        pair_cols = np.stack([p1, p1 + 1], axis=1)
        pair_off = np.stack([n + nnzL + tc, n + tc], axis=1)   # (b, c) slots
        unpaired = np.ones(n, dtype=bool)
        unpaired[p1] = False
        unpaired[p1 + 1] = False
    else:
        pair_cols = np.zeros((0, 2), dtype=np.int64)
        pair_off = np.zeros((0, 2), dtype=np.int64)
        unpaired = np.ones(n, dtype=bool)
    stats["n_pair_pivots"] = int(pair_cols.shape[0])
    return SnodeProgram(
        schedule=tuple(tuple(b) for b in by_level),
        pair_cols=jnp.asarray(pair_cols.astype(np.int32)),
        pair_off=jnp.asarray(pair_off.astype(np.int32)),
        unpaired=jnp.asarray(unpaired),
        stats=stats)


def symbolic_factor(row, col, n: int, *, ordering: str = "amd",
                    incomplete: bool = False,
                    supernodal: Optional[str] = None,
                    pivot_blocks: Optional[str] = None) -> DirectArtifacts:
    """Analyze one sparsity pattern for direct (or incomplete) factorization.

    This is the plan engine's ``analyze`` stage: values-free, eager numpy,
    run ONCE per sparsity pattern and shared by every ``with_values``
    refresh, every shared-pattern batch element, the adjoint's transposed
    solves, ``precond="ilu"``, the AMG coarsest level, and ``slogdet``.

    Parameters
    ----------
    row, col : integer index arrays (COO, concrete — never tracers).
    n : matrix dimension.
    ordering : fill-reducing ordering of the symmetrized pattern graph.

        - ``"amd"`` (default) — approximate minimum degree on a quotient
          graph (:func:`_amd_order`): element absorption, hash-based
          supervariable detection, aggressive absorption and mass
          elimination.  Near-MD fill quality at a fraction of the analyze
          cost; the whole pipeline is ~15–20× faster than ``"md"`` at
          n = 10⁴.
        - ``"md"`` — exact minimum degree (clique-forming elimination),
          retained for A/B fill-quality comparisons.
        - ``"rcm"`` — reverse Cuthill–McKee (scipy when available,
          identity fallback otherwise).
        - ``"natural"`` — identity permutation.
    incomplete : ``True`` produces the ILU(0)/IC(0) program — same storage
        and kernels, zero fill (update tuples restricted to the original
        symmetrized pattern), no elimination tree needed.  Degree-based
        orderings are pointless at zero fill, so ``"amd"``/``"md"`` resolve
        to ``"natural"`` (ILU(0) keeps the assembly order).  The supernodal
        program needs the etree, so incomplete factorizations always stay on
        the scalar path.
    supernodal : ``"auto"``/``"on"``/``"off"`` — emit the dense-panel
        supernodal program next to the scalar one (``numeric_factor`` and
        ``factored_solve`` route through it when present).  ``None``
        (default) reads the :mod:`repro.core.options` ``supernodal`` knob at
        analyze time.  ``"auto"`` declines narrow-chain patterns where the
        scalar scan wins; ``"off"`` is the A/B baseline.
    pivot_blocks : ``"auto"`` requests static Bunch–Kaufman 2x2 pivot blocks
        chosen at analyze time: singleton etree-chain columns are
        amalgamated into pairable supernodes and every supernode's even
        column offsets start a 2x2 pivot, eliminated jointly at numeric
        time — indefinite (saddle-point) systems factor without the
        zero-pivot perturbation stopgap.  Requires the supernodal path
        (``supernodal="off"`` raises); ``None`` keeps plain 1x1 pivots.

    Raises ``ValueError`` when the pattern lacks a structurally full
    diagonal (no pivoting is performed, so every pivot must exist
    structurally; see ``numeric_factor``'s ``pivot_guard`` for the
    *numerically* zero case).

    The analysis is eager even when invoked inside a jit trace (plans are
    cached on long-lived SparseTensors, so the index tensors must be
    concrete arrays, never trace-bound constants).  Nothing values-dependent
    may be captured here — that is ``setup``'s job.
    """
    with jax.ensure_compile_time_eval():
        return _symbolic_factor(row, col, n, ordering, incomplete,
                                supernodal, pivot_blocks)


def _symbolic_factor(row, col, n: int, ordering: str, incomplete: bool,
                     supernodal: Optional[str] = None,
                     pivot_blocks: Optional[str] = None) -> DirectArtifacts:
    row = np.asarray(row, dtype=np.int64)
    col = np.asarray(col, dtype=np.int64)
    from .sparse import has_full_diagonal
    if not has_full_diagonal(row, col, n):
        raise ValueError(
            "direct factorization needs a structurally full diagonal "
            "(no pivoting); use an iterative backend for this pattern")

    if supernodal is None:
        from . import options as _options
        supernodal = _options.current().supernodal
    if supernodal not in ("auto", "on", "off"):
        raise ValueError(
            f"supernodal must be 'auto'|'on'|'off', got {supernodal!r}")
    if pivot_blocks not in (None, "auto"):
        raise ValueError(
            f"pivot_blocks must be None or 'auto', got {pivot_blocks!r}")
    want_pairs = pivot_blocks == "auto"
    if incomplete:
        if want_pairs:
            raise ValueError(
                "pivot_blocks needs the full (etree) factorization; "
                "incomplete=True has no pivoting")
        supernodal = "off"          # ILU(0) has no etree — scalar program
    if want_pairs and supernodal == "off":
        raise ValueError(
            "pivot_blocks='auto' requires the supernodal path "
            "(supernodal='off' keeps the scalar 1x1-pivot program)")

    if incomplete and ordering in ("amd", "md"):
        ordering = "natural"        # ILU(0) keeps the assembly order
    if ordering == "amd":
        perm = _amd_order(row, col, n)
    elif ordering == "md":
        perm = _exact_md_order(row, col, n)
    elif ordering == "rcm":
        perm = _rcm_order(row, col, n)
    elif ordering == "natural":
        perm = np.arange(n, dtype=np.int64)
    else:
        raise ValueError(f"unknown ordering {ordering!r}")
    ipos = np.empty(n, dtype=np.int64)
    ipos[perm] = np.arange(n)

    # strict-lower symmetrized pattern in permuted coordinates, CSR by row
    rptr, rcol = _sym_lower_csr(row, col, n, ipos)
    if incomplete:                  # zero fill: the L structure IS the pattern
        Ri, Rj = np.repeat(np.arange(n, dtype=np.int64),
                           np.diff(rptr)), rcol
        level = _pattern_levels(n, rptr, rcol)
        parent = None
    else:                           # etree pass: fill without the filled graph
        Ri, Rj, level, parent = _etree_fill(n, rptr, rcol)
    n_levels = int(level.max()) + 1 if n else 1

    # L pattern, column-major: column k holds sorted permuted row indices.
    corder = np.lexsort((Ri, Rj))
    Li = Ri[corder]
    counts = np.bincount(Rj, minlength=n).astype(np.int64)
    Lptr = np.concatenate([[0], np.cumsum(counts)])

    # supernode partition (+ Bunch–Kaufman pair amalgamation, which pads the
    # pattern — a superset, so ``level`` stays a valid schedule and every
    # closure assert below still holds)
    sptr = None
    if parent is not None and supernodal != "off" and n:
        sptr = _supernode_partition(parent, counts, SN_MAX_W)
        if want_pairs:
            Ri2, Rj2 = _amalgamate_pairs(n, Ri, Rj, parent, Li, Lptr, sptr)
            if Ri2 is not Ri:
                Ri, Rj = Ri2, Rj2
                corder = np.lexsort((Ri, Rj))
                Li = Ri[corder]
                counts = np.bincount(Rj, minlength=n).astype(np.int64)
                Lptr = np.concatenate([[0], np.cumsum(counts)])
            sptr = _supernode_partition(parent, counts, SN_MAX_W)
    nnzL = int(Lptr[-1])
    nnzF = n + 2 * nnzL
    lkeys = Rj[corder] * np.int64(n) + Li      # sorted: position lookup in L

    # scatter map for A's entries into F = diag ∪ L ∪ mirror(U)
    pi, pj = ipos[row], ipos[col]
    ak = np.minimum(pi, pj) * np.int64(n) + np.maximum(pi, pj)
    at = np.searchsorted(lkeys, ak)
    at = np.minimum(at, max(nnzL - 1, 0))
    aok = (lkeys[at] == ak) if nnzL else np.zeros_like(ak, bool)
    diag = pi == pj
    assert bool((aok | diag).all()), \
        "A entry outside its own symmetrized pattern?"
    a2f = np.where(diag, pi, np.where(pi > pj, n + at, n + nnzL + at))

    factor, fS, kept_updates = _emit_factor(
        n, nnzL, Li, Lptr, counts, level, n_levels, lkeys, incomplete)

    # row program (levels leaf→root): forward-L and transposed-Uᵀ sweeps;
    # col program (root→leaf): backward-U and transposed-Lᵀ sweeps.
    Ljc = Rj[corder]
    row_sweep = _emit_sweep(n, nnzL, Li, Ljc, level, n_levels,
                            descending=False)
    col_sweep = _emit_sweep(n, nnzL, Ljc, Li, level, n_levels,
                            descending=True)

    snode = None
    if sptr is not None:
        snode = _emit_snode(n, nnzL, Li, Lptr, Ljc, counts, lkeys, sptr,
                            want_pairs, supernodal)

    stats = {"nnz_L": nnzL, "n_levels": n_levels, "flops": kept_updates,
             "fill_ratio": float(nnzF) / float(max(len(row), 1)),
             "n_steps": fS, "ordering": ordering, "incomplete": incomplete,
             "supernodal": snode is not None}
    if snode is not None:
        stats.update(snode.stats)
    return DirectArtifacts(
        n=n, nnzF=nnzF,
        perm=jnp.asarray(perm, jnp.int32), ipos=jnp.asarray(ipos, jnp.int32),
        a2f=jnp.asarray(a2f, jnp.int32),
        factor=factor, row_sweep=row_sweep, col_sweep=col_sweep, stats=stats,
        snode=snode)


# ---------------------------------------------------------------------------
# shard-local factorization (the distributed plan engine's Schwarz stage)
# ---------------------------------------------------------------------------

class SchwarzArtifacts(NamedTuple):
    """Product of :func:`schwarz_symbolic` — ONE union-pattern symbolic
    factorization shared by every shard, plus the per-shard numeric assembly
    programs.  Everything is pattern-only; the numeric half is a plain
    ``vmap(numeric_factor)`` over per-shard values at setup time."""
    art: DirectArtifacts     # ILU(0)/IC(0) program on the union pattern
    nnz_u: int               # union-pattern nonzeros
    src: jax.Array           # (P, m) gather into flat values (+zero slot last)
    dst: jax.Array           # (P, m) scatter into union slots (pads → nnz_u)
    diag_fix: jax.Array      # (P, nnz_u) +1.0 on structurally-absent diagonals


def schwarz_symbolic(entries, n_ext: int, n_src: int) -> SchwarzArtifacts:
    """Analyze shard-local extended matrices for overlapping Schwarz.

    ``entries[q]`` lists shard ``q``'s extended-domain matrix as
    ``(rows, cols, srcs)`` — COO coordinates in ``[0, n_ext)`` plus the flat
    index of each entry's value in the global value storage (length
    ``n_src``; a trailing zero slot is appended at gather time).  The
    extended matrices of all shards are unioned into ONE sparsity pattern so
    a single zero-fill (ILU(0)/IC(0)) step program — built by
    :func:`symbolic_factor` — serves every shard under ``vmap``/``shard_map``:
    per-shard numeric values are scattered into union slots, structurally
    absent diagonals (phantom halos of edge shards, padded tail rows) are
    completed with 1.0 identity pivots, and entries another shard has but
    this one lacks stay numerically zero.
    """
    p = len(entries)
    keys = [r.astype(np.int64) * n_ext + c.astype(np.int64)
            for r, c, _ in entries]
    dkeys = np.arange(n_ext, dtype=np.int64) * (n_ext + 1)
    ukeys = np.unique(np.concatenate(keys + [dkeys]))
    nnz_u = int(ukeys.size)
    urow = (ukeys // n_ext).astype(np.int64)
    ucol = (ukeys % n_ext).astype(np.int64)

    m = max(max((k.size for k in keys), default=1), 1)
    src = np.full((p, m), n_src, dtype=np.int64)        # pads → zero slot
    dst = np.full((p, m), nnz_u, dtype=np.int64)        # pads → dump slot
    diag_fix = np.ones((p, nnz_u), dtype=np.float64)
    dslot = np.searchsorted(ukeys, dkeys)
    for q, (k, (_, _, s)) in enumerate(zip(keys, entries)):
        slot = np.searchsorted(ukeys, k)
        src[q, :k.size] = np.asarray(s, np.int64)
        dst[q, :k.size] = slot
        diag_fix[q] = 0.0
        have = np.zeros(nnz_u, bool)
        have[slot] = True
        diag_fix[q, dslot[~have[dslot]]] = 1.0          # identity completion

    art = symbolic_factor(urow, ucol, n_ext, incomplete=True)
    return SchwarzArtifacts(art=art, nnz_u=nnz_u,
                            src=jnp.asarray(src, jnp.int32),
                            dst=jnp.asarray(dst, jnp.int32),
                            diag_fix=jnp.asarray(diag_fix))


def schwarz_numeric(sch: SchwarzArtifacts, flat_val: jax.Array) -> jax.Array:
    """Traced-safe numeric half: assemble every shard's extended matrix from
    the flat global values and refactorize — ``(P, nnzF + 2)`` stacked
    factors, one per shard (the setup stage of ``precond='schwarz'``)."""
    padded = jnp.concatenate([flat_val, jnp.zeros((1,), flat_val.dtype)])

    def one_shard(src_q, dst_q, fix_q):
        v = jnp.zeros(sch.nnz_u + 1, flat_val.dtype).at[dst_q].add(
            padded[src_q])[:-1]
        return numeric_factor(sch.art, v + fix_q.astype(flat_val.dtype))

    return jax.vmap(one_shard)(sch.src, sch.dst, sch.diag_fix)


# ---------------------------------------------------------------------------
# supernodal numeric drivers (per-bucket compiled panel kernels)
# ---------------------------------------------------------------------------

def _sn_use_pallas() -> bool:
    """Pallas panel kernels on compiled backends; jnp oracles on CPU (the
    same routing as the fused solver steps — interpret-mode emulation would
    serialize the panel loops)."""
    from ..kernels.solve_step import default_interpret
    return not default_interpret()


@functools.lru_cache(maxsize=256)
def _sn_factor_fn(wb: int, rb: int, pairs: bool, guard: bool,
                  use_pallas: bool):
    """One compiled factorize step for a bucket shape: gather panels, dense
    panel factorization, scatter back, Schur GEMM, extend-add.  Cached per
    padded shape — pow2 bucketing keeps the number of distinct compilations
    logarithmic in problem size."""
    from ..kernels import ref as _kref
    from ..kernels import supernode as _ksn

    @jax.jit
    def fn(C, tau, pidx, qidx, uidx, wvec, rvec, bkm):
        P = C[pidx]
        Q = C[qidx]
        if use_pallas:
            P, Q, nbad = _ksn.panel_factor(P, Q, wvec, rvec, tau, bkm,
                                           pairs=pairs, guard=guard)
            S = _ksn.schur_update(P, Q)
        else:
            P, Q, nbad = _kref.sn_panel_factor_ref(P, Q, wvec, rvec, tau,
                                                   bkm, pairs=pairs,
                                                   guard=guard)
            S = _kref.sn_schur_ref(P, Q)
        # pad slots all point at the scratch sink; colliding pad writes are
        # masked zeros/ones and every later gather re-masks, so the sink's
        # value is never observed
        C = C.at[pidx].set(P)
        C = C.at[qidx].set(Q)
        C = C.at[uidx].add(-S)
        return C, nbad

    return fn


@functools.lru_cache(maxsize=512)
def _sn_sweep_fn(wb: int, rb: int, pairs: bool, mode: str, use_pallas: bool):
    """One compiled triangular-sweep step for a bucket shape.

    ``mode``: ``"l"`` forward unit-L (block trsv + L-panel GEMV scatter),
    ``"u"`` backward U (U-panel GEMV gather + block trsv with pivots),
    ``"ut"``/``"lt"`` the transposed mirrors on the same factors.  The
    solution vector carries a scratch element at index n; every write to it
    is a masked zero, so pad gathers always read 0.
    """
    from ..kernels import ref as _kref
    from ..kernels import supernode as _ksn

    def trsv(D, yb, wvec, bkm):
        if use_pallas:
            return _ksn.block_trsv(D, yb, wvec, bkm, mode=mode, pairs=pairs)
        return _kref.sn_trsv_ref(D, yb, wvec, bkm, mode=mode, pairs=pairs)

    @jax.jit
    def fn(C, y, pidx, qidx, rows, wvec, rvec, bkm):
        tw = jnp.arange(wb)[None, :] < wvec[:, None]
        ta = jnp.arange(rb)[None, :] < rvec[:, None]
        D = C[pidx[:, :wb, :]]
        rb_rows = rows[:, wb:]
        wb_rows = rows[:, :wb]
        if mode in ("l", "lt"):
            Pp = jnp.where(ta[:, :, None] & tw[:, None, :],
                           C[pidx[:, wb:, :]], 0.0)
        else:
            Qm = jnp.where(tw[:, :, None] & ta[:, None, :], C[qidx], 0.0)
        if mode == "l":
            yb = trsv(D, y[wb_rows], wvec, bkm)
            upd = jnp.einsum("kaw,kw->ka", Pp, yb)
            y = y.at[wb_rows].set(jnp.where(tw, yb, 0.0))
            return y.at[rb_rows].add(-upd)
        if mode == "u":
            xb0 = y[wb_rows] - jnp.einsum("ktr,kr->kt", Qm, y[rb_rows])
            xb = trsv(D, xb0, wvec, bkm)
            return y.at[wb_rows].set(jnp.where(tw, xb, 0.0))
        if mode == "ut":
            yb = trsv(D, y[wb_rows], wvec, bkm)
            upd = jnp.einsum("ktr,kt->kr", Qm, yb)
            y = y.at[wb_rows].set(jnp.where(tw, yb, 0.0))
            return y.at[rb_rows].add(-upd)
        # mode == "lt"
        xb0 = y[wb_rows] - jnp.einsum("kaw,ka->kw", Pp, y[rb_rows])
        xb = trsv(D, xb0, wvec, bkm)
        return y.at[wb_rows].set(jnp.where(tw, xb, 0.0))

    return fn


def _pow2_pad(x: jax.Array) -> jax.Array:
    """Pad a 1-D array with zeros to the next power-of-two length.

    The per-bucket jits specialize on every argument shape, so without this
    each distinct pattern (distinct nnzF / n) would recompile every bucket
    program it touches; padding collapses the storage lengths to log-many
    values and the compiled executables are shared across patterns.  All
    panel/sweep indices point below the original length, so the pad region
    is never read or written.
    """
    m = x.shape[0]
    mp = 1 << max(int(m - 1).bit_length(), 0)
    if mp > m:
        x = jnp.concatenate([x, jnp.zeros((mp - m,), x.dtype)])
    return x


def _snode_numeric(art: DirectArtifacts, C: jax.Array, tau: jax.Array,
                   guard: bool) -> Tuple[jax.Array, jax.Array]:
    """Run the supernodal factorization schedule over the assembled C."""
    sn = art.snode
    use_pallas = _sn_use_pallas()
    nbad = jnp.zeros((), C.dtype)
    m = C.shape[0]
    C = _pow2_pad(C)
    for lvl in sn.schedule:
        for bk in lvl:
            fn = _sn_factor_fn(bk.wb, bk.rb, bk.pairs, bool(guard),
                               use_pallas)
            C, nb = fn(C, tau, bk.pidx, bk.qidx, bk.uidx, bk.wvec, bk.rvec,
                       bk.bkm)
            nbad = nbad + nb
    return C[:m], nbad


def _snode_solve(art: DirectArtifacts, C: jax.Array, b: jax.Array,
                 transposed: bool) -> jax.Array:
    """Supernodal triangular sweeps (forward or transposed) on the panel
    factors — ascending levels for L/Uᵀ, descending for U/Lᵀ."""
    sn = art.snode
    use_pallas = _sn_use_pallas()
    # pow2-pad both operands so the sweep programs are shared across
    # patterns (see _pow2_pad); sweep indices never touch the pad regions
    C = _pow2_pad(C)
    y = _pow2_pad(jnp.concatenate([b[art.perm], jnp.zeros((1,), b.dtype)]))

    def run(y, levels, mode):
        for lvl in levels:
            for bk in lvl:
                fn = _sn_sweep_fn(bk.wb, bk.rb, bk.pairs, mode, use_pallas)
                y = fn(C, y, bk.pidx, bk.qidx, bk.rows, bk.wvec, bk.rvec,
                       bk.bkm)
        return y

    if transposed:
        y = run(y, sn.schedule, "ut")
        y = run(y, tuple(reversed(sn.schedule)), "lt")
    else:
        y = run(y, sn.schedule, "l")
        y = run(y, tuple(reversed(sn.schedule)), "u")
    return y[art.ipos]


def factor_slogdet(art: DirectArtifacts, C: jax.Array
                   ) -> Tuple[jax.Array, jax.Array]:
    """(sign, log|det A|) from the factors — pivot-block aware.

    The scalar path's determinant is the pivot product.  With static
    Bunch–Kaufman pairs a 2x2 block contributes ``a·e − b·c`` (its raw
    entries live in the pivot slots and the pair's b/c slots), NOT
    ``a·e`` — ``sparse_slogdet`` routes through here so indefinite factors
    report the correct sign.
    """
    n = art.n
    piv = C[:n]
    sn = art.snode
    if sn is None or sn.pair_cols.shape[0] == 0:
        return jnp.prod(jnp.sign(piv)), jnp.sum(jnp.log(jnp.abs(piv)))
    unp = sn.unpaired
    d = jnp.where(unp, piv, 1.0)
    sign = jnp.prod(jnp.sign(d))
    logabs = jnp.sum(jnp.where(unp, jnp.log(jnp.abs(d)), 0.0))
    a = piv[sn.pair_cols[:, 0]]
    e = piv[sn.pair_cols[:, 1]]
    det = a * e - C[sn.pair_off[:, 0]] * C[sn.pair_off[:, 1]]
    return (sign * jnp.prod(jnp.sign(det)),
            logabs + jnp.sum(jnp.log(jnp.abs(det))))


# ---------------------------------------------------------------------------
# numeric factorization (traced-safe — the setup stage)
# ---------------------------------------------------------------------------

def numeric_factor(art: DirectArtifacts, val: jax.Array, *,
                   pivot_guard: bool = True,
                   pivot_eps: Optional[float] = None) -> jax.Array:
    """Numeric LU/LDLᵀ over the precomputed fill pattern.

    One ``lax.scan`` over the packed step program: pure gather/scatter with
    uniform shapes, so it compiles once, jits, and vmaps over a leading batch
    dimension of ``val`` (shared-pattern batches).  Duplicate COO entries
    accumulate, matching ``coo_matvec`` semantics.

    ``pivot_guard`` (default on): a structurally-present but numerically-
    (near-)zero pivot would silently turn the whole factorization into NaNs
    — no numerical pivoting is performed.  The guard applies a *static
    diagonal perturbation* at divide time instead: any pivot with
    ``|d| < τ`` (τ = ``pivot_eps`` or ``√eps·max|A|``) is replaced by
    ``±τ``, persisted in the factor storage so the triangular sweeps stay
    consistent, and — when the values are concrete (not inside a trace) —
    reported with a ``UserWarning``.  The perturbed factorization solves a
    nearby matrix; proper Bunch–Kaufman pivoting for indefinite systems
    remains a ROADMAP follow-up (this is the documented stopgap).
    """
    scale = jnp.max(jnp.abs(val))
    tau = jnp.asarray(
        pivot_eps if pivot_eps is not None
        else jnp.sqrt(jnp.finfo(val.dtype).eps) * jnp.maximum(scale, 1e-300),
        val.dtype)
    C = jnp.zeros(art.nnzF + 2, dtype=val.dtype)
    C = C.at[art.a2f].add(val).at[art.nnzF + 1].set(1.0)

    if art.snode is not None:
        C, nbad = _snode_numeric(art, C, tau, pivot_guard)
        if (not isinstance(val, jax.core.Tracer)
                and not isinstance(nbad, jax.core.Tracer)):
            n_bad = int(nbad)
            if n_bad:
                import warnings
                warnings.warn(
                    f"numeric factorization hit {n_bad} numerically-zero "
                    f"pivot(s); applied a scaled diagonal perturbation "
                    f"(|d|<{float(tau):.2e} -> ±{float(tau):.2e}). The "
                    f"factors solve a nearby matrix — consider an iterative "
                    f"backend or a symmetric shift for indefinite systems.")
        return C

    if not pivot_guard:
        def step(C, xs):
            fl, fpv, s1, s2, dst = xs
            C = C.at[fl].set(C[fl] / C[fpv])
            C = C.at[dst].add(-C[s1] * C[s2])
            return C, None

        C, _ = lax.scan(step, C, tuple(art.factor))
        return C

    # perturbation BOOKKEEPING (for the warning) only runs when the values
    # are concrete — its sole consumer is the eager warning, which can never
    # fire under jit/vmap, so traced factorizations keep the lean scan body
    # (the safe-pivot clamp itself is always on)
    track = not isinstance(val, jax.core.Tracer)

    def clamp(C, fpv):
        piv = C[fpv]
        bad = jnp.abs(piv) < tau            # pads divide by scratch 1.0 — ok
        safe = jnp.where(bad, jnp.where(piv >= 0, tau, -tau), piv)
        return C.at[fpv].set(safe), bad     # persist: sweeps see the same d

    if not track:
        def step(C, xs):
            fl, fpv, s1, s2, dst = xs
            C, _ = clamp(C, fpv)
            C = C.at[fl].set(C[fl] / C[fpv])
            C = C.at[dst].add(-C[s1] * C[s2])
            return C, None

        C, _ = lax.scan(step, C, tuple(art.factor))
        return C

    pert0 = jnp.zeros(art.nnzF + 2, dtype=bool)

    def step(carry, xs):
        C, pert = carry
        fl, fpv, s1, s2, dst = xs
        C, bad = clamp(C, fpv)
        pert = pert.at[fpv].max(bad)
        C = C.at[fl].set(C[fl] / C[fpv])
        C = C.at[dst].add(-C[s1] * C[s2])
        return (C, pert), None

    (C, pert), _ = lax.scan(step, (C, pert0), tuple(art.factor))
    if not isinstance(pert, jax.core.Tracer):
        n_bad = int(jnp.sum(pert[:art.n]))
        if n_bad:
            import warnings
            warnings.warn(
                f"numeric factorization hit {n_bad} numerically-zero "
                f"pivot(s); applied a scaled diagonal perturbation "
                f"(|d|<{float(tau):.2e} -> ±{float(tau):.2e}). The factors "
                f"solve a nearby matrix — consider an iterative backend or "
                f"a symmetric shift for indefinite systems.")
    return C


# ---------------------------------------------------------------------------
# triangular sweeps (traced-safe — the solve stage)
# ---------------------------------------------------------------------------

def _sweep(art: DirectArtifacts, C: jax.Array, c: jax.Array,
           program: PackedSweep, use_upos: bool, divide: bool) -> jax.Array:
    y = jnp.concatenate([c, jnp.zeros((1,), c.dtype)])   # scratch slot at n
    pos = program.upos if use_upos else program.lpos

    def step(y, xs):
        tgt, src, p, dn, dpiv = xs
        y = y.at[tgt].add(-C[p] * y[src])
        if divide:
            y = y.at[dn].set(y[dn] / C[dpiv])
        return y, None

    y, _ = lax.scan(step, y, (program.tgt, program.src, pos,
                              program.dn, program.dpiv))
    return y[:-1]


def factored_solve(art: DirectArtifacts, C: jax.Array, b: jax.Array,
                   *, transposed: bool = False) -> jax.Array:
    """x with A x = b (or Aᵀ x = b) from the factors ``C``.

    Forward: permute, unit-L then U sweeps, unpermute.  Transposed: the SAME
    factors with Uᵀ then Lᵀ sweeps — this is the adjoint's zero-refactorize
    path (LDLᵀ is self-adjoint; LU mirrors the sweeps).

    Supernodal factors (``art.snode``) route through the blocked panel
    sweeps instead of the scalar packed scan; same permutations, same
    storage, same answer.
    """
    if art.snode is not None:
        return _snode_solve(art, C, b, transposed)
    c = b[art.perm]
    if transposed:
        w = _sweep(art, C, c, art.row_sweep, use_upos=True, divide=True)
        x = _sweep(art, C, w, art.col_sweep, use_upos=False, divide=False)
    else:
        y = _sweep(art, C, c, art.row_sweep, use_upos=False, divide=False)
        x = _sweep(art, C, y, art.col_sweep, use_upos=True, divide=True)
    return x[art.ipos]
