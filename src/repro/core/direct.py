"""Sparse direct factorization backend — the cuDSS analogue (paper §3.1/§3.2.3).

The paper's headline backend is a *direct* sparse solver whose symbolic
factorization is computed once per sparsity pattern and reused across numeric
refactorizations and adjoint solves.  This module is that path for the plan
engine, entirely in JAX:

``symbolic_factor(row, col, n)``  — eager, numpy, values-free (the plan's
``analyze`` stage).  Computes a fill-reducing ordering (exact minimum degree
on the symmetrized pattern graph), the per-column elimination structures, the
static fill-in pattern of L (and its mirror U), a longest-path *level
schedule* of the elimination DAG, and — the part that makes the numeric
stages fast — a **packed step program**: every level's work is cut into
fixed-width steps (finalize entries, rank-1 update tuples, sweep entries,
pivot divides) so the numeric kernels are single ``lax.scan`` loops over
uniform index tensors.  One small compiled body serves every level, every
``with_values`` refresh, every batch element, and the adjoint.

``numeric_factor(art, val)``      — traced-safe (the ``setup`` stage).  Runs
the numeric LU/LDLᵀ over the precomputed fill pattern: per scan step, one
fused pivot-divide + scatter-update pair.  Jits, vmaps over batched values,
and re-traces nothing symbolic.

``factored_solve(art, C, b)``     — two level-scheduled triangular sweeps
(the ``solve`` stage).  ``transposed=True`` swaps the sweeps (Uᵀ then Lᵀ),
which is how the adjoint solves Aᵀλ = g on the FORWARD factors — LDLᵀ is
self-adjoint, LU just runs the mirrored sweeps — zero refactorizations.

Storage layout of the factor vector ``C`` (length ``nnzF + 2``)::

    C[0:n]              pivots  U[k,k]              (permuted order)
    C[n:n+nnzL]         L entries, column-major     (unit diagonal implicit)
    C[n+nnzL:nnzF]      U entries, mirror-aligned   (U[j,k] at mirror of L[k,j])
    C[nnzF]             scratch 0  (padding sink for scatter/gather)
    C[nnzF+1]           scratch 1  (padding divisor — keeps pads NaN-free)

For symmetric values (method ``ldlt``) the same kernel computes U = D·Lᵀ in
the mirror half, i.e. an LDLᵀ factorization with D folded into U; the solve
and adjoint exploit self-adjointness through the plan layer.  No numerical
pivoting is performed — intended for SPD / diagonally-dominant systems
(pivoting for indefinite systems is a ROADMAP follow-up).

``incomplete=True`` restricts the update program to the original pattern
(zero fill): that is ILU(0)/IC(0), which :mod:`repro.core.precond` exposes as
``precond="ilu"`` sharing this exact machinery.
"""
from __future__ import annotations

from typing import List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

__all__ = [
    "DirectArtifacts", "symbolic_factor", "numeric_factor", "factored_solve",
    "SchwarzArtifacts", "schwarz_symbolic", "schwarz_numeric",
]


class PackedFactor(NamedTuple):
    """Step program for the numeric factorization: all arrays are (S, width)
    int32.  Per step: ``C[fin_lpos] /= C[fin_piv]`` (column finalize), then
    ``C[up_dst] -= C[up_s1] * C[up_s2]`` (right-looking updates).  Pads point
    at the scratch slots, so they are exact no-ops."""
    fin_lpos: jax.Array
    fin_piv: jax.Array
    up_s1: jax.Array
    up_s2: jax.Array
    up_dst: jax.Array


class PackedSweep(NamedTuple):
    """Step program for one triangular-sweep direction ((S, width) int32).

    ``row`` program (levels leaf→root): forward-L (use ``lpos``) and
    transposed-Uᵀ (use ``upos`` + divides).  ``col`` program (root→leaf):
    backward-U (``upos`` + divides) and transposed-Lᵀ (``lpos``).  Per step:
    ``y[tgt] -= C[pos] * y[src]`` then optionally ``y[dn] /= C[dpiv]``.
    The solution vector carries one scratch element at index n for pads.
    """
    tgt: jax.Array
    src: jax.Array
    lpos: jax.Array
    upos: jax.Array
    dn: jax.Array
    dpiv: jax.Array


class DirectArtifacts(NamedTuple):
    """Product of the symbolic analysis — pattern-only, shared by every
    ``with_values`` refresh, every batch element, and the adjoint."""
    n: int
    nnzF: int
    perm: jax.Array          # perm[k] = original index eliminated at step k
    ipos: jax.Array          # ipos[v] = elimination position of index v
    a2f: jax.Array           # COO entry e -> position in C (scatter-add)
    factor: PackedFactor
    row_sweep: PackedSweep
    col_sweep: PackedSweep
    stats: dict              # nnz_L, fill_ratio, n_levels, flops, n_steps


# ---------------------------------------------------------------------------
# symbolic analysis (eager / numpy — the analyze stage, once per pattern)
# ---------------------------------------------------------------------------

def _pattern_graph(row: np.ndarray, col: np.ndarray, n: int) -> List[set]:
    """Adjacency of the symmetrized pattern graph (no self loops)."""
    mask = row != col
    rr = np.concatenate([row[mask], col[mask]])
    cc = np.concatenate([col[mask], row[mask]])
    key = np.unique(rr.astype(np.int64) * n + cc)
    adj: List[set] = [set() for _ in range(n)]
    for i, j in zip((key // n).tolist(), (key % n).tolist()):
        adj[i].add(j)
    return adj


def _rcm_order(row: np.ndarray, col: np.ndarray, n: int) -> np.ndarray:
    try:
        import scipy.sparse as sp
        from scipy.sparse.csgraph import reverse_cuthill_mckee
    except Exception:                       # scipy absent — degrade gracefully
        return np.arange(n, dtype=np.int64)
    G = sp.csr_matrix((np.ones(len(row)), (row, col)), shape=(n, n))
    return np.asarray(reverse_cuthill_mckee(G, symmetric_mode=False),
                      dtype=np.int64)


def _eliminate(adj: List[set], n: int, order: Optional[np.ndarray],
               fill: bool) -> Tuple[np.ndarray, List[list]]:
    """Graph elimination: returns the elimination order and, per step, the
    *alive neighbourhood* of the eliminated vertex — exactly the nonzero rows
    of that column of L (Parter's rule).  ``order=None`` picks the minimum
    remaining degree each step (exact minimum degree, the AMD objective
    without its quotient-graph shortcuts); ``fill=False`` skips clique
    formation, yielding the zero-fill (ILU(0)) structures instead.
    """
    INF = np.int64(1) << np.int64(60)
    deg = np.array([len(a) for a in adj], dtype=np.int64)
    perm = np.empty(n, dtype=np.int64)
    structs: List[list] = []
    for k in range(n):
        v = int(order[k]) if order is not None else int(np.argmin(deg))
        perm[k] = v
        deg[v] = INF
        nb = adj[v]
        for u in nb:
            adj[u].discard(v)
        if fill:
            for u in nb:
                au = adj[u]
                au |= nb
                au.discard(u)
                deg[u] = len(au)
        else:
            for u in nb:
                deg[u] = len(adj[u])
        structs.append(sorted(nb))
        adj[v] = set()
    return perm, structs


class _StepPacker:
    """Greedy packer of (value-tuple) streams into fixed-width steps.

    ``put(stream, items, min_step)`` appends ``items`` to ``stream`` starting
    no earlier than step ``min_step``, spilling over step boundaries, and
    returns the step index of the LAST item placed (or ``min_step`` when
    empty).  Streams share the step axis; each keeps its own fill cursor.
    """

    def __init__(self, widths: dict):
        self.widths = dict(widths)
        self.data = {s: [] for s in widths}       # step -> list per stream
        self.cursor = {s: 0 for s in widths}      # next step with free space

    def _ensure(self, stream: str, step: int) -> None:
        rows = self.data[stream]
        while len(rows) <= step:
            rows.append([])

    def put(self, stream: str, items: list, min_step: int) -> int:
        if not items:
            return min_step
        w = self.widths[stream]
        step = max(self.cursor[stream], min_step)
        pos = 0
        while pos < len(items):
            self._ensure(stream, step)
            room = w - len(self.data[stream][step])
            if room <= 0:
                step += 1
                continue
            take = items[pos:pos + room]
            self.data[stream][step].extend(take)
            pos += len(take)
            if len(self.data[stream][step]) >= w and pos < len(items):
                step += 1
        self.cursor[stream] = step if len(self.data[stream][step]) < w \
            else step + 1
        return step

    def n_steps(self) -> int:
        return max((len(rows) for rows in self.data.values()), default=0)

    def packed(self, stream: str, n_steps: int, pad) -> np.ndarray:
        w = self.widths[stream]
        out = np.empty((n_steps, w, len(pad)), dtype=np.int64)
        out[...] = np.asarray(pad, dtype=np.int64)
        for s, chunk in enumerate(self.data[stream]):
            if chunk:
                out[s, :len(chunk)] = np.asarray(chunk, dtype=np.int64)
        return out


def _width(total: int, n_levels: int, lo: int = 32, hi: int = 1 << 16) -> int:
    """Step width ≈ mean level load, clamped and rounded to a power of two —
    few distinct shapes across patterns keeps XLA's compile cache warm."""
    w = max(lo, min(hi, -(-total // max(n_levels, 1))))
    return 1 << int(np.ceil(np.log2(w)))


def symbolic_factor(row, col, n: int, *, ordering: str = "amd",
                    incomplete: bool = False) -> DirectArtifacts:
    """Analyze one sparsity pattern for direct (or incomplete) factorization.

    ``ordering`` ∈ {"amd" (minimum degree, default), "rcm", "natural"}.
    ``incomplete=True`` produces the ILU(0)/IC(0) program: same storage and
    kernels, update tuples restricted to the original (symmetrized) pattern.
    Raises ``ValueError`` when the pattern lacks a structurally full diagonal
    (no pivoting is performed, so every pivot must exist).

    The analysis is eager even when invoked inside a jit trace (plans are
    cached on long-lived SparseTensors, so the index tensors must be concrete
    arrays, never trace-bound constants).
    """
    with jax.ensure_compile_time_eval():
        return _symbolic_factor(row, col, n, ordering, incomplete)


def _symbolic_factor(row, col, n: int, ordering: str,
                     incomplete: bool) -> DirectArtifacts:
    row = np.asarray(row, dtype=np.int64)
    col = np.asarray(col, dtype=np.int64)
    from .sparse import has_full_diagonal
    if not has_full_diagonal(row, col, n):
        raise ValueError(
            "direct factorization needs a structurally full diagonal "
            "(no pivoting); use an iterative backend for this pattern")

    if incomplete and ordering == "amd":
        ordering = "natural"        # ILU(0) keeps the assembly order
    if ordering == "amd":
        order = None
    elif ordering == "rcm":
        order = _rcm_order(row, col, n)
    elif ordering == "natural":
        order = np.arange(n, dtype=np.int64)
    else:
        raise ValueError(f"unknown ordering {ordering!r}")

    adj = _pattern_graph(row, col, n)
    perm, structs = _eliminate(adj, n, order, fill=not incomplete)
    ipos = np.empty(n, dtype=np.int64)
    ipos[perm] = np.arange(n)

    # L pattern, column-major: column k holds sorted permuted row indices.
    cols_rows = [np.sort(ipos[np.asarray(s, dtype=np.int64)])
                 if s else np.empty(0, np.int64) for s in structs]
    counts = np.array([r.size for r in cols_rows], dtype=np.int64)
    Lptr = np.concatenate([[0], np.cumsum(counts)])
    nnzL = int(Lptr[-1])
    nnzF = n + 2 * nnzL
    szero, sone = nnzF, nnzF + 1                  # scratch slots in C

    # position lookup over F = diag ∪ L ∪ mirror(U):  key = i*n + j
    Li = (np.concatenate(cols_rows) if nnzL else np.empty(0, np.int64))
    Lj = np.repeat(np.arange(n, dtype=np.int64), counts)
    fkeys = np.concatenate([np.arange(n, dtype=np.int64) * (n + 1),
                            Li * n + Lj, Lj * n + Li])
    srt = np.argsort(fkeys)
    skeys, spos = fkeys[srt], np.arange(nnzF, dtype=np.int64)[srt]

    def lookup(keys: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        idx = np.searchsorted(skeys, keys)
        idx = np.minimum(idx, max(nnzF - 1, 0))
        found = (skeys[idx] == keys) if nnzF else np.zeros_like(keys, bool)
        return spos[idx], found

    a2f, ok = lookup(ipos[row] * n + ipos[col])
    assert bool(ok.all()), "A entry outside its own symmetrized pattern?"

    # longest-path levels of the elimination DAG: level(i) > level(j) for
    # every L entry (i, j) — the invariant every schedule below relies on.
    level = np.zeros(n, dtype=np.int64)
    for k in range(n):
        rk = cols_rows[k]
        if rk.size:
            np.maximum.at(level, rk, level[k] + 1)
    n_levels = int(level.max()) + 1 if n else 1

    # ---- packed factorization program -----------------------------------
    # Columns are walked level by level (elimination DAG order).  Within one
    # step the body runs finalize-then-update, so a column's updates may
    # share its finalize step; a new level's finalizes must start strictly
    # after any step holding earlier levels' updates (those updates write
    # into the new level's entries and pivots).
    flops = int(sum(int(c) * int(c) for c in counts))
    fp = _StepPacker({"fin": _width(nnzL, n_levels),
                      "up": _width(flops, n_levels)})
    lvl_cols: List[List[int]] = [[] for _ in range(n_levels)]
    for k in range(n):
        lvl_cols[int(level[k])].append(k)
    kept_updates = 0
    for l in range(n_levels):
        # barrier: earlier levels' updates all live in steps < fin start
        up_cur = fp.cursor["up"]
        busy = up_cur < len(fp.data["up"]) and bool(fp.data["up"][up_cur])
        fin_floor = up_cur + 1 if busy else up_cur
        for k in lvl_cols[l]:
            rk = cols_rows[k]
            m = int(rk.size)
            base = n + int(Lptr[k])
            fin_end = fp.put(
                "fin", [(base + t, k) for t in range(m)], fin_floor)
            if not m:
                continue
            ii = np.repeat(rk, m)
            jj = np.tile(rk, m)
            s1 = np.repeat(base + np.arange(m), m)
            s2 = base + nnzL + np.tile(np.arange(m), m)
            dst, ok = lookup(ii * n + jj)
            if incomplete:                       # ILU(0): drop fill updates
                s1, s2, dst = s1[ok], s2[ok], dst[ok]
            else:
                assert bool(ok.all()), "fill closure violated"
            kept_updates += int(dst.size)
            fp.put("up", list(zip(s1.tolist(), s2.tolist(), dst.tolist())),
                   fin_end)
    fS = fp.n_steps()
    fin = fp.packed("fin", fS, (szero, sone))
    ups = fp.packed("up", fS, (szero, szero, szero))
    factor = PackedFactor(
        fin_lpos=jnp.asarray(fin[:, :, 0], jnp.int32),
        fin_piv=jnp.asarray(fin[:, :, 1], jnp.int32),
        up_s1=jnp.asarray(ups[:, :, 0], jnp.int32),
        up_s2=jnp.asarray(ups[:, :, 1], jnp.int32),
        up_dst=jnp.asarray(ups[:, :, 2], jnp.int32))

    # ---- packed sweep programs ------------------------------------------
    # row program: entries grouped by level(row), levels ascending — the
    # forward L (lpos) and transposed Uᵀ (upos, + divides) sweeps.
    # col program: entries grouped by level(col), levels descending — the
    # backward U (upos, + divides) and transposed Lᵀ (lpos) sweeps.
    # Within a level, a node's divide shares (or follows) the step of its
    # last incoming add; adds of different levels never share a step.
    ent_lpos = n + np.arange(nnzL, dtype=np.int64)
    ent_upos = ent_lpos + nnzL
    ent_piv_pad = (n, sone)                      # vector scratch / divisor 1

    def _pack_sweep(group_of_entry: np.ndarray, tgt: np.ndarray,
                    src: np.ndarray, level_order) -> PackedSweep:
        sp = _StepPacker({"e": _width(nnzL, n_levels),
                          "d": _width(n, n_levels)})
        ent_by_g: List[list] = [[] for _ in range(n_levels)]
        for t in range(nnzL):
            ent_by_g[int(group_of_entry[t])].append(t)
        node_by_g: List[list] = [[] for _ in range(n_levels)]
        for v in range(n):
            node_by_g[int(level[v])].append(v)
        floor = 0
        for l in level_order:
            ents = ent_by_g[l]
            by_node: dict = {}
            for t in ents:
                by_node.setdefault(int(tgt[t]), []).append(t)
            last = floor
            for v in node_by_g[l]:
                ts = by_node.pop(v, [])
                e_end = sp.put(
                    "e", [(tgt[t], src[t], ent_lpos[t], ent_upos[t])
                          for t in ts], floor)
                d_end = sp.put("d", [(v, v)], e_end)
                last = max(last, e_end, d_end)
            assert not by_node, "sweep entry without its target node?"
            floor = last + 1        # next level strictly after this one
        S = sp.n_steps()
        e = sp.packed("e", S, (n, n, szero, szero))
        d = sp.packed("d", S, ent_piv_pad)
        return PackedSweep(
            tgt=jnp.asarray(e[:, :, 0], jnp.int32),
            src=jnp.asarray(e[:, :, 1], jnp.int32),
            lpos=jnp.asarray(e[:, :, 2], jnp.int32),
            upos=jnp.asarray(e[:, :, 3], jnp.int32),
            dn=jnp.asarray(d[:, :, 0], jnp.int32),
            dpiv=jnp.asarray(d[:, :, 1], jnp.int32))

    row_sweep = _pack_sweep(level[Li], Li, Lj, range(n_levels))
    col_sweep = _pack_sweep(level[Lj], Lj, Li,
                            range(n_levels - 1, -1, -1))

    stats = {"nnz_L": nnzL, "n_levels": n_levels, "flops": kept_updates,
             "fill_ratio": float(nnzF) / float(max(len(row), 1)),
             "n_steps": fS, "ordering": ordering, "incomplete": incomplete}
    return DirectArtifacts(
        n=n, nnzF=nnzF,
        perm=jnp.asarray(perm, jnp.int32), ipos=jnp.asarray(ipos, jnp.int32),
        a2f=jnp.asarray(a2f, jnp.int32),
        factor=factor, row_sweep=row_sweep, col_sweep=col_sweep, stats=stats)


# ---------------------------------------------------------------------------
# shard-local factorization (the distributed plan engine's Schwarz stage)
# ---------------------------------------------------------------------------

class SchwarzArtifacts(NamedTuple):
    """Product of :func:`schwarz_symbolic` — ONE union-pattern symbolic
    factorization shared by every shard, plus the per-shard numeric assembly
    programs.  Everything is pattern-only; the numeric half is a plain
    ``vmap(numeric_factor)`` over per-shard values at setup time."""
    art: DirectArtifacts     # ILU(0)/IC(0) program on the union pattern
    nnz_u: int               # union-pattern nonzeros
    src: jax.Array           # (P, m) gather into flat values (+zero slot last)
    dst: jax.Array           # (P, m) scatter into union slots (pads → nnz_u)
    diag_fix: jax.Array      # (P, nnz_u) +1.0 on structurally-absent diagonals


def schwarz_symbolic(entries, n_ext: int, n_src: int) -> SchwarzArtifacts:
    """Analyze shard-local extended matrices for overlapping Schwarz.

    ``entries[q]`` lists shard ``q``'s extended-domain matrix as
    ``(rows, cols, srcs)`` — COO coordinates in ``[0, n_ext)`` plus the flat
    index of each entry's value in the global value storage (length
    ``n_src``; a trailing zero slot is appended at gather time).  The
    extended matrices of all shards are unioned into ONE sparsity pattern so
    a single zero-fill (ILU(0)/IC(0)) step program — built by
    :func:`symbolic_factor` — serves every shard under ``vmap``/``shard_map``:
    per-shard numeric values are scattered into union slots, structurally
    absent diagonals (phantom halos of edge shards, padded tail rows) are
    completed with 1.0 identity pivots, and entries another shard has but
    this one lacks stay numerically zero.
    """
    p = len(entries)
    keys = [r.astype(np.int64) * n_ext + c.astype(np.int64)
            for r, c, _ in entries]
    dkeys = np.arange(n_ext, dtype=np.int64) * (n_ext + 1)
    ukeys = np.unique(np.concatenate(keys + [dkeys]))
    nnz_u = int(ukeys.size)
    urow = (ukeys // n_ext).astype(np.int64)
    ucol = (ukeys % n_ext).astype(np.int64)

    m = max(max((k.size for k in keys), default=1), 1)
    src = np.full((p, m), n_src, dtype=np.int64)        # pads → zero slot
    dst = np.full((p, m), nnz_u, dtype=np.int64)        # pads → dump slot
    diag_fix = np.ones((p, nnz_u), dtype=np.float64)
    dslot = np.searchsorted(ukeys, dkeys)
    for q, (k, (_, _, s)) in enumerate(zip(keys, entries)):
        slot = np.searchsorted(ukeys, k)
        src[q, :k.size] = np.asarray(s, np.int64)
        dst[q, :k.size] = slot
        diag_fix[q] = 0.0
        have = np.zeros(nnz_u, bool)
        have[slot] = True
        diag_fix[q, dslot[~have[dslot]]] = 1.0          # identity completion

    art = symbolic_factor(urow, ucol, n_ext, incomplete=True)
    return SchwarzArtifacts(art=art, nnz_u=nnz_u,
                            src=jnp.asarray(src, jnp.int32),
                            dst=jnp.asarray(dst, jnp.int32),
                            diag_fix=jnp.asarray(diag_fix))


def schwarz_numeric(sch: SchwarzArtifacts, flat_val: jax.Array) -> jax.Array:
    """Traced-safe numeric half: assemble every shard's extended matrix from
    the flat global values and refactorize — ``(P, nnzF + 2)`` stacked
    factors, one per shard (the setup stage of ``precond='schwarz'``)."""
    padded = jnp.concatenate([flat_val, jnp.zeros((1,), flat_val.dtype)])

    def one_shard(src_q, dst_q, fix_q):
        v = jnp.zeros(sch.nnz_u + 1, flat_val.dtype).at[dst_q].add(
            padded[src_q])[:-1]
        return numeric_factor(sch.art, v + fix_q.astype(flat_val.dtype))

    return jax.vmap(one_shard)(sch.src, sch.dst, sch.diag_fix)


# ---------------------------------------------------------------------------
# numeric factorization (traced-safe — the setup stage)
# ---------------------------------------------------------------------------

def numeric_factor(art: DirectArtifacts, val: jax.Array, *,
                   pivot_guard: bool = True,
                   pivot_eps: Optional[float] = None) -> jax.Array:
    """Numeric LU/LDLᵀ over the precomputed fill pattern.

    One ``lax.scan`` over the packed step program: pure gather/scatter with
    uniform shapes, so it compiles once, jits, and vmaps over a leading batch
    dimension of ``val`` (shared-pattern batches).  Duplicate COO entries
    accumulate, matching ``coo_matvec`` semantics.

    ``pivot_guard`` (default on): a structurally-present but numerically-
    (near-)zero pivot would silently turn the whole factorization into NaNs
    — no numerical pivoting is performed.  The guard applies a *static
    diagonal perturbation* at divide time instead: any pivot with
    ``|d| < τ`` (τ = ``pivot_eps`` or ``√eps·max|A|``) is replaced by
    ``±τ``, persisted in the factor storage so the triangular sweeps stay
    consistent, and — when the values are concrete (not inside a trace) —
    reported with a ``UserWarning``.  The perturbed factorization solves a
    nearby matrix; proper Bunch–Kaufman pivoting for indefinite systems
    remains a ROADMAP follow-up (this is the documented stopgap).
    """
    scale = jnp.max(jnp.abs(val))
    tau = jnp.asarray(
        pivot_eps if pivot_eps is not None
        else jnp.sqrt(jnp.finfo(val.dtype).eps) * jnp.maximum(scale, 1e-300),
        val.dtype)
    C = jnp.zeros(art.nnzF + 2, dtype=val.dtype)
    C = C.at[art.a2f].add(val).at[art.nnzF + 1].set(1.0)

    if not pivot_guard:
        def step(C, xs):
            fl, fpv, s1, s2, dst = xs
            C = C.at[fl].set(C[fl] / C[fpv])
            C = C.at[dst].add(-C[s1] * C[s2])
            return C, None

        C, _ = lax.scan(step, C, tuple(art.factor))
        return C

    # perturbation BOOKKEEPING (for the warning) only runs when the values
    # are concrete — its sole consumer is the eager warning, which can never
    # fire under jit/vmap, so traced factorizations keep the lean scan body
    # (the safe-pivot clamp itself is always on)
    track = not isinstance(val, jax.core.Tracer)

    def clamp(C, fpv):
        piv = C[fpv]
        bad = jnp.abs(piv) < tau            # pads divide by scratch 1.0 — ok
        safe = jnp.where(bad, jnp.where(piv >= 0, tau, -tau), piv)
        return C.at[fpv].set(safe), bad     # persist: sweeps see the same d

    if not track:
        def step(C, xs):
            fl, fpv, s1, s2, dst = xs
            C, _ = clamp(C, fpv)
            C = C.at[fl].set(C[fl] / C[fpv])
            C = C.at[dst].add(-C[s1] * C[s2])
            return C, None

        C, _ = lax.scan(step, C, tuple(art.factor))
        return C

    pert0 = jnp.zeros(art.nnzF + 2, dtype=bool)

    def step(carry, xs):
        C, pert = carry
        fl, fpv, s1, s2, dst = xs
        C, bad = clamp(C, fpv)
        pert = pert.at[fpv].max(bad)
        C = C.at[fl].set(C[fl] / C[fpv])
        C = C.at[dst].add(-C[s1] * C[s2])
        return (C, pert), None

    (C, pert), _ = lax.scan(step, (C, pert0), tuple(art.factor))
    if not isinstance(pert, jax.core.Tracer):
        n_bad = int(jnp.sum(pert[:art.n]))
        if n_bad:
            import warnings
            warnings.warn(
                f"numeric factorization hit {n_bad} numerically-zero "
                f"pivot(s); applied a scaled diagonal perturbation "
                f"(|d|<{float(tau):.2e} -> ±{float(tau):.2e}). The factors "
                f"solve a nearby matrix — consider an iterative backend or "
                f"a symmetric shift for indefinite systems.")
    return C


# ---------------------------------------------------------------------------
# triangular sweeps (traced-safe — the solve stage)
# ---------------------------------------------------------------------------

def _sweep(art: DirectArtifacts, C: jax.Array, c: jax.Array,
           program: PackedSweep, use_upos: bool, divide: bool) -> jax.Array:
    y = jnp.concatenate([c, jnp.zeros((1,), c.dtype)])   # scratch slot at n
    pos = program.upos if use_upos else program.lpos

    def step(y, xs):
        tgt, src, p, dn, dpiv = xs
        y = y.at[tgt].add(-C[p] * y[src])
        if divide:
            y = y.at[dn].set(y[dn] / C[dpiv])
        return y, None

    y, _ = lax.scan(step, y, (program.tgt, program.src, pos,
                              program.dn, program.dpiv))
    return y[:-1]


def factored_solve(art: DirectArtifacts, C: jax.Array, b: jax.Array,
                   *, transposed: bool = False) -> jax.Array:
    """x with A x = b (or Aᵀ x = b) from the factors ``C``.

    Forward: permute, unit-L then U sweeps, unpermute.  Transposed: the SAME
    factors with Uᵀ then Lᵀ sweeps — this is the adjoint's zero-refactorize
    path (LDLᵀ is self-adjoint; LU mirrors the sweeps).
    """
    c = b[art.perm]
    if transposed:
        w = _sweep(art, C, c, art.row_sweep, use_upos=True, divide=True)
        x = _sweep(art, C, w, art.col_sweep, use_upos=False, divide=False)
    else:
        y = _sweep(art, C, c, art.row_sweep, use_upos=False, divide=False)
        x = _sweep(art, C, y, art.col_sweep, use_upos=True, divide=True)
    return x[art.ipos]
