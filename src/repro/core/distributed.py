"""Distributed layer with autograd-compatible halo exchange (paper §3.3, App. C).

Domain decomposition follows the PETSc/Trilinos/OpenFOAM pattern the paper
adapts: each shard owns a contiguous row block ``O_p`` plus halo metadata
``H_p``; a halo exchange runs before each local SpMV; global inner products
are ``all_reduce`` (here ``lax.psum``).  The halo exchange ``H`` is a
``jax.custom_vjp`` whose backward is the **transposed** exchange ``Hᵀ`` —
reversed sender/receiver roles with *summation* at the receive site
(paper Eq. 5–6) — so every distributed solve composes with autodiff.

JAX rendering: NCCL isend/irecv → ``lax.ppermute`` inside ``shard_map``;
torch.distributed process groups → a named mesh axis.  The whole solver runs
as one SPMD program; data lives as stacked ``(P, n_loc)`` arrays sharded on
the leading axis.

Plan lifecycle (PR 3): ``DSparseTensor`` is a first-class citizen of the
plan engine — ``solve`` routes through the ``dist`` backend's
analyze(pattern) → setup(values) → solve(b) split (:mod:`repro.core.
dispatch`).  ``analyze`` runs ONCE per (global pattern, mesh, partition)
and freezes everything eager: partition bounds, the :class:`HaloProgram`
(axis size and ppermute perms baked in — nothing queries the axis
environment at trace time), the Aᵀ partition for non-symmetric adjoints,
and a :class:`~repro.core.precond.DistPreconditionerPlan` (``jacobi`` or
shard-local overlapping-Schwarz ``schwarz``).  ``setup`` is the traced-safe
per-values half, memoized per values array; ``solve`` is the shard_map'd
Krylov loop.  Plans are cached on the tensor and shared by ``with_values``,
mirroring the single-device contract.

Beyond-paper: ``pipelined_cg`` (Ghysels–Vanroose) fuses the two per-iteration
reductions into ONE length-2 psum — the roadmap item of paper App. C.
"""
from __future__ import annotations

import dataclasses
import functools
from functools import partial
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from . import dispatch as _dispatch
from . import solvers as _solvers
from .sparse import SparseTensor

__all__ = ["halo_exchange", "HaloProgram", "halo_program", "halo_apply",
           "DSparseTensor", "DSparseTensorList",
           "partition_simple", "partition_coordinate", "pipelined_cg"]


# ---------------------------------------------------------------------------
# the paper's H / Hᵀ pair — driven by an eagerly-frozen HaloProgram
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class HaloProgram:
    """Frozen halo-exchange schedule: axis size and ppermute perms are plan
    artifacts computed once at analyze time, never re-derived inside a trace
    (``lax``'s axis environment is not consulted at all)."""
    h_lo: int
    h_hi: int
    axis: str
    p: int
    perm_up: Tuple[Tuple[int, int], ...]   # i → i+1 (left-tail delivery)
    perm_dn: Tuple[Tuple[int, int], ...]   # i → i-1 (right-head delivery)


@functools.lru_cache(maxsize=None)
def halo_program(h_lo: int, h_hi: int, axis: str, p: int) -> HaloProgram:
    return HaloProgram(
        h_lo=h_lo, h_hi=h_hi, axis=axis, p=p,
        perm_up=tuple((i, (i + 1) % p) for i in range(p)),
        perm_dn=tuple((i, (i - 1) % p) for i in range(p)))


def _halo_run(prog: HaloProgram, x: jax.Array) -> jax.Array:
    """H: scatter owned boundary values into neighbours' halo slots.

    ``x``: (..., n_loc) owned values (inside shard_map over ``prog.axis``).
    Returns (..., h_lo + n_loc + h_hi): [left-neighbour tail | own | right-
    neighbour head].  Non-periodic: edge shards see zeros.
    """
    idx = lax.axis_index(prog.axis)
    parts = []
    if prog.h_lo > 0:
        # receive left neighbour's tail:  i-1 → i
        lo = lax.ppermute(x[..., -prog.h_lo:], prog.axis,
                          perm=list(prog.perm_up))
        lo = jnp.where(idx == 0, jnp.zeros_like(lo), lo)
        parts.append(lo)
    parts.append(x)
    if prog.h_hi > 0:
        # receive right neighbour's head:  i+1 → i
        hi = lax.ppermute(x[..., :prog.h_hi], prog.axis,
                          perm=list(prog.perm_dn))
        hi = jnp.where(idx == prog.p - 1, jnp.zeros_like(hi), hi)
        parts.append(hi)
    return jnp.concatenate(parts, axis=-1)


def _halo_run_t(prog: HaloProgram, g: jax.Array) -> jax.Array:
    """Hᵀ: same neighbour graph and message sizes, reversed roles,
    sum-at-receive (paper Eq. 6)."""
    idx = lax.axis_index(prog.axis)
    n_loc = g.shape[-1] - prog.h_lo - prog.h_hi
    g_lo = g[..., :prog.h_lo]
    g_own = g[..., prog.h_lo:prog.h_lo + n_loc]
    g_hi = g[..., prog.h_lo + n_loc:]
    gx = g_own
    if prog.h_lo > 0:
        # my lo-halo grads belong to the LEFT neighbour's tail: send i → i-1
        back = lax.ppermute(
            jnp.where(idx == 0, jnp.zeros_like(g_lo), g_lo), prog.axis,
            perm=list(prog.perm_dn))
        gx = gx.at[..., -prog.h_lo:].add(back)
    if prog.h_hi > 0:
        # my hi-halo grads belong to the RIGHT neighbour's head: send i → i+1
        back = lax.ppermute(
            jnp.where(idx == prog.p - 1, jnp.zeros_like(g_hi), g_hi),
            prog.axis, perm=list(prog.perm_up))
        gx = gx.at[..., :prog.h_hi].add(back)
    return gx


@partial(jax.custom_vjp, nondiff_argnums=(0,))
def halo_apply(prog: HaloProgram, x: jax.Array) -> jax.Array:
    """Differentiable H with the frozen program; backward is Hᵀ."""
    return _halo_run(prog, x)


def _halo_apply_fwd(prog, x):
    return _halo_run(prog, x), None


def _halo_apply_bwd(prog, _, g):
    return (_halo_run_t(prog, g),)


halo_apply.defvjp(_halo_apply_fwd, _halo_apply_bwd)


def halo_exchange(x: jax.Array, h_lo: int, h_hi: int, axis: str) -> jax.Array:
    """Legacy entry point: derive the program from the ambient mesh axis.

    ``lax.psum`` of a static ``1`` folds to a concrete axis size at trace
    time, so this works on any jax that has shard_map (``lax.axis_size``
    does not exist on older releases).  Prefer :func:`halo_apply` with a
    plan-cached :func:`halo_program` on hot paths.
    """
    p = lax.psum(1, axis)
    return halo_apply(halo_program(h_lo, h_hi, axis, int(p)), x)


# ---------------------------------------------------------------------------
# partitioning utilities (paper: contiguous rows, RCB, METIS)
# ---------------------------------------------------------------------------

def partition_simple(n: int, p: int) -> np.ndarray:
    """Contiguous row-block ownership boundaries (paper partition_simple)."""
    base = n // p
    sizes = np.full(p, base)
    sizes[: n - base * p] += 1
    return np.concatenate([[0], np.cumsum(sizes)])


def partition_coordinate(coords: np.ndarray, p: int) -> np.ndarray:
    """Recursive coordinate bisection (Berger–Bokhari 1987): returns a
    permutation making each partition contiguous, so the banded halo
    machinery applies after relabeling.  METIS edge-cut minimization would
    slot in identically (permutation in, contiguous blocks out) but is not
    available offline — documented in DESIGN.md."""
    n = coords.shape[0]
    order = np.arange(n)

    def rcb(idx, parts):
        if parts == 1:
            return [idx]
        d = int(np.argmax(coords[idx].max(0) - coords[idx].min(0)))
        srt = idx[np.argsort(coords[idx, d], kind="stable")]
        half = parts // 2
        cut = len(idx) * half // parts
        return rcb(srt[:cut], half) + rcb(srt[cut:], parts - half)

    groups = rcb(order, p)
    return np.concatenate(groups)


def _partition_pattern(row: np.ndarray, col: np.ndarray, bounds: np.ndarray):
    """Row-block partition of one COO pattern (eager, values-free).

    Returns ``(lrow, lcol, src, h_lo, h_hi, nnz_loc, counts)`` where ``src``
    maps each padded local slot back to its global entry index (pads → -1).
    Shared by ``from_global`` and the plan's Aᵀ-partition build, so both
    sides use identical padding and halo conventions.
    """
    p = len(bounds) - 1
    n_loc = int(np.max(np.diff(bounds)))
    masks = [(row >= bounds[q]) & (row < bounds[q + 1]) for q in range(p)]
    h_lo = h_hi = 0
    for q, m in enumerate(masks):
        if m.any():
            h_lo = max(h_lo, int(max(0, bounds[q] - col[m].min())))
            h_hi = max(h_hi, int(max(0, col[m].max() - (bounds[q + 1] - 1))))
    if h_lo > n_loc or h_hi > n_loc:
        raise ValueError(
            "halo wider than one neighbour shard — repartition or add hops")
    counts = [int(m.sum()) for m in masks]
    nnz_loc = max(max(counts), 1)
    lrow = np.zeros((p, nnz_loc), np.int32)
    lcol = np.zeros((p, nnz_loc), np.int32)
    src = np.full((p, nnz_loc), -1, np.int64)
    for q, m in enumerate(masks):
        idx = np.nonzero(m)[0]
        lrow[q, :idx.size] = row[idx] - bounds[q]
        lcol[q, :idx.size] = col[idx] - bounds[q] + h_lo
        src[q, :idx.size] = idx
    return lrow, lcol, src, h_lo, h_hi, nnz_loc, counts


# ---------------------------------------------------------------------------
# DSparseTensor
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DistMeta:
    n: int
    p: int
    n_loc: int          # padded local rows (uniform)
    h_lo: int
    h_hi: int
    nnz_loc: int        # padded local nnz (uniform)
    axis: str
    symmetric: bool
    shard_nnz: Optional[Tuple[int, ...]] = None   # true nnz per shard


@jax.tree_util.register_pytree_node_class
class DSparseTensor:
    """Row-block distributed sparse matrix (paper §3.3).

    Storage: stacked per-shard arrays with leading dim P, sharded over the
    mesh axis — ``lval (P, nnz_loc)``, ``lrow`` local row ids, ``lcol``
    indices into the halo-extended local vector.  Single-neighbour halos
    (h_lo, h_hi ≤ n_loc) are asserted at construction; wider stencils would
    add ppermute hops (documented, not needed for the paper's workloads).

    Solves route through the plan engine's ``dist`` backend: the first call
    analyzes the (pattern, mesh, partition) once — halo program, Aᵀ
    partition, preconditioner build — and every later solve (tolerance
    sweeps, ``with_values`` refreshes, the adjoint backward) reuses the
    cached :class:`~repro.core.dispatch.SolverPlan`.
    """

    def __init__(self, meta: DistMeta, lval, lrow, lcol, mesh: Mesh,
                 lval_t=None, lrow_t=None, lcol_t=None):
        self.meta = meta
        self.lval, self.lrow, self.lcol = lval, lrow, lcol
        # legacy slots: the Aᵀ partition is a PLAN artifact now (built once
        # per pattern by analyze); kept only for constructor/pytree compat
        self.lval_t, self.lrow_t, self.lcol_t = lval_t, lrow_t, lcol_t
        self.mesh = mesh
        from .sparse import _plan_cache
        self._plans = _plan_cache()

    def tree_flatten(self):
        return ((self.lval, self.lrow, self.lcol, self.lval_t, self.lrow_t,
                 self.lcol_t), (self.meta, self.mesh))

    @classmethod
    def tree_unflatten(cls, aux, children):
        meta, mesh = aux
        return cls(meta, children[0], children[1], children[2], mesh,
                   children[3], children[4], children[5])

    # -- plan-engine protocol (duck-typed SparseTensor pattern surface) ------
    @property
    def val(self):
        return self.lval

    @property
    def row(self):
        return self.lrow

    @property
    def col(self):
        return self.lcol

    @property
    def shape(self):
        return (self.meta.n, self.meta.n)

    @property
    def props(self):
        return {"symmetric": self.meta.symmetric}

    bell = None
    stencil = None
    batch_shape = ()

    @property
    def dtype(self):
        return self.lval.dtype

    def plan_key_extra(self) -> tuple:
        """Mesh-aware plan-cache key suffix: one pattern partitioned over a
        different axis (or shard count) must analyze separately."""
        return (self.meta.axis, self.meta.p, self.meta.n_loc)

    def with_values(self, lval) -> "DSparseTensor":
        """Same partition + pattern, new (possibly traced) stacked values.
        The plan cache is SHARED with the parent, so shared-pattern batches
        and tolerance sweeps do ONE analysis — the single-device contract."""
        obj = DSparseTensor.__new__(DSparseTensor)
        obj.meta, obj.mesh = self.meta, self.mesh
        obj.lval, obj.lrow, obj.lcol = lval, self.lrow, self.lcol
        obj.lval_t = obj.lrow_t = obj.lcol_t = None
        obj._plans = self._plans
        return obj

    def plan(self, **solve_kwargs) -> "_dispatch.SolverPlan":
        """Analyze (or fetch) the cached plan — the analyze stage of
        analyze → setup → solve on the mesh."""
        return _dispatch.get_plan(self, self._make_config(**solve_kwargs))

    def _make_config(self, *, method: str = "auto", tol: float = 1e-6,
                     atol: float = 0.0, maxiter: int = 1000,
                     precond: str = "jacobi", pipelined: bool = False,
                     x0=None) -> "_dispatch.SolverConfig":
        # x0 is a solve-stage argument, accepted here only so callers can
        # forward one kwargs dict; anything else unknown raises (a typo'd
        # knob must not silently run with defaults)
        del x0
        if method == "auto":
            method = "cg" if self.meta.symmetric else "bicgstab"
        if pipelined and method == "cg":
            method = "pipelined_cg"
        return _dispatch.SolverConfig(backend="dist", method=method, tol=tol,
                                      atol=atol, maxiter=maxiter,
                                      precond=precond)

    # -- construction --------------------------------------------------------
    @classmethod
    def from_global(cls, val, row, col, shape, mesh: Mesh, axis: str = "data",
                    symmetric: Optional[bool] = None):
        """Partition a global COO matrix across ``mesh[axis]`` (eager)."""
        val = np.asarray(val); row = np.asarray(row); col = np.asarray(col)
        n = shape[0]
        p = mesh.shape[axis]
        if symmetric is None:
            from .sparse import detect_properties
            symmetric = detect_properties(val, row, col, shape)["symmetric"]
        bounds = partition_simple(n, p)
        lrow, lcol, src, h_lo, h_hi, nnz_loc, counts = _partition_pattern(
            row, col, bounds)
        rowsz = np.diff(bounds)
        if (h_lo > 0 or h_hi > 0) and rowsz.min() != rowsz.max():
            raise ValueError(
                "halo exchange indexes neighbour tails positionally — "
                "coupled (h>0) partitions need uniform shard sizes "
                f"(n={n} not divisible by P={p})")
        # leading shard axis, batch dims (if any) behind it — the mesh axis
        # must be the one NamedSharding splits
        lval = np.moveaxis(
            np.where(src >= 0, val[..., np.clip(src, 0, None)], 0.0), -2, 0)
        meta = DistMeta(n=n, p=p, n_loc=int(np.max(np.diff(bounds))),
                        h_lo=h_lo, h_hi=h_hi, nnz_loc=nnz_loc, axis=axis,
                        symmetric=bool(symmetric), shard_nnz=tuple(counts))
        shard = NamedSharding(mesh, P(axis))
        dev = lambda a: jax.device_put(jnp.asarray(a), shard)
        return cls(meta, dev(lval), dev(lrow), dev(lcol), mesh)

    # -- stacked <-> global --------------------------------------------------
    def stack_vector(self, x_global):
        """(n,) → (P, n_loc) padded+sharded."""
        n, p, n_loc = self.meta.n, self.meta.p, self.meta.n_loc
        bounds = partition_simple(n, p)
        rowsz = np.diff(bounds)
        parts = [np.pad(np.asarray(x_global)[bounds[q]:bounds[q + 1]],
                        (0, n_loc - rowsz[q])) for q in range(p)]
        arr = jnp.asarray(np.stack(parts, 0))
        return jax.device_put(arr, NamedSharding(self.mesh, P(self.meta.axis)))

    def gather_global(self, x_stacked):
        """(P, n_loc) → (n,) on host."""
        n, p, n_loc = self.meta.n, self.meta.p, self.meta.n_loc
        bounds = partition_simple(n, p)
        xs = np.asarray(jax.device_get(x_stacked))
        return np.concatenate([xs[q][: bounds[q + 1] - bounds[q]]
                               for q in range(p)])

    def gather_values(self):
        """Stacked local storage → global COO triplet on host (eager).

        Padding is trimmed via ``meta.shard_nnz``; legacy metas without
        counts fall back to keeping every in-matrix slot (pads carry zero
        values, so they only add numerically-inert duplicate entries)."""
        m = self.meta
        bounds = partition_simple(m.n, m.p)
        row_g, col_g, fa = global_entries(self.lrow, self.lcol, m, bounds)
        flat = np.asarray(jax.device_get(self.lval)).reshape(-1)
        return flat[fa], row_g, col_g

    # -- distributed ops ------------------------------------------------------
    def _halo(self) -> HaloProgram:
        m = self.meta
        return halo_program(m.h_lo, m.h_hi, m.axis, m.p)

    def matvec(self, x_stacked):
        m = self.meta
        prog = self._halo()
        spec = P(m.axis)

        @partial(shard_map, mesh=self.mesh,
                 in_specs=(spec, spec, spec, spec), out_specs=spec,
                 check_rep=False)
        def run(lval, lrow, lcol, x):
            y = _local_matvec(prog, m.n_loc, lval[0], lrow[0], lcol[0], x[0],
                              differentiable=True)
            return y[None]

        return run(self.lval, self.lrow, self.lcol, x_stacked)

    def solve(self, b_stacked, *, method: str = "auto", tol: float = 1e-6,
              atol: float = 0.0, maxiter: int = 1000, precond: str = "jacobi",
              pipelined: bool = False, x0=None):
        """Distributed, differentiable solve through the plan engine.

        Forward: analyze-once (halo program, partition, preconditioner
        build, Aᵀ partition) → per-values setup (memoized per values array)
        → shard_map'd Krylov loop.  Backward: one distributed solve of
        Aᵀλ = g through ``plan.transpose()`` — the SAME plan for symmetric
        patterns, a shared-artifact transposed sibling otherwise — plus
        local O(nnz) gradient assembly with halo'd x (paper §3.3).

        ``precond`` ∈ {none, jacobi, schwarz, schwarz2}: ``schwarz`` is
        shard-local overlapping Schwarz with ILU(0)/IC(0) subdomain solves
        built on the direct backend's symbolic machinery
        (:mod:`repro.core.direct`); ``schwarz2`` adds an additive coarse
        correction (aggregated global Galerkin matrix, cached direct
        factors) so CG iteration counts stay flat as the shard count grows.
        """
        from . import adjoint as _adjoint
        cfg = self._make_config(method=method, tol=tol, atol=atol,
                                maxiter=maxiter, precond=precond,
                                pipelined=pipelined)
        return _adjoint.dist_sparse_solve(cfg, self, b_stacked, x0)

    def solve_with_info(self, b_stacked, **kw):
        """Non-differentiable solve that also returns :class:`SolveInfo`
        (psum'd residual norm + iteration count — replicated scalars)."""
        cfg = self._make_config(**kw)
        plan = _dispatch.get_plan(self, cfg)
        return plan.solve(self, b_stacked, kw.get("x0"), cfg=cfg)

    def eigsh(self, k: int = 4, *, tol: float = 1e-6, maxiter: int = 200,
              seed: int = 0):
        """Distributed LOBPCG: Gram-matrix Rayleigh–Ritz (psum'd s×s),
        halo-exchange matvecs.  Hellmann–Feynman adjoint assembled locally."""
        m = self.meta
        prog = self._halo()
        spec = P(m.axis)

        def impl(lval):
            @partial(shard_map, mesh=self.mesh,
                     in_specs=(spec, spec, spec), out_specs=(P(None), spec),
                     check_rep=False)
            def run(lval, lrow, lcol):
                lv, lr, lc = lval[0], lrow[0], lcol[0]
                mv = lambda x: _local_matvec(prog, m.n_loc, lv, lr, lc, x)
                key = jax.random.PRNGKey(seed + lax.axis_index(m.axis))
                X0 = jax.random.normal(key, (k, m.n_loc), lval.dtype)
                pgram = lambda S1, S2: lax.psum(S1 @ S2.T, m.axis)
                w, X, _ = _solvers.lobpcg_general(mv, X0, gram=pgram, tol=tol,
                                                  maxiter=maxiter)
                return w, jnp.swapaxes(X, 0, 1)[None]  # (P, n_loc, k)

            return run(lval, self.lrow, self.lcol)

        @jax.custom_vjp
        def deig(lval):
            return impl(lval)

        def fwd(lval):
            w, V = jax.tree.map(lax.stop_gradient, impl(lval))
            return (w, V), (lval, w, V)

        def bwd(res, cot):
            lval, w, V = res
            gw, _ = cot  # eigenvector cotangents: deflated solves — local-only
                         # variant omitted in distributed mode (paper exposes
                         # eigenvalue grads; vector grads are a single-device
                         # feature here)

            @partial(shard_map, mesh=self.mesh,
                     in_specs=(P(None), spec, spec, spec), out_specs=spec,
                     check_rep=False)
            def assemble(gw, V, lrow, lcol):
                Vq = V[0]                      # (n_loc, k)
                Vx = jnp.swapaxes(Vq, 0, 1)    # (k, n_loc)
                V_ext = jax.vmap(lambda v: _halo_run(prog, v))(Vx)
                lr, lc = lrow[0], lcol[0]
                gval = jnp.einsum("k,ke,ke->e", gw, Vx[:, lr], V_ext[:, lc])
                return gval[None]

            return (assemble(gw, V, self.lrow, self.lcol),)

        deig.defvjp(fwd, bwd)
        return deig(self.lval)

    def slogdet(self):
        """Gather-based fallback (paper §3.3 'Scope of distributed
        gradients'): pulls the global matrix onto ONE host, rebuilds a
        :class:`SparseTensor`, and delegates to its slogdet — which is the
        sparse cached-LDLᵀ path (Σ log |d_i| with sign tracking, O(nnz_L)
        memory) for patterns within the ``direct_budget`` option and the
        dense O(n²)
        fallback beyond.  The full gather is runtime-warned either way, and
        the host round-trip breaks gradient flow into the stacked values."""
        import warnings
        warnings.warn("DSparseTensor.slogdet gathers the global matrix onto "
                      "one process — not distributed-scalable (sparse LDLT "
                      "within the direct_budget option, dense O(n^2) "
                      "beyond).")
        val, row, col = self.gather_values()
        return SparseTensor(val, row, col, self.shape).slogdet()


# ---------------------------------------------------------------------------
# plan-engine stages (called by dispatch.DistBackend)
# ---------------------------------------------------------------------------

def global_entries(lrow, lcol, meta: DistMeta, bounds):
    """Stacked local pattern → global COO coordinates (eager, values-free).

    Returns ``(row_g, col_g, fa)`` where ``fa`` is each entry's flat index
    into the ``(P·nnz_loc,)`` value storage — the one reconstruction shared
    by the Aᵀ-partition build, ``gather_values`` and the Schwarz extended-
    matrix assembly.  Padding is trimmed via ``meta.shard_nnz``; legacy
    metas without counts drop only the off-matrix pad columns."""
    lr = np.asarray(lrow)
    lc = np.asarray(lcol)
    p, nnz_loc = lr.shape
    rows, cols, fa = [], [], []
    for q in range(p):
        cnt = meta.shard_nnz[q] if meta.shard_nnz is not None else nnz_loc
        rows.append(lr[q, :cnt].astype(np.int64) + bounds[q])
        cols.append(lc[q, :cnt].astype(np.int64) - meta.h_lo + bounds[q])
        fa.append(q * nnz_loc + np.arange(cnt, dtype=np.int64))
    row_g = np.concatenate(rows)
    col_g = np.concatenate(cols)
    fa = np.concatenate(fa)
    ok = (col_g >= 0) & (col_g < meta.n)
    return row_g[ok], col_g[ok], fa[ok]


def _local_matvec(prog: HaloProgram, n_loc: int, lv, lr, lc, x,
                  differentiable: bool = False):
    """halo exchange + purely local SpMV (paper Eq. 5) — inside shard_map."""
    H = halo_apply if differentiable else _halo_run
    x_ext = H(prog, x)
    return jax.ops.segment_sum(lv * x_ext[lc], lr, num_segments=n_loc)


def dist_analyze(cfg, plan) -> dict:
    """analyze(pattern): freeze every eager artifact for one
    (global pattern, mesh, partition) — runs once, cached on the plan."""
    from .precond import DistPreconditionerPlan
    meta = plan.dmeta
    bounds = partition_simple(meta.n, meta.p)
    prog = halo_program(meta.h_lo, meta.h_hi, meta.axis, meta.p)
    return {
        "halo": prog,
        "bounds": bounds,
        "precond": DistPreconditionerPlan(cfg.precond, plan.row, plan.col,
                                          meta, bounds=bounds),
        "transposed": False,
        # non-symmetric only: the Aᵀ partition is a plan artifact, built
        # lazily on the FIRST plan.transpose() (forward-only solves never
        # pay for it) and cached here for the plan's lifetime
        **({"t": None} if not meta.symmetric else {}),
    }


def _build_t_partition(cfg, plan, meta: DistMeta, bounds) -> dict:
    """The Aᵀ partition as a plan artifact (eager numpy, once per pattern).

    Rebuilds the global COO pattern from the stacked local arrays, row-block
    partitions its transpose with its OWN halo widths/padding, and records a
    gather map from the forward ``lval`` layout so the adjoint derives the
    Aᵀ values without any per-call partitioning."""
    from .precond import DistPreconditionerPlan
    _dispatch.PLAN_STATS["t_partition"] += 1
    p, nnz_loc = np.asarray(plan.row).shape
    row_g, col_g, fa = global_entries(plan.row, plan.col, meta, bounds)

    lrow_t, lcol_t, src_t, h_lo_t, h_hi_t, nnz_loc_t, counts_t = \
        _partition_pattern(col_g, row_g, bounds)
    gather = np.where(src_t >= 0, fa[np.clip(src_t, 0, None)],
                      p * nnz_loc).astype(np.int64)
    t_meta = DistMeta(n=meta.n, p=meta.p, n_loc=meta.n_loc, h_lo=h_lo_t,
                      h_hi=h_hi_t, nnz_loc=nnz_loc_t, axis=meta.axis,
                      symmetric=False, shard_nnz=tuple(counts_t))
    shard = NamedSharding(plan.mesh, P(meta.axis))
    dev = lambda a: jax.device_put(jnp.asarray(a), shard)
    lrow_t, lcol_t = dev(lrow_t), dev(lcol_t)
    return {
        "meta": t_meta,
        "lrow": lrow_t,
        "lcol": lcol_t,
        "gather": jnp.asarray(gather),
        "halo": halo_program(h_lo_t, h_hi_t, meta.axis, meta.p),
        "precond": DistPreconditionerPlan(cfg.precond, lrow_t, lcol_t,
                                          t_meta, bounds=bounds),
    }


def dist_transpose_plan(plan):
    """Adjoint plan from the forward plan's own artifacts — zero re-analysis.
    Symmetric patterns never reach here (``SolverPlan.transpose`` returns the
    forward plan itself); non-symmetric ones get a sibling whose pattern IS
    the plan's cached Aᵀ partition (built on first use, then an artifact)."""
    if "t" not in plan.artifacts:
        return None           # not a dist plan
    if plan.artifacts["t"] is None:
        with jax.ensure_compile_time_eval():   # may run inside a bwd trace
            plan.artifacts["t"] = _build_t_partition(
                plan.cfg, plan, plan.dmeta, plan.artifacts["bounds"])
    t = plan.artifacts["t"]
    SolverPlan = _dispatch.SolverPlan
    tp = SolverPlan.__new__(SolverPlan)
    tp.cfg = plan.cfg
    tp.backend = plan.backend
    tp.row, tp.col = t["lrow"], t["lcol"]
    tp.shape = (plan.shape[1], plan.shape[0])
    tp.props = dict(plan.props)
    tp.bell = tp.stencil = None
    tp.mesh = plan.mesh
    tp.dmeta = t["meta"]
    # key with the mesh suffix get_plan composes from plan_key_extra, so a
    # transpose view routed through get_plan hits THIS plan, not a re-analysis
    tmeta = t["meta"]
    tp._cache = {tp.cfg.plan_key() + (tmeta.axis, tmeta.p, tmeta.n_loc): tp}
    tp._tplan = plan
    tp._setup_memo = {}     # Aᵀ values differ from the forward values
    tp.artifacts = {"halo": t["halo"], "bounds": plan.artifacts["bounds"],
                    "precond": t["precond"], "transposed": True}
    return tp


def transpose_values(plan, lval):
    """Forward stacked values → Aᵀ-partition stacked values via the plan's
    cached gather map (the values counterpart of the Aᵀ partition)."""
    t = plan.artifacts["t"]
    flat = jnp.concatenate([lval.reshape(-1),
                            jnp.zeros((1,), lval.dtype)])
    return flat[t["gather"]]


def transpose_view(tplan, lval_t) -> DSparseTensor:
    """DSparseTensor view of the Aᵀ partition carrying derived values —
    what the adjoint feeds back into ``tplan.solve``."""
    D = DSparseTensor.__new__(DSparseTensor)
    D.meta = tplan.dmeta
    D.mesh = tplan.mesh
    D.lval, D.lrow, D.lcol = lval_t, tplan.row, tplan.col
    D.lval_t = D.lrow_t = D.lcol_t = None
    D._plans = tplan._cache
    return D


def dist_setup(plan, A) -> tuple:
    """setup(values): the traced-safe per-values half — preconditioner
    refresh on the stacked values.  Memoized per values array by
    ``SolverPlan.setup`` (``PLAN_STATS['setup_reuse']``)."""
    return plan.artifacts["precond"].refresh(A.lval)


def dist_solve(plan, state, A, b, x0, cfg):
    """solve(b): the shard_map'd Krylov loop over frozen artifacts."""
    meta = plan.dmeta
    prog = plan.artifacts["halo"]
    pplan = plan.artifacts["precond"]
    spec = P(meta.axis)
    state = tuple(state)
    have_x0 = x0 is not None
    method = cfg.method
    if method not in ("cg", "bicgstab", "pipelined_cg"):
        raise ValueError(f"unknown distributed method {method!r}")

    # state leaves may be stacked-and-sharded (P, ·) or replicated (the
    # two-level Schwarz coarse factor) — the preconditioner plan says which
    sharded = pplan.state_sharded()
    in_specs = (spec,) * (4 + (1 if have_x0 else 0)) + \
        tuple(spec if sh else P() for sh in sharded)

    @partial(shard_map, mesh=plan.mesh, in_specs=in_specs,
             out_specs=(spec, P()), check_rep=False)
    def run(lval, lrow, lcol, bq, *rest):
        x0q = rest[0][0] if have_x0 else None
        raw = rest[1:] if have_x0 else rest
        sleaves = tuple(s[0] if sh else s for s, sh in zip(raw, sharded))
        lv, lr, lc = lval[0], lrow[0], lcol[0]
        mv = lambda xv: _local_matvec(prog, meta.n_loc, lv, lr, lc, xv)
        pdot = lambda u, v: lax.psum(jnp.sum(u * v), meta.axis)
        M = pplan.local_closure(sleaves,
                                lambda r: _halo_run(prog, r),
                                lambda z: _halo_run_t(prog, z),
                                matvec=mv)
        if method == "pipelined_cg":
            if x0q is None:
                x, info = pipelined_cg(mv, bq[0], M=M, tol=cfg.tol,
                                       atol=cfg.atol, maxiter=cfg.maxiter,
                                       axis=meta.axis)
            else:
                # warm start by shift — but keep the convergence target
                # relative to the ORIGINAL b, matching the cg/bicgstab paths
                target = jnp.maximum(
                    cfg.tol * jnp.sqrt(pdot(bq[0], bq[0])), cfg.atol)
                x, info = pipelined_cg(mv, bq[0] - mv(x0q), M=M, tol=0.0,
                                       atol=target, maxiter=cfg.maxiter,
                                       axis=meta.axis)
                x = x + x0q
        elif method == "cg":
            x, info = _solvers.cg(mv, bq[0], x0q, M=M, tol=cfg.tol,
                                  atol=cfg.atol, maxiter=cfg.maxiter,
                                  dot=pdot)
        else:
            x, info = _solvers.bicgstab(mv, bq[0], x0q, M=M, tol=cfg.tol,
                                        atol=cfg.atol, maxiter=cfg.maxiter,
                                        dot=pdot)
        return x[None], info

    args = (A.lval, plan.row, plan.col, b)
    if have_x0:
        args = args + (x0,)
    return run(*(args + state))


def assemble_matrix_grad(plan, lam, x):
    """Local O(nnz) matrix-gradient assembly: −λ_i x_j with halo'd x
    (paper §3.3) — runs on the FORWARD partition's pattern."""
    meta = plan.dmeta
    prog = plan.artifacts["halo"]
    spec = P(meta.axis)

    @partial(shard_map, mesh=plan.mesh, in_specs=(spec, spec, spec, spec),
             out_specs=spec, check_rep=False)
    def assemble(lamq, xq, lrow, lcol):
        x_ext = _halo_run(prog, xq[0])
        gval = -(lamq[0][lrow[0]] * x_ext[lcol[0]])
        return gval[None]

    return assemble(lam, x, plan.row, plan.col)


# ---------------------------------------------------------------------------
# DSparseTensorList
# ---------------------------------------------------------------------------

class DSparseTensorList:
    """Distributed batch with distinct patterns — per-element dispatch, but
    members sharing one partitioned pattern (same stacked index arrays +
    meta + mesh) are routed through ONE plan cache, so a shared-pattern
    batch analyzes once."""

    def __init__(self, tensors):
        self.tensors = list(tensors)

    def _share_plans(self):
        seen = {}
        for A in self.tensors:
            key = (id(A.lrow), id(A.lcol), A.meta, id(A.mesh))
            if key in seen:
                # merge, don't overwrite: a member that already analyzed a
                # plan on its own contributes it to the shared cache
                seen[key].update(A._plans)
                A._plans = seen[key]
            else:
                seen[key] = A._plans

    def solve(self, bs, **kw):
        self._share_plans()
        return [A.solve(b, **kw) for A, b in zip(self.tensors, bs)]

    def solve_with_info(self, bs, **kw):
        self._share_plans()
        return [A.solve_with_info(b, **kw)
                for A, b in zip(self.tensors, bs)]


# ---------------------------------------------------------------------------
# pipelined CG — beyond-paper (paper App. C names this as the roadmap item)
# ---------------------------------------------------------------------------

def pipelined_cg(matvec: Callable, b: jax.Array, *, M: Callable = lambda r: r,
                 tol: float = 1e-6, atol: float = 0.0, maxiter: int = 1000,
                 axis: Optional[str] = None):
    """Ghysels–Vanroose pipelined CG: ONE fused length-2 reduction per
    iteration instead of two separate all_reduces, and the reduction can
    overlap the SpMV.  Halves the latency term of the collective roofline at
    large P (see EXPERIMENTS.md §Perf)."""
    psum = (lambda v: lax.psum(v, axis)) if axis else (lambda v: v)
    dot2 = lambda a, b_, c, d: psum(jnp.stack([jnp.sum(a * b_), jnp.sum(c * d)]))

    x = jnp.zeros_like(b)
    r = b - matvec(x)
    u = M(r)
    w = matvec(u)
    gd = dot2(r, u, w, u)
    gamma, delta = gd[0], gd[1]
    bnorm = jnp.sqrt(psum(jnp.sum(b * b)))
    target = jnp.maximum(tol * bnorm, atol)
    z = jnp.zeros_like(b); q = jnp.zeros_like(b)
    s = jnp.zeros_like(b); p = jnp.zeros_like(b)
    one = jnp.asarray(1.0, b.dtype)

    def cond(st):
        *_, k = st
        r = st[1]
        rn = jnp.sqrt(psum(jnp.sum(r * r)))
        return (k < maxiter) & (rn > target)

    def body(st):
        (x, r, u, w, z, q, s, p, gamma, delta, gamma_prev, alpha_prev, k) = st
        m_ = M(w)
        n_ = matvec(m_)
        beta = jnp.where(k == 0, 0.0, gamma / gamma_prev)
        alpha = jnp.where(
            k == 0, gamma / delta,
            gamma / (delta - beta * gamma / jnp.where(alpha_prev == 0.0, one,
                                                      alpha_prev)))
        z = n_ + beta * z
        q = m_ + beta * q
        s = w + beta * s
        p = u + beta * p
        x = x + alpha * p
        r = r - alpha * s
        u = u - alpha * q
        w = w - alpha * z
        gd = dot2(r, u, w, u)
        return (x, r, u, w, z, q, s, p, gd[0], gd[1], gamma, alpha, k + 1)

    st0 = (x, r, u, w, z, q, s, p, gamma, delta, one, jnp.asarray(0.0, b.dtype),
           jnp.array(0))
    st = lax.while_loop(cond, body, st0)
    x, r = st[0], st[1]
    rn = jnp.sqrt(psum(jnp.sum(r * r)))
    return x, _solvers.SolveInfo(st[-1], rn, rn <= target)
