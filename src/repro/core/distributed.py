"""Distributed layer with autograd-compatible halo exchange (paper §3.3, App. C).

Domain decomposition follows the PETSc/Trilinos/OpenFOAM pattern the paper
adapts: each shard owns a contiguous row block ``O_p`` plus halo metadata
``H_p``; a halo exchange runs before each local SpMV; global inner products
are ``all_reduce`` (here ``lax.psum``).  The halo exchange ``H`` is a
``jax.custom_vjp`` whose backward is the **transposed** exchange ``Hᵀ`` —
reversed sender/receiver roles with *summation* at the receive site
(paper Eq. 5–6) — so every distributed solve composes with autodiff.

JAX rendering: NCCL isend/irecv → ``lax.ppermute`` inside ``shard_map``;
torch.distributed process groups → a named mesh axis.  The whole solver runs
as one SPMD program; data lives as stacked ``(P, n_loc)`` arrays sharded on
the leading axis.

Beyond-paper: ``pipelined_cg`` (Ghysels–Vanroose) fuses the two per-iteration
reductions into ONE length-2 psum — the roadmap item of paper App. C.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from . import solvers as _solvers
from .sparse import SparseTensor

__all__ = ["halo_exchange", "DSparseTensor", "DSparseTensorList",
           "partition_simple", "partition_coordinate", "pipelined_cg"]


# ---------------------------------------------------------------------------
# the paper's H / Hᵀ pair
# ---------------------------------------------------------------------------

@partial(jax.custom_vjp, nondiff_argnums=(1, 2, 3))
def halo_exchange(x: jax.Array, h_lo: int, h_hi: int, axis: str) -> jax.Array:
    """H: scatter owned boundary values into neighbours' halo slots.

    ``x``: (..., n_loc) owned values (inside shard_map over ``axis``).
    Returns (..., h_lo + n_loc + h_hi): [left-neighbour tail | own | right-
    neighbour head].  Non-periodic: edge shards see zeros.
    """
    return _halo_fwd_impl(x, h_lo, h_hi, axis)


def _halo_fwd_impl(x, h_lo, h_hi, axis):
    p = lax.axis_size(axis)
    idx = lax.axis_index(axis)
    parts = []
    if h_lo > 0:
        # receive left neighbour's tail:  i-1 → i
        lo = lax.ppermute(x[..., -h_lo:], axis,
                          perm=[(i, (i + 1) % p) for i in range(p)])
        lo = jnp.where(idx == 0, jnp.zeros_like(lo), lo)
        parts.append(lo)
    parts.append(x)
    if h_hi > 0:
        # receive right neighbour's head:  i+1 → i
        hi = lax.ppermute(x[..., :h_hi], axis,
                          perm=[(i, (i - 1) % p) for i in range(p)])
        hi = jnp.where(idx == p - 1, jnp.zeros_like(hi), hi)
        parts.append(hi)
    return jnp.concatenate(parts, axis=-1)


def _halo_fwd(x, h_lo, h_hi, axis):
    return _halo_fwd_impl(x, h_lo, h_hi, axis), None


def _halo_bwd(h_lo, h_hi, axis, _, g):
    """Hᵀ: same neighbour graph and message sizes, reversed roles,
    sum-at-receive (paper Eq. 6)."""
    p = lax.axis_size(axis)
    idx = lax.axis_index(axis)
    n_loc = g.shape[-1] - h_lo - h_hi
    g_lo = g[..., :h_lo]
    g_own = g[..., h_lo:h_lo + n_loc]
    g_hi = g[..., h_lo + n_loc:]
    gx = g_own
    if h_lo > 0:
        # my lo-halo grads belong to the LEFT neighbour's tail: send i → i-1
        back = lax.ppermute(
            jnp.where(idx == 0, jnp.zeros_like(g_lo), g_lo), axis,
            perm=[(i, (i - 1) % p) for i in range(p)])
        gx = gx.at[..., -h_lo:].add(back)
    if h_hi > 0:
        # my hi-halo grads belong to the RIGHT neighbour's head: send i → i+1
        back = lax.ppermute(
            jnp.where(idx == p - 1, jnp.zeros_like(g_hi), g_hi), axis,
            perm=[(i, (i + 1) % p) for i in range(p)])
        gx = gx.at[..., :h_hi].add(back)
    return (gx,)


halo_exchange.defvjp(_halo_fwd, _halo_bwd)


# ---------------------------------------------------------------------------
# partitioning utilities (paper: contiguous rows, RCB, METIS)
# ---------------------------------------------------------------------------

def partition_simple(n: int, p: int) -> np.ndarray:
    """Contiguous row-block ownership boundaries (paper partition_simple)."""
    base = n // p
    sizes = np.full(p, base)
    sizes[: n - base * p] += 1
    return np.concatenate([[0], np.cumsum(sizes)])


def partition_coordinate(coords: np.ndarray, p: int) -> np.ndarray:
    """Recursive coordinate bisection (Berger–Bokhari 1987): returns a
    permutation making each partition contiguous, so the banded halo
    machinery applies after relabeling.  METIS edge-cut minimization would
    slot in identically (permutation in, contiguous blocks out) but is not
    available offline — documented in DESIGN.md."""
    n = coords.shape[0]
    order = np.arange(n)

    def rcb(idx, parts):
        if parts == 1:
            return [idx]
        d = int(np.argmax(coords[idx].max(0) - coords[idx].min(0)))
        srt = idx[np.argsort(coords[idx, d], kind="stable")]
        half = parts // 2
        cut = len(idx) * half // parts
        return rcb(srt[:cut], half) + rcb(srt[cut:], parts - half)

    groups = rcb(order, p)
    return np.concatenate(groups)


# ---------------------------------------------------------------------------
# DSparseTensor
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DistMeta:
    n: int
    p: int
    n_loc: int          # padded local rows (uniform)
    h_lo: int
    h_hi: int
    nnz_loc: int        # padded local nnz (uniform)
    axis: str
    symmetric: bool


@jax.tree_util.register_pytree_node_class
class DSparseTensor:
    """Row-block distributed sparse matrix (paper §3.3).

    Storage: stacked per-shard arrays with leading dim P, sharded over the
    mesh axis — ``lval (P, nnz_loc)``, ``lrow`` local row ids, ``lcol``
    indices into the halo-extended local vector.  Single-neighbour halos
    (h_lo, h_hi ≤ n_loc) are asserted at construction; wider stencils would
    add ppermute hops (documented, not needed for the paper's workloads).
    """

    def __init__(self, meta: DistMeta, lval, lrow, lcol, mesh: Mesh,
                 lval_t=None, lrow_t=None, lcol_t=None):
        self.meta = meta
        self.lval, self.lrow, self.lcol = lval, lrow, lcol
        self.lval_t, self.lrow_t, self.lcol_t = lval_t, lrow_t, lcol_t
        self.mesh = mesh

    def tree_flatten(self):
        return ((self.lval, self.lrow, self.lcol, self.lval_t, self.lrow_t,
                 self.lcol_t), (self.meta, self.mesh))

    @classmethod
    def tree_unflatten(cls, aux, children):
        meta, mesh = aux
        return cls(meta, children[0], children[1], children[2], mesh,
                   children[3], children[4], children[5])

    # -- construction --------------------------------------------------------
    @classmethod
    def from_global(cls, val, row, col, shape, mesh: Mesh, axis: str = "data",
                    symmetric: Optional[bool] = None):
        """Partition a global COO matrix across ``mesh[axis]`` (eager)."""
        val = np.asarray(val); row = np.asarray(row); col = np.asarray(col)
        n = shape[0]
        p = mesh.shape[axis]
        if symmetric is None:
            from .sparse import detect_properties
            symmetric = detect_properties(val, row, col, shape)["symmetric"]
        bounds = partition_simple(n, p)
        n_loc = int(np.max(np.diff(bounds)))

        def build(val, row, col):
            lvals, lrows, lcols = [], [], []
            h_lo = h_hi = 0
            for q in range(p):
                s, e = bounds[q], bounds[q + 1]
                m = (row >= s) & (row < e)
                h_lo = max(h_lo, int(max(0, s - col[m].min())) if m.any() else 0)
                h_hi = max(h_hi, int(max(0, col[m].max() - (e - 1))) if m.any() else 0)
            assert h_lo <= n_loc and h_hi <= n_loc, (
                "halo wider than one neighbour shard — repartition or add hops")
            nnz_loc = 0
            for q in range(p):
                s, e = bounds[q], bounds[q + 1]
                m = (row >= s) & (row < e)
                nnz_loc = max(nnz_loc, int(m.sum()))
            for q in range(p):
                s, e = bounds[q], bounds[q + 1]
                m = (row >= s) & (row < e)
                v = val[..., m]
                r = row[m] - s
                # columns indexed into [h_lo | own n_loc | h_hi]
                c = col[m] - s + h_lo
                pad = nnz_loc - m.sum()
                v = np.concatenate([v, np.zeros(val.shape[:-1] + (pad,), val.dtype)], -1)
                r = np.concatenate([r, np.zeros(pad, np.int32)])
                c = np.concatenate([c, np.zeros(pad, np.int32)])
                lvals.append(v); lrows.append(r.astype(np.int32)); lcols.append(c.astype(np.int32))
            return (np.stack(lvals, 0), np.stack(lrows, 0), np.stack(lcols, 0),
                    h_lo, h_hi, nnz_loc)

        lval, lrow, lcol, h_lo, h_hi, nnz_loc = build(val, row, col)
        if symmetric:
            lval_t = lrow_t = lcol_t = None
        else:
            lval_t, lrow_t, lcol_t, h_lo_t, h_hi_t, nnz_t = build(val, col, row)
            h_lo, h_hi = max(h_lo, h_lo_t), max(h_hi, h_hi_t)
            nnz_loc = max(nnz_loc, nnz_t)
            # rebuild both with unified halos/padding
            lval, lrow, lcol, *_ = _rebuild(val, row, col, bounds, p, n_loc,
                                            h_lo, nnz_loc)
            lval_t, lrow_t, lcol_t, *_ = _rebuild(val, col, row, bounds, p,
                                                  n_loc, h_lo, nnz_loc)
        meta = DistMeta(n=n, p=p, n_loc=n_loc, h_lo=h_lo, h_hi=h_hi,
                        nnz_loc=nnz_loc, axis=axis, symmetric=bool(symmetric))
        shard = NamedSharding(mesh, P(axis))
        dev = lambda a: jax.device_put(jnp.asarray(a), shard)
        if symmetric:
            return cls(meta, dev(lval), dev(lrow), dev(lcol), mesh)
        return cls(meta, dev(lval), dev(lrow), dev(lcol), mesh,
                   dev(lval_t), dev(lrow_t), dev(lcol_t))

    # -- stacked <-> global --------------------------------------------------
    def stack_vector(self, x_global):
        """(n,) → (P, n_loc) padded+sharded."""
        n, p, n_loc = self.meta.n, self.meta.p, self.meta.n_loc
        bounds = partition_simple(n, p)
        rowsz = np.diff(bounds)
        parts = [np.pad(np.asarray(x_global)[bounds[q]:bounds[q + 1]],
                        (0, n_loc - rowsz[q])) for q in range(p)]
        arr = jnp.asarray(np.stack(parts, 0))
        return jax.device_put(arr, NamedSharding(self.mesh, P(self.meta.axis)))

    def gather_global(self, x_stacked):
        """(P, n_loc) → (n,) on host."""
        n, p, n_loc = self.meta.n, self.meta.p, self.meta.n_loc
        bounds = partition_simple(n, p)
        xs = np.asarray(jax.device_get(x_stacked))
        return np.concatenate([xs[q][: bounds[q + 1] - bounds[q]]
                               for q in range(p)])

    # -- distributed ops ------------------------------------------------------
    def _local_matvec(self, lval, lrow, lcol, x_loc):
        """halo exchange + purely local SpMV (paper Eq. 5)."""
        m = self.meta
        x_ext = halo_exchange(x_loc, m.h_lo, m.h_hi, m.axis)
        return jax.ops.segment_sum(lval * x_ext[lcol], lrow,
                                   num_segments=m.n_loc)

    def matvec(self, x_stacked):
        m = self.meta
        spec = P(m.axis)

        @partial(shard_map, mesh=self.mesh,
                 in_specs=(spec, spec, spec, spec), out_specs=spec,
                 check_rep=False)
        def run(lval, lrow, lcol, x):
            y = self._local_matvec(lval[0], lrow[0], lcol[0], x[0])
            return y[None]

        return run(self.lval, self.lrow, self.lcol, x_stacked)

    def solve(self, b_stacked, *, method: str = "auto", tol: float = 1e-6,
              atol: float = 0.0, maxiter: int = 1000, precond: str = "jacobi",
              pipelined: bool = False):
        """Distributed, differentiable solve (adjoint: one distributed solve
        of Aᵀλ = g + local O(nnz) gradient assembly — paper §3.3)."""
        m = self.meta
        if method == "auto":
            method = "cg" if m.symmetric else "bicgstab"
        transposable = self.lval_t is not None

        def run_solve(lval, lrow, lcol, b):
            return self._shard_solve(lval, lrow, lcol, b, method, tol, atol,
                                     maxiter, precond, pipelined)

        @jax.custom_vjp
        def dsolve(lval, b):
            return run_solve(lval, self.lrow, self.lcol, b)

        def fwd(lval, b):
            x = lax.stop_gradient(run_solve(lval, self.lrow, self.lcol, b))
            return x, (lval, x)

        def bwd(res, g):
            lval, x = res
            if m.symmetric:
                lam = run_solve(lval, self.lrow, self.lcol, g)
            else:
                # transposed operator: swap to the Aᵀ partition.  The val
                # arrays of A and Aᵀ differ by a permutation computed at
                # construction; gradients flow through lval via the same
                # permutation (both partitions were built from identical
                # global val ordering, entry-matched by padding).
                lam = self._shard_solve(self.lval_t, self.lrow_t, self.lcol_t,
                                        g, method, tol, atol, maxiter, precond,
                                        pipelined)
                lam = lax.stop_gradient(lam)
            # local matrix-gradient assembly: −λ_i x_j with halo'd x
            spec = P(m.axis)

            @partial(shard_map, mesh=self.mesh,
                     in_specs=(spec, spec, spec, spec), out_specs=spec,
                     check_rep=False)
            def assemble(lamq, xq, lrow, lcol):
                x_ext = halo_exchange(xq[0], m.h_lo, m.h_hi, m.axis)
                gval = -(lamq[0][lrow[0]] * x_ext[lcol[0]])
                return gval[None]

            gval = assemble(lam, x, self.lrow, self.lcol)
            return gval, lam

        dsolve.defvjp(fwd, bwd)
        return dsolve(self.lval, b_stacked)

    def _shard_solve(self, lval, lrow, lcol, b, method, tol, atol, maxiter,
                     precond, pipelined):
        m = self.meta
        spec = P(m.axis)

        @partial(shard_map, mesh=self.mesh,
                 in_specs=(spec, spec, spec, spec), out_specs=spec,
                 check_rep=False)
        def run(lval, lrow, lcol, b):
            lv, lr, lc, bq = lval[0], lrow[0], lcol[0], b[0]
            mv = lambda x: self._local_matvec(lv, lr, lc, x)
            pdot = lambda u, v: lax.psum(jnp.sum(u * v), m.axis)
            if precond == "jacobi":
                diag = jax.ops.segment_sum(
                    jnp.where(lr + m.h_lo == lc, lv, 0.0), lr,
                    num_segments=m.n_loc)
                inv = jnp.where(jnp.abs(diag) > 1e-30, 1.0 / diag, 1.0)
                M = lambda r: inv * r
            else:
                M = lambda r: r
            if pipelined and method == "cg":
                x, _ = pipelined_cg(mv, bq, M=M, tol=tol, atol=atol,
                                    maxiter=maxiter, axis=m.axis)
            elif method == "cg":
                x, _ = _solvers.cg(mv, bq, M=M, tol=tol, atol=atol,
                                   maxiter=maxiter, dot=pdot)
            elif method == "bicgstab":
                x, _ = _solvers.bicgstab(mv, bq, M=M, tol=tol, atol=atol,
                                         maxiter=maxiter, dot=pdot)
            else:
                raise ValueError(f"unknown distributed method {method!r}")
            return x[None]

        return run(lval, lrow, lcol, b)

    def eigsh(self, k: int = 4, *, tol: float = 1e-6, maxiter: int = 200,
              seed: int = 0):
        """Distributed LOBPCG: Gram-matrix Rayleigh–Ritz (psum'd s×s),
        halo-exchange matvecs.  Hellmann–Feynman adjoint assembled locally."""
        m = self.meta
        spec = P(m.axis)

        def impl(lval):
            @partial(shard_map, mesh=self.mesh,
                     in_specs=(spec, spec, spec), out_specs=(P(None), spec),
                     check_rep=False)
            def run(lval, lrow, lcol):
                lv, lr, lc = lval[0], lrow[0], lcol[0]
                mv = lambda x: self._local_matvec(lv, lr, lc, x)
                key = jax.random.PRNGKey(seed + lax.axis_index(m.axis))
                X0 = jax.random.normal(key, (k, m.n_loc), lval.dtype)
                pgram = lambda S1, S2: lax.psum(S1 @ S2.T, m.axis)
                w, X, _ = _solvers.lobpcg_general(mv, X0, gram=pgram, tol=tol,
                                                  maxiter=maxiter)
                return w, jnp.swapaxes(X, 0, 1)[None]  # (P, n_loc, k)

            return run(lval, self.lrow, self.lcol)

        @jax.custom_vjp
        def deig(lval):
            return impl(lval)

        def fwd(lval):
            w, V = jax.tree.map(lax.stop_gradient, impl(lval))
            return (w, V), (lval, w, V)

        def bwd(res, cot):
            lval, w, V = res
            gw, _ = cot  # eigenvector cotangents: deflated solves — local-only
                         # variant omitted in distributed mode (paper exposes
                         # eigenvalue grads; vector grads are a single-device
                         # feature here)

            @partial(shard_map, mesh=self.mesh,
                     in_specs=(P(None), spec, spec, spec), out_specs=spec,
                     check_rep=False)
            def assemble(gw, V, lrow, lcol):
                Vq = V[0]                      # (n_loc, k)
                Vx = jnp.swapaxes(Vq, 0, 1)    # (k, n_loc)
                V_ext = jax.vmap(lambda v: halo_exchange(v, self.meta.h_lo,
                                                         self.meta.h_hi,
                                                         self.meta.axis))(Vx)
                lr, lc = lrow[0], lcol[0]
                gval = jnp.einsum("k,ke,ke->e", gw, Vx[:, lr], V_ext[:, lc])
                return gval[None]

            return (assemble(gw, V, self.lrow, self.lcol),)

        deig.defvjp(fwd, bwd)
        return deig(self.lval)

    def slogdet(self):
        """Gathers to one host and densifies — runtime-warned, does not scale
        (paper §3.3 'Scope of distributed gradients')."""
        import warnings
        warnings.warn("DSparseTensor.slogdet gathers the global matrix onto "
                      "one process — O(n²) memory; not distributed-scalable.")
        raise NotImplementedError(
            "gather via .gather_global + rebuild SparseTensor for slogdet")


def _rebuild(val, row, col, bounds, p, n_loc, h_lo, nnz_loc):
    lvals, lrows, lcols = [], [], []
    for q in range(p):
        s, e = bounds[q], bounds[q + 1]
        m = (row >= s) & (row < e)
        v = val[..., m]
        r = row[m] - s
        c = col[m] - s + h_lo
        pad = nnz_loc - int(m.sum())
        v = np.concatenate([v, np.zeros(val.shape[:-1] + (pad,), val.dtype)], -1)
        r = np.concatenate([r, np.zeros(pad, np.int32)])
        c = np.concatenate([c, np.zeros(pad, np.int32)])
        lvals.append(v); lrows.append(r.astype(np.int32)); lcols.append(c.astype(np.int32))
    return np.stack(lvals, 0), np.stack(lrows, 0), np.stack(lcols, 0)


class DSparseTensorList:
    """Distributed batch with distinct patterns — per-element dispatch."""

    def __init__(self, tensors):
        self.tensors = list(tensors)

    def solve(self, bs, **kw):
        return [A.solve(b, **kw) for A, b in zip(self.tensors, bs)]


# ---------------------------------------------------------------------------
# pipelined CG — beyond-paper (paper App. C names this as the roadmap item)
# ---------------------------------------------------------------------------

def pipelined_cg(matvec: Callable, b: jax.Array, *, M: Callable = lambda r: r,
                 tol: float = 1e-6, atol: float = 0.0, maxiter: int = 1000,
                 axis: Optional[str] = None):
    """Ghysels–Vanroose pipelined CG: ONE fused length-2 reduction per
    iteration instead of two separate all_reduces, and the reduction can
    overlap the SpMV.  Halves the latency term of the collective roofline at
    large P (see EXPERIMENTS.md §Perf)."""
    psum = (lambda v: lax.psum(v, axis)) if axis else (lambda v: v)
    dot2 = lambda a, b_, c, d: psum(jnp.stack([jnp.sum(a * b_), jnp.sum(c * d)]))

    x = jnp.zeros_like(b)
    r = b - matvec(x)
    u = M(r)
    w = matvec(u)
    gd = dot2(r, u, w, u)
    gamma, delta = gd[0], gd[1]
    bnorm = jnp.sqrt(psum(jnp.sum(b * b)))
    target = jnp.maximum(tol * bnorm, atol)
    z = jnp.zeros_like(b); q = jnp.zeros_like(b)
    s = jnp.zeros_like(b); p = jnp.zeros_like(b)
    one = jnp.asarray(1.0, b.dtype)

    def cond(st):
        *_, k = st
        r = st[1]
        rn = jnp.sqrt(psum(jnp.sum(r * r)))
        return (k < maxiter) & (rn > target)

    def body(st):
        (x, r, u, w, z, q, s, p, gamma, delta, gamma_prev, alpha_prev, k) = st
        m_ = M(w)
        n_ = matvec(m_)
        beta = jnp.where(k == 0, 0.0, gamma / gamma_prev)
        alpha = jnp.where(
            k == 0, gamma / delta,
            gamma / (delta - beta * gamma / jnp.where(alpha_prev == 0.0, one,
                                                      alpha_prev)))
        z = n_ + beta * z
        q = m_ + beta * q
        s = w + beta * s
        p = u + beta * p
        x = x + alpha * p
        r = r - alpha * s
        u = u - alpha * q
        w = w - alpha * z
        gd = dot2(r, u, w, u)
        return (x, r, u, w, z, q, s, p, gd[0], gd[1], gamma, alpha, k + 1)

    st0 = (x, r, u, w, z, q, s, p, gamma, delta, one, jnp.asarray(0.0, b.dtype),
           jnp.array(0))
    st = lax.while_loop(cond, body, st0)
    x, r = st[0], st[1]
    rn = jnp.sqrt(psum(jnp.sum(r * r)))
    return x, _solvers.SolveInfo(st[-1], rn, rn <= target)
