"""O(1)-graph adjoint differentiation framework (paper §3.2).

Every solve is wrapped in ``jax.custom_vjp`` so the autodiff graph contains a
single node regardless of solver iterations or backend — the JAX rendering of
torch-sla's ``torch.autograd.Function`` layer.  Instances of Eq. (2):

* linear   (Eq. 3):  Aᵀλ = ∂L/∂x;   ∂L/∂b = λ,  ∂L/∂A_ij = −λ_i x_j  (pattern only)
* nonlinear:         Jᵀλ = ∂L/∂u*;  ∂L/∂θ = −λᵀ ∂F/∂θ  (via jax.vjp, matrix-free)
* eigen    (Eq. 4):  ∂λ_k/∂A_ij = v_ki v_kj (Hellmann–Feynman); eigenvector
                     cotangents take one deflated linear solve per pair.

Only (A, x*) are stashed by the forward — O(n + nnz) residency; intermediate
Krylov iterates are never referenced (paper Table 2).
"""
from __future__ import annotations

from functools import partial
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from . import dispatch as _dispatch
from . import options as _options
from . import solvers as _solvers
from .dispatch import SolverConfig
from .sparse import SparseTensor

__all__ = ["sparse_solve", "dist_sparse_solve", "nonlinear_solve",
           "sparse_eigsh", "sparse_slogdet"]


def _sum_to_shape(x: jax.Array, shape) -> jax.Array:
    """Reverse broadcasting: sum x down to ``shape``."""
    if x.shape == tuple(shape):
        return x
    extra = x.ndim - len(shape)
    x = x.sum(axis=tuple(range(extra))) if extra else x
    axes = tuple(i for i, (a, b) in enumerate(zip(x.shape, shape)) if a != b)
    return x.sum(axis=axes, keepdims=True) if axes else x


# ---------------------------------------------------------------------------
# linear solve (paper §3.2.2 "Linear systems")
# ---------------------------------------------------------------------------

def sparse_solve(cfg: SolverConfig, A: SparseTensor, b: jax.Array,
                 x0: Optional[jax.Array] = None) -> jax.Array:
    """Differentiable A.solve(b).  ``cfg`` must already be resolved.

    The forward fetches (or analyzes once) the pattern's cached
    :class:`~repro.core.dispatch.SolverPlan`; the backward solves Aᵀλ = g
    through ``plan.transpose()`` — the SAME plan object for symmetric
    patterns (kernel layout + preconditioner build reused); for the direct
    backend a shared-artifact transpose plan that runs the mirrored (Uᵀ, Lᵀ)
    sweeps on the FORWARD numeric factors (the per-values setup memo is
    shared, so the backward refactorizes nothing); a once-analyzed transposed
    sibling otherwise.  No re-dispatch, no re-analysis per call.
    """
    plan = _dispatch.get_plan(A, cfg)
    row, col = plan.row, plan.col

    @jax.custom_vjp
    def solve_fn(val, rhs):
        x, _ = plan.solve(plan.matrix(val), rhs, x0, cfg=cfg)
        return x

    def fwd(val, rhs):
        x, _ = plan.solve(plan.matrix(val), rhs, x0, cfg=cfg)
        x = jax.lax.stop_gradient(x)
        return x, (val, x)

    def bwd(res, g):
        val, x = res
        # adjoint system Aᵀ λ = g — forward plan's transpose view (§3.2.3).
        # ``val`` is the identical array object the forward saw (custom_vjp
        # residual), so backends with a per-values setup memo (direct) reuse
        # the forward factorization here instead of re-running setup.
        tplan = plan.transpose()
        lam, _ = tplan.solve(tplan.matrix(val), g, None, cfg=tplan.adapt(cfg))
        # ∂L/∂A_ij = −λ_i x_j  on the sparsity pattern — O(nnz)
        gval_full = -(lam[..., row] * x[..., col])
        gval = _sum_to_shape(gval_full, val.shape)
        gb = _sum_to_shape(lam, b.shape)
        return gval, gb

    solve_fn.defvjp(fwd, bwd)
    return solve_fn(A.val, b)


def sparse_solve_with_info(cfg: SolverConfig, A: SparseTensor, b, x0=None):
    """Non-differentiable variant that also returns SolveInfo."""
    return _dispatch.solve_impl(cfg, A, b, x0)


# ---------------------------------------------------------------------------
# distributed linear solve (paper §3.3) — same plan discipline on a mesh
# ---------------------------------------------------------------------------

def dist_sparse_solve(cfg: SolverConfig, D, b, x0=None) -> jax.Array:
    """Differentiable ``DSparseTensor.solve`` through the plan engine.

    The forward fetches (or analyzes once) the distributed plan — halo
    program, partition bounds, preconditioner build, and for non-symmetric
    patterns the Aᵀ partition, all frozen as plan artifacts.  The backward
    solves Aᵀλ = g through ``plan.transpose()``: the SAME plan object for
    symmetric patterns (halo program + preconditioner build + per-values
    setup memo reused), a shared-artifact transposed sibling otherwise whose
    stacked Aᵀ values are derived from the forward values by the plan's
    cached gather map — never rebuilt per call, never instance state.  The
    matrix gradient is the local O(nnz) assembly −λ_i x_j with halo'd x.
    """
    from . import distributed as _dist
    plan = _dispatch.get_plan(D, cfg)

    @jax.custom_vjp
    def solve_fn(lval, rhs):
        x, _ = plan.solve(D.with_values(lval), rhs, x0, cfg=cfg)
        return x

    def fwd(lval, rhs):
        x, _ = plan.solve(D.with_values(lval), rhs, x0, cfg=cfg)
        x = jax.lax.stop_gradient(x)
        return x, (lval, x)

    def bwd(res, g):
        lval, x = res
        tplan = plan.transpose()
        if tplan is plan:
            # symmetric: same plan, same values — the setup memo makes the
            # adjoint preconditioner refresh a reuse, not a re-trace
            lam, _ = tplan.solve(D.with_values(lval), g, None,
                                 cfg=tplan.adapt(cfg))
        else:
            lval_t = _dist.transpose_values(plan, lval)
            At = _dist.transpose_view(tplan, lval_t)
            lam, _ = tplan.solve(At, g, None, cfg=tplan.adapt(cfg))
        lam = jax.lax.stop_gradient(lam)
        gval = _dist.assemble_matrix_grad(plan, lam, x)
        return gval, lam

    solve_fn.defvjp(fwd, bwd)
    return solve_fn(D.lval, b)


# ---------------------------------------------------------------------------
# nonlinear solve (paper §3.2.2 "Nonlinear systems")
# ---------------------------------------------------------------------------

def nonlinear_solve(residual: Callable, x0: jax.Array, *theta,
                    method: str = "newton", tol: float = 1e-8,
                    maxiter: int = 50, inner_tol: float = 1e-10,
                    inner_maxiter: int = 1000, damping: float = 1.0,
                    anderson_m: int = 5, linear_solver=None,
                    jac_pattern=None, assemble_jacobian=None,
                    symmetric: Optional[bool] = None):
    """Solve F(u, θ) = 0 for u with O(1)-graph adjoint gradients w.r.t. θ.

    ``residual(u, *theta)`` is any JAX-traceable function.  The forward may
    take many Newton/Picard/Anderson iterations (each with inner linear
    solves); the backward is ONE adjoint solve Jᵀλ = g plus one VJP into θ.

    Default (matrix-free) path: Newton inner solves and the adjoint run
    BiCGStab on ``jax.jvp``/``jax.vjp`` of the residual — no pattern needed.

    SparseNewton path (paper §3.2.2): pass ``jac_pattern=`` — the mesh-fixed
    Jacobian sparsity as a :class:`~repro.core.sparse.SparseTensor` or
    ``(row, col[, n])`` index arrays — and optionally ``linear_solver=``, a
    :class:`~repro.core.dispatch.SolverConfig` steering the inner solves
    through the plan engine (``backend="direct"``, ``precond="amg"``, any
    registered backend).  The pattern is colored once, ONE analyzed plan
    serves every Newton step, and the IFT backward solves Jᵀλ = g through
    ``plan.transpose()`` on the converged step's factors/hierarchy — zero
    extra factorizations (see :class:`repro.core.nonlinear.SparseNewton`).
    ``assemble_jacobian(u, *theta) -> values`` overrides the coloring-based
    assembly; ``symmetric=`` overrides the pattern's symmetry detection.
    For ``method="picard"``/``"anderson"`` the forward stays fixed-point
    iteration but the IFT backward still runs through the plan (one
    assembly + setup at the converged point).
    """
    theta = tuple(theta)
    sn = None
    if jac_pattern is not None:
        from .nonlinear import SparseNewton
        cfg = linear_solver if linear_solver is not None else \
            SolverConfig(tol=inner_tol, maxiter=inner_maxiter)
        sn = SparseNewton(residual, jac_pattern, linear_solver=cfg,
                          assemble_jacobian=assemble_jacobian,
                          symmetric=symmetric)
    elif linear_solver is not None:
        raise ValueError("linear_solver= requires jac_pattern= declaring "
                         "the Jacobian sparsity")

    @jax.custom_vjp
    def nl(theta):
        u, _ = _forward(theta)
        return u

    def _forward(theta):
        F = lambda u: residual(u, *theta)
        if method == "newton":
            if sn is not None:
                u, _, vals = sn._solve_full(x0, *theta, tol=tol,
                                            maxiter=maxiter, damping=damping)
                return u, vals
            u, _ = _solvers.newton_solve(F, x0, tol=tol, maxiter=maxiter,
                                         damping=damping,
                                         inner_tol=inner_tol,
                                         inner_maxiter=inner_maxiter)
        elif method == "picard":
            u, _ = _solvers.picard_solve(lambda u: u - F(u), x0, tol=tol,
                                         maxiter=maxiter)
        elif method == "anderson":
            u, _ = _solvers.anderson_solve(lambda u: u - F(u), x0, tol=tol,
                                           maxiter=maxiter, m=anderson_m)
        else:
            raise ValueError(f"unknown nonlinear method {method!r}")
        if sn is not None:
            # fixed-point forward, plan-engine backward: one assembly at u*
            # (its setup is memoized, so the bwd transpose solve reuses it)
            return u, sn.assemble(u, *theta)
        return u, None

    def fwd(theta):
        u, vals = _forward(theta)
        # NOTE: ``vals`` is stashed as the identical array object the plan's
        # setup memo keyed on — do not stop_gradient it (fresh array object,
        # memo miss → a spurious refactorization in the backward)
        return jax.lax.stop_gradient(u), (theta, u, vals)

    def bwd(res, g):
        theta, u, vals = res
        if vals is not None:
            # Jᵀ λ = g on the transpose view of the step plan — converged
            # factors/hierarchy reused, zero refactorization (Eq. 2)
            lam, _ = sn.solve_adjoint(vals, g)
        else:
            # matrix-free via vjp (paper: exact only once F(u*,θ) ≈ 0;
            # early termination biases the gradient)
            _, vjp_u = jax.vjp(lambda uu: residual(uu, *theta), u)
            JT = lambda v: vjp_u(v)[0]
            lam, _ = _solvers.bicgstab(JT, g, tol=inner_tol,
                                       maxiter=inner_maxiter)
        # ∂L/∂θ = −λᵀ ∂F/∂θ
        _, vjp_th = jax.vjp(lambda *th: residual(u, *th), *theta)
        gtheta = jax.tree.map(lambda t: -t, vjp_th(lam))
        return (tuple(gtheta),)

    nl.defvjp(fwd, bwd)
    return nl(theta)


# ---------------------------------------------------------------------------
# symmetric eigensolve (paper §3.2.2 "Eigenvalue problems")
# ---------------------------------------------------------------------------

def sparse_eigsh(A: SparseTensor, k: int = 6, *, method: str = "lobpcg",
                 tol: float = 1e-6, maxiter: int = 200,
                 compute_vector_grads: bool = True, largest: bool = False,
                 precond: Optional[str] = None, seed: int = 0):
    """k extremal eigenpairs of symmetric A with Hellmann–Feynman adjoint.

    Returns ``(w (…,k), V (…,k,n))``.  Eigenvalue cotangents cost one O(nnz)
    outer product; eigenvector cotangents one deflated CG solve per pair.
    Simple (non-degenerate) eigenvalues assumed — paper §5.

    ``precond`` (``"amg"``, ``"jacobi"``, ``"block_jacobi"``, ``"ilu"``, ...;
    LOBPCG only) routes the residual preconditioner through the plan engine:
    the pattern's cached plan builds the hierarchy/factors ONCE at analyze
    time, the per-values refresh goes through the plan's setup memo —
    shared with any linear solves on the same tensor — and the backward's
    deflated CG reuses the same apply (for ``largest=False``, where the
    deflated operator A − λ_k I is positive on the complement; the
    ``largest=True`` backward stays unpreconditioned).
    """
    row, col, n = A.row, A.col, A.shape[0]

    pplan = None
    if precond is not None:
        if method != "lobpcg":
            raise ValueError(f"precond= requires method='lobpcg', "
                             f"got method={method!r}")
        pcfg = SolverConfig(backend="jnp", method="cg", tol=tol,
                            maxiter=maxiter, precond=precond)
        pplan = _dispatch.get_plan(A, pcfg)

    def _make_M(val, mv):
        """Single-vector preconditioner apply from the plan's memoized
        values-setup — LOBPCG vmaps it over the residual block."""
        _, pstate, _ = pplan.setup(pplan.matrix(val))
        return pplan.artifacts["precond"].make_apply(pstate, mv)

    def _impl(val):
        mv = _dispatch.make_matvec(A.with_values(val))
        if method == "lobpcg":
            X0 = jax.random.normal(jax.random.PRNGKey(seed), (k, n), val.dtype)
            M = _make_M(val, mv) if pplan is not None else _solvers._identity
            w, V, _ = _solvers.lobpcg(mv, X0, M=M, tol=tol, maxiter=maxiter,
                                      largest=largest)
            return w, V
        if method == "lanczos":
            mv2 = mv if not largest else (lambda v: -mv(v))
            w, V = _solvers.eigsh_lanczos(mv2, n, k,
                                          num_steps=min(max(4 * k, 32), n),
                                          dtype=val.dtype, seed=seed)
            return (-w[::-1], V[::-1]) if largest else (w, V)
        raise ValueError(f"unknown eig method {method!r}")

    @jax.custom_vjp
    def eig_fn(val):
        return _impl(val)

    def fwd(val):
        w, V = jax.tree.map(jax.lax.stop_gradient, _impl(val))
        return (w, V), (val, w, V)

    def bwd(res, cot):
        val, w, V = res
        gw, gV = cot
        # Hellmann–Feynman eigenvalue term: Σ_k gw_k v_ki v_kj on the pattern
        gval = jnp.einsum("k,ke,ke->e", gw, V[:, row], V[:, col])
        if compute_vector_grads:
            # eigenvector term: y v_kᵀ with y = (λ_k I − A)⁺ (I − v_k v_kᵀ) g.
            # Contributions from the OTHER COMPUTED pairs are analytic
            # (gᵀv_j/(λ_k−λ_j)); the uncomputed complement — where A − λ_k I
            # is definite for extremal pairs — takes one deflated CG solve.
            mv = _dispatch.make_matvec(A.with_values(val))
            # plan-engine preconditioner for the deflated solves: ``val`` is
            # the identical array the forward set up → setup-memo hit, the
            # SAME hierarchy/factors serve forward and backward.  Skipped for
            # largest=True (the deflated operator is negative there, an SPD
            # M ≈ A⁻¹ would break CG).
            Mp = _make_M(val, mv) if (pplan is not None and not largest) \
                else None

            def pair_grad(i, acc):
                lam_i = w[i]
                v_i = V[i]
                gv = gV[i]
                # analytic part over computed pairs j ≠ i (simple eigenvalues
                # assumed — paper §5; degenerate clusters are out of scope)
                dif = lam_i - w
                coeff = jnp.where(jnp.arange(k) == i, 0.0,
                                  (V @ gv) / jnp.where(jnp.abs(dif) < 1e-12,
                                                       jnp.inf, dif))
                y_comp = coeff @ V
                # deflated solve on the complement of ALL computed pairs
                proj = lambda z: z - V.T @ (V @ z)
                op = lambda z: proj(mv(proj(z)) - lam_i * proj(z))
                rhs = -proj(gv)
                Mdef = _solvers._identity if Mp is None else \
                    (lambda z: proj(Mp(proj(z))))
                y_rest, _ = _solvers.cg(op, rhs, M=Mdef, tol=tol,
                                        maxiter=maxiter * 4)
                y = y_comp + proj(y_rest)
                # the solver sees sym(A): differentiate the symmetrized map
                return acc + 0.5 * (y[row] * v_i[col] + v_i[row] * y[col])

            gval = jax.lax.fori_loop(0, k, pair_grad, gval)
        return (gval,)

    eig_fn.defvjp(fwd, bwd)
    return eig_fn(A.val)


# ---------------------------------------------------------------------------
# log-determinant (paper §3.3) — sparse via cached LDLᵀ/LU factors within
# the direct_budget option, dense fallback beyond
# ---------------------------------------------------------------------------

def _slogdet_direct_plan(A: SparseTensor):
    """The direct-backend plan for a slogdet, or None when the sparse path
    does not apply (batched values, traced/oversize pattern, missing
    structural diagonal)."""
    n, m = A.shape
    if n != m or A.batch_shape:
        return None
    if isinstance(A.row, jax.core.Tracer) or isinstance(A.col, jax.core.Tracer):
        return None
    if n > _options.current().direct_budget:
        return None
    if not _dispatch.BACKENDS["direct"].applicable(A):
        return None
    cfg = SolverConfig(backend="direct", method="auto").resolved(A)
    return _dispatch.get_plan(A, cfg)


def sparse_slogdet(A: SparseTensor):
    """(sign, log|det|) of A with gradients on the sparsity pattern.

    For concrete square patterns within the ``direct_budget`` option the
    forward runs
    on the *cached* LDLᵀ/LU factors of the plan engine (the same numeric
    factorization a ``backend="direct"`` solve memoizes): with the symmetric
    fill-reducing permutation det(P A Pᵀ) = det(A) and unit-diagonal L, the
    determinant is the product of the stored pivots — Σ log |d_i| with sign
    tracking, O(nnz_L) work and memory, no densification.  The backward
    solves Aᵀ X = I column-by-column on the SAME factors (vmapped
    transposed sweeps) to evaluate d log|det| / dA_ij = (A⁻ᵀ)_ij on the
    pattern.  Batched values, oversize or diagonal-deficient patterns keep
    the dense fallback.
    """
    row, col = A.row, A.col
    plan = _slogdet_direct_plan(A)

    if plan is not None:
        from . import direct as _direct
        art = plan.artifacts["direct"]
        n = A.shape[0]

        @jax.custom_vjp
        def sld(val):
            C = plan.setup(plan.matrix(val))      # memoized numeric factors
            # pivot-block aware: 2x2 Bunch–Kaufman pairs contribute their
            # block determinant, not the raw diagonal product
            return _direct.factor_slogdet(art, C)

        def fwd(val):
            return sld(val), (val,)

        def bwd(res, cot):
            (val,) = res
            _, glog = cot
            C = plan.setup(plan.matrix(val))      # memo hit — zero refactor
            # columns of A⁻ᵀ from the forward factors: Aᵀ x_j = e_j
            X = jax.vmap(lambda e: _direct.factored_solve(
                art, C, e, transposed=not plan.artifacts["transposed"]))(
                    jnp.eye(n, dtype=val.dtype))
            # d log|det| / dA_ij = (A⁻ᵀ)_ij = X[j, i] on the pattern
            return (glog * X[col, row],)

        sld.defvjp(fwd, bwd)
        return sld(A.val)

    @jax.custom_vjp
    def sld(val):
        dense = A.with_values(val).todense()
        sign, logabs = jnp.linalg.slogdet(dense)
        return sign, logabs

    def fwd(val):
        out = sld(val)
        return out, (val,)

    def bwd(res, cot):
        (val,) = res
        _, glog = cot
        dense = A.with_values(val).todense()
        inv_T = jnp.linalg.inv(dense).T
        # d logdet / dA_ij = (A⁻ᵀ)_ij restricted to the pattern
        gval = glog * inv_T[..., row, col]
        return (_sum_to_shape(gval, val.shape),)

    sld.defvjp(fwd, bwd)
    return sld(A.val)
