"""Solver kernels (paper §3.1, Appendix A).

Every iterative solver is *matvec-parametric*: it takes a closure
``matvec(x) -> Ax`` so the same loop serves the ``jnp`` (COO segment-sum),
``pallas`` (block-ELL kernel), ``stencil`` (matrix-free) and ``dist``
(halo-exchange) backends.  All loops are ``lax.while_loop`` — they are *not*
reverse-differentiable, which is exactly the point: gradients always come from
the O(1)-graph adjoint in :mod:`repro.core.adjoint`.

``cg_scan`` is the deliberately-naive fixed-k differentiable CG used as the
O(k)-graph baseline of paper Fig. 2 / Table 7.
"""
from __future__ import annotations

from functools import partial
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax import lax

__all__ = [
    "SolveInfo", "SolveResult", "cg", "cg_fused", "bicgstab",
    "bicgstab_fused", "block_cg", "gmres", "cg_scan", "eigh_pinv_solve",
    "dense_solve", "newton_solve", "picard_solve", "anderson_solve",
    "lobpcg", "lanczos",
]


class SolveInfo(NamedTuple):
    iters: jax.Array       # iterations executed
    resnorm: jax.Array     # final ‖r‖₂
    converged: jax.Array   # bool


class SolveResult(NamedTuple):
    """Typed solve payload, uniform across iterative/direct/distributed
    backends — what :func:`repro.sla.solve_with_info` returns, and what the
    serving driver reports per request.

    ``iterations``/``residual``/``converged`` mirror :class:`SolveInfo`
    (per-rhs vectors for multi-rhs/batched solves, scalars otherwise);
    ``reason`` is a static string: ``"converged"``, ``"maxiter"``, or
    ``"unknown"`` when the result is still a tracer (inside jit) and the
    outcome is not concretely decidable.
    """
    x: jax.Array
    iterations: jax.Array
    residual: jax.Array
    converged: jax.Array
    reason: str


def as_solve_result(x, info: SolveInfo,
                    reason: Optional[str] = None) -> SolveResult:
    """Wrap a backend's ``(x, SolveInfo)`` pair into a :class:`SolveResult`."""
    if reason is None:
        try:
            reason = "converged" if bool(jnp.all(info.converged)) \
                else "maxiter"
        except Exception:      # traced under jit/vmap: not concretely known
            reason = "unknown"
    return SolveResult(x=x, iterations=info.iters, residual=info.resnorm,
                       converged=info.converged, reason=reason)


def _identity(x):
    return x


def eigh_pinv_solve(G, rhs, *, ridge: float = 1e-12):
    """Solve the (near-)singular symmetric system ``G x = rhs`` by a
    symmetric-eigendecomposition pseudo-inverse with a RELATIVE cutoff.

    ``G`` is symmetrized, eigenvalues below ``max(ridge, m·10·eps) ·
    max|w|`` are zeroed instead of inverted, so rank-deficient directions
    (converged/duplicate columns in :func:`block_cg`'s Gram systems, stale
    difference columns in :func:`anderson_solve`'s window) become inert
    no-ops rather than amplified roundoff.  Unlike a FIXED ridge, the cutoff
    scales with the dtype: in f32 roundoff noise sits at ~1e-7·‖G‖, far
    above a 1e-12 ridge — the fixed-ridge normal-equations solve there
    returns garbage coefficients and stagnates (the PR-7 f32 Anderson bug).
    ``rhs`` may be a vector ``(m,)`` or a matrix ``(m, k)``.
    """
    m = G.shape[0]
    cutoff = jnp.maximum(jnp.asarray(ridge, G.dtype),
                         m * 10 * jnp.finfo(G.dtype).eps)
    w, V = jnp.linalg.eigh(0.5 * (G + G.T))
    cut = jnp.max(jnp.abs(w)) * cutoff
    winv = jnp.where(jnp.abs(w) > cut, 1.0 / w, 0.0)
    if rhs.ndim == 1:
        return V @ (winv * (V.T @ rhs))
    return V @ (winv[:, None] * (V.T @ rhs))


# ---------------------------------------------------------------------------
# Krylov solvers
# ---------------------------------------------------------------------------

def cg(matvec: Callable, b: jax.Array, x0: Optional[jax.Array] = None, *,
       M: Callable = _identity, tol: float = 1e-6, atol: float = 0.0,
       maxiter: int = 1000, min_iter: int = 0,
       dot: Optional[Callable] = None):
    """Preconditioned conjugate gradient (Hestenes–Stiefel).

    Two inner products per iteration — the textbook form used by the paper
    (Alg. 1).  See ``pipelined_cg`` in core/distributed.py for the
    reduced-latency variant (beyond-paper).  ``dot`` is injectable so the
    distributed backend can psum across the mesh (paper Alg. 1 all_reduce).
    """
    x0 = jnp.zeros_like(b) if x0 is None else x0
    dot = dot or (lambda u, v: jnp.sum(u * v))
    bnorm = jnp.sqrt(dot(b, b))
    target = jnp.maximum(tol * bnorm, atol)

    r0 = b - matvec(x0)
    z0 = M(r0)
    p0 = z0
    rz0 = dot(r0, z0)

    def cond(state):
        x, r, p, rz, k = state
        return (k < maxiter) & ((jnp.sqrt(dot(r, r)) > target) | (k < min_iter))

    def body(state):
        x, r, p, rz, k = state
        Ap = matvec(p)
        alpha = rz / dot(p, Ap)
        x = x + alpha * p
        r = r - alpha * Ap
        z = M(r)
        rz_new = dot(r, z)
        p = z + (rz_new / rz) * p
        return (x, r, p, rz_new, k + 1)

    x, r, p, rz, k = lax.while_loop(cond, body, (x0, r0, p0, rz0, jnp.array(0)))
    rn = jnp.sqrt(dot(r, r))
    return x, SolveInfo(k, rn, rn <= target)


def bicgstab(matvec: Callable, b: jax.Array, x0: Optional[jax.Array] = None, *,
             M: Callable = _identity, tol: float = 1e-6, atol: float = 0.0,
             maxiter: int = 1000, dot: Optional[Callable] = None):
    """BiCGStab (van der Vorst 1992) for general (non-symmetric) systems."""
    x0 = jnp.zeros_like(b) if x0 is None else x0
    dot = dot or (lambda u, v: jnp.sum(u * v))
    bnorm = jnp.sqrt(dot(b, b))
    target = jnp.maximum(tol * bnorm, atol)
    eps = jnp.asarray(1e-30, b.dtype)

    r0 = b - matvec(x0)

    def cond(st):
        x, r, rhat, p, v, rho, alpha, omega, k, fresh = st
        return (k < maxiter) & (jnp.sqrt(dot(r, r)) > target)

    def body(st):
        x, r, rhat, p, v, rho_prev, alpha, omega, k, fresh = st
        rho = dot(rhat, r)
        rr = dot(r, r)
        # ρ-breakdown (r ⟂ r̂): restart with r̂ ← r (PETSc-style) instead of
        # stagnating — BiCGStab otherwise stalls once <r̂,r> underflows.
        restart = (jnp.abs(rho) < 1e-12 * rr) | fresh
        rhat = jnp.where(restart, r, rhat)
        rho = jnp.where(restart, rr, rho)
        beta = (rho / (rho_prev + eps)) * (alpha / (omega + eps))
        beta = jnp.where(restart, 0.0, beta)
        p = jnp.where(restart, r, r + beta * (p - omega * v))
        phat = M(p)
        v = matvec(phat)
        alpha = rho / (dot(rhat, v) + eps)
        s = r - alpha * v
        shat = M(s)
        t = matvec(shat)
        omega_new = dot(t, s) / (dot(t, t) + eps)
        x = x + alpha * phat + omega_new * shat
        r = s - omega_new * t
        return (x, r, rhat, p, v, rho, alpha, omega_new, k + 1,
                jnp.array(False))

    z = jnp.zeros_like(b)
    one = jnp.asarray(1.0, b.dtype)
    st0 = (x0, r0, r0, z, z, one, one, one, jnp.array(0), jnp.array(True))
    x, r, *_, k, _ = lax.while_loop(cond, body, st0)
    rn = jnp.sqrt(dot(r, r))
    return x, SolveInfo(k, rn, rn <= target)


def cg_fused(matvec: Callable, b: jax.Array, x0: Optional[jax.Array] = None, *,
             dinv: Optional[jax.Array] = None, M: Callable = _identity,
             tol: float = 1e-6, atol: float = 0.0, maxiter: int = 1000,
             min_iter: int = 0, interpret: Optional[bool] = None):
    """CG with the iteration fused into Pallas step kernels (single device).

    With a diagonal preconditioner (``dinv`` given) this is the merged
    Chronopoulos–Gear recurrence: α comes from α' = ρ'/(δ − βρ'/α) with
    δ = <Az, z>, so the standalone p·Ap reduction pass vanishes and each
    iteration is one matvec plus exactly two fused vector sweeps
    (``fused_cg_update`` and ``fused_cg_direction``).  The recurrence is
    algebraically identical to Hestenes–Stiefel (same iterates in exact
    arithmetic); the residual-based stopping rule absorbs the small
    floating-point divergence.

    Without ``dinv`` (external preconditioner closure ``M``) the textbook
    recurrence is kept and only the axpy/convergence-dot passes fuse
    (``fused_cg_halfstep``).
    """
    from ..kernels import solve_step as _fk

    x0 = jnp.zeros_like(b) if x0 is None else x0
    dot = lambda u, v: jnp.sum(u * v)
    bnorm = jnp.sqrt(dot(b, b))
    target = jnp.maximum(tol * bnorm, atol)
    eps = jnp.asarray(1e-30, b.dtype)

    r0 = b - matvec(x0)
    rr0 = dot(r0, r0)

    if dinv is not None:
        z0 = dinv * r0
        p0 = z0
        s0 = matvec(p0)
        rho0 = dot(r0, z0)
        alpha0 = rho0 / (dot(p0, s0) + eps)

        def cond(st):
            x, r, p, s, rho, rr, alpha, k = st
            return (k < maxiter) & ((jnp.sqrt(rr) > target) | (k < min_iter))

        def body(st):
            x, r, p, s, rho, rr, alpha, k = st
            x, r, z, rho_new, rr_new = _fk.fused_cg_update(
                x, r, p, s, dinv, alpha, interpret=interpret)
            w = matvec(z)
            beta = rho_new / (rho + eps)
            p, s, delta = _fk.fused_cg_direction(
                z, w, p, s, beta, interpret=interpret)
            alpha_new = rho_new / (delta - beta * rho_new / (alpha + eps) + eps)
            return (x, r, p, s, rho_new, rr_new, alpha_new, k + 1)

        st0 = (x0, r0, p0, s0, rho0, rr0, alpha0, jnp.array(0))
        x, r, p, s, rho, rr, alpha, k = lax.while_loop(cond, body, st0)
    else:
        z0 = M(r0)
        p0 = z0
        rz0 = dot(r0, z0)

        def cond(st):
            x, r, p, rz, rr, k = st
            return (k < maxiter) & ((jnp.sqrt(rr) > target) | (k < min_iter))

        def body(st):
            x, r, p, rz, rr, k = st
            Ap = matvec(p)
            alpha = rz / (dot(p, Ap) + eps)
            x, r, rr_new = _fk.fused_cg_halfstep(
                x, r, p, Ap, alpha, interpret=interpret)
            z = M(r)
            rz_new = dot(r, z)
            p = z + (rz_new / (rz + eps)) * p
            return (x, r, p, rz_new, rr_new, k + 1)

        st0 = (x0, r0, p0, rz0, rr0, jnp.array(0))
        x, r, p, rz, rr, k = lax.while_loop(cond, body, st0)

    rn = jnp.sqrt(rr)
    return x, SolveInfo(k, rn, rn <= target)


def bicgstab_fused(matvec: Callable, b: jax.Array,
                   x0: Optional[jax.Array] = None, *,
                   dinv: Optional[jax.Array] = None, M: Callable = _identity,
                   tol: float = 1e-6, atol: float = 0.0, maxiter: int = 1000,
                   interpret: Optional[bool] = None):
    """BiCGStab with fused Pallas step kernels (single device).

    Same recurrence as :func:`bicgstab`; the vector passes fuse into
    ``fused_bicg_p`` / ``fused_bicg_s`` (diagonal preconditioner folded in),
    ``fused_dots2`` (ω numerator+denominator in one read), and
    ``fused_bicg_tail`` (x/r updates plus next iteration's head dot <r̂,r'>
    and the convergence dot <r',r'>, carried through the loop state).
    """
    from ..kernels import solve_step as _fk

    x0 = jnp.zeros_like(b) if x0 is None else x0
    dot = lambda u, v: jnp.sum(u * v)
    bnorm = jnp.sqrt(dot(b, b))
    target = jnp.maximum(tol * bnorm, atol)
    eps = jnp.asarray(1e-30, b.dtype)

    r0 = b - matvec(x0)
    rr0 = dot(r0, r0)

    def cond(st):
        x, r, rhat, p, v, rho_prev, rho_c, alpha, omega, rr, k, fresh = st
        return (k < maxiter) & (jnp.sqrt(rr) > target)

    def body(st):
        x, r, rhat, p, v, rho_prev, rho_c, alpha, omega, rr, k, fresh = st
        # ρ = <r̂, r> was computed by last iteration's tail pass (rho_c).
        restart = (jnp.abs(rho_c) < 1e-12 * rr) | fresh
        rhat = jnp.where(restart, r, rhat)
        rho = jnp.where(restart, rr, rho_c)
        beta = (rho / (rho_prev + eps)) * (alpha / (omega + eps))
        beta = jnp.where(restart, 0.0, beta)
        if dinv is not None:
            p, phat = _fk.fused_bicg_p(r, p, v, dinv, beta, omega,
                                       restart.astype(b.dtype),
                                       interpret=interpret)
        else:
            p = jnp.where(restart, r, r + beta * (p - omega * v))
            phat = M(p)
        v = matvec(phat)
        alpha = rho / (dot(rhat, v) + eps)
        if dinv is not None:
            s, shat = _fk.fused_bicg_s(r, v, dinv, alpha, interpret=interpret)
        else:
            s = r - alpha * v
            shat = M(s)
        t = matvec(shat)
        ts, tt = _fk.fused_dots2(t, s, interpret=interpret)
        omega_new = ts / (tt + eps)
        x, r, rho_next, rr_new = _fk.fused_bicg_tail(
            x, s, t, phat, shat, rhat, alpha, omega_new, interpret=interpret)
        return (x, r, rhat, p, v, rho, rho_next, alpha, omega_new, rr_new,
                k + 1, jnp.array(False))

    z = jnp.zeros_like(b)
    one = jnp.asarray(1.0, b.dtype)
    st0 = (x0, r0, r0, z, z, one, rr0, one, one, rr0, jnp.array(0),
           jnp.array(True))
    x, r, *_, rr, k, _ = lax.while_loop(cond, body, st0)
    rn = jnp.sqrt(rr)
    return x, SolveInfo(k, rn, rn <= target)


def block_cg(matvec: Callable, B: jax.Array,
             X0: Optional[jax.Array] = None, *, M: Callable = _identity,
             tol: float = 1e-6, atol: float = 0.0, maxiter: int = 1000,
             ridge: float = 1e-12):
    """Block conjugate gradient (O'Leary 1980) for multiple right-hand sides.

    ``B`` is ``(k, n)`` — k right-hand sides sharing ONE SPD matrix.  The k
    Krylov directions are coupled through (k, k) Gram solves each iteration,
    so hard right-hand sides borrow search directions from easy ones
    (iteration count tracks the HARDEST rhs, not the sum), and every
    iteration runs its k matvecs as one ``vmap`` sweep — the same
    multi-rhs amortization the serving driver's batched dispatch exploits.
    ``matvec``/``M`` are single-vector closures, vmapped here, so every
    kernel-plan matvec and every preconditioner apply works unchanged.

    Convergence targets are per-rhs (``max(tol·‖bᵢ‖, atol)``); the loop runs
    until EVERY rhs meets its target or ``maxiter``.  Converged or linearly
    dependent directions make the Gram matrices singular — those are solved
    through a symmetric eigendecomposition pseudo-inverse with a relative
    cutoff (``ridge`` above dtype eps), so a finished/duplicate column
    becomes an inert no-op instead of amplified roundoff or NaNs
    (breakdown-free in the O'Leary rank-deficient sense).

    Returns ``(X, SolveInfo)`` with per-rhs ``resnorm``/``converged``
    vectors of length k and a scalar shared iteration count.
    """
    if B.ndim != 2:
        raise ValueError(f"block_cg expects B of shape (k, n), got {B.shape}")
    k = B.shape[0]
    X0 = jnp.zeros_like(B) if X0 is None else X0
    mv = jax.vmap(matvec)
    Mv = jax.vmap(M)
    target = jnp.maximum(tol * jnp.linalg.norm(B, axis=1), atol)

    def gram_solve(G, rhs):
        # both Gram matrices (PᵀAP and ZᵀR) are symmetric for SPD A and
        # symmetric M, up to roundoff — symmetrize and pseudo-invert
        return eigh_pinv_solve(G, rhs, ridge=ridge)

    R0 = B - mv(X0)
    Z0 = Mv(R0)
    rho0 = Z0 @ R0.T

    def cond(st):
        X, R, P, rho, it = st
        return (it < maxiter) & jnp.any(jnp.linalg.norm(R, axis=1) > target)

    def body(st):
        X, R, P, rho, it = st
        Q = mv(P)
        alpha = gram_solve(P @ Q.T, rho)       # (PᵀAP)⁻¹ ZᵀR, row convention
        X = X + alpha.T @ P
        R = R - alpha.T @ Q
        Z = Mv(R)
        rho_new = Z @ R.T
        beta = gram_solve(rho, rho_new)
        P = Z + beta.T @ P
        return (X, R, P, rho_new, it + 1)

    X, R, P, rho, it = lax.while_loop(
        cond, body, (X0, R0, Z0, rho0, jnp.array(0)))
    rn = jnp.linalg.norm(R, axis=1)
    return X, SolveInfo(it, rn, rn <= target)


def gmres(matvec: Callable, b: jax.Array, x0: Optional[jax.Array] = None, *,
          M: Callable = _identity, tol: float = 1e-6, atol: float = 0.0,
          restart: int = 32, maxiter: int = 50):
    """Restarted GMRES(m) with modified Gram–Schmidt Arnoldi.

    ``maxiter`` counts outer restarts.  Static Krylov dimension ``restart``
    keeps shapes fixed for jit.  The true residual (and its norm) is carried
    through the loop state — one matvec per restart cycle pays for both the
    convergence check and the next cycle's start vector.
    """
    x0 = jnp.zeros_like(b) if x0 is None else x0
    n = b.shape[-1]
    m = restart
    dtype = b.dtype
    bnorm = jnp.linalg.norm(b)
    target = jnp.maximum(tol * bnorm, atol)

    def arnoldi_cycle(x, r_true):
        r = M(r_true)
        beta = jnp.linalg.norm(r)
        V = jnp.zeros((m + 1, n), dtype).at[0].set(r / (beta + 1e-30))
        H = jnp.zeros((m + 1, m), dtype)

        def step(carry, j):
            V, H = carry
            w = M(matvec(V[j]))

            def mgs(i, w_h):
                w, h = w_h
                hij = jnp.where(i <= j, jnp.sum(w * V[i]), 0.0)
                return (w - hij * V[i], h.at[i].set(hij))

            w, hcol = lax.fori_loop(0, m + 1, mgs, (w, jnp.zeros(m + 1, dtype)))
            hn = jnp.linalg.norm(w)
            hcol = hcol.at[j + 1].set(hn)
            V = V.at[j + 1].set(w / (hn + 1e-30))
            H = H.at[:, j].set(hcol)
            return (V, H), None

        (V, H), _ = lax.scan(step, (V, H), jnp.arange(m))
        # least squares min ‖βe₁ − Hy‖
        e1 = jnp.zeros(m + 1, dtype).at[0].set(beta)
        y, *_ = jnp.linalg.lstsq(H, e1, rcond=None)
        return x + V[:m].T @ y

    r0 = b - matvec(x0)

    def cond(st):
        x, r, rn, k = st
        return (k < maxiter) & (rn > target)

    def body(st):
        x, r, rn, k = st
        x = arnoldi_cycle(x, r)
        r = b - matvec(x)
        return (x, r, jnp.linalg.norm(r), k + 1)

    x, r, rn, k = lax.while_loop(
        cond, body, (x0, r0, jnp.linalg.norm(r0), jnp.array(0)))
    return x, SolveInfo(k * m, rn, rn <= target)


def cg_scan(matvec: Callable, b: jax.Array, k: int,
            M: Callable = _identity, x0: Optional[jax.Array] = None):
    """Fixed-k CG via ``lax.scan`` — fully reverse-differentiable.

    This is the *naive O(k)-graph baseline* of paper §4.2: reverse-mode
    through the scan stores every per-iteration residual (O(k·n) memory),
    exactly like autograd-tracked PyTorch CG.  Never used by the adjoint path.
    """
    x0 = jnp.zeros_like(b) if x0 is None else x0
    dot = lambda u, v: jnp.sum(u * v)
    r0 = b - matvec(x0)
    z0 = M(r0)

    rz0 = dot(r0, z0)
    eps = jnp.finfo(b.dtype).eps
    tiny = jnp.asarray((100 * eps) ** 2, b.dtype) * rz0

    def step(carry, _):
        x, r, p, rz = carry
        Ap = matvec(p)
        pAp = dot(p, Ap)
        # guard: once converged (rz → 0) iterate as a no-op instead of 0/0.
        # double-where keeps reverse-mode NaN-free (the unselected branch's
        # denominator must be safe too) — the forced-k sweep of paper Fig. 2
        # runs past convergence by design.
        live = rz > tiny
        pAp_safe = jnp.where(live, pAp, 1.0)
        rz_safe = jnp.where(live, rz, 1.0)
        alpha = jnp.where(live, rz / pAp_safe, 0.0)
        x = x + alpha * p
        r = r - alpha * Ap
        z = M(r)
        rz_new = dot(r, z)
        beta = jnp.where(live, rz_new / rz_safe, 0.0)
        p = z + beta * p
        return (x, r, p, rz_new), None

    (x, r, _, _), _ = lax.scan(step, (x0, r0, z0, dot(r0, z0)), None, length=k)
    return x


# ---------------------------------------------------------------------------
# dense direct backend (TPU: batched LU/Cholesky on the MXU)
# ---------------------------------------------------------------------------

def dense_solve(A_dense: jax.Array, b: jax.Array, method: str = "lu"):
    if method == "cholesky":
        L = jnp.linalg.cholesky(A_dense)
        x = jax.scipy.linalg.cho_solve((L, True), b)
    else:
        x = jnp.linalg.solve(A_dense, b)
    return x, SolveInfo(jnp.array(1), jnp.asarray(0.0, b.dtype), jnp.array(True))


# ---------------------------------------------------------------------------
# nonlinear solvers (paper §3.2.2, "Nonlinear systems")
# ---------------------------------------------------------------------------

def newton_solve(residual: Callable, x0: jax.Array, *, tol: float = 1e-8,
                 maxiter: int = 50, dense_jacobian_budget: int = 2048,
                 inner_tol: float = 1e-8, inner_maxiter: int = 500,
                 damping: float = 1.0, linear_solver=None, jac_pattern=None,
                 assemble_jacobian: Optional[Callable] = None):
    """Newton's method.  Small systems use a dense Jacobian (MXU solve);
    large systems use matrix-free JVP-Krylov (BiCGStab) inner solves.

    Declaring the Jacobian sparsity (``jac_pattern`` — a
    :class:`~repro.core.sparse.SparseTensor` or ``(row, col, n)`` triple)
    routes every inner solve through the plan engine instead: one symbolic
    analysis serves the whole sweep, values refreshed per step
    (:class:`repro.core.nonlinear.SparseNewton`).  ``linear_solver`` is the
    inner :class:`~repro.core.dispatch.SolverConfig` (``backend="direct"``,
    ``precond="amg"``, ...); ``assemble_jacobian(u) -> values`` overrides
    the coloring-based jvp assembly.
    """
    if linear_solver is not None or jac_pattern is not None:
        if jac_pattern is None:
            raise ValueError("linear_solver= needs jac_pattern= declaring "
                             "the Jacobian sparsity")
        from .nonlinear import SparseNewton   # lazy: avoids a module cycle
        sn = SparseNewton(lambda u: residual(u), jac_pattern,
                          linear_solver=linear_solver,
                          assemble_jacobian=(
                              None if assemble_jacobian is None
                              else lambda u: assemble_jacobian(u)))
        return sn.solve(x0, tol=tol, maxiter=maxiter, damping=damping)
    n = x0.shape[-1]
    use_dense = n <= dense_jacobian_budget

    def cond(st):
        x, k, rn = st
        return (k < maxiter) & (rn > tol)

    def body(st):
        x, k, _ = st
        F = residual(x)
        if use_dense:
            J = jax.jacfwd(residual)(x)
            dx = jnp.linalg.solve(J, -F)
        else:
            mv = lambda v: jax.jvp(residual, (x,), (v,))[1]
            dx, _ = bicgstab(mv, -F, tol=inner_tol, maxiter=inner_maxiter)
        x = x + damping * dx
        rn = jnp.linalg.norm(residual(x))
        return (x, k + 1, rn)

    rn0 = jnp.linalg.norm(residual(x0))
    x, k, rn = lax.while_loop(cond, body, (x0, jnp.array(0), rn0))
    return x, SolveInfo(k, rn, rn <= tol)


def picard_solve(fixed_point: Callable, x0: jax.Array, *, tol: float = 1e-8,
                 maxiter: int = 500, relax: float = 1.0):
    """Damped fixed-point (Picard) iteration x ← (1−ω)x + ω G(x)."""
    def cond(st):
        x, k, rn = st
        return (k < maxiter) & (rn > tol)

    def body(st):
        x, k, _ = st
        x_new = (1 - relax) * x + relax * fixed_point(x)
        rn = jnp.linalg.norm(x_new - x)
        return (x_new, k + 1, rn)

    x, k, rn = lax.while_loop(cond, body, (x0, jnp.array(0), jnp.inf))
    return x, SolveInfo(k, rn, rn <= tol)


def anderson_solve(fixed_point: Callable, x0: jax.Array, *, m: int = 5,
                   tol: float = 1e-8, maxiter: int = 200, beta: float = 1.0,
                   ridge: float = 1e-12, gram_solver: str = "pinv"):
    """Anderson acceleration, type-II difference form (Walker & Ni 2011):

        f_k = G(x_k) − x_k
        γ   = argmin ‖f_k − ΔF γ‖²  (windowed least squares, window m)
        x⁺  = x_k + β f_k − (ΔX + β ΔF) γ

    Convergence is checked on ‖f_k‖ (the true fixed-point residual).

    The normal-equations Gram matrix ΔF ΔFᵀ is structurally rank-deficient
    whenever the window is degenerate — fewer iterations than ``m``
    (zero-padded rows), duplicate residual columns, or a residual space of
    dimension < m (any affine map).  ``gram_solver="pinv"`` (default) solves
    it through :func:`eigh_pinv_solve`, the relative-cutoff pseudo-inverse
    :func:`block_cg` uses for exactly the same breakdown; ``"ridge"`` keeps
    the legacy fixed-ridge ``solve(G + ridge·I)`` path, which stagnates in
    f32 (roundoff ~1e-7·‖G‖ swamps the 1e-12 ridge) — retained only as the
    A/B baseline for the regression test."""
    if gram_solver not in ("pinv", "ridge"):
        raise ValueError(f"gram_solver must be 'pinv'|'ridge', "
                         f"got {gram_solver!r}")
    n = x0.shape[-1]
    dtype = x0.dtype
    Xh = jnp.zeros((m + 1, n), dtype)   # iterate history (last row = newest)
    Fh = jnp.zeros((m + 1, n), dtype)   # residual history

    def cond(st):
        x, Xh, Fh, k, rn = st
        return (k < maxiter) & (rn > tol)

    def body(st):
        x, Xh, Fh, k, _ = st
        f = fixed_point(x) - x
        rn = jnp.linalg.norm(f)
        Xh = jnp.roll(Xh, -1, axis=0).at[-1].set(x)
        Fh = jnp.roll(Fh, -1, axis=0).at[-1].set(f)
        dX = Xh[1:] - Xh[:-1]                    # (m, n) rows: Δx_i
        dF = Fh[1:] - Fh[:-1]
        mk = jnp.minimum(k, m)                   # number of valid diffs
        valid = (jnp.arange(m) >= (m - mk))[:, None]
        dXv = jnp.where(valid, dX, 0.0)
        dFv = jnp.where(valid, dF, 0.0)
        if gram_solver == "pinv":
            gamma = eigh_pinv_solve(dFv @ dFv.T, dFv @ f, ridge=ridge)
        else:
            gram = dFv @ dFv.T + ridge * jnp.eye(m, dtype=dtype)
            gamma = jnp.linalg.solve(gram, dFv @ f)
        x_new = x + beta * f - gamma @ (dXv + beta * dFv)
        return (x_new, Xh, Fh, k + 1, rn)

    x, Xh, Fh, k, rn = lax.while_loop(
        cond, body, (x0, Xh, Fh, jnp.array(0), jnp.asarray(jnp.inf, dtype)))
    return x, SolveInfo(k, rn, rn <= tol)


# ---------------------------------------------------------------------------
# eigensolvers (paper §3.2.2 "Eigenvalue problems", §4.3 LOBPCG/Lanczos)
# ---------------------------------------------------------------------------

def lobpcg_general(matvec: Callable, X0: jax.Array, *,
                   gram: Optional[Callable] = None, M: Callable = _identity,
                   tol: float = 1e-6, maxiter: int = 200,
                   largest: bool = False):
    """Locally optimal block preconditioned CG (Knyazev 2001), block form.

    ``X0``: (k, n_local) initial block (rows are vectors).  ``gram(S1, S2)``
    computes S1 S2ᵀ with a global reduction — inject a psum'd version for the
    distributed backend (all row-space arithmetic is s×s and replicated).

    Robustness: the [X | W | P] subspace is orthonormalized by pseudo-inverse
    whitening of its Gram matrix (rank-deficient directions are masked and
    their Ritz values pushed to +inf), and the conjugate block P uses the
    classical coefficient split (its component in the non-X blocks).
    """
    k, n = X0.shape
    dtype = X0.dtype
    sign = -1.0 if largest else 1.0
    mv = (lambda v: sign * matvec(v))
    gram = gram or (lambda S1, S2: S1 @ S2.T)
    BIG = jnp.asarray(1e30, dtype)

    def rr(S):
        """Rayleigh–Ritz on the (possibly rank-deficient) row space of S.

        Whitening in the *eigenbasis* of the Gram matrix (Q = Λ^{-1/2}Vᵀ S)
        makes Q's rows exactly orthonormal on the good directions and exactly
        zero on null ones, so rank deficiency reduces to masking diagonal
        slots of the projected T."""
        G = gram(S, S)
        e, V = jnp.linalg.eigh(G)
        good = e > jnp.maximum(e[-1], 1e-30) * 1e-10
        isq = jnp.where(good, 1.0 / jnp.sqrt(jnp.maximum(e, 1e-300)), 0.0)
        W_ = isq[:, None] * V.T                    # Λ^{-1/2} Vᵀ
        Q = W_ @ S                                  # QQᵀ = diag(good)
        AQ = jax.vmap(mv)(Q)
        T = gram(Q, AQ)
        T = 0.5 * (T + T.T)
        T = T + jnp.diag(jnp.where(good, 0.0, BIG))
        w, U = jnp.linalg.eigh(T)
        C = V @ (isq[:, None] * U[:, :k])           # coefficients in S rows
        X_new = C.T @ S
        return w[:k], X_new, C

    w0, X, _ = rr(X0)
    P = jnp.zeros_like(X)

    def cond(st):
        X, w, P, k_it, rn = st
        return (k_it < maxiter) & (rn > tol)

    def body(st):
        X, w, P, k_it, _ = st
        AX = jax.vmap(mv)(X)
        R = AX - w[:, None] * X
        rr_norms = jnp.sqrt(jnp.diag(gram(R, R)))
        rn = jnp.max(rr_norms / (jnp.abs(w) + 1.0))
        Wp = jax.vmap(M)(R)
        # explicit inter-block orthogonalization (conditioning of S):
        Wp = Wp - gram(Wp, X) @ X
        Wn = jnp.sqrt(jnp.maximum(jnp.diag(gram(Wp, Wp)), 1e-300))
        Wp = Wp / Wn[:, None]
        P = P - gram(P, X) @ X
        Pn = jnp.sqrt(jnp.diag(gram(P, P)))
        P = jnp.where(Pn[:, None] > 1e-150, P / jnp.maximum(Pn, 1e-300)[:, None], P)
        S = jnp.concatenate([X, Wp, P], axis=0)
        w_new, X_new, C = rr(S)
        P_new = C[k:].T @ S[k:]                    # non-X component
        return (X_new, w_new, P_new, k_it + 1, rn)

    X, w, P, k_it, rn = lax.while_loop(
        cond, body, (X, w0, P, jnp.array(0), jnp.asarray(jnp.inf, dtype)))
    nrm = jnp.sqrt(jnp.diag(gram(X, X)))
    X = X / nrm[:, None]
    return sign * w, X, SolveInfo(k_it, rn, rn <= tol)


def lobpcg(matvec: Callable, X0: jax.Array, *, M: Callable = _identity,
           tol: float = 1e-6, maxiter: int = 200, largest: bool = False):
    """Single-device LOBPCG — see :func:`lobpcg_general`."""
    return lobpcg_general(matvec, X0, M=M, tol=tol, maxiter=maxiter,
                          largest=largest)


def lanczos(matvec: Callable, v0: jax.Array, num_steps: int):
    """Lanczos tridiagonalization with full reorthogonalization (small m).

    Returns (alphas, betas, V) — eigenvalues of T approximate extremal
    eigenvalues of A.  Used for Chebyshev-bound estimation and as an
    alternative ``eigsh`` method.
    """
    n = v0.shape[-1]
    m = num_steps
    dtype = v0.dtype
    V = jnp.zeros((m + 1, n), dtype)
    V = V.at[0].set(v0 / jnp.linalg.norm(v0))
    alphas = jnp.zeros(m, dtype)
    betas = jnp.zeros(m, dtype)

    def step(carry, j):
        V, alphas, betas = carry
        w = matvec(V[j])
        alpha = jnp.sum(w * V[j])
        w = w - alpha * V[j] - jnp.where(j > 0, betas[jnp.maximum(j - 1, 0)], 0.0) * V[jnp.maximum(j - 1, 0)]
        # full reorthogonalization (numerical hygiene at small m)
        proj = V @ w                       # (m+1,)
        mask = (jnp.arange(m + 1) <= j)
        w = w - (jnp.where(mask, proj, 0.0)[None, :] @ V).reshape(n)
        beta = jnp.linalg.norm(w)
        V = V.at[j + 1].set(w / (beta + 1e-30))
        return (V, alphas.at[j].set(alpha), betas.at[j].set(beta)), None

    (V, alphas, betas), _ = lax.scan(step, (V, alphas, betas), jnp.arange(m))
    return alphas, betas, V


def eigsh_lanczos(matvec: Callable, n: int, k: int, *, num_steps: int = 64,
                  dtype=jnp.float32, seed: int = 0):
    """k smallest eigenpairs via Lanczos + dense eigh of T, Ritz vectors."""
    v0 = jax.random.normal(jax.random.PRNGKey(seed), (n,), dtype)
    alphas, betas, V = lanczos(matvec, v0, num_steps)
    T = (jnp.diag(alphas) + jnp.diag(betas[:-1], 1) + jnp.diag(betas[:-1], -1))
    w, U = jnp.linalg.eigh(T)
    ritz = (V[:num_steps].T @ U[:, :k]).T      # (k, n)
    ritz = ritz / jnp.linalg.norm(ritz, axis=1, keepdims=True)
    return w[:k], ritz
