"""SparseNewton — nonlinear solves through the plan engine (paper §3.2.2).

The Jacobian sparsity of a mesh-based residual is FIXED: Newton changes the
values, never the pattern.  SparseNewton exploits that exactly the way the
linear plan engine does — analyze once, refresh values every step:

* **coloring** (analyze-time, eager): a Curtis–Powell–Reid distance-1
  coloring of the declared pattern's column-intersection graph
  (:func:`repro.core.sparse.color_pattern`) compresses the Jacobian to
  ``n_colors`` probe directions, counted once in
  ``PLAN_STATS["jac_color"]``.  Each Newton step then recovers the exact
  nnz values with ONE vmapped ``jax.jvp`` sweep
  (``PLAN_STATS["jac_assemble"]``) — or a user ``assemble_jacobian``
  callback when the residual has a cheaper closed-form Jacobian.
* **one plan serves every step**: the inner solve dispatches through the
  same cached :class:`~repro.core.dispatch.SolverPlan` — sparse-direct
  (supernodal) factorization, ``precond="amg"``, block-Jacobi, any
  registered backend — so ``PLAN_STATS["analyze"] == 1`` across a whole
  Newton sweep.  Per-step numeric refreshes go through the plan's setup
  memo: a fresh values array per step means ``factorize == n_steps`` for
  the direct backend (``galerkin == n_steps`` for AMG), never more.
* **IFT backward on the converged step's factors**:
  :meth:`SparseNewton.solve_adjoint` runs Jᵀλ = g through
  ``plan.transpose()`` on the SAME values array the last forward step set
  up — the shared setup memo turns the backward's factorization into a
  reuse (``transpose_shared == 1``, zero extra ``factorize``/``galerkin``,
  O(1) autodiff graph nodes, paper Eq. 2).

The differentiable entry point is
:func:`repro.core.adjoint.nonlinear_solve` with ``jac_pattern=`` /
``linear_solver=``; this module is the engine underneath.
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import dispatch as _dispatch
from . import options as _options
from .dispatch import PLAN_STATS, SolverConfig
from .solvers import SolveInfo
from .sparse import SparseTensor, color_pattern, detect_properties

__all__ = ["SparseNewton"]


def _is_staging() -> bool:
    # same ambient-trace probe as SolverPlan._memo_store: does an op on a
    # fresh constant come back traced?  (eager jax.grad says no)
    return isinstance(jnp.zeros(()) + 0.0, jax.core.Tracer)


class SparseNewton:
    """Newton's method with a mesh-fixed sparse Jacobian through the plan
    engine — analyze once, one symbolic factorization (or AMG hierarchy)
    for every step, per-step values through the setup memo.

    Parameters
    ----------
    residual
        ``residual(u, *theta) -> F`` with ``F.shape == u.shape == (n,)``.
    pattern
        The Jacobian sparsity, fixed across steps: a
        :class:`~repro.core.sparse.SparseTensor` (its pattern, props, and —
        crucially — its plan cache are reused, so a mesh tensor you already
        solve with shares its analyzed plans), or a ``(row, col)`` /
        ``(row, col, n)`` tuple of concrete index arrays.  Entries of the
        true Jacobian outside the declared pattern are silently dropped —
        declare a superset when unsure.
    linear_solver
        Inner-solve :class:`~repro.core.dispatch.SolverConfig`
        (``backend="direct"``, ``precond="amg"``, tolerances, ...).
        ``None`` → auto-dispatch on the first assembled values.
    assemble_jacobian
        Optional ``assemble_jacobian(u, *theta) -> values`` returning the
        nnz values on the declared pattern, replacing the coloring-based
        jvp sweep (use it when a closed form is cheaper, or when the
        pattern needs more colors than ``options.jac_coloring_budget``).
    symmetric
        Override the symmetry detection — controls whether the adjoint
        shares the forward plan outright.  Default: inherited from a
        tensor ``pattern``, else detected from the first concretely
        assembled values (``False`` when assembly only ever runs traced —
        the safe choice, at the cost of a transposed sibling plan).
    """

    def __init__(self, residual: Callable, pattern, *,
                 linear_solver: Optional[SolverConfig] = None,
                 assemble_jacobian: Optional[Callable] = None,
                 symmetric: Optional[bool] = None):
        self.residual = residual
        self.assemble_jacobian = assemble_jacobian
        self._symmetric = symmetric
        self._cfg0 = linear_solver
        self._cfg: Optional[SolverConfig] = None
        self._plan = None

        if isinstance(pattern, SparseTensor):
            n, m = pattern.shape
            if n != m:
                raise ValueError(f"Jacobian pattern must be square, "
                                 f"got {pattern.shape}")
            self.row, self.col, self.n = pattern.row, pattern.col, n
            self._template = pattern
            if symmetric is not None and symmetric != bool(
                    pattern.props.get("symmetric", False)):
                # different props change plan selection/sharing: give the
                # override its own template so the tensor's cached plans
                # (keyed on config only, not props) are not reused unsoundly
                t = SparseTensor(pattern.val, pattern.row, pattern.col,
                                 pattern.shape,
                                 props=dict(pattern.props), validate=False)
                t.props["symmetric"] = symmetric
                if not symmetric:
                    t.props["spd_hint"] = False
                self._template = t
        else:
            if len(pattern) == 2:
                row, col = pattern
                n = int(max(np.asarray(row).max(), np.asarray(col).max())) + 1
            else:
                row, col, n = pattern
            self.row = jnp.asarray(row, jnp.int32)
            self.col = jnp.asarray(col, jnp.int32)
            self.n = int(n)
            self._template = None

        if assemble_jacobian is None:
            color, n_colors = color_pattern(self.row, self.col, self.n)
            budget = _options.current().jac_coloring_budget
            if n_colors > budget:
                raise ValueError(
                    f"Jacobian pattern needs {n_colors} colors (jvp probes "
                    f"per assembly) > jac_coloring_budget ({budget}); pass "
                    f"assemble_jacobian= or raise the option "
                    f"(sla.set_options(jac_coloring_budget=...))")
            PLAN_STATS["jac_color"] += 1
            self.n_colors = n_colors
            probes = np.zeros((n_colors, self.n))
            probes[color, np.arange(self.n)] = 1.0
            self._probes = jnp.asarray(probes)
            # entry e of the pattern reads probe-sweep slot
            # (color[col[e]], row[e]):  J[r,c] == (J @ p_color[c])[r]
            self._slot = jnp.asarray(color[np.asarray(self.col)], jnp.int32)
        else:
            self.n_colors = 0

    # -- Jacobian values on the pattern --------------------------------------
    def assemble(self, u, *theta):
        """Numeric Jacobian values on the declared pattern at ``u`` — one
        vmapped jvp sweep over the color probes (or the user callback)."""
        PLAN_STATS["jac_assemble"] += 1
        if self.assemble_jacobian is not None:
            return self.assemble_jacobian(u, *theta)
        F = lambda x: self.residual(x, *theta)
        P = self._probes.astype(u.dtype)
        Jp = jax.vmap(lambda p: jax.jvp(F, (u,), (p,))[1])(P)  # (colors, n)
        return Jp[self._slot, self.row]

    # -- plan resolution (once) ----------------------------------------------
    def _ensure_plan(self, vals=None):
        if self._plan is not None:
            return self._plan
        tmpl = self._template
        if tmpl is None:
            concrete = vals is not None and \
                not isinstance(vals, jax.core.Tracer)
            if concrete:
                props = detect_properties(vals, self.row, self.col,
                                          (self.n, self.n))
            else:
                # never-concrete assembly: symmetry unknowable — default to
                # the safe transposed-sibling adjoint unless overridden
                props = detect_properties(jnp.ones(self.row.shape[0]),
                                          self.row, self.col,
                                          (self.n, self.n),
                                          check_values=False)
                props["symmetric"] = False
                props["spd_hint"] = False
            if self._symmetric is not None:
                props["symmetric"] = self._symmetric
                if not self._symmetric:
                    props["spd_hint"] = False
            vv = vals if concrete else jnp.ones(self.row.shape[0])
            tmpl = SparseTensor(vv, self.row, self.col, (self.n, self.n),
                                props=props, validate=False)
            self._template = tmpl
        cfg = self._cfg0 if self._cfg0 is not None else SolverConfig()
        if cfg.backend in (None, "auto") or cfg.method in (None, "auto"):
            cfg = cfg.resolved(tmpl)
        self._cfg = cfg
        self._plan = _dispatch.get_plan(tmpl, cfg)
        return self._plan

    @property
    def plan(self):
        """The analyzed :class:`~repro.core.dispatch.SolverPlan` (None until
        the first solve resolves auto-dispatch against real values)."""
        return self._plan

    # -- Newton driver -------------------------------------------------------
    def solve(self, u0, *theta, tol: float = 1e-8, maxiter: int = 50,
              damping: float = 1.0):
        """Newton sweep: assemble values → plan.solve(J, −F) → update.

        Eager inputs run a Python loop (each step's fresh values array is a
        setup-memo miss, so ``factorize``/``galerkin`` count the steps);
        traced inputs fall back to a ``lax.while_loop``.  Returns
        ``(u, SolveInfo)``.  For gradients w.r.t. ``theta`` use
        :func:`repro.core.adjoint.nonlinear_solve` — this entry point is
        un-differentiated, like ``plan.solve``.
        """
        u, info, _ = self._solve_full(u0, *theta, tol=tol, maxiter=maxiter,
                                      damping=damping)
        return u, info

    def _solve_full(self, u0, *theta, tol, maxiter, damping):
        """(u, info, vals_last) — vals_last is the values array whose setup
        the plan memoized, handed to :meth:`solve_adjoint` by the IFT
        backward so the adjoint refactorizes nothing."""
        u0 = jnp.asarray(u0)
        leaves = jax.tree_util.tree_leaves((u0,) + theta)
        traced = _is_staging() or any(
            isinstance(l, jax.core.Tracer) for l in leaves)
        if traced:
            return self._solve_traced(u0, theta, tol, maxiter, damping)
        return self._solve_eager(u0, theta, tol, maxiter, damping)

    def _solve_eager(self, u0, theta, tol, maxiter, damping):
        u = u0
        Fu = self.residual(u, *theta)
        rn = float(jnp.linalg.norm(Fu))
        vals = None
        k = 0
        while k < maxiter and rn > tol:
            vals = self.assemble(u, *theta)
            plan = self._ensure_plan(vals)
            dx, _ = plan.solve(plan.matrix(vals), -Fu, cfg=self._cfg)
            u = u + damping * dx
            Fu = self.residual(u, *theta)
            rn = float(jnp.linalg.norm(Fu))
            k += 1
        if vals is None:
            # converged at u0: assemble (and set up) once so the adjoint
            # still has factors to reuse
            vals = self.assemble(u, *theta)
            self._ensure_plan(vals)
        info = SolveInfo(jnp.asarray(k), jnp.asarray(rn, u.dtype),
                         jnp.asarray(rn <= tol))
        return u, info, vals

    def _solve_traced(self, u0, theta, tol, maxiter, damping):
        vals0 = self.assemble(u0, *theta)
        plan = self._ensure_plan(vals0)
        Fu0 = self.residual(u0, *theta)

        def cond(st):
            u, vals, Fu, rn, k = st
            return (k < maxiter) & (rn > tol)

        def body(st):
            u, _, Fu, _, k = st
            vals = self.assemble(u, *theta)
            dx, _ = plan.solve(plan.matrix(vals), -Fu, cfg=self._cfg)
            u = u + damping * dx
            Fu = self.residual(u, *theta)
            return (u, vals, Fu, jnp.linalg.norm(Fu), k + 1)

        st0 = (u0, vals0, Fu0, jnp.linalg.norm(Fu0), jnp.asarray(0))
        u, vals, Fu, rn, k = jax.lax.while_loop(cond, body, st0)
        return u, SolveInfo(k, rn, rn <= tol), vals

    # -- IFT adjoint ---------------------------------------------------------
    def solve_adjoint(self, vals, g):
        """λ from Jᵀλ = g on the transpose view of the step plan.

        Pass the IDENTICAL values array the last forward step set up (the
        custom_vjp residual does) and the shared setup memo serves the
        backward: symmetric patterns reuse the plan outright, the direct
        backend runs mirrored Uᵀ/Lᵀ sweeps on the forward factors — zero
        refactorizations either way.  Exact once F(u*, θ) ≈ 0 and J is
        evaluated at the converged root; with a tight forward ``tol`` the
        last-step J is within that tolerance of J(u*).
        """
        plan = self._ensure_plan(vals)
        tplan = plan.transpose()
        lam, info = tplan.solve(tplan.matrix(vals), g, None,
                                cfg=tplan.adapt(self._cfg))
        return lam, info
