"""recurrentgemma-2b — RG-LRU + local attention, 1:2 [arXiv:2402.19427; hf]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b", family="hybrid",
    n_layers=26, d_model=2560, n_heads=10, n_kv_heads=1, d_ff=7680,
    vocab=256_000, head_dim=256,
    layer_pattern=("rec", "rec", "attn_local"), window=2048,
    lru_width=2560, conv_width=4, act="gelu",
    rope_theta=10_000.0, tie_embeddings=True,
)
