"""The paper's own workload: 2D Poisson solve configs (Tables 3–4, Figs 2–3)."""
import dataclasses


@dataclasses.dataclass(frozen=True)
class PoissonConfig:
    ng: int                  # grid points per side (DOF = ng²)
    dtype: str = "float64"
    precond: str = "jacobi"
    tol: float = 1e-6
    maxiter: int = 20_000


SIZES = {                    # paper Table 3 ladder (DOF)
    "10K": PoissonConfig(ng=100),
    "100K": PoissonConfig(ng=316),
    "1M": PoissonConfig(ng=1000),
    "2M": PoissonConfig(ng=1414),
    "16M": PoissonConfig(ng=4000),
}
