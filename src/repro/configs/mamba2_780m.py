"""mamba2-780m — SSD (state-space duality) [arXiv:2405.21060; unverified]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-780m", family="ssm",
    n_layers=48, d_model=1536, n_heads=24, n_kv_heads=24, d_ff=0,
    vocab=50_280, head_dim=64,
    layer_pattern=("ssd",), ssm_state=128, ssm_head_dim=64, ssm_chunk=256,
    conv_width=4, tie_embeddings=True,
)
