"""qwen3-8b — qk_norm, GQA [hf:Qwen/Qwen3-8B; hf]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-8b", family="dense",
    n_layers=36, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=12288,
    vocab=151_936, head_dim=128, qk_norm=True,
    rope_theta=1_000_000.0, act="silu",
)
