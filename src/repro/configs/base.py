"""Config schema for the LM substrate.

One :class:`ModelConfig` per assigned architecture (see sibling modules);
:class:`ShapeConfig` encodes the four assigned input-shape cells.  Configs are
frozen dataclasses — hashable, usable as jit static args.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                       # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None
    # attention flavour
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    mrope: bool = False               # qwen2-vl M-RoPE (3-section rotary)
    window: int = 2048                # local-attention window
    # layer pattern, cycled to n_layers (e.g. recurrentgemma: rec,rec,attn_local)
    layer_pattern: Tuple[str, ...] = ("attn",)
    # MoE
    n_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0
    capacity_factor: float = 1.25
    # SSM (mamba2 / SSD)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_chunk: int = 256
    conv_width: int = 4
    # RG-LRU
    lru_width: Optional[int] = None
    # encoder-decoder (whisper)
    enc_dec: bool = False
    n_enc_layers: int = 0
    enc_frames: int = 1500
    # VLM stub frontend
    vis_patches: int = 0              # prefix patch embeddings (precomputed)
    # numerics / training
    act: str = "silu"
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    dtype: str = "bfloat16"           # activation dtype
    param_dtype: str = "float32"
    remat: str = "full"               # none | full | dots
    # beyond-paper perf knobs (see EXPERIMENTS.md §Perf)
    seq_shard_long: bool = True       # sequence-parallel halo attention for long ctx
    seq_shards_mixer: int = 1         # SSD sequence-domain decomposition (§3.3 pattern)

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def pattern_layers(self) -> Tuple[str, ...]:
        """The pattern cycled out to exactly n_layers entries."""
        p = self.layer_pattern
        return tuple(p[i % len(p)] for i in range(self.n_layers))

    def param_count(self) -> int:
        """Analytic parameter count (embedding included once if tied)."""
        d, hd = self.d_model, self.hd
        n = 0
        for kind in self.pattern_layers:
            if kind in ("attn", "attn_local", "attn_bidir"):
                qkv = d * (self.n_heads * hd) + 2 * d * (self.n_kv_heads * hd)
                if self.qkv_bias:
                    qkv += (self.n_heads + 2 * self.n_kv_heads) * hd
                n += qkv + (self.n_heads * hd) * d          # o_proj
                n += self._mlp_params()
                n += 2 * d                                   # norms
            elif kind == "moe":
                qkv = d * (self.n_heads * hd) + 2 * d * (self.n_kv_heads * hd)
                n += qkv + (self.n_heads * hd) * d
                n += d * self.n_experts                      # router
                n += self.n_experts * 3 * d * self.d_ff_expert
                n += 2 * d
            elif kind == "rec":
                w = self.lru_width or d
                n += 2 * d * w + 2 * w * d                   # in/out projections
                n += self.conv_width * w + 3 * w             # conv + gates(diag-ish)
                n += 2 * w * w // 4                          # gate projections (block)
                n += self._mlp_params() + 2 * d
            elif kind == "ssd":
                d_in = 2 * d
                nheads = d_in // self.ssm_head_dim
                n += d * (2 * d_in + 2 * self.ssm_state + nheads)  # in_proj
                n += self.conv_width * (d_in + 2 * self.ssm_state)
                n += nheads * 2                                # A, D
                n += d_in * d + d                              # out_proj + norm
            n += 0
        n += self.vocab * d                                   # embed
        if not self.tie_embeddings:
            n += self.vocab * d                               # unembed
        if self.enc_dec:
            # encoder stack (attn_bidir + mlp) + cross-attn in decoder
            qkv = self.d_model * (self.n_heads * self.hd) * 4
            n += self.n_enc_layers * (qkv + self._mlp_params() + 2 * d)
            n += self.n_layers * (qkv + 2 * d)                # cross attn
        return n

    def _mlp_params(self) -> int:
        if self.d_ff == 0:
            return 0
        gates = 3 if self.act in ("silu", "swiglu", "geglu") else 2
        return gates * self.d_model * self.d_ff


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                          # train | prefill | decode


SHAPES = {
    "train_4k":    ShapeConfig("train_4k",    4_096,   256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768,  32,  "prefill"),
    "decode_32k":  ShapeConfig("decode_32k",  32_768,  128, "decode"),
    "long_500k":   ShapeConfig("long_500k",   524_288, 1,   "decode"),
}


def smoke_variant(cfg: ModelConfig) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests."""
    return dataclasses.replace(
        cfg,
        n_layers=max(len(cfg.layer_pattern), 2 if not cfg.enc_dec else 2),
        d_model=64,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) or 1,
        d_ff=128 if cfg.d_ff else 0,
        d_ff_expert=32 if cfg.d_ff_expert else 0,
        n_experts=min(cfg.n_experts, 4),
        top_k=min(cfg.top_k, 2),
        capacity_factor=8.0,      # no capacity drops → decode ≡ forward

        vocab=512,
        head_dim=16,
        ssm_state=min(cfg.ssm_state, 16) if cfg.ssm_state else 0,
        ssm_head_dim=16 if cfg.ssm_state else cfg.ssm_head_dim,
        ssm_chunk=8,
        lru_width=64 if cfg.lru_width else None,
        window=16,
        n_enc_layers=2 if cfg.enc_dec else 0,
        enc_frames=24 if cfg.enc_dec else cfg.enc_frames,
        vis_patches=8 if cfg.vis_patches else 0,
        dtype="float32",
        param_dtype="float32",
        remat="none",
    )
