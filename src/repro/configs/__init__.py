"""Architecture registry: ``--arch <id>`` → ModelConfig."""
from .base import ModelConfig, ShapeConfig, SHAPES, smoke_variant
from . import (recurrentgemma_2b, llama3_2_1b, qwen2_1_5b, qwen3_8b,
               qwen1_5_110b, granite_moe_1b_a400m, dbrx_132b, whisper_medium,
               mamba2_780m, qwen2_vl_72b)

REGISTRY = {m.CONFIG.name: m.CONFIG for m in (
    recurrentgemma_2b, llama3_2_1b, qwen2_1_5b, qwen3_8b, qwen1_5_110b,
    granite_moe_1b_a400m, dbrx_132b, whisper_medium, mamba2_780m,
    qwen2_vl_72b)}

ARCH_IDS = tuple(REGISTRY)


def get_config(name: str) -> ModelConfig:
    if name not in REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(REGISTRY)}")
    return REGISTRY[name]


def is_subquadratic(cfg: ModelConfig) -> bool:
    """Archs that can run long_500k (DESIGN.md §Arch-applicability)."""
    kinds = set(cfg.pattern_layers)
    return "attn" not in kinds and "moe" not in kinds and not cfg.enc_dec


__all__ = ["ModelConfig", "ShapeConfig", "SHAPES", "REGISTRY", "ARCH_IDS",
           "get_config", "smoke_variant", "is_subquadratic"]
