"""qwen2-vl-72b — M-RoPE, dynamic resolution (stub frontend)
[arXiv:2409.12191; hf]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-72b", family="vlm",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8, d_ff=29568,
    vocab=152_064, head_dim=128, qkv_bias=True, mrope=True,
    vis_patches=256, rope_theta=1_000_000.0, act="silu",
)
