"""repro.sla — the stable public surface of the sparse linear algebra engine.

This is the supported way in: a curated namespace over the plan-cached solver
engine (:mod:`repro.core`) and the request-batching serving driver
(:mod:`repro.launch.solve_serve`).  Internal modules remain importable but
undocumented and unstable; everything listed in ``__all__`` here is covered
by the API-surface snapshot test and the generated reference
(``docs/api.md``, built by ``tools/gen_api_ref.py``).

Quick start::

    import jax.numpy as jnp
    from repro import sla

    A = sla.SparseTensor(val, row, col, (n, n))   # COO, differentiable vals
    x = sla.solve(A, b)                           # auto-dispatch + adjoint
    res = sla.solve_with_info(A, b, tol=1e-10)    # typed SolveResult
    print(res.iterations, res.residual, res.reason)

Options (the former ``repro.core.dispatch`` module globals)::

    sla.set_options(fused_step="on")              # process-wide
    with sla.options(direct_budget=10**5):        # scoped, exception-safe
        x = sla.solve(A, b)
    sla.get_options().plan_cache_bytes            # the active record

Every option also has a ``REPRO_SLA_*`` environment override read at import
(e.g. ``REPRO_SLA_FUSED_STEP=off``, ``REPRO_SLA_PLAN_CACHE_BYTES=1e8``).

Serving::

    from repro.sla import SolveServer
    server = SolveServer()
    results = server.submit_batch(requests)       # grouped + vmapped dispatch

The engine's contract, in one line: ``analyze`` (pattern → plan) is eager
and cached, ``setup`` (values → state) is traced-safe and memoized per
values array, ``solve`` (rhs → x) is where gradients attach — see
CONTRIBUTING.md for why that split is load-bearing.
"""
from __future__ import annotations

from .core.dispatch import (PLAN_STATS, SolverConfig, SolverPlan, get_plan,
                            make_config, register_backend, reset_plan_stats)
from .core.options import Options
from .core.options import current as get_options
from .core.options import options, set_options
from .core.solvers import SolveInfo, SolveResult, as_solve_result
from .core.sparse import SparseTensor

__all__ = [
    "SparseTensor",
    "DSparseTensor",
    "SparseNewton",
    "nonlinear_solve",
    "eigsh",
    "SolverConfig",
    "SolverPlan",
    "SolveResult",
    "Options",
    "solve",
    "solve_with_info",
    "get_plan",
    "register_backend",
    "set_options",
    "options",
    "get_options",
    "serve",
    "SolveServer",
    "PLAN_STATS",
    "reset_plan_stats",
]

# lazily bound: the distributed layer pulls in mesh/shard_map machinery and
# the serving driver pulls in the launch package — single-device library use
# should not pay either import
_LAZY = {
    "DSparseTensor": ("repro.core.distributed", "DSparseTensor"),
    "serve": ("repro.launch.solve_serve", "serve"),
    "SolveServer": ("repro.launch.solve_serve", "SolveServer"),
    # nonlinear/eigen layer: pulls in the adjoint + coloring machinery
    "SparseNewton": ("repro.core.nonlinear", "SparseNewton"),
    "nonlinear_solve": ("repro.core.adjoint", "nonlinear_solve"),
    "eigsh": ("repro.core.adjoint", "sparse_eigsh"),
}


def __getattr__(name: str):
    target = _LAZY.get(name)
    if target is not None:
        from importlib import import_module
        return getattr(import_module(target[0]), target[1])
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(set(globals()) | set(__all__))


def solve(A, b, **kw):
    """Solve ``A @ x = b`` with adjoint gradients (paper §3.2).

    ``A`` is a :class:`SparseTensor` (or :class:`DSparseTensor`); ``b`` may
    carry leading batch dimensions, and ``A`` may carry stacked values
    sharing one pattern — both batch through ONE analyzed plan and one
    vmapped setup.  Keyword options: ``backend`` ("auto", "dense", "direct",
    "jnp", "pallas", "stencil"), ``method`` (backend-specific; "block_cg"
    solves a multi-rhs batch as one coupled block), ``precond``, ``tol``,
    ``atol``, ``maxiter``, ``x0``.  Returns ``x`` only; gradients flow
    through the O(1)-graph adjoint solve.  Use :func:`solve_with_info` for
    convergence diagnostics."""
    return A.solve(b, **kw)


def solve_with_info(A, b, *, x0=None, **kw) -> SolveResult:
    """Like :func:`solve`, returning a typed :class:`SolveResult`.

    Works uniformly across the iterative, direct, and distributed backends:
    ``x`` (solution), ``iterations``, ``residual`` (final ‖r‖₂, per-rhs for
    batches), ``converged``, and a static ``reason`` string ("converged",
    "maxiter", or "unknown" under a trace).  This entry point is
    un-differentiated — it is the serving/diagnostics path; use
    :func:`solve` when gradients matter."""
    if getattr(A, "mesh", None) is not None:      # distributed tensor
        x, info = A.solve_with_info(b, x0=x0, **kw)
    else:
        from .core.dispatch import make_config, solve_impl
        cfg = make_config(A, **kw)
        x, info = solve_impl(cfg, A, b, x0)
    return as_solve_result(x, info)
