"""repro — JAX/TPU reproduction of torch-sla (differentiable sparse linear
algebra with adjoint solvers and sparse tensor parallelism), embedded in a
multi-pod LM training/serving framework."""

__version__ = "1.0.0"
