"""repro — JAX/TPU reproduction of torch-sla (differentiable sparse linear
algebra with adjoint solvers and sparse tensor parallelism), embedded in a
multi-pod LM training/serving framework.

The supported public surface is :mod:`repro.sla`::

    from repro import sla
    x = sla.solve(A, b)

Everything else (``repro.core``, ``repro.kernels``, ``repro.launch``) is
internal and may change between releases.
"""

__version__ = "1.0.0"

__all__ = ["sla"]


def __getattr__(name):
    """Lazy re-export (PEP 562): ``import repro`` stays free of jax import
    cost until the public API is actually touched."""
    if name == "sla":
        from importlib import import_module
        return import_module("repro.sla")
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
