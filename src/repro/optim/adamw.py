"""AdamW with decoupled weight decay, global-norm clipping, schedules.

Pure-JAX (no optax dependency); optimizer state is a pytree parallel to
params so the launcher shards it with the same rules (ZeRO-style: m/v inherit
the parameter sharding → fully sharded optimizer state).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    schedule: str = "cosine"        # cosine | linear | constant


def init_opt_state(params) -> dict:
    zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def schedule_lr(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    s = step.astype(jnp.float32)
    warm = jnp.minimum(s / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    if cfg.schedule == "constant":
        decay = 1.0
    else:
        frac = jnp.clip((s - cfg.warmup_steps) /
                        jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                        0.0, 1.0)
        if cfg.schedule == "cosine":
            decay = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (
                1 + jnp.cos(jnp.pi * frac))
        else:
            decay = 1.0 - (1.0 - cfg.min_lr_frac) * frac
    return cfg.lr * warm * decay


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(cfg: AdamWConfig, params, grads, state):
    """One AdamW step.  Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9)) \
        if cfg.grad_clip > 0 else 1.0
    lr = schedule_lr(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mh = m / bc1
        vh = v / bc2
        step_ = mh / (jnp.sqrt(vh) + cfg.eps)
        if p.ndim >= 2:                        # decoupled decay on matrices
            step_ = step_ + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step_).astype(p.dtype), m, v

    out = jax.tree.map(upd, params, grads, state["m"], state["v"])
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, {"m": new_m, "v": new_v, "step": step}, metrics
