"""Gradient / halo compression with error feedback (distributed-optimization
trick; beyond-paper for the solver, standard for LM training at scale).

``quantize_int8`` is a per-tensor max-abs int8 quantizer; ``ErrorFeedback``
accumulates the quantization residual so the compressed reduction is unbiased
over steps (Karimireddy et al. 2019).  ``compressed_psum`` is meant for
shard_map contexts (the halo-exchange layer, the distributed CG inner loop):
int8 payloads cut collective bytes 4× vs f32 — measured in §Perf.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax


def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-30
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compressed_psum(x: jax.Array, axis: str) -> jax.Array:
    """Quantize → int32 psum (int8 payload semantics; the wire format on a
    real interconnect is the int8 tensor + one scalar) → dequantize.
    The shared scale is the psum-max of local scales (one extra scalar
    reduction, amortized)."""
    local_scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-30
    scale = lax.pmax(local_scale, axis)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int32)
    s = lax.psum(q, axis)
    return s.astype(jnp.float32) * scale


def ef_compress(x: jax.Array, err: jax.Array) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Error-feedback compression: returns (q, scale, new_err)."""
    corrected = x + err
    q, scale = quantize_int8(corrected)
    new_err = corrected - dequantize_int8(q, scale)
    return q, scale, new_err


def compressed_halo_exchange(x: jax.Array, h_lo: int, h_hi: int, axis: str):
    """Quantized halo exchange (forward-only utility): int8 boundary payloads
    + one scalar scale per neighbour message — 4× fewer halo bytes per CG
    iteration.  Each halo zone is dequantized with the *sender's* scale
    (exchanged alongside).  Accuracy impact is benchmarked, not assumed
    (EXPERIMENTS.md §Perf)."""
    p = lax.psum(1, axis)     # static fold; lax.axis_size absent on old jax
    idx = lax.axis_index(axis)
    q, scale = quantize_int8(x)
    qi = q.astype(jnp.int32)
    parts = []
    if h_lo > 0:
        perm = [(i, (i + 1) % p) for i in range(p)]
        lo_q = lax.ppermute(qi[..., -h_lo:], axis, perm=perm)
        lo_s = lax.ppermute(scale, axis, perm=perm)
        lo = lo_q.astype(jnp.float32) * lo_s
        parts.append(jnp.where(idx == 0, jnp.zeros_like(lo), lo))
    parts.append(q.astype(jnp.float32) * scale)   # own values round-tripped
    if h_hi > 0:
        perm = [(i, (i - 1) % p) for i in range(p)]
        hi_q = lax.ppermute(qi[..., :h_hi], axis, perm=perm)
        hi_s = lax.ppermute(scale, axis, perm=perm)
        hi = hi_q.astype(jnp.float32) * hi_s
        parts.append(jnp.where(idx == p - 1, jnp.zeros_like(hi), hi))
    out = jnp.concatenate(parts, axis=-1)
    # own (non-halo) segment stays exact: splice the uncompressed values back
    return lax.dynamic_update_slice(out, x, (h_lo,))
