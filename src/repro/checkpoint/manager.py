"""Checkpointing: atomic, keep-k, elastic reshard-on-load, async save.

Layout per step:  <dir>/step_<N>/arrays.npz + manifest.json
Writes land in ``<dir>/.tmp_<N>`` and are renamed atomically, so a crash
mid-save never corrupts the latest checkpoint — the restart driver (ft/)
always finds a consistent step.  Restore takes target shardings, so a run
can resume on a different mesh (elastic scaling): arrays are loaded on host
and ``device_put`` against the new layout.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Optional

import jax
import numpy as np

SEP = "/"


def _flatten(tree) -> dict:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out[key] = np.asarray(jax.device_get(leaf))
    return out


def _unflatten_into(template, flat: dict):
    paths = jax.tree_util.tree_flatten_with_path(template)[0]
    treedef = jax.tree_util.tree_structure(template)
    leaves = []
    for path, leaf in paths:
        key = SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        if key not in flat:
            raise KeyError(f"checkpoint missing {key}")
        arr = flat[key]
        want = getattr(leaf, "shape", None)
        if want is not None and tuple(arr.shape) != tuple(want):
            raise ValueError(f"{key}: checkpoint shape {arr.shape} != {want}")
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_save: bool = False):
        self.dir = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # -- write ---------------------------------------------------------------
    def save(self, step: int, tree, extra: Optional[dict] = None):
        flat = _flatten(tree)          # host copy happens sync (consistency)
        if self.async_save:
            self.wait()
            self._thread = threading.Thread(
                target=self._write, args=(step, flat, extra or {}), daemon=True)
            self._thread.start()
        else:
            self._write(step, flat, extra or {})

    def _write(self, step: int, flat: dict, extra: dict):
        tmp = os.path.join(self.dir, f".tmp_{step}")
        final = os.path.join(self.dir, f"step_{step}")
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        np.savez(os.path.join(tmp, "arrays.npz"), **flat)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump({"step": step, "time": time.time(),
                       "n_arrays": len(flat), **extra}, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._gc()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = sorted(self.all_steps())
        for s in steps[: max(0, len(steps) - self.keep)]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"),
                          ignore_errors=True)

    # -- read ----------------------------------------------------------------
    def all_steps(self):
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_"):
                if os.path.exists(os.path.join(self.dir, name, "manifest.json")):
                    out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, template, shardings=None):
        """Load step into the structure of ``template``; ``shardings`` (same
        pytree of NamedSharding, or None) relocates onto any mesh — elastic."""
        path = os.path.join(self.dir, f"step_{step}", "arrays.npz")
        with np.load(path) as z:
            flat = {k: z[k] for k in z.files}
        tree = _unflatten_into(template, flat)
        if shardings is not None:
            tree = jax.tree.map(
                lambda a, s: jax.device_put(a, s), tree, shardings)
        else:
            tree = jax.tree.map(
                lambda a, t: jax.numpy.asarray(
                    a, dtype=getattr(t, "dtype", None)), tree, template)
        return tree

    def manifest(self, step: int) -> dict:
        with open(os.path.join(self.dir, f"step_{step}", "manifest.json")) as f:
            return json.load(f)
