"""Direct sparse solver backend (the cuDSS analogue — paper §3.1/§3.2.3).

Covers: LDLᵀ/LU accuracy vs the dense backend on Poisson-2D and a
non-symmetric convection pattern; gradcheck vs dense autodiff; the plan
engine's reuse contract (ONE symbolic analysis + ONE numeric factorization
across a tolerance sweep including the backward pass); the transposed-sweep
adjoint for LU; batched values / multi-rhs; the kernel-level orderings; the
auto-dispatch preference; and the ILU(0) preconditioner built on the same
symbolic machinery.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (SparseTensor, PLAN_STATS, get_plan, make_config,
                        reset_plan_stats)
from repro.core import dispatch
from repro.core.direct import symbolic_factor, numeric_factor, factored_solve
from repro.data.poisson import poisson1d, poisson2d


@pytest.fixture()
def A():
    return poisson2d(12)    # 144 dof, SPD


def _convection(n, c=0.4):
    """1D convection-diffusion: symmetric pattern, non-symmetric values."""
    A1 = poisson1d(n)
    val = np.asarray(A1.val).copy()
    val[np.asarray(A1.col) == np.asarray(A1.row) - 1] = -1.0 - c
    val[np.asarray(A1.col) == np.asarray(A1.row) + 1] = -1.0 + c
    return SparseTensor(val, A1.row, A1.col, (n, n))


# ---------------------------------------------------------------------------
# accuracy vs the dense backend (acceptance: 1e-8 at f64)
# ---------------------------------------------------------------------------

def test_direct_matches_dense_poisson2d(A):
    b = jnp.asarray(np.random.default_rng(0).normal(size=A.shape[0]))
    x = A.solve(b, backend="direct")
    xd = A.solve(b, backend="dense", method="cholesky")
    np.testing.assert_allclose(np.asarray(x), np.asarray(xd),
                               rtol=1e-10, atol=1e-8)
    assert float(jnp.linalg.norm(A @ x - b)) < 1e-10


def test_direct_matches_dense_nonsymmetric_convection():
    B = _convection(64, c=0.4)
    assert not B.props["symmetric"]
    b = jnp.asarray(np.random.default_rng(1).normal(size=64))
    x = B.solve(b, backend="direct")        # default method resolves to lu
    xd = B.solve(b, backend="dense", method="lu")
    np.testing.assert_allclose(np.asarray(x), np.asarray(xd),
                               rtol=1e-10, atol=1e-8)
    cfg = make_config(B, backend="direct")
    assert cfg.method == "lu"


def test_direct_requires_structural_diagonal():
    # off-diagonal-only pattern: no pivots without pivoting → clear error
    A = SparseTensor(np.array([1.0, 1.0]), np.array([0, 1]),
                     np.array([1, 0]), (2, 2))
    with pytest.raises(ValueError, match="diagonal"):
        A.solve(jnp.ones(2), backend="direct")


def test_ldlt_rejects_nonsymmetric_values():
    B = _convection(16)
    with pytest.raises(ValueError, match="ldlt"):
        B.solve(jnp.ones(16), backend="direct", method="ldlt")


# ---------------------------------------------------------------------------
# kernel level: orderings and the transposed sweeps on shared factors
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("ordering", ["amd", "rcm", "natural"])
def test_symbolic_orderings_all_solve_exactly(ordering):
    A = poisson2d(8)
    b = jnp.asarray(np.random.default_rng(2).normal(size=A.shape[0]))
    art = symbolic_factor(np.asarray(A.row), np.asarray(A.col), A.shape[0],
                          ordering=ordering)
    x = factored_solve(art, numeric_factor(art, A.val), b)
    np.testing.assert_allclose(np.asarray(A @ x), np.asarray(b), atol=1e-10)


def test_transposed_sweeps_solve_At_on_forward_factors():
    B = _convection(48, c=0.3)
    b = jnp.asarray(np.random.default_rng(3).normal(size=48))
    art = symbolic_factor(np.asarray(B.row), np.asarray(B.col), 48)
    C = numeric_factor(art, B.val)          # ONE factorization of B
    xt = factored_solve(art, C, b, transposed=True)
    xtd = jnp.linalg.solve(B.todense().T, b)
    np.testing.assert_allclose(np.asarray(xt), np.asarray(xtd),
                               rtol=1e-10, atol=1e-10)


def test_transpose_plan_shares_factors_nonsymmetric():
    B = _convection(40)
    b = jnp.ones(40)
    plan = B.plan(backend="direct")
    tp = plan.transpose()
    assert tp is not plan
    assert tp.artifacts["direct"] is plan.artifacts["direct"]   # shared symbolic
    assert tp.transpose() is plan                               # (Aᵀ)ᵀ = A
    x, info = tp.solve(tp.matrix(B.val), b)
    assert float(jnp.linalg.norm(B.T.todense() @ x - b)) < 1e-10
    assert bool(info.converged)


# ---------------------------------------------------------------------------
# gradients: adjoint on forward factors must match dense autodiff
# ---------------------------------------------------------------------------

def test_gradcheck_direct_symmetric_matches_dense_autodiff(A):
    rng = np.random.default_rng(4)
    b = jnp.asarray(rng.normal(size=A.shape[0]))

    def loss(val, rhs):
        x = A.with_values(val).solve(rhs, backend="direct")
        return jnp.sum(x ** 2)

    def loss_dense(val, rhs):
        return jnp.sum(jnp.linalg.solve(A.with_values(val).todense(), rhs) ** 2)

    g = jax.grad(loss, (0, 1))(A.val, b)
    gd = jax.grad(loss_dense, (0, 1))(A.val, b)
    np.testing.assert_allclose(np.asarray(g[0]), np.asarray(gd[0]),
                               rtol=1e-8, atol=1e-8)
    np.testing.assert_allclose(np.asarray(g[1]), np.asarray(gd[1]),
                               rtol=1e-8, atol=1e-8)


def test_gradcheck_direct_nonsymmetric_matches_dense_autodiff():
    B = _convection(48, c=0.4)
    rng = np.random.default_rng(5)
    b = jnp.asarray(rng.normal(size=48))

    def loss(val, rhs):
        x = B.with_values(val).solve(rhs, backend="direct")
        return jnp.sum(x ** 3)

    def loss_dense(val, rhs):
        return jnp.sum(jnp.linalg.solve(B.with_values(val).todense(), rhs) ** 3)

    g = jax.grad(loss, (0, 1))(B.val, b)
    gd = jax.grad(loss_dense, (0, 1))(B.val, b)
    np.testing.assert_allclose(np.asarray(g[0]), np.asarray(gd[0]),
                               rtol=1e-8, atol=1e-8)
    np.testing.assert_allclose(np.asarray(g[1]), np.asarray(gd[1]),
                               rtol=1e-8, atol=1e-8)


def test_gradcheck_direct_under_jit(A):
    b = jnp.ones(A.shape[0])

    def loss(val):
        return jnp.sum(A.with_values(val).solve(b, backend="direct") ** 2)

    def loss_dense(val):
        return jnp.sum(jnp.linalg.solve(A.with_values(val).todense(), b) ** 2)

    g = jax.jit(jax.grad(loss))(A.val)
    gd = jax.grad(loss_dense)(A.val)
    np.testing.assert_allclose(np.asarray(g), np.asarray(gd),
                               rtol=1e-8, atol=1e-8)


# ---------------------------------------------------------------------------
# plan-engine reuse (acceptance: 1 analyze + 1 factorize incl. backward)
# ---------------------------------------------------------------------------

def test_tolerance_sweep_plus_backward_one_analysis_one_factorization():
    A = poisson2d(10)               # fresh pattern: nothing cached yet
    b = jnp.ones(A.shape[0])

    def sweep_loss(val):
        acc = 0.0
        for tol in (1e-4, 1e-8, 1e-12):
            x = A.with_values(val).solve(b, backend="direct", tol=tol)
            acc = acc + jnp.sum(x ** 2)
        return acc

    reset_plan_stats()
    jax.grad(sweep_loss)(A.val)
    assert PLAN_STATS["analyze"] == 1, PLAN_STATS
    assert PLAN_STATS["factorize"] == 1, PLAN_STATS
    assert PLAN_STATS["setup"] == 1, PLAN_STATS
    # 2 forward reuses + 3 backward reuses, all on the one factorization
    assert PLAN_STATS["setup_reuse"] == 5, PLAN_STATS
    assert PLAN_STATS["transpose_shared"] == 1, PLAN_STATS


def test_sweep_plus_backward_shares_factors_nonsymmetric_lu():
    B = _convection(56, c=0.3)      # fresh non-symmetric pattern
    b = jnp.ones(56)

    def sweep_loss(val):
        acc = 0.0
        for tol in (1e-4, 1e-8, 1e-12):
            x = B.with_values(val).solve(b, backend="direct", tol=tol)
            acc = acc + jnp.sum(x ** 2)
        return acc

    reset_plan_stats()
    jax.grad(sweep_loss)(B.val)
    # the adjoint runs the transposed sweeps on the forward factors: still
    # exactly one symbolic analysis and one numeric factorization
    assert PLAN_STATS["analyze"] == 1, PLAN_STATS
    assert PLAN_STATS["factorize"] == 1, PLAN_STATS
    assert PLAN_STATS["transpose_shared"] == 1, PLAN_STATS


def test_batched_values_vmap_single_analysis(A):
    vals = jnp.stack([A.val, 2.0 * A.val, 0.5 * A.val])
    Ab = SparseTensor(vals, A.row, A.col, A.shape, props=A.props)
    bs = jnp.ones((3, A.shape[0]))
    reset_plan_stats()
    xs, _ = dispatch.solve_impl(make_config(Ab, backend="direct"), Ab, bs)
    assert PLAN_STATS["analyze"] == 1, PLAN_STATS
    for i, s in enumerate((1.0, 2.0, 0.5)):
        r = A.with_values(A.val * s) @ xs[i] - bs[i]
        assert float(jnp.linalg.norm(r)) < 1e-9


def test_multirhs_single_factorization(A):
    bs = jnp.asarray(np.random.default_rng(6).normal(size=(4, A.shape[0])))
    reset_plan_stats()
    xs = A.solve(bs, backend="direct")
    # one matrix, four right-hand sides: ONE setup serves the whole batch
    assert PLAN_STATS["factorize"] == 1, PLAN_STATS
    for i in range(4):
        assert float(jnp.linalg.norm(A @ xs[i] - bs[i])) < 1e-9


# ---------------------------------------------------------------------------
# auto-dispatch: direct preferred mid-size and when ill-conditioning is hinted
# ---------------------------------------------------------------------------

def test_auto_prefers_direct_midsize_and_illcond():
    mid = poisson2d(80)     # 6400: above DENSE_BUDGET, below DIRECT_BUDGET
    assert dispatch.select_backend(mid, "auto", "auto") == ("direct", "ldlt")
    mid2 = poisson2d(250)   # 62500: inside the RAISED 10⁵ budget (was 24576)
    assert dispatch.select_backend(mid2, "auto", "auto") == ("direct", "ldlt")
    big = poisson2d(320)    # 102400 > DIRECT_BUDGET → iterative
    assert dispatch.select_backend(big, "auto", "auto") == ("jnp", "cg")
    big.props["illcond_hint"] = True
    assert dispatch.select_backend(big, "auto", "auto") == ("direct", "ldlt")


# ---------------------------------------------------------------------------
# ILU(0) preconditioner on the shared symbolic machinery
# ---------------------------------------------------------------------------

def test_ilu_precond_accelerates_cg():
    A = poisson2d(24)       # 576 dof
    b = jnp.ones(A.shape[0])
    cfg_j = make_config(A, backend="jnp", method="cg", tol=1e-10,
                        precond="jacobi")
    cfg_i = make_config(A, backend="jnp", method="cg", tol=1e-10,
                        precond="ilu")
    _, info_j = dispatch.solve_impl(cfg_j, A, b)
    x, info_i = dispatch.solve_impl(cfg_i, A, b)
    assert float(jnp.linalg.norm(A @ x - b)) < 1e-7
    assert int(info_i.iters) < int(info_j.iters), (
        int(info_i.iters), int(info_j.iters))


def test_ilu_precond_differentiable():
    A = poisson2d(10)
    b = jnp.ones(A.shape[0])

    def loss(val):
        x = A.with_values(val).solve(b, backend="jnp", method="cg",
                                     tol=1e-13, precond="ilu")
        return jnp.sum(x ** 2)

    def loss_dense(val):
        return jnp.sum(jnp.linalg.solve(A.with_values(val).todense(), b) ** 2)

    g = jax.jit(jax.grad(loss))(A.val)
    gd = jax.grad(loss_dense)(A.val)
    np.testing.assert_allclose(np.asarray(g), np.asarray(gd),
                               rtol=1e-6, atol=1e-8)


def test_ilu_exact_on_tridiagonal():
    # a tridiagonal pattern has zero fill: ILU(0) IS the exact factorization
    A = poisson1d(32)
    b = jnp.asarray(np.random.default_rng(7).normal(size=32))
    plan = dispatch.get_plan(A, make_config(A, backend="jnp", method="cg",
                                            tol=1e-12, precond="ilu"))
    M = plan.artifacts["precond"].refresh(A, dispatch.make_matvec(A))
    np.testing.assert_allclose(np.asarray(M(b)),
                               np.asarray(jnp.linalg.solve(A.todense(), b)),
                               rtol=1e-10, atol=1e-10)


# ---------------------------------------------------------------------------
# zero-pivot guard (PR 4): scaled diagonal perturbation instead of NaNs
# ---------------------------------------------------------------------------

def test_zero_pivot_perturbed_with_warning_not_nan():
    """A structurally-present but numerically-zero pivot used to yield NaNs
    (no pivoting); the guard perturbs it to ±τ and warns."""
    import warnings
    Z = SparseTensor(np.array([0.0, 1.0, 1.0, 0.0]),
                     np.array([0, 0, 1, 1]), np.array([0, 1, 0, 1]), (2, 2))
    b = jnp.asarray([1.0, 2.0])
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        x = Z.solve(b, backend="direct")
    assert any("pivot" in str(w.message) for w in rec), rec
    assert bool(jnp.all(jnp.isfinite(x)))
    # the perturbed factors solve a τ-nearby matrix: still ~8 digits here
    np.testing.assert_allclose(np.asarray(x), [2.0, 1.0], rtol=1e-6)


def test_zero_pivot_guard_off_reproduces_nan():
    Z_art = symbolic_factor(np.array([0, 0, 1, 1]), np.array([0, 1, 0, 1]), 2)
    val = jnp.asarray([0.0, 1.0, 1.0, 0.0])
    C_bad = numeric_factor(Z_art, val, pivot_guard=False)
    assert not bool(jnp.all(jnp.isfinite(
        factored_solve(Z_art, C_bad, jnp.asarray([1.0, 2.0])))))
    C_ok = numeric_factor(Z_art, val)            # guard on by default
    x = factored_solve(Z_art, C_ok, jnp.asarray([1.0, 2.0]))
    assert bool(jnp.all(jnp.isfinite(x)))


def test_healthy_pivots_unperturbed(A):
    """The guard is a no-op (bit-identical factors) on well-pivoted
    matrices — no warning, no accuracy change."""
    import warnings
    art = symbolic_factor(np.asarray(A.row), np.asarray(A.col), A.shape[0])
    with warnings.catch_warnings():
        warnings.simplefilter("error")           # any warning fails the test
        C = numeric_factor(art, A.val)
    C_ref = numeric_factor(art, A.val, pivot_guard=False)
    np.testing.assert_array_equal(np.asarray(C), np.asarray(C_ref))


# ---------------------------------------------------------------------------
# sparse slogdet on the cached factors (PR 4)
# ---------------------------------------------------------------------------

def test_slogdet_sparse_matches_dense(A):
    s, l = A.slogdet()
    sd, ld = np.linalg.slogdet(np.asarray(A.todense()))
    assert float(s) == sd
    np.testing.assert_allclose(float(l), ld, rtol=1e-12)


def test_slogdet_sign_tracking_indefinite():
    """Negative pivots of an indefinite LDLᵀ must flow into the sign."""
    D = poisson2d(6)
    vals = np.asarray(D.val).copy()
    r_, c_ = np.asarray(D.row), np.asarray(D.col)
    vals[r_ == c_] -= 3.0                        # shift into indefiniteness
    Dn = SparseTensor(vals, D.row, D.col, D.shape)
    s, l = Dn.slogdet()
    sd, ld = np.linalg.slogdet(np.asarray(Dn.todense()))
    assert float(s) == sd
    np.testing.assert_allclose(float(l), ld, rtol=1e-6)


def test_slogdet_gradient_matches_dense(A):
    g = jax.grad(lambda v: A.with_values(v).slogdet()[1])(A.val)
    gd = jax.grad(lambda v: jnp.linalg.slogdet(
        A.with_values(v).todense())[1])(A.val)
    np.testing.assert_allclose(np.asarray(g), np.asarray(gd),
                               rtol=1e-8, atol=1e-10)


def test_slogdet_nonsymmetric_lu_path():
    B = _convection(40)
    s, l = B.slogdet()
    sd, ld = np.linalg.slogdet(np.asarray(B.todense()))
    assert float(s) == sd
    np.testing.assert_allclose(float(l), ld, rtol=1e-10)
    g = jax.grad(lambda v: B.with_values(v).slogdet()[1])(B.val)
    gd = jax.grad(lambda v: jnp.linalg.slogdet(
        B.with_values(v).todense())[1])(B.val)
    np.testing.assert_allclose(np.asarray(g), np.asarray(gd),
                               rtol=1e-8, atol=1e-10)


def test_slogdet_shares_factors_with_direct_solve(A):
    """slogdet rides the plan engine: a prior backend='direct' solve leaves
    memoized factors, and the slogdet forward reuses them outright."""
    b = jnp.ones(A.shape[0])
    reset_plan_stats()
    A.solve(b, backend="direct")
    assert PLAN_STATS["factorize"] == 1, PLAN_STATS
    A.slogdet()
    assert PLAN_STATS["factorize"] == 1, PLAN_STATS    # reused, not re-run
    assert PLAN_STATS["analyze"] == 1, PLAN_STATS      # same plan object


def test_slogdet_batched_falls_back_dense():
    A = poisson2d(8)
    vals = jnp.stack([A.val, 2.0 * A.val])
    Ab = SparseTensor(vals, A.row, A.col, A.shape, props=A.props)
    s, l = Ab.slogdet()
    for i, sc in enumerate((1.0, 2.0)):
        sd, ld = np.linalg.slogdet(sc * np.asarray(A.todense()))
        assert float(s[i]) == sd
        np.testing.assert_allclose(float(l[i]), ld, rtol=1e-10)
