"""Plan-cached solver engine (paper §3.2.3: one symbolic setup per pattern).

Proves the analyze(pattern) → setup(values) → solve(b) split is actually
reused: ``with_values`` re-solves and ``jax.grad`` backward passes perform
zero additional pattern analyses, the adjoint shares (symmetric) or caches
(non-symmetric) the transpose plan, and the values-dependent preconditioner
refreshes are traced-safe under jit/grad.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (SparseTensor, PLAN_STATS, get_plan, make_config,
                        reset_plan_stats)
from repro.core import dispatch
from repro.core import options as sla_options
from repro.data.poisson import poisson1d, poisson2d, poisson2d_vc


@pytest.fixture()
def A():
    return poisson2d(8)     # 64 dof, SPD


def _convection_diffusion(n, c=0.3):
    A1 = poisson1d(n)
    val = np.asarray(A1.val).copy()
    val[np.asarray(A1.col) == np.asarray(A1.row) - 1] = -1.0 - c
    val[np.asarray(A1.col) == np.asarray(A1.row) + 1] = -1.0 + c
    return SparseTensor(val, A1.row, A1.col, (n, n))


# ---------------------------------------------------------------------------
# plan-cache observability (the tentpole's acceptance criterion)
# ---------------------------------------------------------------------------

def test_with_values_solves_analyze_once(A):
    b = jnp.ones(A.shape[0])
    reset_plan_stats()
    A.solve(b, backend="jnp", method="cg", tol=1e-12)
    A.with_values(A.val * 2.0).solve(b, backend="jnp", method="cg", tol=1e-12)
    A.with_values(A.val * 0.5).solve(b, backend="jnp", method="cg", tol=1e-12)
    assert PLAN_STATS["analyze"] == 1, PLAN_STATS
    assert PLAN_STATS["cache_hit"] == 2, PLAN_STATS
    # values-dependent setup still ran per solve
    assert PLAN_STATS["setup"] == 3, PLAN_STATS


def test_grad_adds_zero_analyzes_symmetric(A):
    """Backward pass reuses the forward plan's transpose view (same object)."""
    b = jnp.ones(A.shape[0])

    def loss(val):
        x = A.with_values(val).solve(b, backend="jnp", method="cg", tol=1e-13)
        return jnp.sum(x ** 2)

    reset_plan_stats()
    jax.grad(loss)(A.val)
    assert PLAN_STATS["analyze"] == 1, PLAN_STATS
    assert PLAN_STATS["transpose_shared"] == 1, PLAN_STATS


def test_grad_transpose_plan_cached_nonsymmetric():
    """Non-symmetric: the transposed sibling is analyzed once, then cached."""
    B = _convection_diffusion(40)
    assert not B.props["symmetric"]
    b = jnp.ones(40)

    def loss(val):
        x = B.with_values(val).solve(b, backend="jnp", method="bicgstab",
                                     tol=1e-13, maxiter=4000)
        return jnp.sum(x ** 2)

    reset_plan_stats()
    jax.grad(loss)(B.val)
    first = PLAN_STATS["analyze"]
    assert first == 2, PLAN_STATS       # forward plan + transpose plan
    jax.grad(loss)(B.val * 1.5)
    assert PLAN_STATS["analyze"] == first, PLAN_STATS   # fully cached now


def test_batched_shared_pattern_single_analysis(A):
    vals = jnp.stack([A.val, 2.0 * A.val, 0.5 * A.val])
    Ab = SparseTensor(vals, A.row, A.col, A.shape, props=A.props)
    bs = jnp.ones((3, A.shape[0]))
    reset_plan_stats()
    xs = Ab.solve(bs, backend="jnp", method="cg", tol=1e-12)
    assert PLAN_STATS["analyze"] == 1, PLAN_STATS
    for i, s in enumerate((1.0, 2.0, 0.5)):
        r = A.with_values(A.val * s) @ xs[i] - bs[i]
        assert float(jnp.linalg.norm(r)) < 1e-8


def test_tolerance_sweep_shares_one_plan(A):
    """tol/atol/maxiter are solve-loop knobs, not part of the plan key."""
    b = jnp.ones(A.shape[0])
    reset_plan_stats()
    for tol in (1e-4, 1e-8, 1e-12):
        A.solve(b, backend="jnp", method="cg", tol=tol)
    assert PLAN_STATS["analyze"] == 1, PLAN_STATS
    assert PLAN_STATS["cache_hit"] == 2, PLAN_STATS
    # and the tighter tolerance was actually honored, not the cached one
    x = A.solve(b, backend="jnp", method="cg", tol=1e-12)
    assert float(jnp.linalg.norm(A @ x - b)) < 1e-9


def _nonsym_stencil(ng=8):
    from repro.data.poisson import vc_pattern, vc_coefficients
    rows, cols, meta = vc_pattern(ng)
    kappa = jnp.ones((ng, ng))
    val = vc_coefficients(kappa).reshape(5, ng, ng)
    val = val.at[1].mul(1.3).at[2].mul(0.7).reshape(-1)   # break symmetry
    return SparseTensor(val, rows, cols, (ng * ng, ng * ng),
                        props={"symmetric": False, "spd_hint": False},
                        stencil=meta, validate=False)


def test_grad_nonsymmetric_stencil_mg_stays_on_stencil():
    """Backward of a non-symmetric stencil-layout solve with precond='mg':
    the transpose plan used to drop to COO (and mg to jacobi); it now keeps
    the stencil kernel via the transposed-planes view, mg included — and the
    gradients still match dense autodiff."""
    B = _nonsym_stencil(8)
    b = jnp.ones(B.shape[0])

    def loss(v):
        x = B.with_values(v).solve(b, method="bicgstab", tol=1e-13,
                                   maxiter=8000, precond="mg")
        return jnp.sum(x ** 2)

    def loss_dense(v):
        return jnp.sum(jnp.linalg.solve(B.with_values(v).todense(), b) ** 2)

    g = jax.grad(loss)(B.val)
    gd = jax.grad(loss_dense)(B.val)
    np.testing.assert_allclose(np.asarray(g), np.asarray(gd),
                               rtol=1e-6, atol=1e-8)


def test_stencil_transpose_plan_keeps_kernel():
    """The adjoint plan of a non-symmetric stencil operator is a
    shared-artifact transposed-planes view: stencil layout retained, same
    backend, counted as transpose_shared — and Aᵀ numerics are exact."""
    B = _nonsym_stencil(8)
    plan = B.plan(method="bicgstab", tol=1e-12)
    assert plan.cfg.backend == "stencil"
    reset_plan_stats()
    tp = plan.transpose()
    assert tp.stencil is not None                 # kernel view survived
    assert tp.cfg.backend == "stencil"            # no jnp/COO rewrite
    assert PLAN_STATS["analyze"] == 0, PLAN_STATS  # zero re-analysis
    assert PLAN_STATS["transpose_shared"] == 1, PLAN_STATS
    assert tp.transpose() is plan                 # (Aᵀ)ᵀ = A
    # the transposed-planes matvec equals dense Aᵀ
    g = jnp.asarray(np.random.default_rng(0).normal(size=B.shape[0]))
    lam, info = tp.solve(tp.matrix(B.val), g)
    res = np.asarray(B.todense()).T @ np.asarray(lam) - np.asarray(g)
    assert np.abs(res).max() < 1e-7, np.abs(res).max()


def test_plan_api_stages(A):
    """analyze → setup → solve stages are individually addressable."""
    plan = A.plan(backend="jnp", method="cg", tol=1e-12)
    assert plan is A.plan(backend="jnp", method="cg", tol=1e-12)  # cached
    state = plan.setup(A)
    x, info = plan.solve_single(A, jnp.ones(A.shape[0]), state=state)
    assert bool(info.converged)
    assert plan.transpose() is plan     # symmetric pattern


def test_iterative_setup_memoized_per_values(A):
    """PR-2 leftover closed: the iterative backends memoize setup(values)
    per values array like the direct backend — a tolerance sweep refreshes
    the preconditioner ONCE, new values still refresh."""
    b = jnp.ones(A.shape[0])
    reset_plan_stats()
    for tol in (1e-4, 1e-8, 1e-12):
        A.solve(b, backend="jnp", method="cg", tol=tol)
    assert PLAN_STATS["setup"] == 1, PLAN_STATS
    assert PLAN_STATS["setup_reuse"] == 2, PLAN_STATS
    # a with_values refresh is NOT served from the memo (different array)
    A.with_values(A.val * 2.0).solve(b, backend="jnp", method="cg", tol=1e-8)
    assert PLAN_STATS["setup"] == 2, PLAN_STATS
    # and the sweep honored the tightest tolerance despite the shared state
    x = A.solve(b, backend="jnp", method="cg", tol=1e-12)
    assert float(jnp.linalg.norm(A @ x - b)) < 1e-9


def test_jitted_solve_over_captured_matrix_does_not_poison_memo(A):
    """A jitted solve that CLOSES OVER the matrix computes setup state from
    a concrete values array inside the trace; memoizing that traced state
    under the concrete key used to leak tracers into the next eager solve
    (UnexpectedTracerError).  The staging probe now skips the store — and
    eager-grad setups (concrete state under a dirty trace stack) still
    memoize."""
    b = jnp.ones(A.shape[0])
    for kw in (dict(backend="jnp", method="cg", tol=1e-10),
               dict(backend="direct")):
        cfg = make_config(A, **kw)
        plan = dispatch.get_plan(A, cfg)
        x1 = jax.jit(lambda bb: plan.solve(A, bb)[0])(b)
        x2, _ = plan.solve(A, b)           # used to raise
        np.testing.assert_allclose(np.asarray(x1), np.asarray(x2),
                                   rtol=1e-10, atol=1e-10)


def test_symmetric_backward_reuses_iterative_setup(A):
    """The adjoint of a symmetric iterative solve hits the per-values memo:
    forward and backward share one preconditioner refresh."""
    b = jnp.ones(A.shape[0])

    def loss(val):
        x = A.with_values(val).solve(b, backend="jnp", method="cg",
                                     tol=1e-13, precond="block_jacobi")
        return jnp.sum(x ** 2)

    reset_plan_stats()
    jax.grad(loss)(A.val)
    assert PLAN_STATS["setup"] == 1, PLAN_STATS
    assert PLAN_STATS["setup_reuse"] >= 1, PLAN_STATS


# ---------------------------------------------------------------------------
# kernel plans: analyze-time BELL conversion, transpose sharing, fused step
# ---------------------------------------------------------------------------

def test_kernel_plan_one_bell_conversion_serves_everything(A):
    """One analyze-time BELL conversion serves the forward solve, the
    backward adjoint, and a with_values sweep (the tentpole's counter)."""
    b = jnp.ones(A.shape[0])

    def loss(val):
        x = A.with_values(val).solve(b, backend="pallas", method="cg",
                                     tol=1e-13)
        return jnp.sum(x ** 2)

    reset_plan_stats()
    jax.grad(loss)(A.val)
    A.with_values(A.val * 2.0).solve(b, backend="pallas", method="cg",
                                     tol=1e-12)
    A.with_values(A.val * 0.5).solve(b, backend="pallas", method="cg",
                                     tol=1e-12)
    assert PLAN_STATS["analyze"] == 1, PLAN_STATS
    assert PLAN_STATS["kernel_plan"] == 1, PLAN_STATS   # symmetric: Aᵀ shares
    assert PLAN_STATS["transpose_shared"] == 1, PLAN_STATS
    kp = A.plan(backend="pallas", method="cg").artifacts["kernel"]
    assert kp.choice == "bell"
    assert kp.t_bell is kp.bell


def test_kernel_plan_transpose_shares_layout_nonsymmetric():
    """Non-symmetric pallas plan: A and Aᵀ BELL layouts are built in the SAME
    analyze pass, and the adjoint plan is a shared-artifact sibling — zero
    additional analyzes, gradients still exact."""
    B = _convection_diffusion(40)
    b = jnp.ones(40)

    def loss(val):
        x = B.with_values(val).solve(b, backend="pallas", method="bicgstab",
                                     tol=1e-13, maxiter=4000)
        return jnp.sum(x ** 2)

    def loss_dense(val):
        return jnp.sum(jnp.linalg.solve(B.with_values(val).todense(), b) ** 2)

    reset_plan_stats()
    g = jax.grad(loss)(B.val)
    assert PLAN_STATS["analyze"] == 1, PLAN_STATS       # NOT 2: layout shared
    assert PLAN_STATS["kernel_plan"] == 2, PLAN_STATS   # A + Aᵀ, one pass
    assert PLAN_STATS["transpose_shared"] == 1, PLAN_STATS
    jax.grad(loss)(B.val * 1.5)
    assert PLAN_STATS["analyze"] == 1, PLAN_STATS
    assert PLAN_STATS["kernel_plan"] == 2, PLAN_STATS
    plan = B.plan(backend="pallas", method="bicgstab")
    tp = plan.transpose()
    assert tp.artifacts["kernel"].bell is plan.artifacts["kernel"].t_bell
    gd = jax.grad(loss_dense)(B.val)
    np.testing.assert_allclose(np.asarray(g), np.asarray(gd),
                               rtol=1e-6, atol=1e-8)


def test_kernel_plan_auto_falls_back_on_interpret_platform(A):
    """The jnp backend's "auto" kernel plan records a segment-sum fallback
    (with its reason) on platforms where Pallas would only be emulated."""
    if jax.default_backend() in ("tpu", "gpu"):
        pytest.skip("compiled-Pallas platform: auto plan may adopt BELL")
    reset_plan_stats()
    plan = A.plan(backend="jnp", method="cg")
    kp = plan.artifacts["kernel"]
    assert kp.choice == "coo"
    assert "interpret" in kp.reason
    assert PLAN_STATS["kernel_plan"] == 0, PLAN_STATS   # no conversion ran


def test_plan_cache_lru_eviction(A):
    """Satellite: the per-tensor plan cache is a bounded LRU — overflowing
    it evicts the oldest plan and counts it."""
    A._plans = dispatch.PlanCache(cap=2)
    reset_plan_stats()
    A.plan(backend="jnp", method="cg")
    A.plan(backend="jnp", method="bicgstab")
    assert PLAN_STATS["evictions"] == 0, PLAN_STATS
    A.plan(backend="jnp", method="gmres")              # evicts the cg plan
    assert PLAN_STATS["evictions"] == 1, PLAN_STATS
    assert PLAN_STATS["cache_miss"] == 3, PLAN_STATS
    A.plan(backend="jnp", method="bicgstab")           # still resident
    assert PLAN_STATS["cache_hit"] == 1, PLAN_STATS
    A.plan(backend="jnp", method="cg")                 # re-analyzed
    assert PLAN_STATS["cache_miss"] == 4, PLAN_STATS
    assert PLAN_STATS["evictions"] == 2, PLAN_STATS


def test_fused_step_solve_matches_plain_and_grad(A):
    """fused_step='on' routes CG/BiCGStab through the fused Pallas step
    kernels: same solution as the plain loops, gradients still match dense
    autodiff (the adjoint solve runs fused too)."""
    b = jnp.asarray(np.random.default_rng(7).normal(size=A.shape[0]))

    def loss(val):
        x = A.with_values(val).solve(b, backend="pallas", method="cg",
                                     tol=1e-13)
        return jnp.sum(x ** 2)

    def loss_dense(val):
        return jnp.sum(jnp.linalg.solve(A.with_values(val).todense(), b) ** 2)

    x_plain = A.solve(b, backend="pallas", method="cg", tol=1e-12)
    with sla_options.options(fused_step="on"):
        x_fused = A.solve(b, backend="pallas", method="cg", tol=1e-12)
        g = jax.grad(loss)(A.val)
    np.testing.assert_allclose(np.asarray(x_fused), np.asarray(x_plain),
                               rtol=1e-9, atol=1e-11)
    gd = jax.grad(loss_dense)(A.val)
    np.testing.assert_allclose(np.asarray(g), np.asarray(gd),
                               rtol=1e-6, atol=1e-8)


def test_fused_step_bicgstab_nonsymmetric_grad():
    B = _convection_diffusion(40, c=0.4)
    b = jnp.asarray(np.random.default_rng(8).normal(size=40))

    def loss(val):
        x = B.with_values(val).solve(b, backend="pallas", method="bicgstab",
                                     tol=1e-13, maxiter=4000)
        return jnp.sum(x ** 2)

    def loss_dense(val):
        return jnp.sum(jnp.linalg.solve(B.with_values(val).todense(), b) ** 2)

    with sla_options.options(fused_step="on"):
        g = jax.grad(loss)(B.val)
    gd = jax.grad(loss_dense)(B.val)
    np.testing.assert_allclose(np.asarray(g), np.asarray(gd),
                               rtol=1e-6, atol=1e-8)


def test_fused_chebyshev_precond_matches_plain(A):
    """The fused Chebyshev inner step threads through the preconditioner
    refresh without changing the polynomial."""
    b = jnp.ones(A.shape[0])
    x_plain = A.solve(b, backend="pallas", method="cg", tol=1e-12,
                      precond="chebyshev")
    with sla_options.options(fused_step="on"):
        x_fused = A.solve(b, backend="pallas", method="cg", tol=1e-12,
                          precond="chebyshev")
    np.testing.assert_allclose(np.asarray(x_fused), np.asarray(x_plain),
                               rtol=1e-9, atol=1e-11)


# ---------------------------------------------------------------------------
# gradients: forward-vs-adjoint plan reuse must not change the math
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend,method", [("jnp", "cg"), ("dense", "lu"),
                                            ("dense", "cholesky")])
def test_gradcheck_symmetric_matches_dense_autodiff(A, backend, method):
    rng = np.random.default_rng(0)
    b = jnp.asarray(rng.normal(size=A.shape[0]))

    def loss(val, rhs):
        x = A.with_values(val).solve(rhs, backend=backend, method=method,
                                     tol=1e-13, maxiter=8000)
        return jnp.sum(x ** 2)

    def loss_dense(val, rhs):
        return jnp.sum(jnp.linalg.solve(A.with_values(val).todense(), rhs) ** 2)

    g = jax.grad(loss, (0, 1))(A.val, b)
    gd = jax.grad(loss_dense, (0, 1))(A.val, b)
    np.testing.assert_allclose(np.asarray(g[0]), np.asarray(gd[0]),
                               rtol=1e-6, atol=1e-8)
    np.testing.assert_allclose(np.asarray(g[1]), np.asarray(gd[1]),
                               rtol=1e-6, atol=1e-8)


@pytest.mark.parametrize("backend,method", [("jnp", "bicgstab"), ("dense", "lu")])
def test_gradcheck_nonsymmetric_matches_dense_autodiff(backend, method):
    B = _convection_diffusion(48, c=0.4)
    rng = np.random.default_rng(1)
    b = jnp.asarray(rng.normal(size=48))

    def loss(val, rhs):
        x = B.with_values(val).solve(rhs, backend=backend, method=method,
                                     tol=1e-13, maxiter=8000)
        return jnp.sum(x ** 3)

    def loss_dense(val, rhs):
        return jnp.sum(jnp.linalg.solve(B.with_values(val).todense(), rhs) ** 3)

    g = jax.grad(loss, (0, 1))(B.val, b)
    gd = jax.grad(loss_dense, (0, 1))(B.val, b)
    np.testing.assert_allclose(np.asarray(g[0]), np.asarray(gd[0]),
                               rtol=1e-6, atol=1e-8)
    np.testing.assert_allclose(np.asarray(g[1]), np.asarray(gd[1]),
                               rtol=1e-6, atol=1e-8)


# ---------------------------------------------------------------------------
# preconditioner plans: traced-safe refresh (regression for the jit crash)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("precond", ["block_jacobi", "chebyshev"])
def test_preconditioned_solve_differentiable(precond):
    """block_jacobi used to call np.asarray on tracers; chebyshev re-ran the
    Lanczos bound inside every solve.  Both now refresh inside setup(values)
    and work under jit + grad."""
    A = poisson2d(12)
    b = jnp.ones(A.shape[0])

    def loss(val):
        x = A.with_values(val).solve(b, backend="jnp", method="cg",
                                     tol=1e-13, precond=precond)
        return jnp.sum(x ** 2)

    def loss_dense(val):
        return jnp.sum(jnp.linalg.solve(A.with_values(val).todense(), b) ** 2)

    g = jax.jit(jax.grad(loss))(A.val)
    gd = jax.grad(loss_dense)(A.val)
    np.testing.assert_allclose(np.asarray(g), np.asarray(gd),
                               rtol=1e-6, atol=1e-8)


def test_mg_first_class_precond_option():
    """precond='mg' builds the V-cycle from the stencil planes inside setup."""
    xs = jnp.linspace(0, 1, 32)
    X, Y = jnp.meshgrid(xs, xs, indexing="ij")
    kappa = 1.0 + 0.5 * jnp.sin(2 * jnp.pi * X) * jnp.sin(2 * jnp.pi * Y)
    A = poisson2d_vc(kappa, use_stencil_kernel=True)
    b = jnp.ones(A.shape[0])
    x = A.solve(b, method="cg", tol=1e-10, precond="mg", maxiter=200)
    assert float(jnp.linalg.norm(A @ x - b)) < 1e-7
    cfg = make_config(A, method="cg", tol=1e-10, precond="mg", maxiter=200)
    assert cfg.backend == "stencil"     # auto-dispatch kept the kernel


def test_mg_precond_requires_stencil():
    A = poisson2d(8)
    with pytest.raises(ValueError, match="mg"):
        A.solve(jnp.ones(A.shape[0]), backend="jnp", precond="mg")


# ---------------------------------------------------------------------------
# batched matvec kernel routing (regression: used to silently fall to COO)
# ---------------------------------------------------------------------------

def test_batched_matvec_routes_through_stencil_kernel(monkeypatch):
    xs = jnp.linspace(0, 1, 16)
    X, Y = jnp.meshgrid(xs, xs, indexing="ij")
    kappa = 1.0 + 0.3 * jnp.cos(2 * jnp.pi * X) * jnp.cos(2 * jnp.pi * Y)
    A = poisson2d_vc(kappa, use_stencil_kernel=True)
    import repro.kernels.ops as kops
    calls = {"n": 0}
    orig = kops.stencil5_matvec

    def counting(*args, **kw):
        calls["n"] += 1
        return orig(*args, **kw)

    monkeypatch.setattr(kops, "stencil5_matvec", counting)
    rng = np.random.default_rng(0)
    xb = jnp.asarray(rng.normal(size=(3, A.shape[0])))
    y = A @ xb
    assert calls["n"] > 0, "batched matvec bypassed the stencil kernel"
    dense = np.asarray(A.todense())
    np.testing.assert_allclose(np.asarray(y), np.asarray(xb) @ dense.T,
                               rtol=1e-10, atol=1e-10)


# ---------------------------------------------------------------------------
# gmres residual carry (regression: 2 extra matvecs per restart cycle)
# ---------------------------------------------------------------------------

def test_gmres_reports_true_carried_residual():
    from repro.core import solvers
    B = _convection_diffusion(60, c=0.4)
    rng = np.random.default_rng(2)
    b = jnp.asarray(rng.normal(size=60))
    mv = lambda v: B @ v
    x, info = solvers.gmres(mv, b, tol=1e-10, restart=20, maxiter=100)
    assert bool(info.converged)
    true_rn = float(jnp.linalg.norm(mv(x) - b))
    np.testing.assert_allclose(float(info.resnorm), true_rn, rtol=1e-10)


def test_gmres_matvec_count_per_cycle():
    """Trace-level matvec count: restart(m) Arnoldi steps + ONE residual
    update per cycle — the convergence check rides on the carried residual."""
    from repro.core import solvers
    B = _convection_diffusion(40)
    b = jnp.ones(40)
    calls = {"n": 0}

    def mv(v):
        calls["n"] += 1
        return B @ v

    m = 10
    jax.make_jaxpr(lambda rhs: solvers.gmres(mv, rhs, tol=1e-10, restart=m,
                                             maxiter=50)[0])(b)
    # trace-level count: init residual (1) + cond (0 — carried norm) +
    # body (1 scan-traced Arnoldi step + 1 residual update) = 3.  The old
    # loop re-derived the residual in cond and at exit → 5 traced matvecs.
    assert calls["n"] <= 3, calls["n"]
