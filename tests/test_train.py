"""Training-substrate tests: loss decreases, checkpoint/restart bitwise
resume, failure injection, data determinism, gradient compression."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, smoke_variant
from repro.checkpoint.manager import CheckpointManager
from repro.data.tokens import synthetic_batch
from repro.ft.driver import FTConfig, TrainLoop
from repro.launch.train import make_train_step
from repro.models import transformer as T
from repro.optim.adamw import AdamWConfig, init_opt_state
from repro.optim import compress


def _setup(tmp, arch="llama3.2-1b", lr=3e-3, steps=40):
    cfg = smoke_variant(get_config(arch))
    opt_cfg = AdamWConfig(lr=lr, warmup_steps=5, total_steps=steps)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    state = {"params": params, "opt": init_opt_state(params)}
    step = jax.jit(make_train_step(cfg, opt_cfg))

    def make_batch(s):
        return synthetic_batch(0, s, 4, 65, cfg.vocab)

    return cfg, state, step, make_batch


def test_loss_decreases(tmp_path):
    cfg, state, step, make_batch = _setup(tmp_path)
    losses = []
    for s in range(40):
        state, m = step(state, make_batch(s))
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.2, losses[::8]


def test_checkpoint_roundtrip_and_resume_equivalence(tmp_path):
    """Stop at step 10, restore, continue to 20 — bitwise equal to an
    uninterrupted run (determinism of data + optimizer)."""
    cfg, state0, step, make_batch = _setup(tmp_path)
    mgr = CheckpointManager(str(tmp_path / "ck"), keep=2)

    state = state0
    for s in range(10):
        state, _ = step(state, make_batch(s))
    mgr.save(10, state)
    cont = state
    for s in range(10, 20):
        cont, _ = step(cont, make_batch(s))

    resumed = mgr.restore(10, state0)
    for s in range(10, 20):
        resumed, _ = step(resumed, make_batch(s))

    for a, b in zip(jax.tree.leaves(cont), jax.tree.leaves(resumed)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_ft_failure_injection_recovers(tmp_path):
    cfg, state, step, make_batch = _setup(tmp_path)
    loop = TrainLoop(FTConfig(ckpt_dir=str(tmp_path / "ft"), ckpt_every=5,
                              async_save=False),
                     step, make_batch)
    final, last = loop.run(state, 20, fail_at=12, log_every=0,
                           logger=lambda *_: None)
    assert last == 20
    assert loop.mgr.latest_step() == 20
    # equivalent run without failure gives identical state
    loop2 = TrainLoop(FTConfig(ckpt_dir=str(tmp_path / "ft2"), ckpt_every=5,
                               async_save=False), step, make_batch)
    final2, _ = loop2.run(state, 20, log_every=0, logger=lambda *_: None)
    for a, b in zip(jax.tree.leaves(final), jax.tree.leaves(final2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_keep_k_and_atomicity(tmp_path):
    mgr = CheckpointManager(str(tmp_path / "k"), keep=2)
    tree = {"a": jnp.arange(5), "b": {"c": jnp.ones((2, 2))}}
    for s in (1, 2, 3, 4):
        mgr.save(s, tree)
    assert mgr.all_steps() == [3, 4]
    r = mgr.restore(4, tree)
    np.testing.assert_array_equal(np.asarray(r["a"]), np.arange(5))
    # no stray tmp dirs
    assert not [d for d in os.listdir(tmp_path / "k") if d.startswith(".tmp")]


def test_data_determinism_and_restart_safety():
    b1 = synthetic_batch(0, 7, 4, 33, 1000)
    b2 = synthetic_batch(0, 7, 4, 33, 1000)
    b3 = synthetic_batch(0, 8, 4, 33, 1000)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    assert not np.array_equal(np.asarray(b1["tokens"]),
                              np.asarray(b3["tokens"]))


def test_elastic_restore_under_new_topology(tmp_path):
    """Checkpoints store global arrays: restoring onto a different device
    layout (here: explicit single-device shardings) must preserve values."""
    mgr = CheckpointManager(str(tmp_path / "e"), keep=1)
    tree = {"w": jnp.arange(16.0).reshape(4, 4)}
    mgr.save(3, tree)
    mesh = jax.sharding.Mesh(np.array(jax.devices()[:1]), ("data",))
    sh = {"w": jax.sharding.NamedSharding(
        mesh, jax.sharding.PartitionSpec(None, None))}
    restored = mgr.restore(3, tree, shardings=sh)
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.arange(16.0).reshape(4, 4))


def test_int8_quantization_roundtrip_and_error_feedback():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=512) * 3.0)
    q, s = compress.quantize_int8(x)
    err0 = float(jnp.max(jnp.abs(compress.dequantize_int8(q, s) - x)))
    assert err0 <= float(s) * 0.5 + 1e-9
    # error feedback: accumulated compressed sum → unbiased over steps
    err = jnp.zeros_like(x)
    acc = jnp.zeros_like(x)
    for _ in range(50):
        q, s, err = compress.ef_compress(x, err)
        acc = acc + compress.dequantize_int8(q, s)
    np.testing.assert_allclose(np.asarray(acc / 50), np.asarray(x),
                               atol=float(s) * 0.1)
