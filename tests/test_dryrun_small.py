"""Miniature dry-run: the full lower→compile→analyze path on an 8-device
(2,2,2) pod/data/model mesh in a subprocess, plus roofline-parser unit tests
on synthetic HLO."""
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.mark.known_failing
def test_mini_multipod_dryrun():
    src = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp
        from repro.configs import get_config, smoke_variant
        from repro.configs.base import ShapeConfig
        from repro.launch import shardings as sh
        from repro.launch.specs import batch_specs, decode_specs
        from repro.launch.train import jit_train_step
        from repro.launch.serve import jit_serve_step
        from repro.launch import roofline as R
        from repro.models import transformer as T
        from repro.optim.adamw import AdamWConfig, init_opt_state

        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        rules = sh.baseline_rules(mesh)
        cfg = smoke_variant(get_config("llama3.2-1b"))
        shape = ShapeConfig("t", seq_len=64, global_batch=8, kind="train")
        pshapes = T.param_shapes(cfg)
        specs = batch_specs(cfg, shape)
        step, _ = jit_train_step(cfg, AdamWConfig(), rules, pshapes, specs)
        state_shapes = {"params": pshapes,
                        "opt": jax.eval_shape(init_opt_state, pshapes)}
        lowered = step.lower(state_shapes, specs)
        compiled = lowered.compile()
        mem = compiled.memory_analysis()
        assert mem.temp_size_in_bytes > 0
        ca = compiled.cost_analysis()
        ca = ca[0] if isinstance(ca, (list, tuple)) else ca
        assert ca.get("flops", 0) > 0
        hlo = R.analyze_hlo(compiled.as_text())
        assert hlo.dot_flops > 0
        # the layer scan must be trip-multiplied: corrected ≥ xla raw count
        assert hlo.dot_flops >= 0.8 * float(ca["flops"])
        # decode path lowers too
        dshape = ShapeConfig("d", seq_len=64, global_batch=8, kind="decode")
        dspecs = decode_specs(cfg, dshape)
        sstep, _ = jit_serve_step(cfg, rules, pshapes, dspecs)
        sc = sstep.lower(pshapes, dspecs["state"], dspecs["token"],
                         dspecs["pos"]).compile()
        assert "all-" in sc.as_text() or "collective" in sc.as_text() or True
        print("MINI_DRYRUN_OK")
    """)
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    proc = subprocess.run([sys.executable, "-c", src], capture_output=True,
                          text=True, env=env, timeout=900)
    assert proc.returncode == 0, proc.stderr[-4000:]
    assert "MINI_DRYRUN_OK" in proc.stdout


def test_roofline_parser_units():
    from repro.launch import roofline as R
    hlo = textwrap.dedent("""\
        HloModule test, num_partitions=4

        %body (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
          %p = (s32[], f32[8,8]) parameter(0)
          %i = s32[] get-tuple-element(%p), index=0
          %x = f32[8,8]{1,0} get-tuple-element(%p), index=1
          %d = f32[8,8]{1,0} dot(%x, %x), lhs_contracting_dims={1}, rhs_contracting_dims={0}
          %ar = f32[8,8]{1,0} all-reduce(%d), replica_groups={}
          ROOT %t = (s32[], f32[8,8]) tuple(%i, %ar)
        }

        %cond (p: (s32[], f32[8,8])) -> pred[] {
          %p = (s32[], f32[8,8]) parameter(0)
          %i = s32[] get-tuple-element(%p), index=0
          %c = s32[] constant(5)
          ROOT %lt = pred[] compare(%i, %c), direction=LT
        }

        ENTRY %main (a: f32[8,8]) -> f32[8,8] {
          %a = f32[8,8]{1,0} parameter(0)
          %t0 = (s32[], f32[8,8]) tuple(%a, %a)
          %w = (s32[], f32[8,8]) while(%t0), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"5"}}
          ROOT %out = f32[8,8]{1,0} get-tuple-element(%w), index=1
        }
    """)
    stats = R.analyze_hlo(hlo)
    # dot: 2·8·8·8 = 1024 flops × 5 trips
    assert stats.dot_flops == 1024 * 5
    # all-reduce: 8·8·4 bytes × 5 trips
    assert stats.collective_bytes == 256 * 5
    assert stats.coll_by_kind == {"all-reduce": 256 * 5}


def test_roofline_model_flops():
    from repro.configs import get_config
    from repro.configs.base import SHAPES
    from repro.launch import roofline as R
    cfg = get_config("llama3.2-1b")
    mf = R.model_flops(cfg, SHAPES["train_4k"])
    # 6 · 1.24e9 · (4096·256) ≈ 7.8e15
    assert 6e15 < mf < 9e15
    moe = get_config("dbrx-132b")
    # active ≪ total for MoE
    assert R.active_params(moe) < 0.45 * moe.param_count()


def test_ledger_complete_and_green():
    """The production sweep artifact: every (arch × shape × mesh) cell is
    either ok or a documented long-context skip; both meshes covered."""
    import json
    path = os.path.join(REPO, "results", "dryrun.jsonl")
    if not os.path.exists(path):
        pytest.skip("run launch.dryrun --all --mesh both first")
    recs = [json.loads(l) for l in open(path)]
    cells = {(r["arch"], r["shape"], r["mesh"]): r for r in recs}
    from repro.configs import ARCH_IDS, SHAPES, get_config, is_subquadratic
    for arch in ARCH_IDS:
        for shape in SHAPES:
            for mesh in ("16x16", "2x16x16"):
                r = cells.get((arch, shape, mesh))
                assert r is not None, (arch, shape, mesh)
                if shape == "long_500k" and not is_subquadratic(
                        get_config(arch)):
                    assert r["status"] == "skipped"
                else:
                    assert r["status"] == "ok", (arch, shape, mesh,
                                                 r.get("error"))
