"""Algebraic multigrid in the plan engine (smoothed aggregation).

Covers the PR-4 tentpole: ``precond="amg"`` works on unstructured patterns
the geometric ``mg`` cannot touch, cuts CG iterations ≥4× vs Jacobi on a
graph Laplacian, carries exact adjoint gradients through ``sparse_solve``,
and the analyze/setup split is observable — exactly ONE pattern coarsening
and ONE numeric Galerkin product across a tolerance sweep + backward
(``PLAN_STATS["coarsen"]``/``["galerkin"]``).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import PLAN_STATS, SparseTensor, reset_plan_stats
from repro.core import multigrid as mg
from repro.core.adjoint import sparse_solve_with_info
from repro.core.dispatch import make_config
from repro.data.graphs import graph_laplacian
from repro.data.poisson import poisson1d, poisson2d


def _convection_diffusion(n, c=0.3):
    A1 = poisson1d(n)
    val = np.asarray(A1.val).copy()
    val[np.asarray(A1.col) == np.asarray(A1.row) - 1] = -1.0 - c
    val[np.asarray(A1.col) == np.asarray(A1.row) + 1] = -1.0 + c
    return SparseTensor(val, A1.row, A1.col, (n, n))


# ---------------------------------------------------------------------------
# the acceptance criterion: ≥4× fewer CG iterations than Jacobi on an
# unstructured problem mg cannot handle
# ---------------------------------------------------------------------------

def test_amg_beats_jacobi_4x_on_unstructured_graph():
    G = graph_laplacian(3000, seed=0, shift=1e-3)
    assert G.stencil is None          # no grid structure — mg inapplicable
    with pytest.raises(ValueError, match="mg"):
        G.solve(jnp.ones(G.shape[0]), backend="jnp", precond="mg")
    b = jnp.asarray(np.random.default_rng(0).normal(size=G.shape[0]))
    cfg_j = make_config(G, backend="jnp", method="cg", tol=1e-8,
                        maxiter=40000)
    xj, ij = sparse_solve_with_info(cfg_j, G, b)
    cfg_a = make_config(G, backend="jnp", method="cg", tol=1e-8,
                        maxiter=40000, precond="amg")
    xa, ia = sparse_solve_with_info(cfg_a, G, b)
    assert bool(ia.converged) and bool(ij.converged)
    assert float(jnp.linalg.norm(G @ xa - b)) < 1e-6
    assert int(ia.iters) * 4 <= int(ij.iters), (int(ia.iters), int(ij.iters))


def test_amg_on_structured_poisson_too():
    """amg needs no stencil metadata but still works on grid operators."""
    A = poisson2d(32)
    b = jnp.ones(A.shape[0])
    cfg = make_config(A, backend="jnp", method="cg", tol=1e-10, maxiter=2000,
                      precond="amg")
    x, info = sparse_solve_with_info(cfg, A, b)
    assert bool(info.converged)
    assert float(jnp.linalg.norm(A @ x - b)) < 1e-7


# ---------------------------------------------------------------------------
# plan-reuse counters: 1 coarsening + 1 Galerkin across sweep + backward
# ---------------------------------------------------------------------------

def test_amg_one_coarsen_one_galerkin_across_sweep_and_backward():
    A = poisson2d(12)
    b = jnp.ones(A.shape[0])
    reset_plan_stats()
    for tol in (1e-4, 1e-8, 1e-12):
        A.solve(b, backend="jnp", method="cg", tol=tol, precond="amg")

    def loss(val):
        x = A.with_values(val).solve(b, backend="jnp", method="cg",
                                     tol=1e-12, precond="amg")
        return jnp.sum(x ** 2)

    jax.grad(loss)(A.val)
    assert PLAN_STATS["coarsen"] == 1, PLAN_STATS   # symbolic: once/pattern
    assert PLAN_STATS["galerkin"] == 1, PLAN_STATS  # numeric: once/values
    assert PLAN_STATS["analyze"] == 1, PLAN_STATS
    assert PLAN_STATS["transpose_shared"] == 1, PLAN_STATS
    # new values DO refresh the numeric half — but never re-coarsen
    A.with_values(A.val * 2.0).solve(b, backend="jnp", method="cg",
                                     tol=1e-8, precond="amg")
    assert PLAN_STATS["coarsen"] == 1, PLAN_STATS
    assert PLAN_STATS["galerkin"] == 2, PLAN_STATS


# ---------------------------------------------------------------------------
# gradients through the AMG-preconditioned solve (sym + non-sym)
# ---------------------------------------------------------------------------

def test_amg_gradcheck_symmetric_matches_dense_autodiff():
    A = poisson2d(12)
    b = jnp.asarray(np.random.default_rng(0).normal(size=A.shape[0]))

    def loss(val, rhs):
        x = A.with_values(val).solve(rhs, backend="jnp", method="cg",
                                     tol=1e-13, precond="amg")
        return jnp.sum(x ** 2)

    def loss_dense(val, rhs):
        return jnp.sum(jnp.linalg.solve(A.with_values(val).todense(),
                                        rhs) ** 2)

    g = jax.grad(loss, (0, 1))(A.val, b)
    gd = jax.grad(loss_dense, (0, 1))(A.val, b)
    np.testing.assert_allclose(np.asarray(g[0]), np.asarray(gd[0]),
                               rtol=1e-6, atol=1e-8)
    np.testing.assert_allclose(np.asarray(g[1]), np.asarray(gd[1]),
                               rtol=1e-6, atol=1e-8)


def test_amg_gradcheck_nonsymmetric_matches_dense_autodiff():
    B = _convection_diffusion(48, c=0.4)
    assert not B.props["symmetric"]
    b = jnp.asarray(np.random.default_rng(1).normal(size=48))

    def loss(val, rhs):
        x = B.with_values(val).solve(rhs, backend="jnp", method="bicgstab",
                                     tol=1e-13, maxiter=8000, precond="amg")
        return jnp.sum(x ** 3)

    def loss_dense(val, rhs):
        return jnp.sum(jnp.linalg.solve(B.with_values(val).todense(),
                                        rhs) ** 3)

    g = jax.grad(loss, (0, 1))(B.val, b)
    gd = jax.grad(loss_dense, (0, 1))(B.val, b)
    np.testing.assert_allclose(np.asarray(g[0]), np.asarray(gd[0]),
                               rtol=1e-6, atol=1e-8)
    np.testing.assert_allclose(np.asarray(g[1]), np.asarray(gd[1]),
                               rtol=1e-6, atol=1e-8)


def test_amg_jit_safe():
    """The numeric half (filtered weights, smoothing, Galerkin, coarse
    refactorization) runs under jit — the symbolic half stays eager."""
    A = poisson2d(10)
    b = jnp.ones(A.shape[0])
    f = jax.jit(lambda val, rhs: A.with_values(val).solve(
        rhs, backend="jnp", method="cg", tol=1e-11, precond="amg"))
    x = f(A.val, b)
    assert float(jnp.linalg.norm(A @ x - b)) < 1e-7


# ---------------------------------------------------------------------------
# the symbolic/numeric split itself (unit level)
# ---------------------------------------------------------------------------

def test_galerkin_program_matches_dense_triple_product():
    G = graph_laplacian(300, seed=2, shift=1e-2)
    r, c, n = np.asarray(G.row), np.asarray(G.col), G.shape[0]
    art = mg.amg_symbolic(r, c, n)
    state, C = mg.amg_numeric(art, G.val)
    lev = art.levels[0]
    aval, dinv, p_val = state[0]
    P = np.zeros((n, lev.n_c))
    P[np.asarray(lev.p_row), np.asarray(lev.p_col)] += np.asarray(p_val)
    Ad = np.asarray(G.todense())
    Ac_ref = P.T @ Ad @ P
    nxt = art.levels[1] if len(art.levels) > 1 else None
    if nxt is not None:
        Ac = np.zeros((lev.n_c, lev.n_c))
        np.add.at(Ac, (np.asarray(nxt.arow), np.asarray(nxt.acol)),
                  np.asarray(state[1][0]))
        np.testing.assert_allclose(Ac, Ac_ref, rtol=1e-10, atol=1e-12)


def test_amg_hierarchy_coarsens_geometrically():
    G = graph_laplacian(2000, seed=1)
    art = mg.amg_symbolic(np.asarray(G.row), np.asarray(G.col), G.shape[0])
    sizes = art.stats["sizes"]
    assert sizes[0] == 2000
    assert sizes[1] <= sizes[0] // 2          # real coarsening, level 1
    assert art.n_coarse <= 256                 # bottomed out in direct range


def test_shared_vcycle_driver_used_by_geometric_mg():
    """The geometric path now runs through the same Level/v_cycle
    abstraction as AMG (refactor regression)."""
    from repro.data.poisson import poisson2d_vc
    xs = jnp.linspace(0, 1, 16)
    X, Y = jnp.meshgrid(xs, xs, indexing="ij")
    kappa = 1.0 + 0.3 * jnp.sin(2 * jnp.pi * X) * jnp.sin(2 * jnp.pi * Y)
    pre = mg.MultigridPreconditioner(kappa)
    assert isinstance(pre._hier[0], mg.Level)
    assert pre._hier[-1].coarse_solve is not None
    r = jnp.ones(16 * 16)
    z = pre(r)
    assert z.shape == r.shape and bool(jnp.all(jnp.isfinite(z)))
