"""Geometric multigrid preconditioner (beyond-paper: the paper's §5 names
stronger-than-Jacobi preconditioning as future work)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dispatch import make_matvec
from repro.core.multigrid import make_mg_preconditioner
from repro.core.solvers import cg
from repro.data.poisson import poisson2d_vc


def _setup(ng):
    xs = jnp.linspace(0, 1, ng)
    X, Y = jnp.meshgrid(xs, xs, indexing="ij")
    kappa = 1.0 + 0.5 * jnp.sin(2 * jnp.pi * X) * jnp.sin(2 * jnp.pi * Y)
    A = poisson2d_vc(kappa)
    return kappa, A, make_matvec(A)


def test_mg_beats_jacobi_and_converges():
    kappa, A, mv = _setup(64)
    b = jnp.ones(A.shape[0])
    Mj = lambda r: r / A.diagonal()
    _, ij = cg(mv, b, M=Mj, tol=1e-10, maxiter=20000)
    Mg = make_mg_preconditioner(kappa)
    x, im = cg(mv, b, M=Mg, tol=1e-10, maxiter=500)
    assert bool(im.converged)
    assert float(jnp.linalg.norm(mv(x) - b)) < 1e-7
    assert int(im.iters) < int(ij.iters) / 5


def test_mg_iterations_h_independent():
    """The multigrid property: iterations ~constant as the grid refines
    (Jacobi-CG grows like √κ ~ n)."""
    iters = {}
    for ng in (32, 64, 128):
        kappa, A, mv = _setup(ng)
        Mg = make_mg_preconditioner(kappa)
        _, info = cg(mv, jnp.ones(A.shape[0]), M=Mg, tol=1e-9, maxiter=500)
        iters[ng] = int(info.iters)
        assert bool(info.converged)
    assert iters[128] <= 2 * iters[32] + 4, iters


def test_mg_inside_adjoint_solve():
    """MG-preconditioned solve composes with the O(1)-graph adjoint."""
    kappa, A, mv = _setup(32)
    b = jnp.ones(A.shape[0])
    Mg = make_mg_preconditioner(kappa)

    from repro.core import solvers

    def loss(val):
        A2 = A.with_values(val)
        mv2 = make_matvec(A2)
        # use the library CG directly with MG as M inside a custom adjoint
        from repro.core.dispatch import make_config
        from repro.core.adjoint import sparse_solve
        cfg = make_config(A2, backend="jnp", method="cg", tol=1e-12)
        x = sparse_solve(cfg, A2, b)
        return jnp.sum(x ** 2)

    g = jax.grad(loss)(A.val)
    assert bool(jnp.all(jnp.isfinite(g)))
