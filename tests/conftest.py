"""Test configuration.

x64 is enabled for solver accuracy tests (the paper's CPU baselines are
f64).  XLA_FLAGS / device count are NOT touched here — smoke tests must see
the real single CPU device; multi-device tests spawn subprocesses.
"""
import jax

jax.config.update("jax_enable_x64", True)
