"""Test configuration.

x64 is enabled for solver accuracy tests (the paper's CPU baselines are
f64).  XLA_FLAGS / device count are NOT touched here — smoke tests must see
the real single CPU device; multi-device tests spawn subprocesses.
"""
import jax
import pytest

jax.config.update("jax_enable_x64", True)

# Every live XLA:CPU executable holds ~50 anonymous memory mappings (LLVM
# ORC JIT code/data sections).  The full suite compiles enough distinct
# programs in one process to cross the kernel's vm.max_map_count (65530 by
# default), at which point mmap fails inside the JIT and the NEXT compile
# segfaults.  Dropping the compile caches releases the mappings (measured:
# 16k -> 0.5k), so bound the count here: check after each test, clear well
# below the kernel limit.  Costs nothing until triggered; when triggered the
# affected programs simply recompile on next use.
_MAPS_LIMIT = 30_000


@pytest.fixture(autouse=True)
def _bound_jit_map_count():
    yield
    try:
        with open("/proc/self/maps") as f:
            n_maps = sum(1 for _ in f)
    except OSError:          # non-Linux: no /proc, no known map-count limit
        return
    if n_maps > _MAPS_LIMIT:
        jax.clear_caches()
