"""Public API surface (repro.sla), options API, and deprecated aliases.

The surface snapshot is the contract: adding or removing a public name must
be a deliberate edit to EXPECTED_SURFACE here (and to docs/api.md via
tools/gen_api_ref.py), never an accident.  These are also the ONLY tests
allowed to touch the deprecated dispatch globals.
"""
import warnings

import jax.numpy as jnp
import numpy as np
import pytest

import repro
from repro import sla
from repro.core import dispatch
from repro.core import options as _options
from repro.data.poisson import poisson2d

# the checked-in public surface — keep sorted
EXPECTED_SURFACE = sorted([
    "DSparseTensor",
    "Options",
    "PLAN_STATS",
    "SolveResult",
    "SolveServer",
    "SolverConfig",
    "SolverPlan",
    "SparseNewton",
    "SparseTensor",
    "eigsh",
    "get_options",
    "nonlinear_solve",
    "get_plan",
    "options",
    "register_backend",
    "reset_plan_stats",
    "serve",
    "set_options",
    "solve",
    "solve_with_info",
])


# ---------------------------------------------------------------------------
# surface snapshot
# ---------------------------------------------------------------------------

def test_api_surface_snapshot():
    assert sorted(sla.__all__) == EXPECTED_SURFACE


def test_api_surface_resolvable_and_documented():
    for name in sla.__all__:
        obj = getattr(sla, name)     # lazy names must resolve too
        assert obj is not None
        if callable(obj) and not isinstance(obj, dict):
            assert getattr(obj, "__doc__", None), f"{name} lacks a docstring"


def test_repro_reexports_sla():
    assert repro.sla is sla
    assert "sla" in repro.__all__


# ---------------------------------------------------------------------------
# options API
# ---------------------------------------------------------------------------

def test_set_options_roundtrip():
    base = sla.get_options()
    try:
        new = sla.set_options(fused_step="off", direct_budget=1234)
        assert new.fused_step == "off" and new.direct_budget == 1234
        assert sla.get_options() is new
    finally:
        sla.set_options(fused_step=base.fused_step,
                        direct_budget=base.direct_budget)
    assert sla.get_options().fused_step == base.fused_step


def test_options_context_scoped_and_exception_safe():
    base = sla.get_options()
    with sla.options(dense_budget=7):
        assert sla.get_options().dense_budget == 7
        with sla.options(dense_budget=9):     # nesting: innermost wins
            assert sla.get_options().dense_budget == 9
        assert sla.get_options().dense_budget == 7
    assert sla.get_options().dense_budget == base.dense_budget
    with pytest.raises(RuntimeError):
        with sla.options(dense_budget=7):
            raise RuntimeError("boom")
    assert sla.get_options().dense_budget == base.dense_budget


def test_options_validation():
    with pytest.raises(ValueError):
        sla.set_options(fused_step="maybe")
    with pytest.raises(ValueError):
        sla.set_options(plan_cache_cap=0)
    with pytest.raises(ValueError):
        sla.set_options(bell_min_fill=2.0)
    with pytest.raises(TypeError):
        sla.set_options(not_an_option=1)


def test_env_var_parsing():
    parsed = _options._parse_env({
        "REPRO_SLA_FUSED_STEP": "OFF",
        "REPRO_SLA_PLAN_CACHE_BYTES": "1e8",
        "REPRO_SLA_DIRECT_BUDGET": "50000",
        "UNRELATED": "x",
    })
    assert parsed == {"fused_step": "off", "plan_cache_bytes": 10 ** 8,
                      "direct_budget": 50000}
    assert _options._parse_env({"REPRO_SLA_PLAN_CACHE_BYTES": "none"}) == \
        {"plan_cache_bytes": None}
    with pytest.raises(ValueError):
        _options._parse_env({"REPRO_SLA_TYPO": "1"})


def test_options_read_at_use_time():
    """Budgets apply at dispatch time, not frozen at import/plan time."""
    A = poisson2d(8)    # n=64: auto → dense under the default budget
    assert dispatch.select_backend(A, "auto", "auto")[0] == "dense"
    with sla.options(dense_budget=1, direct_budget=1):
        assert dispatch.select_backend(A, "auto", "auto")[0] == "jnp"


# ---------------------------------------------------------------------------
# deprecated aliases (the ONLY tests that may touch them)
# ---------------------------------------------------------------------------

@pytest.fixture()
def _fresh_warn_state():
    saved = set(_options._warned)
    _options._warned.clear()
    yield
    _options._warned.clear()
    _options._warned.update(saved)


def test_deprecated_global_read_warns_once(_fresh_warn_state):
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        v = dispatch.DIRECT_BUDGET
        assert v == sla.get_options().direct_budget
        _ = dispatch.DIRECT_BUDGET
    deps = [x for x in w if issubclass(x.category, DeprecationWarning)]
    assert len(deps) == 1, [str(x.message) for x in w]
    assert "direct_budget" in str(deps[0].message)


def test_deprecated_global_write_warns_and_forwards(_fresh_warn_state):
    base = sla.get_options().fused_step
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        dispatch.FUSED_STEP = "off"
    try:
        assert sla.get_options().fused_step == "off"
        assert dispatch.FUSED_STEP == "off"
    finally:
        sla.set_options(fused_step=base)
    deps = [x for x in w if issubclass(x.category, DeprecationWarning)]
    assert len(deps) == 1
    assert "fused_step" in str(deps[0].message)


def test_new_plan_cache_bytes_alias(_fresh_warn_state):
    base = sla.get_options().plan_cache_bytes
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        dispatch.PLAN_CACHE_BYTES = 12345
    try:
        assert sla.get_options().plan_cache_bytes == 12345
    finally:
        sla.set_options(plan_cache_bytes=base)
    assert any(issubclass(x.category, DeprecationWarning) for x in w)


def test_unknown_dispatch_attribute_still_raises():
    with pytest.raises(AttributeError):
        dispatch.NO_SUCH_KNOB


# ---------------------------------------------------------------------------
# typed results
# ---------------------------------------------------------------------------

def test_solve_result_fields_iterative():
    A = poisson2d(8)
    b = jnp.ones(A.shape[0])
    res = sla.solve_with_info(A, b, backend="jnp", method="cg", tol=1e-10)
    assert isinstance(res, sla.SolveResult)
    assert res._fields == ("x", "iterations", "residual", "converged",
                           "reason")
    assert res.reason == "converged" and bool(res.converged)
    assert float(res.residual) <= 1e-10 * np.linalg.norm(np.asarray(b)) * 1.01
    x_ref = np.linalg.solve(np.asarray(A.todense()), np.asarray(b))
    np.testing.assert_allclose(np.asarray(res.x), x_ref, rtol=1e-8)


def test_solve_result_fields_direct_and_dense():
    A = poisson2d(8)
    b = jnp.ones(A.shape[0])
    for backend in ("direct", "dense"):
        res = sla.solve_with_info(A, b, backend=backend)
        assert isinstance(res, sla.SolveResult)
        assert res.reason == "converged", (backend, res)


def test_solve_result_maxiter_reason():
    A = poisson2d(8)
    b = jnp.ones(A.shape[0])
    res = sla.solve_with_info(A, b, backend="jnp", method="cg", tol=1e-14,
                              maxiter=2)
    assert res.reason == "maxiter" and not bool(res.converged)
