"""Solver correctness vs scipy/dense references (paper Table 3 behaviours)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
import scipy.sparse as sp
import scipy.sparse.linalg as spla

from repro.core import SparseTensor, solvers
from repro.core.dispatch import make_config, select_backend
from repro.core import precond
from repro.data.poisson import poisson1d, poisson2d


@pytest.fixture(scope="module")
def A2d():
    return poisson2d(16)   # 256 dof


def to_scipy(A):
    return sp.coo_matrix((np.asarray(A.val), (np.asarray(A.row),
                                              np.asarray(A.col))),
                         shape=A.shape).tocsr()


def test_cg_matches_scipy(A2d):
    b = np.random.default_rng(0).normal(size=A2d.shape[0])
    x_ref = spla.spsolve(to_scipy(A2d), b)
    x = A2d.solve(jnp.asarray(b), backend="jnp", method="cg", tol=1e-12)
    np.testing.assert_allclose(np.asarray(x), x_ref, rtol=1e-8)


def _convection_diffusion(n, c=0.3):
    """tridiag(−1−c, 2, −1+c): non-symmetric, positive spectrum."""
    A1 = poisson1d(n)
    val = np.asarray(A1.val).copy()
    val[np.asarray(A1.col) == np.asarray(A1.row) - 1] = -1.0 - c
    val[np.asarray(A1.col) == np.asarray(A1.row) + 1] = -1.0 + c
    return SparseTensor(val, A1.row, A1.col, (n, n))


def test_bicgstab_nonsymmetric():
    rng = np.random.default_rng(1)
    n = 80
    A = _convection_diffusion(n)
    assert not A.props["symmetric"]
    b = rng.normal(size=n)
    x = A.solve(jnp.asarray(b), backend="jnp", method="bicgstab", tol=1e-12,
                maxiter=4000)
    np.testing.assert_allclose(np.asarray(A @ x), b, atol=1e-8)


def test_gmres():
    rng = np.random.default_rng(2)
    n = 60
    A = _convection_diffusion(n, c=0.4)
    b = rng.normal(size=n)
    x = A.solve(jnp.asarray(b), backend="jnp", method="gmres", tol=1e-10,
                maxiter=2000)
    np.testing.assert_allclose(np.asarray(A @ x), b, atol=1e-6)


def test_dense_backend_cholesky(A2d):
    b = np.random.default_rng(3).normal(size=A2d.shape[0])
    x = A2d.solve(jnp.asarray(b), backend="dense", method="cholesky")
    np.testing.assert_allclose(np.asarray(A2d @ x), b, atol=1e-9)


def test_auto_dispatch_policy(A2d):
    # small SPD → dense cholesky
    b, m = select_backend(A2d, "auto", "auto")
    assert (b, m) == ("dense", "cholesky")
    # mid-size → sparse-direct LDLᵀ (cached symbolic factorization)
    mid = poisson2d(80)    # 6400: DENSE_BUDGET < n ≤ DIRECT_BUDGET
    b2, m2 = select_backend(mid, "auto", "auto")
    assert (b2, m2) == ("direct", "ldlt")
    # large → iterative cg (symmetric)
    big = poisson2d(320)   # 102400 > DIRECT_BUDGET (raised to 10⁵ with the
    b3, m3 = select_backend(big, "auto", "auto")   # supernodal panel kernels)
    assert (b3, m3) == ("jnp", "cg")
    # ... unless the caller hints ill-conditioning (Krylov stalls there)
    big.props["illcond_hint"] = True
    b4, m4 = select_backend(big, "auto", "auto")
    assert (b4, m4) == ("direct", "ldlt")
    # explicit override honored
    b5, m5 = select_backend(A2d, "jnp", "bicgstab")
    assert (b5, m5) == ("jnp", "bicgstab")


def test_batched_shared_pattern_solve(A2d):
    rng = np.random.default_rng(4)
    vals = jnp.stack([A2d.val, A2d.val * 2.0])
    Ab = SparseTensor(vals, A2d.row, A2d.col, A2d.shape, props=A2d.props)
    bs = jnp.asarray(rng.normal(size=(2, A2d.shape[0])))
    xs = Ab.solve(bs, backend="jnp", method="cg", tol=1e-12)
    for i, scale in enumerate((1.0, 2.0)):
        Ai = SparseTensor(np.asarray(A2d.val) * scale, A2d.row, A2d.col,
                          A2d.shape)
        np.testing.assert_allclose(np.asarray(Ai @ xs[i]), np.asarray(bs[i]),
                                   atol=1e-8)


@pytest.mark.parametrize("name", ["jacobi", "block_jacobi", "chebyshev"])
def test_preconditioners_accelerate(A2d, name):
    b = jnp.ones(A2d.shape[0])
    from repro.core.dispatch import make_matvec
    mv = make_matvec(A2d)
    M = precond.make_preconditioner(name, A2d, mv)
    x, info = solvers.cg(mv, b, M=M, tol=1e-10, maxiter=2000)
    x0, info0 = solvers.cg(mv, b, tol=1e-10, maxiter=2000)
    assert bool(info.converged)
    assert float(jnp.linalg.norm(A2d @ x - b)) < 1e-7
    if name != "jacobi":   # Poisson diagonal is constant → jacobi = identity
        assert int(info.iters) <= int(info0.iters)


def test_nonlinear_newton_picard_anderson():
    n = 32
    A = poisson1d(n)
    b = jnp.linspace(0.5, 1.5, n)

    def F(u):
        return A @ u + 0.1 * u ** 3 - b

    for method, tol in (("newton", 1e-12), ("picard", 1e-10),
                        ("anderson", 1e-10)):
        if method == "newton":
            u, info = solvers.newton_solve(F, jnp.zeros(n), tol=tol)
        elif method == "picard":
            u, info = solvers.picard_solve(lambda u: u - 0.2 * F(u),
                                           jnp.zeros(n), tol=tol, maxiter=5000)
        else:
            u, info = solvers.anderson_solve(lambda u: u - 0.2 * F(u),
                                             jnp.zeros(n), tol=tol,
                                             maxiter=2000)
        assert float(jnp.linalg.norm(F(u))) < 1e-6, method


def aniso_poisson2d(ng, cy=0.6):
    """2D Poisson with anisotropic y-coupling — breaks the square-grid
    eigenvalue degeneracy (the paper targets simple eigenvalues, §5)."""
    A = poisson2d(ng)
    val = np.asarray(A.val).copy()
    row, col = np.asarray(A.row), np.asarray(A.col)
    y_edge = np.abs(row - col) == 1
    val[y_edge] *= cy
    val[row == col] = 2.0 + 2.0 * cy
    return SparseTensor(val, row, col, A.shape)


@pytest.mark.known_failing
def test_lobpcg_and_lanczos_eigenvalues():
    A = aniso_poisson2d(10)
    w_ref = np.sort(np.linalg.eigvalsh(np.asarray(A.todense())))
    w, V = A.eigsh(k=4, method="lobpcg", tol=1e-11, maxiter=2000)
    np.testing.assert_allclose(np.asarray(w), w_ref[:4], atol=1e-7)
    # residuals ‖Av − λv‖ small
    for i in range(4):
        r = A @ V[i] - w[i] * V[i]
        assert float(jnp.linalg.norm(r)) < 1e-6
    w2, V2 = A.eigsh(k=3, method="lanczos")
    np.testing.assert_allclose(np.asarray(w2), w_ref[:3], atol=1e-6)


def test_largest_eigenpairs():
    A = aniso_poisson2d(8)
    w_ref = np.sort(np.linalg.eigvalsh(np.asarray(A.todense())))
    from repro.core.adjoint import sparse_eigsh
    w, V = sparse_eigsh(A, 2, largest=True, tol=1e-11, maxiter=1500,
                        compute_vector_grads=False)
    np.testing.assert_allclose(np.sort(np.asarray(w)), w_ref[-2:], atol=1e-6)


def test_solve_info_reports_convergence():
    A = poisson2d(12)
    from repro.core.adjoint import sparse_solve_with_info
    cfg = make_config(A, backend="jnp", method="cg", tol=1e-10)
    x, info = sparse_solve_with_info(cfg, A, jnp.ones(A.shape[0]))
    assert bool(info.converged)
    assert int(info.iters) > 0
    assert float(info.resnorm) < 1e-7 * np.linalg.norm(np.ones(A.shape[0])) * 10


# ---------------------------------------------------------------------------
# nonlinear fixed-point solvers: property-based coverage + the PR-10
# Anderson least-squares regression
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hypothesis_compat import given, settings, st


def _contraction(seed, n, L):
    """Random affine map G(x) = c + M x with ‖M‖₂ = L < 1 — the Banach
    fixed point x* = (I − M)⁻¹ c is unique and known."""
    rng = np.random.default_rng(seed)
    M = rng.normal(size=(n, n))
    M *= L / np.linalg.norm(M, 2)
    c = rng.normal(size=n)
    x_star = np.linalg.solve(np.eye(n) - M, c)
    Mj, cj = jnp.asarray(M), jnp.asarray(c)
    return (lambda x: cj + Mj @ x), x_star


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000), n=st.integers(2, 24),
       L=st.floats(0.05, 0.9))
def test_picard_converges_on_random_contractions(seed, n, L):
    G, x_star = _contraction(seed, n, L)
    tol = 1e-10
    x, info = solvers.picard_solve(G, jnp.zeros(n), tol=tol, maxiter=5000)
    assert bool(info.converged) == bool(float(info.resnorm) <= tol)
    assert bool(info.converged)
    np.testing.assert_allclose(np.asarray(x), x_star, atol=1e-8)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000), n=st.integers(2, 24),
       L=st.floats(0.05, 0.9), m=st.integers(1, 12))
def test_anderson_converges_on_random_contractions(seed, n, L, m):
    G, x_star = _contraction(seed, n, L)
    tol = 1e-10
    x, info = solvers.anderson_solve(G, jnp.zeros(n), m=m, tol=tol,
                                     maxiter=2000)
    assert bool(info.converged) == bool(float(info.resnorm) <= tol)
    assert bool(info.converged), (seed, n, L, m)
    np.testing.assert_allclose(np.asarray(x), x_star, atol=1e-7)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000), n=st.integers(2, 12))
def test_anderson_degenerate_windows_no_nan(seed, n):
    """m > iteration count AND duplicate residual columns (an affine map on
    a rank-1 M makes successive differences collinear): the window's Gram
    matrix is singular from step one — the pinv path must stay finite."""
    rng = np.random.default_rng(seed)
    u = rng.normal(size=n)
    v = rng.normal(size=n)
    M = 0.5 * np.outer(u, v) / (np.linalg.norm(u) * np.linalg.norm(v))
    c = rng.normal(size=n)
    x_star = np.linalg.solve(np.eye(n) - M, c)
    Mj, cj = jnp.asarray(M), jnp.asarray(c)
    G = lambda x: cj + Mj @ x
    # window far larger than the iterations the solve will ever take
    x, info = solvers.anderson_solve(G, jnp.zeros(n), m=4 * n, tol=1e-11,
                                     maxiter=500)
    assert bool(jnp.all(jnp.isfinite(x)))
    assert bool(info.converged) == bool(float(info.resnorm) <= 1e-11)
    np.testing.assert_allclose(np.asarray(x), x_star, atol=1e-8)


def test_anderson_f32_pinv_regression():
    """PR-10 bugfix: the fixed-ridge (1e-12) Gram solve underflows f32
    roundoff (~1e-7·‖G‖ ≫ ridge), producing NaN iterates on large-scale
    rank-deficient windows; the relative-cutoff eigh pseudo-inverse (the
    same ``eigh_pinv_solve`` block_cg uses) stays finite and converges.
    ``gram_solver="ridge"`` is kept only as the A/B baseline."""
    rng = np.random.default_rng(0)
    n, m = 6, 8
    M = rng.normal(size=(n, n)).astype(np.float32)
    M = 0.5 * M / np.linalg.norm(M, 2)
    U, S, Vt = np.linalg.svd(M)
    S[2:] = 0.0                      # rank-2: degenerate difference window
    M = (U * S) @ Vt
    c = (rng.normal(size=n) * 1e3).astype(np.float32)   # amplify roundoff
    x_star = np.linalg.solve(np.eye(n) - M, c)
    Mj, cj = jnp.asarray(M, jnp.float32), jnp.asarray(c, jnp.float32)
    G = lambda x: cj + Mj @ x
    x0 = jnp.zeros(n, jnp.float32)

    x_old, _ = solvers.anderson_solve(G, x0, m=m, tol=1e-3, maxiter=100,
                                      gram_solver="ridge")
    assert not bool(jnp.all(jnp.isfinite(x_old)))       # the old path fails

    x_new, info = solvers.anderson_solve(G, x0, m=m, tol=1e-3, maxiter=100)
    assert bool(jnp.all(jnp.isfinite(x_new)))
    assert bool(info.converged)
    np.testing.assert_allclose(np.asarray(x_new), x_star, atol=1e-2)

    with pytest.raises(ValueError, match="gram_solver"):
        solvers.anderson_solve(G, x0, gram_solver="qr")


def test_eigh_pinv_solve_relative_cutoff():
    rng = np.random.default_rng(5)
    Q, _ = np.linalg.qr(rng.normal(size=(6, 6)))
    w = np.array([1e4, 2e3, 50.0, 1.0, 1e-12, 0.0])    # hard rank-4 @ f64
    G = jnp.asarray((Q * w) @ Q.T)
    y = jnp.asarray(rng.normal(size=6))
    rhs = G @ y
    x = solvers.eigh_pinv_solve(G, rhs)
    # exact on range(G), zero on the null space
    np.testing.assert_allclose(np.asarray(G @ x), np.asarray(rhs), atol=1e-6)
    null = jnp.asarray(Q[:, 4:])
    assert float(jnp.linalg.norm(null.T @ x)) < 1e-8
    # multi-rhs shape
    X = solvers.eigh_pinv_solve(G, jnp.stack([rhs, rhs], 1))
    assert X.shape == (6, 2)
