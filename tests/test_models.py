"""Per-architecture smoke tests: reduced same-family config, one forward +
one train step on CPU; output shapes + no NaNs.  Plus decode↔forward
consistency for one arch per family."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, smoke_variant
from repro.models import transformer as T
from repro.optim.adamw import AdamWConfig, init_opt_state
from repro.launch.train import make_train_step


def _batch(cfg, B=2, S=24, seed=0):
    toks = jax.random.randint(jax.random.PRNGKey(seed), (B, S), 0, cfg.vocab)
    batch = {"tokens": toks, "labels": toks}
    if cfg.vis_patches:
        P = cfg.vis_patches
        batch["patches"] = jnp.zeros((B, P, cfg.d_model), jnp.dtype(cfg.dtype))
        batch["labels"] = jnp.concatenate(
            [-jnp.ones((B, P), jnp.int32), toks], axis=1)
    if cfg.enc_dec:
        batch["enc_frames"] = jax.random.normal(
            jax.random.PRNGKey(7), (B, cfg.enc_frames, cfg.d_model),
            jnp.dtype(cfg.dtype))
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_forward_and_train_step(arch):
    cfg = smoke_variant(get_config(arch))
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg)
    logits, aux = T.forward(params, cfg, batch["tokens"],
                            patches=batch.get("patches"),
                            enc_frames=batch.get("enc_frames"))
    B = batch["tokens"].shape[0]
    S_total = batch["labels"].shape[1]
    assert logits.shape == (B, S_total, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))

    step = jax.jit(make_train_step(cfg, AdamWConfig(lr=1e-3, warmup_steps=1,
                                                    total_steps=10)))
    state = {"params": params, "opt": init_opt_state(params)}
    state, metrics = step(state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    # params actually changed
    delta = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))),
                         state["params"], params)
    assert max(jax.tree.leaves(delta)) > 0


@pytest.mark.parametrize("arch", ["llama3.2-1b", "recurrentgemma-2b",
                                  "mamba2-780m", "granite-moe-1b-a400m",
                                  "whisper-medium"])
def test_decode_matches_forward(arch):
    cfg = smoke_variant(get_config(arch))
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    B, S = 2, 20
    batch = _batch(cfg, B=B, S=S)
    logits, _ = T.forward(params, cfg, batch["tokens"],
                          enc_frames=batch.get("enc_frames"))
    state = T.init_decode_state(params, cfg, B, S,
                                enc_frames=batch.get("enc_frames"))
    outs = []
    for t in range(S):
        lg, state = T.decode_step(params, cfg, state,
                                  batch["tokens"][:, t:t + 1], jnp.array(t))
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(logits),
                               atol=5e-4)


def test_local_attention_ring_cache_beyond_window():
    """Decode past the window: ring cache must equal full forward with the
    local mask (the long_500k mechanism)."""
    cfg = smoke_variant(get_config("recurrentgemma-2b"))
    assert cfg.window == 16
    params = T.init_params(cfg, jax.random.PRNGKey(1))
    B, S = 1, 40            # > 2× window
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0, cfg.vocab)
    logits, _ = T.forward(params, cfg, toks)
    state = T.init_decode_state(params, cfg, B, S)
    # cache capacity capped at the window
    caps = [v.shape[2] for k, v in jax.tree_util.tree_flatten_with_path(
        state)[0] if "k" == str(getattr(k[-1], "key", ""))]
    assert caps and all(c <= cfg.window for c in caps)
    outs = []
    for t in range(S):
        lg, state = T.decode_step(params, cfg, state, toks[:, t:t + 1],
                                  jnp.array(t))
        outs.append(lg[:, 0])
    np.testing.assert_allclose(np.asarray(jnp.stack(outs, 1)),
                               np.asarray(logits), atol=5e-4)


def test_param_count_analytic_vs_actual():
    for arch in ("llama3.2-1b", "mamba2-780m", "granite-moe-1b-a400m"):
        cfg = smoke_variant(get_config(arch))
        params = T.init_params(cfg, jax.random.PRNGKey(0))
        actual = sum(x.size for x in jax.tree.leaves(params))
        analytic = cfg.param_count()
        assert abs(actual - analytic) / actual < 0.35, (arch, actual, analytic)


def test_param_axes_cover_all_leaves():
    cfg = smoke_variant(get_config("dbrx-132b"))
    shapes = T.param_shapes(cfg)
    axes = T.param_axes(shapes)
    is_ax = lambda x: isinstance(x, tuple) and all(
        isinstance(i, (str, type(None))) for i in x)
    ax_leaves = jax.tree_util.tree_flatten(axes, is_leaf=is_ax)[0]
    shape_leaves = jax.tree_util.tree_flatten(shapes)[0]
    assert len(ax_leaves) == len(shape_leaves)
    for ax, leaf in zip(ax_leaves, shape_leaves):
        assert len(ax) == leaf.ndim
