"""Fallback property-testing shim for environments without ``hypothesis``.

Import sites do::

    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:
        from _hypothesis_compat import given, settings, st

When hypothesis is missing, ``@given`` degrades to a deterministic sweep of a
few seeded samples per strategy — far weaker than real shrinking/coverage,
but the property tests still collect and run on a bare environment instead of
erroring the whole suite.  Only the strategy surface this repo uses is
implemented (integers, floats, sampled_from, booleans, keyword-style given).
"""
from __future__ import annotations

import random

_FALLBACK_EXAMPLES = 5


class _Strategy:
    def __init__(self, sample):
        self.sample = sample


class st:
    @staticmethod
    def integers(min_value, max_value):
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    @staticmethod
    def floats(min_value, max_value):
        return _Strategy(lambda rng: rng.uniform(min_value, max_value))

    @staticmethod
    def sampled_from(elements):
        elements = list(elements)
        return _Strategy(lambda rng: rng.choice(elements))

    @staticmethod
    def booleans():
        return _Strategy(lambda rng: rng.random() < 0.5)


def settings(max_examples=_FALLBACK_EXAMPLES, **_ignored):
    """No-op stand-in: records a (capped) example budget on the test."""
    def deco(fn):
        fn._max_examples = min(max_examples, _FALLBACK_EXAMPLES)
        return fn
    return deco


def given(**strategies):
    """Keyword-argument ``@given``: runs the test body over seeded draws.

    The wrapper deliberately exposes a ZERO-argument signature (no
    ``functools.wraps``/``__wrapped__``) so pytest does not mistake the
    strategy parameters for fixtures."""
    def deco(fn):
        def wrapper():
            n = getattr(wrapper, "_max_examples", _FALLBACK_EXAMPLES)
            rng = random.Random(0)
            for _ in range(n):
                draw = {k: s.sample(rng) for k, s in strategies.items()}
                fn(**draw)
        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        return wrapper
    return deco
