"""Distributed-layer tests.  Multi-device cases run in a subprocess with 8
forced host devices (XLA device count locks at first jax use, so the main
test process must keep its single real device)."""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_forced(src: str, n_dev: int = 8) -> str:
    env = dict(os.environ,
               XLA_FLAGS=f"--xla_force_host_platform_device_count={n_dev}",
               PYTHONPATH=os.path.join(REPO, "src"))
    proc = subprocess.run([sys.executable, "-c", src], capture_output=True,
                          text=True, env=env, timeout=900)
    assert proc.returncode == 0, proc.stderr[-4000:]
    return proc.stdout


PREAMBLE = """
import jax, numpy as np, jax.numpy as jnp
jax.config.update("jax_enable_x64", True)
from jax.sharding import Mesh
from repro.core import PLAN_STATS, reset_plan_stats
from repro.core.distributed import (DSparseTensor, DSparseTensorList,
                                    halo_exchange, partition_simple,
                                    partition_coordinate, pipelined_cg)
from repro.core.sparse import SparseTensor
from repro.data.poisson import poisson1d

n = 192
A1 = poisson1d(n)
vals, rows, cols = np.asarray(A1.val), np.asarray(A1.row), np.asarray(A1.col)
mesh = jax.make_mesh((8,), ("data",))
D = DSparseTensor.from_global(vals, rows, cols, (n, n), mesh)
As = SparseTensor(vals, rows, cols, (n, n))
b = np.linspace(0.5, 1.5, n)
bs = D.stack_vector(b)
"""


def test_distributed_solve_matches_single_device():
    out = run_forced(PREAMBLE + textwrap.dedent("""
        x = D.gather_global(D.solve(bs, tol=1e-12, maxiter=4000))
        x_ref = np.asarray(As.solve(jnp.asarray(b), backend="jnp",
                                    method="cg", tol=1e-12, maxiter=4000))
        print("ERR", np.abs(x - x_ref).max() / np.abs(x_ref).max())
    """))
    assert float(out.split("ERR")[1]) < 1e-9


def test_distributed_matvec_and_halo_adjoint():
    out = run_forced(PREAMBLE + textwrap.dedent("""
        # matvec
        xt = np.random.default_rng(0).normal(size=n)
        yd = D.gather_global(D.matvec(D.stack_vector(xt)))
        ys = np.asarray(As @ jnp.asarray(xt))
        print("MV", np.abs(yd - ys).max())

        # halo exchange: Hᵀ is the true adjoint (⟨Hx, y⟩ = ⟨x, Hᵀy⟩)
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        from functools import partial
        n_loc = n // 8
        @partial(shard_map, mesh=mesh, in_specs=P("data"), out_specs=P("data"),
                 check_rep=False)
        def H(x):
            return halo_exchange(x, 2, 3, "data")
        x = jnp.asarray(np.random.default_rng(1).normal(size=n))
        y = jnp.asarray(np.random.default_rng(2).normal(size=8 * (n_loc + 5)))
        Hx = H(x)
        lhs = float(jnp.vdot(Hx, y))
        g = jax.vjp(H, x)[1](y)[0]
        rhs = float(jnp.vdot(x, g))
        print("ADJ", abs(lhs - rhs) / abs(lhs))
    """))
    assert float(out.split("MV")[1].split()[0]) < 1e-12
    assert float(out.split("ADJ")[1]) < 1e-12


def test_distributed_gradients_match_single_device():
    out = run_forced(PREAMBLE + textwrap.dedent("""
        def loss_dist(lval, bstack):
            return jnp.sum(D.with_values(lval).solve(bstack, tol=1e-13,
                                                     maxiter=4000) ** 2)
        gd_val, gd_b = jax.grad(loss_dist, (0, 1))(D.lval, bs)
        def loss_single(v, bb):
            x = As.with_values(v).solve(bb, backend="jnp", method="cg",
                                        tol=1e-13, maxiter=4000)
            return jnp.sum(x ** 2)
        gs_val, gs_b = jax.grad(loss_single, (0, 1))(jnp.asarray(vals),
                                                     jnp.asarray(b))
        bounds = partition_simple(n, 8)
        gv = np.zeros(len(vals))
        for q in range(8):
            s, e = bounds[q], bounds[q + 1]
            m = (rows >= s) & (rows < e)
            gv[m] = np.asarray(gd_val)[q][:m.sum()]
        rel = np.abs(gv - np.asarray(gs_val)) / (np.abs(gs_val) + 1e-30)
        print("GV", rel.max())
        print("GB", np.abs(D.gather_global(gd_b) - np.asarray(gs_b)).max()
              / np.abs(np.asarray(gs_b)).max())
    """))
    assert float(out.split("GV")[1].split()[0]) < 1e-9
    assert float(out.split("GB")[1]) < 1e-9


def test_pipelined_cg_and_compressed_halo():
    out = run_forced(PREAMBLE + textwrap.dedent("""
        xp = D.gather_global(D.solve(bs, tol=1e-11, maxiter=4000,
                                     pipelined=True))
        res = np.abs(np.asarray(As @ jnp.asarray(xp)) - b).max()
        print("PIPE", res)

        # compressed halo exchange: int8 payload, own values exact
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        from functools import partial
        from repro.optim.compress import compressed_halo_exchange
        @partial(shard_map, mesh=mesh, in_specs=P("data"), out_specs=P("data"),
                 check_rep=False)
        def Hq(x):
            return compressed_halo_exchange(x, 1, 1, "data")
        @partial(shard_map, mesh=mesh, in_specs=P("data"), out_specs=P("data"),
                 check_rep=False)
        def H(x):
            return halo_exchange(x, 1, 1, "data")
        x = jnp.asarray(np.random.default_rng(3).normal(size=n))
        err = jnp.abs(Hq(x) - H(x))
        print("CQ", float(jnp.max(err)), float(jnp.max(jnp.abs(x))))
    """))
    assert float(out.split("PIPE")[1].split()[0]) < 1e-7
    parts = out.split("CQ")[1].split()
    err, scale = float(parts[0]), float(parts[1])
    assert err <= scale / 127.0 + 1e-9     # int8 quantization bound


def test_distributed_eigsh():
    out = run_forced(PREAMBLE + textwrap.dedent("""
        w, V = D.eigsh(k=3, tol=1e-10, maxiter=3000)
        wr = np.sort(np.linalg.eigvalsh(np.asarray(As.todense())))[:3]
        print("EW", np.abs(np.asarray(w) - wr).max())
    """))
    assert float(out.split("EW")[1]) < 1e-7


def test_partition_utilities():
    from repro.core.distributed import partition_coordinate, partition_simple
    b = partition_simple(103, 8)
    assert b[0] == 0 and b[-1] == 103 and len(b) == 9
    assert (np.diff(b) >= 103 // 8).all()
    rng = np.random.default_rng(0)
    coords = rng.normal(size=(64, 2))
    perm = partition_coordinate(coords, 4)
    assert sorted(perm.tolist()) == list(range(64))


def test_nonsymmetric_distributed_solve():
    out = run_forced(PREAMBLE + textwrap.dedent("""
        v2 = vals.copy()
        v2[cols == rows - 1] = -1.3
        v2[cols == rows + 1] = -0.7
        Dn = DSparseTensor.from_global(v2, rows, cols, (n, n), mesh)
        assert not Dn.meta.symmetric
        xs = Dn.solve(Dn.stack_vector(b), tol=1e-11, maxiter=6000)
        An = SparseTensor(v2, rows, cols, (n, n))
        res = np.abs(np.asarray(An @ jnp.asarray(Dn.gather_global(xs))) - b).max()
        print("NS", res)
        # gradient through the plan's Aᵀ-partition adjoint
        def loss(lval):
            return jnp.sum(Dn.with_values(lval).solve(
                Dn.stack_vector(b), tol=1e-12, maxiter=6000) ** 2)
        g = jax.grad(loss)(Dn.lval)
        def loss_s(v):
            x = An.with_values(v).solve(jnp.asarray(b), backend="jnp",
                                        method="bicgstab", tol=1e-12,
                                        maxiter=6000)
            return jnp.sum(x ** 2)
        gs = jax.grad(loss_s)(jnp.asarray(v2))
        bounds = partition_simple(n, 8)
        gv = np.zeros(len(v2))
        for q in range(8):
            s, e = bounds[q], bounds[q + 1]
            m = (rows >= s) & (rows < e)
            gv[m] = np.asarray(g)[q][:m.sum()]
        rel = np.abs(gv - np.asarray(gs)) / (np.abs(np.asarray(gs)).max())
        print("NG", rel.max())
    """))
    assert float(out.split("NS")[1].split()[0]) < 1e-7
    assert float(out.split("NG")[1]) < 1e-6


# ---------------------------------------------------------------------------
# plan-engine path (PR 3): analyze-once across sweeps, backward, with_values
# ---------------------------------------------------------------------------

def test_distributed_plan_reuse_counters():
    """A tolerance sweep (3 solves) + one backward on a NON-symmetric
    DSparseTensor performs exactly ONE analyze and builds the Aᵀ partition
    once; the per-values setup memo serves the repeat solves."""
    out = run_forced(PREAMBLE + textwrap.dedent("""
        v2 = vals.copy()
        v2[cols == rows - 1] = -1.3
        v2[cols == rows + 1] = -0.7
        Dn = DSparseTensor.from_global(v2, rows, cols, (n, n), mesh)
        bn = Dn.stack_vector(b)
        reset_plan_stats()
        for tol in (1e-4, 1e-8, 1e-11):
            Dn.solve(bn, tol=tol, maxiter=6000)
        jax.grad(lambda lv: jnp.sum(Dn.with_values(lv).solve(
            bn, tol=1e-11, maxiter=6000) ** 2))(Dn.lval)
        print("ANALYZE", PLAN_STATS["analyze"])
        print("TPART", PLAN_STATS["t_partition"])
        print("HITS", PLAN_STATS["cache_hit"])
        print("REUSE", PLAN_STATS["setup_reuse"])
        print("TSHARED", PLAN_STATS["transpose_shared"])
    """))
    assert int(out.split("ANALYZE")[1].split()[0]) == 1, out
    assert int(out.split("TPART")[1].split()[0]) == 1, out
    assert int(out.split("HITS")[1].split()[0]) >= 3, out
    assert int(out.split("REUSE")[1].split()[0]) >= 2, out
    assert int(out.split("TSHARED")[1].split()[0]) == 1, out


def test_distributed_with_values_shares_plan_cache():
    """with_values views re-solve without re-analyzing, and the symmetric
    backward adds zero analyzes (transpose is the same plan object)."""
    out = run_forced(PREAMBLE + textwrap.dedent("""
        reset_plan_stats()
        x1 = D.solve(bs, tol=1e-10, maxiter=4000)
        x2 = D.with_values(2.0 * D.lval).solve(bs, tol=1e-10, maxiter=4000)
        jax.grad(lambda lv: jnp.sum(D.with_values(lv).solve(
            bs, tol=1e-12, maxiter=4000) ** 2))(D.lval)
        print("REL", float(jnp.abs(2.0 * x2 - x1).max() / jnp.abs(x1).max()))
        print("ANALYZE", PLAN_STATS["analyze"])
        print("TSHARED", PLAN_STATS["transpose_shared"])
    """))
    assert float(out.split("REL")[1].split()[0]) < 1e-8
    assert int(out.split("ANALYZE")[1].split()[0]) == 1, out
    assert int(out.split("TSHARED")[1].split()[0]) == 1, out


def test_nonsymmetric_distributed_gradcheck_vs_dense_adjoint():
    """Distributed non-symmetric gradient against the DENSE autodiff adjoint
    (jnp.linalg.solve), not just the single-device sparse path."""
    out = run_forced(PREAMBLE + textwrap.dedent("""
        v2 = vals.copy()
        v2[cols == rows - 1] = -1.4
        v2[cols == rows + 1] = -0.6
        Dn = DSparseTensor.from_global(v2, rows, cols, (n, n), mesh)
        bn = Dn.stack_vector(b)
        g = jax.grad(lambda lv: jnp.sum(Dn.with_values(lv).solve(
            bn, tol=1e-13, maxiter=8000) ** 2))(Dn.lval)
        def loss_dense(v):
            dense = jnp.zeros((n, n)).at[rows, cols].add(v)
            return jnp.sum(jnp.linalg.solve(dense, jnp.asarray(b)) ** 2)
        gd = jax.grad(loss_dense)(jnp.asarray(v2))
        bounds = partition_simple(n, 8)
        gv = np.zeros(len(v2))
        for q in range(8):
            s, e = bounds[q], bounds[q + 1]
            m = (rows >= s) & (rows < e)
            gv[m] = np.asarray(g)[q][:m.sum()]
        print("DG", (np.abs(gv - np.asarray(gd))
                     / np.abs(np.asarray(gd)).max()).max())
    """))
    assert float(out.split("DG")[1]) < 1e-6


def test_schwarz_converges_in_fewer_iterations_than_jacobi():
    """precond='schwarz' (shard-local overlapping Schwarz, ILU(0) subdomain
    solves on the direct machinery) beats point Jacobi on the 2-shard
    Poisson problem — strictly fewer CG iterations at the same tolerance."""
    out = run_forced(PREAMBLE + textwrap.dedent("""
        mesh2 = jax.sharding.Mesh(np.array(jax.devices()[:2]), ("data",))
        D2 = DSparseTensor.from_global(vals, rows, cols, (n, n), mesh2)
        b2 = D2.stack_vector(b)
        xj, ij = D2.solve_with_info(b2, tol=1e-10, maxiter=4000)
        xs, isz = D2.solve_with_info(b2, tol=1e-10, maxiter=4000,
                                     precond="schwarz")
        print("JIT", int(ij.iters), bool(ij.converged))
        print("SIT", int(isz.iters), bool(isz.converged))
        print("SRES", float(jnp.abs(jnp.asarray(
            As @ jnp.asarray(D2.gather_global(xs))) - jnp.asarray(b)).max()))
    """))
    jit_, jconv = out.split("JIT")[1].split()[:2]
    sit, sconv = out.split("SIT")[1].split()[:2]
    assert jconv == "True" and sconv == "True"
    assert int(sit) < int(jit_), (sit, jit_)
    assert float(out.split("SRES")[1]) < 1e-7


def test_schwarz_distributed_gradients():
    """Gradients flow through a schwarz-preconditioned distributed solve
    (the preconditioner state is setup(values) output, not traced-through)."""
    out = run_forced(PREAMBLE + textwrap.dedent("""
        g = jax.grad(lambda lv: jnp.sum(D.with_values(lv).solve(
            bs, tol=1e-13, maxiter=4000, precond="schwarz") ** 2))(D.lval)
        def loss_single(v):
            x = As.with_values(v).solve(jnp.asarray(b), backend="jnp",
                                        method="cg", tol=1e-13, maxiter=4000)
            return jnp.sum(x ** 2)
        gs = jax.grad(loss_single)(jnp.asarray(vals))
        bounds = partition_simple(n, 8)
        gv = np.zeros(len(vals))
        for q in range(8):
            s, e = bounds[q], bounds[q + 1]
            m = (rows >= s) & (rows < e)
            gv[m] = np.asarray(g)[q][:m.sum()]
        print("SG", (np.abs(gv - np.asarray(gs))
                     / np.abs(np.asarray(gs)).max()).max())
    """))
    assert float(out.split("SG")[1]) < 1e-8


def test_two_level_schwarz_beats_one_level_and_scales():
    """precond='schwarz2' (symmetric deflated two-level: aggregated global
    coarse matrix, cached direct factors) needs FEWER CG iterations than
    one-level schwarz at 8 shards on 2-D Poisson, and its count grows
    sublinearly from 2 → 8 shards."""
    out = run_forced(PREAMBLE + textwrap.dedent("""
        from repro.data.poisson import poisson2d
        ng = 48
        A2 = poisson2d(ng)
        n2 = ng * ng
        v2, r2, c2 = (np.asarray(A2.val), np.asarray(A2.row),
                      np.asarray(A2.col))
        b2v = np.random.default_rng(3).normal(size=n2)
        its = {}
        for p in (2, 8):
            meshp = jax.sharding.Mesh(np.array(jax.devices()[:p]), ("data",))
            Dp = DSparseTensor.from_global(v2, r2, c2, (n2, n2), meshp)
            bp = Dp.stack_vector(b2v)
            _, i1 = Dp.solve_with_info(bp, tol=1e-8, maxiter=4000,
                                       precond="schwarz")
            _, i2 = Dp.solve_with_info(bp, tol=1e-8, maxiter=4000,
                                       precond="schwarz2")
            assert bool(i1.converged) and bool(i2.converged)
            its[p] = (int(i1.iters), int(i2.iters))
        print("IT2", its[2][0], its[2][1])
        print("IT8", its[8][0], its[8][1])
    """))
    one2, two2 = map(int, out.split("IT2")[1].split()[:2])
    one8, two8 = map(int, out.split("IT8")[1].split()[:2])
    assert two8 < one8, (two8, one8)                 # two-level wins at P=8
    # sublinear growth 2 → 8 shards (4× shards, far less than 4× iters)
    assert two8 <= 2 * two2, (two2, two8)


def test_two_level_schwarz_gradients_and_plan_reuse():
    """Gradients flow through schwarz2 (replicated coarse factor rides the
    shard_map state), match the single-device reference, and the sweep +
    backward still analyze once."""
    out = run_forced(PREAMBLE + textwrap.dedent("""
        reset_plan_stats()
        for tol in (1e-6, 1e-10):
            D.solve(bs, tol=tol, maxiter=4000, precond="schwarz2")
        g = jax.grad(lambda lv: jnp.sum(D.with_values(lv).solve(
            bs, tol=1e-13, maxiter=4000, precond="schwarz2") ** 2))(D.lval)
        print("ANALYZE", PLAN_STATS["analyze"])
        print("REUSE", PLAN_STATS["setup_reuse"])
        def loss_single(v):
            x = As.with_values(v).solve(jnp.asarray(b), backend="jnp",
                                        method="cg", tol=1e-13, maxiter=4000)
            return jnp.sum(x ** 2)
        gs = jax.grad(loss_single)(jnp.asarray(vals))
        bounds = partition_simple(n, 8)
        gv = np.zeros(len(vals))
        for q in range(8):
            s, e = bounds[q], bounds[q + 1]
            m = (rows >= s) & (rows < e)
            gv[m] = np.asarray(g)[q][:m.sum()]
        print("SG", (np.abs(gv - np.asarray(gs))
                     / np.abs(np.asarray(gs)).max()).max())
    """))
    assert int(out.split("ANALYZE")[1].split()[0]) == 1, out
    assert int(out.split("REUSE")[1].split()[0]) >= 1, out
    assert float(out.split("SG")[1]) < 1e-8


def test_dsparse_list_shared_pattern_single_analysis():
    """DSparseTensorList members sharing one partitioned pattern route
    through ONE plan (a single analyze serves the whole batch)."""
    out = run_forced(PREAMBLE + textwrap.dedent("""
        batch = DSparseTensorList([D, D.with_values(2.0 * D.lval),
                                   D.with_values(0.5 * D.lval)])
        reset_plan_stats()
        xs = batch.solve([bs, bs, bs], tol=1e-11, maxiter=4000)
        print("ANALYZE", PLAN_STATS["analyze"])
        for s, x in zip((1.0, 2.0, 0.5), xs):
            r = np.abs(s * np.asarray(As @ jnp.asarray(D.gather_global(x)))
                       - b).max()
            assert r < 1e-7, (s, r)
        print("LIST_OK")
    """))
    assert int(out.split("ANALYZE")[1].split()[0]) == 1, out
    assert "LIST_OK" in out


def test_distributed_slogdet_gather_fallback():
    """slogdet gathers to one host, rebuilds a SparseTensor, delegates —
    within DIRECT_BUDGET that is now the sparse cached-LDLᵀ path (no
    densification; PLAN_STATS['factorize'] proves it), and the gather is
    still warned about."""
    out = run_forced(PREAMBLE + textwrap.dedent("""
        import warnings
        reset_plan_stats()
        with warnings.catch_warnings(record=True) as rec:
            warnings.simplefilter("always")
            sign, logabs = D.slogdet()
        assert any("slogdet" in str(w.message) for w in rec), rec
        print("FACT", PLAN_STATS["factorize"])
        sr, lr_ = np.linalg.slogdet(np.asarray(As.todense()))
        print("SLD", abs(float(sign) - sr) + abs(float(logabs) - lr_) /
              abs(lr_))
    """))
    assert int(out.split("FACT")[1].split()[0]) == 1, out   # LDLᵀ, not dense
    assert float(out.split("SLD")[1]) < 1e-10
