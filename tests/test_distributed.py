"""Distributed-layer tests.  Multi-device cases run in a subprocess with 8
forced host devices (XLA device count locks at first jax use, so the main
test process must keep its single real device)."""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_forced(src: str, n_dev: int = 8) -> str:
    env = dict(os.environ,
               XLA_FLAGS=f"--xla_force_host_platform_device_count={n_dev}",
               PYTHONPATH=os.path.join(REPO, "src"))
    proc = subprocess.run([sys.executable, "-c", src], capture_output=True,
                          text=True, env=env, timeout=900)
    assert proc.returncode == 0, proc.stderr[-4000:]
    return proc.stdout


PREAMBLE = """
import jax, numpy as np, jax.numpy as jnp
jax.config.update("jax_enable_x64", True)
from jax.sharding import Mesh
from repro.core.distributed import (DSparseTensor, halo_exchange,
                                    partition_simple, partition_coordinate,
                                    pipelined_cg)
from repro.core.sparse import SparseTensor
from repro.data.poisson import poisson1d

n = 192
A1 = poisson1d(n)
vals, rows, cols = np.asarray(A1.val), np.asarray(A1.row), np.asarray(A1.col)
mesh = jax.make_mesh((8,), ("data",),
                     axis_types=(jax.sharding.AxisType.Auto,))
D = DSparseTensor.from_global(vals, rows, cols, (n, n), mesh)
As = SparseTensor(vals, rows, cols, (n, n))
b = np.linspace(0.5, 1.5, n)
bs = D.stack_vector(b)
"""


@pytest.mark.known_failing
def test_distributed_solve_matches_single_device():
    out = run_forced(PREAMBLE + textwrap.dedent("""
        x = D.gather_global(D.solve(bs, tol=1e-12, maxiter=4000))
        x_ref = np.asarray(As.solve(jnp.asarray(b), backend="jnp",
                                    method="cg", tol=1e-12, maxiter=4000))
        print("ERR", np.abs(x - x_ref).max() / np.abs(x_ref).max())
    """))
    assert float(out.split("ERR")[1]) < 1e-9


@pytest.mark.known_failing
def test_distributed_matvec_and_halo_adjoint():
    out = run_forced(PREAMBLE + textwrap.dedent("""
        # matvec
        xt = np.random.default_rng(0).normal(size=n)
        yd = D.gather_global(D.matvec(D.stack_vector(xt)))
        ys = np.asarray(As @ jnp.asarray(xt))
        print("MV", np.abs(yd - ys).max())

        # halo exchange: Hᵀ is the true adjoint (⟨Hx, y⟩ = ⟨x, Hᵀy⟩)
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        from functools import partial
        n_loc = n // 8
        @partial(shard_map, mesh=mesh, in_specs=P("data"), out_specs=P("data"),
                 check_rep=False)
        def H(x):
            return halo_exchange(x, 2, 3, "data")
        x = jnp.asarray(np.random.default_rng(1).normal(size=n))
        y = jnp.asarray(np.random.default_rng(2).normal(size=8 * (n_loc + 5)))
        Hx = H(x)
        lhs = float(jnp.vdot(Hx, y))
        g = jax.vjp(H, x)[1](y)[0]
        rhs = float(jnp.vdot(x, g))
        print("ADJ", abs(lhs - rhs) / abs(lhs))
    """))
    assert float(out.split("MV")[1].split()[0]) < 1e-12
    assert float(out.split("ADJ")[1]) < 1e-12


@pytest.mark.known_failing
def test_distributed_gradients_match_single_device():
    out = run_forced(PREAMBLE + textwrap.dedent("""
        def loss_dist(lval, bstack):
            A2 = DSparseTensor(D.meta, lval, D.lrow, D.lcol, D.mesh)
            return jnp.sum(A2.solve(bstack, tol=1e-13, maxiter=4000) ** 2)
        gd_val, gd_b = jax.grad(loss_dist, (0, 1))(D.lval, bs)
        def loss_single(v, bb):
            x = As.with_values(v).solve(bb, backend="jnp", method="cg",
                                        tol=1e-13, maxiter=4000)
            return jnp.sum(x ** 2)
        gs_val, gs_b = jax.grad(loss_single, (0, 1))(jnp.asarray(vals),
                                                     jnp.asarray(b))
        bounds = partition_simple(n, 8)
        gv = np.zeros(len(vals))
        for q in range(8):
            s, e = bounds[q], bounds[q + 1]
            m = (rows >= s) & (rows < e)
            gv[m] = np.asarray(gd_val)[q][:m.sum()]
        rel = np.abs(gv - np.asarray(gs_val)) / (np.abs(gs_val) + 1e-30)
        print("GV", rel.max())
        print("GB", np.abs(D.gather_global(gd_b) - np.asarray(gs_b)).max()
              / np.abs(np.asarray(gs_b)).max())
    """))
    assert float(out.split("GV")[1].split()[0]) < 1e-9
    assert float(out.split("GB")[1]) < 1e-9


@pytest.mark.known_failing
def test_pipelined_cg_and_compressed_halo():
    out = run_forced(PREAMBLE + textwrap.dedent("""
        xp = D.gather_global(D.solve(bs, tol=1e-11, maxiter=4000,
                                     pipelined=True))
        res = np.abs(np.asarray(As @ jnp.asarray(xp)) - b).max()
        print("PIPE", res)

        # compressed halo exchange: int8 payload, own values exact
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        from functools import partial
        from repro.optim.compress import compressed_halo_exchange
        @partial(shard_map, mesh=mesh, in_specs=P("data"), out_specs=P("data"),
                 check_rep=False)
        def Hq(x):
            return compressed_halo_exchange(x, 1, 1, "data")
        @partial(shard_map, mesh=mesh, in_specs=P("data"), out_specs=P("data"),
                 check_rep=False)
        def H(x):
            return halo_exchange(x, 1, 1, "data")
        x = jnp.asarray(np.random.default_rng(3).normal(size=n))
        err = jnp.abs(Hq(x) - H(x))
        print("CQ", float(jnp.max(err)), float(jnp.max(jnp.abs(x))))
    """))
    assert float(out.split("PIPE")[1].split()[0]) < 1e-7
    parts = out.split("CQ")[1].split()
    err, scale = float(parts[0]), float(parts[1])
    assert err <= scale / 127.0 + 1e-9     # int8 quantization bound


@pytest.mark.known_failing
def test_distributed_eigsh():
    out = run_forced(PREAMBLE + textwrap.dedent("""
        w, V = DSparseTensor(D.meta, D.lval, D.lrow, D.lcol, D.mesh).eigsh(
            k=3, tol=1e-10, maxiter=3000)
        wr = np.sort(np.linalg.eigvalsh(np.asarray(As.todense())))[:3]
        print("EW", np.abs(np.asarray(w) - wr).max())
    """))
    assert float(out.split("EW")[1]) < 1e-7


def test_partition_utilities():
    from repro.core.distributed import partition_coordinate, partition_simple
    b = partition_simple(103, 8)
    assert b[0] == 0 and b[-1] == 103 and len(b) == 9
    assert (np.diff(b) >= 103 // 8).all()
    rng = np.random.default_rng(0)
    coords = rng.normal(size=(64, 2))
    perm = partition_coordinate(coords, 4)
    assert sorted(perm.tolist()) == list(range(64))


@pytest.mark.known_failing
def test_nonsymmetric_distributed_solve():
    out = run_forced(PREAMBLE + textwrap.dedent("""
        v2 = vals.copy()
        v2[cols == rows - 1] = -1.3
        v2[cols == rows + 1] = -0.7
        Dn = DSparseTensor.from_global(v2, rows, cols, (n, n), mesh)
        assert not Dn.meta.symmetric
        xs = Dn.solve(Dn.stack_vector(b), tol=1e-11, maxiter=6000)
        An = SparseTensor(v2, rows, cols, (n, n))
        res = np.abs(np.asarray(An @ jnp.asarray(Dn.gather_global(xs))) - b).max()
        print("NS", res)
        # gradient through the transposed-partition adjoint
        def loss(lval):
            A2 = DSparseTensor(Dn.meta, lval, Dn.lrow, Dn.lcol, Dn.mesh,
                               Dn.lval_t, Dn.lrow_t, Dn.lcol_t)
            return jnp.sum(A2.solve(Dn.stack_vector(b), tol=1e-12,
                                    maxiter=6000) ** 2)
        g = jax.grad(loss)(Dn.lval)
        def loss_s(v):
            x = An.with_values(v).solve(jnp.asarray(b), backend="jnp",
                                        method="bicgstab", tol=1e-12,
                                        maxiter=6000)
            return jnp.sum(x ** 2)
        gs = jax.grad(loss_s)(jnp.asarray(v2))
        from repro.core.distributed import partition_simple
        bounds = partition_simple(n, 8)
        gv = np.zeros(len(v2))
        for q in range(8):
            s, e = bounds[q], bounds[q + 1]
            m = (rows >= s) & (rows < e)
            gv[m] = np.asarray(g)[q][:m.sum()]
        rel = np.abs(gv - np.asarray(gs)) / (np.abs(np.asarray(gs)).max())
        print("NG", rel.max())
    """))
    assert float(out.split("NS")[1].split()[0]) < 1e-7
    assert float(out.split("NG")[1]) < 1e-6
