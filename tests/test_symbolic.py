"""Symbolic-analysis quality and cost — the quotient-graph AMD ordering and
the etree fill pass (ISSUE 5 acceptance).

Covers: AMD fill-in within 25% of exact minimum degree on the suite
matrices (2-D Poisson stencils, random-geometric graph Laplacians);
bit-level validity of the AMD permutation (supervariable/mass-elimination
bookkeeping); identical solve results to 1e-8 vs dense for both orderings;
the analyze-cost regression bound at n = 10⁴ (the seed exact-MD pipeline
took ~14 s; the AMD + etree + vectorized-emission pipeline must stay an
order of magnitude under it); and the plan-counter regression proving ONE
analyze keeps serving each consumer of ``symbolic_factor`` — the direct
backend + slogdet sharing a plan, ``precond="ilu"``, and the AMG coarsest
level — unchanged across forward + backward sweeps.
"""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (SparseTensor, PLAN_STATS, make_config,
                        reset_plan_stats)
from repro.core import dispatch
from repro.core.direct import (_amd_order, symbolic_factor, numeric_factor,
                               factored_solve)
from repro.data.graphs import graph_laplacian
from repro.data.poisson import poisson2d


SUITE = [
    ("poisson2d_30", lambda: poisson2d(30)),
    ("poisson2d_50", lambda: poisson2d(50)),
    ("graph_laplacian_1000", lambda: graph_laplacian(1000, seed=0)),
    ("graph_laplacian_3000", lambda: graph_laplacian(3000, seed=1)),
]


# ---------------------------------------------------------------------------
# ordering quality: AMD fill within 25% of exact minimum degree
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name,make", SUITE, ids=[s[0] for s in SUITE])
def test_amd_fill_within_25pct_of_exact_md(name, make):
    A = make()
    r, c, n = np.asarray(A.row), np.asarray(A.col), A.shape[0]
    amd = symbolic_factor(r, c, n, ordering="amd")
    md = symbolic_factor(r, c, n, ordering="md")
    ratio = amd.stats["nnz_L"] / max(md.stats["nnz_L"], 1)
    assert ratio <= 1.25, (name, amd.stats["nnz_L"], md.stats["nnz_L"])


def test_amd_perm_is_valid_permutation():
    """Supervariable merging + mass elimination must not lose or duplicate
    variables, including on patterns with many indistinguishable columns
    (a block pattern is the classic supervariable trigger)."""
    rng = np.random.default_rng(0)
    # dense 4x4 blocks on a ring: every block column is indistinguishable
    nb, bs = 12, 4
    n = nb * bs
    rows, cols = [], []
    for b in range(nb):
        for b2 in (b, (b + 1) % nb, (b - 1) % nb):
            i, j = np.meshgrid(np.arange(bs), np.arange(bs))
            rows.append((b * bs + i).ravel())
            cols.append((b2 * bs + j).ravel())
    row = np.concatenate(rows)
    col = np.concatenate(cols)
    perm = _amd_order(row, col, n)
    assert sorted(perm.tolist()) == list(range(n))
    # random patterns too (full diagonal, symmetrized inside)
    for trial in range(3):
        n = int(rng.integers(5, 60))
        nnz = int(rng.integers(n, 4 * n))
        r = np.concatenate([np.arange(n), rng.integers(0, n, nnz)])
        c = np.concatenate([np.arange(n), rng.integers(0, n, nnz)])
        perm = _amd_order(r, c, n)
        assert sorted(perm.tolist()) == list(range(n)), trial


@pytest.mark.parametrize("ordering", ["amd", "md"])
def test_orderings_solve_to_1e8_vs_dense(ordering):
    for name, make in SUITE[:3]:            # keep runtime modest
        A = make()
        n = A.shape[0]
        b = jnp.asarray(np.random.default_rng(7).normal(size=n))
        art = symbolic_factor(np.asarray(A.row), np.asarray(A.col), n,
                              ordering=ordering)
        x = factored_solve(art, numeric_factor(art, A.val), b)
        xd = jnp.linalg.solve(A.todense(), b)
        np.testing.assert_allclose(np.asarray(x), np.asarray(xd),
                                   rtol=1e-8, atol=1e-8, err_msg=name)


def test_incomplete_resolves_degree_orderings_to_natural():
    A = poisson2d(8)
    for ordering in ("amd", "md"):
        art = symbolic_factor(np.asarray(A.row), np.asarray(A.col),
                              A.shape[0], ordering=ordering, incomplete=True)
        assert art.stats["ordering"] == "natural"
        assert art.stats["fill_ratio"] == pytest.approx(1.0)


# ---------------------------------------------------------------------------
# analyze cost: the n = 10⁴ regression bound (seed exact-MD path: ~14 s)
# ---------------------------------------------------------------------------

def test_analyze_cost_n1e4_order_of_magnitude_under_seed():
    A = poisson2d(100)                      # 10⁴ DOF
    r, c = np.asarray(A.row), np.asarray(A.col)
    t0 = time.perf_counter()
    art = symbolic_factor(r, c, A.shape[0])
    dt = time.perf_counter() - t0
    # the seed exact-MD pipeline measured 14.3 s here; the AMD + etree +
    # vectorized-emission pipeline measures ~1.2 s.  6 s keeps 5× headroom
    # for slow CI boxes while still failing on any O(n·fill) regression.
    assert dt < 6.0, f"symbolic analyze took {dt:.1f}s at n=1e4"
    assert art.stats["ordering"] == "amd"
    # the fill must stay in the AMD quality regime, not blow up silently
    assert art.stats["nnz_L"] < 300_000, art.stats


# ---------------------------------------------------------------------------
# plan-counter regression: one analyze per consumer, unchanged
# ---------------------------------------------------------------------------

def test_one_analyze_serves_direct_solve_and_slogdet():
    A = poisson2d(14)                       # fresh pattern
    b = jnp.ones(A.shape[0])
    reset_plan_stats()
    for tol in (1e-4, 1e-10):
        A.solve(b, backend="direct", tol=tol)
    A.slogdet()                             # rides the SAME plan + factors
    jax.grad(lambda v: jnp.sum(A.with_values(v).solve(
        b, backend="direct") ** 2))(A.val)
    assert PLAN_STATS["analyze"] == 1, PLAN_STATS
    assert PLAN_STATS["factorize"] == 1, PLAN_STATS


def test_one_analyze_serves_ilu_forward_and_backward():
    A = poisson2d(14)
    b = jnp.ones(A.shape[0])
    cfg = make_config(A, backend="jnp", method="cg", tol=1e-12,
                      precond="ilu")
    reset_plan_stats()
    x, _ = dispatch.solve_impl(cfg, A, b)
    jax.grad(lambda v: jnp.sum(A.with_values(v).solve(
        b, backend="jnp", method="cg", tol=1e-12, precond="ilu") ** 2))(A.val)
    assert PLAN_STATS["analyze"] == 1, PLAN_STATS
    assert float(jnp.linalg.norm(A @ x - b)) < 1e-8


def test_one_analyze_serves_amg_coarsest_level():
    G = graph_laplacian(600, seed=2)
    b = jnp.asarray(np.random.default_rng(3).normal(size=G.shape[0]))
    reset_plan_stats()
    for tol in (1e-6, 1e-10):               # sweep reuses one plan
        x = G.solve(b, backend="jnp", method="cg", tol=tol, precond="amg")
    assert PLAN_STATS["analyze"] == 1, PLAN_STATS
    assert PLAN_STATS["coarsen"] == 1, PLAN_STATS
    assert float(jnp.linalg.norm(G @ x - b)) < 1e-6
