"""Pallas-kernel validation: hypothesis sweeps over shapes/dtypes, allclose
against the ref.py pure-jnp oracles (interpret mode on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:       # bare env: seeded-sweep fallback, suite still collects
    from _hypothesis_compat import given, settings, st

from repro.core.sparse import build_bell, coo_matvec
from repro.kernels import ops
from repro.kernels.stencil5 import Stencil5Meta


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == np.float16 else (
        dict(rtol=2e-5, atol=2e-5) if dtype == np.float32 else
        dict(rtol=1e-12, atol=1e-12))


@settings(max_examples=15, deadline=None)
@given(nx=st.integers(3, 70), ny=st.integers(3, 300),
       dtype=st.sampled_from([np.float32, np.float64]),
       seed=st.integers(0, 99))
def test_stencil5_kernel_sweep(nx, ny, dtype, seed):
    rng = np.random.default_rng(seed)
    val5 = rng.normal(size=(5, nx, ny)).astype(dtype)
    val5[1, 0, :] = 0; val5[2, -1, :] = 0
    val5[3, :, 0] = 0; val5[4, :, -1] = 0
    x = rng.normal(size=(nx * ny,)).astype(dtype)
    meta = Stencil5Meta(nx=nx, ny=ny)
    v = jnp.asarray(val5.reshape(-1))
    xk = jnp.asarray(x)
    y_k = ops.stencil5_matvec(meta, v, xk)
    y_r = ops.stencil5_matvec_ref(meta, v, xk)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r), **_tol(dtype))


@settings(max_examples=15, deadline=None)
@given(n=st.integers(4, 200), m=st.integers(4, 300),
       density=st.floats(0.01, 0.3),
       dtype=st.sampled_from([np.float32, np.float64]),
       seed=st.integers(0, 99))
def test_bell_kernel_sweep(n, m, density, dtype, seed):
    rng = np.random.default_rng(seed)
    nnz = max(1, int(n * m * density))
    row = rng.integers(0, n, nnz)
    col = rng.integers(0, m, nnz)
    keys = np.unique(row.astype(np.int64) * m + col)
    row = (keys // m).astype(np.int32)
    col = (keys % m).astype(np.int32)
    val = rng.normal(size=len(row)).astype(dtype)
    meta, bcols, perm = build_bell(row, col, (n, m))
    v, x = jnp.asarray(val), jnp.asarray(rng.normal(size=m).astype(dtype))
    y_k = ops.bell_matvec(meta, bcols, perm, v, x, n)
    y_r = ops.bell_matvec_ref(meta, bcols, perm, v, x, n)
    y_c = coo_matvec(v, jnp.asarray(row), jnp.asarray(col), x, n)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r), **_tol(dtype))
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_c), **_tol(dtype))


def test_stencil5_gradients_vs_ref():
    rng = np.random.default_rng(0)
    nx, ny = 21, 83
    val5 = rng.normal(size=(5, nx, ny))
    val5[1, 0, :] = 0; val5[2, -1, :] = 0
    val5[3, :, 0] = 0; val5[4, :, -1] = 0
    v = jnp.asarray(val5.reshape(-1))
    x = jnp.asarray(rng.normal(size=nx * ny))
    w = jnp.asarray(rng.normal(size=nx * ny))
    meta = Stencil5Meta(nx=nx, ny=ny)
    Lk = lambda vv, xx: jnp.sum(w * ops.stencil5_matvec(meta, vv, xx))
    Lr = lambda vv, xx: jnp.sum(w * ops.stencil5_matvec_ref(meta, vv, xx))
    gk = jax.grad(Lk, (0, 1))(v, x)
    gr = jax.grad(Lr, (0, 1))(v, x)
    np.testing.assert_allclose(np.asarray(gk[0]), np.asarray(gr[0]), rtol=1e-10)
    np.testing.assert_allclose(np.asarray(gk[1]), np.asarray(gr[1]), rtol=1e-10)


def test_bell_gradients_vs_coo():
    rng = np.random.default_rng(1)
    n, m = 120, 90
    nnz = 900
    row = rng.integers(0, n, nnz)
    col = rng.integers(0, m, nnz)
    keys = np.unique(row.astype(np.int64) * m + col)
    row = (keys // m).astype(np.int32)
    col = (keys % m).astype(np.int32)
    val = jnp.asarray(rng.normal(size=len(row)))
    x = jnp.asarray(rng.normal(size=m))
    w = jnp.asarray(rng.normal(size=n))
    meta, bcols, perm = build_bell(row, col, (n, m))
    Lk = lambda v, xx: jnp.sum(w * ops.bell_matvec(meta, bcols, perm, v, xx, n))
    Lc = lambda v, xx: jnp.sum(w * coo_matvec(v, jnp.asarray(row),
                                              jnp.asarray(col), xx, n))
    gk = jax.grad(Lk, (0, 1))(val, x)
    gc = jax.grad(Lc, (0, 1))(val, x)
    np.testing.assert_allclose(np.asarray(gk[0]), np.asarray(gc[0]), rtol=1e-10)
    np.testing.assert_allclose(np.asarray(gk[1]), np.asarray(gc[1]), rtol=1e-10)


def test_bell_fill_and_padding_invariants():
    """BELL layout bookkeeping: every COO entry lands in exactly one slot."""
    rng = np.random.default_rng(2)
    n = m = 64
    row = rng.integers(0, n, 300)
    col = rng.integers(0, m, 300)
    keys = np.unique(row.astype(np.int64) * m + col)
    row = (keys // m).astype(np.int32)
    col = (keys % m).astype(np.int32)
    meta, bcols, perm = build_bell(row, col, (n, m))
    p = np.asarray(perm)
    kept = p[p >= 0]
    assert len(np.unique(kept)) == len(kept)           # injective
    assert meta.fill <= 1.0
    assert kept.max() < meta.n_rb * meta.k * meta.bm * meta.bn


def test_stencil_solve_path_matches_jnp():
    """End-to-end: stencil-kernel CG solve == COO CG solve."""
    from repro.data.poisson import poisson2d_vc
    ng = 24
    kappa = jnp.asarray(1.0 + 0.3 * np.random.default_rng(3).random((ng, ng)))
    f = jnp.ones(ng * ng)
    A_k = poisson2d_vc(kappa, use_stencil_kernel=True)
    A_j = poisson2d_vc(kappa, use_stencil_kernel=False)
    x_k = A_k.solve(f, backend="stencil", method="cg", tol=1e-12)
    x_j = A_j.solve(f, backend="jnp", method="cg", tol=1e-12)
    np.testing.assert_allclose(np.asarray(x_k), np.asarray(x_j), rtol=1e-8)
