"""Pallas-kernel validation: hypothesis sweeps over shapes/dtypes, allclose
against the ref.py pure-jnp oracles (interpret mode on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:       # bare env: seeded-sweep fallback, suite still collects
    from _hypothesis_compat import given, settings, st

from repro.core.sparse import build_bell, coo_matvec
from repro.kernels import ops
from repro.kernels.stencil5 import Stencil5Meta


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == np.float16 else (
        dict(rtol=2e-5, atol=2e-5) if dtype == np.float32 else
        dict(rtol=1e-12, atol=1e-12))


@settings(max_examples=15, deadline=None)
@given(nx=st.integers(3, 70), ny=st.integers(3, 300),
       dtype=st.sampled_from([np.float32, np.float64]),
       seed=st.integers(0, 99))
def test_stencil5_kernel_sweep(nx, ny, dtype, seed):
    rng = np.random.default_rng(seed)
    val5 = rng.normal(size=(5, nx, ny)).astype(dtype)
    val5[1, 0, :] = 0; val5[2, -1, :] = 0
    val5[3, :, 0] = 0; val5[4, :, -1] = 0
    x = rng.normal(size=(nx * ny,)).astype(dtype)
    meta = Stencil5Meta(nx=nx, ny=ny)
    v = jnp.asarray(val5.reshape(-1))
    xk = jnp.asarray(x)
    y_k = ops.stencil5_matvec(meta, v, xk)
    y_r = ops.stencil5_matvec_ref(meta, v, xk)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r), **_tol(dtype))


@settings(max_examples=15, deadline=None)
@given(n=st.integers(4, 200), m=st.integers(4, 300),
       density=st.floats(0.01, 0.3),
       dtype=st.sampled_from([np.float32, np.float64]),
       seed=st.integers(0, 99))
def test_bell_kernel_sweep(n, m, density, dtype, seed):
    rng = np.random.default_rng(seed)
    nnz = max(1, int(n * m * density))
    row = rng.integers(0, n, nnz)
    col = rng.integers(0, m, nnz)
    keys = np.unique(row.astype(np.int64) * m + col)
    row = (keys // m).astype(np.int32)
    col = (keys % m).astype(np.int32)
    val = rng.normal(size=len(row)).astype(dtype)
    meta, bcols, perm = build_bell(row, col, (n, m))
    v, x = jnp.asarray(val), jnp.asarray(rng.normal(size=m).astype(dtype))
    y_k = ops.bell_matvec(meta, bcols, perm, v, x, n)
    y_r = ops.bell_matvec_ref(meta, bcols, perm, v, x, n)
    y_c = coo_matvec(v, jnp.asarray(row), jnp.asarray(col), x, n)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r), **_tol(dtype))
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_c), **_tol(dtype))


def test_stencil5_gradients_vs_ref():
    rng = np.random.default_rng(0)
    nx, ny = 21, 83
    val5 = rng.normal(size=(5, nx, ny))
    val5[1, 0, :] = 0; val5[2, -1, :] = 0
    val5[3, :, 0] = 0; val5[4, :, -1] = 0
    v = jnp.asarray(val5.reshape(-1))
    x = jnp.asarray(rng.normal(size=nx * ny))
    w = jnp.asarray(rng.normal(size=nx * ny))
    meta = Stencil5Meta(nx=nx, ny=ny)
    Lk = lambda vv, xx: jnp.sum(w * ops.stencil5_matvec(meta, vv, xx))
    Lr = lambda vv, xx: jnp.sum(w * ops.stencil5_matvec_ref(meta, vv, xx))
    gk = jax.grad(Lk, (0, 1))(v, x)
    gr = jax.grad(Lr, (0, 1))(v, x)
    np.testing.assert_allclose(np.asarray(gk[0]), np.asarray(gr[0]), rtol=1e-10)
    np.testing.assert_allclose(np.asarray(gk[1]), np.asarray(gr[1]), rtol=1e-10)


def test_bell_gradients_vs_coo():
    rng = np.random.default_rng(1)
    n, m = 120, 90
    nnz = 900
    row = rng.integers(0, n, nnz)
    col = rng.integers(0, m, nnz)
    keys = np.unique(row.astype(np.int64) * m + col)
    row = (keys // m).astype(np.int32)
    col = (keys % m).astype(np.int32)
    val = jnp.asarray(rng.normal(size=len(row)))
    x = jnp.asarray(rng.normal(size=m))
    w = jnp.asarray(rng.normal(size=n))
    meta, bcols, perm = build_bell(row, col, (n, m))
    Lk = lambda v, xx: jnp.sum(w * ops.bell_matvec(meta, bcols, perm, v, xx, n))
    Lc = lambda v, xx: jnp.sum(w * coo_matvec(v, jnp.asarray(row),
                                              jnp.asarray(col), xx, n))
    gk = jax.grad(Lk, (0, 1))(val, x)
    gc = jax.grad(Lc, (0, 1))(val, x)
    np.testing.assert_allclose(np.asarray(gk[0]), np.asarray(gc[0]), rtol=1e-10)
    np.testing.assert_allclose(np.asarray(gk[1]), np.asarray(gc[1]), rtol=1e-10)


def test_bell_fill_and_padding_invariants():
    """BELL layout bookkeeping: every COO entry lands in exactly one slot."""
    rng = np.random.default_rng(2)
    n = m = 64
    row = rng.integers(0, n, 300)
    col = rng.integers(0, m, 300)
    keys = np.unique(row.astype(np.int64) * m + col)
    row = (keys // m).astype(np.int32)
    col = (keys % m).astype(np.int32)
    meta, bcols, perm = build_bell(row, col, (n, m))
    p = np.asarray(perm)
    kept = p[p >= 0]
    assert len(np.unique(kept)) == len(kept)           # injective
    assert meta.fill <= 1.0
    assert kept.max() < meta.n_rb * meta.k * meta.bm * meta.bn


def test_stencil_solve_path_matches_jnp():
    """End-to-end: stencil-kernel CG solve == COO CG solve."""
    from repro.data.poisson import poisson2d_vc
    ng = 24
    kappa = jnp.asarray(1.0 + 0.3 * np.random.default_rng(3).random((ng, ng)))
    f = jnp.ones(ng * ng)
    A_k = poisson2d_vc(kappa, use_stencil_kernel=True)
    A_j = poisson2d_vc(kappa, use_stencil_kernel=False)
    x_k = A_k.solve(f, backend="stencil", method="cg", tol=1e-12)
    x_j = A_j.solve(f, backend="jnp", method="cg", tol=1e-12)
    np.testing.assert_allclose(np.asarray(x_k), np.asarray(x_j), rtol=1e-8)


def test_bell_empty_rows_and_cols():
    """Rows/cols with no entries: the BELL slot table must still produce
    exact zeros there, forward and transpose."""
    rng = np.random.default_rng(4)
    n, m = 200, 150
    # entries confined to a band; rows 0–9 and 180–199, cols 140–149 empty
    row = rng.integers(10, 180, 400).astype(np.int32)
    col = rng.integers(0, 140, 400).astype(np.int32)
    keys = np.unique(row.astype(np.int64) * m + col)
    row = (keys // m).astype(np.int32)
    col = (keys % m).astype(np.int32)
    val = jnp.asarray(rng.normal(size=len(row)))
    x = jnp.asarray(rng.normal(size=m))
    meta, bcols, perm = build_bell(row, col, (n, m))
    y = ops.bell_matvec(meta, bcols, perm, val, x, n)
    y_c = coo_matvec(val, jnp.asarray(row), jnp.asarray(col), x, n)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_c), atol=1e-12)
    assert float(jnp.abs(y[:10]).max()) == 0.0
    assert float(jnp.abs(y[180:]).max()) == 0.0
    # transpose layout (the kernel plan's t_bell): empty columns of A are
    # empty rows of Aᵀ
    tmeta, tbcols, tperm = build_bell(col, row, (m, n))
    g = jnp.asarray(rng.normal(size=n))
    yt = ops.bell_matvec(tmeta, tbcols, tperm, val, g, m)
    yt_c = coo_matvec(val, jnp.asarray(col), jnp.asarray(row), g, m)
    np.testing.assert_allclose(np.asarray(yt), np.asarray(yt_c), atol=1e-12)
    assert float(jnp.abs(yt[140:]).max()) == 0.0


# ---------------------------------------------------------------------------
# fused solver-step kernels (kernels/solve_step.py) vs the pure-jnp oracles
# ---------------------------------------------------------------------------

from repro.kernels import ref as _fref
from repro.kernels import solve_step as _fk

# kernel name → (vector-argument count, scalar-argument count)
_FUSED_SIGS = {
    "fused_cg_update": (5, 1),
    "fused_cg_direction": (4, 1),
    "fused_cg_halfstep": (4, 1),
    "fused_cheb_step": (3, 2),
    "fused_dots2": (2, 0),
    "fused_bicg_p": (4, 3),
    "fused_bicg_s": (3, 1),
    "fused_bicg_tail": (6, 2),
}


def _fused_parity_case(name, n, dtype, seed):
    n_vec, n_sc = _FUSED_SIGS[name]
    rng = np.random.default_rng(seed)
    vecs = [jnp.asarray(rng.normal(size=n).astype(dtype))
            for _ in range(n_vec)]
    scalars = [jnp.asarray(dtype(rng.normal())) for _ in range(n_sc)]
    out_k = getattr(_fk, name)(*vecs, *scalars)
    out_r = getattr(_fref, name + "_ref")(*vecs, *scalars)
    assert len(out_k) == len(out_r)
    for a, b in zip(out_k, out_r):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   **_tol(dtype))


@settings(max_examples=20, deadline=None)
@given(name=st.sampled_from(sorted(_FUSED_SIGS)),
       n=st.integers(3, 3000),
       dtype=st.sampled_from([np.float32, np.float64]),
       seed=st.integers(0, 99))
def test_fused_step_kernel_sweep(name, n, dtype, seed):
    """Every fused kernel matches its ref oracle across sizes (ragged last
    blocks included — n is rarely a multiple of the 1024 tile) and dtypes."""
    _fused_parity_case(name, n, dtype, seed)


@pytest.mark.parametrize("name", sorted(_FUSED_SIGS))
def test_fused_step_kernel_edges(name):
    """Deterministic coverage of every kernel at the tile edges the sweep
    may miss: exact one-tile n, the 8×128 sub-tile boundary, and ragged."""
    for n in (5, 128, 1024, 1029):
        for dtype in (np.float32, np.float64):
            _fused_parity_case(name, n, dtype, seed=0)


def test_fused_dots_exclude_padding():
    """The in-kernel reductions must not pick up the zero-padded tail — the
    padding contributes exact zeros, so dots over a size-5 vector padded to
    1024 equal the length-5 dots."""
    u = jnp.asarray([1.0, 2.0, 3.0, 4.0, 5.0])
    v = jnp.asarray([1.0, -1.0, 1.0, -1.0, 1.0])
    uv, uu = _fk.fused_dots2(u, v)
    np.testing.assert_allclose(float(uv), float(jnp.dot(u, v)), rtol=1e-14)
    np.testing.assert_allclose(float(uu), float(jnp.dot(u, u)), rtol=1e-14)


def test_fused_cg_solver_matches_plain():
    """cg_fused (merged Chronopoulos–Gear recurrence) produces the same
    iterates as the textbook loop — identical solution AND iteration count."""
    from repro.core import solvers
    from repro.data.poisson import poisson2d
    A = poisson2d(20)
    b = jnp.asarray(np.random.default_rng(5).normal(size=A.shape[0]))
    mv = lambda x: A @ x
    dinv = 1.0 / A.diagonal()
    M = lambda r: dinv * r
    x_p, i_p = solvers.cg(mv, b, M=M, tol=1e-11)
    x_f, i_f = solvers.cg_fused(mv, b, dinv=dinv, tol=1e-11)
    assert bool(i_f.converged)
    assert int(i_f.iters) == int(i_p.iters)
    np.testing.assert_allclose(np.asarray(x_f), np.asarray(x_p),
                               rtol=1e-9, atol=1e-11)
    # M-callable branch (no diagonal): fused axpy passes, plain recurrence
    x_m, i_m = solvers.cg_fused(mv, b, M=M, tol=1e-11)
    assert bool(i_m.converged)
    np.testing.assert_allclose(np.asarray(x_m), np.asarray(x_p),
                               rtol=1e-9, atol=1e-11)


def test_fused_bicgstab_solver_matches_plain():
    from repro.core import solvers
    from repro.data.poisson import poisson1d
    from repro.core.sparse import SparseTensor
    n = 80
    A1 = poisson1d(n)
    val = np.asarray(A1.val).copy()
    val[np.asarray(A1.col) == np.asarray(A1.row) - 1] = -1.4
    val[np.asarray(A1.col) == np.asarray(A1.row) + 1] = -0.6
    B = SparseTensor(val, A1.row, A1.col, (n, n))
    b = jnp.asarray(np.random.default_rng(6).normal(size=n))
    mv = lambda x: B @ x
    dinv = 1.0 / B.diagonal()
    x_p, i_p = solvers.bicgstab(mv, b, M=lambda r: dinv * r, tol=1e-11)
    x_f, i_f = solvers.bicgstab_fused(mv, b, dinv=dinv, tol=1e-11)
    assert bool(i_f.converged)
    np.testing.assert_allclose(np.asarray(x_f), np.asarray(x_p),
                               rtol=1e-8, atol=1e-10)


def test_default_interpret_matches_platform():
    """Satellite: the interpret flag auto-detects the platform instead of
    defaulting to emulation everywhere."""
    expect = jax.default_backend() not in ("tpu", "gpu")
    assert _fk.default_interpret() == expect
    # and the kernel-plan artifact records the same resolution
    from repro.core import dispatch
    from repro.data.poisson import poisson2d
    A = poisson2d(8)
    plan = A.plan(backend="pallas", method="cg")
    assert plan.artifacts["kernel"].interpret == expect
