"""Serving tentpole: vmap-clean batched setup/solve + the request-batching
driver.

Parity is against a plain Python loop over the batch; plan counters prove
one analyze serves the whole batch and setup runs ONCE (vmapped) rather
than per element.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import PLAN_STATS, get_plan, make_config, reset_plan_stats
from repro.core import dispatch
from repro.core import options as sla_options
from repro.data.poisson import poisson2d, poisson2d_vc


def _batch(A, scales):
    return A.with_values(jnp.stack([A.val * s for s in scales]))


def _loop_reference(A, scales, b, **kw):
    ref = np.stack([np.asarray(A.with_values(A.val * s).solve(b, **kw))
                    for s in scales])
    A._plans.clear()     # the reference warmed the shared plan cache —
    reset_plan_stats()   # drop it so the batched solve is counted fresh
    return ref


SCALES = (1.0, 1.7, 0.6)


# ---------------------------------------------------------------------------
# vmap-clean batched setup/solve, per backend
# ---------------------------------------------------------------------------

def test_batched_values_iterative_parity_and_counters():
    A = poisson2d(8)
    b = jnp.ones(A.shape[0])
    kw = dict(backend="jnp", method="cg", tol=1e-11)
    ref = _loop_reference(A, SCALES, b, **kw)
    Ab = _batch(A, SCALES)
    reset_plan_stats()
    xs = Ab.solve(b, **kw)
    assert PLAN_STATS["analyze"] == 1, PLAN_STATS
    assert PLAN_STATS["setup"] == 1, PLAN_STATS   # ONE vmapped setup
    np.testing.assert_allclose(np.asarray(xs), ref, rtol=1e-8, atol=1e-10)


def test_batched_values_direct_parity_single_factorize_trace():
    A = poisson2d(8)
    b = jnp.ones(A.shape[0])
    kw = dict(backend="direct", method="ldlt")
    ref = _loop_reference(A, SCALES, b, **kw)
    Ab = _batch(A, SCALES)
    reset_plan_stats()
    xs = Ab.solve(b, **kw)
    assert PLAN_STATS["analyze"] == 1, PLAN_STATS
    assert PLAN_STATS["setup"] == 1, PLAN_STATS
    # the numeric factorization is traced ONCE for the whole stack (vmap),
    # not once per element
    assert PLAN_STATS["factorize"] == 1, PLAN_STATS
    np.testing.assert_allclose(np.asarray(xs), ref, rtol=1e-9, atol=1e-11)


def test_batched_values_amg_parity_single_galerkin_trace():
    A = poisson2d(10)
    b = jnp.ones(A.shape[0])
    kw = dict(backend="jnp", method="cg", precond="amg", tol=1e-11)
    ref = _loop_reference(A, SCALES, b, **kw)
    Ab = _batch(A, SCALES)
    reset_plan_stats()
    xs = Ab.solve(b, **kw)
    assert PLAN_STATS["analyze"] == 1, PLAN_STATS
    assert PLAN_STATS["setup"] == 1, PLAN_STATS
    # PR-4 follow-up: the batched hierarchy builds through ONE vmapped
    # Galerkin trace (amg_numeric), not one per batch element
    assert PLAN_STATS["galerkin"] == 1, PLAN_STATS
    np.testing.assert_allclose(np.asarray(xs), ref, rtol=1e-8, atol=1e-10)


def test_batched_values_stencil_mg_parity():
    kappa = jnp.ones((8, 8))
    A = poisson2d_vc(kappa, use_stencil_kernel=True)
    b = jnp.ones(A.shape[0])
    kw = dict(backend="stencil", method="cg", precond="mg", tol=1e-11)
    ref = _loop_reference(A, SCALES, b, **kw)
    Ab = _batch(A, SCALES)
    reset_plan_stats()
    xs = Ab.solve(b, **kw)
    assert PLAN_STATS["analyze"] == 1, PLAN_STATS
    assert PLAN_STATS["setup"] == 1, PLAN_STATS
    np.testing.assert_allclose(np.asarray(xs), ref, rtol=1e-8, atol=1e-10)


def test_batched_setup_memo_reused_across_solves():
    """Same stacked values array → the vmapped setup is memoized (a
    tolerance sweep over a batch costs one setup, like the single case)."""
    A = poisson2d(8)
    b = jnp.ones(A.shape[0])
    Ab = _batch(A, SCALES)
    cfg = make_config(Ab, backend="jnp", method="cg", tol=1e-8)
    plan = get_plan(Ab, cfg)
    reset_plan_stats()
    plan.solve(Ab, b, cfg=cfg)
    plan.solve(Ab, b, cfg=dispatch.SolverConfig(
        backend="jnp", method="cg", tol=1e-10, precond="jacobi"))
    assert PLAN_STATS["setup"] == 1, PLAN_STATS
    assert PLAN_STATS["setup_reuse"] == 1, PLAN_STATS


def test_batched_values_jit_and_grad():
    """The batched path stays differentiable and jit-safe end to end."""
    A = poisson2d(6)
    b = jnp.ones(A.shape[0])
    vals = jnp.stack([A.val * s for s in SCALES])

    def loss(v):
        xs = A.with_values(v).solve(b, backend="jnp", method="cg", tol=1e-12)
        return jnp.sum(xs ** 2)

    g = jax.jit(jax.grad(loss))(vals)
    def loss_dense(v):
        X = jax.vmap(lambda vi: jnp.linalg.solve(
            A.with_values(vi).todense(), b))(v)
        return jnp.sum(X ** 2)
    gd = jax.grad(loss_dense)(vals)
    np.testing.assert_allclose(np.asarray(g), np.asarray(gd),
                               rtol=1e-6, atol=1e-8)


# ---------------------------------------------------------------------------
# multi-rhs: block-CG and the fused block-Jacobi path
# ---------------------------------------------------------------------------

def test_block_cg_multi_rhs_matches_per_rhs_cg():
    A = poisson2d(8)
    n = A.shape[0]
    rng = np.random.default_rng(3)
    B = jnp.asarray(np.vstack([np.ones(n), rng.normal(size=n),
                               rng.normal(size=n)]))
    ref = np.linalg.solve(np.asarray(A.todense()), np.asarray(B).T).T
    X = A.solve(B, backend="jnp", method="block_cg", tol=1e-11)
    np.testing.assert_allclose(np.asarray(X), ref, rtol=1e-8, atol=1e-10)
    # coupled block iteration: the whole block takes no more iterations
    # than the hardest rhs does alone
    cfg_b = make_config(A, backend="jnp", method="block_cg", tol=1e-11)
    plan = get_plan(A, cfg_b)
    _, info_b = plan.solve(A, B, cfg=cfg_b)
    cfg_c = make_config(A, backend="jnp", method="cg", tol=1e-11)
    _, info_c = plan.solve(A, B, cfg=cfg_c)
    assert int(info_b.iters) <= int(np.max(np.asarray(info_c.iters)))
    assert bool(np.all(np.asarray(info_b.converged)))
    assert info_b.resnorm.shape == (3,)


def test_block_cg_duplicate_rhs_is_breakdown_free():
    A = poisson2d(8)
    n = A.shape[0]
    b = jnp.ones(n)
    B = jnp.stack([b, 2.0 * b, b])      # rank-1 block
    X = A.solve(B, backend="jnp", method="block_cg", tol=1e-10)
    ref = np.linalg.solve(np.asarray(A.todense()), np.asarray(B).T).T
    np.testing.assert_allclose(np.asarray(X), ref, rtol=1e-8, atol=1e-10)


def test_block_cg_single_rhs_degenerates_to_vector():
    A = poisson2d(8)
    b = jnp.ones(A.shape[0])
    x = A.solve(b, backend="jnp", method="block_cg", tol=1e-11)
    assert x.shape == b.shape
    ref = np.linalg.solve(np.asarray(A.todense()), np.asarray(b))
    np.testing.assert_allclose(np.asarray(x), ref, rtol=1e-8, atol=1e-10)


def test_multi_rhs_block_jacobi_through_fused_step():
    """Multi-rhs + block-Jacobi preconditioning through the fused step
    kernels (PR-6) matches the plain path."""
    A = poisson2d(8)
    n = A.shape[0]
    rng = np.random.default_rng(5)
    B = jnp.asarray(rng.normal(size=(4, n)))
    kw = dict(backend="pallas", method="cg", precond="block_jacobi",
              tol=1e-11)
    with sla_options.options(fused_step="off"):
        X_plain = A.solve(B, **kw)
    with sla_options.options(fused_step="on"):
        X_fused = A.solve(B, **kw)
    np.testing.assert_allclose(np.asarray(X_fused), np.asarray(X_plain),
                               rtol=1e-8, atol=1e-10)
    with sla_options.options(fused_step="on"):
        X_blk = A.solve(B, method="block_cg", backend="jnp",
                        precond="block_jacobi", tol=1e-11)
    ref = np.linalg.solve(np.asarray(A.todense()), np.asarray(B).T).T
    np.testing.assert_allclose(np.asarray(X_blk), ref, rtol=1e-8, atol=1e-10)


# ---------------------------------------------------------------------------
# plan cache byte budget
# ---------------------------------------------------------------------------

def test_plan_nbytes_positive_and_plausible():
    A = poisson2d(8)
    plan = A.plan(backend="jnp", method="cg")
    nb = plan.nbytes()
    # at least the pattern index arrays must be counted
    assert nb >= A.row.nbytes + A.col.nbytes
    direct = A.plan(backend="direct")
    assert direct.nbytes() > 0


def test_plan_cache_byte_budget_evicts_lru():
    A = poisson2d(8)
    p1 = A.plan(backend="jnp", method="cg")
    budget = int(p1.nbytes() * 1.5)
    A._plans.clear()
    reset_plan_stats()
    with sla_options.options(plan_cache_bytes=budget):
        A.plan(backend="jnp", method="cg")
        assert PLAN_STATS["evictions"] == 0
        A.plan(backend="jnp", method="bicgstab")   # over budget → evict cg
        assert PLAN_STATS["evictions"] == 1, PLAN_STATS
        A.plan(backend="jnp", method="bicgstab")   # still resident
        assert PLAN_STATS["cache_hit"] == 1, PLAN_STATS
        A.plan(backend="jnp", method="cg")         # re-analyzed
        assert PLAN_STATS["cache_miss"] == 3, PLAN_STATS
    assert A._plans.total_bytes > 0


def test_plan_cache_byte_budget_keeps_oversized_single_entry():
    A = poisson2d(8)
    A._plans.clear()
    reset_plan_stats()
    with sla_options.options(plan_cache_bytes=1):   # below any plan's size
        p = A.plan(backend="jnp", method="cg")
        assert A._plans.get(("jnp", "cg", "jacobi")) is p   # still cached
        assert A.plan(backend="jnp", method="cg") is p


# ---------------------------------------------------------------------------
# the serving driver
# ---------------------------------------------------------------------------

def test_solve_server_groups_and_orders():
    from repro.launch.solve_serve import SolveRequest, SolveServer
    A1, A2 = poisson2d(6), poisson2d(7)
    rng = np.random.default_rng(0)
    reqs, refs = [], []
    for i in range(10):
        A0 = A1 if i % 2 == 0 else A2      # interleaved patterns
        s = float(rng.uniform(0.8, 1.2))
        Ai = A0.with_values(A0.val * s)
        bi = jnp.asarray(rng.normal(size=A0.shape[0]))
        reqs.append(SolveRequest(Ai, bi, {"backend": "jnp", "method": "cg",
                                          "tol": 1e-10}))
        refs.append(np.linalg.solve(np.asarray(Ai.todense()),
                                    np.asarray(bi)))
    server = SolveServer(max_batch=8)
    reset_plan_stats()
    out = server.submit_batch(reqs)
    # one vmapped dispatch per pattern group, results in request order
    assert server.stats["dispatches"] == 2, server.stats
    assert PLAN_STATS["analyze"] == 2, PLAN_STATS
    for res, ref in zip(out, refs):
        assert res.reason == "converged"
        np.testing.assert_allclose(np.asarray(res.x), ref,
                                   rtol=1e-7, atol=1e-9)
    # 5 requests padded to 8 slots per group
    assert server.stats["padded_slots"] == 16
    assert server.occupancy == pytest.approx(10 / 16)


def test_serve_smoke_report():
    from repro.launch.solve_serve import serve
    rep = serve(n_requests=8, grid=6, n_patterns=1, max_batch=8,
                check=True)   # parity asserted inside
    assert rep["plan_stats"]["analyze"] == 1
    assert rep["converged"]
    for side in ("batched", "sequential"):
        assert rep[side]["solves_per_sec"] > 0
        assert rep[side]["p99_ms"] >= rep[side]["p50_ms"]
    assert rep["occupancy"] == 1.0
