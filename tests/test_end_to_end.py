"""End-to-end behaviour tests: the paper's §4.4 inverse problem (reduced) and
solver accuracy on the paper's Poisson ladder (reduced sizes)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import SparseTensor
from repro.data.poisson import poisson2d, poisson2d_vc, vc_coefficients


def test_poisson_solution_accuracy_against_analytic():
    """Manufactured solution: u = sin(πx)sin(πy) on the unit square."""
    ng = 48
    h = 1.0 / (ng + 1)
    xs = (np.arange(1, ng + 1) * h)
    X, Y = np.meshgrid(xs, xs, indexing="ij")
    u_exact = np.sin(np.pi * X) * np.sin(np.pi * Y)
    f = 2 * np.pi ** 2 * u_exact * h ** 2       # A is unscaled stencil
    A = poisson2d(ng)
    u = A.solve(jnp.asarray(f.ravel()), backend="jnp", method="cg", tol=1e-12)
    err = np.abs(np.asarray(u) - u_exact.ravel()).max()
    assert err < 5e-3                            # O(h²) discretization error


def test_inverse_coefficient_learning_reduced():
    """Paper §4.4 at 24×24 with 120 Adam steps: κ recovered with decreasing
    loss and sub-15% relative L2 error (full benchmark: fig3_inverse.py)."""
    ng = 24
    xs = jnp.linspace(0, 1, ng)
    X, Y = jnp.meshgrid(xs, xs, indexing="ij")
    kappa_true = 1.0 + 0.5 * jnp.sin(2 * jnp.pi * X) * jnp.sin(2 * jnp.pi * Y)
    f = jnp.ones(ng * ng)
    u_obs = poisson2d_vc(kappa_true).solve(f, backend="jnp", method="cg",
                                           tol=1e-12)

    theta0 = jnp.zeros((ng, ng)) + jnp.log(jnp.exp(1.0) - 1)  # softplus⁻¹(1)

    def loss_fn(theta):
        kappa = jax.nn.softplus(theta)
        u = poisson2d_vc(kappa).solve(f, backend="jnp", method="cg", tol=1e-11)
        data = jnp.sum((u - u_obs) ** 2)
        gx = jnp.diff(kappa, axis=0)
        gy = jnp.diff(kappa, axis=1)
        reg = 1e-3 * (jnp.sum(gx ** 2) + jnp.sum(gy ** 2)) / (ng * ng)
        return data + reg

    from repro.optim.adamw import AdamWConfig, adamw_update, init_opt_state
    opt_cfg = AdamWConfig(lr=5e-2, weight_decay=0.0, warmup_steps=0,
                          total_steps=120, schedule="constant", grad_clip=0.0)
    theta = theta0
    state = init_opt_state(theta)
    losses = []
    for step in range(120):
        l, g = jax.value_and_grad(loss_fn)(theta)
        theta, state, _ = adamw_update(opt_cfg, theta, g, state)
        losses.append(float(l))
    kappa = jax.nn.softplus(theta)
    rel = float(jnp.linalg.norm(kappa - kappa_true)
                / jnp.linalg.norm(kappa_true))
    assert losses[-1] < losses[0] * 1e-2, (losses[0], losses[-1])
    assert rel < 0.2, rel   # full-scale benchmark reaches the paper's 0.23%


def test_gradient_flows_through_assembly():
    """A(κ) assembly (vc_coefficients) is differentiable and the adjoint path
    composes: ∂‖u‖²/∂κ matches finite differences."""
    ng = 10
    kappa = jnp.ones((ng, ng)) * 1.2
    f = jnp.ones(ng * ng)

    def loss(kap):
        u = poisson2d_vc(kap).solve(f, backend="jnp", method="cg", tol=1e-13)
        return jnp.sum(u ** 2)

    g = jax.grad(loss)(kappa)
    eps = 1e-6
    for (i, j) in ((0, 0), (4, 7), (9, 9)):
        kp = kappa.at[i, j].add(eps)
        km = kappa.at[i, j].add(-eps)
        fd = (loss(kp) - loss(km)) / (2 * eps)
        assert abs(float(g[i, j]) - float(fd)) / abs(float(fd)) < 1e-5
