"""Adjoint-framework tests (paper §3.2, §4.2, §4.3, App. D).

Covers: linear adjoint vs dense autodiff + FD; the O(1)-graph property
(jaxpr size independent of maxiter, the Fig. 2 claim); adjoint vs naive
agreement at convergence (App. D); nonlinear + eigen adjoints vs FD / exact
dense adjoints (Table 5)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import SparseTensor, nonlinear_solve
from repro.core.solvers import cg_scan
from repro.core.dispatch import make_config, make_matvec
from repro.data.poisson import poisson1d, poisson2d


@pytest.fixture(scope="module")
def A():
    return poisson2d(8)     # 64 dof, SPD


def _loss_through_solve(A, maxiter=4000, tol=1e-13):
    def loss(val, b):
        x = A.with_values(val).solve(b, backend="jnp", method="cg",
                                     tol=tol, maxiter=maxiter)
        return jnp.sum(x ** 2)
    return loss


def test_linear_adjoint_matches_dense_autodiff(A):
    rng = np.random.default_rng(0)
    b = jnp.asarray(rng.normal(size=A.shape[0]))
    loss = _loss_through_solve(A)

    def loss_dense(val, b):
        x = jnp.linalg.solve(A.with_values(val).todense(), b)
        return jnp.sum(x ** 2)

    g = jax.grad(loss, (0, 1))(A.val, b)
    gd = jax.grad(loss_dense, (0, 1))(A.val, b)
    np.testing.assert_allclose(np.asarray(g[0]), np.asarray(gd[0]),
                               rtol=1e-6, atol=1e-8)
    np.testing.assert_allclose(np.asarray(g[1]), np.asarray(gd[1]),
                               rtol=1e-6, atol=1e-8)


def test_linear_adjoint_vs_finite_differences(A):
    b = jnp.ones(A.shape[0])
    loss = _loss_through_solve(A)
    g = jax.grad(loss)(A.val, b)
    eps = 1e-6
    rng = np.random.default_rng(1)
    for e in rng.choice(A.nnz, 5, replace=False):
        lp = loss(A.val.at[e].add(eps), b)
        lm = loss(A.val.at[e].add(-eps), b)
        fd = (lp - lm) / (2 * eps)
        assert abs(float(g[e]) - float(fd)) / max(abs(float(fd)), 1e-9) < 1e-4


def test_o1_graph_independent_of_iterations(A):
    """The central §4.2 claim, statically: the adjoint backward jaxpr does
    not grow with maxiter, while naive scan-based backprop grows O(k)."""
    b = jnp.ones(A.shape[0])

    def make_adj(maxiter):
        return jax.make_jaxpr(
            jax.grad(_loss_through_solve(A, maxiter=maxiter)))(A.val, b)

    n10 = len(make_adj(10).eqns)
    n1000 = len(make_adj(1000).eqns)
    assert n10 == n1000   # O(1) graph

    mv_val = lambda val, x: SparseTensor(
        val, A.row, A.col, A.shape, props=A.props, validate=False) @ x

    def naive_loss(k):
        def loss(val):
            x = cg_scan(lambda x: mv_val(val, x), b, k)
            return jnp.sum(x ** 2)
        return loss

    # naive graph grows with k (the O(k) path of Fig. 2)
    jx10 = jax.make_jaxpr(jax.grad(naive_loss(10)))(A.val)
    # scan keeps eqn count constant but the *residual stack* grows with k:
    shapes10 = [v.aval.shape for eq in jx10.eqns for v in eq.outvars
                if eq.primitive.name == "scan"]
    jx50 = jax.make_jaxpr(jax.grad(naive_loss(50)))(A.val)
    shapes50 = [v.aval.shape for eq in jx50.eqns for v in eq.outvars
                if eq.primitive.name == "scan"]
    mem10 = sum(int(np.prod(s)) for s in shapes10)
    mem50 = sum(int(np.prod(s)) for s in shapes50)
    assert mem50 > 4 * mem10   # ≈ linear growth in k


def test_adjoint_equals_naive_at_convergence(A):
    """Paper App. D: run both paths to full convergence on a small problem;
    loss identical, gradients match."""
    b = jnp.ones(A.shape[0])
    k = 400

    def naive(val, bb):
        Av = lambda x: SparseTensor(val, A.row, A.col, A.shape,
                                    props=A.props, validate=False) @ x
        x = cg_scan(Av, bb, k)
        return jnp.sum(x ** 2)

    adj = _loss_through_solve(A, tol=1e-14, maxiter=4000)
    l_n = float(naive(A.val, b))
    l_a = float(adj(A.val, b))
    assert abs(l_n - l_a) / abs(l_n) < 1e-12
    gn = jax.grad(naive, (0, 1))(A.val, b)
    ga = jax.grad(adj, (0, 1))(A.val, b)
    np.testing.assert_allclose(np.asarray(ga[1]), np.asarray(gn[1]),
                               rtol=1e-9, atol=1e-11)
    # matrix gradients agree on the SYMMETRIC tangent space (per-entry
    # perturbations of one triangle de-symmetrize A, where converged-CG
    # derivatives are algorithm-dependent — cf. paper App. D's looser 6.8e-4
    # matrix-gradient agreement): compare pairwise-symmetrized gradients.
    row, col = np.asarray(A.row), np.asarray(A.col)
    pair = {(int(r), int(c)): i for i, (r, c) in enumerate(zip(row, col))}
    mate = np.array([pair[(int(c), int(r))] for r, c in zip(row, col)])
    ga_sym = np.asarray(ga[0]) + np.asarray(ga[0])[mate]
    gn_sym = np.asarray(gn[0]) + np.asarray(gn[0])[mate]
    np.testing.assert_allclose(ga_sym, gn_sym, rtol=1e-6, atol=1e-9)


def test_batched_adjoint(A):
    rng = np.random.default_rng(2)
    vals = jnp.stack([A.val, 1.5 * A.val])
    bs = jnp.asarray(rng.normal(size=(2, A.shape[0])))
    Ab = SparseTensor(vals, A.row, A.col, A.shape, props=A.props)

    def loss(v, b):
        x = SparseTensor(v, A.row, A.col, A.shape, props=A.props,
                         validate=False).solve(b, backend="jnp", method="cg",
                                               tol=1e-13)
        return jnp.sum(x ** 3)

    g = jax.grad(lambda v, b: loss(v, b) )(vals, bs)
    for i in range(2):
        gi = jax.grad(lambda v, b: loss(v, b))(vals[i], bs[i])
        np.testing.assert_allclose(np.asarray(g[i]), np.asarray(gi),
                                   rtol=1e-7, atol=1e-9)


def test_nonlinear_adjoint_vs_fd():
    n = 48
    A = poisson1d(n)
    b = jnp.linspace(0.5, 1.5, n)

    def residual(u, val, f):
        return A.with_values(val) @ u + u ** 3 - f

    def loss(val, f):
        u = nonlinear_solve(residual, jnp.zeros(n), val, f,
                            method="newton", tol=1e-13)
        return jnp.sum(u ** 2)

    g_val, g_f = jax.grad(loss, (0, 1))(A.val, b)
    eps = 1e-6
    rng = np.random.default_rng(3)
    for e in rng.choice(A.nnz, 3, replace=False):
        fd = (loss(A.val.at[e].add(eps), b) -
              loss(A.val.at[e].add(-eps), b)) / (2 * eps)
        assert abs(float(g_val[e]) - float(fd)) / max(abs(float(fd)), 1e-9) < 1e-5
    for i in (0, n // 2):
        fd = (loss(A.val, b.at[i].add(eps)) -
              loss(A.val, b.at[i].add(-eps))) / (2 * eps)
        assert abs(float(g_f[i]) - float(fd)) / max(abs(float(fd)), 1e-9) < 1e-5


def test_nonlinear_backward_is_single_solve():
    """Forward may take many Newton iterations; backward jaxpr is independent
    of the iteration budget (paper Table 5: 5 solves fwd, 1 bwd)."""
    n = 16
    A = poisson1d(n)
    b = jnp.ones(n)

    def residual(u, val):
        return A.with_values(val) @ u + u ** 3 - b

    def loss(maxiter):
        def f(val):
            u = nonlinear_solve(residual, jnp.zeros(n), val,
                                method="newton", tol=1e-13, maxiter=maxiter)
            return jnp.sum(u ** 2)
        return f

    na = len(jax.make_jaxpr(jax.grad(loss(5)))(A.val).eqns)
    nb = len(jax.make_jaxpr(jax.grad(loss(50)))(A.val).eqns)
    assert na == nb


def _aniso(ng, cy=0.6):
    A = poisson2d(ng)
    val = np.asarray(A.val).copy()
    row, col = np.asarray(A.row), np.asarray(A.col)
    val[np.abs(row - col) == 1] *= cy
    val[row == col] = 2.0 + 2.0 * cy
    return SparseTensor(val, row, col, A.shape)


def test_eigsh_eigenvalue_grads_vs_fd():
    A = _aniso(7)

    def loss(val):
        w, _ = A.with_values(val).eigsh(k=2, tol=1e-12, maxiter=2000,
                                        compute_vector_grads=False)
        return 2.0 * w[0] + w[1]

    g = jax.grad(loss)(A.val)
    eps = 1e-6
    rng = np.random.default_rng(4)
    for e in rng.choice(A.nnz, 4, replace=False):
        fd = (loss(A.val.at[e].add(eps)) - loss(A.val.at[e].add(-eps))) / (2 * eps)
        assert abs(float(g[e]) - float(fd)) / max(abs(float(fd)), 1e-8) < 1e-3


def test_eigsh_eigenvector_grads_vs_exact():
    """Eigenvector cotangents vs the exact dense-eigendecomposition adjoint
    (symmetrized convention — FD on single entries breaks symmetry)."""
    A = _aniso(6)
    n = A.shape[0]
    rng = np.random.default_rng(5)
    a = jnp.asarray(rng.normal(size=n))

    def loss(val):
        w, V = A.with_values(val).eigsh(k=2, tol=1e-13, maxiter=3000)
        return 1.3 * w[0] + (V[1] @ a) ** 2

    g = np.asarray(jax.grad(loss)(A.val))

    D = np.asarray(A.todense())
    w_all, V_all = np.linalg.eigh(D)
    v0, v1 = V_all[:, 0], V_all[:, 1]
    gv1 = 2 * (v1 @ np.asarray(a)) * np.asarray(a)
    y = np.zeros(n)
    for j in range(n):
        if j == 1:
            continue
        y += (V_all[:, j] @ gv1) / (w_all[1] - w_all[j]) * V_all[:, j]
    row, col = np.asarray(A.row), np.asarray(A.col)
    g_exact = (1.3 * v0[row] * v0[col]
               + 0.5 * (y[row] * v1[col] + v1[row] * y[col]))
    np.testing.assert_allclose(g, g_exact, atol=5e-3)


def test_slogdet_grad():
    A = poisson2d(5)

    def loss(val):
        sign, logdet = A.with_values(val).slogdet()
        return logdet

    g = jax.grad(loss)(A.val)
    eps = 1e-6
    for e in (0, 7, 30):
        fd = (loss(A.val.at[e].add(eps)) - loss(A.val.at[e].add(-eps))) / (2 * eps)
        assert abs(float(g[e]) - float(fd)) < 1e-6


def test_kernel_backend_adjoint():
    """Gradients flow through the stencil-kernel solve path identically."""
    from repro.data.poisson import poisson2d_vc
    ng = 12
    kappa = jnp.ones((ng, ng)) * 1.3
    f = jnp.ones(ng * ng)

    def loss(kap, use_kernel):
        A = poisson2d_vc(kap, use_stencil_kernel=use_kernel)
        x = A.solve(f, backend="stencil" if use_kernel else "jnp",
                    method="cg", tol=1e-12)
        return jnp.sum(x ** 2)

    g_kernel = jax.grad(lambda k: loss(k, True))(kappa)
    g_jnp = jax.grad(lambda k: loss(k, False))(kappa)
    np.testing.assert_allclose(np.asarray(g_kernel), np.asarray(g_jnp),
                               rtol=1e-6, atol=1e-8)
