"""SparseTensor data-structure tests + hypothesis property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:       # bare env: seeded-sweep fallback, suite still collects
    from _hypothesis_compat import given, settings, st

from repro.core import SparseTensor, SparseTensorList, build_bell, coo_matvec
from repro.data.poisson import poisson1d, poisson2d


def random_coo(rng, n, m, density=0.1):
    nnz = max(1, int(n * m * density))
    row = rng.integers(0, n, nnz)
    col = rng.integers(0, m, nnz)
    keys = np.unique(row.astype(np.int64) * m + col)
    row = (keys // m).astype(np.int32)
    col = (keys % m).astype(np.int32)
    val = rng.normal(size=len(row))
    return val, row, col


def test_matvec_matches_dense():
    rng = np.random.default_rng(0)
    val, row, col = random_coo(rng, 40, 30)
    A = SparseTensor(val, row, col, (40, 30))
    x = rng.normal(size=30)
    np.testing.assert_allclose(np.asarray(A @ jnp.asarray(x)),
                               np.asarray(A.todense()) @ x, rtol=1e-12)


def test_transpose_and_rmatvec():
    rng = np.random.default_rng(1)
    val, row, col = random_coo(rng, 25, 35)
    A = SparseTensor(val, row, col, (25, 35))
    y = rng.normal(size=25)
    np.testing.assert_allclose(np.asarray(A.rmatvec(jnp.asarray(y))),
                               np.asarray(A.todense()).T @ y, rtol=1e-12)
    assert A.T.shape == (35, 25)


def test_batched_matvec_broadcasting():
    rng = np.random.default_rng(2)
    val, row, col = random_coo(rng, 20, 20)
    valb = np.stack([val, 2 * val, -val])
    A = SparseTensor(valb, row, col, (20, 20))
    x = rng.normal(size=(3, 20))
    y = A @ jnp.asarray(x)
    assert y.shape == (3, 20)
    for b in range(3):
        np.testing.assert_allclose(
            np.asarray(y[b]),
            np.asarray(SparseTensor(valb[b], row, col, (20, 20)).todense()) @ x[b],
            rtol=1e-12)


def test_pytree_roundtrip():
    A = poisson1d(16)
    leaves, treedef = jax.tree_util.tree_flatten(A)
    A2 = jax.tree_util.tree_unflatten(treedef, leaves)
    assert A2.shape == A.shape
    np.testing.assert_array_equal(np.asarray(A2.val), np.asarray(A.val))

    @jax.jit
    def through_jit(A):
        return A @ jnp.ones(16)

    y = through_jit(A)
    assert y.shape == (16,)


def test_property_detection():
    A = poisson2d(8)
    assert A.props["symmetric"]
    assert A.props["spd_hint"]
    rng = np.random.default_rng(3)
    val, row, col = random_coo(rng, 20, 20, 0.2)
    B = SparseTensor(val, row, col, (20, 20))
    assert not B.props["symmetric"]


def test_diagonal():
    A = poisson2d(5)
    np.testing.assert_allclose(np.asarray(A.diagonal()), np.full(25, 4.0))


def test_sparse_tensor_list():
    rng = np.random.default_rng(4)
    mats, rhs = [], []
    for n in (10, 17, 23):
        val, row, col = random_coo(rng, n, n, 0.3)
        val = np.concatenate([val, np.full(n, n * 1.0)])
        row = np.concatenate([row, np.arange(n)]).astype(np.int32)
        col = np.concatenate([col, np.arange(n)]).astype(np.int32)
        mats.append(SparseTensor(val, row, col, (n, n)))
        rhs.append(jnp.asarray(rng.normal(size=n)))
    L = SparseTensorList(mats)
    xs = L.solve(rhs, tol=1e-12)
    for A, b, x in zip(mats, rhs, xs):
        assert float(jnp.linalg.norm(A @ x - b)) < 1e-8


@settings(max_examples=20, deadline=None)
@given(n=st.integers(5, 60), m=st.integers(5, 60), seed=st.integers(0, 999))
def test_bell_layout_property(n, m, seed):
    """Block-ELL matvec ≡ COO matvec for arbitrary random patterns."""
    rng = np.random.default_rng(seed)
    val, row, col = random_coo(rng, n, m, 0.15)
    from repro.kernels import ops
    meta, bcols, perm = build_bell(row, col, (n, m), bm=8, bn=128)
    x = jnp.asarray(rng.normal(size=m))
    v = jnp.asarray(val)
    y_bell = ops.bell_matvec_ref(meta, bcols, perm, v, x, n)
    y_coo = coo_matvec(v, jnp.asarray(row), jnp.asarray(col), x, n)
    np.testing.assert_allclose(np.asarray(y_bell), np.asarray(y_coo),
                               rtol=1e-10, atol=1e-10)
