"""SparseNewton through the plan engine (paper §3.2.2) — the PR-10 gate.

What this file pins down:

* coloring-based sparse Jacobian assembly is EXACT on the declared pattern
  (vs ``jax.jacfwd``) and the coloring itself is valid;
* plan-counter regressions: ONE analyze (and at most one kernel-plan build)
  serves a full Newton sweep PLUS its IFT backward; ``factorize`` (direct)
  / ``galerkin`` (AMG) count the Newton steps exactly — the backward's
  transpose solve reuses the converged step's factors (``transpose_shared``)
  through the shared setup memo (``setup_reuse``);
* solution parity with the dense-Jacobian ``newton_solve`` path;
* θ-gradients of ``nonlinear_solve(jac_pattern=...)`` match dense autodiff
  through an unrolled Newton loop, for BOTH ``backend="direct"`` and
  ``precond="amg"`` inner solvers;
* the ISSUE acceptance case: a p-Laplacian-type solve on an n ≥ 10⁴
  graph-Laplacian mesh keeps ``PLAN_STATS["analyze"] == 1`` across all
  Newton steps and the IFT backward, with the θ-gradient matching a central
  finite difference to 1e-5.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import sla
from repro.core import SparseNewton, solvers
from repro.core.dispatch import (PLAN_STATS, SolverConfig, get_plan,
                                 reset_plan_stats)
from repro.core.nonlinear import SparseNewton as SparseNewtonDirect
from repro.core.sparse import SparseTensor, color_pattern
from repro.data.poisson import poisson1d, poisson2d
from repro.data.graphs import graph_laplacian


def _cubic_problem(A, th0=0.7):
    """F(u, θ) = A u + θ u³ − f: Jacobian A + 3θ diag(u²) lives exactly on
    A's pattern (Poisson/graph-Laplacian patterns carry the full diagonal)."""
    n = A.shape[0]
    f = jnp.linspace(0.5, 1.5, n)

    def residual(u, th):
        return A @ u + th * u ** 3 - f

    return residual, jnp.asarray(th0), f


# ---------------------------------------------------------------------------
# coloring
# ---------------------------------------------------------------------------

def test_color_pattern_is_valid_coloring():
    rng = np.random.default_rng(0)
    n = 40
    nnz = 260
    row = rng.integers(0, n, nnz)
    col = rng.integers(0, n, nnz)
    color, k = color_pattern(row, col, n)
    assert color.shape == (n,) and k >= 1 and color.max() == k - 1
    # validity: two columns sharing a row never share a color
    for i in range(n):
        cols_i = np.unique(col[row == i])
        assert len(np.unique(color[cols_i])) == len(cols_i)


def test_colored_assembly_matches_jacfwd():
    A = poisson2d(6)
    residual, th, _ = _cubic_problem(A)
    sn = SparseNewton(residual, A)
    # tridiagonal-ish 2D stencil: handful of colors, never O(n)
    assert sn.n_colors <= 8
    u = jnp.asarray(np.random.default_rng(1).normal(size=A.shape[0]))
    vals = sn.assemble(u, th)
    J_dense = jax.jacfwd(lambda uu: residual(uu, th))(u)
    np.testing.assert_allclose(np.asarray(vals),
                               np.asarray(J_dense[A.row, A.col]),
                               rtol=1e-12, atol=1e-12)


def test_coloring_budget_guard_and_callback_escape():
    n = 24
    # one dense row → every column pairwise adjacent → n colors
    row = np.concatenate([np.zeros(n, np.int64), np.arange(n)])
    col = np.concatenate([np.arange(n), np.arange(n)])

    def residual(u):
        r = jnp.zeros(n).at[0].set(jnp.sum(u))
        return r + u

    with sla.options(jac_coloring_budget=4):
        with pytest.raises(ValueError, match="jac_coloring_budget"):
            SparseNewton(residual, (row, col, n))
        # explicit assembly callback bypasses the coloring entirely;
        # J = I + e₀1ᵀ, so J[row, col] has a 2 wherever (0, 0) appears
        def assemble(u):
            blk = jnp.ones(n).at[0].set(2.0)
            return jnp.concatenate([blk, blk]).astype(u.dtype)
        sn = SparseNewton(residual, (row, col, n), assemble_jacobian=assemble)
        vals = sn.assemble(jnp.zeros(n))
        J = jax.jacfwd(residual)(jnp.zeros(n))
        np.testing.assert_allclose(np.asarray(vals),
                                   np.asarray(J[row, col]), atol=1e-14)


# ---------------------------------------------------------------------------
# plan-counter regressions
# ---------------------------------------------------------------------------

def test_one_analyze_serves_sweep_and_backward_direct():
    A = poisson2d(8)          # fresh pattern below so counters start clean
    A = SparseTensor(A.val, A.row, A.col, A.shape, props=dict(A.props),
                     validate=False)
    residual, th, _ = _cubic_problem(A)
    n = A.shape[0]

    reset_plan_stats()

    def loss(t):
        u = sla.nonlinear_solve(residual, jnp.zeros(n), t, jac_pattern=A,
                                linear_solver=SolverConfig(backend="direct"))
        return jnp.sum(u ** 2)

    g = jax.grad(loss)(th)
    assert jnp.isfinite(g)
    n_steps = PLAN_STATS["jac_assemble"]
    assert n_steps >= 2
    # ONE analyze and at most one kernel-plan build serve the whole sweep
    # plus the IFT backward; the backward reuses the converged factors
    assert PLAN_STATS["analyze"] == 1
    assert PLAN_STATS["kernel_plan"] <= 1
    assert PLAN_STATS["jac_color"] == 1
    assert PLAN_STATS["factorize"] == n_steps      # one per step, none extra
    assert PLAN_STATS["transpose_shared"] == 1
    assert PLAN_STATS["setup_reuse"] >= 1          # bwd memo hit on last vals


def test_factorize_counts_steps_amg():
    A = poisson2d(8)
    A = SparseTensor(A.val, A.row, A.col, A.shape, props=dict(A.props),
                     validate=False)
    residual, th, _ = _cubic_problem(A)
    n = A.shape[0]

    reset_plan_stats()
    cfg = SolverConfig(backend="jnp", method="cg", precond="amg",
                       tol=1e-12, maxiter=500)

    def loss(t):
        u = sla.nonlinear_solve(residual, jnp.zeros(n), t, jac_pattern=A,
                                linear_solver=cfg)
        return jnp.sum(u ** 2)

    jax.grad(loss)(th)
    n_steps = PLAN_STATS["jac_assemble"]
    assert PLAN_STATS["analyze"] == 1
    assert PLAN_STATS["coarsen"] == 1              # aggregation is symbolic
    assert PLAN_STATS["galerkin"] == n_steps       # one numeric pass per step
    assert PLAN_STATS["transpose_shared"] == 1
    assert PLAN_STATS["setup_reuse"] >= 1


# ---------------------------------------------------------------------------
# parity and gradients
# ---------------------------------------------------------------------------

def test_sparse_newton_matches_dense_newton_solution():
    A = poisson1d(48)
    residual, th, _ = _cubic_problem(A)
    n = A.shape[0]
    F = lambda u: residual(u, th)

    u_dense, info_d = solvers.newton_solve(F, jnp.zeros(n), tol=1e-12)
    sn = SparseNewtonDirect(residual, A,
                            linear_solver=SolverConfig(backend="direct"))
    u_sparse, info_s = sn.solve(jnp.zeros(n), th, tol=1e-12)
    assert bool(info_d.converged) and bool(info_s.converged)
    np.testing.assert_allclose(np.asarray(u_sparse), np.asarray(u_dense),
                               atol=1e-8)

    # same result through the newton_solve front door
    u_api, info_api = solvers.newton_solve(
        F, jnp.zeros(n), tol=1e-12, jac_pattern=A,
        linear_solver=SolverConfig(backend="direct"))
    assert bool(info_api.converged)
    np.testing.assert_allclose(np.asarray(u_api), np.asarray(u_dense),
                               atol=1e-8)

    with pytest.raises(ValueError, match="jac_pattern"):
        solvers.newton_solve(F, jnp.zeros(n),
                             linear_solver=SolverConfig(backend="direct"))


def _dense_unrolled_loss(A, residual, n_steps=25):
    """Reference: autodiff straight through an unrolled dense Newton loop."""
    Ad = jnp.asarray(A.todense())
    n = A.shape[0]

    def loss(t):
        u = jnp.zeros(n)
        for _ in range(n_steps):
            F = residual(u, t)
            J = jax.jacfwd(lambda uu: residual(uu, t))(u)
            u = u - jnp.linalg.solve(J, F)
        return jnp.sum(u ** 2)

    del Ad
    return loss


@pytest.mark.parametrize("cfg", [
    SolverConfig(backend="direct"),
    SolverConfig(backend="jnp", method="cg", precond="amg",
                 tol=1e-13, maxiter=800),
], ids=["direct", "amg"])
def test_theta_gradient_matches_dense_autodiff(cfg):
    A = poisson2d(7)
    residual, th, _ = _cubic_problem(A)
    n = A.shape[0]

    def loss(t):
        u = sla.nonlinear_solve(residual, jnp.zeros(n), t, jac_pattern=A,
                                linear_solver=cfg, tol=1e-13)
        return jnp.sum(u ** 2)

    g = jax.grad(loss)(th)
    g_ref = jax.grad(_dense_unrolled_loss(A, residual))(th)
    # the references differ at the level of the inner-solve tolerance: the
    # unrolled dense reference differentiates THROUGH the iteration, the
    # plan path applies the IFT at the (1e-13-converged) root
    assert abs(float(g - g_ref)) / abs(float(g_ref)) < 1e-9


def test_fixed_point_forward_plan_backward():
    """picard/anderson forward + SparseNewton IFT backward: the gradient is a
    property of the converged root, independent of how it was found."""
    A0 = poisson1d(40)
    # shift the diagonal by +1 so u ← u − 0.3·F is a FAST contraction
    # (λ(A) ∈ [1.03, 5]; pure Picard on the unshifted Poisson operator
    # needs ~cond(A)·30 ≈ 2·10⁴ sweeps to reach 1e-13)
    val = np.asarray(A0.val).copy()
    val[np.asarray(A0.row) == np.asarray(A0.col)] += 1.0
    A = SparseTensor(jnp.asarray(val), A0.row, A0.col, A0.shape)
    residual, th, _ = _cubic_problem(A, th0=0.3)
    n = A.shape[0]
    g_ref = jax.grad(_dense_unrolled_loss(A, residual))(th)

    for method, kw in (("picard", dict(maxiter=8000)),
                       ("anderson", dict(maxiter=2000))):
        def loss(t):
            u = sla.nonlinear_solve(
                lambda u, tt: 0.3 * residual(u, tt), jnp.zeros(n), t,
                method=method, tol=1e-13, jac_pattern=A,
                linear_solver=SolverConfig(backend="direct"), **kw)
            return jnp.sum(u ** 2)
        # nonlinear_solve's fixed-point methods iterate u ← u − F; scaling F
        # by 0.3 makes the map contractive without moving the root, but ALSO
        # scales the residual the IFT sees — the gradient is invariant
        # because both J and ∂F/∂θ pick up the same factor.
        g = jax.grad(loss)(th)
        assert abs(float(g - g_ref)) / abs(float(g_ref)) < 1e-7, method


def test_jit_traced_sparse_newton():
    """The traced path (lax.while_loop) stays usable under jit and agrees
    with the eager loop."""
    A = poisson1d(32)
    residual, th, _ = _cubic_problem(A)
    n = A.shape[0]
    sn = SparseNewtonDirect(residual, A,
                            linear_solver=SolverConfig(
                                backend="jnp", method="cg", tol=1e-12,
                                maxiter=400))
    u_eager, _ = sn.solve(jnp.zeros(n), th, tol=1e-12)

    @jax.jit
    def run(t):
        u, _ = sn.solve(jnp.zeros(n), t, tol=1e-12)
        return u

    np.testing.assert_allclose(np.asarray(run(th)), np.asarray(u_eager),
                               atol=1e-9)


# ---------------------------------------------------------------------------
# eigen through the plan engine
# ---------------------------------------------------------------------------

def test_eigsh_precond_amg_matches_unpreconditioned():
    # anisotropic y-coupling breaks the square-grid eigenvalue degeneracy:
    # eigenVECTOR gradients scale as 1/(λ_i − λ_j), so on the plain
    # poisson2d grid (λ_ij = λ_ji pairs) BOTH gradients below would be
    # 1/gap garbage (same reason test_adjoint.py uses simple spectra)
    A0 = poisson2d(9)
    val = np.asarray(A0.val).copy()
    row, col = np.asarray(A0.row), np.asarray(A0.col)
    val[np.abs(row - col) == 1] *= 0.7
    val[row == col] = 2.0 + 2.0 * 0.7
    A = SparseTensor(jnp.asarray(val), A0.row, A0.col, A0.shape,
                     props=dict(A0.props), validate=False)
    w_ref = np.linalg.eigvalsh(np.asarray(A.todense()))

    reset_plan_stats()
    w, V = sla.eigsh(A, k=3, precond="amg", tol=1e-10, maxiter=500)
    np.testing.assert_allclose(np.asarray(w), w_ref[:3], rtol=1e-8)
    assert PLAN_STATS["analyze"] == 1 and PLAN_STATS["coarsen"] == 1

    wl, _ = sla.eigsh(A, k=2, precond="amg", largest=True, tol=1e-9,
                      maxiter=500, compute_vector_grads=False)
    np.testing.assert_allclose(np.sort(np.asarray(wl)), w_ref[-2:], rtol=1e-6)
    assert PLAN_STATS["analyze"] == 1      # second call reuses the plan

    # gradients: the preconditioner must not change WHAT is computed —
    # AD grad with precond="amg" matches the unpreconditioned AD grad
    # (FD on single COO entries breaks symmetry; see test_adjoint.py)
    a = jnp.asarray(np.random.default_rng(3).normal(size=A.shape[0]))

    def eloss(val, precond):
        w, V = sla.eigsh(A.with_values(val), k=2, precond=precond,
                         tol=1e-13, maxiter=2000)
        return 1.3 * w[0] + (V[1] @ a) ** 2

    g_pre = jax.grad(lambda v: eloss(v, "amg"))(A.val)
    g_ref = jax.grad(lambda v: eloss(v, None))(A.val)
    np.testing.assert_allclose(np.asarray(g_pre), np.asarray(g_ref),
                               rtol=1e-5, atol=1e-7)

    with pytest.raises(ValueError, match="lobpcg"):
        sla.eigsh(A, k=2, method="lanczos", precond="amg")


# ---------------------------------------------------------------------------
# the ISSUE acceptance case: n >= 1e4 mesh, one analyze end-to-end
# ---------------------------------------------------------------------------

def test_acceptance_p_laplacian_10k_one_analyze_grad_1e5():
    n = 10_000
    A = graph_laplacian(n, seed=7)
    assert A.shape[0] >= 10_000
    f = jnp.asarray(np.random.default_rng(11).normal(size=n)) * 1e-2
    p, eps_reg = 3.0, 1e-3

    def residual(u, th):
        # regularized p-Laplacian on the graph: edge flux φ(du) = |du|^{p-2}du
        # evaluated through the graph Laplacian's off-diagonal structure,
        # plus a θ-weighted cubic zero-order term
        return A @ u + th * ((u ** 2 + eps_reg) ** ((p - 2) / 2)) * u - f

    cfg = SolverConfig(backend="jnp", method="cg", precond="amg",
                       tol=1e-12, maxiter=600)
    reset_plan_stats()

    def loss(t):
        u = sla.nonlinear_solve(residual, jnp.zeros(n), t, jac_pattern=A,
                                linear_solver=cfg, tol=1e-11, maxiter=30)
        return jnp.sum(u ** 2)

    th = jnp.asarray(0.8)
    g = jax.grad(loss)(th)

    # ONE analyze across every Newton step AND the IFT backward
    assert PLAN_STATS["analyze"] == 1
    assert PLAN_STATS["jac_color"] == 1
    assert PLAN_STATS["transpose_shared"] == 1
    n_steps = PLAN_STATS["jac_assemble"]
    assert PLAN_STATS["galerkin"] == n_steps
    assert PLAN_STATS["setup_reuse"] >= 1

    # θ-gradient vs central FD to 1e-5 (dense autodiff would need an
    # 800 MB Jacobian at this size; FD on the same cached plan is exact
    # enough at x64).  The FD evaluations reuse the SAME pattern → still
    # one analyze at the end.
    eps = 1e-4
    fd = (loss(th + eps) - loss(th - eps)) / (2 * eps)
    assert PLAN_STATS["analyze"] == 1
    assert abs(float(g - fd)) / abs(float(fd)) < 1e-5
