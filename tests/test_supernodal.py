"""Supernodal direct factorization (PR 9 — dense panel kernels).

Covers: fundamental-supernode partition validity across structured and
unstructured patterns (the panel program reproduces the scalar packed-scan
factors in the SAME storage); panel-path solve parity vs the scalar path and
vs the dense backend at 1e-8; the static Bunch–Kaufman 2x2 pivot blocks on a
genuinely indefinite saddle-point system — solve + gradcheck through the
pair kernels with NO zero-pivot perturbation warning; slogdet through the
pair determinants; the ``supernodal`` option knob and its env override; plan
counters proving ONE symbolic analysis serves the solve, slogdet, and the
batched path; and the dense backend's batched-setup memo.
"""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import PLAN_STATS, SparseTensor, reset_plan_stats
from repro.core import options as _options
from repro.core.direct import factor_slogdet, factored_solve, \
    numeric_factor, symbolic_factor
from repro.data.graphs import graph_laplacian
from repro.data.poisson import poisson1d, poisson2d


def _random_pattern(n, nnz_per_row, seed):
    """Unsymmetric random sparse matrix with a dominant full diagonal."""
    rng = np.random.default_rng(seed)
    row = np.repeat(np.arange(n), nnz_per_row)
    col = rng.integers(0, n, size=row.size)
    row = np.concatenate([row, np.arange(n)])
    col = np.concatenate([col, np.arange(n)])
    val = rng.standard_normal(row.size)
    val[-n:] = 4.0 * nnz_per_row          # diagonal dominance
    return row, col, val, n


def _saddle(m, k, seed=1):
    """Indefinite saddle-point KKT system [[H, Bᵀ], [B, 0]] with the zero
    block kept structurally present (explicit zero diagonal values)."""
    rng = np.random.default_rng(seed)
    H = rng.standard_normal((m, m))
    H = H @ H.T + m * np.eye(m)
    B = rng.standard_normal((k, m))
    A = np.block([[H, B.T], [B, np.zeros((k, k))]])
    n = m + k
    mask = (np.abs(A) > 1e-12) | np.eye(n, dtype=bool)
    row, col = np.nonzero(mask)
    return row, col, A[row, col], A, n


# ---------------------------------------------------------------------------
# partition validity: the panel program reproduces the scalar factors in the
# same packed storage, across structured / unstructured / random patterns
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("case", ["poisson2d", "graph", "random"])
def test_partition_factor_parity_vs_scalar(case):
    if case == "poisson2d":
        A = poisson2d(16)
        row, col, val = (np.asarray(A.row), np.asarray(A.col),
                         np.asarray(A.val))
        n = A.shape[0]
    elif case == "graph":
        A = graph_laplacian(300, seed=3)
        row, col, val = (np.asarray(A.row), np.asarray(A.col),
                         np.asarray(A.val))
        n = A.shape[0]
    else:
        row, col, val, n = _random_pattern(200, 4, seed=7)

    art_on = symbolic_factor(row, col, n, supernodal="on")
    art_off = symbolic_factor(row, col, n, supernodal="off")
    assert art_on.snode is not None and art_off.snode is None
    st = art_on.stats
    assert st["n_snodes"] >= 1
    assert 1.0 <= st["mean_snode_width"]
    assert 0.0 <= st["panel_fraction"] <= 1.0

    v = jnp.asarray(val)
    C_on = numeric_factor(art_on, v)
    C_off = numeric_factor(art_off, v)
    # identical storage layout — the panel path writes the SAME C vector
    np.testing.assert_allclose(np.asarray(C_on), np.asarray(C_off),
                               rtol=1e-10, atol=1e-10)

    b = jnp.asarray(np.random.default_rng(0).standard_normal(n))
    for transposed in (False, True):
        x_on = factored_solve(art_on, C_on, b, transposed=transposed)
        x_off = factored_solve(art_off, C_off, b, transposed=transposed)
        np.testing.assert_allclose(np.asarray(x_on), np.asarray(x_off),
                                   rtol=1e-9, atol=1e-9)


def test_panel_solve_matches_dense_1e8():
    A = poisson2d(20)          # 400 dof
    b = jnp.asarray(np.random.default_rng(1).standard_normal(A.shape[0]))
    with _options.options(supernodal="on"):
        x = A.solve(b, backend="direct")
    xd = A.solve(b, backend="dense", method="cholesky")
    np.testing.assert_allclose(np.asarray(x), np.asarray(xd),
                               rtol=1e-10, atol=1e-8)


def test_auto_gate_declines_sequential_chain():
    # tridiagonal: every supernode is its own elimination level — one lane
    # per kernel launch would serialize; auto must keep the scalar scan
    A = poisson1d(2048)
    art = symbolic_factor(np.asarray(A.row), np.asarray(A.col), A.shape[0])
    assert art.snode is None
    # 2-D Poisson batches many lanes per level — auto emits
    B = poisson2d(40)
    art2 = symbolic_factor(np.asarray(B.row), np.asarray(B.col), B.shape[0])
    assert art2.snode is not None


# ---------------------------------------------------------------------------
# static Bunch–Kaufman 2x2 pivot blocks on an indefinite system
# ---------------------------------------------------------------------------

def test_bk_pairs_indefinite_no_perturbation_warning():
    row, col, val, A, n = _saddle(18, 8)
    b = np.random.default_rng(2).standard_normal(n)
    with warnings.catch_warnings():
        warnings.simplefilter("error")      # any perturbation warning fails
        art = symbolic_factor(row, col, n, pivot_blocks="auto")
        assert art.snode is not None
        assert art.snode.stats["n_pair_pivots"] > 0
        C = numeric_factor(art, jnp.asarray(val))
        x = factored_solve(art, C, jnp.asarray(b))
        xt = factored_solve(art, C, jnp.asarray(b), transposed=True)
    np.testing.assert_allclose(np.asarray(x), np.linalg.solve(A, b),
                               rtol=1e-8, atol=1e-8)
    np.testing.assert_allclose(np.asarray(xt), np.linalg.solve(A.T, b),
                               rtol=1e-8, atol=1e-8)


def test_bk_pairs_slogdet_sign():
    row, col, val, A, n = _saddle(14, 6, seed=5)
    art = symbolic_factor(row, col, n, pivot_blocks="auto")
    C = numeric_factor(art, jnp.asarray(val))
    s, l = factor_slogdet(art, C)
    sd, ld = np.linalg.slogdet(A)
    assert float(s) == sd
    np.testing.assert_allclose(float(l), ld, rtol=1e-10)


def test_bk_pairs_gradcheck_vs_dense():
    row, col, val, A, n = _saddle(12, 5, seed=9)
    b = jnp.asarray(np.random.default_rng(3).standard_normal(n))
    art = symbolic_factor(row, col, n, pivot_blocks="auto")

    def f_sparse(v):
        C = numeric_factor(art, v)
        return jnp.sum(factored_solve(art, C, b) ** 2)

    def f_dense(v):
        Ad = jnp.zeros((n, n)).at[row, col].add(v)
        return jnp.sum(jnp.linalg.solve(Ad, b) ** 2)

    g_s = jax.grad(f_sparse)(jnp.asarray(val))
    g_d = jax.grad(f_dense)(jnp.asarray(val))
    assert bool(jnp.all(jnp.isfinite(g_s)))
    np.testing.assert_allclose(np.asarray(g_s), np.asarray(g_d),
                               rtol=1e-6, atol=1e-8)


def test_indefinite_hint_routes_to_pairs():
    row, col, val, A, n = _saddle(16, 7, seed=11)
    T = SparseTensor(val, row, col, (n, n),
                     props={"indefinite_hint": True})
    b = jnp.asarray(np.random.default_rng(4).standard_normal(n))
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        x = T.solve(b, backend="direct", method="lu")
    np.testing.assert_allclose(np.asarray(x),
                               np.linalg.solve(A, np.asarray(b)),
                               rtol=1e-8, atol=1e-8)


def test_pairs_require_supernodal():
    A = poisson2d(8)
    with pytest.raises(ValueError, match="pivot_blocks"):
        symbolic_factor(np.asarray(A.row), np.asarray(A.col), A.shape[0],
                        supernodal="off", pivot_blocks="auto")


# ---------------------------------------------------------------------------
# options knob + env override
# ---------------------------------------------------------------------------

def test_supernodal_option_knob():
    assert _options.current().supernodal == "auto"
    with _options.options(supernodal="off"):
        A = poisson2d(24)
        art = symbolic_factor(np.asarray(A.row), np.asarray(A.col),
                              A.shape[0])
        assert art.snode is None
    with pytest.raises(ValueError, match="supernodal"):
        _options.Options(supernodal="sometimes")._validate()


def test_supernodal_env_override():
    out = _options._parse_env({"REPRO_SLA_SUPERNODAL": "ON"})
    assert out == {"supernodal": "on"}


# ---------------------------------------------------------------------------
# plan counters: one analysis serves solve + slogdet + batched; dense memo
# ---------------------------------------------------------------------------

def test_one_analysis_serves_solve_slogdet_batch():
    A = poisson2d(24)
    n = A.shape[0]
    b = jnp.asarray(np.random.default_rng(5).standard_normal(n))
    with _options.options(supernodal="on"):
        reset_plan_stats()
        x = A.solve(b, backend="direct")
        s, l = A.slogdet()
        g = jax.grad(lambda v: A.with_values(v).slogdet()[1])(A.val)
        V = jnp.stack([A.val, A.val * 2.0])
        XB = A.with_values(V).solve(jnp.stack([b, b]), backend="direct")
        assert PLAN_STATS["analyze"] == 1, dict(PLAN_STATS)
        # one factorization for the sweep+slogdet+backward, one for the batch
        assert PLAN_STATS["factorize"] == 2, dict(PLAN_STATS)
    assert float(jnp.linalg.norm(A @ x - b)) < 1e-9
    assert float(jnp.linalg.norm(A @ XB[0] - b)) < 1e-9
    assert float(jnp.linalg.norm(2.0 * (A @ XB[1]) - b)) < 1e-9
    assert bool(jnp.all(jnp.isfinite(g)))


def test_dense_backend_batched_setup_memo():
    A = poisson2d(6)            # tiny → dense backend
    n = A.shape[0]
    V = jnp.stack([A.val, A.val * 3.0])
    B = jnp.asarray(np.random.default_rng(6).standard_normal((2, n)))
    reset_plan_stats()
    X1 = A.with_values(V).solve(B, backend="dense", method="lu")
    setups_after_first = PLAN_STATS["setup"]
    X2 = A.with_values(V).solve(B, backend="dense", method="lu")
    # second call with the SAME stacked values array reuses the memoized
    # vmapped densification — no new setup
    assert PLAN_STATS["setup"] == setups_after_first, dict(PLAN_STATS)
    np.testing.assert_allclose(np.asarray(A @ X1[0]), np.asarray(B[0]),
                               atol=1e-9)
    np.testing.assert_allclose(np.asarray(3.0 * (A @ X1[1])),
                               np.asarray(B[1]), atol=1e-9)
    np.testing.assert_allclose(np.asarray(X1), np.asarray(X2))
