"""Flash-attention Pallas kernel vs oracle (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:       # bare env: seeded-sweep fallback, suite still collects
    from _hypothesis_compat import given, settings, st

from repro.kernels.flash_attention import flash_attention, flash_attention_ref


@settings(max_examples=10, deadline=None)
@given(bh=st.integers(1, 4), s_blocks=st.integers(1, 4),
       d=st.sampled_from([32, 64]), causal=st.booleans(),
       seed=st.integers(0, 99))
def test_flash_attention_sweep(bh, s_blocks, d, causal, seed):
    S = 64 * s_blocks
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(bh, S, d)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(bh, S, d)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(bh, S, d)).astype(np.float32))
    out = flash_attention(q, k, v, causal=causal, bq=64, bk=64)
    ref = flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_flash_attention_uneven_blocks():
    """KV longer than queries (cross-attention shape) + rectangular blocks."""
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(2, 128, 64)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(2, 256, 64)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(2, 256, 64)).astype(np.float32))
    out = flash_attention(q, k, v, causal=False, bq=64, bk=128)
    ref = flash_attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_flash_attention_matches_model_attention():
    """Kernel ≡ the model's chunked jnp attention for an MHA layer."""
    from repro.configs.base import ModelConfig
    from repro.models import attention as A

    cfg = ModelConfig(name="t", family="dense", n_layers=1, d_model=64,
                      n_heads=4, n_kv_heads=4, d_ff=0, vocab=16, head_dim=16,
                      dtype="float32", param_dtype="float32", remat="none",
                      qkv_bias=False)
    B, S, H, hd = 2, 128, 4, 16
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.normal(size=(B, S, H, hd)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(B, S, H, hd)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(B, S, H, hd)).astype(np.float32))
    # model path (no rope/proj — compare the score/softmax/PV core)
    s = A._gqa_scores(q, k, cfg).astype(jnp.float32)
    mask = jnp.arange(S)[None, :] <= jnp.arange(S)[:, None]
    s = jnp.where(mask[None, None], s, A.NEG_INF)
    p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    o_model = jnp.einsum("bhst,bthd->bshd", p, v)
    # kernel path
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    o_kern = flash_attention(qf, kf, vf, causal=True, bq=64, bk=64)
    o_kern = o_kern.reshape(B, H, S, hd).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(o_kern), np.asarray(o_model),
                               rtol=2e-5, atol=2e-5)
