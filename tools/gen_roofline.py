"""Generate the EXPERIMENTS.md §Roofline table from results/dryrun.jsonl."""
import json
import sys
from collections import defaultdict


def fmt_t(s):
    return f"{s*1e3:.1f}" if s < 10 else f"{s:.2f}e3"


def main(ledger="results/dryrun.jsonl", mesh="16x16", variant="baseline"):
    recs = [json.loads(l) for l in open(ledger)]
    cells = {}
    for r in recs:
        if r.get("rules", "baseline") == variant and r["mesh"] == mesh:
            cells[(r["arch"], r["shape"])] = r

    from repro.configs import ARCH_IDS, SHAPES

    print("| arch | shape | GiB/dev | t_comp ms | t_mem ms | t_coll ms | "
          "dominant | useful | roofline frac |")
    print("|---|---|--:|--:|--:|--:|---|--:|--:|")
    for arch in ARCH_IDS:
        for shape in SHAPES:
            r = cells.get((arch, shape))
            if r is None:
                continue
            if r["status"] == "skipped":
                print(f"| {arch} | {shape} | — | — | — | — | *skipped: "
                      f"full attention, quadratic at 524k* | — | — |")
                continue
            if r["status"] != "ok":
                print(f"| {arch} | {shape} | ERROR | | | | | | |")
                continue
            tc, tm, tl = (r["t_compute_s"], r["t_memory_s"],
                          r["t_collective_s"])
            dom = max(tc, tm, tl)
            frac = tc / dom if dom else 0.0
            useful = r.get("useful_ratio") or 0.0
            print(f"| {arch} | {shape} | {r['bytes_per_device']/2**30:.2f} | "
                  f"{tc*1e3:.1f} | {tm*1e3:.1f} | {tl*1e3:.1f} | "
                  f"{r['dominant']} | {useful*100:.0f}% | {frac:.2f} |")

    # mesh comparison summary
    multi = {(r["arch"], r["shape"]): r for r in recs
             if r.get("rules", "baseline") == variant
             and r["mesh"] == "2x16x16" and r["status"] == "ok"}
    print()
    print(f"Single-pod cells: {sum(1 for r in cells.values() if r['status']=='ok')} ok; "
          f"multi-pod cells: {len(multi)} ok.")


if __name__ == "__main__":
    main(*sys.argv[1:])
