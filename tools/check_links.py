"""Markdown link checker for the docs CI job — stdlib only, no network.

    python tools/check_links.py README.md docs/architecture.md ROADMAP.md ...

Checks every inline markdown link ``[text](target)``:

* local file targets must exist (resolved relative to the containing file);
* ``#anchor`` fragments pointing at a markdown file must match a heading in
  that file (GitHub slug rules: lowercase, spaces → ``-``, punctuation
  dropped);
* ``http(s)``/``mailto`` targets are recorded but NOT fetched (CI must not
  depend on the network); pass ``--list-external`` to print them.

Exits nonzero with a per-link report when anything is broken.
"""
from __future__ import annotations

import argparse
import re
import sys
from pathlib import Path

LINK_RE = re.compile(r"(?<!\!)\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
IMAGE_RE = re.compile(r"\!\[[^\]]*\]\(([^)\s]+)\)")
HEADING_RE = re.compile(r"^#{1,6}\s+(.*)$", re.MULTILINE)
CODE_FENCE_RE = re.compile(r"```.*?```", re.DOTALL)


def slugify(heading: str) -> str:
    """GitHub-style anchor slug: strip markdown/punctuation, lowercase,
    spaces to dashes."""
    h = re.sub(r"[`*_]", "", heading.strip()).lower()
    h = re.sub(r"[^\w\- ]", "", h)
    return h.replace(" ", "-")


def anchors_of(md_path: Path) -> set:
    text = md_path.read_text(encoding="utf-8")
    text = CODE_FENCE_RE.sub("", text)
    return {slugify(m.group(1)) for m in HEADING_RE.finditer(text)}


def check_file(md_path: Path, list_external: bool) -> list:
    errors = []
    text = md_path.read_text(encoding="utf-8")
    stripped = CODE_FENCE_RE.sub("", text)
    targets = [m.group(1) for m in LINK_RE.finditer(stripped)]
    targets += [m.group(1) for m in IMAGE_RE.finditer(stripped)]
    for target in targets:
        if target.startswith(("http://", "https://", "mailto:")):
            if list_external:
                print(f"  external: {md_path}: {target}")
            continue
        path_part, _, fragment = target.partition("#")
        if not path_part:                       # same-file #anchor
            dest = md_path
        else:
            dest = (md_path.parent / path_part).resolve()
            if not dest.exists():
                errors.append(f"{md_path}: broken link -> {target}")
                continue
        if fragment and dest.suffix.lower() in (".md", ".markdown"):
            if slugify(fragment) not in anchors_of(dest):
                errors.append(
                    f"{md_path}: missing anchor #{fragment} in {dest.name}")
    return errors


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("files", nargs="+", help="markdown files to check")
    ap.add_argument("--list-external", action="store_true",
                    help="print (but do not fetch) external URLs")
    args = ap.parse_args()
    errors = []
    n_links = 0
    for f in args.files:
        p = Path(f)
        if not p.exists():
            errors.append(f"{f}: file does not exist")
            continue
        stripped = CODE_FENCE_RE.sub("", p.read_text(encoding="utf-8"))
        n_links += len(LINK_RE.findall(stripped))
        errors.extend(check_file(p, args.list_external))
    if errors:
        print("\n".join(errors), file=sys.stderr)
        print(f"FAIL: {len(errors)} broken link(s)", file=sys.stderr)
        return 1
    print(f"OK: {len(args.files)} file(s), {n_links} link(s) checked")
    return 0


if __name__ == "__main__":
    sys.exit(main())
