"""Generate ``docs/api.md`` from the :mod:`repro.sla` docstrings.

    PYTHONPATH=src python tools/gen_api_ref.py            # rewrite docs/api.md
    PYTHONPATH=src python tools/gen_api_ref.py --check    # exit 1 on drift

Stdlib only (``inspect``) — no doc toolchain.  The rendered
file is CHECKED IN: the docs CI job runs without JAX installed, so it
verifies links in the committed ``docs/api.md`` rather than regenerating
it.  Re-run this script whenever the ``repro.sla`` surface or a public
docstring changes; ``--check`` makes drift visible locally.
"""
from __future__ import annotations

import argparse
import inspect
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO / "src"))

HEADER = """\
# `repro.sla` API reference

<!-- GENERATED FILE — do not edit by hand.
     Rebuild with: PYTHONPATH=src python tools/gen_api_ref.py -->
"""


def _doc(obj) -> str:
    doc = inspect.getdoc(obj)
    return doc.strip() if doc else "*(no docstring)*"


def _signature(obj) -> str:
    try:
        return str(inspect.signature(obj))
    except (TypeError, ValueError):
        return "(...)"


def _render_class(name: str, cls: type) -> list:
    lines = [f"### `{name}`", "", _doc(cls), ""]
    if hasattr(cls, "_fields"):          # NamedTuple: fields are the API
        lines += ["Fields: " + ", ".join(f"`{f}`" for f in cls._fields), ""]
        return lines
    methods = []
    for mname, m in sorted(vars(cls).items()):
        if mname.startswith("_") or not callable(m):
            continue
        if not inspect.getdoc(m):
            continue
        methods.append((mname, m))
    for mname, m in methods:
        first = _doc(m).split("\n\n")[0].replace("\n", " ")
        lines += [f"- **`.{mname}{_signature(m)}`** — {first}"]
    if methods:
        lines.append("")
    return lines


def _render_function(name: str, fn) -> list:
    return [f"### `{name}{_signature(fn)}`", "", _doc(fn), ""]


def render() -> str:
    import repro.sla as sla

    out = [HEADER]
    # the module docstring is the narrative front page
    out += [inspect.getdoc(sla).strip(), "", "---", ""]

    groups = [
        ("Tensors and plans",
         ["SparseTensor", "DSparseTensor", "SolverPlan", "get_plan"]),
        ("Solving",
         ["solve", "solve_with_info", "SolveResult", "SolverConfig",
          "register_backend"]),
        ("Nonlinear and eigen",
         ["nonlinear_solve", "SparseNewton", "eigsh"]),
        ("Options",
         ["Options", "set_options", "options", "get_options"]),
        ("Serving",
         ["serve", "SolveServer"]),
        ("Introspection",
         ["PLAN_STATS", "reset_plan_stats"]),
    ]
    grouped = {n for _, names in groups for n in names}
    missing = sorted(set(sla.__all__) - grouped)
    if missing:                      # new public names must pick a section
        raise SystemExit(f"gen_api_ref: ungrouped public names: {missing}")

    for title, names in groups:
        out += [f"## {title}", ""]
        for name in names:
            obj = getattr(sla, name)
            if inspect.isclass(obj):
                out += _render_class(name, obj)
            elif callable(obj):
                out += _render_function(name, obj)
            else:                    # plain objects (PLAN_STATS dict)
                desc = {
                    "PLAN_STATS": "Process-wide plan-lifecycle counters "
                    "(`analyze`, `setup`, `setup_reuse`, `factorize`, "
                    "`cache_hit`, `cache_miss`, `evictions`, ...) — read "
                    "them to verify amortization, reset with "
                    "`reset_plan_stats()`.",
                }.get(name, "*(module-level object)*")
                out += [f"### `{name}`", "", desc, ""]
    return "\n".join(out).rstrip() + "\n"


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--check", action="store_true",
                    help="exit 1 if docs/api.md is stale instead of writing")
    ap.add_argument("--out", default=str(REPO / "docs" / "api.md"))
    args = ap.parse_args()

    text = render()
    out = Path(args.out)
    if args.check:
        current = out.read_text(encoding="utf-8") if out.exists() else ""
        if current != text:
            print(f"{out} is stale — re-run: "
                  "PYTHONPATH=src python tools/gen_api_ref.py",
                  file=sys.stderr)
            return 1
        print(f"OK: {out} is up to date")
        return 0
    out.write_text(text, encoding="utf-8")
    print(f"wrote {out} ({len(text.splitlines())} lines)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
