"""Paper Fig. 2 + Table 7: adjoint vs naive backprop through k CG iterations.

Both paths share the same CG forward; ``naive`` reverse-differentiates a
``lax.scan``-unrolled CG (O(k) residual stack — the autograd-tracked PyTorch
analogue), ``adjoint`` is the O(1)-graph custom_vjp path.  We report backward
wall time and the *residual-stack bytes* of each path, extracted from the
jaxpr (the k-stacked scan outputs — the quantity that OOMs the paper's naive
path at k=2000), plus the App. D exact-agreement check at convergence.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import SparseTensor
from repro.core.solvers import cg_scan
from repro.data.poisson import poisson2d

from .common import csv_row, timeit

K_SWEEP = [10, 50, 100, 200, 500]
NG = 80    # 6400 DOF on CPU (paper: 640K on RTX 6000)


def residual_stack_bytes(jaxpr) -> int:
    """Sum k-stacked scan-output buffers (the saved-for-backward residuals)."""
    total = 0
    for eq in jaxpr.eqns:
        if eq.primitive.name == "scan":
            for v in eq.outvars:
                total += int(np.prod(v.aval.shape)) * v.aval.dtype.itemsize
        for sub in eq.params.get("jaxpr", ()), eq.params.get("call_jaxpr", ()):
            pass
    return total


def run(k_sweep=None):
    rows = []
    A = poisson2d(NG, dtype=np.float64)
    n = A.shape[0]
    b = jnp.ones(n)

    def naive_loss(k):
        def loss(val, bb):
            mv = lambda x: SparseTensor(val, A.row, A.col, A.shape,
                                        props=A.props, validate=False) @ x
            return jnp.sum(cg_scan(mv, bb, k) ** 2)
        return loss

    def adjoint_loss(maxiter):
        def loss(val, bb):
            x = A.with_values(val).solve(bb, backend="jnp", method="cg",
                                         tol=0.0, atol=1e-300,
                                         maxiter=maxiter)
            return jnp.sum(x ** 2)
        return loss

    for k in (k_sweep or K_SWEEP):
        g_naive = jax.jit(jax.grad(naive_loss(k), argnums=(0, 1)))
        g_adj = jax.jit(jax.grad(adjoint_loss(k), argnums=(0, 1)))
        tn, _ = timeit(g_naive, A.val, b)
        ta, _ = timeit(g_adj, A.val, b)
        mem_n = residual_stack_bytes(
            jax.make_jaxpr(jax.grad(naive_loss(k)))(A.val, b))
        mem_a = residual_stack_bytes(
            jax.make_jaxpr(jax.grad(adjoint_loss(k)))(A.val, b))
        rows.append(csv_row(
            f"fig2/naive/k={k}", tn * 1e6,
            f"stack_bytes={mem_n};"))
        rows.append(csv_row(
            f"fig2/adjoint/k={k}", ta * 1e6,
            f"stack_bytes={mem_a};ratio_time={tn/ta:.1f}x;"
            f"ratio_mem={mem_n/max(mem_a,1):.0f}x"))

    # ---- App. D: exact agreement at convergence on a small problem ----
    As = poisson2d(16, dtype=np.float64)   # 256 dof
    bs = jnp.ones(As.shape[0])
    k = 600
    mvs = lambda val, x: SparseTensor(val, As.row, As.col, As.shape,
                                      props=As.props, validate=False) @ x
    l_n = float(jnp.sum(cg_scan(lambda x: mvs(As.val, x), bs, k) ** 2))
    l_a = float(jnp.sum(As.solve(bs, backend="jnp", method="cg",
                                 tol=1e-14, maxiter=6000) ** 2))
    gn = jax.grad(lambda v, bb: jnp.sum(
        cg_scan(lambda x: mvs(v, x), bb, k) ** 2), (0, 1))(As.val, bs)
    ga = jax.grad(lambda v, bb: jnp.sum(
        As.with_values(v).solve(bb, backend="jnp", method="cg", tol=1e-14,
                                maxiter=6000) ** 2), (0, 1))(As.val, bs)
    loss_rel = abs(l_n - l_a) / abs(l_n)
    gb_rel = float(jnp.max(jnp.abs(ga[1] - gn[1]))
                   / jnp.max(jnp.abs(gn[1])))
    # matrix gradient on the symmetric tangent space (App. D convention)
    row, col = np.asarray(As.row), np.asarray(As.col)
    pair = {(int(r), int(c)): i for i, (r, c) in enumerate(zip(row, col))}
    mate = np.array([pair[(int(c), int(r))] for r, c in zip(row, col)])
    ga_s = np.asarray(ga[0]) + np.asarray(ga[0])[mate]
    gn_s = np.asarray(gn[0]) + np.asarray(gn[0])[mate]
    gA_rel = float(np.max(np.abs(ga_s - gn_s)) / np.max(np.abs(gn_s)))
    rows.append(csv_row("fig2/appD/loss_agreement", 0.0,
                        f"rel={loss_rel:.2e}"))
    rows.append(csv_row("fig2/appD/grad_b_agreement", 0.0, f"rel={gb_rel:.2e}"))
    rows.append(csv_row("fig2/appD/grad_A_agreement", 0.0, f"rel={gA_rel:.2e}"))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
