"""Paper Table 5: gradient verification for nonlinear and eigenvalue paths
vs central finite differences, with forward/backward cost in units of
forward operations (nonlinear: N Newton solves fwd → 1 adjoint solve bwd;
eigen: 1 LOBPCG fwd → outer product bwd)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import SparseTensor, nonlinear_solve
from repro.data.poisson import poisson1d, poisson2d

from .common import csv_row


def _aniso(ng, cy=0.3679):
    A = poisson2d(ng, dtype=np.float64)
    val = np.asarray(A.val).copy()
    row, col = np.asarray(A.row), np.asarray(A.col)
    val[np.abs(row - col) == 1] *= cy
    val[row == col] = 2.0 + 2.0 * cy
    return SparseTensor(val, row, col, A.shape)


def run():
    rows = []
    eps = 1e-5
    rng = np.random.default_rng(0)

    # ---- eigenvalue path (k=6, LOBPCG fwd, outer-product bwd) ----
    A = _aniso(12)

    def eig_loss(val):
        w, _ = A.with_values(val).eigsh(k=6, tol=1e-12, maxiter=3000,
                                        compute_vector_grads=False)
        return jnp.sum(w * jnp.arange(1.0, 7.0))

    g = jax.grad(eig_loss)(A.val)
    errs = []
    for e in rng.choice(A.nnz, 6, replace=False):
        fd = (eig_loss(A.val.at[e].add(eps))
              - eig_loss(A.val.at[e].add(-eps))) / (2 * eps)
        errs.append(abs(float(g[e]) - float(fd)) / max(abs(float(fd)), 1e-12))
    rows.append(csv_row("table5/eigenvalue_k6", 0.0,
                        f"rel_err={max(errs):.2e};fwd=1 LOBPCG;"
                        f"bwd=outer product"))

    # ---- nonlinear path (Newton fwd, 1 adjoint solve bwd) ----
    n = 96
    An = poisson1d(n)
    f = jnp.linspace(0.5, 1.5, n)

    def residual(u, val, ff):
        return An.with_values(val) @ u + u ** 3 - ff

    newton_iters = []

    def nl_loss(val, ff):
        u = nonlinear_solve(residual, jnp.zeros(n), val, ff,
                            method="newton", tol=1e-13)
        return jnp.sum(u ** 2)

    gv, gf = jax.grad(nl_loss, (0, 1))(An.val, f)
    errs = []
    for e in rng.choice(An.nnz, 6, replace=False):
        fd = (nl_loss(An.val.at[e].add(eps), f)
              - nl_loss(An.val.at[e].add(-eps), f)) / (2 * eps)
        errs.append(abs(float(gv[e]) - float(fd)) / max(abs(float(fd)), 1e-12))
    # count forward Newton iterations (each = 1 linear solve)
    from repro.core.solvers import newton_solve
    _, info = newton_solve(lambda u: residual(u, An.val, f), jnp.zeros(n),
                           tol=1e-13)
    rows.append(csv_row("table5/nonlinear_newton", 0.0,
                        f"rel_err={max(errs):.2e};"
                        f"fwd={int(info.iters)} solves;bwd=1 solve"))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
