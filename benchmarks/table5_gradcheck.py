"""Paper Table 5: gradient verification for nonlinear and eigenvalue paths
vs central finite differences, with forward/backward cost in units of
forward operations (nonlinear: N Newton solves fwd → 1 adjoint solve bwd;
eigen: 1 LOBPCG fwd → outer product bwd).

PR 10 adds the plan-engine rows, gated in CI by ``check_table5.py``:

* ``nonlinear_sparse_newton_{direct,amg}`` — SparseNewton IFT θ-gradients vs
  dense autodiff through an unrolled Newton loop, with the plan counters
  (``analyze``/``transpose_shared``/``factorize`` or ``galerkin``) recorded
  in the derived column so CI catches a re-analysis regression, not just a
  wrong number;
* ``eigen_amg_{smallest,largest}`` — ``sparse_eigsh`` with ``precond="amg"``
  routed through the same plan engine; eigenvalue gradients vs central FD
  (per-entry FD breaks COO symmetry, so the smallest-pair row additionally
  checks the eigenvector cotangent path against the unpreconditioned AD
  gradient).
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import SparseTensor, nonlinear_solve, sparse_eigsh
from repro.core.dispatch import PLAN_STATS, SolverConfig, reset_plan_stats
from repro.data.poisson import poisson1d, poisson2d

from .common import csv_row


def _aniso(ng, cy=0.3679):
    A = poisson2d(ng, dtype=np.float64)
    val = np.asarray(A.val).copy()
    row, col = np.asarray(A.row), np.asarray(A.col)
    val[np.abs(row - col) == 1] *= cy
    val[row == col] = 2.0 + 2.0 * cy
    return SparseTensor(val, row, col, A.shape)


def _fresh(A):
    """Same matrix, fresh plan cache — keeps PLAN_STATS attributable."""
    return SparseTensor(A.val, A.row, A.col, A.shape, props=dict(A.props),
                        validate=False)


def run(full: bool = False, smoke: bool = False):
    rows = []
    eps = 1e-5
    rng = np.random.default_rng(0)

    # ---- eigenvalue path (k=6, LOBPCG fwd, outer-product bwd) ----
    A = _aniso(12)

    def eig_loss(val):
        w, _ = A.with_values(val).eigsh(k=6, tol=1e-12, maxiter=3000,
                                        compute_vector_grads=False)
        return jnp.sum(w * jnp.arange(1.0, 7.0))

    g = jax.grad(eig_loss)(A.val)
    errs = []
    for e in rng.choice(A.nnz, 6, replace=False):
        fd = (eig_loss(A.val.at[e].add(eps))
              - eig_loss(A.val.at[e].add(-eps))) / (2 * eps)
        errs.append(abs(float(g[e]) - float(fd)) / max(abs(float(fd)), 1e-12))
    rows.append(csv_row("table5/eigenvalue_k6", 0.0,
                        f"rel_err={max(errs):.2e};fwd=1 LOBPCG;"
                        f"bwd=outer product"))

    # ---- nonlinear path (Newton fwd, 1 adjoint solve bwd) ----
    n = 96
    An = poisson1d(n)
    f = jnp.linspace(0.5, 1.5, n)

    def residual(u, val, ff):
        return An.with_values(val) @ u + u ** 3 - ff

    def nl_loss(val, ff):
        u = nonlinear_solve(residual, jnp.zeros(n), val, ff,
                            method="newton", tol=1e-13)
        return jnp.sum(u ** 2)

    gv, gf = jax.grad(nl_loss, (0, 1))(An.val, f)
    errs = []
    for e in rng.choice(An.nnz, 6, replace=False):
        fd = (nl_loss(An.val.at[e].add(eps), f)
              - nl_loss(An.val.at[e].add(-eps), f)) / (2 * eps)
        errs.append(abs(float(gv[e]) - float(fd)) / max(abs(float(fd)), 1e-12))
    # count forward Newton iterations (each = 1 linear solve)
    from repro.core.solvers import newton_solve
    _, info = newton_solve(lambda u: residual(u, An.val, f), jnp.zeros(n),
                           tol=1e-13)
    rows.append(csv_row("table5/nonlinear_newton", 0.0,
                        f"rel_err={max(errs):.2e};"
                        f"fwd={int(info.iters)} solves;bwd=1 solve"))

    # ---- SparseNewton IFT through the plan engine (PR 10) ----
    ng = 16 if full else (8 if smoke else 12)
    B = _aniso(ng)
    nB = B.shape[0]
    fB = jnp.linspace(0.5, 1.5, nB)

    def residualB(u, th):
        return B @ u + th * u ** 3 - fB

    def dense_unrolled(th):
        u = jnp.zeros(nB)
        for _ in range(25):
            F = residualB(u, th)
            J = jax.jacfwd(lambda uu: residualB(uu, th))(u)
            u = u - jnp.linalg.solve(J, F)
        return jnp.sum(u ** 2)

    th0 = jnp.asarray(0.7)
    g_ref = float(jax.grad(dense_unrolled)(th0))

    for tag, cfg in (("direct", SolverConfig(backend="direct")),
                     ("amg", SolverConfig(backend="jnp", method="cg",
                                          precond="amg", tol=1e-13,
                                          maxiter=800))):
        Bf = _fresh(B)

        def sn_loss(th):
            u = nonlinear_solve(lambda u, t: Bf @ u + t * u ** 3 - fB,
                                jnp.zeros(nB), th, jac_pattern=Bf,
                                linear_solver=cfg, tol=1e-13)
            return jnp.sum(u ** 2)

        reset_plan_stats()
        g = float(jax.grad(sn_loss)(th0))
        rel = abs(g - g_ref) / max(abs(g_ref), 1e-12)
        steps = PLAN_STATS["jac_assemble"]
        refresh = PLAN_STATS["factorize"] if tag == "direct" \
            else PLAN_STATS["galerkin"]
        rows.append(csv_row(
            f"table5/nonlinear_sparse_newton_{tag}", 0.0,
            f"rel_err={rel:.2e};n={nB};analyze={PLAN_STATS['analyze']};"
            f"transpose_shared={PLAN_STATS['transpose_shared']};"
            f"steps={steps};refresh={refresh};"
            f"fwd={steps} solves;bwd=1 solve"))

    # ---- eigenpairs with precond="amg" through the plan engine (PR 10) ----
    C = _aniso(16 if full else (8 if smoke else 12))

    def eig_amg_loss(val, largest):
        w, _ = sparse_eigsh(C.with_values(val), k=3, precond="amg",
                            largest=largest, tol=1e-12, maxiter=3000,
                            compute_vector_grads=False)
        return jnp.sum(w * jnp.arange(1.0, 4.0))

    for tag, largest in (("smallest", False), ("largest", True)):
        reset_plan_stats()
        g = jax.grad(lambda v: eig_amg_loss(v, largest))(C.val)
        analyze = PLAN_STATS["analyze"]
        errs = []
        for e in rng.choice(C.nnz, 6, replace=False):
            fd = (eig_amg_loss(C.val.at[e].add(eps), largest)
                  - eig_amg_loss(C.val.at[e].add(-eps), largest)) / (2 * eps)
            errs.append(abs(float(g[e]) - float(fd))
                        / max(abs(float(fd)), 1e-12))
        extra = ""
        if not largest:
            # eigenvector cotangents: preconditioned deflated CG vs the
            # unpreconditioned AD reference (FD breaks COO symmetry)
            a = jnp.asarray(rng.normal(size=C.shape[0]))

            def vec_loss(val, precond):
                w, V = sparse_eigsh(C.with_values(val), k=2, precond=precond,
                                    tol=1e-13, maxiter=3000)
                return 1.3 * w[0] + (V[1] @ a) ** 2

            gv_pre = jax.grad(lambda v: vec_loss(v, "amg"))(C.val)
            gv_ref = jax.grad(lambda v: vec_loss(v, None))(C.val)
            vec_err = float(jnp.max(jnp.abs(gv_pre - gv_ref))
                            / jnp.max(jnp.abs(gv_ref)))
            extra = f";vec_rel_err={vec_err:.2e}"
        rows.append(csv_row(
            f"table5/eigen_amg_{tag}", 0.0,
            f"rel_err={max(errs):.2e};n={C.shape[0]};analyze={analyze}"
            f"{extra};fwd=1 LOBPCG;bwd=outer product"))

    return rows


if __name__ == "__main__":
    print("\n".join(run()))
