"""Benchmark harness — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--full] [--only table3,fig2,...]
                                            [--json BENCH.json]

Prints ``name,us_per_call,derived`` CSV rows (the harness contract).
``--json`` additionally writes the rows as structured JSON (the CI
bench-smoke artifact).  A suite that raises still lets the others run, but
the process exits nonzero so CI goes red on any benchmark failure.
"""
import argparse
import json
import sys
import traceback

import jax

jax.config.update("jax_enable_x64", True)   # paper CPU baselines are f64


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale sizes (slower)")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized subset of each suite (minutes, not tens)")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset: "
                         "table3,table4,fig2,table5,fig3,spmv,serve")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write rows + failure count as JSON")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    print("name,us_per_call,derived")
    suites = []
    if only is None or "table3" in only:
        from . import table3_single_device
        suites.append(("table3", lambda: table3_single_device.run(
            args.full, smoke=args.smoke)))
    if only is None or "table4" in only:
        from . import table4_distributed
        suites.append(("table4", lambda: table4_distributed.run(
            args.full, smoke=args.smoke)))
    if only is None or "spmv" in only:
        from . import spmv
        suites.append(("spmv", lambda: spmv.run(args.full, smoke=args.smoke)))
    if only is None or "serve" in only:
        from . import serve
        suites.append(("serve", lambda: serve.run(args.full,
                                                  smoke=args.smoke)))
    if only is None or "fig2" in only:
        from . import fig2_adjoint_vs_naive
        suites.append(("fig2", fig2_adjoint_vs_naive.run))
    if only is None or "table5" in only:
        from . import table5_gradcheck
        suites.append(("table5", lambda: table5_gradcheck.run(
            args.full, smoke=args.smoke)))
    if only is None or "fig3" in only:
        from . import fig3_inverse
        steps = 1500 if args.full else 300
        suites.append(("fig3", lambda: fig3_inverse.run(steps=steps)))

    rows, errors = [], []
    for name, fn in suites:
        try:
            for row in fn():
                rows.append(row)
                print(row, flush=True)
        except Exception as e:  # report, keep the remaining suites running
            errors.append(f"{name}: {type(e).__name__}: {e}")
            print(f"{name}/ERROR,0,{type(e).__name__}: {e}", flush=True)
            traceback.print_exc(file=sys.stderr)

    if args.json:
        def parse(row: str) -> dict:
            name, us, derived = row.split(",", 2)
            return {"name": name, "us_per_call": float(us), "derived": derived}

        with open(args.json, "w") as f:
            json.dump({"rows": [parse(r) for r in rows],
                       "failures": len(errors), "errors": errors}, f, indent=1)

    # a failed suite MUST surface as a nonzero exit code — the CI bench job
    # gates on it (a swallowed traceback used to leave the job green)
    sys.exit(1 if errors else 0)


if __name__ == "__main__":
    main()
