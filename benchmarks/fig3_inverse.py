"""Paper Fig. 3 / §4.4: inverse coefficient learning on variable-coefficient
Poisson, 64×64 grid, κ* = 1 + 0.5·sin(2πx)sin(2πy), f ≡ 1, Adam,
Tikhonov-regularized.  Reports final relative L2 error (paper: 2.3e-3 after
1500 steps) and ms/step.  ``--steps`` trims for CI speed.
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.poisson import poisson2d_vc
from repro.optim.adamw import AdamWConfig, adamw_update, init_opt_state

from .common import csv_row


def run(ng: int = 64, steps: int = 400, lr: float = 5e-2,
        use_stencil_kernel: bool = False):
    xs = jnp.linspace(0, 1, ng)
    X, Y = jnp.meshgrid(xs, xs, indexing="ij")
    kappa_true = 1.0 + 0.5 * jnp.sin(2 * jnp.pi * X) * jnp.sin(2 * jnp.pi * Y)
    h = 1.0 / (ng + 1)
    f = jnp.ones(ng * ng) * h * h      # physical scaling: A/h² u = f
    u_obs = poisson2d_vc(kappa_true).solve(f, backend="jnp", method="cg",
                                           tol=1e-12, maxiter=20000)

    def loss_fn(theta):
        kappa = jax.nn.softplus(theta)
        A = poisson2d_vc(kappa, use_stencil_kernel=use_stencil_kernel)
        u = A.solve(f, backend="stencil" if use_stencil_kernel else "jnp",
                    method="cg", tol=1e-11, maxiter=20000)
        data = jnp.sum((u - u_obs) ** 2)
        gx = jnp.diff(kappa, axis=0)
        gy = jnp.diff(kappa, axis=1)
        reg = 1e-3 * (jnp.sum(gx ** 2) + jnp.sum(gy ** 2)) / (ng * ng)
        return data + reg

    theta = jnp.zeros((ng, ng)) + jnp.log(jnp.exp(1.0) - 1)
    opt_cfg = AdamWConfig(lr=lr, b2=0.999, weight_decay=0.0, warmup_steps=0,
                          total_steps=steps, schedule="constant",
                          grad_clip=0.0)
    state = init_opt_state(theta)
    vg = jax.jit(jax.value_and_grad(loss_fn))
    t0 = time.perf_counter()
    losses = []
    for s in range(steps):
        l, g = vg(theta)
        theta, state, _ = adamw_update(opt_cfg, theta, g, state)
        losses.append(float(l))
    dt = time.perf_counter() - t0
    kappa = jax.nn.softplus(theta)
    rel = float(jnp.linalg.norm(kappa - kappa_true)
                / jnp.linalg.norm(kappa_true))
    u_final = poisson2d_vc(kappa).solve(f, backend="jnp", method="cg",
                                        tol=1e-12, maxiter=20000)
    urel = float(jnp.linalg.norm(u_final - u_obs) / jnp.linalg.norm(u_obs))
    krange = (float(kappa.min()), float(kappa.max()))
    return [csv_row(
        f"fig3/inverse_ng{ng}_steps{steps}", dt / steps * 1e6,
        f"kappa_rel_l2={rel:.2e};u_rel_l2={urel:.2e};"
        f"kappa_range=[{krange[0]:.3f},{krange[1]:.3f}];"
        f"loss0={losses[0]:.2e};lossN={losses[-1]:.2e}")]


if __name__ == "__main__":
    import sys
    steps = int(sys.argv[1]) if len(sys.argv) > 1 else 400
    print("\n".join(run(steps=steps)))
