"""Paper Table 3: single-device backend comparison on 2D Poisson.

The paper's ladder (10K → 169M DOF, H200, f64) becomes a CPU-scaled ladder;
the *dispatch behaviour* is what is reproduced: direct backends win small,
iterative CG scales with O(nnz) memory, and the crossover matches the
auto-dispatch policy constants.  Columns: backend time, peak-memory estimate,
final residual — mirroring the paper's layout.  The ``direct`` rows exercise
the cuDSS-analogue sparse LDLᵀ path (cached symbolic factorization, packed
level-scheduled numeric kernel) up to the ``direct_budget`` crossover.

``analyze_*`` rows time the symbolic stage itself — the cost every
``symbolic_factor`` consumer (direct solves, ``precond="ilu"``, the AMG
coarsest level, ``slogdet``) pays once per pattern: ``analyze_amd`` is the
production quotient-graph-AMD + etree pipeline, ``analyze_md`` the retained
exact-minimum-degree A/B path (smaller rungs only — exact MD is the cost
the AMD pipeline replaced).  These rows flow into the bench-smoke
``table3.csv`` / ``BENCH_table3.json`` CI artifacts, so the analyze-time
trajectory is tracked per PR.
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dispatch import make_config, get_plan
from repro.core.options import current as _current_options
from repro.core.adjoint import sparse_solve_with_info
from repro.core.direct import symbolic_factor
from repro.data.poisson import poisson2d, poisson2d_vc

from .common import csv_row, timeit

MD_ANALYZE_CAP = 10_000      # exact-MD A/B rung cap: the n=10⁴ rung is the
                             # ISSUE-5 acceptance point (seed path ~14 s)

SMOKE_LADDER = [32, 100]                # 1K, 10K DOF — per-PR CI smoke
LADDER = [32, 100, 200, 400]            # 1K, 10K, 40K, 160K DOF
FULL_LADDER = LADDER + [1000]           # +1M DOF with --full


def mem_estimate_bytes(n, nnz, dtype_bytes=8):
    """CG working set: COO (2×int32 + val) + 5 vectors (x,r,p,Ap,diag)."""
    return nnz * (8 + dtype_bytes) + 5 * n * dtype_bytes


def run(full: bool = False, smoke: bool = False):
    rows = []
    opts = _current_options()
    DENSE_BUDGET, DIRECT_BUDGET = opts.dense_budget, opts.direct_budget
    ladder = SMOKE_LADDER if smoke else (FULL_LADDER if full else LADDER)
    for ng in ladder:
        n = ng * ng
        A = poisson2d(ng, dtype=np.float64)
        b = jnp.ones(n)

        entries = {}
        if n <= DENSE_BUDGET * 4:
            cfg_d = make_config(A, backend="dense", method="cholesky")
            t, (x, info) = timeit(
                jax.jit(lambda val, bb: sparse_solve_with_info(
                    cfg_d, A.with_values(val), bb)), A.val, b)
            entries["dense"] = (t, float(info.resnorm))
        # explicit backend="direct" tolerates a bigger one-time analyze than
        # the silent auto window — benchmark up to twice the auto budget
        if n <= 2 * DIRECT_BUDGET:
            # symbolic-analyze time: the stage is paid once per pattern, so
            # a single sample IS the amortized reality — and the SAME plan
            # the timed get_plan analyzes then serves the direct solve rows
            # below (no duplicate analysis).  Exact-MD A/B rung on the
            # smaller sizes (it is the cost the AMD pipeline replaced).
            cfg_s = make_config(A, backend="direct")
            t0 = time.perf_counter()
            plan = get_plan(A, cfg_s)      # symbolic analysis (once, eager)
            t_amd = time.perf_counter() - t0
            st_a = plan.artifacts["direct"].stats
            entries["analyze_amd"] = (
                t_amd, 0.0,
                f"nnzL={st_a['nnz_L']};levels={st_a['n_levels']}")
            if n <= MD_ANALYZE_CAP:
                t0 = time.perf_counter()
                art_m = symbolic_factor(np.asarray(A.row), np.asarray(A.col),
                                        n, ordering="md")
                t_md = time.perf_counter() - t0
                entries["analyze_md"] = (
                    t_md, 0.0,
                    f"nnzL={art_m.stats['nnz_L']};"
                    f"fill_vs_amd={st_a['nnz_L']/max(art_m.stats['nnz_L'], 1):.3f}")
            t, (x, info) = timeit(
                jax.jit(lambda val, bb: sparse_solve_with_info(
                    cfg_s, A.with_values(val), bb)), A.val, b)
            st = plan.artifacts["direct"].stats
            entries["direct"] = (t, float(info.resnorm),
                                 f"nnzL={st['nnz_L']};levels={st['n_levels']}")
        cfg_cg = make_config(A, backend="jnp", method="cg", tol=1e-7,
                             maxiter=20000)
        t, (x, info) = timeit(
            jax.jit(lambda val, bb: sparse_solve_with_info(
                cfg_cg, A.with_values(val), bb)), A.val, b)
        entries["cg_jnp"] = (t, float(info.resnorm))
        # stencil-kernel CG (the Pallas path, interpret mode on CPU)
        kappa = jnp.ones((ng, ng))
        Ak = poisson2d_vc(kappa, use_stencil_kernel=True)
        cfg_k = make_config(Ak, backend="stencil", method="cg", tol=1e-7,
                            maxiter=20000)
        t, (x, info) = timeit(
            jax.jit(lambda val, bb: sparse_solve_with_info(
                cfg_k, Ak.with_values(val), bb)), Ak.val, b)
        entries["cg_stencil"] = (t, float(info.resnorm))
        # preconditioner ladder on the SAME operator: iterations + time for
        # jacobi / ilu / geometric mg (stencil) / algebraic amg (COO) — the
        # PR-4 rows; analyze cost is paid once before timing (plan cached).
        # Capped like the direct rows: the eager ILU/AMG symbolic pass is
        # python-loop-bound, so the biggest ladder rungs skip it.
        if n <= 2 * DIRECT_BUDGET:
            for pname, At, cfg_p in (
                    ("jacobi", A, cfg_cg),
                    ("ilu", A, make_config(A, backend="jnp", method="cg",
                                           tol=1e-7, maxiter=20000,
                                           precond="ilu")),
                    ("mg", Ak, make_config(Ak, backend="stencil",
                                           method="cg", tol=1e-7,
                                           maxiter=20000, precond="mg")),
                    ("amg", A, make_config(A, backend="jnp", method="cg",
                                           tol=1e-7, maxiter=20000,
                                           precond="amg"))):
                get_plan(At, cfg_p)        # symbolic analysis (once, eager)
                t, (x, info) = timeit(
                    jax.jit(lambda val, bb, At=At, cfg_p=cfg_p:
                            sparse_solve_with_info(
                                cfg_p, At.with_values(val), bb)), At.val, b)
                entries[f"precond_{pname}"] = (t, float(info.resnorm),
                                               f"iters={int(info.iters)}")

        mem = mem_estimate_bytes(n, A.nnz)
        for name, entry in entries.items():
            t, res = entry[0], entry[1]
            extra = f";{entry[2]}" if len(entry) > 2 else ""
            rows.append(csv_row(
                f"table3/{name}/dof={n}", t * 1e6,
                f"residual={res:.1e};mem_est={mem/2**20:.1f}MiB{extra}"))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
