"""Paper Table 3: single-device backend comparison on 2D Poisson.

The paper's ladder (10K → 169M DOF, H200, f64) becomes a CPU-scaled ladder;
the *dispatch behaviour* is what is reproduced: direct backends win small,
iterative CG scales with O(nnz) memory, and the crossover matches the
auto-dispatch policy constants.  Columns: backend time, peak-memory estimate,
final residual — mirroring the paper's layout.  The ``direct`` rows exercise
the cuDSS-analogue sparse LDLᵀ path (cached symbolic factorization, packed
level-scheduled numeric kernel) up to the ``direct_budget`` crossover.

``analyze_*`` rows time the symbolic stage itself — the cost every
``symbolic_factor`` consumer (direct solves, ``precond="ilu"``, the AMG
coarsest level, ``slogdet``) pays once per pattern: ``analyze_amd`` is the
production quotient-graph-AMD + etree pipeline, ``analyze_md`` the retained
exact-minimum-degree A/B path (smaller rungs only — exact MD is the cost
the AMD pipeline replaced).  These rows flow into the bench-smoke
``table3.csv`` / ``BENCH_table3.json`` CI artifacts, so the analyze-time
trajectory is tracked per PR.
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dispatch import make_config, get_plan
from repro.core.options import current as _current_options
from repro.core.adjoint import sparse_solve_with_info
from repro.core.direct import symbolic_factor
from repro.data.poisson import poisson2d, poisson2d_vc

from .common import csv_row, timeit

MD_ANALYZE_CAP = 10_000      # exact-MD A/B rung cap: the n=10⁴ rung is the
                             # ISSUE-5 acceptance point (seed path ~14 s)

# eager-analysis row cap: direct_budget is now 10⁵ (the supernodal panel
# kernels moved the crossover), but the bench still bounds the rungs that
# pay the one-time python symbolic pass so the CI smoke stays minutes-sized;
# the budget itself is exercised by the budget_probe row below
DIRECT_ROW_CAP = 40_000

SMOKE_LADDER = [32, 100]                # 1K, 10K DOF — per-PR CI smoke
LADDER = [32, 100, 200, 400]            # 1K, 10K, 40K, 160K DOF
FULL_LADDER = LADDER + [1000]           # +1M DOF with --full
BUDGET_PROBE_NG = 200                   # 40K DOF: above the OLD 24576 budget
                                        # — auto-dispatch must pick direct
                                        # under the raised 10⁵ budget


def mem_estimate_bytes(n, nnz, dtype_bytes=8):
    """CG working set: COO (2×int32 + val) + 5 vectors (x,r,p,Ap,diag)."""
    return nnz * (8 + dtype_bytes) + 5 * n * dtype_bytes


def run(full: bool = False, smoke: bool = False):
    rows = []
    opts = _current_options()
    DENSE_BUDGET, DIRECT_BUDGET = opts.dense_budget, opts.direct_budget
    ladder = SMOKE_LADDER if smoke else (FULL_LADDER if full else LADDER)
    for ng in ladder:
        n = ng * ng
        A = poisson2d(ng, dtype=np.float64)
        b = jnp.ones(n)

        entries = {}
        if n <= DENSE_BUDGET * 4:
            cfg_d = make_config(A, backend="dense", method="cholesky")
            t, (x, info) = timeit(
                jax.jit(lambda val, bb: sparse_solve_with_info(
                    cfg_d, A.with_values(val), bb)), A.val, b)
            entries["dense"] = (t, float(info.resnorm))
        # explicit backend="direct" rows pay the one-time eager analyze —
        # bounded by the bench-local cap, not the (now much larger) budget
        if n <= DIRECT_ROW_CAP:
            # symbolic-analyze time: the stage is paid once per pattern, so
            # a single sample IS the amortized reality — and the SAME plan
            # the timed get_plan analyzes then serves the direct solve rows
            # below (no duplicate analysis).  Exact-MD A/B rung on the
            # smaller sizes (it is the cost the AMD pipeline replaced).
            cfg_s = make_config(A, backend="direct")
            t0 = time.perf_counter()
            plan = get_plan(A, cfg_s)      # symbolic analysis (once, eager)
            t_amd = time.perf_counter() - t0
            st_a = plan.artifacts["direct"].stats
            entries["analyze_amd"] = (
                t_amd, 0.0,
                f"nnzL={st_a['nnz_L']};levels={st_a['n_levels']}")
            if n <= MD_ANALYZE_CAP:
                t0 = time.perf_counter()
                art_m = symbolic_factor(np.asarray(A.row), np.asarray(A.col),
                                        n, ordering="md")
                t_md = time.perf_counter() - t0
                entries["analyze_md"] = (
                    t_md, 0.0,
                    f"nnzL={art_m.stats['nnz_L']};"
                    f"fill_vs_amd={st_a['nnz_L']/max(art_m.stats['nnz_L'], 1):.3f}")
            # eager: the supernodal numeric drivers jit per panel bucket;
            # an outer jit would inline every bucket into one giant XLA
            # program (minutes of compile at the larger rungs)
            t, (x, info) = timeit(
                lambda val, bb: sparse_solve_with_info(
                    cfg_s, A.with_values(val), bb), A.val, b)
            st = plan.artifacts["direct"].stats
            entries["direct"] = (t, float(info.resnorm),
                                 f"nnzL={st['nnz_L']};levels={st['n_levels']}")
            # supernodal vs scalar A/B on the numeric stage itself: the same
            # pattern analyzed twice (panel program / packed scan), factorize
            # and the triangular solves timed on each — the PR-9 headline
            from repro.core.direct import factored_solve, numeric_factor
            art_sn = symbolic_factor(np.asarray(A.row), np.asarray(A.col),
                                     n, supernodal="on")
            art_sc = symbolic_factor(np.asarray(A.row), np.asarray(A.col),
                                     n, supernodal="off")
            t_fs, C_sn = timeit(
                lambda v: numeric_factor(art_sn, v), A.val)
            t_fc, C_sc = timeit(jax.jit(
                lambda v: numeric_factor(art_sc, v)), A.val)
            t_ss, _ = timeit(
                lambda C, bb: factored_solve(art_sn, C, bb), C_sn, b)
            t_sc, _ = timeit(jax.jit(
                lambda C, bb: factored_solve(art_sc, C, bb)), C_sc, b)
            sn_st = art_sn.snode.stats if art_sn.snode is not None else {}
            entries["factor_supernodal"] = (
                t_fs, 0.0,
                f"speedup={t_fc / max(t_fs, 1e-12):.2f}x;"
                f"panel_fraction={sn_st.get('panel_fraction', 0.0):.3f};"
                f"mean_snode_width={sn_st.get('mean_snode_width', 0.0):.2f}")
            entries["factor_scalar"] = (t_fc, 0.0)
            entries["solve_supernodal"] = (
                t_ss, 0.0, f"speedup={t_sc / max(t_ss, 1e-12):.2f}x")
            entries["solve_scalar"] = (t_sc, 0.0)
        cfg_cg = make_config(A, backend="jnp", method="cg", tol=1e-7,
                             maxiter=20000)
        t, (x, info) = timeit(
            jax.jit(lambda val, bb: sparse_solve_with_info(
                cfg_cg, A.with_values(val), bb)), A.val, b)
        entries["cg_jnp"] = (t, float(info.resnorm))
        # stencil-kernel CG (the Pallas path, interpret mode on CPU)
        kappa = jnp.ones((ng, ng))
        Ak = poisson2d_vc(kappa, use_stencil_kernel=True)
        cfg_k = make_config(Ak, backend="stencil", method="cg", tol=1e-7,
                            maxiter=20000)
        t, (x, info) = timeit(
            jax.jit(lambda val, bb: sparse_solve_with_info(
                cfg_k, Ak.with_values(val), bb)), Ak.val, b)
        entries["cg_stencil"] = (t, float(info.resnorm))
        # preconditioner ladder on the SAME operator: iterations + time for
        # jacobi / ilu / geometric mg (stencil) / algebraic amg (COO) — the
        # PR-4 rows; analyze cost is paid once before timing (plan cached).
        # Capped like the direct rows: the eager ILU/AMG symbolic pass is
        # python-loop-bound, so the biggest ladder rungs skip it.
        if n <= DIRECT_ROW_CAP:
            for pname, At, cfg_p in (
                    ("jacobi", A, cfg_cg),
                    ("ilu", A, make_config(A, backend="jnp", method="cg",
                                           tol=1e-7, maxiter=20000,
                                           precond="ilu")),
                    ("mg", Ak, make_config(Ak, backend="stencil",
                                           method="cg", tol=1e-7,
                                           maxiter=20000, precond="mg")),
                    ("amg", A, make_config(A, backend="jnp", method="cg",
                                           tol=1e-7, maxiter=20000,
                                           precond="amg"))):
                get_plan(At, cfg_p)        # symbolic analysis (once, eager)
                t, (x, info) = timeit(
                    jax.jit(lambda val, bb, At=At, cfg_p=cfg_p:
                            sparse_solve_with_info(
                                cfg_p, At.with_values(val), bb)), At.val, b)
                entries[f"precond_{pname}"] = (t, float(info.resnorm),
                                               f"iters={int(info.iters)}")

        mem = mem_estimate_bytes(n, A.nnz)
        for name, entry in entries.items():
            t, res = entry[0], entry[1]
            extra = f";{entry[2]}" if len(entry) > 2 else ""
            rows.append(csv_row(
                f"table3/{name}/dof={n}", t * 1e6,
                f"residual={res:.1e};mem_est={mem/2**20:.1f}MiB{extra}"))

    # budget probe: n=40K sits ABOVE the pre-supernodal 24576 crossover —
    # auto dispatch must now route it to the direct backend (budget 10⁵)
    # and the solve must complete; the bench-smoke gate checks this row
    from repro.core.dispatch import select_backend
    Ap = poisson2d(BUDGET_PROBE_NG, dtype=np.float64)
    np_ = Ap.shape[0]
    backend, method = select_backend(Ap, "auto", "auto")
    cfg_b = make_config(Ap, backend=backend, method=method)
    bp = jnp.ones(np_)
    # eager (no outer jit): the supernodal drivers jit per panel bucket —
    # wrapping the whole 40K-DOF solve in one jit would inline every bucket
    # into a single giant XLA program and spend minutes compiling it
    t, (x, info) = timeit(
        lambda val, bb: sparse_solve_with_info(
            cfg_b, Ap.with_values(val), bb), Ap.val, bp)
    rows.append(csv_row(
        f"table3/budget_probe/dof={np_}", t * 1e6,
        f"residual={float(info.resnorm):.1e};backend={backend};"
        f"budget={DIRECT_BUDGET}"))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
