"""Bench-smoke gate over the table5 artifact (CI goes red on regression).

    PYTHONPATH=src python -m benchmarks.check_table5 BENCH_table5.json

Asserts the PR-10 gradcheck claims hold on every run:

- every gradcheck row's ``rel_err`` (AD vs central FD, or AD vs the dense
  unrolled-Newton reference) stays under ``MAX_REL_ERR``;
- both SparseNewton rows (``direct`` and ``amg`` inner solvers) are present
  with ``analyze == 1`` and ``transpose_shared == 1`` — one symbolic
  analysis serves the whole Newton sweep plus its IFT backward — and the
  per-step numeric refresh count equals the step count, never more;
- both preconditioned eigen rows are present (including ``largest``); the
  ``smallest`` row analyzes the pattern exactly once and the ``largest``
  row — same tensor, later in the run — shows ``analyze == 0`` (the cached
  plan served it); the smallest-pair row's eigenvector-cotangent check
  (``vec_rel_err``) also clears the gate.
"""
import json
import sys

MAX_REL_ERR = 1e-5

REQUIRED = (
    "table5/eigenvalue_k6",
    "table5/nonlinear_newton",
    "table5/nonlinear_sparse_newton_direct",
    "table5/nonlinear_sparse_newton_amg",
    "table5/eigen_amg_smallest",
    "table5/eigen_amg_largest",
)


def _derived(row):
    return dict(kv.split("=", 1) for kv in row["derived"].split(";")
                if "=" in kv)


def check(path: str) -> None:
    with open(path) as f:
        data = json.load(f)
    by_name = {r["name"]: r for r in data["rows"]}

    missing = [n for n in REQUIRED if n not in by_name]
    if missing:
        raise SystemExit(f"check_table5: missing rows {missing}")

    for name in REQUIRED:
        d = _derived(by_name[name])
        rel = float(d.get("rel_err", "inf"))
        if not rel < MAX_REL_ERR:
            raise SystemExit(f"check_table5: {name} rel_err {rel:.1e} >= "
                             f"{MAX_REL_ERR:.0e}")
        print(f"check_table5: {name} rel_err={rel:.1e} ok")

    for tag in ("direct", "amg"):
        d = _derived(by_name[f"table5/nonlinear_sparse_newton_{tag}"])
        if d.get("analyze") != "1" or d.get("transpose_shared") != "1":
            raise SystemExit(
                f"check_table5: sparse_newton_{tag} plan counters regressed "
                f"(analyze={d.get('analyze')}, "
                f"transpose_shared={d.get('transpose_shared')}; expected 1/1 "
                f"across the Newton sweep AND the IFT backward)")
        if d.get("refresh") != d.get("steps"):
            raise SystemExit(
                f"check_table5: sparse_newton_{tag} refreshed "
                f"{d.get('refresh')} times for {d.get('steps')} Newton steps "
                f"— the setup memo should make these equal")
        print(f"check_table5: sparse_newton_{tag} counters ok "
              f"(analyze=1, transpose_shared=1, "
              f"refresh=steps={d.get('steps')})")

    # the two eigen rows share one tensor: smallest analyzes the pattern,
    # largest must hit the cached plan (analyze == 0) — both counts regress
    # if the eigsh path stops routing through the plan engine
    for tag, want in (("smallest", "1"), ("largest", "0")):
        d = _derived(by_name[f"table5/eigen_amg_{tag}"])
        if d.get("analyze") != want:
            raise SystemExit(f"check_table5: eigen_amg_{tag} analyze="
                             f"{d.get('analyze')}, expected {want}")
    d = _derived(by_name["table5/eigen_amg_smallest"])
    vec = float(d.get("vec_rel_err", "inf"))
    if not vec < MAX_REL_ERR:
        raise SystemExit(f"check_table5: eigen_amg_smallest vec_rel_err "
                         f"{vec:.1e} >= {MAX_REL_ERR:.0e}")
    print(f"check_table5: eigen rows ok (analyze=1 then cached, "
          f"vec_rel_err={vec:.1e})")


if __name__ == "__main__":
    check(sys.argv[1] if len(sys.argv) > 1 else "BENCH_table5.json")
