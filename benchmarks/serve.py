"""Serving-driver benchmark: batched vmapped dispatch vs one-at-a-time loop.

Runs :func:`repro.launch.solve_serve.serve` on the shared-pattern smoke
workload and reports p50/p99 request latency, solves/sec for both drivers,
the speedup, and batch-group occupancy.  The suite RAISES (→ nonzero exit →
CI red) unless the batched driver achieves ≥ 2× solves/sec over the
sequential loop with exactly one pattern analysis across the whole run —
the PR-7 acceptance gate, recorded in ``BENCH_serve.json``.
"""
from repro.launch.solve_serve import serve

from .common import csv_row

SPEEDUP_GATE = 2.0


def run(full: bool = False, smoke: bool = False):
    n_requests, grid = (64, 20) if smoke else (256, 32)
    if full:
        n_requests, grid = 512, 48
    rep = serve(n_requests=n_requests, grid=grid, n_patterns=1, max_batch=32)

    rows = []
    b, s = rep["batched"], rep["sequential"]
    rows.append(csv_row(
        f"serve/batched/req={n_requests}", 1e6 / b["solves_per_sec"],
        f"solves_per_sec={b['solves_per_sec']:.1f};"
        f"p50_ms={b['p50_ms']:.2f};p99_ms={b['p99_ms']:.2f};"
        f"occupancy={rep['occupancy']:.3f}"))
    rows.append(csv_row(
        f"serve/sequential/req={n_requests}", 1e6 / s["solves_per_sec"],
        f"solves_per_sec={s['solves_per_sec']:.1f};"
        f"p50_ms={s['p50_ms']:.2f};p99_ms={s['p99_ms']:.2f}"))
    rows.append(csv_row(
        "serve/speedup", 0.0,
        f"ratio={rep['speedup']:.2f};gate={SPEEDUP_GATE:.1f};"
        f"analyze={rep['plan_stats']['analyze']};"
        f"patterns={rep['n_patterns']};converged={rep['converged']}"))

    analyze = rep["plan_stats"]["analyze"]
    if analyze != rep["n_patterns"]:
        raise AssertionError(
            f"expected one analyze per pattern ({rep['n_patterns']}), "
            f"got {analyze} — plan amortization regressed")
    if rep["speedup"] < SPEEDUP_GATE:
        raise AssertionError(
            f"batched serving speedup {rep['speedup']:.2f}x below the "
            f"{SPEEDUP_GATE:.1f}x gate (batched {b['solves_per_sec']:.1f} "
            f"vs sequential {s['solves_per_sec']:.1f} solves/sec)")
    if not rep["converged"]:
        raise AssertionError("batched serving produced unconverged solves")
    return rows
