"""Shared benchmark utilities."""
import time

import jax


def timeit(fn, *args, warmup: int = 1, iters: int = 3):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
        jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters, out


def csv_row(name: str, us_per_call: float, derived: str = "") -> str:
    return f"{name},{us_per_call:.1f},{derived}"
