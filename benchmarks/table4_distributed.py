"""Paper Table 4: distributed CG throughput/memory under a fixed iteration
budget (the paper runs 1000 Jacobi-CG iterations at 1e8–4e8 DOF on H200s;
here: 8 forced host devices, CPU-scaled DOF, 200-iteration budget).

Reports time, per-shard memory estimate, residual-after-budget — plus the
pipelined-CG variant (beyond-paper: one fused reduction/iteration) and the
halo-byte count per iteration.  Runs in a subprocess so the parent keeps its
single-device view."""
import os
import subprocess
import sys
import textwrap

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

SRC = textwrap.dedent("""
    import time
    import jax, numpy as np, jax.numpy as jnp
    jax.config.update("jax_enable_x64", True)
    from repro.core.distributed import DSparseTensor
    from repro.data.poisson import poisson2d

    mesh = jax.make_mesh((8,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    for ng in (64, 128, 256):
        n = ng * ng
        A = poisson2d(ng, dtype=np.float64)
        D = DSparseTensor.from_global(np.asarray(A.val), np.asarray(A.row),
                                      np.asarray(A.col), A.shape, mesh)
        b = D.stack_vector(np.ones(n))
        for pipelined in (False, True):
            solve = jax.jit(lambda bb: D.solve(bb, tol=0.0, atol=1e-300,
                                               maxiter=200,
                                               pipelined=pipelined))
            jax.block_until_ready(solve(b))
            t0 = time.perf_counter()
            x = solve(b)
            jax.block_until_ready(x)
            dt = time.perf_counter() - t0
            xg = D.gather_global(x)
            res = float(np.abs(np.asarray(
                poisson2d(ng, dtype=np.float64) @ jnp.asarray(xg))
                - 1.0).max())
            shard_mem = (D.meta.nnz_loc * 16 + 6 * D.meta.n_loc * 8)
            halo = (D.meta.h_lo + D.meta.h_hi) * 8
            tag = "pipelined" if pipelined else "standard"
            print(f"ROW,table4/{tag}/dof={n},{dt/200*1e6:.1f},"
                  f"residual_after_budget={res:.1e};"
                  f"mem_per_shard={shard_mem/2**20:.2f}MiB;"
                  f"halo_bytes_per_iter={halo};dof_per_s={n*200/dt:.2e}")
""")


def run():
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=os.path.join(REPO, "src"))
    proc = subprocess.run([sys.executable, "-c", SRC], capture_output=True,
                          text=True, env=env, timeout=1200)
    if proc.returncode != 0:
        return [f"table4/ERROR,0,{proc.stderr[-300:]}"]
    return [line[4:] for line in proc.stdout.splitlines()
            if line.startswith("ROW,")]


if __name__ == "__main__":
    print("\n".join(run()))
