"""Paper Table 4: distributed CG throughput/memory under a fixed iteration
budget (the paper runs 1000 Jacobi-CG iterations at 1e8–4e8 DOF on H200s;
here: 8 forced host devices, CPU-scaled DOF, 200-iteration budget).

Reports time, per-shard memory estimate, residual-after-budget — plus the
pipelined-CG variant (beyond-paper: one fused reduction/iteration) and the
halo-byte count per iteration.  PR 3 adds the plan-engine columns: analyze
count and setup reuse across a 3-solve tolerance sweep (``PLAN_STATS``) and
the setup-amortization ratio (first solve incl. analyze+setup vs steady-state
re-solve on the cached plan).  Runs in a subprocess so the parent keeps its
single-device view."""
import os
import subprocess
import sys
import textwrap

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

SRC = textwrap.dedent("""
    import time
    import jax, numpy as np, jax.numpy as jnp
    jax.config.update("jax_enable_x64", True)
    from repro.core import PLAN_STATS, reset_plan_stats
    from repro.core.distributed import DSparseTensor
    from repro.data.poisson import poisson2d

    SMOKE = bool(int("%(smoke)d"))
    grids = (48, 96) if SMOKE else (64, 128, 256)
    budget = 100 if SMOKE else 200

    mesh = jax.make_mesh((8,), ("data",))
    for ng in grids:
        n = ng * ng
        A = poisson2d(ng, dtype=np.float64)
        D = DSparseTensor.from_global(np.asarray(A.val), np.asarray(A.row),
                                      np.asarray(A.col), A.shape, mesh)
        b = D.stack_vector(np.ones(n))
        for pipelined in (False, True):
            solve = jax.jit(lambda bb: D.solve(bb, tol=0.0, atol=1e-300,
                                               maxiter=budget,
                                               pipelined=pipelined))
            jax.block_until_ready(solve(b))
            t0 = time.perf_counter()
            x = solve(b)
            jax.block_until_ready(x)
            dt = time.perf_counter() - t0
            xg = D.gather_global(x)
            res = float(np.abs(np.asarray(
                poisson2d(ng, dtype=np.float64) @ jnp.asarray(xg))
                - 1.0).max())
            shard_mem = (D.meta.nnz_loc * 16 + 6 * D.meta.n_loc * 8)
            halo = (D.meta.h_lo + D.meta.h_hi) * 8
            tag = "pipelined" if pipelined else "standard"
            print(f"ROW,table4/{tag}/dof={n},{dt/budget*1e6:.1f},"
                  f"residual_after_budget={res:.1e};"
                  f"mem_per_shard={shard_mem/2**20:.2f}MiB;"
                  f"halo_bytes_per_iter={halo};dof_per_s={n*budget/dt:.2e}")

        # Schwarz ladder (PR 4): one-level vs two-level (deflated coarse
        # correction on cached direct factors) — iterations + per-solve time
        # at a fixed tolerance; the coarse solve must BUY its extra
        # all_gather per iteration with fewer iterations
        bsz = D.stack_vector(np.random.default_rng(3).normal(size=n))
        for pname in ("jacobi", "schwarz", "schwarz2"):
            solve = jax.jit(lambda lv, bb, pname=pname: D.with_values(lv)
                            .solve_with_info(bb, tol=1e-8, maxiter=4000,
                                             precond=pname))
            jax.block_until_ready(solve(D.lval, bsz))  # warm (incl. analyze)
            t0 = time.perf_counter()
            x, info = solve(D.lval, bsz)
            jax.block_until_ready(x)
            dt = time.perf_counter() - t0
            print(f"ROW,table4/{pname}/dof={n},{dt*1e6:.1f},"
                  f"iters={int(info.iters)};converged={bool(info.converged)}")

        # plan-engine amortization: cold first solve (analyze + setup) vs
        # steady-state re-solves on the cached plan, counters proving the
        # tolerance sweep analyzes once and reuses the per-values setup
        Dp = DSparseTensor.from_global(np.asarray(A.val), np.asarray(A.row),
                                       np.asarray(A.col), A.shape, mesh)
        reset_plan_stats()
        t0 = time.perf_counter()
        jax.block_until_ready(Dp.solve(b, tol=1e-8, maxiter=budget))
        t_first = time.perf_counter() - t0
        t0 = time.perf_counter()
        for tol in (1e-6, 1e-8):
            jax.block_until_ready(Dp.solve(b, tol=tol, maxiter=budget))
        t_steady = (time.perf_counter() - t0) / 2
        print(f"ROW,table4/plan/dof={n},{t_steady*1e6:.1f},"
              f"analyze={PLAN_STATS['analyze']};"
              f"setup_reuse={PLAN_STATS['setup_reuse']};"
              f"cache_hit={PLAN_STATS['cache_hit']};"
              f"amortization=x{t_first/max(t_steady,1e-9):.1f};"
              f"t_first_us={t_first*1e6:.1f}")
""")


def run(full: bool = False, smoke: bool = False):
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=os.path.join(REPO, "src"))
    src = SRC % {"smoke": 1 if smoke else 0}
    proc = subprocess.run([sys.executable, "-c", src], capture_output=True,
                          text=True, env=env, timeout=1200)
    if proc.returncode != 0:
        # raise so benchmarks.run counts the suite as failed and exits
        # nonzero — the bench-smoke CI gate must go red, not print a row
        raise RuntimeError(f"table4 subprocess failed: {proc.stderr[-300:]}")
    return [line[4:] for line in proc.stdout.splitlines()
            if line.startswith("ROW,")]


if __name__ == "__main__":
    print("\n".join(run(smoke="--smoke" in sys.argv)))
