"""SpMV kernel-plan comparison: segment-sum COO vs block-ELL Pallas, plus
the fused CG step vs the separate-pass loop.

Three row families on the 2D Poisson ladder (CPU: Pallas runs in interpret
mode, so the BELL/fused timings are correctness-trajectory rows, not perf —
the perf claim is carried by the roofline byte model, asserted below):

* ``spmv/segment_sum`` / ``spmv/bell`` — one matvec through each kernel.
* ``spmv/cg_plain`` / ``spmv/cg_fused`` — one full CG solve with the fused
  step kernels forced off/on through the SAME pallas kernel plan.
* ``spmv/fused_step_model`` — the roofline byte model of one CG iteration:
  ``launch.roofline.assert_fused_step_savings`` raises (→ suite fails, CI
  red) unless the fused step stays under 0.5× the separate-pass baseline
  and the baseline matches the compiled-HLO measurement.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dispatch as _dispatch
from repro.core import options as _options
from repro.core.adjoint import sparse_solve_with_info
from repro.core.dispatch import get_plan, make_config
from repro.core.sparse import coo_matvec
from repro.data.poisson import poisson2d
from repro.launch.roofline import assert_fused_step_savings

from .common import csv_row, timeit

SMOKE_LADDER = [16]                 # 256 DOF — interpret-mode Pallas is slow
LADDER = [16, 32]
FULL_LADDER = [16, 32, 64]


def run(full: bool = False, smoke: bool = False):
    rows = []
    ladder = SMOKE_LADDER if smoke else (FULL_LADDER if full else LADDER)
    for ng in ladder:
        n = ng * ng
        A = poisson2d(ng, dtype=np.float64)
        b = jnp.ones(n)

        t, _ = timeit(jax.jit(
            lambda v, x: coo_matvec(v, A.row, A.col, x, n)), A.val, b)
        rows.append(csv_row(f"spmv/segment_sum/dof={n}", t * 1e6,
                            f"nnz={A.nnz}"))

        cfg = make_config(A, backend="pallas", method="cg", tol=1e-8,
                          maxiter=2000)
        plan = get_plan(A, cfg)                  # analyze: BELL built once
        kp = plan.artifacts["kernel"]
        mv = jax.jit(lambda x: _dispatch._plan_matvec(plan, kp, A.val)(x))
        t, y = timeit(mv, b)
        err = float(jnp.linalg.norm(y - coo_matvec(A.val, A.row, A.col, b, n)))
        rows.append(csv_row(f"spmv/bell/dof={n}", t * 1e6,
                            f"fill={kp.bell[0].fill:.4f};err={err:.1e}"))

        for label, mode in (("cg_plain", "off"), ("cg_fused", "on")):
            with _options.options(fused_step=mode):
                t, (x, info) = timeit(jax.jit(
                    lambda val, bb: sparse_solve_with_info(
                        cfg, A.with_values(val), bb)), A.val, b)
            rows.append(csv_row(
                f"spmv/{label}/dof={n}", t * 1e6,
                f"residual={float(info.resnorm):.1e};iters={int(info.iters)}"))

    model = assert_fused_step_savings()          # raises → CI red
    rows.append(csv_row(
        "spmv/fused_step_model", 0.0,
        f"ratio={model['ratio']:.3f};"
        f"baseline_bytes={model['baseline_bytes']:.0f};"
        f"fused_bytes={model['fused_step_bytes']:.0f};"
        f"iteration_ratio={model['iteration_ratio']:.3f};"
        f"measured_baseline={model['measured_baseline_bytes']:.0f}"))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
