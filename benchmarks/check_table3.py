"""Bench-smoke gate over the table3 artifact (CI goes red on regression).

    PYTHONPATH=src python -m benchmarks.check_table3 BENCH_table3.json

Asserts the PR-9 supernodal claims hold on every run:

- supernodal numeric factorize >= ``MIN_FACTOR_SPEEDUP`` x the scalar
  packed-scan at the largest smoke rung (the paper-scale claim is 10x at
  n=10⁵; the CI smoke rung n=10⁴ must clear 3x);
- the ``budget_probe`` row exists, was routed to the direct backend by the
  raised ``direct_budget`` (10⁵ — n=40K sat above the old 24576 crossover),
  and its solve completed with a small residual.
"""
import json
import re
import sys

MIN_FACTOR_SPEEDUP = 3.0
MAX_PROBE_RESIDUAL = 1e-5


def _derived(row):
    return dict(kv.split("=", 1) for kv in row["derived"].split(";")
                if "=" in kv)


def check(path: str) -> None:
    with open(path) as f:
        data = json.load(f)
    rows = data["rows"]

    by_dof = {}
    for row in rows:
        m = re.match(r"table3/(factor_supernodal|factor_scalar)/dof=(\d+)",
                     row["name"])
        if m:
            by_dof.setdefault(int(m.group(2)), {})[m.group(1)] = row
    pairs = {d: r for d, r in by_dof.items()
             if "factor_supernodal" in r and "factor_scalar" in r}
    if not pairs:
        raise SystemExit("check_table3: no supernodal/scalar factor row "
                         "pairs in the artifact")
    dof = max(pairs)
    sn = pairs[dof]["factor_supernodal"]["us_per_call"]
    sc = pairs[dof]["factor_scalar"]["us_per_call"]
    speedup = sc / max(sn, 1e-9)
    print(f"check_table3: dof={dof} supernodal factorize {sn:.0f}us vs "
          f"scalar {sc:.0f}us -> {speedup:.2f}x "
          f"(gate {MIN_FACTOR_SPEEDUP:.1f}x)")
    if speedup < MIN_FACTOR_SPEEDUP:
        raise SystemExit(
            f"check_table3: supernodal factorize speedup {speedup:.2f}x "
            f"< {MIN_FACTOR_SPEEDUP:.1f}x at dof={dof}")

    probes = [r for r in rows if r["name"].startswith("table3/budget_probe/")]
    if not probes:
        raise SystemExit("check_table3: budget_probe row missing — the "
                         "raised direct_budget solve did not run")
    d = _derived(probes[0])
    if d.get("backend") != "direct":
        raise SystemExit(
            f"check_table3: budget_probe auto-dispatched to "
            f"{d.get('backend')!r}, expected 'direct' (direct_budget="
            f"{d.get('budget')})")
    res = float(d.get("residual", "inf"))
    if not res < MAX_PROBE_RESIDUAL:
        raise SystemExit(
            f"check_table3: budget_probe residual {res:.1e} >= "
            f"{MAX_PROBE_RESIDUAL:.0e}")
    print(f"check_table3: budget_probe ok (backend=direct, "
          f"residual={res:.1e}, budget={d.get('budget')})")


if __name__ == "__main__":
    check(sys.argv[1] if len(sys.argv) > 1 else "BENCH_table3.json")
