"""Regularized p-Laplacian on an unstructured graph via SparseNewton.

    PYTHONPATH=src python examples/p_laplacian.py

The problem: find u with

    F(u) = L u + θ φ(u) + γ u − f = 0,
    φ(t) = (t² + ε)^((p−2)/2) · t                     (p > 2, ε > 0)

on a random geometric graph — graph diffusion with a regularized p-type
zero-order nonlinearity whose θ → 0 limit is the ordinary graph-Laplacian
solve.  The Jacobian L + diag(θ φ′(u) + γ) changes values every Newton
step but is symmetric positive definite with the mesh's sparsity: exactly
the graph Laplacian pattern.  SparseNewton exploits that the way the
linear plan engine does —

* the pattern is colored ONCE (PLAN_STATS["jac_color"]); each step recovers
  the exact Jacobian values with one vmapped jvp probe sweep
  (PLAN_STATS["jac_assemble"]);
* ONE analyzed plan (here CG + smoothed-aggregation AMG) serves every step:
  PLAN_STATS["analyze"] == 1 across the whole sweep, the numeric Galerkin
  refresh runs once per step through the setup memo;
* the implicit-function-theorem backward solves Jᵀλ = g through
  plan.transpose() on the converged step's hierarchy — zero extra
  coarsening/refresh, and the θ-gradient costs ONE linear solve no matter
  how many Newton steps the forward took.
"""
import jax
import jax.numpy as jnp
import numpy as np

jax.config.update("jax_enable_x64", True)

from repro import sla
from repro.core import PLAN_STATS, reset_plan_stats
from repro.core.dispatch import SolverConfig
from repro.data.graphs import graph_laplacian

# -- mesh: random geometric graph, n >= 10^4 --------------------------------
n = 10_000
L = graph_laplacian(n, seed=3)          # SPD graph Laplacian (+ small shift)
print(f"mesh: n={n}, nnz={L.nnz} (~{L.nnz / n:.1f} per row)")

p, eps_reg, gamma = 3.0, 1e-4, 1e-2
f = jnp.asarray(np.random.default_rng(0).normal(size=n)) * 1e-2

# Pointwise regularized p-term: F = L u + θ φ(u) + γ u − f with
# φ(t) = (t² + ε)^((p−2)/2) t.  The Jacobian L + diag(θ φ′(u) + γ) is
# symmetric positive definite (φ′ > 0) and lives EXACTLY on L's pattern —
# that symmetry is what lets CG + AMG serve every Newton step.  (Putting
# φ inside the divergence, L @ φ(u), would make J = L·diag(φ′)
# nonsymmetric and CG inapplicable — use backend="direct" for that form.)
def residual(u, theta):
    return L @ u + theta * ((u ** 2 + eps_reg) ** ((p - 2) / 2)) * u \
        + gamma * u - f


# -- forward: Newton with AMG inner solves through ONE plan -----------------
cfg = SolverConfig(backend="jnp", method="cg", precond="amg",
                   tol=1e-12, maxiter=600)
theta = jnp.asarray(1.0)

reset_plan_stats()
u = sla.nonlinear_solve(residual, jnp.zeros(n), theta,
                        jac_pattern=L, linear_solver=cfg, tol=1e-10)
print(f"forward: |F(u*)| = {float(jnp.linalg.norm(residual(u, theta))):.2e} "
      f"after {PLAN_STATS['jac_assemble']} Newton steps")
print(f"  analyze={PLAN_STATS['analyze']} (one symbolic AMG hierarchy)",
      f"coarsen={PLAN_STATS['coarsen']}",
      f"galerkin={PLAN_STATS['galerkin']} (numeric refresh per step)",
      f"jac_color={PLAN_STATS['jac_color']}")

# -- backward: IFT adjoint on the converged step's hierarchy ----------------
# NOTE: no reset — the cached plan keeps serving; analyze stays 1 across
# the forward above, the gradient below, AND the FD corroboration solves.


def loss(theta):
    u = sla.nonlinear_solve(residual, jnp.zeros(n), theta,
                            jac_pattern=L, linear_solver=cfg, tol=1e-10)
    return jnp.sum(u ** 2)


g = jax.grad(loss)(theta)
print(f"dloss/dθ = {float(g):+.6e}")
print(f"  analyze={PLAN_STATS['analyze']} across forward AND backward,",
      f"transpose_shared={PLAN_STATS['transpose_shared']} (Jᵀλ = g reused "
      f"the forward plan),",
      f"setup_reuse={PLAN_STATS['setup_reuse']} (the converged step's "
      f"hierarchy served the adjoint)")

# central FD corroboration (reuses the SAME cached plan — analyze stays 1)
eps = 1e-4
fd = (loss(theta + eps) - loss(theta - eps)) / (2 * eps)
print(f"  vs central FD {float(fd):+.6e} "
      f"(rel err {abs(float(g - fd)) / abs(float(fd)):.1e}, "
      f"analyze still {PLAN_STATS['analyze']})")
