"""End-to-end LM training driver with fault tolerance.

Default: a ~100M-parameter llama-family model for a few hundred steps on CPU
with checkpoint/restart (kill it mid-run and re-invoke with --resume).

    PYTHONPATH=src python examples/train_lm.py --steps 200
    PYTHONPATH=src python examples/train_lm.py --steps 200 --resume
    PYTHONPATH=src python examples/train_lm.py --fail-at 120   # FT demo
"""
import argparse
import dataclasses
import sys

sys.argv = sys.argv  # keep argparse happy under -m

from repro.configs import get_config
from repro.launch import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--fail-at", type=int, default=None)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm_100m")
    args = ap.parse_args()

    # ~100M-param llama-family config (decoder, GQA, tied embeddings)
    base = get_config("llama3.2-1b")
    cfg = dataclasses.replace(
        base, name="llama-100m", n_layers=8, d_model=768, n_heads=12,
        n_kv_heads=4, d_ff=2048, vocab=32_000, head_dim=64,
        dtype="float32", param_dtype="float32", remat="none")

    import repro.configs as C
    C.REGISTRY[cfg.name] = cfg
    argv = ["--arch", cfg.name, "--steps", str(args.steps),
            "--batch", str(args.batch), "--seq", str(args.seq),
            "--ckpt-dir", args.ckpt_dir, "--ckpt-every", "50",
            "--lr", "3e-4"]
    if args.resume:
        argv.append("--resume")
    if args.fail_at is not None:
        argv += ["--fail-at", str(args.fail_at)]
    train.main(argv)


if __name__ == "__main__":
    main()
