"""Quickstart — the paper's Listing 1 in JAX.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

jax.config.update("jax_enable_x64", True)

from repro.core import SparseTensor, SparseTensorList, nonlinear_solve
from repro.data.poisson import poisson2d

# 1. single solve with auto-dispatched backend ------------------------------
A = poisson2d(32)                       # 1024-dof SPD matrix, COO
b = jnp.ones(A.shape[0])
x = A.solve(b)                          # dense (small) / sparse-direct (mid) / CG (large)
print("solve residual:", float(jnp.linalg.norm(A @ x - b)))

# gradients flow through the solve with an O(1) graph ------------------------
def loss(val, b):
    return jnp.sum(A.with_values(val).solve(b) ** 2)

g_val, g_b = jax.grad(loss, (0, 1))(A.val, b)
print("grad shapes:", g_val.shape, g_b.shape)

# 2. explicit backend / method override --------------------------------------
x_cg = A.solve(b, backend="jnp", method="cg", tol=1e-12)
x_bi = A.solve(b, backend="jnp", method="bicgstab", tol=1e-12)
print("cg vs bicgstab:", float(jnp.max(jnp.abs(x_cg - x_bi))))

# sparse direct (the cuDSS-analogue backend): the symbolic factorization is
# analyzed once per sparsity pattern and cached on the plan; re-solves and
# gradients refactorize numerically at most once per values array
x_dir = A.solve(b, backend="direct")        # LDLT (symmetric values)
print("direct vs cg:", float(jnp.max(jnp.abs(x_dir - x_cg))))

# ILU(0) preconditioning shares the same symbolic machinery
x_ilu = A.solve(b, backend="jnp", method="cg", tol=1e-12, precond="ilu")
print("ilu-cg residual:", float(jnp.linalg.norm(A @ x_ilu - b)))

# 3. batched solve with shared sparsity pattern ------------------------------
vals = jnp.stack([A.val, 2.0 * A.val, 3.0 * A.val])
Ab = SparseTensor(vals, A.row, A.col, A.shape, props=A.props)
xb = Ab.solve(jnp.stack([b, b, b]), backend="jnp", method="cg")
print("batched solve:", xb.shape)

# 4. nonlinear solve with adjoint gradients ----------------------------------
def residual(u, val, f):
    return A.with_values(val) @ u + u ** 3 - f

u = nonlinear_solve(residual, jnp.zeros(A.shape[0]), A.val, b,
                    method="newton", tol=1e-12)
print("newton residual:", float(jnp.linalg.norm(residual(u, A.val, b))))

# 5. eigenpairs with Hellmann–Feynman gradients ------------------------------
w, V = A.eigsh(k=4, tol=1e-10)
print("eigenvalues:", np.asarray(w).round(6))
g = jax.grad(lambda v: A.with_values(v).eigsh(k=2)[0][0])(A.val)
print("dλ₀/dval is on the pattern:", g.shape == A.val.shape)

# 6. distinct patterns (SparseTensorList) ------------------------------------
mats = [poisson2d(n) for n in (8, 12, 16)]
xs = SparseTensorList(mats).solve([jnp.ones(m.shape[0]) for m in mats])
print("list solve sizes:", [x.shape[0] for x in xs])
