"""Quickstart — the paper's Listing 1 in JAX.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

jax.config.update("jax_enable_x64", True)

from repro.core import SparseTensor, SparseTensorList, nonlinear_solve
from repro.data.poisson import poisson2d

# 1. single solve with auto-dispatched backend ------------------------------
A = poisson2d(32)                       # 1024-dof SPD matrix, COO
b = jnp.ones(A.shape[0])
x = A.solve(b)                          # dense (small) / sparse-direct (mid) / CG (large)
print("solve residual:", float(jnp.linalg.norm(A @ x - b)))

# gradients flow through the solve with an O(1) graph ------------------------
def loss(val, b):
    return jnp.sum(A.with_values(val).solve(b) ** 2)

g_val, g_b = jax.grad(loss, (0, 1))(A.val, b)
print("grad shapes:", g_val.shape, g_b.shape)

# 2. explicit backend / method override --------------------------------------
x_cg = A.solve(b, backend="jnp", method="cg", tol=1e-12)
x_bi = A.solve(b, backend="jnp", method="bicgstab", tol=1e-12)
print("cg vs bicgstab:", float(jnp.max(jnp.abs(x_cg - x_bi))))

# sparse direct (the cuDSS-analogue backend): the symbolic factorization —
# quotient-graph AMD ordering + an etree-derived fill pattern (ordering="md"
# retains exact minimum degree for A/B runs) — is analyzed once per sparsity
# pattern and cached on the plan; re-solves and gradients refactorize
# numerically at most once per values array
x_dir = A.solve(b, backend="direct")        # LDLT (symmetric values)
print("direct vs cg:", float(jnp.max(jnp.abs(x_dir - x_cg))))

# ILU(0) preconditioning shares the same symbolic machinery
x_ilu = A.solve(b, backend="jnp", method="cg", tol=1e-12, precond="ilu")
print("ilu-cg residual:", float(jnp.linalg.norm(A @ x_ilu - b)))

# 2b. algebraic multigrid on an UNSTRUCTURED pattern ------------------------
# precond="amg" is smoothed-aggregation AMG living entirely in the plan
# engine: analyze coarsens the sparsity pattern once (greedy aggregation +
# static Galerkin index programs, PLAN_STATS["coarsen"]), setup evaluates
# the numeric hierarchy per values array (PLAN_STATS["galerkin"], memoized),
# and the V-cycle bottoms out in the direct backend's cached LDLT.  It needs
# no grid — here a graph Laplacian the geometric mg cannot touch:
from repro.core import PLAN_STATS, reset_plan_stats
from repro.data.graphs import graph_laplacian

G = graph_laplacian(2000, seed=0, shift=1e-3)     # random geometric graph
bg = jnp.asarray(np.random.default_rng(0).normal(size=G.shape[0]))
reset_plan_stats()
from repro.core.adjoint import sparse_solve_with_info
from repro.core.dispatch import make_config
_, ij = sparse_solve_with_info(make_config(G, backend="jnp", method="cg",
                                           tol=1e-8, maxiter=40000), G, bg)
xg, ia = sparse_solve_with_info(make_config(G, backend="jnp", method="cg",
                                            tol=1e-8, maxiter=40000,
                                            precond="amg"), G, bg)
print(f"graph Laplacian n={G.shape[0]}: jacobi-cg {int(ij.iters)} iters, "
      f"amg-cg {int(ia.iters)} iters "
      f"(coarsen={PLAN_STATS['coarsen']}, galerkin={PLAN_STATS['galerkin']})")
# gradients flow through the AMG-preconditioned solve like any other
g_amg = jax.grad(lambda v: jnp.sum(G.with_values(v).solve(
    bg, backend="jnp", method="cg", tol=1e-10, precond="amg") ** 2))(G.val)
print("amg-preconditioned grad on the pattern:", g_amg.shape == G.val.shape)

# sparse slogdet rides the SAME cached LDLT factors (sign-tracked pivots)
sign, logabs = A.slogdet()
print("slogdet via cached LDLT:", float(sign), round(float(logabs), 6))

# 3. batched solve with shared sparsity pattern ------------------------------
vals = jnp.stack([A.val, 2.0 * A.val, 3.0 * A.val])
Ab = SparseTensor(vals, A.row, A.col, A.shape, props=A.props)
xb = Ab.solve(jnp.stack([b, b, b]), backend="jnp", method="cg")
print("batched solve:", xb.shape)

# 4. nonlinear solve with adjoint gradients ----------------------------------
def residual(u, val, f):
    return A.with_values(val) @ u + u ** 3 - f

u = nonlinear_solve(residual, jnp.zeros(A.shape[0]), A.val, b,
                    method="newton", tol=1e-12)
print("newton residual:", float(jnp.linalg.norm(residual(u, A.val, b))))

# 5. eigenpairs with Hellmann–Feynman gradients ------------------------------
w, V = A.eigsh(k=4, tol=1e-10)
print("eigenvalues:", np.asarray(w).round(6))
g = jax.grad(lambda v: A.with_values(v).eigsh(k=2)[0][0])(A.val)
print("dλ₀/dval is on the pattern:", g.shape == A.val.shape)

# 6. distinct patterns (SparseTensorList) ------------------------------------
mats = [poisson2d(n) for n in (8, 12, 16)]
xs = SparseTensorList(mats).solve([jnp.ones(m.shape[0]) for m in mats])
print("list solve sizes:", [x.shape[0] for x in xs])

# 7. distributed solve on a mesh — the analyze/setup/solve lifecycle ---------
# DSparseTensor is a first-class citizen of the plan engine: the FIRST solve
# analyzes the (pattern, mesh, partition) once — partition bounds, the halo
# program (ppermute perms frozen eagerly), the Aᵀ partition for
# non-symmetric adjoints, and the distributed preconditioner build — and
# every later solve (tolerance sweeps, with_values refreshes, the adjoint
# backward of jax.grad) reuses the cached plan.  Run with
# XLA_FLAGS=--xla_force_host_platform_device_count=8 for a real 8-shard
# mesh (see examples/distributed_poisson.py); a 1-device mesh shows the
# identical lifecycle here.
from repro.core import DSparseTensor, PLAN_STATS, reset_plan_stats

mesh = jax.sharding.Mesh(np.array(jax.devices()[:1]), ("data",))
Ad = poisson2d(24)
D = DSparseTensor.from_global(np.asarray(Ad.val), np.asarray(Ad.row),
                              np.asarray(Ad.col), Ad.shape, mesh)
bd = D.stack_vector(np.ones(Ad.shape[0]))

reset_plan_stats()
for tol in (1e-4, 1e-8, 1e-12):        # ❶ analyze once, ❷ setup memoized,
    xd = D.solve(bd, tol=tol)          # ❸ shard_map'd CG per call
gd = jax.grad(lambda lv: jnp.sum(D.with_values(lv).solve(bd) ** 2))(D.lval)
print("distributed sweep+grad:", f"analyses={PLAN_STATS['analyze']}",
      f"setup_reuse={PLAN_STATS['setup_reuse']}",
      f"transpose_shared={PLAN_STATS['transpose_shared']}")

# shard-local overlapping Schwarz (ILU(0) subdomain solves on the direct
# machinery) — far fewer CG iterations than point Jacobi on PDE problems
x_sz, info = D.solve_with_info(bd, tol=1e-10, precond="schwarz")
print("schwarz iters:", int(info.iters), "converged:", bool(info.converged))
