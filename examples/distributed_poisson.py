"""Distributed Poisson solve with halo exchange (paper §3.3) on 8 forced
host devices — run AS A SCRIPT (device count must be set before jax loads):

    PYTHONPATH=src python examples/distributed_poisson.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import jax.numpy as jnp
import numpy as np

jax.config.update("jax_enable_x64", True)

from repro.core.distributed import DSparseTensor
from repro.core.sparse import SparseTensor
from repro.data.poisson import poisson2d

ng = 96
n = ng * ng
A = poisson2d(ng)
mesh = jax.make_mesh((8,), ("data",),
                     axis_types=(jax.sharding.AxisType.Auto,))
D = DSparseTensor.from_global(np.asarray(A.val), np.asarray(A.row),
                              np.asarray(A.col), A.shape, mesh)
print(f"partitioned {n} dof over {D.meta.p} shards "
      f"(halo ±{D.meta.h_lo}/{D.meta.h_hi} rows)")

b = D.stack_vector(np.ones(n))
x = D.solve(b, tol=1e-10, maxiter=5000)
xg = D.gather_global(x)
print("residual:", float(np.abs(np.asarray(A @ jnp.asarray(xg)) - 1).max()))

# gradients through the distributed solve (transposed halo exchange)
def loss(lval):
    A2 = DSparseTensor(D.meta, lval, D.lrow, D.lcol, D.mesh)
    return jnp.sum(A2.solve(b, tol=1e-11, maxiter=5000) ** 2)

g = jax.grad(loss)(D.lval)
print("grad through distributed solve:", g.shape,
      bool(jnp.all(jnp.isfinite(g))))

# pipelined CG (beyond-paper): one fused reduction per iteration
xp = D.solve(b, tol=1e-10, maxiter=5000, pipelined=True)
print("pipelined residual:", float(np.abs(np.asarray(
    A @ jnp.asarray(D.gather_global(xp))) - 1).max()))

# distributed eigensolve
w, V = D.eigsh(k=3, tol=1e-8, maxiter=1500)
print("smallest eigenvalues:", np.asarray(w).round(8))
