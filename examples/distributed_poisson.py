"""Distributed Poisson solve with halo exchange (paper §3.3) on 8 forced
host devices — run AS A SCRIPT (device count must be set before jax loads):

    PYTHONPATH=src python examples/distributed_poisson.py

The solve routes through the plan engine's ``dist`` backend: analyze runs
once per (pattern, mesh, partition) and freezes the halo program, partition
bounds, Aᵀ partition and preconditioner build; setup is the per-values
refresh memoized per values array; solve is the shard_map'd Krylov loop.
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import jax.numpy as jnp
import numpy as np

jax.config.update("jax_enable_x64", True)

from repro.core import PLAN_STATS, reset_plan_stats
from repro.core.distributed import DSparseTensor
from repro.core.sparse import SparseTensor
from repro.data.poisson import poisson2d

ng = 96
n = ng * ng
A = poisson2d(ng)
mesh = jax.make_mesh((8,), ("data",))
D = DSparseTensor.from_global(np.asarray(A.val), np.asarray(A.row),
                              np.asarray(A.col), A.shape, mesh)
print(f"partitioned {n} dof over {D.meta.p} shards "
      f"(halo ±{D.meta.h_lo}/{D.meta.h_hi} rows)")

# the analyze stage is addressable on its own — and cached
reset_plan_stats()
plan = D.plan(tol=1e-10)
print("plan:", plan.cfg.backend, plan.cfg.method,
      "halo program:", plan.artifacts["halo"])

b = D.stack_vector(np.ones(n))
for tol in (1e-6, 1e-8, 1e-10):                  # tolerance sweep: 1 analysis
    x = D.solve(b, tol=tol, maxiter=5000)
xg = D.gather_global(x)
print("residual:", float(np.abs(np.asarray(A @ jnp.asarray(xg)) - 1).max()))
print("sweep plan stats:", f"analyze={PLAN_STATS['analyze']}",
      f"cache_hit={PLAN_STATS['cache_hit']}",
      f"setup_reuse={PLAN_STATS['setup_reuse']}")

# gradients through the distributed solve (transposed halo exchange); the
# with_values view shares the plan cache, so the backward re-analyzes nothing
def loss(lval):
    return jnp.sum(D.with_values(lval).solve(b, tol=1e-11, maxiter=5000) ** 2)

g = jax.grad(loss)(D.lval)
print("grad through distributed solve:", g.shape,
      bool(jnp.all(jnp.isfinite(g))))

# shard-local overlapping Schwarz (ILU(0) subdomain solves reusing the
# direct backend's symbolic machinery) vs point Jacobi — and the two-level
# variant: precond="schwarz2" adds a symmetric deflated coarse correction
# (the global pattern aggregated by the AMG machinery, its Galerkin matrix
# factored once through core/direct.py) so iteration counts stay flat as
# the shard count grows
_, ij = D.solve_with_info(b, tol=1e-8, maxiter=5000)
_, isz = D.solve_with_info(b, tol=1e-8, maxiter=5000, precond="schwarz")
_, is2 = D.solve_with_info(b, tol=1e-8, maxiter=5000, precond="schwarz2")
print(f"CG iterations   jacobi={int(ij.iters)}  schwarz={int(isz.iters)}"
      f"  schwarz2={int(is2.iters)}")

# pipelined CG (beyond-paper): one fused reduction per iteration
xp = D.solve(b, tol=1e-10, maxiter=5000, pipelined=True)
print("pipelined residual:", float(np.abs(np.asarray(
    A @ jnp.asarray(D.gather_global(xp))) - 1).max()))

# distributed eigensolve
w, V = D.eigsh(k=3, tol=1e-8, maxiter=1500)
print("smallest eigenvalues:", np.asarray(w).round(8))
