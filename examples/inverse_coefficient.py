"""Paper §4.4 end-to-end: learn κ(x) in −∇·(κ∇u)=f from observations of u.

The only solver-specific line in the training loop is ``A.solve(f)`` —
gradients flow through the adjoint path (§3.2) into the κ parametrization.

    PYTHONPATH=src python examples/inverse_coefficient.py [steps]
"""
import sys

import jax

jax.config.update("jax_enable_x64", True)

from benchmarks.fig3_inverse import run

if __name__ == "__main__":
    steps = int(sys.argv[1]) if len(sys.argv) > 1 else 1500
    for row in run(steps=steps):
        print(row)
